#!/usr/bin/env bash
# Tier-1 verification in one command:
#   ./ci.sh            build + full test suite + live-subsystem integration
#                      test (+ fmt check when rustfmt is present)
#   AIDW_CI_STRICT=1 ./ci.sh   make formatting drift fatal
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# The live mutation subsystem (epoch/delta/WAL) is tier-1: run its
# integration test explicitly so a test-filter or harness change can never
# silently drop the kill-and-restart / compaction-consistency coverage.
echo "== cargo test -q --test it_live =="
cargo test -q --test it_live

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    # Part of tier-1, but fatal only under AIDW_CI_STRICT=1: rustfmt output
    # differs across toolchain versions, and tier-1 must not brick on a
    # formatting disagreement between contributor toolchains.
    if ! cargo fmt --check; then
        if [ "${AIDW_CI_STRICT:-0}" = "1" ]; then
            echo "FAIL: formatting drift (AIDW_CI_STRICT=1)"
            exit 1
        fi
        echo "WARN: formatting drift (non-fatal; set AIDW_CI_STRICT=1 to enforce)"
    fi
else
    echo "rustfmt unavailable; skipping format check"
fi

echo "ci.sh: OK"
