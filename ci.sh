#!/usr/bin/env bash
# Tier-1 verification in one command:
#   ./ci.sh            build + test (+ fmt check when rustfmt is present)
#   AIDW_CI_STRICT=1 ./ci.sh   make formatting drift fatal
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    if ! cargo fmt --check; then
        if [ "${AIDW_CI_STRICT:-0}" = "1" ]; then
            echo "FAIL: formatting drift (AIDW_CI_STRICT=1)"
            exit 1
        fi
        echo "WARN: formatting drift (non-fatal; set AIDW_CI_STRICT=1 to enforce)"
    fi
else
    echo "rustfmt unavailable; skipping format check"
fi

echo "ci.sh: OK"
