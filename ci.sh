#!/usr/bin/env bash
# Tier-1 verification in one command:
#   ./ci.sh            build + full test suite + the live-subsystem and
#                      planner integration tests + the `aidw tidy` static
#                      analysis gate (+ fmt/clippy gates when the tools
#                      are present)
#   AIDW_CI_STRICT=1 ./ci.sh     make fmt/clippy drift fatal
#   AIDW_CI_SANITIZE=1 ./ci.sh   also run live/subscribe unit tests under
#                                Miri/TSan when a nightly toolchain exists
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# The live mutation subsystem (epoch/delta/WAL) is tier-1: run its
# integration test explicitly so a test-filter or harness change can never
# silently drop the kill-and-restart / compaction-consistency coverage.
echo "== cargo test -q --test it_live =="
cargo test -q --test it_live

# The two-stage execution planner is tier-1 for the same reason: the
# coalescing / neighbor-cache / bit-identity property coverage must never
# be silently dropped.
echo "== cargo test -q --test it_planner =="
cargo test -q --test it_planner

# Overlay-versioned neighbor caching is tier-1: the mutated-snapshot
# cache-hit / never-stale property coverage (mutate -> query -> mutate ->
# query bit-identity) must never be silently dropped.
echo "== cargo test -q --test it_cache_live =="
cargo test -q --test it_cache_live

# The tiled streaming surface is tier-1: the streamed-equals-monolithic
# bit-identity property, the v2.3 back-compat pin, and the bounded-buffer
# acceptance assertion must never be silently dropped.
echo "== cargo test -q --test it_stream =="
cargo test -q --test it_stream

# Incremental raster subscriptions are tier-1: the materialized-view
# bit-identity property (random mutate/compact sequences vs a
# from-scratch oracle), the dirty-footprint soundness scan, and the
# drop/retire sweep coverage must never be silently dropped.
echo "== cargo test -q --test it_subscribe =="
cargo test -q --test it_subscribe

# Observability is tier-1: the traced-timeline acceptance, the v2.5
# byte-compat pin for untraced replies, the journal loss-detection
# property, and the sub-lag exposition coverage must never be silently
# dropped.
echo "== cargo test -q --test it_obs =="
cargo test -q --test it_obs

# The layout-parameterized stage-2 engine is tier-1: the cross-layout
# bit-identity property (SoA / AoSoA vs the AoS reference, dense and
# local, clean and mutated snapshots), the v2.6 no-override wire pin,
# and the neither-stage-key coalescing assertion must never be silently
# dropped.
echo "== cargo test -q --test it_layout =="
cargo test -q --test it_layout

# Sharded stage 1 + multi-tenant admission (v2.8) is tier-1: the
# sharded-equals-unsharded bit-identity property (dense/local,
# clean/mutated, shard counts {1,2,7}), the cross-shard escalation
# exactness check, the per-tenant fail-closed quota coverage (in process
# and over a raw socket), and the DRR no-starvation assertion must never
# be silently dropped.
echo "== cargo test -q --test it_shard =="
cargo test -q --test it_shard

# Metrics-exposition parity gate: every MetricsSnapshot field must appear
# in BOTH the JSON `metrics` op and the Prometheus-style `metrics_text`
# exposition, or a new counter silently ships half-observable.
echo "== metrics exposition parity gate =="
cargo test -q --lib metrics_parity

# Every examples/*.rs must be a registered [[example]] compile target, or
# `cargo build --examples` (and cargo test's example builds) silently
# skip it and it rots.
echo "== examples registration gate =="
for f in ../examples/*.rs; do
    name=$(basename "$f" .rs)
    # match the example's path line, not just any name (a [[bench]] of
    # the same name must not satisfy the gate)
    if ! grep -q "path = \"../examples/$name.rs\"" Cargo.toml; then
        echo "FAIL: examples/$name.rs is not listed as a [[example]] target in Cargo.toml"
        exit 1
    fi
done
echo "examples: all $(ls ../examples/*.rs | wc -l) source files are registered targets"
if [ "${AIDW_CI_STRICT:-0}" = "1" ]; then
    echo "== cargo build --examples (strict) =="
    cargo build --examples
fi

# Repo-invariant static analysis (fatal).  `aidw tidy` lexes this crate's
# own sources and enforces the stage-key classification contract, the
# lock-order graph, protocol doc/decoder agreement (this subsumes the old
# shell-grep version drift check), panic/print hygiene, and SAFETY
# comments — see rust/src/analysis/ for the rule docs and the
# `// tidy:allow(<rule>) -- <reason>` escape hatch.
echo "== aidw tidy (static analysis gate) =="
./target/release/aidw tidy

# Bench-smoke gate (strict only: a full bench run is too slow for every
# tier-1 pass).  `--sizes small` runs the 256/512 suite end to end and
# must emit parseable JSON with a non-empty `layout` section, so the
# layout ablation axis can never silently fall out of BENCH_aidw.json.
if [ "${AIDW_CI_STRICT:-0}" = "1" ]; then
    echo "== bench smoke (strict): --sizes small =="
    smoke_out=$(mktemp /tmp/aidw_bench_smoke.XXXXXX.json)
    cargo run --release --bin aidw -- bench --sizes small --no-serial --reps 1 --warmup 0 --out "$smoke_out"
    if command -v python3 >/dev/null 2>&1; then
        python3 - "$smoke_out" <<'PY'
import json, sys
doc = json.load(open(sys.argv[1]))
layout = doc.get("layout")
assert isinstance(layout, list) and layout, "layout section missing or empty"
for entry in layout:
    assert entry.get("layouts"), f"size {entry.get('n')}: no per-layout timings"
print(f"bench smoke: layout section covers {len(layout)} sizes")
PY
    else
        # no python3: at least pin that the section key made it to disk
        grep -q '"layout"' "$smoke_out" || {
            echo "FAIL: bench smoke output has no layout section"
            exit 1
        }
        echo "bench smoke: layout section present (python3 unavailable; shallow check)"
    fi
    rm -f "$smoke_out"
fi

# Lint gates.  Both run whenever the component is installed; they are
# fatal under AIDW_CI_STRICT=1 and advisory otherwise, because rustfmt
# output and clippy's lint set both drift across toolchain versions and
# tier-1 must not brick on a disagreement between contributor toolchains.
if cargo fmt --version >/dev/null 2>&1; then
    echo "== cargo fmt --check =="
    if ! cargo fmt --check; then
        if [ "${AIDW_CI_STRICT:-0}" = "1" ]; then
            echo "FAIL: formatting drift (AIDW_CI_STRICT=1)"
            exit 1
        fi
        echo "WARN: formatting drift (non-fatal; set AIDW_CI_STRICT=1 to enforce)"
    fi
else
    echo "rustfmt unavailable; skipping format check"
fi

if cargo clippy --version >/dev/null 2>&1; then
    echo "== cargo clippy -- -D warnings =="
    if ! cargo clippy --all-targets -- -D warnings; then
        if [ "${AIDW_CI_STRICT:-0}" = "1" ]; then
            echo "FAIL: clippy warnings (AIDW_CI_STRICT=1)"
            exit 1
        fi
        echo "WARN: clippy warnings (non-fatal; set AIDW_CI_STRICT=1 to enforce)"
    fi
else
    echo "clippy unavailable; skipping lint gate"
fi

# Sanitizer lane (opt-in: AIDW_CI_SANITIZE=1).  Runs the concurrency-heavy
# live/ and subscribe/ unit tests under Miri (preferred) or ThreadSanitizer
# when a nightly toolchain is available; skips with a notice otherwise, so
# the lane never bricks a stable-only contributor toolchain.
if [ "${AIDW_CI_SANITIZE:-0}" = "1" ]; then
    if rustup toolchain list 2>/dev/null | grep -q '^nightly' ; then
        if rustup component list --toolchain nightly 2>/dev/null | grep -q 'miri.*(installed)'; then
            echo "== miri: live/ + subscribe/ unit tests (AIDW_CI_SANITIZE=1) =="
            cargo +nightly miri test --lib live:: subscribe::
        else
            echo "== tsan: live/ + subscribe/ unit tests (AIDW_CI_SANITIZE=1) =="
            RUSTFLAGS="-Zsanitizer=thread" RUSTDOCFLAGS="-Zsanitizer=thread" \
                cargo +nightly test --lib -Zbuild-std \
                --target "$(rustc -vV | sed -n 's/host: //p')" \
                live:: subscribe::
        fi
    else
        echo "AIDW_CI_SANITIZE=1 set but no nightly toolchain found; skipping sanitizer lane"
    fi
fi

echo "ci.sh: OK"
