//! DEM generation — the paper's motivating geosciences workload: build a
//! raster digital elevation model from a scattered LiDAR-like survey.
//!
//! ```bash
//! cargo run --release --example dem_generation -- [nx] [ny] [n_samples]
//! ```
//!
//! Interpolates the analytic terrain surface with (a) standard IDW
//! (alpha = 2, Shepard 1968) and (b) AIDW, reports the RMSE of each
//! against ground truth — demonstrating *why* adaptive alpha exists —
//! and writes `dem_aidw.pgm` / `dem_idw.pgm` / `dem_truth.pgm`.

use aidw::aidw::serial::rmse;
use aidw::prelude::*;
use aidw::raster::Raster;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let nx: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(96);
    let ny: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(96);
    let n_samples: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(3000);

    let side = 100.0;
    // survey concentrated in clusters (flight lines / accessible areas)
    // plus scattered fill — a realistic mixed-density acquisition, the
    // regime where adaptive alpha matters
    let mut data = workload::clustered(n_samples * 7 / 10, side, 12, 3.0, 7);
    let fill = workload::uniform_square(n_samples * 3 / 10, side, 8);
    for i in 0..fill.len() {
        data.push(fill.xs[i], fill.ys[i], 0.0);
    }
    // sample the true surface at every survey point
    for i in 0..data.len() {
        data.zs[i] = workload::terrain_height(data.xs[i], data.ys[i], side);
    }
    println!(
        "survey: {} samples (70% clustered, 30% scattered), raster {nx}x{ny}",
        data.len()
    );

    let queries = workload::raster_queries(nx, ny, side);
    let truth: Vec<f64> = queries
        .iter()
        .map(|&(x, y)| workload::terrain_height(x, y, side))
        .collect();

    // --- standard IDW (constant alpha = 2) ------------------------------
    let t0 = std::time::Instant::now();
    let z_idw = aidw::aidw::serial::idw_serial(&data, &queries, 2.0);
    let t_idw = t0.elapsed().as_secs_f64();

    // --- AIDW through the coordinator -----------------------------------
    let coord = Coordinator::with_defaults()?;
    coord.register_dataset("survey", data)?;
    let t1 = std::time::Instant::now();
    let resp = coord.interpolate(aidw::coordinator::InterpolationRequest::new(
        "survey",
        queries.clone(),
    ))?;
    let t_aidw = t1.elapsed().as_secs_f64();
    let z_aidw = resp.values;

    // --- report ----------------------------------------------------------
    let rmse_idw = rmse(&z_idw, &truth);
    let rmse_aidw = rmse(&z_aidw, &truth);
    println!("\n                      RMSE      time");
    println!("standard IDW (a=2):  {rmse_idw:7.3}   {:7.1} ms", t_idw * 1e3);
    println!(
        "AIDW ({:?}):  {rmse_aidw:7.3}   {:7.1} ms  (kNN {:.1} ms + interp {:.1} ms)",
        coord.backend(),
        t_aidw * 1e3,
        resp.knn_s * 1e3,
        resp.interp_s * 1e3
    );
    println!(
        "\nAIDW improves RMSE by {:.1}% over standard IDW on this mixed-density survey",
        100.0 * (rmse_idw - rmse_aidw) / rmse_idw
    );

    for (name, vals) in [
        ("dem_truth.pgm", &truth),
        ("dem_idw.pgm", &z_idw),
        ("dem_aidw.pgm", &z_aidw),
    ] {
        Raster::new(nx, ny, vals.clone()).write_pgm(std::path::Path::new(name))?;
        println!("wrote {name}");
    }
    Ok(())
}
