//! End-to-end driver — the full-system validation run recorded in
//! EXPERIMENTS.md.
//!
//! ```bash
//! cargo run --release --example e2e_driver -- [size]
//! ```
//!
//! On one real workload (default n = m = 16K, the paper's experimental
//! design scaled to this testbed) it exercises *every* layer:
//!
//!   1. CPU serial AIDW (f64)                      — Table-1 baseline;
//!   2. original algorithm (brute kNN on PJRT)     — naive + tiled;
//!   3. improved algorithm (grid kNN + PJRT)       — naive + tiled;
//!   4. cross-checks all five outputs agree;
//!   5. reports the paper's headline metrics: speedup over serial,
//!      improved-vs-original speedup, and the stage workload split.

use aidw::aidw::params::AidwParams;
use aidw::aidw::serial;
use aidw::benchlib::{fmt_ms, fmt_x, Table};
use aidw::knn::grid_knn::{grid_knn_avg_distances_on, GridKnnConfig};
use aidw::pool::Pool;
use aidw::prelude::*;
use aidw::runtime::{artifacts_available, AidwExecutor, Variant};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(16 * 1024);

    println!("=== aidw end-to-end driver: n = m = {n} (uniform square, k = 10) ===\n");
    let side = 100.0;
    let data = workload::uniform_square(n, side, 42);
    let queries = workload::uniform_square(n, side, 43).xy();
    let params = AidwParams::default();
    let pool = Pool::machine_sized();

    // ---- 1. CPU serial baseline (subsampled queries for large n) --------
    let serial_queries = if n > 8192 { &queries[..8192] } else { &queries[..] };
    let t0 = std::time::Instant::now();
    let z_serial = serial::aidw_serial(&data, serial_queries, &params);
    let serial_s_sub = t0.elapsed().as_secs_f64();
    // O(n*m): scale measured sub-query time to the full query count
    let serial_s = serial_s_sub * (queries.len() as f64 / serial_queries.len() as f64);
    println!(
        "CPU serial (f64): {:.1} ms for {} queries -> {:.1} ms extrapolated to {n}",
        serial_s_sub * 1e3,
        serial_queries.len(),
        serial_s * 1e3
    );

    if !artifacts_available() {
        eprintln!("\nNO ARTIFACTS — run `make artifacts` for the PJRT experiments");
        return Ok(());
    }
    let engine = Engine::new(&aidw::runtime::default_artifact_dir())?;
    let exec = AidwExecutor::new(&engine);
    exec.warmup()?; // XLA compiles outside the timed region

    // ---- 2+3. the four GPU-analog variants ------------------------------
    let grid = EvenGrid::build_on(&pool, &data, None, &Default::default())?;

    let mut table = Table::new(&["version", "kNN (ms)", "interp (ms)", "total (ms)", "vs serial"]);
    let mut results: Vec<(String, Vec<f64>, f64)> = Vec::new();

    for (label, original, variant) in [
        ("original naive", true, Variant::Naive),
        ("original tiled", true, Variant::Tiled),
        ("improved naive", false, Variant::Naive),
        ("improved tiled", false, Variant::Tiled),
    ] {
        let t = std::time::Instant::now();
        let (z, times) = if original {
            exec.original_aidw(&data, &queries, &params, variant)?
        } else {
            // stage 1: grid kNN in rust (the paper's fast kNN), timed in
            let tg = std::time::Instant::now();
            let (r_obs, _) = grid_knn_avg_distances_on(
                &pool,
                &grid,
                &queries,
                &GridKnnConfig { k: params.k, ..Default::default() },
            );
            let grid_knn_s = tg.elapsed().as_secs_f64();
            let (z, mut times) = exec.improved_aidw(&data, &queries, &r_obs, &params, variant)?;
            times.knn_s += grid_knn_s;
            (z, times)
        };
        let total = t.elapsed().as_secs_f64();
        table.row(&[
            label.to_string(),
            fmt_ms(times.knn_s * 1e3),
            fmt_ms(times.interp_s * 1e3),
            fmt_ms(total * 1e3),
            fmt_x(serial_s / total),
        ]);
        results.push((label.to_string(), z, total));
    }
    println!("\n{}", Table::render(&table));

    // ---- 4. cross-validation against serial ------------------------------
    let mut worst = 0.0f64;
    for (label, z, _) in &results {
        for (g, w) in z[..serial_queries.len()].iter().zip(&z_serial) {
            let rel = (g - w).abs() / w.abs().max(1.0);
            assert!(rel < 2e-2, "{label}: {g} vs serial {w}");
            worst = worst.max(rel);
        }
    }
    println!("all variants agree with the serial f64 reference (max rel err {worst:.2e})");

    // ---- 5. headline metrics ----------------------------------------------
    let t_orig = results[1].2; // original tiled
    let t_impr = results[3].2; // improved tiled
    println!("\nheadline (paper Fig. 8): improved tiled is {} faster than original tiled",
             fmt_x(t_orig / t_impr));
    let t_orig_n = results[0].2;
    let t_impr_n = results[2].2;
    println!("                         improved naive is {} faster than original naive",
             fmt_x(t_orig_n / t_impr_n));
    println!("paper reports >= 2.54x (tiled) and >= 2.02x (naive) on a GT730M — \
              shape must hold, constants may differ on CPU-PJRT.");
    Ok(())
}
