//! Live sensor-feed serving — the mutation subsystem end to end.
//!
//! ```bash
//! cargo run --release --example live_feed -- [n_stations] [n_batches]
//! ```
//!
//! A station network registers against a WAL-backed service; a feeder
//! thread then streams append batches and retires the oldest stations
//! over TCP (protocol v2.1 `mutate` ops) while query clients interpolate
//! concurrently.  The overlay crosses the compaction threshold mid-feed,
//! so the background compactor publishes new epochs under live traffic —
//! watch the `epoch` field of the response options echo move.  At the
//! end, the service is dropped without any graceful save and rebuilt
//! from snapshot + WAL replay; a verification query must match the
//! pre-restart answer bit for bit.

use std::sync::Arc;

use aidw::coordinator::{Coordinator, CoordinatorConfig};
use aidw::live::LiveConfig;
use aidw::prelude::*;
use aidw::service::{Client, Server};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_stations: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let n_batches: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(12);

    let live_dir = std::env::temp_dir().join(format!("aidw_live_feed_{}", std::process::id()));
    std::fs::remove_dir_all(&live_dir).ok();

    let config = CoordinatorConfig {
        live_dir: Some(live_dir.clone()),
        // small threshold so the demo actually compacts mid-feed
        live: LiveConfig { compact_threshold: 512, ..Default::default() },
        ..Default::default()
    };

    // --- serve ------------------------------------------------------------
    let coord = Arc::new(Coordinator::new(config.clone())?);
    let server = Server::start(coord.clone(), "127.0.0.1:0")?;
    let addr = server.addr();
    println!("live service on {addr} (WAL dir {})", live_dir.display());

    let side = 100.0;
    let stations = workload::sensor_stations(n_stations, side, 99);
    {
        let mut admin = Client::connect(addr)?;
        admin.register("pm25", &stations)?;
    }
    println!("registered {n_stations} stations");

    // --- feeder: appends + retirements over the wire ------------------------
    let feeder = std::thread::spawn(move || -> (u64, u64) {
        let mut client = Client::connect(addr).expect("feeder connect");
        let mut appended = 0u64;
        let mut retired = 0u64;
        let mut next_retire = 0u64;
        for b in 0..n_batches {
            let batch = workload::sensor_stations(128, side, 1000 + b);
            let r = client.append("pm25", &batch).expect("append");
            appended += r.count as u64;
            // retire the 32 oldest surviving stations
            let ids: Vec<u64> = (next_retire..next_retire + 32).collect();
            next_retire += 32;
            let rm = client.remove("pm25", &ids).expect("remove");
            retired += rm.removed as u64;
            if b % 4 == 3 {
                let st = client.live_stat("pm25").expect("stat");
                println!(
                    "  feed {b:>3}: epoch {} live {} delta {} tombstones {} compactions {}",
                    st.epoch, st.live_points, st.delta_points, st.tombstones, st.compactions
                );
            }
        }
        (appended, retired)
    });

    // --- concurrent query clients ------------------------------------------
    let mut clients = Vec::new();
    for c in 0..4u64 {
        clients.push(std::thread::spawn(move || -> (usize, Vec<u64>) {
            let mut client = Client::connect(addr).expect("connect");
            let mut rng = aidw::rng::Pcg32::seeded(7000 + c);
            let mut epochs = Vec::new();
            let mut total = 0usize;
            for _ in 0..10 {
                let queries: Vec<(f64, f64)> = (0..64)
                    .map(|_| (rng.uniform(0.0, side), rng.uniform(0.0, side)))
                    .collect();
                let reply = client
                    .interpolate_with("pm25", &queries, QueryOptions::default())
                    .expect("interpolate");
                total += reply.values.len();
                if let Some(o) = reply.options {
                    if let Some(e) = o.epoch {
                        epochs.push(e);
                    }
                }
            }
            (total, epochs)
        }));
    }

    let (appended, retired) = feeder.join().expect("feeder");
    let mut epochs_seen = std::collections::BTreeSet::new();
    let mut total_queries = 0usize;
    for h in clients {
        let (n, epochs) = h.join().expect("client");
        total_queries += n;
        epochs_seen.extend(epochs);
    }
    println!(
        "\nfed {appended} appends / {retired} retirements; served {total_queries} queries \
         across epochs {epochs_seen:?}"
    );
    let final_stat = {
        let mut c = Client::connect(addr)?;
        c.live_stat("pm25")?
    };
    println!(
        "final: epoch {} live {} ({} compactions, {} WAL records pending)",
        final_stat.epoch, final_stat.live_points, final_stat.compactions, final_stat.wal_records
    );

    // --- kill + restart from WAL -------------------------------------------
    let probe = vec![(side * 0.4, side * 0.6), (side * 0.1, side * 0.2)];
    let before = {
        let mut c = Client::connect(addr)?;
        c.interpolate("pm25", &probe)?
    };
    drop(server);
    drop(coord); // no graceful save: snapshot + WAL is all that survives

    let coord2 = Arc::new(Coordinator::new(config)?);
    let after = {
        let server2 = Server::start(coord2.clone(), "127.0.0.1:0")?;
        let mut c = Client::connect(server2.addr())?;
        let z = c.interpolate("pm25", &probe)?;
        drop(c);
        z
    };
    assert_eq!(before, after, "restart must reproduce answers bit-for-bit");
    println!(
        "restart from WAL replay: {} datasets, probe answers bit-identical ✓",
        coord2.datasets().len()
    );
    std::fs::remove_dir_all(&live_dir).ok();
    Ok(())
}
