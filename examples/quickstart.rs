//! Quickstart: interpolate scattered samples with AIDW in a few lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! One facade (`AidwSession`) covers every execution path, and one
//! options type (`QueryOptions`) tunes every request — the same knobs
//! the serving coordinator and the TCP protocol v2 accept.

use aidw::prelude::*;

fn main() -> Result<()> {
    // --- 1. generate a toy survey: 2000 scattered samples of a terrain ---
    let side = 100.0;
    let data = workload::terrain_samples(2000, side, 0.5, 42);
    println!("data: {} samples over a {side}x{side} region", data.len());
    let queries = workload::raster_queries(8, 8, side);

    // --- 2. the pure-rust improved pipeline ----------------------------
    let fast = AidwSession::in_process();
    fast.register("survey", data.clone())?;
    let z = fast.interpolate_values("survey", &queries, &QueryOptions::default())?;
    println!("\npure-rust improved pipeline (grid kNN + adaptive IDW):");
    for row in 0..4 {
        let vals: Vec<String> =
            (0..4).map(|c| format!("{:6.1}", z[row * 8 + c])).collect();
        println!("  z[{row}][0..4] = {}", vals.join(" "));
    }

    // --- 3. the serving coordinator, same facade -----------------------
    let serving = AidwSession::serving(CoordinatorConfig::default())?;
    println!("\nserving backend: {}", serving.backend_label());
    serving.register("survey", data)?;
    let reply = serving.interpolate("survey", &queries, &QueryOptions::default())?;
    println!(
        "coordinator: {} predictions  (kNN {:.1} ms, interpolation {:.1} ms)",
        reply.values.len(),
        reply.knn_s * 1e3,
        reply.interp_s * 1e3
    );

    // both paths agree
    let max_diff = z
        .iter()
        .zip(&reply.values)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |pure-rust - coordinator| = {max_diff:.2e}");

    // --- 4. per-request tuning -----------------------------------------
    // restrict stage 2 to each query's 64 nearest neighbors (A5) and use
    // the paper's ring heuristic — per request, no reconfiguration
    let tuned = serving.interpolate(
        "survey",
        &queries,
        &QueryOptions::new()
            .k(16)
            .local_neighbors(64)
            .ring_rule(grid_knn::RingRule::PaperPlusOne),
    )?;
    let o = &tuned.options; // the response echoes what actually ran
    println!(
        "tuned request ran with k={}, ring={}, local={:?}",
        o.k,
        o.ring_rule.tag(),
        o.local_neighbors
    );

    // ground-truth check: the terrain is analytic, so we can score RMSE
    let truth: Vec<f64> = queries
        .iter()
        .map(|&(x, y)| workload::terrain_height(x, y, side))
        .collect();
    println!(
        "RMSE vs analytic terrain: dense {:.2}, local-64 {:.2}",
        serial::rmse(&reply.values, &truth),
        serial::rmse(&tuned.values, &truth),
    );
    Ok(())
}
