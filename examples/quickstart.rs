//! Quickstart: interpolate scattered samples with AIDW in a few lines.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```
//!
//! Shows both entry points:
//! 1. the one-call pure-rust pipeline (`aidw::pipeline::interpolate_improved`);
//! 2. the serving coordinator (grid kNN + PJRT artifacts when present).

use aidw::prelude::*;

fn main() -> Result<()> {
    // --- 1. generate a toy survey: 2000 scattered samples of a terrain ---
    let side = 100.0;
    let data = workload::terrain_samples(2000, side, 0.5, 42);
    println!("data: {} samples over a {side}x{side} region", data.len());

    // --- 2. the one-call API -------------------------------------------
    let queries = workload::raster_queries(8, 8, side);
    let params = AidwParams::default(); // k=10, alpha levels per Lu & Wong
    let z = pipeline::interpolate_improved(&data, &queries, &params);
    println!("\npure-rust improved pipeline (grid kNN + adaptive IDW):");
    for row in 0..4 {
        let vals: Vec<String> =
            (0..4).map(|c| format!("{:6.1}", z[row * 8 + c])).collect();
        println!("  z[{row}][0..4] = {}", vals.join(" "));
    }

    // --- 3. the serving coordinator ------------------------------------
    let coord = Coordinator::with_defaults()?;
    println!("\ncoordinator backend: {:?}", coord.backend());
    coord.register_dataset("survey", data)?;
    let resp = coord.interpolate(
        ::aidw::coordinator::InterpolationRequest::new("survey", queries.clone()),
    )?;
    println!(
        "coordinator: {} predictions  (kNN {:.1} ms, interpolation {:.1} ms)",
        resp.values.len(),
        resp.knn_s * 1e3,
        resp.interp_s * 1e3
    );

    // both paths agree
    let max_diff = z
        .iter()
        .zip(&resp.values)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f64, f64::max);
    println!("max |pure-rust - coordinator| = {max_diff:.2e}");

    // ground-truth check: the terrain is analytic, so we can score RMSE
    let truth: Vec<f64> = queries
        .iter()
        .map(|&(x, y)| workload::terrain_height(x, y, side))
        .collect();
    println!(
        "RMSE vs analytic terrain: {:.2}",
        serial::rmse(&resp.values, &truth)
    );
    Ok(())
}
