//! Sensor-network serving — a PM2.5-style deployment (cf. Li et al. 2014
//! in the paper's related work): a TCP interpolation service fed by a
//! sparse station network, queried concurrently by many clients that each
//! want a city-block raster of the pollution field.
//!
//! ```bash
//! cargo run --release --example sensor_service -- [n_stations] [n_clients]
//! ```
//!
//! Demonstrates the full serving stack: TCP JSON protocol v2 -> dynamic
//! batcher -> two-stage pipeline.  Even-numbered clients use the server
//! defaults; odd-numbered clients override options per request
//! (localized stage 2 over 64 neighbors) — the batcher keeps the two
//! populations in separate batches while still coalescing within each.
//! Reports per-client latency, service throughput, and batching
//! effectiveness from the coordinator metrics.

use std::sync::Arc;

use aidw::coordinator::{Coordinator, CoordinatorConfig};
use aidw::prelude::*;
use aidw::service::{Client, Server};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_stations: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(2000);
    let n_clients: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(8);

    // --- serve -----------------------------------------------------------
    let coord = Arc::new(Coordinator::new(CoordinatorConfig::default())?);
    println!("coordinator backend: {:?}", coord.backend());
    let server = Server::start(coord.clone(), "127.0.0.1:0")?;
    let addr = server.addr();
    println!("sensor service on {addr}");

    // --- register the station network -------------------------------------
    let side = 100.0; // a 100x100 km region
    let stations = workload::sensor_stations(n_stations, side, 17);
    {
        let mut admin = Client::connect(addr)?;
        admin.register("pm25", &stations)?;
    }
    println!("registered {n_stations} stations (hotspot-biased placement)");

    // --- concurrent clients, mixed per-request options ---------------------
    let t0 = std::time::Instant::now();
    let mut handles = Vec::new();
    for c in 0..n_clients {
        handles.push(std::thread::spawn(move || -> (usize, f64, f64, String) {
            let mut client = Client::connect(addr).expect("connect");
            // each client asks for a 16x16 raster over its own district
            let mut rng = aidw::rng::Pcg32::seeded(1000 + c as u64);
            let ox = rng.uniform(0.0, side * 0.75);
            let oy = rng.uniform(0.0, side * 0.75);
            let mut queries = Vec::with_capacity(256);
            for j in 0..16 {
                for i in 0..16 {
                    queries.push((
                        ox + (i as f64 + 0.5) * side * 0.25 / 16.0,
                        oy + (j as f64 + 0.5) * side * 0.25 / 16.0,
                    ));
                }
            }
            // odd clients localize stage 2 to 64 neighbors (protocol v2)
            let options = if c % 2 == 1 {
                QueryOptions::new().local_neighbors(64)
            } else {
                QueryOptions::default()
            };
            let t = std::time::Instant::now();
            let reply = client
                .interpolate_with("pm25", &queries, options)
                .expect("interpolate");
            let dt = t.elapsed().as_secs_f64();
            let mean = reply.values.iter().sum::<f64>() / reply.values.len() as f64;
            // the response echoes the resolved options for audit
            let mode = match reply.options.as_ref().and_then(|o| o.local_neighbors) {
                Some(n) => format!("local-{n}"),
                None => "dense".to_string(),
            };
            (reply.values.len(), dt, mean, mode)
        }));
    }
    let mut total_queries = 0usize;
    let mut latencies = Vec::new();
    for h in handles {
        let (n, dt, mean, mode) = h.join().expect("client thread");
        total_queries += n;
        latencies.push(dt);
        println!(
            "  client done ({mode}): {n} queries in {:.1} ms (mean PM2.5 {mean:.1})",
            dt * 1e3
        );
    }
    let wall = t0.elapsed().as_secs_f64();

    // --- report -------------------------------------------------------------
    latencies.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p50 = latencies[latencies.len() / 2];
    let p_max = latencies[latencies.len() - 1];
    println!("\n{n_clients} concurrent clients, {total_queries} queries total");
    println!("wall time {:.1} ms -> {:.0} queries/s", wall * 1e3, total_queries as f64 / wall);
    println!("client latency: p50 {:.1} ms, max {:.1} ms", p50 * 1e3, p_max * 1e3);

    let m = coord.metrics();
    println!(
        "coordinator: {} requests folded into {} batches (mean latency {:.1} ms, p99 {:.1} ms)",
        m.requests,
        m.batches,
        m.mean_latency_s * 1e3,
        m.p99_latency_s * 1e3
    );
    println!(
        "stage split: kNN {:.1} ms, interpolation {:.1} ms",
        m.knn_s * 1e3,
        m.interp_s * 1e3
    );
    Ok(())
}
