//! Tiled streaming interpolation — protocol v2.4 end to end.
//!
//! ```bash
//! cargo run --release --example stream_raster -- [n_points] [n_rows] [tile_rows]
//! ```
//!
//! A service is started with a small `stream_buffer_tiles` bound, a
//! raster far larger than that buffer is requested with `stream: true`,
//! and the tiles are consumed as they arrive: at no point does either
//! side hold the whole raster — the server computes one tile at a time
//! and blocks once `stream_buffer_tiles` are unconsumed (backpressure),
//! the client drops each tile after folding it into running statistics.
//! At the end the same request is made monolithically (v2.3 style) and
//! the concatenation is verified bit-identical, then the server's
//! `stream_peak_buffered` metric receipt is printed: peak buffered
//! values never exceeded `stream_buffer_tiles x tile_rows`.

use std::sync::Arc;

use aidw::coordinator::{Coordinator, CoordinatorConfig, EngineMode};
use aidw::prelude::*;
use aidw::service::{Client, Server};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_points: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(20_000);
    let n_rows: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(16_384);
    let tile_rows: usize = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(512);

    let buffer_tiles = 2usize;
    let config = CoordinatorConfig {
        engine_mode: EngineMode::CpuOnly,
        stream_buffer_tiles: buffer_tiles,
        ..Default::default()
    };
    let coord = Arc::new(Coordinator::new(config)?);
    let server = Server::start(coord, "127.0.0.1:0")?;
    let addr = server.addr();
    println!(
        "service on {addr} (stream buffer: {buffer_tiles} tiles = {} values)",
        buffer_tiles * tile_rows
    );

    let side = 100.0;
    let data = workload::terrain_samples(n_points, side, 0.5, 99);
    let queries = workload::uniform_square(n_rows, side, 7).xy();
    let mut client = Client::connect(addr)?;
    client.register("dem", &data)?;
    println!("registered {n_points} terrain samples; streaming a {n_rows}-row raster");

    // --- stream: constant-memory consumption -----------------------------
    let t0 = std::time::Instant::now();
    let mut stream =
        client.interpolate_stream("dem", &queries, QueryOptions::new().tile_rows(tile_rows))?;
    println!(
        "header: {} rows in {} tiles of <= {} rows (epoch {:?})",
        stream.rows,
        stream.n_tiles,
        stream.tile_rows,
        stream.options.as_ref().and_then(|o| o.epoch)
    );
    assert!(
        stream.n_tiles > buffer_tiles * 4,
        "raster must dwarf the stream buffer for the demo to mean anything"
    );
    // running statistics only — each tile is dropped after this fold, so
    // client-side memory is one tile regardless of n_rows
    let (mut n, mut zmin, mut zmax, mut zsum) = (0usize, f64::INFINITY, f64::NEG_INFINITY, 0.0);
    let mut first_tile_checksum = 0.0f64;
    while let Some(tile) = stream.next_tile() {
        let tile = tile?;
        if tile.tile_index == 0 {
            first_tile_checksum = tile.values.iter().sum();
        }
        for &z in &tile.values {
            zmin = zmin.min(z);
            zmax = zmax.max(z);
            zsum += z;
        }
        n += tile.values.len();
        if tile.tile_index % 8 == 0 {
            println!(
                "  tile {:>3}: rows {:>6}..{:<6} ({:.0}%)",
                tile.tile_index,
                tile.row0,
                tile.row0 + tile.values.len(),
                100.0 * n as f64 / n_rows as f64
            );
        }
    }
    let done = *stream.done().expect("done frame");
    drop(stream); // release the connection borrow for the verify pass
    println!(
        "streamed {n} rows in {:.3}s: z in [{zmin:.3}, {zmax:.3}], mean {:.4}",
        t0.elapsed().as_secs_f64(),
        zsum / n as f64
    );
    println!(
        "server stage split: stage1 {:.3}s, stage2 {:.3}s, cache_hit {}",
        done.knn_s, done.interp_s, done.cache_hit
    );

    // --- verify: bit-identical to the monolithic v2.3 response -----------
    let whole = client.interpolate_with(
        "dem",
        &queries,
        QueryOptions::new().tile_rows(tile_rows),
    )?;
    assert_eq!(whole.values.len(), n);
    let whole_sum: f64 = whole.values.iter().sum();
    assert_eq!(whole_sum, zsum, "streamed tiles must sum bit-identically");
    assert_eq!(
        whole.values[..tile_rows].iter().sum::<f64>(),
        first_tile_checksum,
        "first tile must equal the monolithic response's first rows"
    );
    println!("verified: streamed concatenation == monolithic response");

    // --- the backpressure receipt ----------------------------------------
    let m = client.metrics()?;
    let peak = m.get("stream_peak_buffered").as_usize().unwrap_or(0);
    let tiles = m.get("stream_tiles").as_usize().unwrap_or(0);
    println!(
        "metrics: {tiles} tiles streamed, peak buffered {peak} values \
         (bound: {} = stream_buffer_tiles x tile_rows)",
        buffer_tiles * tile_rows
    );
    assert!(peak <= buffer_tiles * tile_rows, "buffering must stay bounded");
    Ok(())
}
