//! Incremental raster subscriptions — live materialized views end to end.
//!
//! ```bash
//! cargo run --release --example subscribe_feed -- [n_stations] [n_updates]
//! ```
//!
//! A station network registers against an in-process service; one client
//! then opens a protocol v2.5 subscription on a standing query raster and
//! materializes the initial answer from the tile frames.  A second client
//! mutates the dataset over the wire — localized appends, retirements, a
//! compaction — and after every mutation the subscriber applies the pushed
//! update block: only the tiles whose rows the dirty-footprint bound could
//! not prove clean are recomputed and resent, each stamped with the
//! serving `(epoch, overlay)` identity.  At the end the incrementally
//! maintained raster is checked bit-for-bit against a from-scratch query
//! at the final snapshot, and the subscription is torn down gracefully so
//! the feed connection stays usable for ordinary requests.

use std::sync::Arc;

use aidw::coordinator::{Coordinator, CoordinatorConfig};
use aidw::live::LiveConfig;
use aidw::prelude::*;
use aidw::service::{Client, Server};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let n_stations: usize = args.first().and_then(|s| s.parse().ok()).unwrap_or(4000);
    let n_updates: u64 = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(6);

    let config = CoordinatorConfig {
        // keep the overlay unmerged so updates exercise overlay versions;
        // the explicit compact below bumps the epoch instead
        live: LiveConfig { auto_compact: false, ..Default::default() },
        ..Default::default()
    };

    // --- serve ------------------------------------------------------------
    let coord = Arc::new(Coordinator::new(config)?);
    let server = Server::start(coord.clone(), "127.0.0.1:0")?;
    let addr = server.addr();
    println!("subscription service on {addr}");

    let side = 100.0;
    let stations = workload::sensor_stations(n_stations, side, 99);
    let mut mutator = Client::connect(addr)?;
    mutator.register("pm25", &stations)?;
    println!("registered {n_stations} stations");

    // --- standing raster ---------------------------------------------------
    // exact local-neighbor mode: the per-row kNN termination bound is what
    // lets the server prove tiles clean instead of recomputing everything
    // (k = 16 keeps far rows' alphas saturated, hence bitwise stable)
    let queries: Vec<(f64, f64)> = workload::uniform_square(24 * 24, side, 7).xy();
    let options = QueryOptions::new().k(16).local_neighbors(32).tile_rows(24);

    let mut feed = Client::connect(addr)?;
    let mut sub = feed.subscribe("pm25", &queries, options)?;
    println!(
        "subscribed: sub {} — {} rows in {} tiles of {} rows",
        sub.sub, sub.rows, sub.n_tiles, sub.tile_rows
    );

    let mut raster = vec![f64::NAN; sub.rows];
    let initial = sub.next_update()?;
    initial.apply(&mut raster);
    println!(
        "initial raster materialized (epoch {} overlay {}, {} tiles)",
        initial.epoch,
        initial.overlay,
        initial.tiles.len()
    );

    // --- mutate and apply the pushed dirty tiles ---------------------------
    let mut pushed = 0usize;
    let mut skipped = 0usize;
    for b in 0..n_updates {
        if b == n_updates / 2 {
            // an explicit compaction folds the overlay into a new epoch;
            // values are unchanged, so the push is a zero-tile identity
            // refresh of the serving snapshot identity
            mutator.compact("pm25")?;
        } else if b % 2 == 0 {
            // a localized burst near one corner: most tiles stay clean
            let burst = workload::clustered(64, side * 0.08, 2, side / 200.0, 1000 + b);
            mutator.append("pm25", &burst)?;
        } else {
            let ids: Vec<u64> = (b * 16..b * 16 + 16).collect();
            mutator.remove("pm25", &ids)?;
        }
        let update = sub.next_update()?;
        update.apply(&mut raster);
        pushed += update.tiles.len();
        skipped += update.skipped_clean;
        println!(
            "  update {:>2}: epoch {} overlay {:>2} — {} dirty tile(s) pushed, {} clean skipped",
            update.update,
            update.epoch,
            update.overlay,
            update.tiles.len(),
            update.skipped_clean
        );
    }
    println!("feed totals: {pushed} tiles pushed, {skipped} proven clean");

    // --- verify against a from-scratch query at the final snapshot --------
    let fresh = mutator.interpolate_with(
        "pm25",
        &queries,
        QueryOptions::new().k(16).local_neighbors(32).tile_rows(24),
    )?;
    assert_eq!(
        fresh.values, raster,
        "incrementally maintained raster must match a from-scratch query bit for bit"
    );
    println!("materialized view bit-identical to a from-scratch raster ✓");

    // --- graceful teardown: the connection stays usable --------------------
    sub.unsubscribe()?;
    let stat = feed.live_stat("pm25")?;
    println!(
        "unsubscribed; feed connection reusable (epoch {} live {} points)",
        stat.epoch, stat.live_points
    );
    Ok(())
}
