"""Adaptive power-parameter pipeline (paper Eqs. 2-6).

This is the mathematical heart of AIDW (Lu & Wong 2008): the distance-decay
parameter ``alpha`` is not a user constant but is derived per interpolated
point from the local spatial pattern of its k nearest data points.

The pipeline is::

    r_exp  = 1 / (2 * sqrt(n / A))                      (Eq. 2)
    r_obs  = mean of the k nearest-neighbor distances    (Eq. 3)
    R(S0)  = r_obs / r_exp                               (Eq. 4)
    mu_R   = cosine fuzzy membership of R(S0)            (Eq. 5)
    alpha  = triangular membership over 5 levels         (Eq. 6)

All functions are pure jnp so they lower into the same HLO module as the
Pallas kernels and run on the PJRT CPU client from rust.
"""

from __future__ import annotations

import jax.numpy as jnp

# Default fuzzy-membership bounds (paper: "in general, the R_min and R_max
# can be set to 0.0 and 2.0, respectively").
R_MIN_DEFAULT = 0.0
R_MAX_DEFAULT = 2.0

# Default distance-decay levels alpha_1..alpha_5.  Lu & Wong (2008) use five
# categories spanning gentle to steep decay; these are the values used by the
# paper's reference implementation.
ALPHA_LEVELS_DEFAULT = (0.5, 1.0, 2.0, 3.0, 4.0)

# Knots of the triangular membership function in mu_R space (Eq. 6).
MU_KNOTS = (0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0)


def expected_nn_distance(n_points, area):
    """Eq. 2: expected nearest-neighbor distance of a random pattern.

    ``r_exp = 1 / (2 * sqrt(n / A))`` where ``n`` is the number of data
    points in the study region and ``A`` its area.  Scalar (or broadcast)
    jnp computation.
    """
    n_points = jnp.asarray(n_points, dtype=jnp.float32)
    area = jnp.asarray(area, dtype=jnp.float32)
    return 1.0 / (2.0 * jnp.sqrt(n_points / area))


def nn_statistic(r_obs, r_exp):
    """Eq. 4: nearest-neighbor statistic ``R(S0) = r_obs / r_exp``."""
    return r_obs / r_exp


def fuzzy_membership(r_stat, r_min=R_MIN_DEFAULT, r_max=R_MAX_DEFAULT):
    """Eq. 5: normalize R(S0) into [0, 1] with a cosine fuzzy membership.

    mu_R = 0                                          R <= R_min
         = 0.5 - 0.5*cos(pi/R_max * (R - R_min))      R_min <= R <= R_max
         = 1                                          R >= R_max
    """
    r_stat = jnp.asarray(r_stat, dtype=jnp.float32)
    mid = 0.5 - 0.5 * jnp.cos(jnp.pi / r_max * (r_stat - r_min))
    return jnp.clip(jnp.where(r_stat <= r_min, 0.0, jnp.where(r_stat >= r_max, 1.0, mid)), 0.0, 1.0)


def alpha_from_membership(mu, levels=ALPHA_LEVELS_DEFAULT):
    """Eq. 6: map mu_R to a distance-decay alpha by triangular membership.

    Piecewise-linear over the knots (0, .1, .3, .5, .7, .9, 1) with plateau
    values alpha_1 at both ends — written out branch-by-branch exactly as the
    paper states it (the tests check it coincides with ``jnp.interp`` over
    the equivalent knot table).
    """
    a1, a2, a3, a4, a5 = [jnp.float32(a) for a in levels]
    mu = jnp.asarray(mu, dtype=jnp.float32)

    seg1 = a1                                                        # [0.0, 0.1]
    seg2 = a1 * (1.0 - 5.0 * (mu - 0.1)) + 5.0 * a2 * (mu - 0.1)     # [0.1, 0.3]
    seg3 = 5.0 * a3 * (mu - 0.3) + a2 * (1.0 - 5.0 * (mu - 0.3))     # [0.3, 0.5]
    seg4 = a3 * (1.0 - 5.0 * (mu - 0.5)) + 5.0 * a4 * (mu - 0.5)     # [0.5, 0.7]
    seg5 = 5.0 * a5 * (mu - 0.7) + a4 * (1.0 - 5.0 * (mu - 0.7))     # [0.7, 0.9]
    seg6 = a5                                                        # [0.9, 1.0]

    out = jnp.where(
        mu <= 0.1, seg1,
        jnp.where(mu <= 0.3, seg2,
                  jnp.where(mu <= 0.5, seg3,
                            jnp.where(mu <= 0.7, seg4,
                                      jnp.where(mu <= 0.9, seg5, seg6)))))
    return out


def knot_table(levels=ALPHA_LEVELS_DEFAULT):
    """The (mu, alpha) knot table equivalent to Eq. 6 — used by tests and by
    the rust mirror implementation to cross-check."""
    a1, a2, a3, a4, a5 = levels
    return list(MU_KNOTS), [a1, a1, a2, a3, a4, a5, a5]


def adaptive_alpha(r_obs, r_exp,
                   r_min=R_MIN_DEFAULT, r_max=R_MAX_DEFAULT,
                   levels=ALPHA_LEVELS_DEFAULT):
    """Full Eq. 2-6 pipeline: observed avg kNN distance -> adaptive alpha."""
    r_stat = nn_statistic(r_obs, r_exp)
    mu = fuzzy_membership(r_stat, r_min, r_max)
    return alpha_from_membership(mu, levels)
