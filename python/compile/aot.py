"""AOT pipeline: lower every L2 artifact to HLO text + manifest.json.

``make artifacts`` runs this once at build time; the rust runtime then
loads ``artifacts/*.hlo.txt`` through ``HloModuleProto::from_text_file``
and python is never on the request path again.

Interchange format is HLO **text**, not a serialized HloModuleProto:
jax >= 0.5 emits protos with 64-bit instruction ids which xla_extension
0.5.1 (the version the published ``xla`` crate binds) rejects
(``proto.id() <= INT_MAX``); the text parser reassigns ids and round-trips
cleanly.  See /opt/xla-example/README.md.

Every artifact is lowered with ``return_tuple=True``; the rust side unwraps
the result tuple.  ``manifest.json`` records the exact input/output
shapes+dtypes so the runtime can validate calls before dispatch.
"""

from __future__ import annotations

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# --------------------------------------------------------------------------
# Artifact registry
# --------------------------------------------------------------------------

# Production shapes: Q-batch 1024 queries, M-chunk 4096 data points, k-buffer
# 16 wide (runtime k <= 16 slices columns).  Test shapes are smaller so the
# integration tests compile fast.
Q_PROD, M_PROD = 1024, 4096
Q_TEST, M_TEST = 256, 1024
# k-buffer width = the paper's k: the extract-min merge costs K passes per
# tile, so K_BUF 16 -> 10 bought a 1.6x on the original-algorithm kNN stage
# (EXPERIMENTS.md §Perf).  Re-emit with a wider K_BUF for runtime k > 10.
K_BUF = 10
K_DEFAULT = 10  # paper's k
# Local-AIDW (extension A5) neighbor-panel width: stage 2 weights each
# query over its N_LOCAL gathered nearest neighbors instead of all m.
N_LOCAL = 64
N_LOCAL_TEST = 32

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def _arg(name, *shape):
    return {"name": name, "dtype": "f32", "shape": list(shape)}


def _interp_chunk_args(q, m):
    specs = [_spec(q), _spec(q), _spec(q), _spec(m), _spec(m), _spec(m), _spec(m)]
    descr = [_arg("qx", q), _arg("qy", q), _arg("alpha", q),
             _arg("dx", m), _arg("dy", m), _arg("dz", m), _arg("valid", m)]
    return specs, descr


def _knn_chunk_args(q, m, kbuf):
    specs = [_spec(q), _spec(q), _spec(m), _spec(m), _spec(m), _spec(q, kbuf)]
    descr = [_arg("qx", q), _arg("qy", q), _arg("dx", m), _arg("dy", m),
             _arg("valid", m), _arg("best_in", q, kbuf)]
    return specs, descr


def _fused_args(q, m):
    specs = [_spec(q), _spec(q), _spec(m), _spec(m), _spec(m), _spec(m),
             _spec(), _spec()]
    descr = [_arg("qx", q), _arg("qy", q), _arg("dx", m), _arg("dy", m),
             _arg("dz", m), _arg("valid", m), _arg("n_eff"), _arg("area")]
    return specs, descr


def _local_args(q, n):
    specs = [_spec(q), _spec(q), _spec(q), _spec(),
             _spec(q, n), _spec(q, n), _spec(q, n), _spec(q, n)]
    descr = [_arg("qx", q), _arg("qy", q), _arg("r_obs", q), _arg("r_exp"),
             _arg("nx", q, n), _arg("ny", q, n), _arg("nz", q, n),
             _arg("nvalid", q, n)]
    return specs, descr


def _oneshot_args(q, m):
    specs = [_spec(q), _spec(q), _spec(q), _spec(),
             _spec(m), _spec(m), _spec(m), _spec(m)]
    descr = [_arg("qx", q), _arg("qy", q), _arg("r_obs", q), _arg("r_exp"),
             _arg("dx", m), _arg("dy", m), _arg("dz", m), _arg("valid", m)]
    return specs, descr


def _registry():
    """name -> (fn, input_specs, input_descr, output_descr)."""
    arts = {}

    for q, m, tag in [(Q_PROD, M_PROD, "prod"), (Q_TEST, M_TEST, "test")]:
        specs, descr = _interp_chunk_args(q, m)
        outs = [_arg("sum_w", q), _arg("sum_wz", q)]
        arts[f"interp_naive_chunk_q{q}_m{m}"] = (
            model.interp_naive_chunk_artifact, specs, descr, outs)
        arts[f"interp_tiled_chunk_q{q}_m{m}"] = (
            model.interp_tiled_chunk_artifact, specs, descr, outs)

        kspecs, kdescr = _knn_chunk_args(q, m, K_BUF)
        arts[f"knn_chunk_q{q}_m{m}_k{K_BUF}"] = (
            model.knn_chunk, kspecs, kdescr, [_arg("best_out", q, K_BUF)])

        arts[f"alpha_q{q}"] = (
            model.alpha_stage, [_spec(q), _spec()],
            [_arg("r_obs", q), _arg("r_exp")], [_arg("alpha", q)])

        arts[f"knn_finalize_q{q}_k{K_DEFAULT}"] = (
            functools.partial(model.knn_finalize, k_used=K_DEFAULT),
            [_spec(q, K_BUF)], [_arg("best", q, K_BUF)], [_arg("r_obs", q)])

        n_local = N_LOCAL if tag == "prod" else N_LOCAL_TEST
        lspecs, ldescr = _local_args(q, n_local)
        arts[f"local_interp_q{q}_n{n_local}"] = (
            model.local_interp_artifact, lspecs, ldescr, [_arg("z", q)])

    # Fused originals + improved one-shots at test size (integration tests
    # and the small-problem fast path).
    q, m = Q_TEST, M_TEST
    fspecs, fdescr = _fused_args(q, m)
    for tiled, tag in [(False, "naive"), (True, "tiled")]:
        arts[f"original_fused_{tag}_q{q}_m{m}_k{K_DEFAULT}"] = (
            functools.partial(model.original_fused, k=K_DEFAULT, tiled=tiled),
            fspecs, fdescr, [_arg("z", q)])
    ospecs, odescr = _oneshot_args(q, m)
    for tiled, tag in [(False, "naive"), (True, "tiled")]:
        arts[f"improved_oneshot_{tag}_q{q}_m{m}"] = (
            functools.partial(model.improved_interp_oneshot, tiled=tiled),
            ospecs, odescr, [_arg("z", q)])

    return arts


# --------------------------------------------------------------------------
# Lowering
# --------------------------------------------------------------------------

def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True)
    return comp.as_hlo_text()


def lower_artifact(fn, specs) -> str:
    lowered = jax.jit(fn).lower(*specs)
    return to_hlo_text(lowered)


def emit(out_dir: str, only: str | None = None, verbose: bool = True) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    manifest = {"version": 1, "q_prod": Q_PROD, "m_prod": M_PROD,
                "q_test": Q_TEST, "m_test": M_TEST,
                "k_buf": K_BUF, "k_default": K_DEFAULT,
                "n_local": N_LOCAL, "n_local_test": N_LOCAL_TEST,
                "artifacts": []}
    for name, (fn, specs, in_descr, out_descr) in sorted(_registry().items()):
        if only and only not in name:
            continue
        fname = f"{name}.hlo.txt"
        text = lower_artifact(fn, specs)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        manifest["artifacts"].append({
            "name": name, "file": fname,
            "inputs": in_descr, "outputs": out_descr,
        })
        if verbose:
            print(f"  {fname}  ({len(text)/1024:.0f} KiB)")
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    if verbose:
        print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json "
              f"to {out_dir}")
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default=None,
                    help="artifact directory (default: <repo>/artifacts)")
    ap.add_argument("--only", default=None,
                    help="substring filter over artifact names")
    ap.add_argument("--list", action="store_true", help="list and exit")
    args = ap.parse_args()

    if args.list:
        for name in sorted(_registry()):
            print(name)
        return

    out_dir = args.out_dir
    if out_dir is None:
        here = os.path.dirname(os.path.abspath(__file__))
        out_dir = os.path.join(os.path.dirname(os.path.dirname(here)),
                               "artifacts")
    emit(out_dir, only=args.only)


if __name__ == "__main__":
    main()
