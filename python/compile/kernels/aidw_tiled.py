"""L1 Pallas kernel: block-tiled AIDW weighted interpolation (paper §4.2.2).

The paper's *tiled* CUDA kernel stages data-point coordinates through shared
memory so every thread in a block reads each data point from fast memory.
On TPU the same insight maps to the BlockSpec schedule: the (Q, M) iteration
space is cut into (Q_BLK, D_BLK) tiles; for each grid step Pallas stages one
query panel and one data tile into VMEM, and the kernel accumulates the
partial inverse-distance sums in the output block, which stays resident in
VMEM across the data-tile axis (``arbitrary`` / sequential semantics).

HBM traffic drops from O(Q*M) point reads (the naive kernel) to
O(M * Q/Q_BLK) — exactly the paper's ``n / threadsPerBlock`` reduction.

CPU note: the artifact is lowered with ``interpret=True`` so the grid loop
becomes plain HLO (scan + dynamic-slice); the tiling survives as loop
blocking, which is also the right optimization for CPU caches.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Squared-distance floor — keep identical to ref.EPS_D2.
EPS_D2 = 1e-12

# Default tile shape.  (256 queries x 512 data points) keeps the per-step
# working set at ~0.7 MB f32 (query panel 256*3 + data tile 512*4 + a
# 256x512 weight tile) — far below the 16 MiB VMEM budget; the weight tile
# dominates and is the term to shrink first if k tiles are fused later.
Q_BLK_DEFAULT = 256
D_BLK_DEFAULT = 512


def _interp_kernel(qx_ref, qy_ref, alpha_ref, dx_ref, dy_ref, dz_ref,
                   valid_ref, sw_ref, swz_ref):
    """One (q-block, d-block) grid step: accumulate partial IDW sums.

    Grid layout is (num_q_blocks, num_d_blocks); axis 0 is parallel across
    query blocks, axis 1 sequentially streams data tiles (the accumulator
    output block is revisited, so axis 1 must be ``arbitrary``).
    """
    d_step = pl.program_id(1)

    # First data tile for this query block: zero the accumulators.
    @pl.when(d_step == 0)
    def _init():
        sw_ref[...] = jnp.zeros_like(sw_ref)
        swz_ref[...] = jnp.zeros_like(swz_ref)

    qx = qx_ref[...]          # (Q_BLK,)
    qy = qy_ref[...]
    alpha = alpha_ref[...]
    dx = dx_ref[...]          # (D_BLK,)
    dy = dy_ref[...]
    dz = dz_ref[...]
    valid = valid_ref[...]

    ddx = qx[:, None] - dx[None, :]
    ddy = qy[:, None] - dy[None, :]
    d2 = jnp.maximum(ddx * ddx + ddy * ddy, EPS_D2)
    # w = d^-alpha = exp(-alpha/2 * log d2); padding lanes are zeroed by the
    # valid mask instead of a branch (no divergence).
    w = jnp.exp(-0.5 * alpha[:, None] * jnp.log(d2)) * valid[None, :]

    sw_ref[...] += jnp.sum(w, axis=1)
    swz_ref[...] += jnp.sum(w * dz[None, :], axis=1)


@functools.partial(jax.jit, static_argnames=("q_blk", "d_blk"))
def interp_tiled_partial(qx, qy, alpha, dx, dy, dz, valid,
                         q_blk=Q_BLK_DEFAULT, d_blk=D_BLK_DEFAULT):
    """Tiled partial IDW sums: returns (sum_w, sum_wz) per query.

    Shapes: qx/qy/alpha (Q,), dx/dy/dz/valid (M,); Q % q_blk == 0 and
    M % d_blk == 0 (the rust coordinator pads to artifact shape).
    """
    nq, nd = qx.shape[0], dx.shape[0]
    assert nq % q_blk == 0 and nd % d_blk == 0, (nq, nd, q_blk, d_blk)
    grid = (nq // q_blk, nd // d_blk)

    qspec = pl.BlockSpec((q_blk,), lambda i, j: (i,))
    dspec = pl.BlockSpec((d_blk,), lambda i, j: (j,))
    ospec = pl.BlockSpec((q_blk,), lambda i, j: (i,))

    sw, swz = pl.pallas_call(
        _interp_kernel,
        grid=grid,
        in_specs=[qspec, qspec, qspec, dspec, dspec, dspec, dspec],
        out_specs=[ospec, ospec],
        out_shape=[
            jax.ShapeDtypeStruct((nq,), jnp.float32),
            jax.ShapeDtypeStruct((nq,), jnp.float32),
        ],
        interpret=True,  # CPU-PJRT target; see module docstring.
    )(qx, qy, alpha, dx, dy, dz, valid)
    return sw, swz


def interp_tiled(qx, qy, alpha, dx, dy, dz, valid,
                 q_blk=Q_BLK_DEFAULT, d_blk=D_BLK_DEFAULT):
    """Full tiled interpolation: partial sums -> prediction (Eq. 1)."""
    sw, swz = interp_tiled_partial(qx, qy, alpha, dx, dy, dz, valid,
                                   q_blk=q_blk, d_blk=d_blk)
    return swz / sw
