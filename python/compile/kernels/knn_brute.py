"""L1 Pallas kernel: tiled brute-force kNN distances (the *original*
algorithm's search, paper §2.3 / Mei et al. 2015).

The paper's original GPU AIDW finds the k nearest data points for every
interpolated point with a per-thread global scan: keep a sorted k-buffer of
the smallest squared distances, stream every data point through it.  This
kernel reproduces that formulation as a tile-parallel program:

  * the (Q, M) space is tiled exactly like the interpolation kernel;
  * each grid step computes a (Q_BLK, D_BLK) tile of squared distances and
    merges it into the running per-query k-buffer held in the output block
    (VMEM-resident across the data axis);
  * the merge extracts the k smallest of concat(kbuf, tile) by k rounds of
    vectorized extract-min (see `topk_small`) — the natural SIMD
    re-expression of the paper's insert-and-swap selection.  A full
    `jnp.sort` merge is 3.4x slower on CPU-XLA (EXPERIMENTS.md §Perf);
    `lax.top_k` would be faster still but lowers to the `topk` HLO op,
    which xla_extension 0.5.1's text parser rejects;
  * squared distances only; sqrt is deferred to the epilogue (paper
    §4.1.4's "remarkable implementation detail").

The k-buffer width is fixed at compile time (pad k up; the runtime slices
the first k columns it needs).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.aidw_tiled import D_BLK_DEFAULT, Q_BLK_DEFAULT

# Sentinel for "no point": +inf keeps padded lanes out of every k-buffer.
# (a python float, not a jnp scalar — pallas kernels must not capture traced
# constants from module scope)
INF = float("inf")


def topk_small(m, k):
    """The k smallest values per row of `m`, ascending: (Q, k).

    k rounds of vectorized extract-min: take the row minimum, knock the
    first occurrence out with +inf, repeat.  All operations are wide
    vector min/compare — ~3.4x faster than XLA's generic comparator sort
    at the (Q=256, 528) merge width this kernel runs at (EXPERIMENTS.md
    §Perf), and it lowers to plain HLO the 0.5.1 text parser accepts.
    """
    cols = jax.lax.broadcasted_iota(jnp.int32, m.shape, 1)

    def body(m, _):
        v = jnp.min(m, axis=1)
        idx = jnp.argmin(m, axis=1)  # first occurrence -> duplicates survive
        mask = cols == idx[:, None]
        return jnp.where(mask, INF, m), v

    _, vs = jax.lax.scan(body, m, None, length=k)
    return vs.T


def _knn_kernel(k, qx_ref, qy_ref, dx_ref, dy_ref, valid_ref, best_ref):
    """One (q-block, d-block) step: merge a distance tile into the k-buffer."""
    d_step = pl.program_id(1)

    @pl.when(d_step == 0)
    def _init():
        best_ref[...] = jnp.full_like(best_ref, INF)

    qx = qx_ref[...]
    qy = qy_ref[...]
    dx = dx_ref[...]
    dy = dy_ref[...]
    valid = valid_ref[...]

    ddx = qx[:, None] - dx[None, :]
    ddy = qy[:, None] - dy[None, :]
    d2 = ddx * ddx + ddy * ddy
    d2 = jnp.where(valid[None, :] > 0, d2, INF)

    merged = jnp.concatenate([best_ref[...], d2], axis=1)
    best_ref[...] = topk_small(merged, k)


@functools.partial(jax.jit, static_argnames=("k", "q_blk", "d_blk"))
def knn_brute_topk(qx, qy, dx, dy, valid, k,
                   q_blk=Q_BLK_DEFAULT, d_blk=D_BLK_DEFAULT):
    """k smallest squared distances per query, ascending: (Q, k) f32.

    Q % q_blk == 0, M % d_blk == 0 (runtime pads); masked lanes never win.
    """
    nq, nd = qx.shape[0], dx.shape[0]
    assert nq % q_blk == 0 and nd % d_blk == 0, (nq, nd, q_blk, d_blk)
    grid = (nq // q_blk, nd // d_blk)

    qspec = pl.BlockSpec((q_blk,), lambda i, j: (i,))
    dspec = pl.BlockSpec((d_blk,), lambda i, j: (j,))
    ospec = pl.BlockSpec((q_blk, k), lambda i, j: (i, 0))

    best = pl.pallas_call(
        functools.partial(_knn_kernel, k),
        grid=grid,
        in_specs=[qspec, qspec, dspec, dspec, dspec],
        out_specs=ospec,
        out_shape=jax.ShapeDtypeStruct((nq, k), jnp.float32),
        interpret=True,  # CPU-PJRT target
    )(qx, qy, dx, dy, valid)
    return best


def merge_topk(best_a, best_b):
    """Merge two sorted k-buffers (the chunk-streaming combine inside the
    `knn_chunk` artifact; associative + commutative over chunks)."""
    k = best_a.shape[1]
    return topk_small(jnp.concatenate([best_a, best_b], axis=1), k)


def knn_brute_avg_distance(qx, qy, dx, dy, valid, k,
                           q_blk=Q_BLK_DEFAULT, d_blk=D_BLK_DEFAULT):
    """Average distance to the k nearest points (Eq. 3): kernel + epilogue.

    sqrt happens exactly once, here, per the paper's deferred-sqrt detail.
    """
    best = knn_brute_topk(qx, qy, dx, dy, valid, k, q_blk=q_blk, d_blk=d_blk)
    return jnp.mean(jnp.sqrt(best), axis=1)
