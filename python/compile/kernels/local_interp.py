"""L1 Pallas kernel: gathered local weighted interpolation (extension A5).

The paper's weighted-interpolating stage streams **all m data points**
past every query — O(n*m), >95% of the improved algorithm's runtime at
scale (paper Table 2).  The local extension has the rust stage-1 gather
each query's N nearest neighbors (one extra product of the same grid
search that feeds alpha), and stage 2 becomes a dense (Q, N) weighting —
O(n*N), one kernel dispatch, no chunk streaming.

Tiling: the (Q, N) panel is cut along Q only; one grid step holds a
(Q_BLK, N) block in VMEM (N <= 128 keeps a 256xN f32 block under 128 KiB).
No accumulation across steps, so the grid is embarrassingly parallel
(`parallel` semantics on a real TPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from compile.kernels.aidw_tiled import EPS_D2, Q_BLK_DEFAULT


def _local_kernel(qx_ref, qy_ref, alpha_ref, nx_ref, ny_ref, nz_ref,
                  nvalid_ref, z_ref):
    """One query block: weight the gathered neighbor panel (Eq. 1)."""
    qx = qx_ref[...]          # (Q_BLK,)
    qy = qy_ref[...]
    alpha = alpha_ref[...]
    nx = nx_ref[...]          # (Q_BLK, N)
    ny = ny_ref[...]
    nz = nz_ref[...]
    nvalid = nvalid_ref[...]

    ddx = qx[:, None] - nx
    ddy = qy[:, None] - ny
    d2 = jnp.maximum(ddx * ddx + ddy * ddy, EPS_D2)
    w = jnp.exp(-0.5 * alpha[:, None] * jnp.log(d2)) * nvalid

    sw = jnp.sum(w, axis=1)
    swz = jnp.sum(w * nz, axis=1)
    z_ref[...] = swz / sw


@functools.partial(jax.jit, static_argnames=("q_blk",))
def interp_local(qx, qy, alpha, nx, ny, nz, nvalid, q_blk=Q_BLK_DEFAULT):
    """Local weighted interpolation over gathered neighbors.

    Shapes: qx/qy/alpha (Q,), nx/ny/nz/nvalid (Q, N); Q % q_blk == 0.
    Returns predictions (Q,) f32.  Padded neighbor slots carry
    ``nvalid = 0`` (their coordinates are ignored).
    """
    nq, n = nx.shape
    assert qx.shape[0] == nq and nq % q_blk == 0, (nq, q_blk)
    grid = (nq // q_blk,)

    vspec = pl.BlockSpec((q_blk,), lambda i: (i,))
    pspec = pl.BlockSpec((q_blk, n), lambda i: (i, 0))

    return pl.pallas_call(
        _local_kernel,
        grid=grid,
        in_specs=[vspec, vspec, vspec, pspec, pspec, pspec, pspec],
        out_specs=vspec,
        out_shape=jax.ShapeDtypeStruct((nq,), jnp.float32),
        interpret=True,  # CPU-PJRT target
    )(qx, qy, alpha, nx, ny, nz, nvalid)
