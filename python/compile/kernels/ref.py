"""Pure-jnp correctness oracles for the Pallas kernels.

Everything here is the *simplest possible* formulation — dense broadcasts,
no tiling, no masking tricks — so it is easy to audit against the paper's
equations.  The Pallas kernels (aidw_tiled.py, knn_brute.py) and the rust
implementations are all validated against these oracles.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile import alpha as alpha_mod

# Squared-distance floor: avoids pow(0, -a) at exact data-point hits.  The
# rust serial reference uses the same constant so fp paths agree.
EPS_D2 = 1e-12


def pairwise_sq_distances(qx, qy, dx, dy):
    """(nq, nd) squared Euclidean distances between query and data points."""
    ddx = qx[:, None] - dx[None, :]
    ddy = qy[:, None] - dy[None, :]
    return ddx * ddx + ddy * ddy


def knn_avg_distance(qx, qy, dx, dy, k, valid=None):
    """Average distance to the k nearest data points for each query (Eq. 3).

    Brute force: full distance matrix, sort, take k smallest.  ``valid`` is
    an optional 0/1 mask over data points (padding support).
    """
    d2 = pairwise_sq_distances(qx, qy, dx, dy)
    if valid is not None:
        d2 = jnp.where(valid[None, :] > 0, d2, jnp.inf)
    smallest = jnp.sort(d2, axis=1)[:, :k]
    return jnp.mean(jnp.sqrt(smallest), axis=1)


def knn_topk_sq(qx, qy, dx, dy, k, valid=None):
    """The k smallest *squared* distances, ascending — the paper's kernels
    carry squared distances and defer sqrt to the very end (Sec. 4.1.4)."""
    d2 = pairwise_sq_distances(qx, qy, dx, dy)
    if valid is not None:
        d2 = jnp.where(valid[None, :] > 0, d2, jnp.inf)
    return jnp.sort(d2, axis=1)[:, :k]


def idw_weights(d2, alpha):
    """Inverse-distance weights w_i = d^-alpha = (d2)^(-alpha/2) (Eq. 1).

    ``alpha`` broadcasts per query row.  Computed as exp(-alpha/2 * log d2)
    which is what XLA lowers variable-exponent pow to anyway.
    """
    d2 = jnp.maximum(d2, EPS_D2)
    return jnp.exp(-0.5 * alpha[:, None] * jnp.log(d2))


def weighted_interpolate(qx, qy, dx, dy, dz, alpha, valid=None):
    """Eq. 1: prediction = sum(w_i * z_i) / sum(w_i) with per-query alpha."""
    d2 = pairwise_sq_distances(qx, qy, dx, dy)
    w = idw_weights(d2, alpha)
    if valid is not None:
        w = w * valid[None, :]
    sw = jnp.sum(w, axis=1)
    swz = jnp.sum(w * dz[None, :], axis=1)
    return swz / sw


def weighted_partial_sums(qx, qy, dx, dy, dz, alpha, valid=None):
    """Partial sums (sum w, sum w*z) for one data chunk — the streaming
    decomposition used by the rust coordinator.  Summing partials over
    chunks and dividing reproduces ``weighted_interpolate`` exactly."""
    d2 = pairwise_sq_distances(qx, qy, dx, dy)
    w = idw_weights(d2, alpha)
    if valid is not None:
        w = w * valid[None, :]
    return jnp.sum(w, axis=1), jnp.sum(w * dz[None, :], axis=1)


def local_weighted_interpolate(qx, qy, alpha, nx, ny, nz, nvalid):
    """Oracle for the gathered local-interpolation kernel: Eq. 1 over each
    query's own neighbor panel (Q, N) with a 0/1 validity mask."""
    ddx = qx[:, None] - nx
    ddy = qy[:, None] - ny
    d2 = jnp.maximum(ddx * ddx + ddy * ddy, EPS_D2)
    w = jnp.exp(-0.5 * alpha[:, None] * jnp.log(d2)) * nvalid
    return jnp.sum(w * nz, axis=1) / jnp.sum(w, axis=1)


def standard_idw(qx, qy, dx, dy, dz, alpha_const=2.0):
    """The standard (constant-alpha) IDW of Shepard 1968 — the baseline that
    AIDW improves on; used by the accuracy example."""
    alpha = jnp.full(qx.shape, alpha_const, dtype=jnp.float32)
    return weighted_interpolate(qx, qy, dx, dy, dz, alpha)


def aidw(qx, qy, dx, dy, dz, k, area=None,
         levels=alpha_mod.ALPHA_LEVELS_DEFAULT):
    """Full AIDW reference: brute kNN -> Eq. 2-6 alpha -> Eq. 1 weighting.

    ``area`` defaults to the bounding-box area of the data points, matching
    the paper's study-region definition.
    """
    if area is None:
        area = (jnp.max(dx) - jnp.min(dx)) * (jnp.max(dy) - jnp.min(dy))
    r_obs = knn_avg_distance(qx, qy, dx, dy, k)
    r_exp = alpha_mod.expected_nn_distance(dx.shape[0], area)
    a = alpha_mod.adaptive_alpha(r_obs, r_exp, levels=levels)
    return weighted_interpolate(qx, qy, dx, dy, dz, a)
