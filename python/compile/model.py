"""L2: the AIDW compute graphs that get AOT-lowered to PJRT artifacts.

Each public function here is one *artifact*: a fixed-shape jax function that
``aot.py`` lowers to HLO text for the rust runtime.  The rust coordinator
streams arbitrary problem sizes through these fixed shapes:

  * queries are padded up to the artifact's Q and processed in Q-batches;
  * data points are streamed in M-sized chunks with a 0/1 validity mask;
  * ``interp_*_chunk`` returns partial sums (sum w, sum w*z) which the
    coordinator accumulates and divides (the decomposition is exact —
    see python/tests/test_model.py::test_chunked_equals_oneshot);
  * ``knn_chunk`` threads a sorted k-buffer of squared distances through
    the chunk stream (monoid merge, also exact).

Two interpolation variants mirror the paper's §4.2:

  * ``interp_naive_chunk``  — dense broadcast over the whole chunk (the
    paper's global-memory kernel: every thread re-reads every data point);
  * ``interp_tiled_chunk``  — the Pallas block-tiled kernel (the paper's
    shared-memory kernel: data staged tile-by-tile into fast memory).

The *original* algorithm (Mei et al. 2015) fuses brute-force kNN into the
same pass; ``original_fused`` reproduces it for the Table-1/3 baselines.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile import alpha as alpha_mod
from compile.kernels import ref
from compile.kernels.aidw_tiled import interp_tiled_partial
from compile.kernels.knn_brute import knn_brute_topk, merge_topk
from compile.kernels.local_interp import interp_local


# --------------------------------------------------------------------------
# Stage 2 artifacts: weighted interpolating (Eq. 1)
# --------------------------------------------------------------------------

def interp_naive_chunk(qx, qy, alpha, dx, dy, dz, valid):
    """Naive (global-memory analog) partial IDW sums over one data chunk.

    Returns (sum_w, sum_wz), each (Q,) f32.
    """
    return ref.weighted_partial_sums(qx, qy, dx, dy, dz, alpha, valid)


def interp_tiled_chunk(qx, qy, alpha, dx, dy, dz, valid):
    """Tiled (shared-memory analog, L1 Pallas) partial IDW sums."""
    return interp_tiled_partial(qx, qy, alpha, dx, dy, dz, valid)


# --------------------------------------------------------------------------
# Stage 1 artifacts: adaptive alpha (Eqs. 2-6) and brute kNN (original alg.)
# --------------------------------------------------------------------------

def alpha_stage(r_obs, r_exp):
    """Adaptive power parameter from observed avg kNN distances.

    r_obs: (Q,) f32, r_exp: () f32 scalar.  Returns alpha (Q,) f32.
    """
    return (alpha_mod.adaptive_alpha(r_obs, r_exp),)


def knn_chunk(qx, qy, dx, dy, valid, best_in):
    """Stream one data chunk through the brute-force kNN k-buffer.

    best_in/best_out: (Q, K) sorted ascending squared distances (inf-padded
    before the first chunk).  The merge is associative and commutative over
    chunks, so the rust coordinator can fold chunks in any order.
    """
    k = best_in.shape[1]
    chunk_best = knn_brute_topk(qx, qy, dx, dy, valid, k)
    return (merge_topk(best_in, chunk_best),)


def knn_finalize(best, k_used):
    """Epilogue: average distance over the first k_used columns (Eq. 3).

    Emitted per-k (k is static in HLO); the single deferred sqrt lives here.
    """
    return (jnp.mean(jnp.sqrt(best[:, :k_used]), axis=1),)


# --------------------------------------------------------------------------
# Fused one-shot artifacts (small sizes: integration tests + the original
# algorithm baseline at exact paper semantics)
# --------------------------------------------------------------------------

def original_fused(qx, qy, dx, dy, dz, valid, n_eff, area, k, tiled):
    """The *original* GPU AIDW (Mei et al. 2015): brute kNN + Eq. 2-6 +
    weighted interpolation in one executable.

    n_eff: () f32 — number of real (unmasked) data points; area: () f32.
    """
    best = knn_brute_topk(qx, qy, dx, dy, valid, k)
    r_obs = jnp.mean(jnp.sqrt(best), axis=1)
    r_exp = alpha_mod.expected_nn_distance(n_eff, area)
    a = alpha_mod.adaptive_alpha(r_obs, r_exp)
    if tiled:
        sw, swz = interp_tiled_partial(qx, qy, a, dx, dy, dz, valid)
    else:
        sw, swz = ref.weighted_partial_sums(qx, qy, dx, dy, dz, a, valid)
    return (swz / sw,)


def improved_interp_oneshot(qx, qy, r_obs, r_exp, dx, dy, dz, valid, tiled):
    """Improved-algorithm stage 2 in one call: alpha pipeline + weighting.

    Stage 1 (grid kNN) runs in rust; its per-query r_obs feeds in here.
    Used by integration tests and the small-problem fast path (no chunk
    streaming when the whole problem fits one artifact).
    """
    a = alpha_mod.adaptive_alpha(r_obs, r_exp)
    if tiled:
        sw, swz = interp_tiled_partial(qx, qy, a, dx, dy, dz, valid)
    else:
        sw, swz = ref.weighted_partial_sums(qx, qy, dx, dy, dz, a, valid)
    return (swz / sw,)


def local_interp_artifact(qx, qy, r_obs, r_exp, nx, ny, nz, nvalid):
    """Local-AIDW stage 2 (extension A5): alpha pipeline + gathered-neighbor
    weighting in one executable.  The rust stage 1 supplies each query's N
    nearest neighbors (coords/values/mask) from its grid search.
    """
    a = alpha_mod.adaptive_alpha(r_obs, r_exp)
    return (interp_local(qx, qy, a, nx, ny, nz, nvalid),)


# Tuple-returning wrappers for chunk artifacts (AOT lowers with
# return_tuple=True; keeping the tuple explicit here makes the manifest's
# output arity obvious).

def interp_naive_chunk_artifact(qx, qy, alpha, dx, dy, dz, valid):
    sw, swz = interp_naive_chunk(qx, qy, alpha, dx, dy, dz, valid)
    return (sw, swz)


def interp_tiled_chunk_artifact(qx, qy, alpha, dx, dy, dz, valid):
    sw, swz = interp_tiled_chunk(qx, qy, alpha, dx, dy, dz, valid)
    return (sw, swz)
