"""Unit + property tests for the adaptive-alpha pipeline (paper Eqs. 2-6)."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import alpha as am


class TestExpectedNNDistance:
    def test_eq2_unit_square(self):
        # n=100 points in a unit square: r_exp = 1/(2*sqrt(100)) = 0.05
        assert np.isclose(float(am.expected_nn_distance(100.0, 1.0)), 0.05)

    def test_eq2_scales_with_area(self):
        # doubling the area scales r_exp by sqrt(2)
        r1 = float(am.expected_nn_distance(64.0, 1.0))
        r2 = float(am.expected_nn_distance(64.0, 2.0))
        assert np.isclose(r2 / r1, np.sqrt(2.0), rtol=1e-6)

    def test_eq2_denser_is_smaller(self):
        assert float(am.expected_nn_distance(1000.0, 1.0)) < \
            float(am.expected_nn_distance(10.0, 1.0))


class TestFuzzyMembership:
    def test_eq5_clamps_below(self):
        assert float(am.fuzzy_membership(-0.5)) == 0.0
        assert float(am.fuzzy_membership(0.0)) == 0.0

    def test_eq5_clamps_above(self):
        assert float(am.fuzzy_membership(2.0)) == 1.0
        assert float(am.fuzzy_membership(5.0)) == 1.0

    def test_eq5_midpoint(self):
        # R = R_max/2 = 1: mu = 0.5 - 0.5*cos(pi/2) = 0.5
        assert np.isclose(float(am.fuzzy_membership(1.0)), 0.5, atol=1e-7)

    def test_eq5_quarter(self):
        # R = 0.5: mu = 0.5 - 0.5*cos(pi/4)
        expect = 0.5 - 0.5 * np.cos(np.pi / 4)
        assert np.isclose(float(am.fuzzy_membership(0.5)), expect, rtol=1e-6)

    @given(st.floats(min_value=-10.0, max_value=10.0, allow_nan=False))
    @settings(max_examples=50, deadline=None)
    def test_eq5_bounded(self, r):
        mu = float(am.fuzzy_membership(jnp.float32(r)))
        assert 0.0 <= mu <= 1.0

    @given(st.lists(st.floats(min_value=0.0, max_value=2.0), min_size=2,
                    max_size=8))
    @settings(max_examples=30, deadline=None)
    def test_eq5_monotone_in_range(self, rs):
        rs = sorted(rs)
        mus = np.asarray(am.fuzzy_membership(jnp.asarray(rs, jnp.float32)))
        assert np.all(np.diff(mus) >= -1e-6)


class TestAlphaMapping:
    def test_eq6_plateaus(self):
        a = am.ALPHA_LEVELS_DEFAULT
        assert float(am.alpha_from_membership(0.0)) == a[0]
        assert float(am.alpha_from_membership(0.05)) == a[0]
        assert float(am.alpha_from_membership(0.95)) == a[-1]
        assert float(am.alpha_from_membership(1.0)) == a[-1]

    def test_eq6_knots_hit_levels(self):
        # mu = 0.1, 0.3, 0.5, 0.7, 0.9 map exactly to alpha_1..alpha_5
        for mu, expect in zip((0.1, 0.3, 0.5, 0.7, 0.9),
                              am.ALPHA_LEVELS_DEFAULT):
            got = float(am.alpha_from_membership(jnp.float32(mu)))
            assert np.isclose(got, expect, atol=1e-6), (mu, got, expect)

    def test_eq6_segment_midpoints(self):
        # halfway between knots: exact average of adjacent levels
        a = am.ALPHA_LEVELS_DEFAULT
        for i, mu in enumerate((0.2, 0.4, 0.6, 0.8)):
            expect = 0.5 * (a[i] + a[i + 1])
            got = float(am.alpha_from_membership(jnp.float32(mu)))
            assert np.isclose(got, expect, atol=1e-6)

    def test_eq6_equals_interp_table(self):
        # the branchy Eq. 6 must coincide with jnp.interp over the knot table
        mus, alphas = am.knot_table()
        grid = jnp.linspace(0.0, 1.0, 501)
        branchy = am.alpha_from_membership(grid)
        table = jnp.interp(grid, jnp.asarray(mus), jnp.asarray(alphas))
        np.testing.assert_allclose(np.asarray(branchy), np.asarray(table),
                                   atol=2e-6)

    @given(st.floats(min_value=0.0, max_value=1.0, allow_nan=False))
    @settings(max_examples=50, deadline=None)
    def test_eq6_bounded_by_levels(self, mu):
        a = float(am.alpha_from_membership(jnp.float32(mu)))
        lv = am.ALPHA_LEVELS_DEFAULT
        assert min(lv) - 1e-6 <= a <= max(lv) + 1e-6

    @given(st.lists(st.floats(min_value=0.0, max_value=1.0), min_size=2,
                    max_size=8))
    @settings(max_examples=30, deadline=None)
    def test_eq6_monotone_for_increasing_levels(self, mus):
        mus = sorted(mus)
        out = np.asarray(am.alpha_from_membership(jnp.asarray(mus, jnp.float32)))
        assert np.all(np.diff(out) >= -1e-5)

    def test_eq6_custom_levels(self):
        levels = (1.0, 1.5, 2.5, 3.5, 5.0)
        got = float(am.alpha_from_membership(jnp.float32(0.3), levels))
        assert np.isclose(got, 1.5, atol=1e-6)


class TestFullPipeline:
    def test_dense_pattern_low_alpha(self):
        # r_obs << r_exp (clustered): R ~ 0 -> mu 0 -> alpha_1
        a = float(am.adaptive_alpha(jnp.float32(0.001), jnp.float32(1.0)))
        assert np.isclose(a, am.ALPHA_LEVELS_DEFAULT[0])

    def test_sparse_pattern_high_alpha(self):
        # r_obs >> r_exp (dispersed): R >= 2 -> mu 1 -> alpha_5
        a = float(am.adaptive_alpha(jnp.float32(5.0), jnp.float32(1.0)))
        assert np.isclose(a, am.ALPHA_LEVELS_DEFAULT[-1])

    def test_random_pattern_middle_alpha(self):
        # r_obs == r_exp: R = 1 -> mu = 0.5 -> alpha_3
        a = float(am.adaptive_alpha(jnp.float32(1.0), jnp.float32(1.0)))
        assert np.isclose(a, am.ALPHA_LEVELS_DEFAULT[2], atol=1e-5)

    @given(st.floats(min_value=1e-3, max_value=10.0),
           st.floats(min_value=1e-3, max_value=10.0))
    @settings(max_examples=50, deadline=None)
    def test_alpha_always_in_level_range(self, r_obs, r_exp):
        a = float(am.adaptive_alpha(jnp.float32(r_obs), jnp.float32(r_exp)))
        lv = am.ALPHA_LEVELS_DEFAULT
        assert min(lv) - 1e-6 <= a <= max(lv) + 1e-6
