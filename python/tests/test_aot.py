"""AOT emission tests: artifacts lower to parseable HLO text and the
manifest describes them accurately."""

import json
import os

import pytest

from compile import aot


@pytest.fixture(scope="module")
def emitted(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    # only the small/test-size artifacts: keeps the suite fast
    manifest = aot.emit(out, only="q256", verbose=False)
    return out, manifest


class TestEmission:
    def test_registry_is_nonempty(self):
        names = aot._registry()
        assert len(names) >= 12

    def test_all_q256_artifacts_emitted(self, emitted):
        out, manifest = emitted
        assert len(manifest["artifacts"]) >= 6
        for art in manifest["artifacts"]:
            path = os.path.join(out, art["file"])
            assert os.path.exists(path), art["name"]
            assert os.path.getsize(path) > 100

    def test_hlo_text_has_entry(self, emitted):
        out, manifest = emitted
        for art in manifest["artifacts"]:
            text = open(os.path.join(out, art["file"])).read()
            assert "ENTRY" in text, art["name"]
            assert "HloModule" in text, art["name"]

    def test_hlo_no_custom_calls(self, emitted):
        # interpret=True must have eliminated Mosaic custom-calls — a
        # custom-call in the text would be unloadable on the CPU client
        out, manifest = emitted
        for art in manifest["artifacts"]:
            text = open(os.path.join(out, art["file"])).read()
            assert "custom-call" not in text, art["name"]

    def test_manifest_input_arity_matches_hlo(self, emitted):
        # each manifest input corresponds to one HLO entry parameter
        out, manifest = emitted
        for art in manifest["artifacts"]:
            text = open(os.path.join(out, art["file"])).read()
            # parameters of the ENTRY computation (ENTRY is the last block
            # in jax-emitted HLO text) appear as "... = f32[...] parameter(i)"
            entry = text[text.index("ENTRY"):]
            n_params = entry.count(" parameter(")
            assert n_params == len(art["inputs"]), art["name"]

    def test_manifest_roundtrips_json(self, emitted):
        out, _ = emitted
        with open(os.path.join(out, "manifest.json")) as f:
            m = json.load(f)
        assert m["version"] == 1
        assert m["k_default"] == 10
        names = [a["name"] for a in m["artifacts"]]
        assert len(names) == len(set(names))

    def test_only_filter(self, tmp_path):
        manifest = aot.emit(str(tmp_path), only="alpha_q256", verbose=False)
        assert [a["name"] for a in manifest["artifacts"]] == ["alpha_q256"]
