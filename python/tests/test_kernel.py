"""Pallas kernels vs pure-jnp oracle — the core L1 correctness signal.

Hypothesis sweeps shapes (block-divisible), masks, alphas and k; every
kernel output must match ref.py within fp32 tolerance.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile.kernels import ref
from compile.kernels.aidw_tiled import interp_tiled, interp_tiled_partial
from compile.kernels.knn_brute import (knn_brute_avg_distance,
                                       knn_brute_topk, merge_topk)


def make_points(rng, q, m, scale=100.0):
    qx = jnp.asarray(rng.uniform(0, scale, q), jnp.float32)
    qy = jnp.asarray(rng.uniform(0, scale, q), jnp.float32)
    dx = jnp.asarray(rng.uniform(0, scale, m), jnp.float32)
    dy = jnp.asarray(rng.uniform(0, scale, m), jnp.float32)
    dz = jnp.asarray(rng.uniform(-50, 50, m), jnp.float32)
    return qx, qy, dx, dy, dz


class TestInterpTiled:
    def test_matches_ref_basic(self):
        rng = np.random.default_rng(1)
        qx, qy, dx, dy, dz = make_points(rng, 256, 1024)
        alpha = jnp.asarray(rng.uniform(0.5, 4.0, 256), jnp.float32)
        valid = jnp.ones(1024, jnp.float32)
        got = interp_tiled(qx, qy, alpha, dx, dy, dz, valid)
        want = ref.weighted_interpolate(qx, qy, dx, dy, dz, alpha)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=1e-4)

    def test_partial_sums_match_ref(self):
        rng = np.random.default_rng(2)
        qx, qy, dx, dy, dz = make_points(rng, 256, 512)
        alpha = jnp.full(256, 2.0, jnp.float32)
        valid = jnp.ones(512, jnp.float32)
        sw, swz = interp_tiled_partial(qx, qy, alpha, dx, dy, dz, valid)
        rsw, rswz = ref.weighted_partial_sums(qx, qy, dx, dy, dz, alpha, valid)
        np.testing.assert_allclose(np.asarray(sw), np.asarray(rsw), rtol=2e-5)
        np.testing.assert_allclose(np.asarray(swz), np.asarray(rswz), rtol=2e-5, atol=1e-3)

    def test_mask_excludes_padding(self):
        # padded garbage points with valid=0 must not change the result
        rng = np.random.default_rng(3)
        qx, qy, dx, dy, dz = make_points(rng, 256, 512)
        alpha = jnp.full(256, 2.0, jnp.float32)
        pad_x = jnp.concatenate([dx, jnp.full(512, 12345.0, jnp.float32)])
        pad_y = jnp.concatenate([dy, jnp.full(512, -999.0, jnp.float32)])
        pad_z = jnp.concatenate([dz, jnp.full(512, 1e6, jnp.float32)])
        valid = jnp.concatenate([jnp.ones(512), jnp.zeros(512)]).astype(jnp.float32)
        got = interp_tiled(qx, qy, alpha, pad_x, pad_y, pad_z, valid)
        want = ref.weighted_interpolate(qx, qy, dx, dy, dz, alpha)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=1e-4)

    def test_prediction_within_z_range(self):
        # weights are positive: prediction is a convex combination of z
        rng = np.random.default_rng(4)
        qx, qy, dx, dy, dz = make_points(rng, 256, 512)
        alpha = jnp.asarray(rng.uniform(0.5, 4.0, 256), jnp.float32)
        valid = jnp.ones(512, jnp.float32)
        z = np.asarray(interp_tiled(qx, qy, alpha, dx, dy, dz, valid))
        assert np.all(z >= float(jnp.min(dz)) - 1e-3)
        assert np.all(z <= float(jnp.max(dz)) + 1e-3)

    def test_query_on_data_point_recovers_value(self):
        # query exactly at a data point: weight blows up (d2 floored at
        # EPS_D2) and the prediction collapses to that point's z
        rng = np.random.default_rng(5)
        qx, qy, dx, dy, dz = make_points(rng, 256, 512)
        qx = qx.at[0].set(dx[7]); qy = qy.at[0].set(dy[7])
        alpha = jnp.full(256, 3.0, jnp.float32)
        valid = jnp.ones(512, jnp.float32)
        z = np.asarray(interp_tiled(qx, qy, alpha, dx, dy, dz, valid))
        assert np.isclose(z[0], float(dz[7]), atol=1e-2)

    @given(q_blocks=st.integers(1, 2), d_blocks=st.integers(1, 3),
           seed=st.integers(0, 2**31 - 1),
           alpha_const=st.floats(0.5, 4.0))
    @settings(max_examples=8, deadline=None)
    def test_hypothesis_shapes(self, q_blocks, d_blocks, seed, alpha_const):
        rng = np.random.default_rng(seed)
        q, m = 256 * q_blocks, 512 * d_blocks
        qx, qy, dx, dy, dz = make_points(rng, q, m)
        alpha = jnp.full(q, alpha_const, jnp.float32)
        valid = jnp.ones(m, jnp.float32)
        got = interp_tiled(qx, qy, alpha, dx, dy, dz, valid)
        want = ref.weighted_interpolate(qx, qy, dx, dy, dz, alpha)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=1e-3)


class TestKnnBrute:
    def test_matches_ref_topk(self):
        rng = np.random.default_rng(10)
        qx, qy, dx, dy, _ = make_points(rng, 256, 1024)
        valid = jnp.ones(1024, jnp.float32)
        got = knn_brute_topk(qx, qy, dx, dy, valid, 16)
        want = ref.knn_topk_sq(qx, qy, dx, dy, 16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_avg_distance_matches_ref(self):
        rng = np.random.default_rng(11)
        qx, qy, dx, dy, _ = make_points(rng, 256, 512)
        valid = jnp.ones(512, jnp.float32)
        got = knn_brute_avg_distance(qx, qy, dx, dy, valid, 10)
        want = ref.knn_avg_distance(qx, qy, dx, dy, 10)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-5)

    def test_sorted_ascending(self):
        rng = np.random.default_rng(12)
        qx, qy, dx, dy, _ = make_points(rng, 256, 512)
        valid = jnp.ones(512, jnp.float32)
        best = np.asarray(knn_brute_topk(qx, qy, dx, dy, valid, 16))
        assert np.all(np.diff(best, axis=1) >= 0)

    def test_mask_excludes_padding(self):
        rng = np.random.default_rng(13)
        qx, qy, dx, dy, _ = make_points(rng, 256, 512)
        # padded points sit exactly on the queries — nearest possible — but
        # must be ignored
        pad_x = jnp.concatenate([dx, qx, qx])
        pad_y = jnp.concatenate([dy, qy, qy])
        valid = jnp.concatenate([jnp.ones(512), jnp.zeros(512)]).astype(jnp.float32)
        got = knn_brute_topk(qx, qy, pad_x, pad_y, valid, 16)
        want = ref.knn_topk_sq(qx, qy, dx, dy, 16)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_merge_topk_is_monoid(self):
        # merging chunk k-buffers == k-buffer of the union
        rng = np.random.default_rng(14)
        qx, qy, dx, dy, _ = make_points(rng, 256, 1024)
        valid = jnp.ones(512, jnp.float32)
        a = knn_brute_topk(qx, qy, dx[:512], dy[:512], valid, 16)
        b = knn_brute_topk(qx, qy, dx[512:], dy[512:], valid, 16)
        merged = merge_topk(a, b)
        want = ref.knn_topk_sq(qx, qy, dx, dy, 16)
        np.testing.assert_allclose(np.asarray(merged), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)
        # commutativity
        np.testing.assert_array_equal(np.asarray(merge_topk(b, a)),
                                      np.asarray(merged))

    @given(seed=st.integers(0, 2**31 - 1), k=st.sampled_from([1, 4, 10, 16]))
    @settings(max_examples=8, deadline=None)
    def test_hypothesis_k_sweep(self, seed, k):
        rng = np.random.default_rng(seed)
        qx, qy, dx, dy, _ = make_points(rng, 256, 512)
        valid = jnp.ones(512, jnp.float32)
        got = knn_brute_topk(qx, qy, dx, dy, valid, k)
        want = ref.knn_topk_sq(qx, qy, dx, dy, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_duplicate_points(self):
        # ties (duplicate data points) must still yield k entries
        qx = jnp.zeros(256, jnp.float32)
        qy = jnp.zeros(256, jnp.float32)
        dx = jnp.ones(512, jnp.float32)   # all identical
        dy = jnp.ones(512, jnp.float32)
        valid = jnp.ones(512, jnp.float32)
        best = np.asarray(knn_brute_topk(qx, qy, dx, dy, valid, 10))
        np.testing.assert_allclose(best, 2.0, rtol=1e-6)
