"""Local-interpolation kernel (extension A5) vs the pure-jnp oracle, and
its end-to-end agreement with dense AIDW when the panel covers all data."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import alpha as am
from compile import model
from compile.kernels import ref
from compile.kernels.local_interp import interp_local


def make_panel(seed, q, n, scale=100.0):
    rng = np.random.default_rng(seed)
    qx = jnp.asarray(rng.uniform(0, scale, q), jnp.float32)
    qy = jnp.asarray(rng.uniform(0, scale, q), jnp.float32)
    alpha = jnp.asarray(rng.uniform(0.5, 4.0, q), jnp.float32)
    nx = jnp.asarray(rng.uniform(0, scale, (q, n)), jnp.float32)
    ny = jnp.asarray(rng.uniform(0, scale, (q, n)), jnp.float32)
    nz = jnp.asarray(rng.uniform(-50, 50, (q, n)), jnp.float32)
    nvalid = jnp.ones((q, n), jnp.float32)
    return qx, qy, alpha, nx, ny, nz, nvalid


class TestLocalKernel:
    def test_matches_oracle(self):
        qx, qy, alpha, nx, ny, nz, nvalid = make_panel(1, 256, 32)
        got = interp_local(qx, qy, alpha, nx, ny, nz, nvalid)
        want = ref.local_weighted_interpolate(qx, qy, alpha, nx, ny, nz, nvalid)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=1e-4)

    def test_mask_ignores_padded_slots(self):
        qx, qy, alpha, nx, ny, nz, nvalid = make_panel(2, 256, 32)
        # poison the last 8 neighbor slots, mask them off
        nx = nx.at[:, 24:].set(1e9)
        nz = nz.at[:, 24:].set(1e9)
        nvalid = nvalid.at[:, 24:].set(0.0)
        got = interp_local(qx, qy, alpha, nx, ny, nz, nvalid)
        want = ref.local_weighted_interpolate(
            qx, qy, alpha, nx[:, :24], ny[:, :24], nz[:, :24],
            jnp.ones((256, 24), jnp.float32))
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=2e-5, atol=1e-4)

    def test_prediction_is_convex(self):
        qx, qy, alpha, nx, ny, nz, nvalid = make_panel(3, 256, 32)
        z = np.asarray(interp_local(qx, qy, alpha, nx, ny, nz, nvalid))
        assert np.all(z >= float(jnp.min(nz)) - 1e-3)
        assert np.all(z <= float(jnp.max(nz)) + 1e-3)

    @given(q_blocks=st.integers(1, 2), n=st.sampled_from([8, 32, 64]),
           seed=st.integers(0, 2**31 - 1))
    @settings(max_examples=6, deadline=None)
    def test_hypothesis_shapes(self, q_blocks, n, seed):
        q = 256 * q_blocks
        qx, qy, alpha, nx, ny, nz, nvalid = make_panel(seed, q, n)
        got = interp_local(qx, qy, alpha, nx, ny, nz, nvalid)
        want = ref.local_weighted_interpolate(qx, qy, alpha, nx, ny, nz, nvalid)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=1e-3)


class TestLocalArtifact:
    def test_full_panel_equals_dense_aidw(self):
        # when the neighbor panel holds the entire dataset, local AIDW must
        # equal the dense reference exactly
        q, m, k = 256, 32, 10
        rng = np.random.default_rng(40)
        qx = jnp.asarray(rng.uniform(0, 100, q), jnp.float32)
        qy = jnp.asarray(rng.uniform(0, 100, q), jnp.float32)
        dx = jnp.asarray(rng.uniform(0, 100, m), jnp.float32)
        dy = jnp.asarray(rng.uniform(0, 100, m), jnp.float32)
        dz = jnp.asarray(rng.uniform(-50, 50, m), jnp.float32)
        area = (jnp.max(dx) - jnp.min(dx)) * (jnp.max(dy) - jnp.min(dy))
        r_obs = ref.knn_avg_distance(qx, qy, dx, dy, k)
        r_exp = am.expected_nn_distance(m, area)
        # panel = all m points for every query
        nx = jnp.broadcast_to(dx, (q, m))
        ny = jnp.broadcast_to(dy, (q, m))
        nz = jnp.broadcast_to(dz, (q, m))
        nvalid = jnp.ones((q, m), jnp.float32)
        (got,) = model.local_interp_artifact(qx, qy, r_obs, r_exp,
                                             nx, ny, nz, nvalid)
        want = ref.aidw(qx, qy, dx, dy, dz, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=1e-3)
