"""L2 model tests: chunk-streaming decompositions are exact, fused artifacts
match the dense reference, and padding is inert — the contracts the rust
coordinator relies on.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from compile import alpha as am
from compile import model
from compile.kernels import ref


def make_problem(seed, q, m, scale=100.0):
    rng = np.random.default_rng(seed)
    qx = jnp.asarray(rng.uniform(0, scale, q), jnp.float32)
    qy = jnp.asarray(rng.uniform(0, scale, q), jnp.float32)
    dx = jnp.asarray(rng.uniform(0, scale, m), jnp.float32)
    dy = jnp.asarray(rng.uniform(0, scale, m), jnp.float32)
    dz = jnp.asarray(rng.uniform(-50, 50, m), jnp.float32)
    return qx, qy, dx, dy, dz


class TestChunkedInterpolation:
    """sum_w/sum_wz accumulate exactly over data chunks."""

    @pytest.mark.parametrize("variant", ["naive", "tiled"])
    def test_chunked_equals_oneshot(self, variant):
        q, m, chunk = 256, 2048, 512
        qx, qy, dx, dy, dz = make_problem(20, q, m)
        alpha = jnp.full(q, 2.5, jnp.float32)
        fn = (model.interp_naive_chunk if variant == "naive"
              else model.interp_tiled_chunk)
        sw = jnp.zeros(q, jnp.float32)
        swz = jnp.zeros(q, jnp.float32)
        valid = jnp.ones(chunk, jnp.float32)
        for s in range(0, m, chunk):
            psw, pswz = fn(qx, qy, alpha, dx[s:s + chunk], dy[s:s + chunk],
                           dz[s:s + chunk], valid)
            sw = sw + psw
            swz = swz + pswz
        got = swz / sw
        want = ref.weighted_interpolate(qx, qy, dx, dy, dz, alpha)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=1e-3)

    def test_last_chunk_padding(self):
        # m = 1536 streamed as 512-chunks: last chunk half padding
        q, m, chunk = 256, 1280, 512
        qx, qy, dx, dy, dz = make_problem(21, q, m)
        alpha = jnp.full(q, 2.0, jnp.float32)
        sw = jnp.zeros(q, jnp.float32)
        swz = jnp.zeros(q, jnp.float32)
        for s in range(0, m, chunk):
            e = min(s + chunk, m)
            n = e - s
            pad = chunk - n
            cx = jnp.concatenate([dx[s:e], jnp.zeros(pad, jnp.float32)])
            cy = jnp.concatenate([dy[s:e], jnp.zeros(pad, jnp.float32)])
            cz = jnp.concatenate([dz[s:e], jnp.zeros(pad, jnp.float32)])
            cv = jnp.concatenate([jnp.ones(n), jnp.zeros(pad)]).astype(jnp.float32)
            psw, pswz = model.interp_naive_chunk(qx, qy, alpha, cx, cy, cz, cv)
            sw = sw + psw
            swz = swz + pswz
        got = swz / sw
        want = ref.weighted_interpolate(qx, qy, dx, dy, dz, alpha)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=1e-3)

    def test_naive_and_tiled_agree(self):
        q, m = 256, 1024
        qx, qy, dx, dy, dz = make_problem(22, q, m)
        alpha = jnp.asarray(np.random.default_rng(22).uniform(0.5, 4, q),
                            jnp.float32)
        valid = jnp.ones(m, jnp.float32)
        n_sw, n_swz = model.interp_naive_chunk(qx, qy, alpha, dx, dy, dz, valid)
        t_sw, t_swz = model.interp_tiled_chunk(qx, qy, alpha, dx, dy, dz, valid)
        np.testing.assert_allclose(np.asarray(n_sw), np.asarray(t_sw), rtol=2e-5)
        np.testing.assert_allclose(np.asarray(n_swz), np.asarray(t_swz),
                                   rtol=2e-5, atol=1e-2)


class TestChunkedKnn:
    def test_knn_chunk_stream_equals_full(self):
        q, m, chunk, kbuf = 256, 2048, 1024, 16
        qx, qy, dx, dy, _ = make_problem(30, q, m)
        best = jnp.full((q, kbuf), jnp.inf, jnp.float32)
        valid = jnp.ones(chunk, jnp.float32)
        for s in range(0, m, chunk):
            (best,) = model.knn_chunk(qx, qy, dx[s:s + chunk],
                                      dy[s:s + chunk], valid, best)
        want = ref.knn_topk_sq(qx, qy, dx, dy, kbuf)
        np.testing.assert_allclose(np.asarray(best), np.asarray(want),
                                   rtol=1e-6, atol=1e-6)

    def test_knn_finalize_eq3(self):
        q, m, kbuf, k = 256, 1024, 16, 10
        qx, qy, dx, dy, _ = make_problem(31, q, m)
        best = ref.knn_topk_sq(qx, qy, dx, dy, kbuf)
        (r_obs,) = model.knn_finalize(best, k)
        want = ref.knn_avg_distance(qx, qy, dx, dy, k)
        np.testing.assert_allclose(np.asarray(r_obs), np.asarray(want),
                                   rtol=1e-6)

    def test_fold_order_invariance(self):
        # the chunk merge is commutative: fold chunks in reverse order
        q, m, chunk, kbuf = 256, 2048, 1024, 16
        qx, qy, dx, dy, _ = make_problem(32, q, m)
        valid = jnp.ones(chunk, jnp.float32)
        starts = list(range(0, m, chunk))
        results = []
        for order in (starts, starts[::-1]):
            best = jnp.full((q, kbuf), jnp.inf, jnp.float32)
            for s in order:
                (best,) = model.knn_chunk(qx, qy, dx[s:s + chunk],
                                          dy[s:s + chunk], valid, best)
            results.append(np.asarray(best))
        np.testing.assert_array_equal(results[0], results[1])


class TestFusedArtifacts:
    @pytest.mark.parametrize("tiled", [False, True])
    def test_original_fused_matches_ref_aidw(self, tiled):
        q, m, k = 256, 1024, 10
        qx, qy, dx, dy, dz = make_problem(40, q, m)
        valid = jnp.ones(m, jnp.float32)
        area = (jnp.max(dx) - jnp.min(dx)) * (jnp.max(dy) - jnp.min(dy))
        (got,) = model.original_fused(qx, qy, dx, dy, dz, valid,
                                      jnp.float32(m), area, k=k, tiled=tiled)
        want = ref.aidw(qx, qy, dx, dy, dz, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=1e-3)

    @pytest.mark.parametrize("tiled", [False, True])
    def test_improved_oneshot_matches_ref(self, tiled):
        # feed the oracle's r_obs (standing in for the rust grid kNN) and
        # check stage 2 alone reproduces full AIDW
        q, m, k = 256, 1024, 10
        qx, qy, dx, dy, dz = make_problem(41, q, m)
        valid = jnp.ones(m, jnp.float32)
        area = (jnp.max(dx) - jnp.min(dx)) * (jnp.max(dy) - jnp.min(dy))
        r_obs = ref.knn_avg_distance(qx, qy, dx, dy, k)
        r_exp = am.expected_nn_distance(m, area)
        (got,) = model.improved_interp_oneshot(qx, qy, r_obs, r_exp,
                                               dx, dy, dz, valid, tiled=tiled)
        want = ref.aidw(qx, qy, dx, dy, dz, k)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                                   rtol=5e-5, atol=1e-3)

    def test_alpha_stage_matches_pipeline(self):
        q = 256
        rng = np.random.default_rng(42)
        r_obs = jnp.asarray(rng.uniform(0.01, 3.0, q), jnp.float32)
        r_exp = jnp.float32(0.7)
        (got,) = model.alpha_stage(r_obs, r_exp)
        want = am.adaptive_alpha(r_obs, r_exp)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestAccuracyStory:
    def test_aidw_adapts_alpha_across_density(self):
        """Clustered data -> alpha near alpha_1; sparse -> alpha near
        alpha_5.  This is the paper's motivation for AIDW (Sec. 2.2)."""
        rng = np.random.default_rng(50)
        # dense cluster in [0,1]^2 embedded in a [0,100]^2 region
        dxc = jnp.asarray(rng.uniform(0, 1, 512), jnp.float32)
        dyc = jnp.asarray(rng.uniform(0, 1, 512), jnp.float32)
        area = jnp.float32(100.0 * 100.0)
        r_exp = am.expected_nn_distance(512, area)  # expects sparse pattern
        r_obs_dense = ref.knn_avg_distance(dxc[:4], dyc[:4], dxc, dyc, 10)
        a_dense = np.asarray(am.adaptive_alpha(r_obs_dense, r_exp))
        assert np.all(a_dense <= am.ALPHA_LEVELS_DEFAULT[1])
        # genuinely dispersed points over the whole region
        dxs = jnp.asarray(rng.uniform(0, 100, 512), jnp.float32)
        dys = jnp.asarray(rng.uniform(0, 100, 512), jnp.float32)
        r_obs_sparse = ref.knn_avg_distance(dxs[:4], dys[:4], dxs, dys, 10)
        a_sparse = np.asarray(am.adaptive_alpha(r_obs_sparse, r_exp))
        assert np.all(a_sparse >= a_dense)
