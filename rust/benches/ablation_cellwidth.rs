//! **Ablation A1** — sensitivity of the grid kNN to the cell width.
//!
//! The paper fixes cell width = r_exp (Eq. 2).  This ablation sweeps a
//! multiplier over that choice and reports grid-build time, search time,
//! and candidates visited per query — showing Eq. 2 sits near the
//! build/search sweet spot (small cells: bigger grid + more rings; large
//! cells: fewer rings but many more candidates per ring).
//!
//! `cargo bench --bench ablation_cellwidth -- --sizes 16384`

use aidw::benchlib::{BenchArgs, Table};
use aidw::benchsuite::{print_header, size_label, standard_workload, MeasureOpts};
use aidw::grid::{EvenGrid, GridConfig};
use aidw::knn::grid_knn::{grid_knn_avg_distances_on, GridKnnConfig};
use aidw::pool::Pool;

fn main() {
    let args = BenchArgs::parse(&[16 * 1024]);
    let n = args.sizes[0];
    let pool = Pool::machine_sized();
    print_header("Ablation A1: grid cell-width factor (1.0 = paper's Eq. 2)", &[n]);

    let opts = MeasureOpts::default();
    let (data, queries) = standard_workload(n, &opts);

    let mut table = Table::new(&[
        "factor",
        "cells",
        "build (ms)",
        "search (ms)",
        "total (ms)",
        "cand/query",
        "max ring",
    ]);
    let mut best = (f64::INFINITY, 0.0f64);
    for factor in [0.25, 0.5, 1.0, 2.0, 4.0, 8.0] {
        let cfg = GridConfig { cell_width_factor: factor, ..Default::default() };
        let t0 = std::time::Instant::now();
        let grid = EvenGrid::build_on(&pool, &data, None, &cfg).unwrap();
        let build_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = std::time::Instant::now();
        let (out, stats) =
            grid_knn_avg_distances_on(&pool, &grid, &queries, &GridKnnConfig::default());
        let search_ms = t1.elapsed().as_secs_f64() * 1e3;
        std::hint::black_box(out);
        let total = build_ms + search_ms;
        if total < best.0 {
            best = (total, factor);
        }
        table.row(&[
            format!("{factor:.2}"),
            format!("{}", grid.n_cells()),
            format!("{build_ms:.1}"),
            format!("{search_ms:.1}"),
            format!("{total:.1}"),
            format!("{:.1}", stats.candidates as f64 / queries.len() as f64),
            format!("{}", stats.max_level),
        ]);
    }
    table.print();
    println!(
        "\nbest total at factor {} (paper's Eq.-2 choice is factor 1.0; n = {})",
        best.1,
        size_label(n)
    );
}
