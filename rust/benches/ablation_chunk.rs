//! **Ablation A3** — artifact shape (query batch Q x data chunk M) for the
//! streamed stage-2 interpolation.
//!
//! The production artifacts are (Q=1024, M=4096); the test artifacts are
//! (Q=256, M=1024).  Smaller shapes mean more PJRT dispatches per problem
//! (call overhead) but smaller working sets; this quantifies the tradeoff
//! that picked the production shape.
//!
//! `cargo bench --bench ablation_chunk -- --sizes 8192`

use aidw::aidw::params::AidwParams;
use aidw::benchlib::{BenchArgs, Table};
use aidw::benchsuite::{print_header, standard_workload, MeasureOpts};
use aidw::knn::brute::brute_knn_avg_distances_on;
use aidw::pool::Pool;
use aidw::runtime::{artifacts_available, default_artifact_dir, AidwExecutor, Engine, Variant};

fn main() {
    let args = BenchArgs::parse(&[8 * 1024]);
    let n = args.sizes[0];
    if !artifacts_available() {
        eprintln!("ablation_chunk: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Engine::new(&default_artifact_dir()).expect("engine");
    let pool = Pool::machine_sized();
    print_header("Ablation A3: artifact shape (Q x M) for streamed interpolation", &[n]);

    let opts = MeasureOpts::default();
    let (data, queries) = standard_workload(n, &opts);
    let params = AidwParams::default();
    let r_obs =
        brute_knn_avg_distances_on(&pool, &data.xs, &data.ys, &queries, params.k);

    let man = engine.manifest();
    let shapes = [(man.q_test, man.m_test), (man.q_prod, man.m_prod)];

    let mut table = Table::new(&["Q x M", "dispatches", "naive (ms)", "tiled (ms)"]);
    for (q, m) in shapes {
        let exec = AidwExecutor::with_shapes(&engine, q, m);
        exec.warmup().expect("warmup");
        let dispatches =
            ((queries.len() + q - 1) / q) * ((data.len() + m - 1) / m);
        let mut cells = vec![format!("{q} x {m}"), format!("{dispatches}")];
        for variant in [Variant::Naive, Variant::Tiled] {
            let t0 = std::time::Instant::now();
            let (out, _) = exec
                .improved_aidw(&data, &queries, &r_obs, &params, variant)
                .expect("improved");
            std::hint::black_box(out);
            cells.push(format!("{:.1}", t0.elapsed().as_secs_f64() * 1e3));
        }
        table.row(&cells);
    }
    table.print();
    println!("\nlarger artifacts amortize dispatch overhead; VMEM-analog working-set");
    println!("pressure eventually reverses the trend on real accelerators (DESIGN.md §Perf).");
}
