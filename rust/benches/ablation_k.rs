//! **Ablation A2** — sensitivity to k (number of nearest neighbors).
//!
//! The paper fixes k = 10.  This sweeps k for both kNN engines: brute
//! force degrades gently (k only affects the buffer insertion) while grid
//! search grows with the rings needed to gather k exact neighbors.
//!
//! `cargo bench --bench ablation_k -- --sizes 16384`

use aidw::benchlib::{BenchArgs, Table};
use aidw::benchsuite::{print_header, standard_workload, MeasureOpts};
use aidw::grid::{EvenGrid, GridConfig};
use aidw::knn::brute::brute_knn_avg_distances_on;
use aidw::knn::grid_knn::{grid_knn_avg_distances_on, GridKnnConfig};
use aidw::pool::Pool;

fn main() {
    let args = BenchArgs::parse(&[16 * 1024]);
    let n = args.sizes[0];
    let pool = Pool::machine_sized();
    print_header("Ablation A2: k sweep for both kNN engines", &[n]);

    let opts = MeasureOpts::default();
    let (data, queries) = standard_workload(n, &opts);
    // brute force over all queries is O(n*m); subsample queries for it
    let sub = queries.len().min(2048);
    let grid = EvenGrid::build_on(&pool, &data, None, &GridConfig::default()).unwrap();

    let mut table = Table::new(&[
        "k",
        "grid kNN (ms)",
        "cand/query",
        "brute kNN (ms, scaled)",
        "grid/brute %",
    ]);
    for k in [1usize, 4, 8, 10, 16, 32, 64] {
        let t0 = std::time::Instant::now();
        let (out, stats) = grid_knn_avg_distances_on(
            &pool,
            &grid,
            &queries,
            &GridKnnConfig { k, ..Default::default() },
        );
        let grid_ms = t0.elapsed().as_secs_f64() * 1e3;
        std::hint::black_box(out);

        let t1 = std::time::Instant::now();
        std::hint::black_box(brute_knn_avg_distances_on(
            &pool,
            &data.xs,
            &data.ys,
            &queries[..sub],
            k,
        ));
        let brute_ms =
            t1.elapsed().as_secs_f64() * 1e3 * (queries.len() as f64 / sub as f64);

        table.row(&[
            format!("{k}"),
            format!("{grid_ms:.1}"),
            format!("{:.1}", stats.candidates as f64 / queries.len() as f64),
            format!("{brute_ms:.0}"),
            format!("{:.2}", 100.0 * grid_ms / brute_ms),
        ]);
    }
    table.print();
    println!("\n(brute time scaled from a {sub}-query subsample; exact O(n*m) scaling)");
}
