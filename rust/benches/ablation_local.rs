//! **Ablation A5** — local AIDW (extension): weighting over the N nearest
//! neighbors vs the paper's dense all-m weighting.
//!
//! The paper's conclusion flags the weighted-interpolating stage (>95% of
//! runtime at scale, Table 2) as the next optimization target; localized
//! weighting is the classical answer.  This bench sweeps N and reports
//! runtime + RMSE against the dense result.
//!
//! `cargo bench --bench ablation_local -- --sizes 16384`

use aidw::aidw::local::{interpolate_local_on, LocalConfig};
use aidw::aidw::params::AidwParams;
use aidw::aidw::serial::rmse;
use aidw::benchlib::{BenchArgs, Table};
use aidw::benchsuite::{measure_improved, print_header, standard_workload, MeasureOpts};
use aidw::pool::Pool;
use aidw::runtime::{artifacts_available, default_artifact_dir, AidwExecutor, Engine, Variant};

fn main() {
    let args = BenchArgs::parse(&[16 * 1024]);
    let n_size = args.sizes[0];
    let pool = Pool::machine_sized();
    print_header("Ablation A5: local AIDW (N-neighbor weighting) vs dense", &[n_size]);

    let opts = MeasureOpts::default();
    let (data, queries) = standard_workload(n_size, &opts);
    let params = AidwParams::default();

    // dense reference: the improved tiled pipeline (PJRT when available,
    // else the pure-rust stage 2)
    let (dense_ms, dense_z) = if artifacts_available() {
        let engine = Engine::new(&default_artifact_dir()).expect("engine");
        let exec = AidwExecutor::new(&engine);
        exec.warmup().expect("warmup");
        let times = measure_improved(&pool, &exec, &data, &queries, &params, Variant::Tiled)
            .expect("dense");
        // re-run to capture values (measure_improved discards them)
        let grid = aidw::grid::EvenGrid::build_on(&pool, &data, None, &Default::default()).unwrap();
        let (r_obs, _) = aidw::knn::grid_knn::grid_knn_avg_distances_on(
            &pool, &grid, &queries,
            &aidw::knn::grid_knn::GridKnnConfig { k: params.k, ..Default::default() });
        let (z, _) = exec
            .improved_aidw(&data, &queries, &r_obs, &params, Variant::Tiled)
            .expect("dense values");
        (times.total_ms(), z)
    } else {
        let t0 = std::time::Instant::now();
        let (z, _) = aidw::aidw::pipeline::interpolate_improved_on(
            &pool, &data, &queries, &params,
            aidw::knn::grid_knn::RingRule::Exact);
        (t0.elapsed().as_secs_f64() * 1e3, z)
    };

    let (zlo, zhi) = data.z_range().unwrap();
    let zspan = zhi - zlo;

    let mut table = Table::new(&[
        "variant",
        "time (ms)",
        "speedup vs dense",
        "RMSE vs dense",
        "RMSE % of z-range",
    ]);
    table.row(&[
        format!("dense (all {} points)", data.len()),
        format!("{dense_ms:.1}"),
        "1.00x".into(),
        "0".into(),
        "0".into(),
    ]);
    for n in [16usize, 32, 64, 128, 256] {
        let cfg = LocalConfig { n_neighbors: n, ..Default::default() };
        let t0 = std::time::Instant::now();
        let z = interpolate_local_on(&pool, &data, &queries, &params, &cfg).expect("local");
        let ms = t0.elapsed().as_secs_f64() * 1e3;
        let err = rmse(&z, &dense_z);
        table.row(&[
            format!("local N={n}"),
            format!("{ms:.1}"),
            format!("{:.1}x", dense_ms / ms),
            format!("{err:.4}"),
            format!("{:.3}", 100.0 * err / zspan),
        ]);
    }
    table.print();
    println!("\nreading: the error is the tail mass of d^-alpha weights beyond the N-th");
    println!("neighbor (shrinks ~1/2 per N doubling).  The crossover sits near N=64 at");
    println!("this size: gathering many *exact* neighbors costs superlinear ring");
    println!("expansion, while the dense stage is vectorized O(m).  Since dense cost");
    println!("scales with m and local cost does not, the local advantage at fixed N");
    println!("grows linearly with dataset size (try --sizes 65536).");
}
