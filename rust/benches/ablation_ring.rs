//! **Ablation A4** — the paper's "+1 ring" heuristic vs the exact
//! termination criterion.
//!
//! The paper's Remark mandates one extra expansion ring after reaching k
//! candidates (Fig. 4).  The Exact rule instead expands until no unvisited
//! cell can beat the k-th distance.  This ablation measures: search time,
//! rings + candidates visited, and the *result mismatch rate* of the
//! heuristic on uniform and clustered data.
//!
//! `cargo bench --bench ablation_ring -- --sizes 16384`

use aidw::benchlib::{BenchArgs, Table};
use aidw::benchsuite::{print_header, MeasureOpts};
use aidw::grid::{EvenGrid, GridConfig};
use aidw::knn::grid_knn::{grid_knn_avg_distances_on, grid_knn_topk, GridKnnConfig, RingRule};
use aidw::pool::Pool;
use aidw::workload;

fn main() {
    let args = BenchArgs::parse(&[16 * 1024]);
    let n = args.sizes[0];
    let pool = Pool::machine_sized();
    print_header("Ablation A4: ring-expansion rule (paper +1 vs exact)", &[n]);

    let opts = MeasureOpts::default();
    let workloads: [(&str, aidw::geom::PointSet); 2] = [
        ("uniform", workload::uniform_square(n, opts.side, opts.seed)),
        ("clustered", workload::clustered(n, opts.side, 16, opts.side / 60.0, opts.seed)),
    ];
    let queries = workload::uniform_square(n.min(8192), opts.side, opts.seed + 1).xy();

    let mut table = Table::new(&[
        "workload",
        "rule",
        "time (ms)",
        "rings/query",
        "cand/query",
        "mismatch %",
    ]);
    for (wname, data) in &workloads {
        let grid = EvenGrid::build_on(&pool, data, None, &GridConfig::default()).unwrap();
        let exact_top = grid_knn_topk(
            &pool,
            &grid,
            &queries,
            &GridKnnConfig { k: 10, rule: RingRule::Exact },
        );
        for rule in [RingRule::Exact, RingRule::PaperPlusOne] {
            let cfg = GridKnnConfig { k: 10, rule };
            let t0 = std::time::Instant::now();
            let (out, stats) = grid_knn_avg_distances_on(&pool, &grid, &queries, &cfg);
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            std::hint::black_box(out);
            // mismatch vs the exact result
            let mismatch = if rule == RingRule::Exact {
                0.0
            } else {
                let top = grid_knn_topk(&pool, &grid, &queries, &cfg);
                let bad = top
                    .iter()
                    .zip(&exact_top)
                    .filter(|(a, b)| {
                        a.iter().zip(b.iter()).any(|(x, y)| (x - y).abs() > 1e-9)
                    })
                    .count();
                100.0 * bad as f64 / queries.len() as f64
            };
            table.row(&[
                wname.to_string(),
                format!("{rule:?}"),
                format!("{ms:.1}"),
                format!("{:.2}", stats.rings as f64 / queries.len() as f64),
                format!("{:.1}", stats.candidates as f64 / queries.len() as f64),
                format!("{mismatch:.3}"),
            ]);
        }
    }
    table.print();
    println!("\nExact is the library default: the paper's +1 heuristic can return");
    println!("inexact neighbors (nonzero mismatch on skewed data), exactly the");
    println!("failure mode its own Fig. 4 warns about one level earlier.");
}
