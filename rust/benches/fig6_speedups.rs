//! **Figure 6** — speedups of the improved GPU-analog AIDW (naive + tiled)
//! over the serial CPU algorithm, per size.
//!
//! Paper peaks: 543x (naive) and 1017x (tiled) at 1000K on a GT730M.
//! On CPU-PJRT the absolute factors are smaller; the *shape* to reproduce
//! is: speedup grows with size, and tiled > naive at every size.
//!
//! `cargo bench --bench fig6_speedups -- --sizes 4096,16384`

use aidw::benchlib::{fmt_x, BenchArgs, Table};
use aidw::benchsuite::{measure_size, print_header, size_label, MeasureOpts};
use aidw::pool::Pool;
use aidw::runtime::{artifacts_available, default_artifact_dir, Engine};

fn main() {
    let args = BenchArgs::parse(&[4 * 1024, 16 * 1024]);
    if !artifacts_available() {
        eprintln!("fig6: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Engine::new(&default_artifact_dir()).expect("engine");
    let pool = Pool::machine_sized();
    print_header("Figure 6: speedups of improved AIDW over the serial algorithm", &args.sizes);

    let opts = MeasureOpts::default();
    let mut table = Table::new(&["size", "naive speedup", "tiled speedup"]);
    let mut series = Vec::new();
    for &n in &args.sizes {
        eprintln!("  measuring n = {} ...", size_label(n));
        let m = measure_size(&engine, &pool, n, &opts).expect("measure");
        let serial = m.serial_ms.unwrap();
        let s_naive = serial / m.improved_naive.total_ms();
        let s_tiled = serial / m.improved_tiled.total_ms();
        table.row(&[size_label(n), fmt_x(s_naive), fmt_x(s_tiled)]);
        series.push((n, s_naive, s_tiled));
    }
    table.print();

    println!("\nshape checks (paper Fig. 6):");
    let tiled_ge_naive = series.iter().all(|&(_, sn, st)| st >= sn * 0.95);
    println!("  tiled >= naive at every size: {}", if tiled_ge_naive { "OK" } else { "VIOLATED" });
    if series.len() >= 2 {
        let grows = series.windows(2).all(|w| w[1].2 >= w[0].2 * 0.8);
        println!("  tiled speedup non-decreasing with size: {}", if grows { "OK" } else { "VIOLATED" });
    }
}
