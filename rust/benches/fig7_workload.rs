//! **Figure 7** — workload percentage of the two stages (kNN search vs
//! weighted interpolating) in the improved algorithm, naive and tiled.
//!
//! Paper shape: interpolation dominates and its share *grows* with size;
//! the kNN share decays toward ~1%.
//!
//! `cargo bench --bench fig7_workload -- --sizes 4096,16384`

use aidw::benchlib::{BenchArgs, Table};
use aidw::benchsuite::{measure_size, print_header, size_label, MeasureOpts};
use aidw::pool::Pool;
use aidw::runtime::{artifacts_available, default_artifact_dir, Engine};

fn main() {
    let args = BenchArgs::parse(&[4 * 1024, 16 * 1024]);
    if !artifacts_available() {
        eprintln!("fig7: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Engine::new(&default_artifact_dir()).expect("engine");
    let pool = Pool::machine_sized();
    print_header("Figure 7: workload split between the two stages (improved AIDW)", &args.sizes);

    let opts = MeasureOpts { serial: false, ..Default::default() };
    let mut table = Table::new(&[
        "size",
        "naive kNN %",
        "naive interp %",
        "tiled kNN %",
        "tiled interp %",
    ]);
    let mut knn_shares = Vec::new();
    for &n in &args.sizes {
        eprintln!("  measuring n = {} ...", size_label(n));
        let m = measure_size(&engine, &pool, n, &opts).expect("measure");
        let pn = 100.0 * m.improved_naive.knn_ms / m.improved_naive.total_ms();
        let pt = 100.0 * m.improved_tiled.knn_ms / m.improved_tiled.total_ms();
        table.row(&[
            size_label(n),
            format!("{pn:.1}"),
            format!("{:.1}", 100.0 - pn),
            format!("{pt:.1}"),
            format!("{:.1}", 100.0 - pt),
        ]);
        knn_shares.push(pt);
    }
    table.print();

    if knn_shares.len() >= 2 {
        let decays = knn_shares.windows(2).all(|w| w[1] <= w[0] * 1.2);
        println!(
            "\nkNN share decays with size (paper shape): {}",
            if decays { "OK" } else { "VIOLATED" }
        );
    }
}
