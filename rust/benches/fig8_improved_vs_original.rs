//! **Figure 8** — speedup of the improved algorithm over the original
//! algorithm, naive and tiled.
//!
//! Paper: improved naive >= 2.02x original naive; improved tiled >= 2.54x
//! original tiled.  Shape to reproduce: improved wins at every size, by a
//! growing factor (the brute kNN is O(n*m), the grid kNN ~O(n)).
//!
//! `cargo bench --bench fig8_improved_vs_original -- --sizes 4096,16384`

use aidw::benchlib::{fmt_x, BenchArgs, Table};
use aidw::benchsuite::{measure_size, print_header, size_label, MeasureOpts};
use aidw::pool::Pool;
use aidw::runtime::{artifacts_available, default_artifact_dir, Engine};

fn main() {
    let args = BenchArgs::parse(&[4 * 1024, 16 * 1024]);
    if !artifacts_available() {
        eprintln!("fig8: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Engine::new(&default_artifact_dir()).expect("engine");
    let pool = Pool::machine_sized();
    print_header("Figure 8: speedup of improved over original AIDW", &args.sizes);

    let opts = MeasureOpts { serial: false, ..Default::default() };
    let mut table = Table::new(&["size", "naive", "tiled"]);
    let mut min_naive = f64::INFINITY;
    let mut min_tiled = f64::INFINITY;
    for &n in &args.sizes {
        eprintln!("  measuring n = {} ...", size_label(n));
        let m = measure_size(&engine, &pool, n, &opts).expect("measure");
        let sn = m.original_naive.total_ms() / m.improved_naive.total_ms();
        let st = m.original_tiled.total_ms() / m.improved_tiled.total_ms();
        min_naive = min_naive.min(sn);
        min_tiled = min_tiled.min(st);
        table.row(&[size_label(n), fmt_x(sn), fmt_x(st)]);
    }
    table.print();

    println!("\npaper: improved is at least 2.02x (naive) / 2.54x (tiled) faster on a GT730M.");
    println!(
        "measured minima here: naive {} / tiled {}  ({})",
        fmt_x(min_naive),
        fmt_x(min_tiled),
        if min_naive > 1.0 && min_tiled > 1.0 { "improved wins everywhere: OK" } else { "VIOLATED" }
    );
}
