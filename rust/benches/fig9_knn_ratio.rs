//! **Figure 9** — running time of the kNN search in the improved algorithm
//! as a percentage of the original algorithm's kNN time.
//!
//! Paper: drops below 1% at one million points.  Shape: the percentage
//! decays monotonically with size (grid kNN is ~O(n), brute is O(n*m)).
//!
//! `cargo bench --bench fig9_knn_ratio -- --sizes 4096,16384,32768`
//! (the brute-kNN baseline is O(n*m): 64K+ sizes take minutes per point)

use aidw::aidw::params::AidwParams;
use aidw::benchlib::{BenchArgs, Table};
use aidw::benchsuite::{print_header, size_label, standard_workload, MeasureOpts};
use aidw::grid::{EvenGrid, GridConfig};
use aidw::knn::grid_knn::{grid_knn_avg_distances_on, GridKnnConfig};
use aidw::pool::Pool;
use aidw::runtime::{artifacts_available, default_artifact_dir, AidwExecutor, Engine};

fn main() {
    let args = BenchArgs::parse(&[4 * 1024, 16 * 1024, 32 * 1024]);
    if !artifacts_available() {
        eprintln!("fig9: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Engine::new(&default_artifact_dir()).expect("engine");
    let exec = AidwExecutor::new(&engine);
    exec.warmup().expect("warmup");
    let pool = Pool::machine_sized();
    let params = AidwParams::default();
    print_header("Figure 9: improved kNN time as % of original kNN time", &args.sizes);

    let opts = MeasureOpts::default();
    let mut table = Table::new(&["size", "original kNN (ms)", "improved kNN (ms)", "ratio %"]);
    let mut ratios = Vec::new();
    for &n in &args.sizes {
        eprintln!("  measuring n = {} ...", size_label(n));
        let (data, queries) = standard_workload(n, &opts);
        let t0 = std::time::Instant::now();
        std::hint::black_box(exec.run_knn_brute(&data, &queries, params.k).expect("knn"));
        let orig_ms = t0.elapsed().as_secs_f64() * 1e3;
        let t1 = std::time::Instant::now();
        let grid = EvenGrid::build_on(&pool, &data, None, &GridConfig::default()).unwrap();
        std::hint::black_box(grid_knn_avg_distances_on(
            &pool,
            &grid,
            &queries,
            &GridKnnConfig { k: params.k, ..Default::default() },
        ));
        let impr_ms = t1.elapsed().as_secs_f64() * 1e3;
        let ratio = 100.0 * impr_ms / orig_ms;
        ratios.push(ratio);
        table.row(&[
            size_label(n),
            format!("{orig_ms:.1}"),
            format!("{impr_ms:.1}"),
            format!("{ratio:.2}"),
        ]);
    }
    table.print();

    if ratios.len() >= 2 {
        let decays = ratios.windows(2).all(|w| w[1] <= w[0]);
        println!(
            "\nratio decays with size (paper shape, -> <1% at 1M): {}",
            if decays { "OK" } else { "VIOLATED" }
        );
    }
}
