//! Microbenchmarks of the parallel primitives (the Thrust analogs) —
//! grid-build cost model inputs for DESIGN.md §Perf.
//!
//! `cargo bench --bench micro_primitives -- --sizes 1048576`

use aidw::benchlib::{bench, BenchArgs, Table};
use aidw::pool::Pool;
use aidw::primitives::{reduce, scan, sort};
use aidw::rng::Pcg32;

fn main() {
    let args = BenchArgs::parse(&[1 << 20]);
    let n = args.sizes[0];
    let pool = Pool::machine_sized();
    println!("\n=== primitives microbench (n = {n}, {} threads) ===\n", pool.threads());

    let mut rng = Pcg32::seeded(5);
    let keys: Vec<u32> = (0..n).map(|_| rng.below(1 << 18)).collect();
    let vals: Vec<u32> = (0..n as u32).collect();
    let floats: Vec<f64> = (0..n).map(|_| rng.uniform(-1.0, 1.0)).collect();
    let ones: Vec<u32> = vec![1; n];

    let mut table = Table::new(&["primitive", "mean (ms)", "Melem/s"]);

    let s = bench(1, args.reps, || {
        let mut k = keys.clone();
        let mut v = vals.clone();
        sort::radix_sort_by_key(&pool, &mut k, &mut v);
        k
    });
    table.row(&[
        "radix_sort_by_key (18-bit keys)".into(),
        format!("{:.2}", s.mean_ms()),
        format!("{:.0}", n as f64 / s.mean_s / 1e6),
    ]);

    let s = bench(1, args.reps, || {
        let mut k = keys.clone();
        let mut v = vals.clone();
        let mut pairs: Vec<(u32, u32)> = k.drain(..).zip(v.drain(..)).collect();
        pairs.sort_by_key(|p| p.0);
        pairs
    });
    table.row(&[
        "std stable sort (reference)".into(),
        format!("{:.2}", s.mean_ms()),
        format!("{:.0}", n as f64 / s.mean_s / 1e6),
    ]);

    let mut sorted = keys.clone();
    sorted.sort_unstable();
    let s = bench(1, args.reps, || reduce::counts_by_key(&sorted));
    table.row(&[
        "counts_by_key (reduce_by_key)".into(),
        format!("{:.2}", s.mean_ms()),
        format!("{:.0}", n as f64 / s.mean_s / 1e6),
    ]);

    let s = bench(1, args.reps, || reduce::segment_heads(&sorted));
    table.row(&[
        "segment_heads (unique_by_key)".into(),
        format!("{:.2}", s.mean_ms()),
        format!("{:.0}", n as f64 / s.mean_s / 1e6),
    ]);

    let mut out = vec![0u32; n];
    let s = bench(1, args.reps, || scan::exclusive_scan(&pool, &ones, &mut out));
    table.row(&[
        "exclusive_scan".into(),
        format!("{:.2}", s.mean_ms()),
        format!("{:.0}", n as f64 / s.mean_s / 1e6),
    ]);

    let s = bench(1, args.reps, || reduce::parallel_minmax(&pool, &floats));
    table.row(&[
        "parallel_minmax".into(),
        format!("{:.2}", s.mean_ms()),
        format!("{:.0}", n as f64 / s.mean_s / 1e6),
    ]);

    table.print();
}
