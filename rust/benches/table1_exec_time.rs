//! **Table 1** — execution time (ms) of the CPU serial baseline and the
//! four GPU-analog AIDW versions across problem sizes.
//!
//! Paper row order: CPU/Serial, Original naive, Original tiled,
//! Improved naive, Improved tiled.  Expected shape: improved < original,
//! tiled < naive, serial orders of magnitude above all.
//!
//! `cargo bench --bench table1_exec_time -- --sizes 4096,16384 --paper-sizes`

use aidw::benchlib::{fmt_ms, BenchArgs, Table};
use aidw::benchsuite::{measure_size, print_header, size_label, MeasureOpts, SizeMeasurement};
use aidw::pool::Pool;
use aidw::runtime::{artifacts_available, default_artifact_dir, Engine};

fn main() {
    let args = BenchArgs::parse(&[4 * 1024, 16 * 1024]);
    if !artifacts_available() {
        eprintln!("table1: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Engine::new(&default_artifact_dir()).expect("engine");
    let pool = Pool::machine_sized();
    print_header("Table 1: execution time (ms) of CPU and GPU-analog AIDW versions", &args.sizes);

    let opts = MeasureOpts::default();
    let measurements: Vec<SizeMeasurement> = args
        .sizes
        .iter()
        .map(|&n| {
            eprintln!("  measuring n = {} ...", size_label(n));
            measure_size(&engine, &pool, n, &opts).expect("measure")
        })
        .collect();

    let mut headers = vec!["Version".to_string()];
    headers.extend(args.sizes.iter().map(|&n| size_label(n)));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);

    let row = |name: &str, f: &dyn Fn(&SizeMeasurement) -> f64| -> Vec<String> {
        let mut cells = vec![name.to_string()];
        cells.extend(measurements.iter().map(|m| fmt_ms(f(m))));
        cells
    };
    table.row(&row("CPU/Serial (f64)", &|m| m.serial_ms.unwrap_or(f64::NAN)));
    table.row(&row("Original naive", &|m| m.original_naive.total_ms()));
    table.row(&row("Original tiled", &|m| m.original_tiled.total_ms()));
    table.row(&row("Improved naive", &|m| m.improved_naive.total_ms()));
    table.row(&row("Improved tiled", &|m| m.improved_tiled.total_ms()));
    table.print();

    if measurements.iter().any(|m| m.serial_extrapolated) {
        println!("\n(serial times marked: extrapolated O(n*m) from a query subsample; see benchsuite.rs)");
    }
    println!("\npaper expectation: improved < original and tiled < naive at every size.");
    for m in &measurements {
        let ok_improved = m.improved_tiled.total_ms() < m.original_tiled.total_ms();
        let ok_tiled = m.improved_tiled.total_ms() <= m.improved_naive.total_ms() * 1.10;
        println!(
            "  n={}: improved<original {}  tiled<=naive {}",
            size_label(m.n),
            if ok_improved { "OK" } else { "VIOLATED" },
            if ok_tiled { "OK" } else { "VIOLATED" },
        );
    }
}
