//! **Table 2** — execution time of the kNN-search stage vs the weighted-
//! interpolating stage inside the *improved* algorithm.
//!
//! Paper rows: "kNN Search (Both versions)", "Weighted Interpolating
//! (Improved naive)", "Weighted Interpolating (Improved tiled)".
//! Expected shape: the kNN share shrinks with size (toward ~1%).
//!
//! `cargo bench --bench table2_stage_split -- --sizes 4096,16384`

use aidw::benchlib::{fmt_ms, BenchArgs, Table};
use aidw::benchsuite::{measure_size, print_header, size_label, MeasureOpts, SizeMeasurement};
use aidw::pool::Pool;
use aidw::runtime::{artifacts_available, default_artifact_dir, Engine};

fn main() {
    let args = BenchArgs::parse(&[4 * 1024, 16 * 1024]);
    if !artifacts_available() {
        eprintln!("table2: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Engine::new(&default_artifact_dir()).expect("engine");
    let pool = Pool::machine_sized();
    print_header("Table 2: stage split inside the improved GPU-analog AIDW", &args.sizes);

    let opts = MeasureOpts { serial: false, ..Default::default() };
    let ms: Vec<SizeMeasurement> = args
        .sizes
        .iter()
        .map(|&n| {
            eprintln!("  measuring n = {} ...", size_label(n));
            measure_size(&engine, &pool, n, &opts).expect("measure")
        })
        .collect();

    let mut headers = vec!["Stage".to_string()];
    headers.extend(args.sizes.iter().map(|&n| size_label(n)));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);

    let mut knn_row = vec!["kNN Search (both versions)".to_string()];
    knn_row.extend(ms.iter().map(|m| fmt_ms(m.improved_tiled.knn_ms)));
    table.row(&knn_row);
    let mut naive_row = vec!["Weighted Interp (improved naive)".to_string()];
    naive_row.extend(ms.iter().map(|m| fmt_ms(m.improved_naive.interp_ms)));
    table.row(&naive_row);
    let mut tiled_row = vec!["Weighted Interp (improved tiled)".to_string()];
    tiled_row.extend(ms.iter().map(|m| fmt_ms(m.improved_tiled.interp_ms)));
    table.row(&tiled_row);
    table.print();

    println!("\nkNN share of total (tiled): should FALL with size (paper: -> ~1%)");
    for m in &ms {
        let share = 100.0 * m.improved_tiled.knn_ms / m.improved_tiled.total_ms();
        println!("  n={}: {:.1}%", size_label(m.n), share);
    }
}
