//! **Table 3** — execution time of the kNN-search *stage only*: original
//! algorithm (brute force) vs improved algorithm (grid local search).
//!
//! Note: in the paper the original algorithm's kNN time is obtained by
//! subtraction (its kNN is fused into the interpolation kernel); here the
//! streamed brute-kNN stage is timed directly.  The original naive/tiled
//! rows share one kNN implementation, exactly as the paper's remark about
//! the first stage being identical.
//!
//! `cargo bench --bench table3_knn_compare -- --sizes 4096,16384`

use aidw::aidw::params::AidwParams;
use aidw::benchlib::{fmt_ms, BenchArgs, Table};
use aidw::benchsuite::{print_header, size_label, standard_workload, MeasureOpts};
use aidw::grid::{EvenGrid, GridConfig};
use aidw::knn::grid_knn::{grid_knn_avg_distances_on, GridKnnConfig};
use aidw::pool::Pool;
use aidw::runtime::{artifacts_available, default_artifact_dir, AidwExecutor, Engine};

fn main() {
    let args = BenchArgs::parse(&[4 * 1024, 16 * 1024]);
    if !artifacts_available() {
        eprintln!("table3: no artifacts (run `make artifacts`)");
        return;
    }
    let engine = Engine::new(&default_artifact_dir()).expect("engine");
    let exec = AidwExecutor::new(&engine);
    exec.warmup().expect("warmup");
    let pool = Pool::machine_sized();
    let params = AidwParams::default();
    print_header("Table 3: kNN-search stage time, original vs improved", &args.sizes);

    let opts = MeasureOpts::default();
    let mut original_ms = Vec::new();
    let mut improved_ms = Vec::new();
    for &n in &args.sizes {
        eprintln!("  measuring n = {} ...", size_label(n));
        let (data, queries) = standard_workload(n, &opts);

        // original: streamed brute-force kNN on PJRT (incl. transfers)
        let t0 = std::time::Instant::now();
        let r1 = exec.run_knn_brute(&data, &queries, params.k).expect("knn");
        original_ms.push(t0.elapsed().as_secs_f64() * 1e3);

        // improved: grid build + ring-expansion local search (rust)
        let t1 = std::time::Instant::now();
        let grid = EvenGrid::build_on(&pool, &data, None, &GridConfig::default()).unwrap();
        let (r2, stats) = grid_knn_avg_distances_on(
            &pool,
            &grid,
            &queries,
            &GridKnnConfig { k: params.k, ..Default::default() },
        );
        improved_ms.push(t1.elapsed().as_secs_f64() * 1e3);

        // sanity: both stages agree
        for (a, b) in r1.iter().zip(&r2) {
            assert!((a - b).abs() < 1e-3 * b.max(1e-3), "kNN mismatch {a} vs {b}");
        }
        eprintln!(
            "    grid kNN visited {:.1} candidates/query (vs {} brute)",
            stats.candidates as f64 / queries.len() as f64,
            n
        );
    }

    let mut headers = vec!["Version".to_string()];
    headers.extend(args.sizes.iter().map(|&n| size_label(n)));
    let hdr_refs: Vec<&str> = headers.iter().map(|s| s.as_str()).collect();
    let mut table = Table::new(&hdr_refs);
    let mut r1 = vec!["Original naive (brute kNN)".to_string()];
    r1.extend(original_ms.iter().map(|&v| fmt_ms(v)));
    table.row(&r1);
    let mut r2 = vec!["Original tiled (same kNN)".to_string()];
    r2.extend(original_ms.iter().map(|&v| fmt_ms(v)));
    table.row(&r2);
    let mut r3 = vec!["Two improved versions (grid)".to_string()];
    r3.extend(improved_ms.iter().map(|&v| fmt_ms(v)));
    table.row(&r3);
    table.print();

    println!("\nimproved/original kNN ratio (paper: shrinks to <1% at 1000K):");
    for (i, &n) in args.sizes.iter().enumerate() {
        println!(
            "  n={}: {:.2}%",
            size_label(n),
            100.0 * improved_ms[i] / original_ms[i]
        );
    }
}
