//! The adaptive power-parameter pipeline (Eqs. 2-6) — rust mirror of
//! `python/compile/alpha.py`.  The integration test `it_runtime` checks
//! this implementation against the AOT-compiled `alpha_*` artifact
//! value-for-value, so the two layers cannot drift apart.

use crate::aidw::params::AidwParams;

/// Eq. 2: expected nearest-neighbor distance of a random pattern,
/// `r_exp = 1 / (2 * sqrt(n / A))`.
#[inline]
pub fn expected_nn_distance(n_points: f64, area: f64) -> f64 {
    1.0 / (2.0 * (n_points / area).sqrt())
}

/// Eq. 4: nearest-neighbor statistic `R(S0) = r_obs / r_exp`.
#[inline]
pub fn nn_statistic(r_obs: f64, r_exp: f64) -> f64 {
    r_obs / r_exp
}

/// Eq. 5: cosine fuzzy membership, clamped to [0, 1].
#[inline]
pub fn fuzzy_membership(r_stat: f64, r_min: f64, r_max: f64) -> f64 {
    if r_stat <= r_min {
        0.0
    } else if r_stat >= r_max {
        1.0
    } else {
        (0.5 - 0.5 * (std::f64::consts::PI / r_max * (r_stat - r_min)).cos()).clamp(0.0, 1.0)
    }
}

/// Eq. 6: triangular membership mapping mu_R to a distance-decay alpha
/// over the five levels.  Branch-for-branch as printed in the paper.
#[inline]
pub fn alpha_from_membership(mu: f64, levels: &[f64; 5]) -> f64 {
    let [a1, a2, a3, a4, a5] = *levels;
    if mu <= 0.1 {
        a1
    } else if mu <= 0.3 {
        a1 * (1.0 - 5.0 * (mu - 0.1)) + 5.0 * a2 * (mu - 0.1)
    } else if mu <= 0.5 {
        5.0 * a3 * (mu - 0.3) + a2 * (1.0 - 5.0 * (mu - 0.3))
    } else if mu <= 0.7 {
        a3 * (1.0 - 5.0 * (mu - 0.5)) + 5.0 * a4 * (mu - 0.5)
    } else if mu <= 0.9 {
        5.0 * a5 * (mu - 0.7) + a4 * (1.0 - 5.0 * (mu - 0.7))
    } else {
        a5
    }
}

/// Full Eq. 2-6 pipeline: observed average kNN distance -> adaptive alpha.
#[inline]
pub fn adaptive_alpha(r_obs: f64, r_exp: f64, params: &AidwParams) -> f64 {
    let r_stat = nn_statistic(r_obs, r_exp);
    let mu = fuzzy_membership(r_stat, params.r_min, params.r_max);
    alpha_from_membership(mu, &params.alpha_levels)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn p() -> AidwParams {
        AidwParams::default()
    }

    #[test]
    fn eq2_reference_values() {
        assert!((expected_nn_distance(100.0, 1.0) - 0.05).abs() < 1e-15);
        let r1 = expected_nn_distance(64.0, 1.0);
        let r2 = expected_nn_distance(64.0, 2.0);
        assert!((r2 / r1 - 2f64.sqrt()).abs() < 1e-12);
    }

    #[test]
    fn eq5_shape() {
        assert_eq!(fuzzy_membership(-1.0, 0.0, 2.0), 0.0);
        assert_eq!(fuzzy_membership(0.0, 0.0, 2.0), 0.0);
        assert_eq!(fuzzy_membership(2.0, 0.0, 2.0), 1.0);
        assert_eq!(fuzzy_membership(99.0, 0.0, 2.0), 1.0);
        assert!((fuzzy_membership(1.0, 0.0, 2.0) - 0.5).abs() < 1e-12);
        // monotone on a fine sweep
        let mut prev = -1.0;
        for i in 0..=200 {
            let mu = fuzzy_membership(i as f64 * 0.01, 0.0, 2.0);
            assert!(mu >= prev - 1e-12);
            prev = mu;
        }
    }

    #[test]
    fn eq6_knots_and_midpoints() {
        let lv = p().alpha_levels;
        for (mu, want) in [(0.1, lv[0]), (0.3, lv[1]), (0.5, lv[2]), (0.7, lv[3]), (0.9, lv[4])] {
            assert!((alpha_from_membership(mu, &lv) - want).abs() < 1e-12, "mu={mu}");
        }
        for (i, mu) in [(0usize, 0.2), (1, 0.4), (2, 0.6), (3, 0.8)] {
            let want = 0.5 * (lv[i] + lv[i + 1]);
            assert!((alpha_from_membership(mu, &lv) - want).abs() < 1e-12);
        }
        assert_eq!(alpha_from_membership(0.0, &lv), lv[0]);
        assert_eq!(alpha_from_membership(1.0, &lv), lv[4]);
    }

    #[test]
    fn eq6_continuous_at_breakpoints() {
        let lv = p().alpha_levels;
        for bp in [0.1, 0.3, 0.5, 0.7, 0.9] {
            let lo = alpha_from_membership(bp - 1e-9, &lv);
            let hi = alpha_from_membership(bp + 1e-9, &lv);
            assert!((lo - hi).abs() < 1e-6, "discontinuity at {bp}");
        }
    }

    #[test]
    fn pipeline_density_semantics() {
        let params = p();
        // clustered: r_obs << r_exp -> lowest alpha
        assert_eq!(adaptive_alpha(0.001, 1.0, &params), params.alpha_levels[0]);
        // dispersed: r_obs >> r_exp -> highest alpha
        assert_eq!(adaptive_alpha(10.0, 1.0, &params), params.alpha_levels[4]);
        // random: R = 1 -> mu = 0.5 -> alpha_3
        assert!((adaptive_alpha(1.0, 1.0, &params) - params.alpha_levels[2]).abs() < 1e-12);
    }

    #[test]
    fn matches_python_knot_table() {
        // sanity vs the jnp.interp formulation used in python tests
        let lv = p().alpha_levels;
        let knots_mu = [0.0, 0.1, 0.3, 0.5, 0.7, 0.9, 1.0];
        let knots_a = [lv[0], lv[0], lv[1], lv[2], lv[3], lv[4], lv[4]];
        for i in 0..=100 {
            let mu = i as f64 / 100.0;
            // linear interp over the knot table
            let j = knots_mu.iter().rposition(|&m| m <= mu).unwrap().min(5);
            let t = if knots_mu[j + 1] > knots_mu[j] {
                (mu - knots_mu[j]) / (knots_mu[j + 1] - knots_mu[j])
            } else {
                0.0
            };
            let want = knots_a[j] + t * (knots_a[j + 1] - knots_a[j]);
            let got = alpha_from_membership(mu, &lv);
            assert!((got - want).abs() < 1e-9, "mu={mu}: {got} vs {want}");
        }
    }
}
