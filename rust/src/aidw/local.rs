//! Local AIDW — the extension the paper's own conclusion calls for.
//!
//! §5.2.3 observes that after the fast kNN search the *weighted
//! interpolating* stage dominates (>95% of runtime at scale) and that
//! "further optimizations may need to be employed to improve the
//! efficiency of the weighted interpolating".  The standard remedy —
//! already present in Shepard's 1968 formulation and in Lu & Wong's
//! discussion of neighborhoods — is **localized weighting**: interpolate
//! over the N nearest data points instead of all m.  Complexity falls
//! from O(n·m) to O(n·(N + grid search)), at a controlled accuracy cost
//! (weights decay as d^-alpha, so far points contribute vanishingly).
//!
//! The neighbor lists come from the same grid pass that feeds the alpha
//! statistic (one search serves both stages), so the extension reuses the
//! paper's own data structure end to end.  Ablation A5
//! (`cargo bench --bench ablation_local`) quantifies the speed/accuracy
//! trade across N.

use crate::aidw::params::AidwParams;
use crate::aidw::plan::{self, SearchKind, Stage1Plan};
use crate::error::Result;
use crate::geom::PointSet;
use crate::grid::{EvenGrid, GridConfig};
use crate::knn::grid_knn::RingRule;
use crate::pool::{self, Pool};

/// Local-AIDW configuration.
#[derive(Debug, Clone, Copy)]
pub struct LocalConfig {
    /// Neighbors used in the weighted average (N >= params.k).
    pub n_neighbors: usize,
    /// Ring rule for the neighbor search.
    pub rule: RingRule,
}

impl Default for LocalConfig {
    fn default() -> Self {
        LocalConfig { n_neighbors: 32, rule: RingRule::Exact }
    }
}

/// Local AIDW: one grid pass for (neighbors, r_obs), then Eq. 1 restricted
/// to each query's N nearest points.
pub fn interpolate_local(
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    cfg: &LocalConfig,
) -> Result<Vec<f64>> {
    interpolate_local_on(pool::global(), data, queries, params, cfg)
}

/// [`interpolate_local`] on an explicit pool: build the grid, execute a
/// gathering [`Stage1Plan`], then run the local stage-2 weighting over
/// the artifact — the same plan-IR pair the serving coordinator executes.
pub fn interpolate_local_on(
    pool: &Pool,
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    cfg: &LocalConfig,
) -> Result<Vec<f64>> {
    interpolate_local_layout_on(pool, data, queries, params, cfg, plan::Layout::Aos)
}

/// [`interpolate_local_on`] with an explicit stage-2 [`plan::Layout`]:
/// the blocked layouts gather each row's neighbors into columnar scratch
/// and run the blocked weighting — bit-identical to the scalar reference
/// for every layout (the bench ablation drives this entry point).
pub fn interpolate_local_layout_on(
    pool: &Pool,
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    cfg: &LocalConfig,
    layout: plan::Layout,
) -> Result<Vec<f64>> {
    assert!(!data.is_empty(), "no data points");
    let grid = EvenGrid::build_on(pool, data, None, &GridConfig::default())?;
    let n = cfg.n_neighbors.max(params.k).max(1);
    let area = params.area.unwrap_or_else(|| data.bounds().area());
    let stage1 = Stage1Plan::new(
        params.k,
        cfg.rule,
        Some(n),
        params,
        data.len(),
        area,
        SearchKind::Grid,
    );
    let artifact = stage1.execute_grid(pool, &grid, queries);
    let table = artifact.neighbors.as_ref().expect("gathering plan produces a table");
    Ok(plan::local_weighted_layout_on(pool, data, queries, artifact.alphas(), table, layout))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aidw::serial;
    use crate::workload;

    #[test]
    fn n_equals_m_reproduces_global_aidw() {
        let data = workload::uniform_square(300, 50.0, 311);
        let queries = workload::uniform_square(60, 50.0, 312).xy();
        let params = AidwParams::default();
        let cfg = LocalConfig { n_neighbors: 300, ..Default::default() };
        let got = interpolate_local(&data, &queries, &params, &cfg).unwrap();
        let want = serial::aidw_serial(&data, &queries, &params);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn accuracy_improves_with_n() {
        let data = workload::uniform_square(2000, 100.0, 313);
        let queries = workload::uniform_square(100, 100.0, 314).xy();
        let params = AidwParams::default();
        let global = serial::aidw_serial(&data, &queries, &params);
        let mut prev_err = f64::INFINITY;
        for n in [16usize, 64, 256, 1024] {
            let cfg = LocalConfig { n_neighbors: n, ..Default::default() };
            let local = interpolate_local(&data, &queries, &params, &cfg).unwrap();
            let err = serial::rmse(&local, &global);
            assert!(
                err <= prev_err + 1e-9,
                "error did not shrink: n={n} err={err} prev={prev_err}"
            );
            prev_err = err;
        }
        // with 256 of 2000 points the localized answer is already close
        let cfg = LocalConfig { n_neighbors: 256, ..Default::default() };
        let local = interpolate_local(&data, &queries, &params, &cfg).unwrap();
        let (lo, hi) = data.z_range().unwrap();
        assert!(serial::rmse(&local, &global) < 0.05 * (hi - lo));
    }

    #[test]
    fn prediction_within_range_and_exact_hits() {
        let data = workload::terrain_samples(800, 100.0, 0.0, 315);
        let mut queries = workload::uniform_square(50, 100.0, 316).xy();
        queries[0] = (data.xs[3], data.ys[3]); // exact hit
        let params = AidwParams::default();
        let got = interpolate_local(&data, &queries, &params, &LocalConfig::default()).unwrap();
        let (lo, hi) = data.z_range().unwrap();
        for &z in &got {
            assert!(z >= lo - 1e-9 && z <= hi + 1e-9);
        }
        assert!((got[0] - data.zs[3]).abs() < 1e-3);
    }

    #[test]
    fn small_dataset_smaller_than_n() {
        let data = workload::uniform_square(5, 10.0, 317);
        let queries = vec![(5.0, 5.0), (0.0, 0.0)];
        let params = AidwParams::default();
        let got = interpolate_local(&data, &queries, &params, &LocalConfig::default()).unwrap();
        // N > m: must degrade to global weighting over all 5 points
        let want = serial::aidw_serial(&data, &queries, &params);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }
}
