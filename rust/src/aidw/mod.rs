//! AIDW interpolation: the paper's Eqs. 1-6 in rust.
//!
//! * [`params`] — the knobs (k, alpha levels, fuzzy bounds);
//! * [`alpha`]  — the adaptive power-parameter pipeline (Eqs. 2-6), the
//!   exact mirror of `python/compile/alpha.py` (cross-checked by the
//!   integration tests against the PJRT `alpha_*` artifact);
//! * [`serial`] — the double-precision serial CPU baseline (the paper's
//!   Table-1 "CPU/Serial" column) plus standard IDW;
//! * [`pipeline`] — the pure-rust *improved* pipeline (grid kNN + parallel
//!   weighting): the CPU fallback when no PJRT artifacts are present, and
//!   the reference the coordinator's PJRT path is validated against.

pub mod alpha;
pub mod local;
pub mod params;
pub mod pipeline;
pub mod serial;

pub use params::AidwParams;
