//! AIDW interpolation: the paper's Eqs. 1-6 in rust.
//!
//! * [`params`] — the knobs (k, alpha levels, fuzzy bounds);
//! * [`alpha`]  — the adaptive power-parameter pipeline (Eqs. 2-6), the
//!   exact mirror of `python/compile/alpha.py` (cross-checked by the
//!   integration tests against the PJRT `alpha_*` artifact);
//! * [`plan`]   — the explicit two-stage plan IR: [`plan::Stage1Plan`]
//!   (kNN search + alpha, over a grid or a merged live snapshot) produces
//!   a reusable [`plan::NeighborArtifact`] that a [`plan::Stage2Plan`]
//!   (dense or local weighting) consumes.  Every execution path below —
//!   and the serving coordinator — runs through this seam, which is what
//!   enables stage-level batch coalescing and epoch-keyed neighbor reuse;
//! * [`serial`] — the double-precision serial CPU baseline (the paper's
//!   Table-1 "CPU/Serial" column) plus standard IDW;
//! * [`pipeline`] — the pure-rust *improved* pipeline: a thin driver that
//!   builds a grid, executes a dense `Stage1Plan`, and runs the parallel
//!   Eq.-1 weighting — the CPU fallback when no PJRT artifacts are
//!   present, and the reference the coordinator's PJRT path is validated
//!   against;
//! * [`local`]  — the A5 localized-weighting extension, likewise a plan
//!   builder + executor pair (gathering `Stage1Plan`, local `Stage2Plan`).

pub mod alpha;
pub mod local;
pub mod params;
pub mod pipeline;
pub mod plan;
pub mod serial;

pub use params::AidwParams;
