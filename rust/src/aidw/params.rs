//! AIDW parameters.

/// Tunables of the AIDW algorithm (paper §2.2).
#[derive(Debug, Clone, PartialEq)]
pub struct AidwParams {
    /// Number of nearest neighbors for the spatial-pattern statistic
    /// (Eq. 3).  The paper's experiments use k = 10.
    pub k: usize,
    /// The five distance-decay levels alpha_1..alpha_5 of Eq. 6.
    pub alpha_levels: [f64; 5],
    /// Fuzzy-membership bounds of Eq. 5 (paper default 0.0 / 2.0).
    pub r_min: f64,
    pub r_max: f64,
    /// Optional explicit study-region area `A` of Eq. 2; default is the
    /// data bounding-box area.
    pub area: Option<f64>,
}

impl Default for AidwParams {
    fn default() -> Self {
        AidwParams {
            k: 10,
            alpha_levels: [0.5, 1.0, 2.0, 3.0, 4.0],
            r_min: 0.0,
            r_max: 2.0,
            area: None,
        }
    }
}

impl AidwParams {
    /// Validate parameter sanity; returns a message on failure.
    pub fn validate(&self) -> Result<(), String> {
        if self.k == 0 {
            return Err("k must be >= 1".into());
        }
        if !(self.r_max > self.r_min) {
            return Err(format!("r_max ({}) must exceed r_min ({})", self.r_max, self.r_min));
        }
        if self.alpha_levels.iter().any(|a| !a.is_finite() || *a <= 0.0) {
            return Err("alpha levels must be positive finite".into());
        }
        if let Some(a) = self.area {
            if !(a > 0.0) {
                return Err("explicit area must be positive".into());
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_matches_paper() {
        let p = AidwParams::default();
        assert_eq!(p.k, 10);
        assert_eq!(p.alpha_levels, [0.5, 1.0, 2.0, 3.0, 4.0]);
        assert_eq!((p.r_min, p.r_max), (0.0, 2.0));
        assert!(p.validate().is_ok());
    }

    #[test]
    fn validation_catches_bad_params() {
        let mut p = AidwParams { k: 0, ..Default::default() };
        assert!(p.validate().is_err());
        p.k = 5;
        p.r_max = 0.0;
        assert!(p.validate().is_err());
        p.r_max = 2.0;
        p.alpha_levels[2] = -1.0;
        assert!(p.validate().is_err());
        p.alpha_levels[2] = 2.0;
        p.area = Some(0.0);
        assert!(p.validate().is_err());
    }
}
