//! The pure-rust *improved* AIDW pipeline: grid kNN (stage 1) + parallel
//! weighted interpolation (stage 2).
//!
//! This is the CPU execution of the same two-stage structure the
//! coordinator runs against PJRT artifacts — used as (a) the fallback when
//! artifacts are absent, (b) the cross-check oracle for the PJRT path, and
//! (c) the stage-timing subject for Tables 2/3 style measurements when the
//! PJRT engine is not the variable under test.

use crate::aidw::params::AidwParams;
use crate::aidw::plan::{self, Layout, SearchKind, Stage1Plan};
use crate::geom::{dist2, Columns, PointSet, EPS_D2};
use crate::grid::{EvenGrid, GridConfig};
use crate::knn::grid_knn::RingRule;
use crate::pool::{self, Pool};

/// Timing breakdown of one improved-pipeline run (paper Table 2).
#[derive(Debug, Clone, Copy, Default)]
pub struct StageTimes {
    /// Grid construction + kNN search + alpha (stage 1), seconds.
    pub knn_s: f64,
    /// Weighted interpolating (stage 2), seconds.
    pub interp_s: f64,
}

/// Improved AIDW, pure rust: build grid, grid-kNN for r_obs, adaptive
/// alpha, then parallel Eq.-1 weighting over all data points.
pub fn interpolate_improved(
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
) -> Vec<f64> {
    interpolate_improved_on(pool::global(), data, queries, params, RingRule::Exact).0
}

/// [`interpolate_improved`] with explicit pool and ring rule; returns the
/// per-stage wall-clock breakdown.
///
/// This is the plan-IR driver form: build the grid, execute a dense
/// [`Stage1Plan`], then run the Eq.-1 weighting over the artifact's
/// alphas — the same two calls the coordinator's planner makes, so the
/// in-process and serving paths cannot drift apart numerically.
pub fn interpolate_improved_on(
    pool: &Pool,
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    rule: RingRule,
) -> (Vec<f64>, StageTimes) {
    assert!(!data.is_empty(), "no data points");
    let mut times = StageTimes::default();

    // ---- Stage 1: grid + kNN + alpha (one Stage1Plan execution) -----
    let t0 = std::time::Instant::now();
    let grid = EvenGrid::build_on(pool, data, None, &GridConfig::default())
        .expect("non-empty data");
    let area = params.area.unwrap_or_else(|| data.bounds().area());
    let plan =
        Stage1Plan::new(params.k, rule, None, params, data.len(), area, SearchKind::Grid);
    let artifact = plan.execute_grid(pool, &grid, queries);
    // materialize the lazy alphas inside the stage-1 window: the alpha
    // pass is stage-1 work in the paper's decomposition
    let alphas = artifact.alphas();
    times.knn_s = t0.elapsed().as_secs_f64();

    // ---- Stage 2: weighted interpolating ----------------------------
    let t1 = std::time::Instant::now();
    let out = weighted_stage_on(pool, data, queries, alphas);
    times.interp_s = t1.elapsed().as_secs_f64();

    (out, times)
}

/// Stage 2 alone: parallel Eq.-1 weighting with per-query alphas.
pub fn weighted_stage_on(
    pool: &Pool,
    data: &PointSet,
    queries: &[(f64, f64)],
    alphas: &[f64],
) -> Vec<f64> {
    assert_eq!(queries.len(), alphas.len());
    let mut out = vec![0f64; queries.len()];
    pool.for_each_slice_mut(&mut out, 16, |offset, chunk| {
        for (j, slot) in chunk.iter_mut().enumerate() {
            let (qx, qy) = queries[offset + j];
            let a = alphas[offset + j];
            let mut sw = 0.0f64;
            let mut swz = 0.0f64;
            for i in 0..data.len() {
                let d2 = dist2(qx, qy, data.xs[i], data.ys[i]).max(EPS_D2);
                let w = (-0.5 * a * d2.ln()).exp();
                sw += w;
                swz += w * data.zs[i];
            }
            *slot = swz / sw;
        }
    });
    out
}

/// Query rows one cache panel is shared across in the blocked dense walk
/// (panel loop outside, row group inside — the panel's columns stay hot
/// while every row in the group consumes them).
const DENSE_ROW_GROUP: usize = 8;

/// Data points per cache panel of the blocked dense walk (3 columns ×
/// 4096 × 8 B = 96 KiB, sized to sit in L2 while a row group re-reads
/// it).
const DENSE_PANEL: usize = 4096;

/// Layout-parameterized stage 2: [`Layout::Aos`] is exactly
/// [`weighted_stage_on`]; the blocked layouts walk the dataset's
/// columnar view ([`PointSet::columns`], free — storage is already SoA)
/// as panel-outside/row-group-inside cache-blocked loops with
/// [`plan::accumulate_row_blocked`] micro-blocks inside each panel.
///
/// Each row still accumulates panels in ascending point order (panel 0's
/// micro-blocks, then panel 1's, ...), i.e. the same f64 additions in
/// the same order as the scalar reference — **bit-identical** for every
/// layout (pinned by `tests/it_layout.rs`).
pub fn weighted_stage_layout_on(
    pool: &Pool,
    data: &PointSet,
    queries: &[(f64, f64)],
    alphas: &[f64],
    layout: Layout,
) -> Vec<f64> {
    if layout == Layout::Aos {
        return weighted_stage_on(pool, data, queries, alphas);
    }
    let empty = Columns::new(&[], &[], &[]);
    blocked_dense_on(pool, data.columns(), empty, queries, alphas, layout.micro_width())
}

/// The shared blocked dense core: Eq.-1 over `main` then `tail`, both in
/// ascending index order per row.  `tail` carries a live snapshot's
/// gathered delta appends (empty for compacted data) so the merged-live
/// path reuses this exact loop instead of forking it.  Rows are grouped
/// ([`DENSE_ROW_GROUP`]) and points are paneled ([`DENSE_PANEL`]) so a
/// panel's columns stay cache-hot while the whole group consumes them;
/// within a row the panels are visited in order, which keeps the
/// summation sequence identical to the scalar reference.
pub(crate) fn blocked_dense_on(
    pool: &Pool,
    main: Columns<'_>,
    tail: Columns<'_>,
    queries: &[(f64, f64)],
    alphas: &[f64],
    block: usize,
) -> Vec<f64> {
    assert_eq!(queries.len(), alphas.len());
    let n = main.len();
    let mut out = vec![0f64; queries.len()];
    pool.for_each_slice_mut(&mut out, 16, |offset, chunk| {
        let mut g0 = 0usize;
        while g0 < chunk.len() {
            let g1 = (g0 + DENSE_ROW_GROUP).min(chunk.len());
            let mut sw = [0.0f64; DENSE_ROW_GROUP];
            let mut swz = [0.0f64; DENSE_ROW_GROUP];
            let mut p0 = 0usize;
            while p0 < n {
                let p1 = (p0 + DENSE_PANEL).min(n);
                let panel = main.sub(p0, p1);
                for j in g0..g1 {
                    let (qx, qy) = queries[offset + j];
                    let a = alphas[offset + j];
                    plan::accumulate_row_blocked(
                        qx,
                        qy,
                        a,
                        panel,
                        block,
                        &mut sw[j - g0],
                        &mut swz[j - g0],
                    );
                }
                p0 = p1;
            }
            for j in g0..g1 {
                if !tail.is_empty() {
                    let (qx, qy) = queries[offset + j];
                    let a = alphas[offset + j];
                    plan::accumulate_row_blocked(
                        qx,
                        qy,
                        a,
                        tail,
                        block,
                        &mut sw[j - g0],
                        &mut swz[j - g0],
                    );
                }
                chunk[j] = swz[j - g0] / sw[j - g0];
            }
            g0 = g1;
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aidw::serial;
    use crate::workload;

    #[test]
    fn matches_serial_baseline() {
        let data = workload::uniform_square(800, 100.0, 51);
        let queries = workload::uniform_square(120, 100.0, 52).xy();
        let params = AidwParams::default();
        let want = serial::aidw_serial(&data, &queries, &params);
        let (got, times) = interpolate_improved_on(
            &Pool::new(2), &data, &queries, &params, RingRule::Exact);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
        assert!(times.knn_s >= 0.0 && times.interp_s > 0.0);
    }

    #[test]
    fn paper_rule_also_close_to_serial() {
        let data = workload::uniform_square(1000, 100.0, 53);
        let queries = workload::uniform_square(100, 100.0, 54).xy();
        let params = AidwParams::default();
        let want = serial::aidw_serial(&data, &queries, &params);
        let (got, _) = interpolate_improved_on(
            &Pool::new(2), &data, &queries, &params, RingRule::PaperPlusOne);
        // the +1 heuristic may rarely pick a different neighbor set, which
        // only perturbs alpha slightly; predictions stay very close
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 0.5, "{g} vs {w}");
        }
    }

    #[test]
    fn pool_width_invariance() {
        let data = workload::uniform_square(500, 50.0, 55);
        let queries = workload::uniform_square(64, 50.0, 56).xy();
        let params = AidwParams::default();
        let (a, _) = interpolate_improved_on(&Pool::new(1), &data, &queries, &params, RingRule::Exact);
        let (b, _) = interpolate_improved_on(&Pool::new(4), &data, &queries, &params, RingRule::Exact);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_queries_ok() {
        let data = workload::uniform_square(100, 10.0, 57);
        let out = interpolate_improved(&data, &[], &AidwParams::default());
        assert!(out.is_empty());
    }

    #[test]
    fn blocked_dense_kernel_is_bit_identical() {
        let pool = Pool::new(2);
        // sizes straddle the micro-block, row-group, and panel boundaries
        // (ragged tails everywhere)
        for (n_data, n_q, seed) in [(37usize, 5usize, 58u64), (501, 67, 59), (4099, 19, 60)] {
            let data = workload::uniform_square(n_data, 50.0, seed);
            let queries = workload::uniform_square(n_q, 50.0, seed + 100).xy();
            let alphas: Vec<f64> =
                (0..n_q).map(|i| 0.5 + 0.3 * ((i % 7) as f64)).collect();
            let want = weighted_stage_on(&pool, &data, &queries, &alphas);
            for layout in [
                Layout::Soa,
                Layout::AosoaTiles { width: 1 },
                Layout::AosoaTiles { width: 13 },
                Layout::AosoaTiles { width: 64 },
            ] {
                let got = weighted_stage_layout_on(&pool, &data, &queries, &alphas, layout);
                assert_eq!(got, want, "{} n={n_data} q={n_q}", layout.tag());
            }
            // Aos routes to the reference itself
            let aos = weighted_stage_layout_on(&pool, &data, &queries, &alphas, Layout::Aos);
            assert_eq!(aos, want);
        }
    }
}
