//! The two-stage execution plan IR — the paper's decomposition ("the
//! improved algorithm is composed of the stages of kNN search and
//! weighted interpolating") made explicit and reusable.
//!
//! Every execution path used to fuse both stages into one monolithic call
//! per batch; this module splits them along the seam the paper draws:
//!
//! * a [`Stage1Plan`] describes the kNN search + adaptive-alpha stage —
//!   which search strategy ([`SearchKind`]: grid over a compacted index,
//!   merged base ∪ delta over a mutated live snapshot), the effective `k`
//!   (clamped to the live count), the ring rule, whether neighbor indices
//!   must be gathered for a local (A5) consumer, and the Eq.-2/-6 alpha
//!   parameters.  Executing it yields a [`NeighborArtifact`];
//! * the [`NeighborArtifact`] is the reusable stage-1 product: per-query
//!   `r_obs` (Eq. 3), per-query adaptive alphas (Eqs. 2-6), and — in
//!   local mode — the row-major neighbor-index table.  It is `Arc`-shared
//!   by the coordinator across stage-2 variants of one batch and stored
//!   in the `NeighborCache` for repeated rasters;
//! * a [`Stage2Plan`] names the weighting that consumes the artifact —
//!   dense Eq.-1 over every live point, or local over the gathered
//!   neighbors.  (The kernel *variant* — naive vs tiled — is a stage-2
//!   dispatch detail carried by
//!   [`crate::coordinator::options::Stage2Key`], not by the plan: it
//!   selects a PJRT artifact, never the numerics.);
//! * a [`Layout`] is the stage-2 plan's *data-access schedule*: how the
//!   CPU weighting kernels walk the snapshot.  `Aos` is the scalar
//!   reference loop; `Soa` streams the epoch's columnar view
//!   ([`crate::geom::Columns`] — free, because `PointSet` is SoA and the
//!   view is built once per epoch and carried through compaction) in
//!   cache-blocked, explicitly vectorizable fixed-width blocks;
//!   `AosoaTiles{width}` is the same blocked walk at a caller-chosen
//!   micro-tile width (the bench ablation axis).  The planner picks a
//!   layout per request at stage-2 planning time ([`Layout::choose`]:
//!   by stage-2 work size, with a per-request/config override), and the
//!   choice is stamped on the request trace.  Layout is in **neither**
//!   stage key — it never changes the numerics (blocked kernels keep the
//!   reference summation order, see [`accumulate_row_blocked`]), so jobs
//!   that differ only in layout still coalesce and share cache entries.
//!
//! The seam is what lets the batcher coalesce jobs that differ only in
//! stage-2 variant (one kNN sweep, several weightings), the coordinator
//! cache stage-1 products keyed on `(dataset, epoch, stage1_key, query
//! fingerprint)`, and local mode run on mutated datasets (the merged
//! search gathers per-id neighbors, tombstone-filtered).
//!
//! Numerics contract: executing a plan is **bit-identical** to the
//! monolithic paths it replaced — same search, same `r_exp` derivation,
//! same alpha pipeline, same summation order in stage 2 (pinned by
//! `tests/it_planner.rs`; layout bit-identity by `tests/it_layout.rs`).
//! The one caveat is exact distance ties at a neighbor-gather cut
//! boundary, where merged and grid searches may keep different tied
//! points (see [`crate::knn::merged`]); distances, r_obs, and dense
//! weighting are tie-insensitive.

use crate::aidw::alpha;
use crate::aidw::params::AidwParams;
use crate::geom::{dist2, Columns, PointSet, EPS_D2};
use crate::grid::EvenGrid;
use crate::knn::grid_knn::{self, GridKnnConfig, RingRule};
use crate::knn::merged::{self, MergedView};
use crate::pool::Pool;

/// Which neighbor-search strategy stage 1 runs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SearchKind {
    /// Ring expansion over a compacted [`EvenGrid`] index.
    Grid,
    /// Grid over the epoch base ∪ brute force over the delta overlay
    /// (mutated live snapshots); always the provably-exact bound.
    Merged,
}

/// The stage-1 plan: fully-concrete search + alpha parameters for one
/// dataset snapshot.  Build with [`Stage1Plan::new`], execute with
/// [`Stage1Plan::execute_grid`] / [`Stage1Plan::execute_merged`].
#[derive(Debug, Clone)]
pub struct Stage1Plan {
    /// Effective k for the Eq.-3 statistic (clamped to the live count).
    pub k: usize,
    /// Ring-expansion rule (the merged executor always uses the exact
    /// bound; see [`crate::knn::merged`] for why).
    pub rule: RingRule,
    /// `Some(n)` = also gather the n nearest neighbor indices (n >= k)
    /// for a local stage-2 consumer.
    pub gather: Option<usize>,
    /// Eq.-2 expected NN distance for (live count, effective area).
    pub r_exp: f64,
    /// Alpha parameters (levels + fuzzy bounds), k clamped, area filled.
    pub params: AidwParams,
    pub search: SearchKind,
}

/// Row-major neighbor-index table gathered by a local-mode stage 1
/// (`u32::MAX` = padding when fewer points exist).
#[derive(Debug, Clone)]
pub struct NeighborTable {
    pub idx: Vec<u32>,
    /// Row width (the gathered n).
    pub width: usize,
}

/// The reusable stage-1 product: everything stage 2 needs, and nothing
/// dataset-mutation-sensitive beyond the snapshot it was computed from.
///
/// The adaptive alphas are **lazy**: the artifact stores `r_obs` plus the
/// `(r_exp, params)` pair the Eqs. 2-6 pipeline derives alpha from, and
/// materializes the alpha vector on first [`NeighborArtifact::alphas`]
/// call.  A PJRT stage 2 recomputes alpha on-device from `r_obs`, so on
/// an artifact-backed coordinator the CPU alpha pass was dead work; CPU
/// consumers pay it exactly once per artifact (cached artifacts keep the
/// materialized vector).
///
/// This type is also the **gather seam** of the sharded stage 1
/// ([`crate::shard`], protocol v2.8): the shard engine scatters a raster
/// across spatial shards and gathers the per-row results into one
/// `NeighborArtifact` bit-identical to a whole-grid sweep's, so stage 2,
/// the neighbor cache, streaming, and subscriptions consume sharded and
/// unsharded stage-1 output interchangeably — none of them can tell
/// which path produced it.
#[derive(Debug, Clone, Default)]
pub struct NeighborArtifact {
    /// Eq.-3 average distance to the k nearest live points, per query.
    pub r_obs: Vec<f64>,
    /// Lazily-materialized adaptive alphas — see [`NeighborArtifact::alphas`].
    lazy_alphas: std::sync::OnceLock<Vec<f64>>,
    /// Eq.-2 expected NN distance the lazy alphas derive from.
    r_exp: f64,
    /// Alpha parameters (levels + fuzzy bounds) the lazy alphas derive from.
    params: AidwParams,
    /// Neighbor indices (local mode only).  Grid artifacts hold original
    /// base indices; merged artifacts hold merged candidate indices
    /// (`< n_base` = base index, else `n_base + delta position`).
    pub neighbors: Option<NeighborTable>,
    /// Wall seconds spent producing this artifact (the search; the alpha
    /// pass is lazy and timed by whichever consumer materializes it).
    pub stage1_s: f64,
}

impl NeighborArtifact {
    /// Assemble an artifact from a finished stage-1 search.  `r_exp` and
    /// `params` seed the lazy alpha materialization.
    pub fn new(
        r_obs: Vec<f64>,
        r_exp: f64,
        params: AidwParams,
        neighbors: Option<NeighborTable>,
        stage1_s: f64,
    ) -> NeighborArtifact {
        NeighborArtifact {
            r_obs,
            lazy_alphas: std::sync::OnceLock::new(),
            r_exp,
            params,
            neighbors,
            stage1_s,
        }
    }

    /// Adaptive alpha (Eqs. 2-6), per query — materialized on first use
    /// and cached on the artifact (thread-safe; every caller sees the
    /// same vector).  The per-element function is deterministic in
    /// `(r_obs[i], r_exp, params)`, so a lazily-recomputed vector is
    /// bit-identical to an eagerly-computed one.
    pub fn alphas(&self) -> &[f64] {
        self.lazy_alphas.get_or_init(|| {
            self.r_obs
                .iter()
                .map(|&ro| alpha::adaptive_alpha(ro, self.r_exp, &self.params))
                .collect()
        })
    }

    /// True when the lazy alpha vector has been materialized (memory
    /// accounting and the PJRT dead-work regression test read this).
    pub fn alphas_materialized(&self) -> bool {
        self.lazy_alphas.get().is_some()
    }

    /// Row-gather: a new artifact holding row `rows[i]` of every
    /// per-query buffer — the per-query-row subset reuse behind the
    /// neighbor cache's subset hits.  Materialized alphas are gathered
    /// directly; otherwise the subset recomputes them lazily from the
    /// same `(r_exp, params)`, which is bit-identical either way.
    pub fn subset_rows(&self, rows: &[u32]) -> NeighborArtifact {
        let r_obs = rows.iter().map(|&r| self.r_obs[r as usize]).collect();
        let neighbors = self.neighbors.as_ref().map(|t| {
            let mut idx = Vec::with_capacity(rows.len() * t.width);
            for &r in rows {
                let at = r as usize * t.width;
                idx.extend_from_slice(&t.idx[at..at + t.width]);
            }
            NeighborTable { idx, width: t.width }
        });
        let sub = NeighborArtifact::new(r_obs, self.r_exp, self.params.clone(), neighbors, 0.0);
        if let Some(al) = self.lazy_alphas.get() {
            let _ = sub
                .lazy_alphas
                .set(rows.iter().map(|&r| al[r as usize]).collect());
        }
        sub
    }
}

/// Tile-grained partition of a query raster's rows for stage-2
/// execution and incremental delivery.
///
/// Stage 2 is row-independent — every weighting kernel (dense, local,
/// merged, PJRT) computes each query row from that row's artifact entries
/// alone — so executing stage 2 per tile over `[start, end)` row ranges
/// and concatenating the tiles in order is **bit-identical** to one
/// monolithic pass (pinned by `tests/it_stream.rs`).  Stage 1 is *not*
/// tiled: it runs once per batch and every tile gathers from the shared
/// [`NeighborArtifact`], which is also what makes tile-granular cache
/// reuse sound (a tile's rows are a row subset of the batch artifact).
///
/// `tile_rows = None` means one tile spanning the whole raster — the
/// back-compat default that makes the monolithic path a special case of
/// the tiled one rather than a second code path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct TilePlan {
    n_rows: usize,
    tile_rows: usize,
}

impl TilePlan {
    /// Partition `n_rows` query rows into tiles of at most `tile_rows`
    /// rows (`None` = one whole-raster tile).  A zero `tile_rows` is
    /// clamped to 1; oversized tiles are clamped to the raster.
    pub fn new(n_rows: usize, tile_rows: Option<usize>) -> TilePlan {
        let tile_rows = tile_rows.unwrap_or(n_rows).max(1).min(n_rows.max(1));
        TilePlan { n_rows, tile_rows }
    }

    /// Total rows across all tiles.
    pub fn n_rows(&self) -> usize {
        self.n_rows
    }

    /// Rows per tile (the last tile may be shorter).
    pub fn tile_rows(&self) -> usize {
        self.tile_rows
    }

    /// Number of tiles (0 only for an empty raster).
    pub fn n_tiles(&self) -> usize {
        if self.n_rows == 0 {
            0
        } else {
            (self.n_rows + self.tile_rows - 1) / self.tile_rows
        }
    }

    /// The `[start, end)` row range of one tile.
    pub fn range(&self, tile: usize) -> std::ops::Range<usize> {
        let start = tile * self.tile_rows;
        start..(start + self.tile_rows).min(self.n_rows)
    }

    /// Tiles in row order.
    pub fn iter(&self) -> impl Iterator<Item = std::ops::Range<usize>> + '_ {
        (0..self.n_tiles()).map(move |t| self.range(t))
    }
}

/// The stage-2 plan: which weighting consumes the artifact.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Stage2Plan {
    /// The paper's Eq.-1 dense weighting over every live point.
    Dense,
    /// Weighting restricted to the artifact's gathered neighbors (A5).
    Local,
}

impl Stage2Plan {
    /// The plan a resolved local-mode knob implies.
    pub fn for_local_neighbors(local_neighbors: Option<usize>) -> Stage2Plan {
        match local_neighbors {
            Some(_) => Stage2Plan::Local,
            None => Stage2Plan::Dense,
        }
    }
}

/// Widest micro-block the blocked kernels support (the per-row `d²`
/// scratch is a stack array of this size; `AosoaTiles` widths clamp to
/// it).
pub const MAX_BLOCK: usize = 64;

/// The stage-2 plan's data-access schedule: how the CPU weighting
/// kernels walk the snapshot.  Layout never changes the numerics — the
/// blocked walks keep the scalar reference's per-row summation order
/// ([`accumulate_row_blocked`]) — so it lives in **neither** stage key:
/// jobs that differ only in layout coalesce, and cached artifacts are
/// shared across layouts.  The PJRT stage-2 path has its own fixed
/// device layout and ignores this knob.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Layout {
    /// Scalar reference loop (one point at a time, AoS-style access).
    #[default]
    Aos,
    /// Cache-blocked columnar walk at the default micro width
    /// ([`Layout::SOA_BLOCK`]).
    Soa,
    /// Cache-blocked columnar walk at an explicit micro-tile width
    /// (1..=[`MAX_BLOCK`]) — the bench ablation axis.
    AosoaTiles {
        /// Points per micro-tile.
        width: usize,
    },
}

impl Layout {
    /// Micro width `AosoaTiles` defaults to when parsed as plain
    /// `"aosoa"`.
    pub const DEFAULT_AOSOA_WIDTH: usize = 16;
    /// Micro width the `Soa` schedule blocks by.
    pub const SOA_BLOCK: usize = 64;

    /// Points the planner wants per stage-2 job before it switches from
    /// the scalar reference to the blocked columnar walk (rows ×
    /// points-per-row; below this the blocking setup outweighs the win).
    pub const AUTO_SOA_WORK: usize = 32_768;

    /// Wire/CLI tag (protocol v2.7 `layout` field): `aos`, `soa`, or
    /// `aosoa:<width>`.
    pub fn tag(&self) -> String {
        match self {
            Layout::Aos => "aos".to_string(),
            Layout::Soa => "soa".to_string(),
            Layout::AosoaTiles { width } => format!("aosoa:{width}"),
        }
    }

    /// Micro-block width the blocked kernels run at (1 = scalar
    /// reference).
    pub fn micro_width(&self) -> usize {
        match self {
            Layout::Aos => 1,
            Layout::Soa => Layout::SOA_BLOCK,
            Layout::AosoaTiles { width } => (*width).clamp(1, MAX_BLOCK),
        }
    }

    /// True when the `AosoaTiles` width is representable (validation for
    /// programmatic construction; [`std::str::FromStr`] enforces it for
    /// wire/CLI input).
    pub fn is_valid(&self) -> bool {
        match self {
            Layout::AosoaTiles { width } => (1..=MAX_BLOCK).contains(width),
            _ => true,
        }
    }

    /// Stage-2 planning policy: the explicit override wins; otherwise
    /// pick by job size (`n_rows × points_per_row` — live count for
    /// dense, gathered width for local).  Deterministic in its inputs,
    /// so a given request always runs the same schedule.  Auto never
    /// picks `AosoaTiles`; explicit widths exist for the bench ablation
    /// and for callers that have measured their own sweet spot.
    pub fn choose(requested: Option<Layout>, n_rows: usize, points_per_row: usize) -> Layout {
        if let Some(l) = requested {
            return l;
        }
        if n_rows.saturating_mul(points_per_row) < Layout::AUTO_SOA_WORK {
            Layout::Aos
        } else {
            Layout::Soa
        }
    }
}

impl std::str::FromStr for Layout {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "aos" => Ok(Layout::Aos),
            "soa" => Ok(Layout::Soa),
            "aosoa" => Ok(Layout::AosoaTiles { width: Layout::DEFAULT_AOSOA_WIDTH }),
            other => {
                if let Some(w) = other.strip_prefix("aosoa:") {
                    let width: usize = w.parse().map_err(|_| {
                        crate::error::Error::InvalidArgument(format!(
                            "bad aosoa tile width '{w}' (expected an integer)"
                        ))
                    })?;
                    if !(1..=MAX_BLOCK).contains(&width) {
                        return Err(crate::error::Error::InvalidArgument(format!(
                            "aosoa tile width {width} out of range 1..={MAX_BLOCK}"
                        )));
                    }
                    Ok(Layout::AosoaTiles { width })
                } else {
                    Err(crate::error::Error::InvalidArgument(format!(
                        "unknown layout '{other}' (expected 'aos', 'soa', or 'aosoa[:width]')"
                    )))
                }
            }
        }
    }
}

/// One query row's Eq.-1 accumulation over a columnar range, in
/// fixed-width blocks: pass 1 fills a stack block of clamped `d²` (a
/// straight-line loop over the `xs`/`ys` slices the optimizer can
/// vectorize), pass 2 folds `w = exp(-½·α·ln d²)` into `(sw, swz)`.
/// No per-row allocation — the scratch is a `[f64; MAX_BLOCK]` on the
/// stack.
///
/// **Bit-identity:** every per-point value is computed by the same
/// expression as the scalar reference, and the fold visits points in
/// ascending index order within and across blocks — the same sequence of
/// f64 additions in the same order, hence the same bits for any block
/// width.  Pinned (blocked vs scalar, all layouts) by
/// `tests/it_layout.rs`.
#[inline]
pub fn accumulate_row_blocked(
    qx: f64,
    qy: f64,
    a: f64,
    cols: Columns<'_>,
    block: usize,
    sw: &mut f64,
    swz: &mut f64,
) {
    let block = block.clamp(1, MAX_BLOCK);
    let mut scratch = [0.0f64; MAX_BLOCK];
    let n = cols.len();
    let mut at = 0usize;
    while at < n {
        let b = block.min(n - at);
        let xs = &cols.xs[at..at + b];
        let ys = &cols.ys[at..at + b];
        let zs = &cols.zs[at..at + b];
        let d2s = &mut scratch[..b];
        for (d, (&x, &y)) in d2s.iter_mut().zip(xs.iter().zip(ys)) {
            *d = dist2(qx, qy, x, y).max(EPS_D2);
        }
        for (&d2, &z) in d2s.iter().zip(zs) {
            let w = (-0.5 * a * d2.ln()).exp();
            *sw += w;
            *swz += w * z;
        }
        at += b;
    }
}

impl Stage1Plan {
    /// Build a stage-1 plan.  `k` and `gather` are clamped the way every
    /// execution path historically clamped them (`k` to the live count,
    /// `gather` up to at least `k`); `area` is the effective Eq.-2 area
    /// (request override or dataset bounds); `params` supplies the alpha
    /// levels and fuzzy bounds.
    pub fn new(
        k: usize,
        rule: RingRule,
        gather: Option<usize>,
        params: &AidwParams,
        n_live: usize,
        area: f64,
        search: SearchKind,
    ) -> Stage1Plan {
        let k = k.min(n_live).max(1);
        let gather = gather.map(|n| n.max(k));
        let mut params = params.clone();
        params.k = k;
        params.area = Some(area);
        let r_exp = alpha::expected_nn_distance(n_live as f64, area);
        Stage1Plan { k, rule, gather, r_exp, params, search }
    }

    /// The stage-2 plan this stage-1 plan was built to feed.
    pub fn stage2(&self) -> Stage2Plan {
        Stage2Plan::for_local_neighbors(self.gather)
    }

    /// Execute over a compacted grid index ([`SearchKind::Grid`]).
    pub fn execute_grid(
        &self,
        pool: &Pool,
        grid: &EvenGrid,
        queries: &[(f64, f64)],
    ) -> NeighborArtifact {
        let t0 = std::time::Instant::now();
        let (r_obs, neighbors) = match self.gather {
            Some(n) => {
                let (idx, r_obs) =
                    grid_knn::grid_knn_neighbors(pool, grid, queries, n, self.k, self.rule);
                (r_obs, Some(NeighborTable { idx, width: n }))
            }
            None => {
                let cfg = GridKnnConfig { k: self.k, rule: self.rule };
                let (r_obs, _) = grid_knn::grid_knn_avg_distances_on(pool, grid, queries, &cfg);
                (r_obs, None)
            }
        };
        self.finish(t0, r_obs, neighbors)
    }

    /// Execute over a mutated live snapshot ([`SearchKind::Merged`]):
    /// grid over the epoch base ∪ brute over the delta, tombstones
    /// filtered, exact termination bound regardless of [`Stage1Plan::rule`].
    pub fn execute_merged(
        &self,
        pool: &Pool,
        view: &MergedView<'_>,
        queries: &[(f64, f64)],
    ) -> NeighborArtifact {
        let t0 = std::time::Instant::now();
        let (r_obs, neighbors) = match self.gather {
            Some(n) => {
                let (idx, r_obs) = merged::merged_knn_neighbors_on(pool, view, queries, n, self.k);
                (r_obs, Some(NeighborTable { idx, width: n }))
            }
            None => {
                let r_obs = merged::merged_knn_avg_distances_on(pool, view, queries, self.k);
                (r_obs, None)
            }
        };
        self.finish(t0, r_obs, neighbors)
    }

    /// Artifact epilogue shared by both executors: packages r_obs with
    /// the `(r_exp, params)` pair the lazy alpha pass (Eqs. 2-6) derives
    /// from.  Alpha itself materializes at the first CPU consumer — a
    /// PJRT stage 2 recomputes it on-device and never pays the pass.
    fn finish(
        &self,
        t0: std::time::Instant,
        r_obs: Vec<f64>,
        neighbors: Option<NeighborTable>,
    ) -> NeighborArtifact {
        NeighborArtifact::new(
            r_obs,
            self.r_exp,
            self.params.clone(),
            neighbors,
            t0.elapsed().as_secs_f64(),
        )
    }
}

/// The shared local (A5) stage-2 kernel: Eq.-1 weighting restricted to
/// each query's gathered neighbor row, with neighbor-index resolution
/// supplied by the caller (original base indices for grid artifacts,
/// merged base ∪ delta candidate indices for live snapshots).  **One**
/// kernel — one padding rule, one `EPS_D2` clamp, one summation order —
/// is what the merged-vs-compacted bit-identity contract rests on; do
/// not fork it per index space.
pub fn local_weighted_with<F>(
    pool: &Pool,
    queries: &[(f64, f64)],
    alphas: &[f64],
    nbr_idx: &[u32],
    width: usize,
    resolve: F,
) -> Vec<f64>
where
    F: Fn(u32) -> (f64, f64, f64) + Sync,
{
    assert_eq!(queries.len(), alphas.len());
    assert_eq!(nbr_idx.len(), queries.len() * width);
    let mut out = vec![0f64; queries.len()];
    pool.for_each_slice_mut(&mut out, 64, |offset, chunk| {
        for (j, slot) in chunk.iter_mut().enumerate() {
            let qi = offset + j;
            let (qx, qy) = queries[qi];
            let a = alphas[qi];
            let mut sw = 0.0f64;
            let mut swz = 0.0f64;
            for &pid in &nbr_idx[qi * width..(qi + 1) * width] {
                if pid == u32::MAX {
                    continue; // padding (fewer than n points exist)
                }
                let (x, y, z) = resolve(pid);
                let d2 = dist2(qx, qy, x, y).max(EPS_D2);
                let w = (-0.5 * a * d2.ln()).exp();
                sw += w;
                swz += w * z;
            }
            *slot = swz / sw;
        }
    });
    out
}

/// Local (A5) CPU stage 2 over a plain point set: the artifact's rows
/// hold original point indices (grid gathers).  Rows are consumed in
/// ascending-distance order — see [`local_weighted_with`].
pub fn local_weighted_on(
    pool: &Pool,
    data: &PointSet,
    queries: &[(f64, f64)],
    alphas: &[f64],
    table: &NeighborTable,
) -> Vec<f64> {
    local_weighted_with(pool, queries, alphas, &table.idx, table.width, |pid| {
        let i = pid as usize;
        (data.xs[i], data.ys[i], data.zs[i])
    })
}

/// Layout-parameterized local (A5) stage-2 kernel.  `Aos` is exactly
/// [`local_weighted_with`]; the blocked layouts first gather each row's
/// live neighbors through `resolve` into per-worker columnar scratch
/// (three `Vec`s allocated once per worker chunk and reused across its
/// rows — no per-row allocation), then run [`accumulate_row_blocked`]
/// over the gathered columns.  The gather keeps table order and drops
/// padding exactly where the scalar loop skips it, so the weight fold
/// visits the same points in the same order — **bit-identical** to the
/// reference for every layout (all-padding rows produce the same 0/0).
pub fn local_weighted_with_layout<F>(
    pool: &Pool,
    queries: &[(f64, f64)],
    alphas: &[f64],
    nbr_idx: &[u32],
    width: usize,
    layout: Layout,
    resolve: F,
) -> Vec<f64>
where
    F: Fn(u32) -> (f64, f64, f64) + Sync,
{
    if layout == Layout::Aos {
        return local_weighted_with(pool, queries, alphas, nbr_idx, width, resolve);
    }
    let block = layout.micro_width();
    assert_eq!(queries.len(), alphas.len());
    assert_eq!(nbr_idx.len(), queries.len() * width);
    let mut out = vec![0f64; queries.len()];
    pool.for_each_slice_mut(&mut out, 64, |offset, chunk| {
        let mut gx = vec![0f64; width];
        let mut gy = vec![0f64; width];
        let mut gz = vec![0f64; width];
        for (j, slot) in chunk.iter_mut().enumerate() {
            let qi = offset + j;
            let (qx, qy) = queries[qi];
            let a = alphas[qi];
            let mut live = 0usize;
            for &pid in &nbr_idx[qi * width..(qi + 1) * width] {
                if pid == u32::MAX {
                    continue; // padding (fewer than n points exist)
                }
                let (x, y, z) = resolve(pid);
                gx[live] = x;
                gy[live] = y;
                gz[live] = z;
                live += 1;
            }
            let cols = Columns::new(&gx[..live], &gy[..live], &gz[..live]);
            let mut sw = 0.0f64;
            let mut swz = 0.0f64;
            accumulate_row_blocked(qx, qy, a, cols, block, &mut sw, &mut swz);
            *slot = swz / sw;
        }
    });
    out
}

/// Layout-parameterized twin of [`local_weighted_on`] (original point
/// indices, compacted snapshots).
pub fn local_weighted_layout_on(
    pool: &Pool,
    data: &PointSet,
    queries: &[(f64, f64)],
    alphas: &[f64],
    table: &NeighborTable,
    layout: Layout,
) -> Vec<f64> {
    local_weighted_with_layout(pool, queries, alphas, &table.idx, table.width, layout, |pid| {
        let i = pid as usize;
        (data.xs[i], data.ys[i], data.zs[i])
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aidw::serial;
    use crate::grid::GridConfig;
    use crate::workload;

    #[test]
    fn grid_plan_matches_monolithic_dense_pipeline() {
        let data = workload::uniform_square(700, 80.0, 971);
        let queries = workload::uniform_square(90, 80.0, 972).xy();
        let params = AidwParams::default();
        let pool = Pool::new(2);
        let grid = EvenGrid::build_on(&pool, &data, None, &GridConfig::default()).unwrap();
        let area = data.bounds().area();
        let plan = Stage1Plan::new(
            params.k,
            RingRule::Exact,
            None,
            &params,
            data.len(),
            area,
            SearchKind::Grid,
        );
        assert_eq!(plan.stage2(), Stage2Plan::Dense);
        let art = plan.execute_grid(&pool, &grid, &queries);
        assert_eq!(art.r_obs.len(), queries.len());
        assert!(!art.alphas_materialized(), "alpha is lazy until a CPU consumer asks");
        assert_eq!(art.alphas().len(), queries.len());
        assert!(art.alphas_materialized());
        assert!(art.neighbors.is_none());
        let got = crate::aidw::pipeline::weighted_stage_on(&pool, &data, &queries, art.alphas());
        let want = serial::aidw_serial(&data, &queries, &params);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn local_plan_gathers_and_weights() {
        let data = workload::uniform_square(900, 60.0, 973);
        let queries = workload::uniform_square(50, 60.0, 974).xy();
        let params = AidwParams::default();
        let pool = Pool::new(2);
        let grid = EvenGrid::build_on(&pool, &data, None, &GridConfig::default()).unwrap();
        let plan = Stage1Plan::new(
            params.k,
            RingRule::Exact,
            Some(48),
            &params,
            data.len(),
            data.bounds().area(),
            SearchKind::Grid,
        );
        assert_eq!(plan.stage2(), Stage2Plan::Local);
        let art = plan.execute_grid(&pool, &grid, &queries);
        let table = art.neighbors.as_ref().expect("local plan gathers");
        assert_eq!(table.width, 48);
        let got = local_weighted_on(&pool, &data, &queries, art.alphas(), table);
        let want = crate::aidw::local::interpolate_local(
            &data,
            &queries,
            &params,
            &crate::aidw::local::LocalConfig { n_neighbors: 48, rule: RingRule::Exact },
        )
        .unwrap();
        assert_eq!(got, want, "plan-IR local must be bit-identical");
    }

    #[test]
    fn lazy_alphas_match_eager_and_subset_rows_gather_exactly() {
        let data = workload::uniform_square(400, 70.0, 975);
        let queries = workload::uniform_square(30, 70.0, 976).xy();
        let params = AidwParams::default();
        let pool = Pool::new(2);
        let grid = EvenGrid::build_on(&pool, &data, None, &GridConfig::default()).unwrap();
        let area = data.bounds().area();
        let plan = Stage1Plan::new(
            params.k,
            RingRule::Exact,
            Some(16),
            &params,
            data.len(),
            area,
            SearchKind::Grid,
        );
        let art = plan.execute_grid(&pool, &grid, &queries);
        // eager reference computed by hand from the same inputs
        let want: Vec<f64> = art
            .r_obs
            .iter()
            .map(|&ro| alpha::adaptive_alpha(ro, plan.r_exp, &plan.params))
            .collect();

        // subset BEFORE materialization: recomputes lazily, bit-identical
        let rows: Vec<u32> = vec![5, 0, 29, 5, 17];
        let sub_cold = art.subset_rows(&rows);
        assert!(!sub_cold.alphas_materialized());
        for (i, &r) in rows.iter().enumerate() {
            assert_eq!(sub_cold.r_obs[i], art.r_obs[r as usize]);
            assert_eq!(sub_cold.alphas()[i], want[r as usize]);
            let w = art.neighbors.as_ref().unwrap().width;
            assert_eq!(
                sub_cold.neighbors.as_ref().unwrap().idx[i * w..(i + 1) * w],
                art.neighbors.as_ref().unwrap().idx[r as usize * w..(r as usize + 1) * w]
            );
        }

        // materialize on the source, then subset AFTER: gathered directly
        assert_eq!(art.alphas(), want.as_slice());
        let sub_warm = art.subset_rows(&rows);
        assert!(sub_warm.alphas_materialized(), "materialized alphas are gathered, not redone");
        assert_eq!(sub_warm.alphas(), sub_cold.alphas());
    }

    #[test]
    fn tile_plan_partitions_exactly() {
        // whole-raster default: one tile
        let whole = TilePlan::new(100, None);
        assert_eq!(whole.n_tiles(), 1);
        assert_eq!(whole.range(0), 0..100);
        // even split
        let even = TilePlan::new(100, Some(25));
        assert_eq!(even.n_tiles(), 4);
        assert_eq!(even.iter().collect::<Vec<_>>(), vec![0..25, 25..50, 50..75, 75..100]);
        // ragged tail
        let ragged = TilePlan::new(10, Some(4));
        assert_eq!(ragged.n_tiles(), 3);
        assert_eq!(ragged.range(2), 8..10);
        // every row covered exactly once, in order
        let mut covered = 0usize;
        for r in ragged.iter() {
            assert_eq!(r.start, covered);
            covered = r.end;
        }
        assert_eq!(covered, 10);
        // clamps: zero tile -> 1 row; oversized tile -> whole raster
        assert_eq!(TilePlan::new(5, Some(0)).n_tiles(), 5);
        assert_eq!(TilePlan::new(5, Some(99)).n_tiles(), 1);
        // empty raster: no tiles (callers reject empty queries anyway)
        assert_eq!(TilePlan::new(0, Some(4)).n_tiles(), 0);
    }

    #[test]
    fn tiled_stage2_concatenation_is_bit_identical() {
        // the contract the streaming surface rests on: per-tile stage-2
        // execution over artifact row slices, concatenated in order,
        // equals the monolithic pass bit for bit (dense and local)
        let data = workload::uniform_square(600, 70.0, 977);
        let queries = workload::uniform_square(53, 70.0, 978).xy();
        let params = AidwParams::default();
        let pool = Pool::new(2);
        let grid = EvenGrid::build_on(&pool, &data, None, &GridConfig::default()).unwrap();
        let area = data.bounds().area();

        // dense
        let plan = Stage1Plan::new(
            params.k,
            RingRule::Exact,
            None,
            &params,
            data.len(),
            area,
            SearchKind::Grid,
        );
        let art = plan.execute_grid(&pool, &grid, &queries);
        let alphas = art.alphas();
        let whole = crate::aidw::pipeline::weighted_stage_on(&pool, &data, &queries, alphas);
        let tiles = TilePlan::new(queries.len(), Some(7));
        let mut tiled = Vec::with_capacity(queries.len());
        for r in tiles.iter() {
            tiled.extend(crate::aidw::pipeline::weighted_stage_on(
                &pool,
                &data,
                &queries[r.clone()],
                &alphas[r],
            ));
        }
        assert_eq!(tiled, whole, "tiled dense stage 2 must be bit-identical");

        // local (A5): tiles slice the gathered neighbor table row-wise
        let lplan = Stage1Plan::new(
            params.k,
            RingRule::Exact,
            Some(24),
            &params,
            data.len(),
            area,
            SearchKind::Grid,
        );
        let lart = lplan.execute_grid(&pool, &grid, &queries);
        let table = lart.neighbors.as_ref().unwrap();
        let lalphas = lart.alphas();
        let lwhole = local_weighted_on(&pool, &data, &queries, lalphas, table);
        let mut ltiled = Vec::with_capacity(queries.len());
        for r in tiles.iter() {
            let w = table.width;
            ltiled.extend(local_weighted_with(
                &pool,
                &queries[r.clone()],
                &lalphas[r.clone()],
                &table.idx[r.start * w..r.end * w],
                w,
                |pid| {
                    let i = pid as usize;
                    (data.xs[i], data.ys[i], data.zs[i])
                },
            ));
        }
        assert_eq!(ltiled, lwhole, "tiled local stage 2 must be bit-identical");
    }

    #[test]
    fn gather_clamps_below_k() {
        let params = AidwParams::default(); // k = 10
        let plan = Stage1Plan::new(
            10,
            RingRule::Exact,
            Some(4),
            &params,
            1000,
            100.0,
            SearchKind::Grid,
        );
        assert_eq!(plan.gather, Some(10), "gather widens to at least k");
        // and k clamps to the live count
        let tiny = Stage1Plan::new(10, RingRule::Exact, None, &params, 3, 100.0, SearchKind::Grid);
        assert_eq!(tiny.k, 3);
        assert_eq!(tiny.params.k, 3);
    }

    #[test]
    fn layout_tags_roundtrip_and_parse_rejects_garbage() {
        for (l, tag) in [
            (Layout::Aos, "aos"),
            (Layout::Soa, "soa"),
            (Layout::AosoaTiles { width: 8 }, "aosoa:8"),
            (Layout::AosoaTiles { width: 64 }, "aosoa:64"),
        ] {
            assert_eq!(l.tag(), tag);
            assert_eq!(tag.parse::<Layout>().unwrap(), l);
            assert!(l.is_valid());
        }
        // bare "aosoa" defaults its width
        assert_eq!(
            "aosoa".parse::<Layout>().unwrap(),
            Layout::AosoaTiles { width: Layout::DEFAULT_AOSOA_WIDTH }
        );
        for bad in ["", "soaos", "aosoa:", "aosoa:0", "aosoa:65", "aosoa:x"] {
            assert!(bad.parse::<Layout>().is_err(), "{bad:?} must not parse");
        }
        assert!(!Layout::AosoaTiles { width: 0 }.is_valid());
        assert_eq!(Layout::AosoaTiles { width: 500 }.micro_width(), MAX_BLOCK);
    }

    #[test]
    fn layout_choose_is_override_then_size() {
        // override always wins
        assert_eq!(Layout::choose(Some(Layout::Aos), 1 << 20, 1 << 20), Layout::Aos);
        let aosoa = Layout::AosoaTiles { width: 8 };
        assert_eq!(Layout::choose(Some(aosoa), 1, 1), aosoa);
        // auto: small work scalar, big work blocked, never AosoaTiles
        assert_eq!(Layout::choose(None, 3, 500), Layout::Aos);
        assert_eq!(Layout::choose(None, 4096, 4096), Layout::Soa);
        // exact threshold boundary
        assert_eq!(Layout::choose(None, 1, Layout::AUTO_SOA_WORK - 1), Layout::Aos);
        assert_eq!(Layout::choose(None, 1, Layout::AUTO_SOA_WORK), Layout::Soa);
    }

    #[test]
    fn blocked_local_kernel_is_bit_identical_including_padding() {
        let data = workload::uniform_square(37, 50.0, 979); // fewer points than gather width
        let queries = workload::uniform_square(40, 50.0, 980).xy();
        let params = AidwParams::default();
        let pool = Pool::new(2);
        let grid = EvenGrid::build_on(&pool, &data, None, &GridConfig::default()).unwrap();
        let plan = Stage1Plan::new(
            params.k,
            RingRule::Exact,
            Some(48), // > 37 live points -> padded rows
            &params,
            data.len(),
            data.bounds().area(),
            SearchKind::Grid,
        );
        let art = plan.execute_grid(&pool, &grid, &queries);
        let table = art.neighbors.as_ref().unwrap();
        let want = local_weighted_on(&pool, &data, &queries, art.alphas(), table);
        for layout in [
            Layout::Soa,
            Layout::AosoaTiles { width: 1 },
            Layout::AosoaTiles { width: 7 },
            Layout::AosoaTiles { width: 64 },
        ] {
            let got = local_weighted_layout_on(&pool, &data, &queries, art.alphas(), table, layout);
            assert_eq!(got, want, "{} must be bit-identical to aos", layout.tag());
        }
    }
}
