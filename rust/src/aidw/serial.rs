//! Serial CPU reference implementations — the paper's baseline column.
//!
//! The Table-1 "CPU/Serial" baseline is AIDW in **double precision** on a
//! single thread, with the original algorithm's brute-force kNN embedded
//! per query (Mei et al. 2015).  Also provides standard constant-alpha IDW
//! (Shepard 1968) for the accuracy comparisons.

use crate::aidw::alpha;
use crate::aidw::params::AidwParams;
use crate::geom::{dist2, PointSet, EPS_D2};
use crate::knn::kbuffer::KBuffer;

/// Serial AIDW (the paper's CPU baseline): for every query, brute-force
/// kNN for r_obs, Eqs. 2-6 for alpha, then the Eq.-1 weighted average over
/// all data points.  O(n·m); single-threaded by design.
pub fn aidw_serial(data: &PointSet, queries: &[(f64, f64)], params: &AidwParams) -> Vec<f64> {
    let m = data.len();
    assert!(m > 0, "no data points");
    let area = params.area.unwrap_or_else(|| data.bounds().area());
    let r_exp = alpha::expected_nn_distance(m as f64, area);

    let mut out = Vec::with_capacity(queries.len());
    let mut buf = KBuffer::new(params.k.min(m).max(1));
    for &(qx, qy) in queries {
        // Stage 1: kNN (brute force, as in the original serial algorithm)
        buf.clear();
        for i in 0..m {
            buf.insert(dist2(qx, qy, data.xs[i], data.ys[i]));
        }
        let r_obs = buf.avg_distance();
        let a = alpha::adaptive_alpha(r_obs, r_exp, params);

        // Stage 2: Eq.-1 weighting over all data points
        out.push(weighted_average(data, qx, qy, a));
    }
    out
}

/// Standard IDW (Shepard 1968) with constant alpha — the method AIDW
/// improves on; serial double precision.
pub fn idw_serial(data: &PointSet, queries: &[(f64, f64)], alpha_const: f64) -> Vec<f64> {
    assert!(!data.is_empty(), "no data points");
    queries
        .iter()
        .map(|&(qx, qy)| weighted_average(data, qx, qy, alpha_const))
        .collect()
}

/// Eq. 1 for a single query: `sum(w_i z_i) / sum(w_i)`, `w = d^-alpha`.
/// Matches the artifact kernels' numerics: squared distances floored at
/// [`EPS_D2`], weights via `exp(-alpha/2 * ln d2)`.
#[inline]
pub fn weighted_average(data: &PointSet, qx: f64, qy: f64, a: f64) -> f64 {
    let mut sw = 0.0f64;
    let mut swz = 0.0f64;
    for i in 0..data.len() {
        let d2 = dist2(qx, qy, data.xs[i], data.ys[i]).max(EPS_D2);
        let w = (-0.5 * a * d2.ln()).exp();
        sw += w;
        swz += w * data.zs[i];
    }
    swz / sw
}

/// Root-mean-square error against ground truth (accuracy metric for the
/// examples and EXPERIMENTS.md).
pub fn rmse(pred: &[f64], truth: &[f64]) -> f64 {
    assert_eq!(pred.len(), truth.len());
    if pred.is_empty() {
        return 0.0;
    }
    let s: f64 = pred.iter().zip(truth).map(|(p, t)| (p - t) * (p - t)).sum();
    (s / pred.len() as f64).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    #[test]
    fn prediction_is_convex_combination() {
        let data = workload::uniform_square(300, 50.0, 41);
        let queries = workload::uniform_square(50, 50.0, 42).xy();
        let out = aidw_serial(&data, &queries, &AidwParams::default());
        let (lo, hi) = data.z_range().unwrap();
        for &z in &out {
            assert!(z >= lo - 1e-9 && z <= hi + 1e-9);
        }
    }

    #[test]
    fn query_at_data_point_recovers_value() {
        let data = workload::uniform_square(200, 50.0, 43);
        let q = vec![(data.xs[11], data.ys[11])];
        let out = aidw_serial(&data, &q, &AidwParams::default());
        assert!((out[0] - data.zs[11]).abs() < 1e-3, "{} vs {}", out[0], data.zs[11]);
        let idw = idw_serial(&data, &q, 2.0);
        assert!((idw[0] - data.zs[11]).abs() < 1e-3);
    }

    #[test]
    fn constant_field_is_reproduced_exactly() {
        let mut data = workload::uniform_square(100, 10.0, 44);
        data.zs.iter_mut().for_each(|z| *z = 7.5);
        let queries = workload::uniform_square(20, 10.0, 45).xy();
        for z in aidw_serial(&data, &queries, &AidwParams::default()) {
            assert!((z - 7.5).abs() < 1e-9);
        }
        for z in idw_serial(&data, &queries, 3.0) {
            assert!((z - 7.5).abs() < 1e-9);
        }
    }

    #[test]
    fn aidw_differs_from_standard_idw_on_mixed_density() {
        // on clustered data the adaptive alpha must actually change
        // predictions relative to constant alpha=2
        let data = workload::clustered(600, 100.0, 4, 1.5, 46);
        let queries = workload::uniform_square(80, 100.0, 47).xy();
        let aidw = aidw_serial(&data, &queries, &AidwParams::default());
        let idw = idw_serial(&data, &queries, 2.0);
        let diff: f64 = aidw.iter().zip(&idw).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-6, "adaptive alpha had no effect");
    }

    #[test]
    fn single_data_point() {
        let mut data = PointSet::default();
        data.push(1.0, 1.0, 42.0);
        let mut p = AidwParams::default();
        p.area = Some(1.0); // bbox of one point is empty
        let out = aidw_serial(&data, &[(5.0, 5.0)], &p);
        assert!((out[0] - 42.0).abs() < 1e-12);
    }

    #[test]
    fn rmse_basics() {
        assert_eq!(rmse(&[1.0, 2.0], &[1.0, 2.0]), 0.0);
        assert!((rmse(&[0.0, 0.0], &[3.0, 4.0]) - (12.5f64).sqrt()).abs() < 1e-12);
        assert_eq!(rmse(&[], &[]), 0.0);
    }

    #[test]
    fn explicit_area_changes_alpha_regime() {
        let data = workload::uniform_square(400, 10.0, 48);
        let queries = workload::uniform_square(30, 10.0, 49).xy();
        // huge declared area -> r_exp huge -> R ~ 0 -> alpha_1 everywhere;
        // tiny declared area -> r_exp tiny -> R huge -> alpha_5 everywhere
        let mut p_lo = AidwParams::default();
        p_lo.area = Some(1e9);
        let mut p_hi = AidwParams::default();
        p_hi.area = Some(1e-9);
        let lo = aidw_serial(&data, &queries, &p_lo);
        let hi = aidw_serial(&data, &queries, &p_hi);
        let diff: f64 = lo.iter().zip(&hi).map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-9);
    }
}
