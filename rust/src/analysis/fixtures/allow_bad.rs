// tidy:allow(print_hygiene) -- nothing on the next line triggers it
fn g() {}
// tidy:allow(bogus_rule) -- not a registered rule
fn h() {}
// tidy:allow(print_hygiene)
fn i() {
    eprintln!("x");
}
