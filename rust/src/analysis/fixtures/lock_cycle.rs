// tidy fail-fixture (never compiled): two functions acquire the same two
// annotated locks in opposite orders — the lock_order rule must report
// the cycle alpha -> beta -> alpha.
pub struct S {
    // lock-order: alpha
    a: Mutex<u32>,
    // lock-order: beta
    b: Mutex<u32>,
}
impl S {
    fn one(&self) {
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
        drop(h);
        drop(g);
    }
    fn two(&self) {
        let g = self.b.lock().unwrap();
        let h = self.a.lock().unwrap();
        drop(h);
        drop(g);
    }
}
