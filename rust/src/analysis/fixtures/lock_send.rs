// tidy fail-fixture (never compiled): a guard held across a bounded-
// channel send_while (backpressure can stall every peer of the lock),
// plus a lock field declared without a lock-order annotation.
pub struct S {
    // lock-order: gamma
    q: Mutex<Vec<u32>>,
    u: RwLock<u8>,
}
impl S {
    fn push(&self, tx: &FrameTx) {
        let g = self.q.lock().unwrap();
        let _ = tx.send_while(g.len() as u32, || true);
        drop(g);
    }
}
