// tidy fail-fixture (never compiled): three panic paths in service/
// scope — unwrap, expect, panic! — while the poisoned-lock idiom stays
// exempt.
fn f(x: Option<u32>) -> u32 {
    let v = x.unwrap();
    let w = x.expect("boom");
    if v > w {
        panic!("no");
    }
    v
}
fn ok(m: &std::sync::Mutex<u32>) -> u32 {
    *m.lock().unwrap()
}
