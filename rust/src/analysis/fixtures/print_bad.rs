// tidy fail-fixture (never compiled): three stderr prints outside
// main.rs/cli.rs; the middle one carries a justified allow directive and
// must be suppressed by the allowlist pass (raw rule counts all three).
fn f() {
    eprintln!("oops");
    // tidy:allow(print_hygiene) -- fixture demonstrates a justified allow
    eprint!("allowed");
    dbg!(42);
}
