//! Fixture for the `protocol_drift` rule: the version banner disagrees
//! with `PROTOCOL_VERSION`, the request example advertises a key no
//! decoder reads, and a decoder reads a key the example never shows.
//!
//! Wire protocol **v9.1** — one JSON request object per line:
//!
//! ```json
//! {"op": "query", "dataset": "dem", "k": 8,"ghost_key":1}
//! ```

pub const PROTOCOL_VERSION: &str = "9.0";

fn field<'a>(line: &'a str, key: &str) -> Option<&'a str> {
    let at = line.find(key)?;
    line[at + key.len()..].split('"').nth(2)
}

pub fn decode(line: &str) -> Option<String> {
    let op = field(line, "op")?;
    if op != "query" {
        return None;
    }
    let dataset = field(line, "dataset")?;
    Some(format!("{op} on {dataset}"))
}

pub fn decode_options(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    for key in ["k", "rogue_key"] {
        if let Some(v) = field(line, key) {
            out.push(v.to_string());
        }
    }
    out
}
