pub fn f(p: *mut u32) {
    unsafe { *p = 1 };
    // SAFETY: fixture pointer is valid for writes by construction
    unsafe { *p = 2 };
}
struct P(*mut u32);
unsafe impl Send for P {}
