// tidy fail-fixture (never compiled): a new ResolvedOptions field
// (`frobnicate`) added without classifying it into stage1_key(),
// stage2_key(), or NEITHER_STAGE_KEY — the stage_key rule must fire.
pub struct QueryOptions {
    pub k: Option<usize>,
    pub local: Option<usize>,
}
pub struct ResolvedOptions {
    pub k: usize,
    pub variant: usize,
    pub local_neighbors: Option<usize>,
    pub frobnicate: bool,
}
pub struct Stage1Key {
    pub k: usize,
    pub local_neighbors: Option<usize>,
}
pub struct Stage2Key {
    pub variant: usize,
}
pub const NEITHER_STAGE_KEY: &[&str] = &[];
pub const QUERY_FIELD_ALIASES: &[(&str, &str)] = &[("local", "local_neighbors")];
impl ResolvedOptions {
    pub fn stage1_key(&self) -> Stage1Key {
        Stage1Key { k: self.k, local_neighbors: self.local_neighbors }
    }
    pub fn stage2_key(&self) -> Stage2Key {
        Stage2Key { variant: self.variant }
    }
}
