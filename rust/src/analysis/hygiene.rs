//! Rules `panic_hygiene`, `print_hygiene`, `safety_comments`.
//!
//! * **panic_hygiene** — `service/`, `subscribe/` and
//!   `coordinator/batcher.rs` run inside connection handlers and worker
//!   threads: a panic there kills a thread the process never restarts
//!   (or poisons a lock every peer then trips over).  No `unwrap`,
//!   `expect`, `panic!`, `unreachable!`, `todo!`, `unimplemented!`
//!   outside tests.  Exemption: `.lock().unwrap()` (and `.read()`,
//!   `.write()`, condvar `.wait(..)`/`.wait_timeout(..)`) — a poisoned
//!   lock means another thread already panicked, and propagating is the
//!   std-documented idiom.
//! * **print_hygiene** — no `eprintln!`/`eprint!`/`dbg!` outside
//!   `main.rs`/`cli.rs`: the server reports state through the event
//!   journal (PR 7), not a stderr nobody tails.
//! * **safety_comments** — every `unsafe` keyword (block or
//!   `unsafe impl`) carries a `// SAFETY:` comment on the same line or
//!   in the comment block directly above, stating the invariant that
//!   makes it sound.

use super::lexer::tokens;
use super::{Finding, SourceFile};

fn panic_scope(path: &str) -> bool {
    path.starts_with("service/")
        || path.starts_with("subscribe/")
        || path == "coordinator/batcher.rs"
}

fn print_allowed(path: &str) -> bool {
    path == "main.rs" || path == "cli.rs"
}

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let mut out = Vec::new();
    for f in files {
        let toks = tokens(&f.lex.masked);
        let t = |i: usize| toks.get(i).map(|t| t.text.as_str()).unwrap_or("");
        let masked_lines: Vec<&str> = f.lex.masked.lines().collect();

        for i in 0..toks.len() {
            let line = toks[i].line;
            match t(i) {
                // ---- panic_hygiene ----
                w @ ("unwrap" | "expect")
                    if panic_scope(&f.path)
                        && !f.lex.is_test_line(line)
                        && i >= 1
                        && t(i - 1) == "."
                        && !lock_idiom(&toks, i) =>
                {
                    out.push(Finding::new(
                        "panic_hygiene",
                        &f.path,
                        line,
                        format!(
                            ".{w}() in a worker/decode path — return an Error (or \
                             justify with tidy:allow); a panic here kills a thread \
                             the process never restarts"
                        ),
                    ));
                }
                w @ ("panic" | "unreachable" | "todo" | "unimplemented")
                    if panic_scope(&f.path)
                        && !f.lex.is_test_line(line)
                        && t(i + 1) == "!" =>
                {
                    out.push(Finding::new(
                        "panic_hygiene",
                        &f.path,
                        line,
                        format!("{w}! in a worker/decode path — return an Error instead"),
                    ));
                }
                // ---- print_hygiene ----
                w @ ("eprintln" | "eprint" | "dbg")
                    if !print_allowed(&f.path)
                        && !f.lex.is_test_line(line)
                        && t(i + 1) == "!" =>
                {
                    out.push(Finding::new(
                        "print_hygiene",
                        &f.path,
                        line,
                        format!(
                            "{w}! outside main.rs/cli.rs — report through the event \
                             journal (obs), not stderr"
                        ),
                    ));
                }
                // ---- safety_comments ----
                "unsafe" => {
                    if !has_safety_comment(f, &masked_lines, line) {
                        out.push(Finding::new(
                            "safety_comments",
                            &f.path,
                            line,
                            "unsafe without a `// SAFETY:` comment — state the \
                             invariant that makes this sound"
                                .to_string(),
                        ));
                    }
                }
                _ => {}
            }
        }
    }
    out
}

/// `.unwrap()`/`.expect(..)` directly chained onto a lock/condvar
/// acquisition: `<recv>.lock().unwrap()`, `cond.wait(st).unwrap()`, …
/// Walks back over the acquisition's argument parens.
fn lock_idiom(toks: &[super::lexer::Tok], i: usize) -> bool {
    let t = |j: usize| toks.get(j).map(|t| t.text.as_str()).unwrap_or("");
    // toks[i] is unwrap/expect, toks[i-1] is `.`; before that must sit
    // `<acq> ( .. )` with balanced parens
    if i < 2 || t(i - 2) != ")" {
        return false;
    }
    let mut depth = 0usize;
    let mut j = i - 2;
    loop {
        match t(j) {
            ")" => depth += 1,
            "(" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            _ => {}
        }
        if j == 0 {
            return false;
        }
        j -= 1;
    }
    j >= 2
        && matches!(t(j - 1), "lock" | "read" | "write" | "wait" | "wait_timeout")
        && t(j - 2) == "."
}

/// A `SAFETY:` comment on the same line, or in the contiguous
/// comment-only block directly above it.
fn has_safety_comment(f: &SourceFile, masked_lines: &[&str], line: usize) -> bool {
    let is_safety = |l: usize| {
        f.lex
            .comments_on(l)
            .any(|c| c.text.trim_start().starts_with("SAFETY:"))
    };
    if is_safety(line) {
        return true;
    }
    let mut l = line.saturating_sub(1);
    while l >= 1 {
        let code_blank = masked_lines
            .get(l - 1)
            .map(|s| s.trim().is_empty())
            .unwrap_or(true);
        let has_comment = f.lex.comments_on(l).next().is_some();
        if !code_blank || !has_comment {
            return false;
        }
        if is_safety(l) {
            return true;
        }
        l -= 1;
    }
    false
}

#[cfg(test)]
mod tests {
    use super::super::SourceFile;
    use super::*;

    #[test]
    fn panic_rule_fires_on_fixture_and_exempts_lock_idiom() {
        let f = SourceFile::new("service/fixture.rs", include_str!("fixtures/panic_bad.rs"));
        let findings = check(&[f]);
        let panics: Vec<_> =
            findings.iter().filter(|f| f.rule == "panic_hygiene").collect();
        assert_eq!(panics.len(), 3, "findings: {findings:?}");
        assert!(panics.iter().any(|f| f.message.contains(".unwrap()")));
        assert!(panics.iter().any(|f| f.message.contains(".expect()")));
        assert!(panics.iter().any(|f| f.message.contains("panic!")));
    }

    #[test]
    fn panic_rule_ignores_out_of_scope_and_tests() {
        // same content, non-scoped path: silent
        let f = SourceFile::new("aidw/fixture.rs", include_str!("fixtures/panic_bad.rs"));
        assert!(check(&[f]).iter().all(|f| f.rule != "panic_hygiene"));
        // scoped path but inside #[cfg(test)]: silent
        let src = "#[cfg(test)]\nmod tests {\n    fn t(x: Option<u32>) { x.unwrap(); }\n}\n";
        let f = SourceFile::new("service/x.rs", src);
        assert!(check(&[f]).is_empty());
    }

    #[test]
    fn multiline_lock_chain_is_exempt() {
        let src = "\
fn f(m: &std::sync::RwLock<u32>) -> u32 {
    *m
        .read()
        .unwrap()
}
fn g(c: &std::sync::Condvar, m: &std::sync::Mutex<bool>) {
    let st = m.lock().unwrap();
    let _st = c.wait(st).unwrap();
}
";
        let f = SourceFile::new("service/x.rs", src);
        let findings = check(&[f]);
        assert!(findings.is_empty(), "findings: {findings:?}");
    }

    #[test]
    fn print_rule_fires_on_fixture() {
        let f = SourceFile::new("live/fixture.rs", include_str!("fixtures/print_bad.rs"));
        let findings = check(&[f]);
        let prints: Vec<_> = findings.iter().filter(|f| f.rule == "print_hygiene").collect();
        assert_eq!(prints.len(), 3, "findings: {findings:?}");
        // main.rs/cli.rs are exempt
        let f = SourceFile::new("main.rs", include_str!("fixtures/print_bad.rs"));
        assert!(check(&[f]).iter().all(|f| f.rule != "print_hygiene"));
    }

    #[test]
    fn safety_rule_fires_on_fixture() {
        let f = SourceFile::new(
            "primitives/fixture.rs",
            include_str!("fixtures/safety_bad.rs"),
        );
        let findings = check(&[f]);
        let safety: Vec<_> =
            findings.iter().filter(|f| f.rule == "safety_comments").collect();
        assert_eq!(safety.len(), 2, "findings: {findings:?}");
        // the commented site (line 4) is not among them
        assert!(safety.iter().all(|f| f.line != 4), "findings: {findings:?}");
    }

    #[test]
    fn safety_comment_above_multiline_block_counts() {
        let src = "\
pub fn f(p: *mut u32, n: usize) {
    // SAFETY: p is valid for n writes; indices below are < n by the
    // loop bound, so each write hits a distinct in-bounds slot
    unsafe {
        *p.add(n - 1) = 0;
    }
}
";
        let f = SourceFile::new("primitives/x.rs", src);
        assert!(check(&[f]).is_empty());
    }
}
