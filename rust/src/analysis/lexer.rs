//! A small masking lexer for `aidw tidy` (see the module docs in
//! [`crate::analysis`]).
//!
//! The rules in this suite are lexical: they match tokens, comments and
//! string literals, never a full AST.  To keep that sound, every rule
//! reads sources through [`lex`], which produces:
//!
//! * `masked` — the source with every comment, string literal and char
//!   literal replaced by spaces (newlines preserved, so byte offsets and
//!   line numbers stay aligned with the original).  Token scans over
//!   `masked` can never be fooled by the word `unwrap` inside a doc
//!   comment or an error message.
//! * `comments` — the comment *text* (what the mask erased), line-stamped,
//!   so annotation rules (`// lock-order:`, `// SAFETY:`,
//!   `// tidy:allow(..)`) and doc-header parsing still see it.
//! * `strings` — every string literal's value with its line and the byte
//!   offset of its opening quote, so rules that care about literals in a
//!   specific region (protocol keys inside `fn decode`, the
//!   `NEITHER_STAGE_KEY` table) can range-filter them.
//! * `test_lines` — per-line flags marking `#[cfg(test)] mod` regions,
//!   which most rules skip (tests may unwrap and print freely).
//!
//! The state machine understands line comments, nested block comments,
//! plain/byte/raw strings (any `#` count), char literals vs lifetimes,
//! and escape sequences.  It is deliberately *not* a full Rust lexer:
//! anything it does not recognize passes through unmasked, which fails
//! toward a rule firing (visible) rather than being silently skipped.

/// A comment's text (everything after `//`, or inside `/* */`), stamped
/// with the line its first character appears on.
#[derive(Debug, Clone)]
pub struct Comment {
    pub line: usize,
    pub text: String,
}

/// A string literal's contents (escapes left raw), with the line and byte
/// offset of its opening quote in the original source.
#[derive(Debug, Clone)]
pub struct StrLit {
    pub line: usize,
    pub offset: usize,
    pub value: String,
}

/// One token of the masked source: a maximal `[A-Za-z0-9_]+` word or a
/// single punctuation character.
#[derive(Debug, Clone)]
pub struct Tok {
    pub text: String,
    pub line: usize,
    pub offset: usize,
}

/// The lexer's full output for one file.  See the module docs.
#[derive(Debug, Clone)]
pub struct Lexed {
    pub masked: String,
    pub comments: Vec<Comment>,
    pub strings: Vec<StrLit>,
    /// `test_lines[line]` (1-indexed; index 0 unused) is true inside a
    /// `#[cfg(test)]`-gated region.
    pub test_lines: Vec<bool>,
}

impl Lexed {
    /// True when `line` lies inside a `#[cfg(test)]` region.
    pub fn is_test_line(&self, line: usize) -> bool {
        self.test_lines.get(line).copied().unwrap_or(false)
    }

    /// All comments attached to `line`.
    pub fn comments_on(&self, line: usize) -> impl Iterator<Item = &Comment> {
        self.comments.iter().filter(move |c| c.line == line)
    }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Lex `src` into masked code + comments + strings + test-line flags.
pub fn lex(src: &str) -> Lexed {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut masked = String::with_capacity(src.len());
    let mut comments = Vec::new();
    let mut strings = Vec::new();
    let mut line = 1usize;
    let mut i = 0usize;

    // Byte offset of the char at index `i` in `masked` equals masked.len()
    // because masking writes exactly one byte (space/newline/ASCII char)
    // per source char for everything we erase, and copies code chars
    // verbatim.  Rust code outside strings/comments is ASCII in this
    // repository; a stray non-ASCII code char would shift offsets by the
    // UTF-8 width difference, which only loosens range filters.
    macro_rules! mask_char {
        ($c:expr) => {
            if $c == '\n' {
                masked.push('\n');
            } else {
                masked.push(' ');
            }
        };
    }

    while i < n {
        let c = chars[i];
        let next = if i + 1 < n { chars[i + 1] } else { '\0' };
        if c == '\n' {
            masked.push('\n');
            line += 1;
            i += 1;
            continue;
        }
        if c == '/' && next == '/' {
            // line comment: capture text after the `//`, mask it all
            let start_line = line;
            let mut text = String::new();
            masked.push(' ');
            masked.push(' ');
            i += 2;
            while i < n && chars[i] != '\n' {
                text.push(chars[i]);
                masked.push(' ');
                i += 1;
            }
            comments.push(Comment { line: start_line, text });
            continue;
        }
        if c == '/' && next == '*' {
            // block comment, nested
            let start_line = line;
            let mut text = String::new();
            let mut depth = 1usize;
            masked.push(' ');
            masked.push(' ');
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && i + 1 < n && chars[i + 1] == '*' {
                    depth += 1;
                    text.push_str("/*");
                    masked.push(' ');
                    masked.push(' ');
                    i += 2;
                } else if chars[i] == '*' && i + 1 < n && chars[i + 1] == '/' {
                    depth -= 1;
                    if depth > 0 {
                        text.push_str("*/");
                    }
                    masked.push(' ');
                    masked.push(' ');
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    text.push(chars[i]);
                    mask_char!(chars[i]);
                    i += 1;
                }
            }
            comments.push(Comment { line: start_line, text });
            continue;
        }
        // raw strings: r"..." / r#"..."# / br"..." — only when `r`/`b`
        // starts a token (the previous char is not an ident char)
        let prev_ident = i > 0 && is_ident_char(chars[i - 1]);
        if !prev_ident && (c == 'r' || (c == 'b' && next == 'r')) {
            let mut j = i + if c == 'b' { 2 } else { 1 };
            let mut hashes = 0usize;
            while j < n && chars[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if j < n && chars[j] == '"' {
                // it is a raw string: copy the prefix, mask the contents
                for k in i..=j {
                    masked.push(chars[k]);
                }
                let start_line = line;
                let offset = masked.len() - 1; // the opening quote
                let mut value = String::new();
                i = j + 1;
                loop {
                    if i >= n {
                        break;
                    }
                    if chars[i] == '"' {
                        let mut h = 0usize;
                        while h < hashes && i + 1 + h < n && chars[i + 1 + h] == '#' {
                            h += 1;
                        }
                        if h == hashes {
                            masked.push('"');
                            for _ in 0..hashes {
                                masked.push('#');
                            }
                            i += 1 + hashes;
                            break;
                        }
                    }
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    value.push(chars[i]);
                    mask_char!(chars[i]);
                    i += 1;
                }
                strings.push(StrLit { line: start_line, offset, value });
                continue;
            }
            // not a raw string: fall through and copy the char below
        }
        if c == '"' || (!prev_ident && c == 'b' && next == '"') {
            // plain or byte string
            if c == 'b' {
                masked.push('b');
                i += 1;
            }
            masked.push('"');
            let start_line = line;
            let offset = masked.len() - 1;
            let mut value = String::new();
            i += 1;
            while i < n {
                if chars[i] == '\\' && i + 1 < n {
                    value.push(chars[i]);
                    value.push(chars[i + 1]);
                    masked.push(' ');
                    if chars[i + 1] == '\n' {
                        masked.push('\n');
                        line += 1;
                    } else {
                        masked.push(' ');
                    }
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    masked.push('"');
                    i += 1;
                    break;
                }
                if chars[i] == '\n' {
                    line += 1;
                }
                value.push(chars[i]);
                mask_char!(chars[i]);
                i += 1;
            }
            strings.push(StrLit { line: start_line, offset, value });
            continue;
        }
        if c == '\'' || (!prev_ident && c == 'b' && next == '\'') {
            // char literal vs lifetime: `'x'` / `'\..'` are literals,
            // `'ident` (no closing quote right after) is a lifetime
            let q = if c == 'b' { i + 1 } else { i };
            let is_char_lit = q + 1 < n
                && (chars[q + 1] == '\\' || (q + 2 < n && chars[q + 2] == '\''));
            if is_char_lit {
                if c == 'b' {
                    masked.push('b');
                    i += 1;
                }
                masked.push('\'');
                i += 1;
                while i < n {
                    if chars[i] == '\\' && i + 1 < n {
                        masked.push(' ');
                        masked.push(' ');
                        i += 2;
                        continue;
                    }
                    if chars[i] == '\'' {
                        masked.push('\'');
                        i += 1;
                        break;
                    }
                    if chars[i] == '\n' {
                        line += 1;
                    }
                    mask_char!(chars[i]);
                    i += 1;
                }
                continue;
            }
            // lifetime: copy the quote and fall through
            masked.push('\'');
            i += 1;
            continue;
        }
        masked.push(c);
        i += 1;
    }

    let test_lines = mark_test_lines(&masked);
    Lexed { masked, comments, strings, test_lines }
}

/// Mark lines covered by a `#[cfg(test)]`-gated item: from the attribute
/// line through the matching close brace of the next block that opens.
fn mark_test_lines(masked: &str) -> Vec<bool> {
    let n_lines = masked.lines().count();
    let mut flags = vec![false; n_lines + 2];
    let mut depth = 0usize;
    // pending: saw the attribute, waiting for the `{` that opens the
    // gated item; active: Some(depth at which the region closes)
    let mut pending = false;
    let mut active: Option<usize> = None;

    let lines: Vec<&str> = masked.lines().collect();
    for (li, ltext) in lines.iter().enumerate() {
        let line = li + 1;
        if ltext.contains("#[cfg(test)]") {
            pending = true;
        }
        if pending || active.is_some() {
            flags[line] = true;
        }
        for ch in ltext.chars() {
            if ch == '{' {
                depth += 1;
                if pending {
                    pending = false;
                    active = Some(depth);
                    flags[line] = true;
                }
            } else if ch == '}' {
                if let Some(d) = active {
                    if depth == d {
                        active = None;
                        flags[line] = true;
                    }
                }
                depth = depth.saturating_sub(1);
            }
        }
    }
    flags
}

/// Tokenize a masked source: ident/number words and single-char puncts,
/// whitespace skipped, each stamped with line and byte offset.
pub fn tokens(masked: &str) -> Vec<Tok> {
    let mut out = Vec::new();
    let mut line = 1usize;
    let bytes = masked.as_bytes();
    let mut i = 0usize;
    while i < bytes.len() {
        let c = bytes[i] as char;
        if c == '\n' {
            line += 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            i += 1;
            continue;
        }
        if is_ident_char(c) {
            let start = i;
            while i < bytes.len() && is_ident_char(bytes[i] as char) {
                i += 1;
            }
            out.push(Tok {
                text: masked[start..i].to_string(),
                line,
                offset: start,
            });
            continue;
        }
        out.push(Tok { text: c.to_string(), line, offset: i });
        i += 1;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn masks_comments_and_strings() {
        let src = "let x = \"unwrap()\"; // unwrap() here\nlet y = 1;\n";
        let lx = lex(src);
        assert!(!lx.masked.contains("unwrap"));
        assert_eq!(lx.comments.len(), 1);
        assert_eq!(lx.comments[0].line, 1);
        assert!(lx.comments[0].text.contains("unwrap() here"));
        assert_eq!(lx.strings.len(), 1);
        assert_eq!(lx.strings[0].value, "unwrap()");
        // newlines preserved: same line structure
        assert_eq!(lx.masked.lines().count(), src.lines().count());
    }

    #[test]
    fn raw_strings_and_escapes() {
        let src = "let a = r#\"has \"quotes\" and unwrap()\"#; let b = \"esc \\\" quote\";";
        let lx = lex(src);
        assert!(!lx.masked.contains("unwrap"));
        assert_eq!(lx.strings.len(), 2);
        assert!(lx.strings[0].value.contains("\"quotes\""));
        assert!(lx.strings[1].value.contains("\\\""));
    }

    #[test]
    fn char_literals_vs_lifetimes() {
        let src = "fn f<'a>(x: &'a str) { let c = 'x'; let d = '\\n'; }";
        let lx = lex(src);
        // lifetimes survive masking, char-literal contents do not
        assert!(lx.masked.contains("'a str"));
        assert!(!lx.masked.contains("'x'"));
    }

    #[test]
    fn nested_block_comments() {
        let src = "/* outer /* inner */ still comment */ fn real() {}";
        let lx = lex(src);
        assert!(lx.masked.contains("fn real"));
        assert!(!lx.masked.contains("outer"));
        assert_eq!(lx.comments.len(), 1);
        assert!(lx.comments[0].text.contains("inner"));
    }

    #[test]
    fn test_region_marking() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let lx = lex(src);
        assert!(!lx.is_test_line(1));
        assert!(lx.is_test_line(2));
        assert!(lx.is_test_line(3));
        assert!(lx.is_test_line(4));
        assert!(lx.is_test_line(5));
        assert!(!lx.is_test_line(6));
    }

    #[test]
    fn token_lines_and_offsets() {
        let src = "ab.cd()\nef";
        let toks = tokens(&lex(src).masked);
        let texts: Vec<&str> = toks.iter().map(|t| t.text.as_str()).collect();
        assert_eq!(texts, vec!["ab", ".", "cd", "(", ")", "ef"]);
        assert_eq!(toks[0].line, 1);
        assert_eq!(toks[5].line, 2);
        assert_eq!(toks[0].offset, 0);
    }
}
