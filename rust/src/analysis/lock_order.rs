//! Rule `lock_order`: lock discipline across `live/`, `subscribe/`,
//! `coordinator/` and `shard/`.
//!
//! Every `Mutex`/`RwLock` *field declaration* in scope must carry a
//! `// lock-order: <name>` annotation (same line or the line above) that
//! names the lock.  The rule then scans the lexical nesting of
//! `.lock()` / `.read()` / `.write()` acquisitions:
//!
//! * a `let <ident> = <recv>.lock().unwrap();` binding is a *held* guard
//!   from its binding until its scope's closing brace or an explicit
//!   `drop(<ident>)`; chained acquisitions
//!   (`x.lock().unwrap().take()`) are transient temporaries;
//! * acquiring lock B while holding guard A records the edge A → B; the
//!   union of observed edges over all scope files must be acyclic (and a
//!   lock is never re-acquired while already held — `std::sync` locks
//!   are not reentrant);
//! * no guard may be lexically held across a *blocking* channel op:
//!   `send_while(` (the bounded-stream backpressure helper), `.recv()`,
//!   `.recv_timeout(`.  Plain `.send(` is exempt — the subsystems use it
//!   only on unbounded `mpsc::Sender`s, which cannot block.
//!
//! The approximation is intra-procedural and lexical: closures inherit
//! the guards of their enclosing scope (conservative — a spawned closure
//! runs elsewhere, but lexical acquisitions inside one are rare and the
//! conservative edge is the safe direction), and cross-function holds
//! are invisible (each function contributes its own edges; the global
//! graph still catches two functions that nest in opposite orders).

use std::collections::{BTreeMap, BTreeSet};

use super::lexer::{tokens, Tok};
use super::{Finding, SourceFile};

const RULE: &str = "lock_order";

fn in_scope(path: &str) -> bool {
    path.starts_with("live/")
        || path.starts_with("subscribe/")
        || path.starts_with("coordinator/")
        || path.starts_with("shard/")
}

const ACQUIRE: &[&str] = &["lock", "read", "write"];

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let scope: Vec<&SourceFile> = files.iter().filter(|f| in_scope(&f.path)).collect();
    if scope.is_empty() {
        return Vec::new();
    }
    let mut out = Vec::new();

    // pass 1: annotations + declaration coverage
    // (file, field) -> lock name; field -> set of names (global fallback)
    let mut by_field_file: BTreeMap<(String, String), String> = BTreeMap::new();
    let mut by_field: BTreeMap<String, BTreeSet<String>> = BTreeMap::new();
    for f in &scope {
        let lines: Vec<&str> = f.lex.masked.lines().collect();
        for c in &f.lex.comments {
            let Some(pos) = c.text.find("lock-order:") else { continue };
            let name: String = c.text[pos + "lock-order:".len()..]
                .trim()
                .chars()
                .take_while(|ch| ch.is_ascii_alphanumeric() || *ch == '_')
                .collect();
            if name.is_empty() {
                out.push(Finding::new(
                    RULE,
                    &f.path,
                    c.line,
                    "empty lock-order annotation — `// lock-order: <name>`".to_string(),
                ));
                continue;
            }
            // the annotated declaration: this line or the next few
            // (skipping further comment-only lines)
            let mut decl = None;
            for l in c.line..=(c.line + 3).min(lines.len()) {
                if let Some(field) = decl_field(lines[l - 1]) {
                    decl = Some((field, l));
                    break;
                }
            }
            match decl {
                Some((field, _)) => {
                    by_field_file.insert((f.path.clone(), field.clone()), name.clone());
                    by_field.entry(field).or_default().insert(name);
                }
                None => out.push(Finding::new(
                    RULE,
                    &f.path,
                    c.line,
                    format!("lock-order annotation '{name}' has no Mutex/RwLock field declaration"),
                )),
            }
        }
        for (li, line) in lines.iter().enumerate() {
            let lineno = li + 1;
            if f.lex.is_test_line(lineno) {
                continue;
            }
            if let Some(field) = decl_field(line) {
                if !by_field_file.contains_key(&(f.path.clone(), field.clone())) {
                    out.push(Finding::new(
                        RULE,
                        &f.path,
                        lineno,
                        format!(
                            "lock field '{field}' lacks a `// lock-order: <name>` annotation"
                        ),
                    ));
                }
            }
        }
    }

    // pass 2: acquisition nesting + blocking ops under a guard
    let mut edges: BTreeMap<(String, String), (String, usize)> = BTreeMap::new();
    for f in &scope {
        scan_file(f, &by_field_file, &by_field, &mut edges, &mut out);
    }

    // cycle detection over the observed edge set
    for cycle in find_cycles(&edges) {
        let (file, line) = edges
            .get(&(cycle[0].clone(), cycle[1].clone()))
            .cloned()
            .unwrap_or_else(|| (scope[0].path.clone(), 1));
        out.push(Finding::new(
            RULE,
            &file,
            line,
            format!(
                "lock acquisition cycle: {} — pick one order and stick to it",
                cycle.join(" -> ")
            ),
        ));
    }

    out
}

/// `<field>: Mutex<..>` / `<field>: RwLock<..>` (optionally `std::sync::`
/// qualified) on a struct-field line.  `&Mutex<..>` parameter types do
/// not match.
fn decl_field(line: &str) -> Option<String> {
    for pat in [": Mutex<", ": RwLock<", ": std::sync::Mutex<", ": std::sync::RwLock<"] {
        if let Some(pos) = line.find(pat) {
            let head = &line[..pos];
            let field: String = head
                .chars()
                .rev()
                .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
                .collect::<Vec<_>>()
                .into_iter()
                .rev()
                .collect();
            if !field.is_empty() {
                return Some(field);
            }
        }
    }
    None
}

struct Guard {
    ident: String,
    lock: String,
    depth: usize,
    line: usize,
}

fn resolve(
    file: &str,
    field: &str,
    by_field_file: &BTreeMap<(String, String), String>,
    by_field: &BTreeMap<String, BTreeSet<String>>,
) -> Option<String> {
    if let Some(n) = by_field_file.get(&(file.to_string(), field.to_string())) {
        return Some(n.clone());
    }
    match by_field.get(field) {
        Some(names) if names.len() == 1 => names.iter().next().cloned(),
        _ => None,
    }
}

fn scan_file(
    f: &SourceFile,
    by_field_file: &BTreeMap<(String, String), String>,
    by_field: &BTreeMap<String, BTreeSet<String>>,
    edges: &mut BTreeMap<(String, String), (String, usize)>,
    out: &mut Vec<Finding>,
) {
    let toks = tokens(&f.lex.masked);
    let mut depth = 0usize;
    let mut live: Vec<Guard> = Vec::new();
    let t = |i: usize| toks.get(i).map(|t| t.text.as_str()).unwrap_or("");

    for i in 0..toks.len() {
        let in_test = f.lex.is_test_line(toks[i].line);
        match t(i) {
            "{" => depth += 1,
            "}" => {
                depth = depth.saturating_sub(1);
                live.retain(|g| g.depth <= depth);
            }
            "drop" if t(i + 1) == "(" && t(i + 3) == ")" => {
                let ident = t(i + 2).to_string();
                live.retain(|g| g.ident != ident);
            }
            w if ACQUIRE.contains(&w) && t(i + 1) == "(" && t(i + 2) == ")" && i >= 2 => {
                if in_test || t(i - 1) != "." {
                    continue;
                }
                let recv = t(i - 2);
                let Some(lock) = resolve(&f.path, recv, by_field_file, by_field) else {
                    continue;
                };
                let line = toks[i].line;
                for g in &live {
                    if g.lock == lock {
                        out.push(Finding::new(
                            RULE,
                            &f.path,
                            line,
                            format!(
                                "lock '{lock}' re-acquired while already held (bound at \
                                 line {}) — std::sync locks are not reentrant",
                                g.line
                            ),
                        ));
                    } else {
                        edges
                            .entry((g.lock.clone(), lock.clone()))
                            .or_insert((f.path.clone(), line));
                    }
                }
                // held guard: `let [mut] <ident> = <chain>.lock().unwrap();`
                if let Some(ident) = guard_binding(&toks, i) {
                    live.push(Guard { ident, lock, depth, line });
                }
            }
            w @ ("send_while" | "recv" | "recv_timeout") if i >= 1 && t(i - 1) == "." => {
                if in_test {
                    continue;
                }
                if w == "recv" && !(t(i + 1) == "(" && t(i + 2) == ")") {
                    continue;
                }
                if let Some(g) = live.first() {
                    out.push(Finding::new(
                        RULE,
                        &f.path,
                        toks[i].line,
                        format!(
                            "blocking channel op `.{w}(..)` while holding lock '{}' \
                             (bound at line {}) — release the guard first, or the \
                             channel's backpressure stalls every peer of the lock",
                            g.lock, g.line
                        ),
                    ));
                }
            }
            _ => {}
        }
    }
}

/// For the acquisition token at `i` (`lock`/`read`/`write`), detect the
/// held-guard shape: statement `let [mut] IDENT = <recv chain>.lock()
/// .unwrap();` — the chain is `ident (. ident)*` back from the receiver,
/// and nothing but `.unwrap()` follows before the `;`.
fn guard_binding(toks: &[Tok], i: usize) -> Option<String> {
    let t = |j: usize| toks.get(j).map(|t| t.text.as_str()).unwrap_or("");
    // forward: `. unwrap ( ) ;`
    if !(t(i + 3) == "." && t(i + 4) == "unwrap" && t(i + 5) == "(" && t(i + 6) == ")" && t(i + 7) == ";")
    {
        return None;
    }
    // backward over the receiver chain: i-1 is `.`, i-2 the receiver
    let mut j = i - 2; // first chain ident
    while j >= 2 && t(j - 1) == "." {
        j -= 2; // previous chain ident
    }
    if j < 3 || t(j - 1) != "=" {
        return None;
    }
    let mut k = j - 2; // binding ident
    let ident = t(k).to_string();
    if ident.is_empty() || !ident.chars().next().map(|c| c.is_ascii_alphabetic() || c == '_').unwrap_or(false) {
        return None;
    }
    if t(k - 1) == "mut" {
        k -= 1;
    }
    if k >= 1 && t(k - 1) == "let" {
        Some(ident)
    } else {
        None
    }
}

/// Every elementary cycle's node path (each reported once, smallest node
/// first), via DFS from each node.
fn find_cycles(edges: &BTreeMap<(String, String), (String, usize)>) -> Vec<Vec<String>> {
    let mut adj: BTreeMap<&str, Vec<&str>> = BTreeMap::new();
    for (a, b) in edges.keys() {
        adj.entry(a.as_str()).or_default().push(b.as_str());
    }
    let mut cycles: BTreeSet<Vec<String>> = BTreeSet::new();
    let nodes: Vec<&str> = adj.keys().copied().collect();
    for &start in &nodes {
        let mut stack: Vec<&str> = vec![start];
        dfs(start, start, &adj, &mut stack, &mut cycles);
    }
    cycles.into_iter().collect()
}

fn dfs<'a>(
    start: &'a str,
    node: &'a str,
    adj: &BTreeMap<&'a str, Vec<&'a str>>,
    stack: &mut Vec<&'a str>,
    cycles: &mut BTreeSet<Vec<String>>,
) {
    for &next in adj.get(node).into_iter().flatten() {
        if next == start {
            // canonical form: rotate so the smallest node leads
            let min = stack.iter().enumerate().min_by_key(|(_, s)| **s).map(|(i, _)| i).unwrap_or(0);
            let mut path: Vec<String> =
                stack[min..].iter().chain(stack[..min].iter()).map(|s| s.to_string()).collect();
            path.push(path[0].clone());
            cycles.insert(path);
        } else if !stack.contains(&next) {
            stack.push(next);
            dfs(start, next, adj, stack, cycles);
            stack.pop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::SourceFile;
    use super::*;

    #[test]
    fn fires_on_cycle_fixture() {
        let f = SourceFile::new("live/fixture.rs", include_str!("fixtures/lock_cycle.rs"));
        let findings = check(&[f]);
        assert_eq!(findings.len(), 1, "findings: {findings:?}");
        assert!(findings[0].message.contains("cycle"), "{}", findings[0].message);
        assert!(findings[0].message.contains("alpha -> beta -> alpha"), "{}", findings[0].message);
    }

    #[test]
    fn fires_on_send_under_lock_and_missing_annotation() {
        let f = SourceFile::new("subscribe/fixture.rs", include_str!("fixtures/lock_send.rs"));
        let findings = check(&[f]);
        let msgs: Vec<&str> = findings.iter().map(|f| f.message.as_str()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("send_while") && m.contains("gamma")),
            "{msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("lacks a `// lock-order:")),
            "{msgs:?}"
        );
        assert_eq!(findings.len(), 2, "{msgs:?}");
    }

    #[test]
    fn consistent_order_and_transient_chains_are_clean() {
        let src = "\
pub struct S {
    // lock-order: alpha
    a: Mutex<u32>,
    // lock-order: beta
    b: Mutex<u32>,
}
impl S {
    fn consistent(&self) {
        let g = self.a.lock().unwrap();
        let h = self.b.lock().unwrap();
        drop(h);
        drop(g);
    }
    fn also_a_then_b(&self) {
        let g = self.a.lock().unwrap();
        {
            let h = self.b.lock().unwrap();
            let _ = *h;
        }
        drop(g);
    }
    fn transient(&self) -> u32 {
        // chained temporary: not a held guard, orders freely
        *self.b.lock().unwrap()
    }
    fn plain_send_ok(&self, tx: &std::sync::mpsc::Sender<u32>) {
        let g = self.a.lock().unwrap();
        let _ = tx.send(*g);
    }
}
";
        let f = SourceFile::new("live/ok.rs", src);
        let findings = check(&[f]);
        assert!(findings.is_empty(), "findings: {findings:?}");
    }

    #[test]
    fn guard_released_by_drop_does_not_edge() {
        let src = "\
pub struct S {
    // lock-order: alpha
    a: Mutex<u32>,
    // lock-order: beta
    b: Mutex<u32>,
}
impl S {
    fn one(&self) {
        let g = self.a.lock().unwrap();
        drop(g);
        let h = self.b.lock().unwrap();
        drop(h);
    }
    fn two(&self) {
        let g = self.b.lock().unwrap();
        drop(g);
        let h = self.a.lock().unwrap();
        drop(h);
    }
}
";
        let f = SourceFile::new("live/ok2.rs", src);
        let findings = check(&[f]);
        assert!(findings.is_empty(), "findings: {findings:?}");
    }

    #[test]
    fn reacquisition_of_same_lock_fires() {
        let src = "\
pub struct S {
    // lock-order: alpha
    a: Mutex<u32>,
}
impl S {
    fn oops(&self) {
        let g = self.a.lock().unwrap();
        let h = self.a.lock().unwrap();
        let _ = (g, h);
    }
}
";
        let f = SourceFile::new("live/ok3.rs", src);
        let findings = check(&[f]);
        assert_eq!(findings.len(), 1, "findings: {findings:?}");
        assert!(findings[0].message.contains("re-acquired"));
    }

    #[test]
    fn out_of_scope_files_are_ignored() {
        let f = SourceFile::new("aidw/fixture.rs", include_str!("fixtures/lock_cycle.rs"));
        assert!(check(&[f]).is_empty());
    }
}
