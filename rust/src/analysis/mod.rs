//! `aidw tidy` — a zero-dependency, rustc-`tidy`-style static analysis
//! suite that enforces this repository's cross-cutting invariants.  Run
//! it with `aidw tidy [--json] [--root PATH]`; ci.sh runs it as a fatal
//! tier-1 gate.  The checks are *lexical* (see [`lexer`]): they scan
//! masked tokens, comments and string literals — no AST, no external
//! crates — which keeps them fast, dependency-free, and robust to code
//! that does not compile yet.
//!
//! # Rules
//!
//! | rule | what it enforces |
//! |------|------------------|
//! | `stage_key` | Every `ResolvedOptions` field in `coordinator/options.rs` is classified into exactly one of `stage1_key()`, `stage2_key()`, or the declared `NEITHER_STAGE_KEY` table; `QueryOptions` fields map onto resolved fields (via `QUERY_FIELD_ALIASES`); the `Stage1Key`/`Stage2Key` structs stay in sync with their projection functions.  A new knob cannot silently skew batch admission or cache identity. |
//! | `lock_order` | In `live/`, `subscribe/`, `coordinator/` and `shard/`: every `Mutex`/`RwLock` field declaration carries a `// lock-order: <name>` annotation; the observed lexical nesting of `.lock()`/`.read()`/`.write()` acquisitions forms an acyclic graph over those names; no guard is held across a blocking channel op (`send_while`, `.recv()`, `.recv_timeout(`) — plain `.send(` on an unbounded channel is deliberately exempt. |
//! | `protocol_drift` | `service/protocol.rs`: the doc-header `Wire protocol **vX.Y**` matches `PROTOCOL_VERSION`; every request key read in `fn decode`/`fn decode_options` appears in the header's request-example block, and vice versa (keys, `op` values and `action` values). |
//! | `panic_hygiene` | No `unwrap`/`expect`/`panic!`/`unreachable!`/`todo!`/`unimplemented!` in `service/`, `subscribe/` or `coordinator/batcher.rs` outside tests.  The poisoned-lock idiom (`.lock().unwrap()`, `.read()`, `.write()`, condvar `.wait(..)`/`.wait_timeout(..)`) is exempt: lock poisoning is already a crashed thread. |
//! | `print_hygiene` | No `eprintln!`/`eprint!`/`dbg!` outside `main.rs`/`cli.rs` — the event journal (PR 7) is where the server reports state. |
//! | `safety_comments` | Every `unsafe` keyword (blocks and `unsafe impl`) carries a `// SAFETY:` comment on the same line or the comment block immediately above. |
//!
//! Two audit rules fire on the allowlist itself: `allow_syntax`
//! (malformed or unknown-rule directives) and `allow_unused` (a
//! directive that suppressed nothing — stale allows rot).
//!
//! # Allowlist etiquette
//!
//! A finding is suppressed by a justification-carrying directive on the
//! same line or the line directly above it:
//!
//! ```text
//! // tidy:allow(print_hygiene) -- standalone datasets have no journal;
//! eprintln!("...");
//! ```
//!
//! The rule name must be real, the ` -- reason` is mandatory, and an
//! allow that stops matching anything becomes an `allow_unused` finding
//! — delete it.  Directives are only read from plain `//` comments (doc
//! comments like this one may show the syntax without enacting it).
//! Prefer fixing the code; allow only what is genuinely intentional,
//! and say *why*, not *what*.
//!
//! # Adding a rule
//!
//! 1. Write `fn check(files: &[SourceFile]) -> Vec<Finding>` in a new
//!    submodule, reading only `SourceFile::lex` (masked text, tokens,
//!    comments, strings).  Scope it by path prefix; skip
//!    `lex.is_test_line(..)` lines unless tests are genuinely in scope.
//! 2. Register its name in [`RULES`] and call it from [`run_rules`].
//! 3. Ship a fail-fixture under `analysis/fixtures/` (excluded from the
//!    tree walk, pulled in with `include_str!`) and a test asserting the
//!    rule fires on it — and stays silent on the live tree (the
//!    `live_tree_is_clean` test covers every registered rule).
//! 4. Document it in the table above.

use std::collections::BTreeMap;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

use crate::jsonio::Json;

pub mod hygiene;
pub mod lexer;
pub mod lock_order;
pub mod protocol_drift;
pub mod stage_key;

/// Every registered rule name.  `tidy:allow(..)` directives must name one
/// of these (the two allow-audit rules are implicit and not allowable).
pub const RULES: &[&str] = &[
    "stage_key",
    "lock_order",
    "protocol_drift",
    "panic_hygiene",
    "print_hygiene",
    "safety_comments",
];

/// One source file, path-relative to `rust/src` (forward slashes), with
/// its lexer output.
pub struct SourceFile {
    pub path: String,
    pub lex: lexer::Lexed,
}

impl SourceFile {
    pub fn new(path: &str, text: &str) -> SourceFile {
        SourceFile { path: path.to_string(), lex: lexer::lex(text) }
    }
}

/// One rule violation.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Finding {
    pub rule: &'static str,
    pub file: String,
    pub line: usize,
    pub message: String,
}

impl Finding {
    pub fn new(rule: &'static str, file: &str, line: usize, message: String) -> Finding {
        Finding { rule, file: file.to_string(), line, message }
    }
}

/// The result of a full tidy run: file count + post-allowlist findings.
pub struct TidyReport {
    pub files_scanned: usize,
    pub findings: Vec<Finding>,
}

impl TidyReport {
    pub fn clean(&self) -> bool {
        self.findings.is_empty()
    }

    /// Machine-readable form, built on the repo's own [`Json`] (BTreeMap
    /// object keys make the serialization deterministic).
    pub fn to_json(&self) -> Json {
        let findings: Vec<Json> = self
            .findings
            .iter()
            .map(|f| {
                Json::obj(vec![
                    ("rule", Json::Str(f.rule.to_string())),
                    ("file", Json::Str(f.file.clone())),
                    ("line", Json::Num(f.line as f64)),
                    ("message", Json::Str(f.message.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("tidy", Json::obj(vec![
                ("files", Json::Num(self.files_scanned as f64)),
                ("findings", Json::Arr(findings)),
            ])),
            ("clean", Json::Bool(self.clean())),
        ])
    }

    /// Human-readable form, one `file:line: [rule] message` per finding.
    pub fn render_text(&self) -> String {
        let mut out = String::new();
        for f in &self.findings {
            out.push_str(&format!("{}:{}: [{}] {}\n", f.file, f.line, f.rule, f.message));
        }
        out.push_str(&format!(
            "tidy: {} file(s) scanned, {} finding(s)\n",
            self.files_scanned,
            self.findings.len()
        ));
        out
    }
}

/// Run every registered rule over `files` (no allowlist applied).
pub fn run_rules(files: &[SourceFile]) -> Vec<Finding> {
    let mut findings = Vec::new();
    findings.extend(stage_key::check(files));
    findings.extend(lock_order::check(files));
    findings.extend(protocol_drift::check(files));
    findings.extend(hygiene::check(files));
    findings
}

struct Allow {
    file: String,
    line: usize,
    rule: String,
    used: bool,
}

/// Collect `tidy:allow` directives, flagging malformed ones.
fn collect_allows(files: &[SourceFile]) -> (Vec<Allow>, Vec<Finding>) {
    let mut allows = Vec::new();
    let mut bad = Vec::new();
    for f in files {
        for c in &f.lex.comments {
            // directives live in plain `//` comments only: doc comments
            // (`///`, `//!`) may *show* the syntax without enacting it
            if c.text.starts_with('/') || c.text.starts_with('!') {
                continue;
            }
            let Some(pos) = c.text.find("tidy:allow") else { continue };
            let rest = &c.text[pos + "tidy:allow".len()..];
            let parsed = (|| {
                let rest = rest.strip_prefix('(')?;
                let close = rest.find(')')?;
                let rule = rest[..close].trim().to_string();
                let after = rest[close + 1..].trim_start();
                let reason = after.strip_prefix("--")?.trim();
                if reason.is_empty() {
                    return None;
                }
                Some(rule)
            })();
            match parsed {
                Some(rule) if RULES.contains(&rule.as_str()) => {
                    allows.push(Allow { file: f.path.clone(), line: c.line, rule, used: false });
                }
                Some(rule) => bad.push(Finding::new(
                    "allow_syntax",
                    &f.path,
                    c.line,
                    format!("tidy:allow names unknown rule '{rule}'"),
                )),
                None => bad.push(Finding::new(
                    "allow_syntax",
                    &f.path,
                    c.line,
                    "malformed tidy:allow — expected `tidy:allow(<rule>) -- <reason>`".to_string(),
                )),
            }
        }
    }
    (allows, bad)
}

/// Apply the allowlist: drop suppressed findings, add allow-audit
/// findings, sort deterministically.
pub fn apply_allows(files: &[SourceFile], raw: Vec<Finding>) -> Vec<Finding> {
    let (mut allows, mut out) = collect_allows(files);
    for f in raw {
        let hit = allows.iter_mut().find(|a| {
            a.rule == f.rule && a.file == f.file && (a.line == f.line || a.line + 1 == f.line)
        });
        match hit {
            Some(a) => a.used = true,
            None => out.push(f),
        }
    }
    for a in &allows {
        if !a.used {
            out.push(Finding::new(
                "allow_unused",
                &a.file,
                a.line,
                format!("tidy:allow({}) suppresses nothing — delete it", a.rule),
            ));
        }
    }
    out.sort_by(|x, y| (&x.file, x.line, x.rule).cmp(&(&y.file, y.line, y.rule)));
    out
}

/// Load every `.rs` file under `src_dir` (recursively, sorted), skipping
/// `analysis/fixtures/` — the fixtures are deliberate rule violations.
pub fn scan_tree(src_dir: &Path) -> io::Result<Vec<SourceFile>> {
    let mut paths = Vec::new();
    walk(src_dir, src_dir, &mut paths)?;
    paths.sort();
    let mut files = Vec::with_capacity(paths.len());
    for rel in paths {
        let text = fs::read_to_string(src_dir.join(&rel))?;
        let rel_str = rel
            .components()
            .map(|c| c.as_os_str().to_string_lossy().into_owned())
            .collect::<Vec<_>>()
            .join("/");
        files.push(SourceFile::new(&rel_str, &text));
    }
    Ok(files)
}

fn walk(base: &Path, dir: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    for entry in fs::read_dir(dir)? {
        let entry = entry?;
        let path = entry.path();
        if path.is_dir() {
            if path.file_name().map(|n| n == "fixtures").unwrap_or(false) {
                continue;
            }
            walk(base, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            if let Ok(rel) = path.strip_prefix(base) {
                out.push(rel.to_path_buf());
            }
        }
    }
    Ok(())
}

/// Full run over a source tree: scan, all rules, allowlist.
pub fn run(src_dir: &Path) -> io::Result<TidyReport> {
    let files = scan_tree(src_dir)?;
    let raw = run_rules(&files);
    let findings = apply_allows(&files, raw);
    Ok(TidyReport { files_scanned: files.len(), findings })
}

/// Locate the `rust/src` tree to scan.  `root_override` (the CLI's
/// `--root`) names the repo root; otherwise try the working directory as
/// repo root, as the `rust/` directory, and as `rust/src` itself, then
/// one level up — covers invocation from the repo root, from `rust/`
/// (where cargo runs), and from `rust/src`.
pub fn locate_src_dir(root_override: Option<&str>) -> Option<PathBuf> {
    let candidates: Vec<PathBuf> = match root_override {
        Some(r) => vec![Path::new(r).join("rust/src"), Path::new(r).join("src"), PathBuf::from(r)],
        None => vec![
            PathBuf::from("rust/src"),
            PathBuf::from("src"),
            PathBuf::from("."),
            PathBuf::from("../rust/src"),
            PathBuf::from("../src"),
        ],
    };
    candidates.into_iter().find(|c| c.join("lib.rs").is_file())
}

/// The allow-audit findings keyed for tests.
pub fn findings_by_rule(findings: &[Finding]) -> BTreeMap<&'static str, usize> {
    let mut m = BTreeMap::new();
    for f in findings {
        *m.entry(f.rule).or_insert(0) += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;

    fn live_tree() -> Vec<SourceFile> {
        let src = Path::new(env!("CARGO_MANIFEST_DIR")).join("src");
        scan_tree(&src).expect("scan rust/src")
    }

    /// The headline gate: every rule, run over this repository's own
    /// sources, after the allowlist — zero findings.
    #[test]
    fn live_tree_is_clean() {
        let files = live_tree();
        assert!(files.len() > 20, "tree walk found only {} files", files.len());
        let findings = apply_allows(&files, run_rules(&files));
        assert!(
            findings.is_empty(),
            "tidy findings on the live tree:\n{}",
            findings
                .iter()
                .map(|f| format!("{}:{}: [{}] {}", f.file, f.line, f.rule, f.message))
                .collect::<Vec<_>>()
                .join("\n")
        );
    }

    #[test]
    fn fixtures_are_excluded_from_the_walk() {
        let files = live_tree();
        assert!(files.iter().all(|f| !f.path.contains("fixtures/")));
        assert!(files.iter().any(|f| f.path == "analysis/mod.rs"));
        assert!(files.iter().any(|f| f.path == "coordinator/options.rs"));
    }

    #[test]
    fn json_report_round_trips() {
        let report = TidyReport {
            files_scanned: 3,
            findings: vec![
                Finding::new("print_hygiene", "live/mod.rs", 12, "no printing".to_string()),
                Finding::new("stage_key", "coordinator/options.rs", 7, "classify 'x'".to_string()),
            ],
        };
        let text = report.to_json().to_string();
        let back = Json::parse(&text).expect("tidy JSON parses");
        assert_eq!(back.get("clean").as_bool(), Some(false));
        let tidy = back.get("tidy");
        assert_eq!(tidy.get("files").as_usize(), Some(3));
        let arr = tidy.get("findings").as_arr().expect("findings array");
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("rule").as_str(), Some("print_hygiene"));
        assert_eq!(arr[0].get("file").as_str(), Some("live/mod.rs"));
        assert_eq!(arr[0].get("line").as_usize(), Some(12));
        assert_eq!(arr[1].get("message").as_str(), Some("classify 'x'"));
        // clean report serializes clean:true
        let clean = TidyReport { files_scanned: 1, findings: vec![] };
        let j = Json::parse(&clean.to_json().to_string()).expect("parses");
        assert_eq!(j.get("clean").as_bool(), Some(true));
    }

    #[test]
    fn allow_audit_fires_on_fixture() {
        let f = SourceFile::new(
            "live/fixture2.rs",
            include_str!("fixtures/allow_bad.rs"),
        );
        let files = vec![f];
        let findings = apply_allows(&files, run_rules(&files));
        let by_rule = findings_by_rule(&findings);
        assert_eq!(by_rule.get("allow_unused"), Some(&1), "findings: {findings:?}");
        assert_eq!(by_rule.get("allow_syntax"), Some(&2), "findings: {findings:?}");
        // the malformed (reason-less) allow must NOT suppress the print
        assert_eq!(by_rule.get("print_hygiene"), Some(&1), "findings: {findings:?}");
    }

    #[test]
    fn valid_allow_suppresses_and_counts_as_used() {
        let f = SourceFile::new("live/fixture.rs", include_str!("fixtures/print_bad.rs"));
        let files = vec![f];
        let raw = run_rules(&files);
        assert_eq!(raw.len(), 3, "raw print findings: {raw:?}");
        let findings = apply_allows(&files, raw);
        // one of the three is allowlisted; no allow_unused appears
        assert_eq!(findings.len(), 2, "post-allow findings: {findings:?}");
        assert!(findings.iter().all(|f| f.rule == "print_hygiene"));
    }
}
