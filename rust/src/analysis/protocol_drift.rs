//! Rule `protocol_drift`: `service/protocol.rs`'s doc header is a
//! *contract*, not prose — this rule keeps it honest (and replaces the
//! old ci.sh shell-grep version check).
//!
//! Three comparisons, all against the module's leading `//!` header:
//!
//! * the header's `Wire protocol **vX.Y**` banner equals the
//!   `PROTOCOL_VERSION` constant;
//! * every identifier-like string literal inside `fn decode` /
//!   `fn decode_options` (the request keys, `op` values and `action`
//!   values the server actually reads) appears in the header's first
//!   fenced ```json request-example block;
//! * and the reverse: every key / `op` value / `action` value the block
//!   advertises is really read by the decoders — documentation cannot
//!   promise a field the server ignores.

use std::collections::BTreeSet;

use super::lexer::tokens;
use super::{Finding, SourceFile};

const RULE: &str = "protocol_drift";

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let Some(file) = files.iter().find(|f| f.path.ends_with("service/protocol.rs")) else {
        return Vec::new();
    };
    let mut out = Vec::new();
    let toks = tokens(&file.lex.masked);

    // the doc header: every comment above the first code token
    let first_code_line = toks.first().map(|t| t.line).unwrap_or(usize::MAX);
    let header: String = file
        .lex
        .comments
        .iter()
        .filter(|c| c.line < first_code_line)
        .map(|c| c.text.strip_prefix('!').unwrap_or(&c.text).to_string())
        .collect::<Vec<_>>()
        .join("\n");

    // 1. version banner vs PROTOCOL_VERSION
    let doc_ver = header
        .split("Wire protocol **v")
        .nth(1)
        .and_then(|rest| rest.split("**").next())
        .map(|v| v.trim().to_string());
    let const_ver = find_seq(&toks, &["const", "PROTOCOL_VERSION"]).and_then(|i| {
        let from = toks[i].offset;
        file.lex.strings.iter().find(|s| s.offset > from).map(|s| s.value.clone())
    });
    match (&doc_ver, &const_ver) {
        (Some(d), Some(c)) if d != c => out.push(Finding::new(
            RULE,
            &file.path,
            1,
            format!("doc header says wire protocol v{d} but PROTOCOL_VERSION is \"{c}\""),
        )),
        (None, _) => out.push(Finding::new(
            RULE,
            &file.path,
            1,
            "doc header has no `Wire protocol **vX.Y**` banner".to_string(),
        )),
        (_, None) => out.push(Finding::new(
            RULE,
            &file.path,
            1,
            "no PROTOCOL_VERSION string constant found".to_string(),
        )),
        _ => {}
    }

    // 2. the header's first fenced json block: advertised request keys
    //    plus the op/action verb values
    let block = header
        .split("```json")
        .nth(1)
        .and_then(|rest| rest.split("```").next())
        .unwrap_or("");
    if block.is_empty() {
        out.push(Finding::new(
            RULE,
            &file.path,
            1,
            "doc header has no fenced ```json request-example block".to_string(),
        ));
        return out;
    }
    let mut doc_terms: BTreeSet<String> = BTreeSet::new();
    for (key, value) in json_pairs(block) {
        if is_key_like(&key) {
            doc_terms.insert(key.clone());
        }
        if (key == "op" || key == "action") && is_key_like(&value) {
            doc_terms.insert(value);
        }
    }

    // 3. what the decoders actually read: identifier-like string
    //    literals inside fn decode / fn decode_options
    let mut code_terms: BTreeSet<String> = BTreeSet::new();
    let mut code_lines: Vec<(String, usize)> = Vec::new();
    for name in ["decode", "decode_options"] {
        let Some(start) = find_seq(&toks, &["fn", name]) else {
            out.push(Finding::new(
                RULE,
                &file.path,
                1,
                format!("protocol.rs has no `fn {name}`"),
            ));
            continue;
        };
        let Some((from, to)) = body_range(&toks, start) else { continue };
        for s in &file.lex.strings {
            if s.offset > from && s.offset < to && is_key_like(&s.value) {
                code_terms.insert(s.value.clone());
                code_lines.push((s.value.clone(), s.line));
            }
        }
    }

    for term in code_terms.difference(&doc_terms) {
        let line = code_lines.iter().find(|(t, _)| t == term).map(|(_, l)| *l).unwrap_or(1);
        out.push(Finding::new(
            RULE,
            &file.path,
            line,
            format!(
                "decoder reads \"{term}\" but the doc header's request examples \
                 never mention it — document the field"
            ),
        ));
    }
    for term in doc_terms.difference(&code_terms) {
        out.push(Finding::new(
            RULE,
            &file.path,
            1,
            format!(
                "doc header advertises \"{term}\" but neither decoder reads it — \
                 stale documentation or a missing decode arm"
            ),
        ));
    }

    out
}

/// `"key": <value>` pairs in a json-ish text; values captured only when
/// they are themselves quoted strings (enough for `op`/`action` verbs).
fn json_pairs(block: &str) -> Vec<(String, String)> {
    let mut pairs = Vec::new();
    let chars: Vec<char> = block.chars().collect();
    let mut i = 0usize;
    while i < chars.len() {
        if chars[i] != '"' {
            i += 1;
            continue;
        }
        let start = i + 1;
        let mut j = start;
        while j < chars.len() && chars[j] != '"' {
            j += 1;
        }
        if j >= chars.len() {
            break;
        }
        let word: String = chars[start..j].iter().collect();
        let mut k = j + 1;
        while k < chars.len() && chars[k].is_whitespace() {
            k += 1;
        }
        if k < chars.len() && chars[k] == ':' {
            // a key: its value may be a quoted string
            k += 1;
            while k < chars.len() && chars[k].is_whitespace() {
                k += 1;
            }
            let mut value = String::new();
            if k < chars.len() && chars[k] == '"' {
                let vstart = k + 1;
                let mut v = vstart;
                while v < chars.len() && chars[v] != '"' {
                    v += 1;
                }
                if v < chars.len() {
                    value = chars[vstart..v].iter().collect();
                }
            }
            pairs.push((word, value));
        }
        i = j + 1;
    }
    pairs
}

/// Lowercase snake-case identifiers — protocol keys and verbs.  Filters
/// out prose, numbers and format-string fragments.
fn is_key_like(s: &str) -> bool {
    !s.is_empty()
        && s.chars().next().map(|c| c.is_ascii_lowercase() || c == '_').unwrap_or(false)
        && s.chars().all(|c| c.is_ascii_lowercase() || c.is_ascii_digit() || c == '_')
}

fn find_seq(toks: &[super::lexer::Tok], seq: &[&str]) -> Option<usize> {
    (0..toks.len().saturating_sub(seq.len() - 1))
        .find(|&i| seq.iter().enumerate().all(|(j, s)| toks[i + j].text == *s))
}

/// Byte range of the brace-delimited body of the item starting at token
/// `start`.
fn body_range(toks: &[super::lexer::Tok], start: usize) -> Option<(usize, usize)> {
    let open = (start..toks.len()).find(|&i| toks[i].text == "{")?;
    let mut depth = 0usize;
    for i in open..toks.len() {
        match toks[i].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    return Some((toks[open].offset, toks[i].offset));
                }
            }
            _ => {}
        }
    }
    None
}

#[cfg(test)]
mod tests {
    use super::super::SourceFile;
    use super::*;

    #[test]
    fn fires_on_drift_fixture() {
        let f = SourceFile::new(
            "service/protocol.rs",
            include_str!("fixtures/protocol_drift.rs"),
        );
        let findings = check(&[f]);
        let msgs: Vec<&str> = findings.iter().map(|f| f.message.as_str()).collect();
        assert!(
            msgs.iter().any(|m| m.contains("v9.1") && m.contains("9.0")),
            "version drift not caught: {msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("\"ghost_key\"") && m.contains("advertises")),
            "doc-only key not caught: {msgs:?}"
        );
        assert!(
            msgs.iter().any(|m| m.contains("\"rogue_key\"") && m.contains("never mention")),
            "code-only key not caught: {msgs:?}"
        );
        assert_eq!(findings.len(), 3, "{msgs:?}");
    }

    #[test]
    fn clean_when_doc_and_code_agree() {
        let fixed = include_str!("fixtures/protocol_drift.rs")
            .replace("**v9.1**", "**v9.0**")
            .replace(",\"ghost_key\":1", "")
            .replace(", \"rogue_key\"", "");
        let f = SourceFile::new("service/protocol.rs", &fixed);
        let findings = check(&[f]);
        assert!(findings.is_empty(), "findings: {findings:?}");
    }

    #[test]
    fn absent_protocol_file_is_a_no_op() {
        let f = SourceFile::new("live/mod.rs", "pub fn x() {}\n");
        assert!(check(&[f]).is_empty());
    }
}
