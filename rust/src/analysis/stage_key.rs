//! Rule `stage_key`: the coalescing contract of `coordinator/options.rs`.
//!
//! Batch admission and cache identity hinge on every options field being
//! consciously classified: a field in `stage1_key()` separates batches
//! and cache entries, a field in `stage2_key()` separates stage-2 kernel
//! groups, and a field in `NEITHER_STAGE_KEY` is a declaration that it
//! never changes the numbers (tiling, tracing, layout).  A field in
//! *none* of the three would silently coalesce jobs whose numerics
//! differ — the exact failure mode PRs 3/7/8 document.  This rule makes
//! that a build error:
//!
//! * every `ResolvedOptions` field appears in exactly one of
//!   `stage1_key()` / `stage2_key()` / `NEITHER_STAGE_KEY`;
//! * `NEITHER_STAGE_KEY` names only real fields (no stale entries);
//! * every `QueryOptions` field maps onto a `ResolvedOptions` field,
//!   directly or via `QUERY_FIELD_ALIASES`;
//! * the `Stage1Key`/`Stage2Key` struct fields match exactly what their
//!   projection functions read from `self` — the key type and the
//!   projection cannot drift apart.

use std::collections::BTreeSet;

use super::lexer::{tokens, Tok};
use super::{Finding, SourceFile};

const RULE: &str = "stage_key";
const OPTIONS_PATH: &str = "coordinator/options.rs";

pub fn check(files: &[SourceFile]) -> Vec<Finding> {
    let Some(file) = files.iter().find(|f| f.path.ends_with(OPTIONS_PATH)) else {
        // single-file fixture runs need not include options.rs; the CLI
        // and the live-tree test always scan the whole tree
        return Vec::new();
    };
    let toks = tokens(&file.lex.masked);
    let mut out = Vec::new();

    let q_fields = struct_fields(&toks, "QueryOptions");
    let r_fields = struct_fields(&toks, "ResolvedOptions");
    let s1_fields = struct_fields(&toks, "Stage1Key");
    let s2_fields = struct_fields(&toks, "Stage2Key");
    let s1_refs = self_refs(&toks, "stage1_key");
    let s2_refs = self_refs(&toks, "stage2_key");
    let neither = const_strings(file, &toks, "NEITHER_STAGE_KEY");
    let aliases = const_strings(file, &toks, "QUERY_FIELD_ALIASES");

    let mut missing = Vec::new();
    for (name, present) in [
        ("struct QueryOptions", q_fields.is_some()),
        ("struct ResolvedOptions", r_fields.is_some()),
        ("struct Stage1Key", s1_fields.is_some()),
        ("struct Stage2Key", s2_fields.is_some()),
        ("fn stage1_key", s1_refs.is_some()),
        ("fn stage2_key", s2_refs.is_some()),
        ("const NEITHER_STAGE_KEY", neither.is_some()),
        ("const QUERY_FIELD_ALIASES", aliases.is_some()),
    ] {
        if !present {
            missing.push(name);
        }
    }
    if !missing.is_empty() {
        out.push(Finding::new(
            RULE,
            &file.path,
            1,
            format!("options.rs is missing: {}", missing.join(", ")),
        ));
        return out;
    }
    let q_fields = q_fields.unwrap_or_default();
    let r_fields = r_fields.unwrap_or_default();
    let s1_fields = s1_fields.unwrap_or_default();
    let s2_fields = s2_fields.unwrap_or_default();
    let s1_refs = s1_refs.unwrap_or_default();
    let s2_refs = s2_refs.unwrap_or_default();
    let neither = neither.unwrap_or_default();
    let aliases = aliases.unwrap_or_default();

    if aliases.len() % 2 != 0 {
        out.push(Finding::new(
            RULE,
            &file.path,
            1,
            "QUERY_FIELD_ALIASES must hold (query_field, resolved_field) pairs".to_string(),
        ));
    }
    let alias_pairs: Vec<(&str, &str)> = aliases
        .chunks_exact(2)
        .map(|c| (c[0].as_str(), c[1].as_str()))
        .collect();

    let r_names: BTreeSet<&str> = r_fields.iter().map(|(n, _)| n.as_str()).collect();
    let q_names: BTreeSet<&str> = q_fields.iter().map(|(n, _)| n.as_str()).collect();
    let neither_set: BTreeSet<&str> = neither.iter().map(|s| s.as_str()).collect();

    // 1. every ResolvedOptions field in exactly one bucket
    for (name, line) in &r_fields {
        let in_s1 = s1_refs.contains(name);
        let in_s2 = s2_refs.contains(name);
        let in_neither = neither_set.contains(name.as_str());
        let count = in_s1 as usize + in_s2 as usize + in_neither as usize;
        if count == 0 {
            out.push(Finding::new(
                RULE,
                &file.path,
                *line,
                format!(
                    "ResolvedOptions field '{name}' is in none of stage1_key(), \
                     stage2_key(), NEITHER_STAGE_KEY — unclassified fields silently \
                     coalesce jobs whose numerics may differ; classify it"
                ),
            ));
        } else if count > 1 {
            let mut places = Vec::new();
            if in_s1 {
                places.push("stage1_key()");
            }
            if in_s2 {
                places.push("stage2_key()");
            }
            if in_neither {
                places.push("NEITHER_STAGE_KEY");
            }
            out.push(Finding::new(
                RULE,
                &file.path,
                *line,
                format!(
                    "ResolvedOptions field '{name}' is classified more than once: {}",
                    places.join(" and ")
                ),
            ));
        }
    }

    // 2. no stale NEITHER entries
    for entry in &neither {
        if !r_names.contains(entry.as_str()) {
            out.push(Finding::new(
                RULE,
                &file.path,
                1,
                format!("NEITHER_STAGE_KEY entry '{entry}' is not a ResolvedOptions field"),
            ));
        }
    }

    // 3. every QueryOptions field maps onto a ResolvedOptions field
    for (name, line) in &q_fields {
        let resolved = alias_pairs
            .iter()
            .find(|(q, _)| q == name)
            .map(|(_, r)| *r)
            .unwrap_or(name.as_str());
        if !r_names.contains(resolved) {
            out.push(Finding::new(
                RULE,
                &file.path,
                *line,
                format!(
                    "QueryOptions field '{name}' has no ResolvedOptions counterpart \
                     '{resolved}' (add the field, or a QUERY_FIELD_ALIASES entry)"
                ),
            ));
        }
    }

    // 4. alias table hygiene
    for (q, r) in &alias_pairs {
        if !q_names.contains(q) {
            out.push(Finding::new(
                RULE,
                &file.path,
                1,
                format!("QUERY_FIELD_ALIASES maps '{q}' which is not a QueryOptions field"),
            ));
        }
        if !r_names.contains(r) {
            out.push(Finding::new(
                RULE,
                &file.path,
                1,
                format!("QUERY_FIELD_ALIASES target '{r}' is not a ResolvedOptions field"),
            ));
        }
    }

    // 5. key structs match their projections exactly
    for (struct_name, fields, refs, fn_name) in [
        ("Stage1Key", &s1_fields, &s1_refs, "stage1_key()"),
        ("Stage2Key", &s2_fields, &s2_refs, "stage2_key()"),
    ] {
        let field_set: BTreeSet<&str> = fields.iter().map(|(n, _)| n.as_str()).collect();
        let ref_set: BTreeSet<&str> = refs.iter().map(|s| s.as_str()).collect();
        for f in field_set.difference(&ref_set) {
            out.push(Finding::new(
                RULE,
                &file.path,
                1,
                format!("{struct_name} field '{f}' is never read by {fn_name}"),
            ));
        }
        for f in ref_set.difference(&field_set) {
            out.push(Finding::new(
                RULE,
                &file.path,
                1,
                format!("{fn_name} reads self.{f} but {struct_name} has no such field"),
            ));
        }
    }

    out
}

/// Fields of `struct <name> { .. }`: idents followed by a single `:` at
/// brace depth 1, preceded by `{`, `,` or `pub`.
fn struct_fields(toks: &[Tok], name: &str) -> Option<Vec<(String, usize)>> {
    let start = find_seq(toks, &["struct", name])?;
    let open = (start + 2..toks.len()).find(|&i| toks[i].text == "{")?;
    let mut fields = Vec::new();
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            ":" if depth == 1 && i >= 2 => {
                let next_is_colon = toks.get(i + 1).map(|t| t.text == ":").unwrap_or(false);
                let prev_ident = i > open + 1
                    && toks[i - 1].text.chars().next().map(|c| c.is_ascii_lowercase() || c == '_')
                        == Some(true);
                let before = &toks[i - 2].text;
                if !next_is_colon
                    && prev_ident
                    && (before == "{" || before == "," || before == "pub")
                {
                    fields.push((toks[i - 1].text.clone(), toks[i - 1].line));
                }
            }
            _ => {}
        }
        i += 1;
    }
    Some(fields)
}

/// `self.<field>` reads inside `fn <name>`'s body (method calls on self
/// excluded).
fn self_refs(toks: &[Tok], name: &str) -> Option<BTreeSet<String>> {
    let start = find_seq(toks, &["fn", name])?;
    let open = (start + 2..toks.len()).find(|&i| toks[i].text == "{")?;
    let mut refs = BTreeSet::new();
    let mut depth = 0usize;
    let mut i = open;
    while i < toks.len() {
        match toks[i].text.as_str() {
            "{" => depth += 1,
            "}" => {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
            "self" => {
                if toks.get(i + 1).map(|t| t.text == ".").unwrap_or(false) {
                    if let Some(field) = toks.get(i + 2) {
                        let is_call =
                            toks.get(i + 3).map(|t| t.text == "(").unwrap_or(false);
                        if !is_call {
                            refs.insert(field.text.clone());
                        }
                    }
                }
            }
            _ => {}
        }
        i += 1;
    }
    Some(refs)
}

/// String literals between `const <name>` and its terminating `;`.
fn const_strings(file: &SourceFile, toks: &[Tok], name: &str) -> Option<Vec<String>> {
    let start = find_seq(toks, &["const", name])?;
    let from = toks[start].offset;
    let to = (start..toks.len())
        .find(|&i| toks[i].text == ";")
        .map(|i| toks[i].offset)
        .unwrap_or(usize::MAX);
    Some(
        file.lex
            .strings
            .iter()
            .filter(|s| s.offset > from && s.offset < to)
            .map(|s| s.value.clone())
            .collect(),
    )
}

fn find_seq(toks: &[Tok], seq: &[&str]) -> Option<usize> {
    (0..toks.len().saturating_sub(seq.len() - 1))
        .find(|&i| seq.iter().enumerate().all(|(j, s)| toks[i + j].text == *s))
}

#[cfg(test)]
mod tests {
    use super::super::SourceFile;
    use super::*;

    #[test]
    fn fires_on_unclassified_field_fixture() {
        // the acceptance-criterion pin: a new ResolvedOptions field with
        // no classification fails the build
        let f = SourceFile::new(
            "coordinator/options.rs",
            include_str!("fixtures/stage_key_bad.rs"),
        );
        let findings = check(&[f]);
        assert_eq!(findings.len(), 1, "findings: {findings:?}");
        assert!(findings[0].message.contains("frobnicate"), "{}", findings[0].message);
        assert!(findings[0].message.contains("unclassified") || findings[0].message.contains("none of"));
    }

    #[test]
    fn clean_when_every_field_is_classified() {
        let fixed = include_str!("fixtures/stage_key_bad.rs")
            .replace("&[];", "&[\"frobnicate\"];");
        let f = SourceFile::new("coordinator/options.rs", &fixed);
        let findings = check(&[f]);
        assert!(findings.is_empty(), "findings: {findings:?}");
    }

    #[test]
    fn stale_neither_entry_and_bad_alias_fire() {
        let fixed = include_str!("fixtures/stage_key_bad.rs")
            .replace("&[];", "&[\"frobnicate\", \"ghost\"];")
            .replace(
                "&[(\"local\", \"local_neighbors\")];",
                "&[(\"local\", \"local_neighbors\"), (\"phantom\", \"k\")];",
            );
        let f = SourceFile::new("coordinator/options.rs", &fixed);
        let findings = check(&[f]);
        let msgs: Vec<&str> = findings.iter().map(|f| f.message.as_str()).collect();
        assert!(msgs.iter().any(|m| m.contains("'ghost'")), "{msgs:?}");
        assert!(msgs.iter().any(|m| m.contains("'phantom'")), "{msgs:?}");
        assert_eq!(findings.len(), 2, "{msgs:?}");
    }

    #[test]
    fn key_struct_projection_drift_fires() {
        // stage1_key() stops reading a field the struct still declares
        let broken = include_str!("fixtures/stage_key_bad.rs")
            .replace("&[];", "&[\"frobnicate\"];")
            .replace(
                "Stage1Key { k: self.k, local_neighbors: self.local_neighbors }",
                "Stage1Key { k: self.k, local_neighbors: None }",
            );
        let f = SourceFile::new("coordinator/options.rs", &broken);
        let findings = check(&[f]);
        // local_neighbors: no longer read by stage1_key → both the
        // struct-sync check and the classification check fire
        assert!(
            findings.iter().any(|f| f.message.contains("never read by stage1_key()")),
            "findings: {findings:?}"
        );
        assert!(
            findings.iter().any(|f| f.message.contains("'local_neighbors'")),
            "findings: {findings:?}"
        );
    }

    #[test]
    fn absent_options_file_is_a_no_op() {
        let f = SourceFile::new("live/mod.rs", "pub fn x() {}\n");
        assert!(check(&[f]).is_empty());
    }
}
