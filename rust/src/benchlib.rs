//! Benchmark harness (criterion is unavailable offline): wall-clock timing
//! with warmup, repetition statistics, and paper-style table printing.
//!
//! Every bench binary under `rust/benches/` uses this module and prints
//! rows in the same format as the paper's tables, so `cargo bench` output
//! maps 1:1 onto Table 1-3 / Fig 6-9 of the paper.

use std::time::Instant;

/// Summary statistics of repeated timed runs (seconds).
#[derive(Debug, Clone, Copy)]
pub struct Sample {
    pub reps: usize,
    pub mean_s: f64,
    pub min_s: f64,
    pub max_s: f64,
    pub std_s: f64,
}

impl Sample {
    /// Mean in milliseconds (the paper's unit).
    pub fn mean_ms(&self) -> f64 {
        self.mean_s * 1e3
    }
}

/// Time `f` once (seconds).
pub fn time_once<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// Run `f` with `warmup` unmeasured runs then `reps` measured ones.
pub fn bench<T>(warmup: usize, reps: usize, mut f: impl FnMut() -> T) -> Sample {
    assert!(reps >= 1);
    for _ in 0..warmup {
        let _ = std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(reps);
    for _ in 0..reps {
        let t0 = Instant::now();
        let out = f();
        times.push(t0.elapsed().as_secs_f64());
        std::hint::black_box(out);
    }
    summarize(&times)
}

/// Adaptive repetitions: run until `budget_s` of measured time or
/// `max_reps`, whichever first (min 1 rep).  Keeps big-size benches from
/// dominating the suite while small sizes still average many reps.
pub fn bench_budgeted<T>(warmup: usize, budget_s: f64, max_reps: usize, mut f: impl FnMut() -> T) -> Sample {
    for _ in 0..warmup {
        let _ = std::hint::black_box(f());
    }
    let mut times = Vec::new();
    let mut spent = 0.0;
    while times.is_empty() || (spent < budget_s && times.len() < max_reps) {
        let t0 = Instant::now();
        let out = f();
        let dt = t0.elapsed().as_secs_f64();
        std::hint::black_box(out);
        times.push(dt);
        spent += dt;
    }
    summarize(&times)
}

fn summarize(times: &[f64]) -> Sample {
    let n = times.len();
    let mean = times.iter().sum::<f64>() / n as f64;
    let min = times.iter().cloned().fold(f64::INFINITY, f64::min);
    let max = times.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
    let var = times.iter().map(|t| (t - mean) * (t - mean)).sum::<f64>() / n as f64;
    Sample { reps: n, mean_s: mean, min_s: min, max_s: max, std_s: var.sqrt() }
}

/// Fixed-width table printer for paper-style output.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// New table with column headers.
    pub fn new(headers: &[&str]) -> Self {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    /// Append a row (must match header arity).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len());
        self.rows.push(cells.to_vec());
    }

    /// Render with aligned columns.
    pub fn render(&self) -> String {
        let cols = self.headers.len();
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for c in 0..cols {
                widths[c] = widths[c].max(row[c].len());
            }
        }
        let mut out = String::new();
        let fmt_row = |cells: &[String], widths: &[usize]| -> String {
            let mut line = String::from("| ");
            for (c, cell) in cells.iter().enumerate() {
                line.push_str(&format!("{:>w$} | ", cell, w = widths[c]));
            }
            line.trim_end().to_string()
        };
        out.push_str(&fmt_row(&self.headers, &widths));
        out.push('\n');
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        out.push_str(&fmt_row(&sep, &widths));
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &widths));
            out.push('\n');
        }
        out
    }

    /// Print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }
}

/// Format milliseconds like the paper's tables (3 significant-ish digits).
pub fn fmt_ms(ms: f64) -> String {
    if ms >= 100.0 {
        format!("{ms:.0}")
    } else if ms >= 10.0 {
        format!("{ms:.1}")
    } else {
        format!("{ms:.2}")
    }
}

/// Format a speedup ratio.
pub fn fmt_x(r: f64) -> String {
    if r >= 100.0 {
        format!("{r:.0}x")
    } else {
        format!("{r:.2}x")
    }
}

/// Parse bench CLI args of the form `--sizes 4096,16384 --reps 3`.
/// Unknown args are ignored (cargo bench passes `--bench`).
pub struct BenchArgs {
    pub sizes: Vec<usize>,
    pub reps: usize,
    pub budget_s: f64,
    pub paper_sizes: bool,
    pub quick: bool,
}

impl BenchArgs {
    /// Parse from `std::env::args`, with per-bench default sizes.
    pub fn parse(default_sizes: &[usize]) -> BenchArgs {
        let args: Vec<String> = std::env::args().collect();
        let mut out = BenchArgs {
            sizes: default_sizes.to_vec(),
            reps: 3,
            budget_s: 10.0,
            paper_sizes: false,
            quick: false,
        };
        let mut i = 0;
        while i < args.len() {
            match args[i].as_str() {
                "--sizes" if i + 1 < args.len() => {
                    out.sizes = args[i + 1]
                        .split(',')
                        .filter_map(|s| s.trim().parse().ok())
                        .collect();
                    i += 1;
                }
                "--reps" if i + 1 < args.len() => {
                    out.reps = args[i + 1].parse().unwrap_or(out.reps);
                    i += 1;
                }
                "--budget" if i + 1 < args.len() => {
                    out.budget_s = args[i + 1].parse().unwrap_or(out.budget_s);
                    i += 1;
                }
                "--paper-sizes" => {
                    // the paper's 5 sizes (1K = 1024); serial baselines at
                    // the top sizes take hours — see EXPERIMENTS.md
                    out.sizes = vec![10, 50, 100, 500, 1000]
                        .into_iter()
                        .map(|k| k * 1024)
                        .collect();
                    out.paper_sizes = true;
                }
                "--quick" => {
                    out.quick = true;
                    out.budget_s = 2.0;
                }
                _ => {}
            }
            i += 1;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_counts_reps() {
        let s = bench(1, 5, || 42u64);
        assert_eq!(s.reps, 5);
        assert!(s.min_s <= s.mean_s && s.mean_s <= s.max_s);
    }

    #[test]
    fn budgeted_respects_caps() {
        let s = bench_budgeted(0, 10.0, 4, || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(s.reps <= 4);
        let s2 = bench_budgeted(0, 0.0, 100, || ());
        assert_eq!(s2.reps, 1);
    }

    #[test]
    fn timing_is_sane() {
        let (_, dt) = time_once(|| std::thread::sleep(std::time::Duration::from_millis(5)));
        assert!(dt >= 0.004, "{dt}");
    }

    #[test]
    fn table_renders_aligned() {
        let mut t = Table::new(&["a", "long_header"]);
        t.row(&["1".into(), "2".into()]);
        t.row(&["100000".into(), "x".into()]);
        let s = t.render();
        let lines: Vec<&str> = s.lines().collect();
        assert_eq!(lines.len(), 4);
        assert_eq!(lines[0].len(), lines[2].len());
        assert_eq!(lines[2].len(), lines[3].len());
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(fmt_ms(123.4), "123");
        assert_eq!(fmt_ms(12.34), "12.3");
        assert_eq!(fmt_ms(1.234), "1.23");
        assert_eq!(fmt_x(543.2), "543x");
        assert_eq!(fmt_x(2.5), "2.50x");
    }
}
