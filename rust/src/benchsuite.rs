//! Shared measurement logic for the paper-reproduction benches.
//!
//! Every bench binary under `rust/benches/` needs the same five
//! measurements the paper's §5 takes at each problem size (n = m points,
//! uniform square, k = 10):
//!
//! * CPU serial AIDW (f64)                         — Table 1 baseline
//! * original algorithm, naive + tiled             — brute kNN on PJRT
//! * improved algorithm, naive + tiled             — grid kNN + PJRT
//!
//! with each run split into its kNN and interpolation stages.  This module
//! measures them once; the per-table benches format the slices they need.
//!
//! **Serial extrapolation**: the paper's serial baseline at 1000K took
//! 18.7 hours; on this testbed we measure a query subsample and scale by
//! the O(n·m) query ratio (exact for this embarrassingly parallel loop).
//! The subsample cap is configurable and the extrapolation is flagged in
//! the output.

use crate::aidw::params::AidwParams;
use crate::aidw::serial;
use crate::error::{Error, Result};
use crate::geom::PointSet;
use crate::grid::{EvenGrid, GridConfig};
use crate::jsonio::Json;
use crate::knn::grid_knn::{grid_knn_avg_distances_on, GridKnnConfig, RingRule};
use crate::pool::Pool;
use crate::runtime::{AidwExecutor, Engine, Variant};
use crate::workload;

/// Stage times of one algorithm variant at one size (milliseconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct VariantTimes {
    pub knn_ms: f64,
    pub interp_ms: f64,
}

impl VariantTimes {
    pub fn total_ms(&self) -> f64 {
        self.knn_ms + self.interp_ms
    }
}

/// All five measurements at one problem size.
#[derive(Debug, Clone, Copy)]
pub struct SizeMeasurement {
    /// n = m (data points = interpolated points).
    pub n: usize,
    /// Serial baseline (ms); None when skipped.  `serial_extrapolated`
    /// notes whether it was scaled from a query subsample.
    pub serial_ms: Option<f64>,
    pub serial_extrapolated: bool,
    pub original_naive: VariantTimes,
    pub original_tiled: VariantTimes,
    pub improved_naive: VariantTimes,
    pub improved_tiled: VariantTimes,
}

/// Measurement options.
#[derive(Debug, Clone, Copy)]
pub struct MeasureOpts {
    /// Measure the serial baseline (skippable for kNN-only benches).
    pub serial: bool,
    /// Serial query-subsample cap (extrapolated above this).
    pub serial_sub_cap: usize,
    /// Workload seed.
    pub seed: u64,
    /// Region side length.
    pub side: f64,
    /// Timed repetitions per measurement; the reported run is the one
    /// with the **median** primary time (all of a run's fields stay
    /// coherent — no mixing of fields across reps).
    pub reps: usize,
    /// Discarded warmup runs before the timed reps (cold caches, lazy
    /// pool spin-up, first-touch page faults).
    pub warmup: usize,
}

impl Default for MeasureOpts {
    fn default() -> Self {
        MeasureOpts {
            serial: true,
            serial_sub_cap: 2048,
            seed: 42,
            side: 100.0,
            reps: 3,
            warmup: 1,
        }
    }
}

/// Timing hygiene shared by every `measure_*` section: run `f` `warmup`
/// times discarded, then `reps` times (at least once), and return the
/// run whose `time_of` value is the median.  Returning a whole run —
/// rather than a per-field median — keeps each measurement's counters
/// and timings from the *same* execution, so invariants like "exactly
/// one cache hit" still hold on the reported numbers.
pub fn median_rep<T, E>(
    warmup: usize,
    reps: usize,
    mut f: impl FnMut() -> std::result::Result<T, E>,
    time_of: impl Fn(&T) -> f64,
) -> std::result::Result<T, E> {
    for _ in 0..warmup {
        std::hint::black_box(f()?);
    }
    let mut runs: Vec<T> = Vec::with_capacity(reps.max(1));
    for _ in 0..reps.max(1) {
        runs.push(f()?);
    }
    runs.sort_by(|a, b| time_of(a).total_cmp(&time_of(b)));
    let mid = (runs.len() - 1) / 2;
    Ok(runs.swap_remove(mid))
}

/// The paper's size label ("10K" = 10*1024 points).
pub fn size_label(n: usize) -> String {
    if n % 1024 == 0 {
        format!("{}K", n / 1024)
    } else {
        format!("{n}")
    }
}

/// The standard workload at size n (paper §5.1: n = m, uniform square).
pub fn standard_workload(n: usize, opts: &MeasureOpts) -> (PointSet, Vec<(f64, f64)>) {
    let data = workload::uniform_square(n, opts.side, opts.seed);
    let queries = workload::uniform_square(n, opts.side, opts.seed + 1).xy();
    (data, queries)
}

/// Serial AIDW time (ms), extrapolating from a query subsample when the
/// problem exceeds `sub_cap`.  Returns (ms, extrapolated?).
pub fn measure_serial(
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    sub_cap: usize,
) -> (f64, bool) {
    let sub = queries.len().min(sub_cap.max(1));
    let t0 = std::time::Instant::now();
    let out = serial::aidw_serial(data, &queries[..sub], params);
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(out);
    let scale = queries.len() as f64 / sub as f64;
    (dt * scale * 1e3, sub < queries.len())
}

/// One variant of the *original* algorithm (brute-force kNN on PJRT).
pub fn measure_original(
    exec: &AidwExecutor,
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    variant: Variant,
) -> Result<VariantTimes> {
    let (out, times) = exec.original_aidw(data, queries, params, variant)?;
    std::hint::black_box(out);
    Ok(VariantTimes { knn_ms: times.knn_s * 1e3, interp_ms: times.interp_s * 1e3 })
}

/// One variant of the *improved* algorithm: rust grid kNN (stage 1)
/// + PJRT alpha/interpolation (stage 2).  Grid build time is included in
/// the kNN stage, as in the paper.
pub fn measure_improved(
    pool: &Pool,
    exec: &AidwExecutor,
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    variant: Variant,
) -> Result<VariantTimes> {
    let t0 = std::time::Instant::now();
    let grid = EvenGrid::build_on(pool, data, None, &GridConfig::default())?;
    let (r_obs, _) = grid_knn_avg_distances_on(
        pool,
        &grid,
        queries,
        &GridKnnConfig { k: params.k, rule: RingRule::Exact },
    );
    let grid_knn_s = t0.elapsed().as_secs_f64();
    let (out, times) = exec.improved_aidw(data, queries, &r_obs, params, variant)?;
    std::hint::black_box(out);
    Ok(VariantTimes {
        knn_ms: (grid_knn_s + times.knn_s) * 1e3,
        interp_ms: times.interp_s * 1e3,
    })
}

/// Measure all five versions at one size.
pub fn measure_size(
    engine: &Engine,
    pool: &Pool,
    n: usize,
    opts: &MeasureOpts,
) -> Result<SizeMeasurement> {
    let params = AidwParams::default();
    let (data, queries) = standard_workload(n, opts);
    let exec = AidwExecutor::new(engine);
    exec.warmup()?;

    let (serial_ms, serial_extrapolated) = if opts.serial {
        let (ms, ex) = measure_serial(&data, &queries, &params, opts.serial_sub_cap);
        (Some(ms), ex)
    } else {
        (None, false)
    };

    Ok(SizeMeasurement {
        n,
        serial_ms,
        serial_extrapolated,
        original_naive: measure_original(&exec, &data, &queries, &params, Variant::Naive)?,
        original_tiled: measure_original(&exec, &data, &queries, &params, Variant::Tiled)?,
        improved_naive: measure_improved(pool, &exec, &data, &queries, &params, Variant::Naive)?,
        improved_tiled: measure_improved(pool, &exec, &data, &queries, &params, Variant::Tiled)?,
    })
}

/// CPU-only measurements at one size — what the `aidw bench` subcommand
/// runs on artifact-free testbeds: the serial baseline plus the pure-rust
/// improved pipeline under both ring rules, stage-split.
#[derive(Debug, Clone, Copy)]
pub struct CpuSizeMeasurement {
    pub n: usize,
    pub serial_ms: Option<f64>,
    pub serial_extrapolated: bool,
    pub improved_exact: VariantTimes,
    pub improved_paper1: VariantTimes,
}

/// Measure the CPU-only suite at one size.
pub fn measure_size_cpu(pool: &Pool, n: usize, opts: &MeasureOpts) -> CpuSizeMeasurement {
    let params = AidwParams::default();
    let (data, queries) = standard_workload(n, opts);
    let (serial_ms, serial_extrapolated) = if opts.serial {
        let (ms, ex) = measure_serial(&data, &queries, &params, opts.serial_sub_cap);
        (Some(ms), ex)
    } else {
        (None, false)
    };
    let run = |rule: RingRule| -> VariantTimes {
        let (out, times) =
            crate::aidw::pipeline::interpolate_improved_on(pool, &data, &queries, &params, rule);
        std::hint::black_box(out);
        VariantTimes { knn_ms: times.knn_s * 1e3, interp_ms: times.interp_s * 1e3 }
    };
    CpuSizeMeasurement {
        n,
        serial_ms,
        serial_extrapolated,
        improved_exact: run(RingRule::Exact),
        improved_paper1: run(RingRule::PaperPlusOne),
    }
}

/// Planner-path measurements at one size — the two-stage execution
/// planner through a CPU-only coordinator: cold per-stage times from the
/// response's stage split, a two-variant pair sharing one stage-1 sweep
/// (stage-level coalescing), and a repeated identical raster served from
/// the `NeighborCache`.
#[derive(Debug, Clone, Copy)]
pub struct PlannerMeasurement {
    pub n: usize,
    /// Cold stage-1 (kNN + alpha) ms of one n-query raster.
    pub stage1_ms: f64,
    /// Cold stage-2 (weighted interpolating) ms.
    pub stage2_ms: f64,
    /// Wall ms for a naive+tiled pair submitted together (one stage-1).
    pub coalesce_pair_ms: f64,
    /// Stage-1 executions the pair actually ran (1 = coalesced/reused).
    pub coalesce_stage1_execs: u64,
    /// Wall ms for the repeated identical raster (stage 1 skipped).
    pub cache_hit_ms: f64,
    /// Neighbor-cache hits observed during the repeat (1 expected).
    pub cache_hits: u64,
    /// Stage-1 wall ms the cache saved during the repeat — the served
    /// entry's recorded build time (ROADMAP PR-4(b)).
    pub cache_saved_ms: f64,
}

/// Measure the planner suite at one size (CPU-only coordinator; results
/// are asserted bit-identical between the cold and cached passes).
pub fn measure_planner(
    n: usize,
    opts: &MeasureOpts,
    threads: Option<usize>,
) -> Result<PlannerMeasurement> {
    use crate::coordinator::{
        BatchPolicy, Coordinator, CoordinatorConfig, EngineMode, InterpolationRequest,
    };
    let cfg = CoordinatorConfig {
        engine_mode: EngineMode::CpuOnly,
        stage1_threads: threads,
        batch: BatchPolicy {
            linger: std::time::Duration::from_millis(20),
            // the coalesce pair must fit one batch even at the largest
            // bench sizes (the default 8192 cap would split n >= 4097)
            max_queries: (2 * n).max(8192),
            ..Default::default()
        },
        ..Default::default()
    };
    let coord = Coordinator::new(cfg)?;
    let (data, queries) = standard_workload(n, opts);
    coord.register_dataset("bench", data)?;

    // cold pass: per-stage timings straight from the planner's response
    let cold = coord.interpolate(InterpolationRequest::new("bench", queries.clone()))?;

    // coalesce pass: two stage-2 variants on a fresh raster, submitted
    // together — equal stage-1 keys, so the kNN sweep runs once.  The
    // pair wall time includes the linger window.  `coalesce_stage1_execs
    // == 1` holds even if the second submit misses the linger: the
    // dispatcher is serial, so the first batch's artifact is cached
    // before the second batch can form, which then hits the cache.
    let q2 = workload::uniform_square(n, opts.side, opts.seed + 7).xy();
    let m0 = coord.metrics();
    let t0 = std::time::Instant::now();
    let t_naive = coord.submit(
        InterpolationRequest::new("bench", q2.clone()).with_variant(Variant::Naive),
    )?;
    let t_tiled =
        coord.submit(InterpolationRequest::new("bench", q2).with_variant(Variant::Tiled))?;
    t_naive.wait()?;
    t_tiled.wait()?;
    let coalesce_pair_ms = t0.elapsed().as_secs_f64() * 1e3;
    let m1 = coord.metrics();

    // cache pass: repeat the cold raster bit-identically
    let t1 = std::time::Instant::now();
    let warm = coord.interpolate(InterpolationRequest::new("bench", queries))?;
    let cache_hit_ms = t1.elapsed().as_secs_f64() * 1e3;
    let m2 = coord.metrics();
    if cold.values != warm.values {
        return Err(Error::Service(
            "cached raster diverged from the cold pass".into(),
        ));
    }
    Ok(PlannerMeasurement {
        n,
        stage1_ms: cold.knn_s * 1e3,
        stage2_ms: cold.interp_s * 1e3,
        coalesce_pair_ms,
        coalesce_stage1_execs: m1.stage1_execs - m0.stage1_execs,
        cache_hit_ms,
        cache_hits: m2.stage1_cache_hits - m1.stage1_cache_hits,
        cache_saved_ms: m2.stage1_saved_ms - m1.stage1_saved_ms,
    })
}

/// Mutated-dataset cache measurements at one size — the overlay-versioned
/// neighbor cache's win made measurable: on an **uncompacted** (mutated)
/// snapshot, a repeated identical raster must be served from the
/// `NeighborCache` instead of re-running the merged kNN sweep, and the
/// next mutation must invalidate exactly once.
#[derive(Debug, Clone, Copy)]
pub struct LiveCacheMeasurement {
    pub n: usize,
    /// Cold wall ms of one n-query raster on the mutated snapshot.
    pub mutated_cold_ms: f64,
    /// Wall ms of the identical repeat on the same overlay version.
    pub mutated_warm_ms: f64,
    /// Cache hits observed during the warm repeat (1 expected).
    pub warm_hits: u64,
    /// Stage-1 executions the post-mutation repeat ran (1 expected: the
    /// overlay version bump must retire the cached artifact).
    pub post_mutation_execs: u64,
    /// Warm-over-cold hit rate proxy: cold ms / warm ms (>= 1 when the
    /// cache wins; timing-noisy at small n).
    pub speedup: f64,
    /// Stage-1 wall ms the cache reported saved during the warm repeat
    /// (the merged sweep's recorded build time; ROADMAP PR-4(b)).
    pub saved_ms: f64,
}

/// Measure the mutated-dataset cache suite at one size (CPU-only
/// coordinator; warm values are asserted bit-identical to cold).
pub fn measure_live_cache(
    n: usize,
    opts: &MeasureOpts,
    threads: Option<usize>,
) -> Result<LiveCacheMeasurement> {
    use crate::coordinator::{Coordinator, CoordinatorConfig, EngineMode, InterpolationRequest};
    let cfg = CoordinatorConfig {
        engine_mode: EngineMode::CpuOnly,
        stage1_threads: threads,
        // the point of this suite is the *mutated* snapshot: a background
        // compaction folding the delta mid-measurement would undo it
        live: crate::live::LiveConfig { auto_compact: false, ..Default::default() },
        ..Default::default()
    };
    let coord = Coordinator::new(cfg)?;
    let (data, queries) = standard_workload(n, opts);
    coord.register_dataset("bench", data)?;
    // mutate: append a delta tail (and tombstone one point) so the
    // snapshot is uncompacted and stage 1 takes the merged path
    let delta = workload::uniform_square((n / 16).max(1), opts.side, opts.seed + 11);
    coord.append_points("bench", delta)?;
    coord.remove_points("bench", &[0])?;

    let t0 = std::time::Instant::now();
    let cold = coord.interpolate(InterpolationRequest::new("bench", queries.clone()))?;
    let mutated_cold_ms = t0.elapsed().as_secs_f64() * 1e3;
    if cold.stage1_cache_hit {
        return Err(Error::Service("cold mutated raster cannot be a cache hit".into()));
    }
    let m0 = coord.metrics();

    let t1 = std::time::Instant::now();
    let warm = coord.interpolate(InterpolationRequest::new("bench", queries.clone()))?;
    let mutated_warm_ms = t1.elapsed().as_secs_f64() * 1e3;
    let m1 = coord.metrics();
    if cold.values != warm.values {
        return Err(Error::Service(
            "cached mutated raster diverged from the cold pass".into(),
        ));
    }

    // one more mutation: the overlay version bump must force exactly one
    // stage-1 re-execution for the same raster
    coord.append_points("bench", workload::uniform_square(1, opts.side, opts.seed + 13))?;
    coord.interpolate(InterpolationRequest::new("bench", queries))?;
    let m2 = coord.metrics();

    Ok(LiveCacheMeasurement {
        n,
        mutated_cold_ms,
        mutated_warm_ms,
        warm_hits: m1.stage1_cache_hits - m0.stage1_cache_hits,
        post_mutation_execs: m2.stage1_execs - m1.stage1_execs,
        speedup: mutated_cold_ms / mutated_warm_ms.max(1e-9),
        saved_ms: m1.stage1_saved_ms - m0.stage1_saved_ms,
    })
}

/// Subscription measurements at one size — the dirty-tile win of the
/// incremental raster subscriptions (ROADMAP PR-6) made measurable: a
/// **localized** append against a standing raster must push only the
/// tiles the mutated point's kNN termination-bound footprint touches,
/// at a fraction of the cost of recomputing the whole raster.
#[derive(Debug, Clone, Copy)]
pub struct SubscribeMeasurement {
    pub n: usize,
    /// Wall ms to materialize the initial raster (update 0).
    pub initial_ms: f64,
    /// Wall ms from a localized one-point append to the applied
    /// incremental update (dirty tiles only).
    pub update_dirty_ms: f64,
    /// Wall ms of a from-scratch raster at the mutated snapshot — what
    /// the update avoided.
    pub full_recompute_ms: f64,
    /// Dirty tiles the update pushed.
    pub dirty_tiles: usize,
    /// Tiles the dirty-footprint bound proved clean (not recomputed).
    pub skipped_clean: usize,
}

/// Measure the subscription suite at one size (CPU-only coordinator,
/// exact-local options so the dirty-footprint fast path serves; the
/// incrementally-maintained raster is asserted bit-identical to a
/// from-scratch query at the mutated snapshot).
pub fn measure_subscribe(
    n: usize,
    opts: &MeasureOpts,
    threads: Option<usize>,
) -> Result<SubscribeMeasurement> {
    use crate::coordinator::{
        Coordinator, CoordinatorConfig, EngineMode, InterpolationRequest, QueryOptions,
    };
    let cfg = CoordinatorConfig {
        engine_mode: EngineMode::CpuOnly,
        stage1_threads: threads,
        // a background compaction mid-measurement would fold the delta
        // and change which execution path serves the update
        live: crate::live::LiveConfig { auto_compact: false, ..Default::default() },
        ..Default::default()
    };
    let coord = Coordinator::new(cfg)?;
    let (data, queries) = standard_workload(n, opts);
    coord.register_dataset("bench", data)?;
    // exact local mode + 16-way tiling: the configuration whose
    // termination bound lets clean tiles be proven clean.  k = 16 keeps
    // the Eq.-4 statistic saturated above r_max for uniform data, so a
    // far row's alpha survives the per-mutation r_exp drift bitwise —
    // with the default k = 10 a visible fraction of rows sits on the
    // alpha slope and every append would dirty them all.
    let options = QueryOptions::new()
        .k(16)
        .local_neighbors(32)
        .tile_rows((n / 16).max(1));

    let t0 = std::time::Instant::now();
    let mut sub = coord.subscribe(
        InterpolationRequest::new("bench", queries.clone()).with_options(options.clone()),
    )?;
    let initial = sub.next_update()?;
    let mut raster = vec![0.0f64; queries.len()];
    initial.apply(&mut raster);
    let initial_ms = t0.elapsed().as_secs_f64() * 1e3;

    // localized mutation: one point in a corner of the region, so most
    // tiles' reach bounds never see it
    let corner = PointSet::from_soa(
        vec![opts.side * 0.02],
        vec![opts.side * 0.02],
        vec![1.0],
    );
    let t1 = std::time::Instant::now();
    coord.append_points("bench", corner)?;
    let update = sub.next_update()?;
    update.apply(&mut raster);
    let update_dirty_ms = t1.elapsed().as_secs_f64() * 1e3;

    // what the update avoided: a from-scratch raster at the same snapshot
    let t2 = std::time::Instant::now();
    let full = coord.interpolate(
        InterpolationRequest::new("bench", queries).with_options(options),
    )?;
    let full_recompute_ms = t2.elapsed().as_secs_f64() * 1e3;
    if full.values != raster {
        return Err(Error::Service(
            "incrementally-maintained raster diverged from the from-scratch query".into(),
        ));
    }
    Ok(SubscribeMeasurement {
        n,
        initial_ms,
        update_dirty_ms,
        full_recompute_ms,
        dirty_tiles: update.tiles.len(),
        skipped_clean: update.skipped_clean,
    })
}

// ---- warmup + median-of-N wrappers (one per measure_* section) ---------

/// [`measure_size`] under the [`median_rep`] hygiene (primary time: the
/// improved tiled total, the headline number of the paper's Table 2).
pub fn measure_size_reps(
    engine: &Engine,
    pool: &Pool,
    n: usize,
    opts: &MeasureOpts,
) -> Result<SizeMeasurement> {
    median_rep(
        opts.warmup,
        opts.reps,
        || measure_size(engine, pool, n, opts),
        |m| m.improved_tiled.total_ms(),
    )
}

/// [`measure_size_cpu`] under the [`median_rep`] hygiene (primary time:
/// the exact-ring improved total).
pub fn measure_size_cpu_reps(pool: &Pool, n: usize, opts: &MeasureOpts) -> CpuSizeMeasurement {
    let r: std::result::Result<CpuSizeMeasurement, std::convert::Infallible> = median_rep(
        opts.warmup,
        opts.reps,
        || Ok(measure_size_cpu(pool, n, opts)),
        |m| m.improved_exact.total_ms(),
    );
    match r {
        Ok(m) => m,
        Err(e) => match e {},
    }
}

/// [`measure_planner`] under the [`median_rep`] hygiene (primary time:
/// the cold stage-1 + stage-2 sum).
pub fn measure_planner_reps(
    n: usize,
    opts: &MeasureOpts,
    threads: Option<usize>,
) -> Result<PlannerMeasurement> {
    median_rep(
        opts.warmup,
        opts.reps,
        || measure_planner(n, opts, threads),
        |m| m.stage1_ms + m.stage2_ms,
    )
}

/// [`measure_live_cache`] under the [`median_rep`] hygiene (primary
/// time: the cold mutated raster).
pub fn measure_live_cache_reps(
    n: usize,
    opts: &MeasureOpts,
    threads: Option<usize>,
) -> Result<LiveCacheMeasurement> {
    median_rep(
        opts.warmup,
        opts.reps,
        || measure_live_cache(n, opts, threads),
        |m| m.mutated_cold_ms,
    )
}

/// [`measure_subscribe`] under the [`median_rep`] hygiene (primary time:
/// the localized dirty update).
pub fn measure_subscribe_reps(
    n: usize,
    opts: &MeasureOpts,
    threads: Option<usize>,
) -> Result<SubscribeMeasurement> {
    median_rep(
        opts.warmup,
        opts.reps,
        || measure_subscribe(n, opts, threads),
        |m| m.update_dirty_ms,
    )
}

// ---- stage-2 layout ablation (PR 8 tentpole) ----------------------------

/// One layout's stage-2 times at one size.
#[derive(Debug, Clone)]
pub struct LayoutTimes {
    /// Wire tag ("aos" / "soa" / "aosoa:16").
    pub layout: String,
    /// Dense (all-points) stage-2 ms.
    pub dense_ms: f64,
    /// Local (A5, gathered-neighbor) stage-2 ms.
    pub local_ms: f64,
}

/// Stage-2 layout ablation at one size: the dense and local weighting
/// kernels under each [`crate::aidw::plan::Layout`], every non-AoS
/// result asserted **bit-identical** to the AoS reference before its
/// time is reported (a layout that broke the summation-order contract
/// would fail the bench, not just the tests).
#[derive(Debug, Clone)]
pub struct LayoutMeasurement {
    pub n: usize,
    /// In fixed aos / soa / aosoa:16 order.
    pub layouts: Vec<LayoutTimes>,
}

/// Measure the layout ablation at one size.  Stage 1 runs once per mode
/// (dense alphas; gathered table for local) outside the clock — only the
/// weighting stage differs between layouts, so only it is timed.
pub fn measure_layouts(pool: &Pool, n: usize, opts: &MeasureOpts) -> Result<LayoutMeasurement> {
    use crate::aidw::plan::{self, Layout, SearchKind, Stage1Plan};
    let params = AidwParams::default();
    let (data, queries) = standard_workload(n, opts);
    let grid = EvenGrid::build_on(pool, &data, None, &GridConfig::default())?;
    let area = data.bounds().area();
    let dense_art = Stage1Plan::new(
        params.k,
        RingRule::Exact,
        None,
        &params,
        data.len(),
        area,
        SearchKind::Grid,
    )
    .execute_grid(pool, &grid, &queries);
    let local_art = Stage1Plan::new(
        params.k,
        RingRule::Exact,
        Some(32usize.max(params.k)),
        &params,
        data.len(),
        area,
        SearchKind::Grid,
    )
    .execute_grid(pool, &grid, &queries);
    let table = local_art.neighbors.as_ref().expect("gathering plan produces a table");

    let dense_ref = crate::aidw::pipeline::weighted_stage_layout_on(
        pool,
        &data,
        &queries,
        dense_art.alphas(),
        Layout::Aos,
    );
    let local_ref = plan::local_weighted_layout_on(
        pool,
        &data,
        &queries,
        local_art.alphas(),
        table,
        Layout::Aos,
    );

    let mut layouts = Vec::new();
    for layout in [
        Layout::Aos,
        Layout::Soa,
        Layout::AosoaTiles { width: Layout::DEFAULT_AOSOA_WIDTH },
    ] {
        let (dense_ms, dense_out) = median_rep(
            opts.warmup,
            opts.reps,
            || -> Result<(f64, Vec<f64>)> {
                let t0 = std::time::Instant::now();
                let v = crate::aidw::pipeline::weighted_stage_layout_on(
                    pool,
                    &data,
                    &queries,
                    dense_art.alphas(),
                    layout,
                );
                Ok((t0.elapsed().as_secs_f64() * 1e3, v))
            },
            |r| r.0,
        )?;
        if dense_out != dense_ref {
            return Err(Error::Service(format!(
                "dense layout {} diverged bitwise from AoS",
                layout.tag()
            )));
        }
        let (local_ms, local_out) = median_rep(
            opts.warmup,
            opts.reps,
            || -> Result<(f64, Vec<f64>)> {
                let t0 = std::time::Instant::now();
                let v = plan::local_weighted_layout_on(
                    pool,
                    &data,
                    &queries,
                    local_art.alphas(),
                    table,
                    layout,
                );
                Ok((t0.elapsed().as_secs_f64() * 1e3, v))
            },
            |r| r.0,
        )?;
        if local_out != local_ref {
            return Err(Error::Service(format!(
                "local layout {} diverged bitwise from AoS",
                layout.tag()
            )));
        }
        layouts.push(LayoutTimes { layout: layout.tag(), dense_ms, local_ms });
    }
    Ok(LayoutMeasurement { n, layouts })
}

// ---- sharded stage-1 sweep (PR 10 tentpole) -----------------------------

/// One shard count's stage-1 time at one size.
#[derive(Debug, Clone)]
pub struct ShardTimes {
    /// Shard count the engine ran with.
    pub shards: usize,
    /// Full stage-1 sweep (scatter + per-shard kNN + gather) ms.
    pub stage1_ms: f64,
    /// Rows whose termination ball escaped the shard halo and re-ran
    /// cross-shard (per sweep).
    pub escalated: u64,
    /// Per-shard tasks the worker pool executed (per sweep).
    pub tasks: u64,
}

/// Sharded stage-1 ablation at one size: the same exact-ring sweep under
/// each shard count, every sharded artifact asserted **bit-identical**
/// to the unsharded reference before its time is reported — the bench
/// enforces the scatter/gather exactness contract, not just the tests.
#[derive(Debug, Clone)]
pub struct ShardMeasurement {
    pub n: usize,
    /// The unsharded (single-sweep) stage-1 reference ms.
    pub unsharded_ms: f64,
    /// In fixed 2 / 4 / 8 shard order.
    pub counts: Vec<ShardTimes>,
}

/// Measure the sharded stage-1 sweep at one size.  The dataset goes
/// through [`LiveDataset`] so the snapshot is the serving path's compacted
/// grid; gather width 32 exercises the neighbor-table merge path too.
pub fn measure_shards(pool: &Pool, n: usize, opts: &MeasureOpts) -> Result<ShardMeasurement> {
    use crate::aidw::plan::{SearchKind, Stage1Plan};
    use crate::live::{LiveConfig, LiveDataset};
    use crate::shard::{ShardEngine, TenantPolicy, TenantTag, DEFAULT_QUANTUM};
    use std::sync::Arc;

    let params = AidwParams::default();
    let (data, queries) = standard_workload(n, opts);
    let ds = LiveDataset::build(pool, "bench", data, &GridConfig::default(), None, LiveConfig::default())?;
    let snap = ds.snapshot();
    let queries = Arc::new(queries);
    let plan = Stage1Plan::new(
        params.k,
        RingRule::Exact,
        Some(32usize.max(params.k)),
        &params,
        snap.live_len,
        snap.area(),
        SearchKind::Grid,
    );
    let (unsharded_ms, want) = median_rep(
        opts.warmup,
        opts.reps,
        || -> Result<(f64, crate::aidw::plan::NeighborArtifact)> {
            let t0 = std::time::Instant::now();
            let art = plan.execute_grid(pool, &snap.base.grid, &queries);
            Ok((t0.elapsed().as_secs_f64() * 1e3, art))
        },
        |r| r.0,
    )?;
    let mut counts = Vec::new();
    for shards in [2usize, 4, 8] {
        let engine = ShardEngine::new(Some(shards), pool.threads(), DEFAULT_QUANTUM, TenantPolicy::default());
        let measured = median_rep(
            opts.warmup,
            opts.reps,
            || -> Result<(f64, crate::aidw::plan::NeighborArtifact, crate::shard::SweepStats)> {
                let t0 = std::time::Instant::now();
                let (art, stats) =
                    engine.execute_grid(&plan, &snap, &queries, pool, TenantTag::default());
                Ok((t0.elapsed().as_secs_f64() * 1e3, art, stats))
            },
            |r| r.0,
        );
        let (stage1_ms, art, stats) = match measured {
            Ok(m) => m,
            Err(e) => {
                engine.shutdown();
                return Err(e);
            }
        };
        engine.shutdown();
        if art.r_obs != want.r_obs
            || art.alphas() != want.alphas()
            || art.neighbors.as_ref().map(|t| (&t.idx, t.width))
                != want.neighbors.as_ref().map(|t| (&t.idx, t.width))
        {
            return Err(Error::Service(format!(
                "sharded stage 1 ({shards} shards) diverged bitwise from the unsharded sweep"
            )));
        }
        counts.push(ShardTimes {
            shards,
            stage1_ms,
            escalated: stats.escalated,
            tasks: stats.tasks,
        });
    }
    Ok(ShardMeasurement { n, unsharded_ms, counts })
}

/// The `shard` section of `BENCH_aidw.json`.
fn shard_json(shards: &[ShardMeasurement]) -> Json {
    Json::Arr(
        shards
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("n", Json::Num(m.n as f64)),
                    ("label", Json::Str(size_label(m.n))),
                    ("unsharded_stage1_ms", Json::Num(m.unsharded_ms)),
                    (
                        "counts",
                        Json::Arr(
                            m.counts
                                .iter()
                                .map(|s| {
                                    Json::obj(vec![
                                        ("shards", Json::Num(s.shards as f64)),
                                        ("stage1_ms", Json::Num(s.stage1_ms)),
                                        ("escalated_rows", Json::Num(s.escalated as f64)),
                                        ("shard_tasks", Json::Num(s.tasks as f64)),
                                        (
                                            "speedup",
                                            Json::Num(m.unsharded_ms / s.stage1_ms.max(1e-9)),
                                        ),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

/// The `layout` section of `BENCH_aidw.json`.
fn layout_json(layouts: &[LayoutMeasurement]) -> Json {
    Json::Arr(
        layouts
            .iter()
            .map(|m| {
                Json::obj(vec![
                    ("n", Json::Num(m.n as f64)),
                    ("label", Json::Str(size_label(m.n))),
                    (
                        "layouts",
                        Json::Arr(
                            m.layouts
                                .iter()
                                .map(|l| {
                                    Json::obj(vec![
                                        ("layout", Json::Str(l.layout.clone())),
                                        ("dense_stage2_ms", Json::Num(l.dense_ms)),
                                        ("local_stage2_ms", Json::Num(l.local_ms)),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                ])
            })
            .collect(),
    )
}

/// The `subscribe` section of `BENCH_aidw.json`.
fn subscribe_json(subs: &[SubscribeMeasurement]) -> Json {
    Json::Arr(
        subs.iter()
            .map(|s| {
                Json::obj(vec![
                    ("n", Json::Num(s.n as f64)),
                    ("label", Json::Str(size_label(s.n))),
                    ("initial_ms", Json::Num(s.initial_ms)),
                    ("update_dirty_ms", Json::Num(s.update_dirty_ms)),
                    ("full_recompute_ms", Json::Num(s.full_recompute_ms)),
                    ("dirty_tiles", Json::Num(s.dirty_tiles as f64)),
                    ("skipped_clean", Json::Num(s.skipped_clean as f64)),
                    (
                        "speedup",
                        Json::Num(s.full_recompute_ms / s.update_dirty_ms.max(1e-9)),
                    ),
                ])
            })
            .collect(),
    )
}

/// The `live_cache` section of `BENCH_aidw.json`.
fn live_cache_json(live: &[LiveCacheMeasurement]) -> Json {
    Json::Arr(
        live.iter()
            .map(|l| {
                Json::obj(vec![
                    ("n", Json::Num(l.n as f64)),
                    ("label", Json::Str(size_label(l.n))),
                    ("mutated_cold_ms", Json::Num(l.mutated_cold_ms)),
                    ("mutated_warm_ms", Json::Num(l.mutated_warm_ms)),
                    ("warm_hits", Json::Num(l.warm_hits as f64)),
                    (
                        "post_mutation_execs",
                        Json::Num(l.post_mutation_execs as f64),
                    ),
                    ("speedup", Json::Num(l.speedup)),
                    ("stage1_saved_ms", Json::Num(l.saved_ms)),
                ])
            })
            .collect(),
    )
}

/// The `planner` section of `BENCH_aidw.json`.
fn planner_json(planner: &[PlannerMeasurement]) -> Json {
    Json::Arr(
        planner
            .iter()
            .map(|p| {
                Json::obj(vec![
                    ("n", Json::Num(p.n as f64)),
                    ("label", Json::Str(size_label(p.n))),
                    ("stage1_ms", Json::Num(p.stage1_ms)),
                    ("stage2_ms", Json::Num(p.stage2_ms)),
                    ("coalesce_pair_ms", Json::Num(p.coalesce_pair_ms)),
                    (
                        "coalesce_stage1_execs",
                        Json::Num(p.coalesce_stage1_execs as f64),
                    ),
                    ("cache_hit_ms", Json::Num(p.cache_hit_ms)),
                    ("cache_hits", Json::Num(p.cache_hits as f64)),
                    ("stage1_saved_ms", Json::Num(p.cache_saved_ms)),
                ])
            })
            .collect(),
    )
}

fn variant_json(v: &VariantTimes) -> Json {
    Json::obj(vec![
        ("knn_ms", Json::Num(v.knn_ms)),
        ("interp_ms", Json::Num(v.interp_ms)),
        ("total_ms", Json::Num(v.total_ms())),
    ])
}

/// `BENCH_aidw.json` document for a CPU-only run: sizes × variants ×
/// stage times plus the planner section (stage1/stage2/coalesce/
/// cache-hit) and the mutated-dataset cache section, self-describing
/// enough to diff across PRs.
#[allow(clippy::too_many_arguments)]
pub fn cpu_bench_json(
    results: &[CpuSizeMeasurement],
    planner: &[PlannerMeasurement],
    live_cache: &[LiveCacheMeasurement],
    subscribe: &[SubscribeMeasurement],
    layouts: &[LayoutMeasurement],
    shards: &[ShardMeasurement],
    threads: usize,
    seed: u64,
) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("aidw".into())),
        ("backend", Json::Str("cpu".into())),
        ("threads", Json::Num(threads as f64)),
        ("seed", Json::Num(seed as f64)),
        // the measurements run with the library defaults
        ("k", Json::Num(AidwParams::default().k as f64)),
        ("planner", planner_json(planner)),
        ("live_cache", live_cache_json(live_cache)),
        ("subscribe", subscribe_json(subscribe)),
        ("layout", layout_json(layouts)),
        ("shard", shard_json(shards)),
        (
            "sizes",
            Json::Arr(
                results
                    .iter()
                    .map(|m| {
                        let mut fields = vec![
                            ("n", Json::Num(m.n as f64)),
                            ("label", Json::Str(size_label(m.n))),
                        ];
                        if let Some(s) = m.serial_ms {
                            fields.push(("serial_ms", Json::Num(s)));
                            fields.push((
                                "serial_extrapolated",
                                Json::Bool(m.serial_extrapolated),
                            ));
                        }
                        fields.push((
                            "variants",
                            Json::obj(vec![
                                ("improved_exact", variant_json(&m.improved_exact)),
                                ("improved_paper1", variant_json(&m.improved_paper1)),
                            ]),
                        ));
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// `BENCH_aidw.json` document for a full PJRT run (all five paper
/// versions per size, plus the planner and mutated-dataset cache
/// sections).
#[allow(clippy::too_many_arguments)]
pub fn pjrt_bench_json(
    results: &[SizeMeasurement],
    planner: &[PlannerMeasurement],
    live_cache: &[LiveCacheMeasurement],
    subscribe: &[SubscribeMeasurement],
    layouts: &[LayoutMeasurement],
    shards: &[ShardMeasurement],
    threads: usize,
    seed: u64,
) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("aidw".into())),
        ("backend", Json::Str("pjrt".into())),
        ("threads", Json::Num(threads as f64)),
        ("seed", Json::Num(seed as f64)),
        // the measurements run with the library defaults
        ("k", Json::Num(AidwParams::default().k as f64)),
        ("planner", planner_json(planner)),
        ("live_cache", live_cache_json(live_cache)),
        ("subscribe", subscribe_json(subscribe)),
        ("layout", layout_json(layouts)),
        ("shard", shard_json(shards)),
        (
            "sizes",
            Json::Arr(
                results
                    .iter()
                    .map(|m| {
                        let mut fields = vec![
                            ("n", Json::Num(m.n as f64)),
                            ("label", Json::Str(size_label(m.n))),
                        ];
                        if let Some(s) = m.serial_ms {
                            fields.push(("serial_ms", Json::Num(s)));
                            fields.push((
                                "serial_extrapolated",
                                Json::Bool(m.serial_extrapolated),
                            ));
                        }
                        fields.push((
                            "variants",
                            Json::obj(vec![
                                ("original_naive", variant_json(&m.original_naive)),
                                ("original_tiled", variant_json(&m.original_tiled)),
                                ("improved_naive", variant_json(&m.improved_naive)),
                                ("improved_tiled", variant_json(&m.improved_tiled)),
                            ]),
                        ));
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Standard bench header printed by every table/figure bench.
pub fn print_header(title: &str, sizes: &[usize]) {
    println!("\n=== {title} ===");
    println!(
        "workload: n = m, uniform square, k = 10, single-precision PJRT \
         (CPU) vs f64 serial"
    );
    println!(
        "sizes: {}",
        sizes.iter().map(|&n| size_label(n)).collect::<Vec<_>>().join(", ")
    );
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_labels() {
        assert_eq!(size_label(10 * 1024), "10K");
        assert_eq!(size_label(1000 * 1024), "1000K");
        assert_eq!(size_label(1000), "1000");
    }

    #[test]
    fn serial_measurement_extrapolates() {
        let opts = MeasureOpts::default();
        let (data, queries) = standard_workload(512, &opts);
        let params = AidwParams::default();
        let (full_ms, ex_full) = measure_serial(&data, &queries, &params, 4096);
        assert!(!ex_full);
        let (sub_ms, ex_sub) = measure_serial(&data, &queries, &params, 128);
        assert!(ex_sub);
        // extrapolation should land in the same ballpark (loose: timing)
        assert!(sub_ms > 0.1 * full_ms && sub_ms < 10.0 * full_ms,
                "sub {sub_ms} vs full {full_ms}");
    }

    #[test]
    fn standard_workload_shapes() {
        let opts = MeasureOpts::default();
        let (d, q) = standard_workload(100, &opts);
        assert_eq!(d.len(), 100);
        assert_eq!(q.len(), 100);
    }

    #[test]
    fn median_rep_returns_the_median_run_after_warmup() {
        let mut calls = 0u32;
        // times 30, 10, 20 after one discarded warmup -> median run is 20
        let times = [99.0, 30.0, 10.0, 20.0];
        let got: std::result::Result<f64, std::convert::Infallible> =
            median_rep(1, 3, || { let t = times[calls as usize]; calls += 1; Ok(t) }, |t| *t);
        assert_eq!(calls, 4, "1 warmup + 3 reps");
        assert_eq!(got.unwrap(), 20.0);
        // reps = 0 still measures once
        let one: std::result::Result<f64, std::convert::Infallible> =
            median_rep(0, 0, || Ok(7.0), |t| *t);
        assert_eq!(one.unwrap(), 7.0);
    }

    #[test]
    fn cpu_suite_measures_and_serializes() {
        let pool = Pool::new(2);
        // keep the suite test fast: single rep, no warmup (the hygiene
        // path itself is covered above)
        let opts =
            MeasureOpts { serial_sub_cap: 64, reps: 1, warmup: 0, ..Default::default() };
        let sizes = [256usize, 512];
        let results: Vec<CpuSizeMeasurement> =
            sizes.iter().map(|&n| measure_size_cpu_reps(&pool, n, &opts)).collect();
        for m in &results {
            assert!(m.serial_ms.unwrap() > 0.0);
            assert!(m.improved_exact.total_ms() > 0.0);
            assert!(m.improved_paper1.total_ms() > 0.0);
        }
        let planner: Vec<PlannerMeasurement> = sizes
            .iter()
            .map(|&n| measure_planner_reps(n, &opts, Some(2)).unwrap())
            .collect();
        for p in &planner {
            assert!(p.stage2_ms > 0.0);
            assert_eq!(p.coalesce_stage1_execs, 1, "pair must share one stage-1");
            assert_eq!(p.cache_hits, 1, "repeat raster must hit the cache");
            assert!(p.cache_saved_ms >= 0.0, "saved-time counter is wired");
        }
        let live: Vec<LiveCacheMeasurement> = sizes
            .iter()
            .map(|&n| measure_live_cache_reps(n, &opts, Some(2)).unwrap())
            .collect();
        for l in &live {
            assert_eq!(l.warm_hits, 1, "mutated repeat raster must hit the cache");
            assert_eq!(l.post_mutation_execs, 1, "a mutation must invalidate exactly once");
        }
        let subs: Vec<SubscribeMeasurement> = sizes
            .iter()
            .map(|&n| measure_subscribe_reps(n, &opts, Some(2)).unwrap())
            .collect();
        for s in &subs {
            assert!(s.dirty_tiles >= 1, "the mutated corner tile must be pushed");
            assert!(
                s.skipped_clean >= 1,
                "a localized append must leave some tile provably clean"
            );
        }
        let layouts: Vec<LayoutMeasurement> = sizes
            .iter()
            .map(|&n| measure_layouts(&pool, n, &opts).unwrap())
            .collect();
        for m in &layouts {
            assert_eq!(m.layouts.len(), 3, "aos, soa, aosoa:16");
            assert_eq!(m.layouts[0].layout, "aos");
            assert_eq!(m.layouts[1].layout, "soa");
            assert_eq!(m.layouts[2].layout, "aosoa:16");
            for l in &m.layouts {
                assert!(l.dense_ms > 0.0 && l.local_ms > 0.0, "{}", l.layout);
            }
        }
        let shard: Vec<ShardMeasurement> = sizes
            .iter()
            .map(|&n| measure_shards(&pool, n, &opts).unwrap())
            .collect();
        for m in &shard {
            assert!(m.unsharded_ms > 0.0);
            assert_eq!(
                m.counts.iter().map(|s| s.shards).collect::<Vec<_>>(),
                vec![2, 4, 8]
            );
            for s in &m.counts {
                // bit-identity already asserted inside the measurement;
                // here: the sharded path really ran (it produced tasks)
                assert!(s.stage1_ms > 0.0 && s.tasks > 0, "{} shards", s.shards);
            }
        }
        let doc = cpu_bench_json(
            &results,
            &planner,
            &live,
            &subs,
            &layouts,
            &shard,
            pool.threads(),
            opts.seed,
        );
        let text = doc.to_string();
        // round-trips as JSON and carries the schema the perf trajectory
        // tooling greps for
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("bench").as_str(), Some("aidw"));
        assert_eq!(back.get("backend").as_str(), Some("cpu"));
        let arr = back.get("sizes").as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("n").as_usize(), Some(256));
        assert!(arr[0]
            .get("variants")
            .get("improved_exact")
            .get("knn_ms")
            .as_f64()
            .is_some());
        let pj = back.get("planner").as_arr().unwrap();
        assert_eq!(pj.len(), 2);
        assert_eq!(pj[0].get("coalesce_stage1_execs").as_usize(), Some(1));
        assert_eq!(pj[0].get("cache_hits").as_usize(), Some(1));
        assert!(pj[0].get("stage1_ms").as_f64().is_some());
        let lc = back.get("live_cache").as_arr().unwrap();
        assert_eq!(lc.len(), 2);
        assert_eq!(lc[0].get("warm_hits").as_usize(), Some(1));
        assert_eq!(lc[0].get("post_mutation_execs").as_usize(), Some(1));
        assert!(lc[0].get("mutated_warm_ms").as_f64().is_some());
        assert!(lc[0].get("stage1_saved_ms").as_f64().is_some());
        assert!(pj[0].get("stage1_saved_ms").as_f64().is_some());
        let sj = back.get("subscribe").as_arr().unwrap();
        assert_eq!(sj.len(), 2);
        assert!(sj[0].get("update_dirty_ms").as_f64().is_some());
        assert!(sj[0].get("full_recompute_ms").as_f64().is_some());
        assert!(sj[0].get("skipped_clean").as_usize().unwrap() >= 1);
        let ly = back.get("layout").as_arr().unwrap();
        assert_eq!(ly.len(), 2);
        let per = ly[0].get("layouts").as_arr().unwrap();
        assert_eq!(per.len(), 3);
        assert_eq!(per[1].get("layout").as_str(), Some("soa"));
        assert!(per[1].get("dense_stage2_ms").as_f64().is_some());
        assert!(per[1].get("local_stage2_ms").as_f64().is_some());
        let sh = back.get("shard").as_arr().unwrap();
        assert_eq!(sh.len(), 2);
        assert!(sh[0].get("unsharded_stage1_ms").as_f64().is_some());
        let per_count = sh[0].get("counts").as_arr().unwrap();
        assert_eq!(per_count.len(), 3);
        assert_eq!(per_count[1].get("shards").as_usize(), Some(4));
        assert!(per_count[1].get("stage1_ms").as_f64().is_some());
        assert!(per_count[1].get("escalated_rows").as_usize().is_some());
        assert!(per_count[1].get("shard_tasks").as_usize().is_some());
    }
}
