//! Shared measurement logic for the paper-reproduction benches.
//!
//! Every bench binary under `rust/benches/` needs the same five
//! measurements the paper's §5 takes at each problem size (n = m points,
//! uniform square, k = 10):
//!
//! * CPU serial AIDW (f64)                         — Table 1 baseline
//! * original algorithm, naive + tiled             — brute kNN on PJRT
//! * improved algorithm, naive + tiled             — grid kNN + PJRT
//!
//! with each run split into its kNN and interpolation stages.  This module
//! measures them once; the per-table benches format the slices they need.
//!
//! **Serial extrapolation**: the paper's serial baseline at 1000K took
//! 18.7 hours; on this testbed we measure a query subsample and scale by
//! the O(n·m) query ratio (exact for this embarrassingly parallel loop).
//! The subsample cap is configurable and the extrapolation is flagged in
//! the output.

use crate::aidw::params::AidwParams;
use crate::aidw::serial;
use crate::error::Result;
use crate::geom::PointSet;
use crate::grid::{EvenGrid, GridConfig};
use crate::jsonio::Json;
use crate::knn::grid_knn::{grid_knn_avg_distances_on, GridKnnConfig, RingRule};
use crate::pool::Pool;
use crate::runtime::{AidwExecutor, Engine, Variant};
use crate::workload;

/// Stage times of one algorithm variant at one size (milliseconds).
#[derive(Debug, Clone, Copy, Default)]
pub struct VariantTimes {
    pub knn_ms: f64,
    pub interp_ms: f64,
}

impl VariantTimes {
    pub fn total_ms(&self) -> f64 {
        self.knn_ms + self.interp_ms
    }
}

/// All five measurements at one problem size.
#[derive(Debug, Clone, Copy)]
pub struct SizeMeasurement {
    /// n = m (data points = interpolated points).
    pub n: usize,
    /// Serial baseline (ms); None when skipped.  `serial_extrapolated`
    /// notes whether it was scaled from a query subsample.
    pub serial_ms: Option<f64>,
    pub serial_extrapolated: bool,
    pub original_naive: VariantTimes,
    pub original_tiled: VariantTimes,
    pub improved_naive: VariantTimes,
    pub improved_tiled: VariantTimes,
}

/// Measurement options.
#[derive(Debug, Clone, Copy)]
pub struct MeasureOpts {
    /// Measure the serial baseline (skippable for kNN-only benches).
    pub serial: bool,
    /// Serial query-subsample cap (extrapolated above this).
    pub serial_sub_cap: usize,
    /// Workload seed.
    pub seed: u64,
    /// Region side length.
    pub side: f64,
}

impl Default for MeasureOpts {
    fn default() -> Self {
        MeasureOpts { serial: true, serial_sub_cap: 2048, seed: 42, side: 100.0 }
    }
}

/// The paper's size label ("10K" = 10*1024 points).
pub fn size_label(n: usize) -> String {
    if n % 1024 == 0 {
        format!("{}K", n / 1024)
    } else {
        format!("{n}")
    }
}

/// The standard workload at size n (paper §5.1: n = m, uniform square).
pub fn standard_workload(n: usize, opts: &MeasureOpts) -> (PointSet, Vec<(f64, f64)>) {
    let data = workload::uniform_square(n, opts.side, opts.seed);
    let queries = workload::uniform_square(n, opts.side, opts.seed + 1).xy();
    (data, queries)
}

/// Serial AIDW time (ms), extrapolating from a query subsample when the
/// problem exceeds `sub_cap`.  Returns (ms, extrapolated?).
pub fn measure_serial(
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    sub_cap: usize,
) -> (f64, bool) {
    let sub = queries.len().min(sub_cap.max(1));
    let t0 = std::time::Instant::now();
    let out = serial::aidw_serial(data, &queries[..sub], params);
    let dt = t0.elapsed().as_secs_f64();
    std::hint::black_box(out);
    let scale = queries.len() as f64 / sub as f64;
    (dt * scale * 1e3, sub < queries.len())
}

/// One variant of the *original* algorithm (brute-force kNN on PJRT).
pub fn measure_original(
    exec: &AidwExecutor,
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    variant: Variant,
) -> Result<VariantTimes> {
    let (out, times) = exec.original_aidw(data, queries, params, variant)?;
    std::hint::black_box(out);
    Ok(VariantTimes { knn_ms: times.knn_s * 1e3, interp_ms: times.interp_s * 1e3 })
}

/// One variant of the *improved* algorithm: rust grid kNN (stage 1)
/// + PJRT alpha/interpolation (stage 2).  Grid build time is included in
/// the kNN stage, as in the paper.
pub fn measure_improved(
    pool: &Pool,
    exec: &AidwExecutor,
    data: &PointSet,
    queries: &[(f64, f64)],
    params: &AidwParams,
    variant: Variant,
) -> Result<VariantTimes> {
    let t0 = std::time::Instant::now();
    let grid = EvenGrid::build_on(pool, data, None, &GridConfig::default())?;
    let (r_obs, _) = grid_knn_avg_distances_on(
        pool,
        &grid,
        queries,
        &GridKnnConfig { k: params.k, rule: RingRule::Exact },
    );
    let grid_knn_s = t0.elapsed().as_secs_f64();
    let (out, times) = exec.improved_aidw(data, queries, &r_obs, params, variant)?;
    std::hint::black_box(out);
    Ok(VariantTimes {
        knn_ms: (grid_knn_s + times.knn_s) * 1e3,
        interp_ms: times.interp_s * 1e3,
    })
}

/// Measure all five versions at one size.
pub fn measure_size(
    engine: &Engine,
    pool: &Pool,
    n: usize,
    opts: &MeasureOpts,
) -> Result<SizeMeasurement> {
    let params = AidwParams::default();
    let (data, queries) = standard_workload(n, opts);
    let exec = AidwExecutor::new(engine);
    exec.warmup()?;

    let (serial_ms, serial_extrapolated) = if opts.serial {
        let (ms, ex) = measure_serial(&data, &queries, &params, opts.serial_sub_cap);
        (Some(ms), ex)
    } else {
        (None, false)
    };

    Ok(SizeMeasurement {
        n,
        serial_ms,
        serial_extrapolated,
        original_naive: measure_original(&exec, &data, &queries, &params, Variant::Naive)?,
        original_tiled: measure_original(&exec, &data, &queries, &params, Variant::Tiled)?,
        improved_naive: measure_improved(pool, &exec, &data, &queries, &params, Variant::Naive)?,
        improved_tiled: measure_improved(pool, &exec, &data, &queries, &params, Variant::Tiled)?,
    })
}

/// CPU-only measurements at one size — what the `aidw bench` subcommand
/// runs on artifact-free testbeds: the serial baseline plus the pure-rust
/// improved pipeline under both ring rules, stage-split.
#[derive(Debug, Clone, Copy)]
pub struct CpuSizeMeasurement {
    pub n: usize,
    pub serial_ms: Option<f64>,
    pub serial_extrapolated: bool,
    pub improved_exact: VariantTimes,
    pub improved_paper1: VariantTimes,
}

/// Measure the CPU-only suite at one size.
pub fn measure_size_cpu(pool: &Pool, n: usize, opts: &MeasureOpts) -> CpuSizeMeasurement {
    let params = AidwParams::default();
    let (data, queries) = standard_workload(n, opts);
    let (serial_ms, serial_extrapolated) = if opts.serial {
        let (ms, ex) = measure_serial(&data, &queries, &params, opts.serial_sub_cap);
        (Some(ms), ex)
    } else {
        (None, false)
    };
    let run = |rule: RingRule| -> VariantTimes {
        let (out, times) =
            crate::aidw::pipeline::interpolate_improved_on(pool, &data, &queries, &params, rule);
        std::hint::black_box(out);
        VariantTimes { knn_ms: times.knn_s * 1e3, interp_ms: times.interp_s * 1e3 }
    };
    CpuSizeMeasurement {
        n,
        serial_ms,
        serial_extrapolated,
        improved_exact: run(RingRule::Exact),
        improved_paper1: run(RingRule::PaperPlusOne),
    }
}

fn variant_json(v: &VariantTimes) -> Json {
    Json::obj(vec![
        ("knn_ms", Json::Num(v.knn_ms)),
        ("interp_ms", Json::Num(v.interp_ms)),
        ("total_ms", Json::Num(v.total_ms())),
    ])
}

/// `BENCH_aidw.json` document for a CPU-only run: sizes × variants ×
/// stage times, self-describing enough to diff across PRs.
pub fn cpu_bench_json(results: &[CpuSizeMeasurement], threads: usize, seed: u64) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("aidw".into())),
        ("backend", Json::Str("cpu".into())),
        ("threads", Json::Num(threads as f64)),
        ("seed", Json::Num(seed as f64)),
        // the measurements run with the library defaults
        ("k", Json::Num(AidwParams::default().k as f64)),
        (
            "sizes",
            Json::Arr(
                results
                    .iter()
                    .map(|m| {
                        let mut fields = vec![
                            ("n", Json::Num(m.n as f64)),
                            ("label", Json::Str(size_label(m.n))),
                        ];
                        if let Some(s) = m.serial_ms {
                            fields.push(("serial_ms", Json::Num(s)));
                            fields.push((
                                "serial_extrapolated",
                                Json::Bool(m.serial_extrapolated),
                            ));
                        }
                        fields.push((
                            "variants",
                            Json::obj(vec![
                                ("improved_exact", variant_json(&m.improved_exact)),
                                ("improved_paper1", variant_json(&m.improved_paper1)),
                            ]),
                        ));
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// `BENCH_aidw.json` document for a full PJRT run (all five paper
/// versions per size).
pub fn pjrt_bench_json(results: &[SizeMeasurement], threads: usize, seed: u64) -> Json {
    Json::obj(vec![
        ("bench", Json::Str("aidw".into())),
        ("backend", Json::Str("pjrt".into())),
        ("threads", Json::Num(threads as f64)),
        ("seed", Json::Num(seed as f64)),
        // the measurements run with the library defaults
        ("k", Json::Num(AidwParams::default().k as f64)),
        (
            "sizes",
            Json::Arr(
                results
                    .iter()
                    .map(|m| {
                        let mut fields = vec![
                            ("n", Json::Num(m.n as f64)),
                            ("label", Json::Str(size_label(m.n))),
                        ];
                        if let Some(s) = m.serial_ms {
                            fields.push(("serial_ms", Json::Num(s)));
                            fields.push((
                                "serial_extrapolated",
                                Json::Bool(m.serial_extrapolated),
                            ));
                        }
                        fields.push((
                            "variants",
                            Json::obj(vec![
                                ("original_naive", variant_json(&m.original_naive)),
                                ("original_tiled", variant_json(&m.original_tiled)),
                                ("improved_naive", variant_json(&m.improved_naive)),
                                ("improved_tiled", variant_json(&m.improved_tiled)),
                            ]),
                        ));
                        Json::obj(fields)
                    })
                    .collect(),
            ),
        ),
    ])
}

/// Standard bench header printed by every table/figure bench.
pub fn print_header(title: &str, sizes: &[usize]) {
    println!("\n=== {title} ===");
    println!(
        "workload: n = m, uniform square, k = 10, single-precision PJRT \
         (CPU) vs f64 serial"
    );
    println!(
        "sizes: {}",
        sizes.iter().map(|&n| size_label(n)).collect::<Vec<_>>().join(", ")
    );
    println!();
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn size_labels() {
        assert_eq!(size_label(10 * 1024), "10K");
        assert_eq!(size_label(1000 * 1024), "1000K");
        assert_eq!(size_label(1000), "1000");
    }

    #[test]
    fn serial_measurement_extrapolates() {
        let opts = MeasureOpts::default();
        let (data, queries) = standard_workload(512, &opts);
        let params = AidwParams::default();
        let (full_ms, ex_full) = measure_serial(&data, &queries, &params, 4096);
        assert!(!ex_full);
        let (sub_ms, ex_sub) = measure_serial(&data, &queries, &params, 128);
        assert!(ex_sub);
        // extrapolation should land in the same ballpark (loose: timing)
        assert!(sub_ms > 0.1 * full_ms && sub_ms < 10.0 * full_ms,
                "sub {sub_ms} vs full {full_ms}");
    }

    #[test]
    fn standard_workload_shapes() {
        let opts = MeasureOpts::default();
        let (d, q) = standard_workload(100, &opts);
        assert_eq!(d.len(), 100);
        assert_eq!(q.len(), 100);
    }

    #[test]
    fn cpu_suite_measures_and_serializes() {
        let pool = Pool::new(2);
        let opts = MeasureOpts { serial_sub_cap: 64, ..Default::default() };
        let sizes = [256usize, 512];
        let results: Vec<CpuSizeMeasurement> =
            sizes.iter().map(|&n| measure_size_cpu(&pool, n, &opts)).collect();
        for m in &results {
            assert!(m.serial_ms.unwrap() > 0.0);
            assert!(m.improved_exact.total_ms() > 0.0);
            assert!(m.improved_paper1.total_ms() > 0.0);
        }
        let doc = cpu_bench_json(&results, pool.threads(), opts.seed);
        let text = doc.to_string();
        // round-trips as JSON and carries the schema the perf trajectory
        // tooling greps for
        let back = Json::parse(&text).unwrap();
        assert_eq!(back.get("bench").as_str(), Some("aidw"));
        assert_eq!(back.get("backend").as_str(), Some("cpu"));
        let arr = back.get("sizes").as_arr().unwrap();
        assert_eq!(arr.len(), 2);
        assert_eq!(arr[0].get("n").as_usize(), Some(256));
        assert!(arr[0]
            .get("variants")
            .get("improved_exact")
            .get("knn_ms")
            .as_f64()
            .is_some());
    }
}
