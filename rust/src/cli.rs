//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Grammar: `aidw <subcommand> [--flag value|--switch] ...`.  Flags are
//! declared per subcommand in `main.rs`; unknown flags are errors.

use std::collections::HashMap;

use crate::error::{Error, Result};

/// Parsed command line: a subcommand plus `--key value` / `--switch` flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub subcommand: String,
    flags: HashMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse `args` (without argv[0]).  `switch_names` lists flags that
    /// take no value.
    pub fn parse(args: &[String], switch_names: &[&str]) -> Result<Args> {
        let mut out = Args::default();
        let mut i = 0;
        if i < args.len() && !args[i].starts_with("--") {
            out.subcommand = args[i].clone();
            i += 1;
        }
        while i < args.len() {
            let a = &args[i];
            let Some(name) = a.strip_prefix("--") else {
                return Err(Error::InvalidArgument(format!("unexpected positional '{a}'")));
            };
            if switch_names.contains(&name) {
                out.switches.push(name.to_string());
                i += 1;
            } else {
                let val = args
                    .get(i + 1)
                    .ok_or_else(|| Error::InvalidArgument(format!("--{name} needs a value")))?;
                out.flags.insert(name.to_string(), val.clone());
                i += 2;
            }
        }
        Ok(out)
    }

    /// String flag.
    pub fn get(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    /// String flag with default.
    pub fn get_or(&self, name: &str, default: &str) -> String {
        self.get(name).unwrap_or(default).to_string()
    }

    /// Numeric flag with default.
    pub fn get_usize(&self, name: &str, default: usize) -> Result<usize> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::InvalidArgument(format!("--{name} expects an integer, got '{v}'"))),
        }
    }

    /// f64 flag with default.
    pub fn get_f64(&self, name: &str, default: f64) -> Result<f64> {
        match self.get(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| Error::InvalidArgument(format!("--{name} expects a number, got '{v}'"))),
        }
    }

    /// Comma-separated f64 list flag (e.g. `--alpha-levels 0.5,1,2,3,4`);
    /// None when the flag is absent.
    pub fn get_f64_list(&self, name: &str) -> Result<Option<Vec<f64>>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse::<f64>().map_err(|_| {
                        Error::InvalidArgument(format!(
                            "--{name} expects comma-separated numbers, got '{v}'"
                        ))
                    })
                })
                .collect::<Result<Vec<f64>>>()
                .map(Some),
        }
    }

    /// Comma-separated u64 list flag (e.g. `--ids 3,17,9000`); None when
    /// the flag is absent.
    pub fn get_u64_list(&self, name: &str) -> Result<Option<Vec<u64>>> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .split(',')
                .map(|s| {
                    s.trim().parse::<u64>().map_err(|_| {
                        Error::InvalidArgument(format!(
                            "--{name} expects comma-separated non-negative integers, got '{v}'"
                        ))
                    })
                })
                .collect::<Result<Vec<u64>>>()
                .map(Some),
        }
    }

    /// Boolean switch presence.
    pub fn has(&self, name: &str) -> bool {
        self.switches.iter().any(|s| s == name)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sv(xs: &[&str]) -> Vec<String> {
        xs.iter().map(|s| s.to_string()).collect()
    }

    #[test]
    fn parses_subcommand_flags_switches() {
        let a = Args::parse(&sv(&["serve", "--port", "9000", "--verbose"]), &["verbose"]).unwrap();
        assert_eq!(a.subcommand, "serve");
        assert_eq!(a.get("port"), Some("9000"));
        assert!(a.has("verbose"));
        assert!(!a.has("quiet"));
        assert_eq!(a.get_usize("port", 1).unwrap(), 9000);
    }

    #[test]
    fn defaults_apply() {
        let a = Args::parse(&sv(&["run"]), &[]).unwrap();
        assert_eq!(a.get_or("mode", "tiled"), "tiled");
        assert_eq!(a.get_usize("n", 7).unwrap(), 7);
        assert_eq!(a.get_f64("x", 2.5).unwrap(), 2.5);
    }

    #[test]
    fn f64_list_parses() {
        let a = Args::parse(&sv(&["x", "--alpha-levels", "0.5,1, 2,3,4"]), &[]).unwrap();
        assert_eq!(
            a.get_f64_list("alpha-levels").unwrap(),
            Some(vec![0.5, 1.0, 2.0, 3.0, 4.0])
        );
        assert_eq!(a.get_f64_list("missing").unwrap(), None);
        let bad = Args::parse(&sv(&["x", "--alpha-levels", "1,oops"]), &[]).unwrap();
        assert!(bad.get_f64_list("alpha-levels").is_err());
    }

    #[test]
    fn u64_list_parses() {
        let a = Args::parse(&sv(&["x", "--ids", "3, 17,9000"]), &[]).unwrap();
        assert_eq!(a.get_u64_list("ids").unwrap(), Some(vec![3, 17, 9000]));
        assert_eq!(a.get_u64_list("missing").unwrap(), None);
        let bad = Args::parse(&sv(&["x", "--ids", "1,-2"]), &[]).unwrap();
        assert!(bad.get_u64_list("ids").is_err());
    }

    #[test]
    fn missing_value_is_error() {
        assert!(Args::parse(&sv(&["x", "--port"]), &[]).is_err());
        assert!(Args::parse(&sv(&["x", "--port", "nan_int"]), &[])
            .unwrap()
            .get_usize("port", 0)
            .is_err());
    }

    #[test]
    fn positional_after_sub_is_error() {
        assert!(Args::parse(&sv(&["x", "stray"]), &[]).is_err());
    }
}
