//! Dynamic batcher: bounded job queue with linger-based batch formation.
//!
//! Requests are coalesced into one batch when they target the same
//! dataset **and** agree on the **stage-1 key**
//! ([`ResolvedOptions::stage1_key`]) — k, ring rule, local mode, alpha
//! levels, fuzzy bounds, area, epoch, and overlay version: everything
//! that determines the kNN sweep and the alpha product.  The stage-2 kernel *variant* is
//! deliberately **not** part of the admission key: jobs that differ only
//! there share the batch's single stage-1 execution (the dominant cost in
//! the paper's measurements) and are split into per-variant groups only
//! for stage 2 ([`Batch::stage2_groups`]).  Under the old full-options
//! admission, each variant paid its own kNN sweep.
//!
//! Batches additionally partition on the **tenant** (protocol v2.8) even
//! though it is numerics-neutral and deliberately *not* a stage-1 key
//! member: a batch is the unit of shard-pool scheduling, so single-tenant
//! batches keep deficit-round-robin costs attributable to the tenant that
//! incurred them.  Cached artifacts still flow across tenants — the cache
//! key derives from the stage-1 key alone.
//!
//! A bounded queue provides backpressure: submissions beyond `max_queue`
//! are rejected immediately rather than queued unboundedly.

use std::collections::VecDeque;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::coordinator::options::{ResolvedOptions, Stage2Key};
use crate::coordinator::request::Job;
use crate::error::{Error, Result};

/// Batch-formation policy.
#[derive(Debug, Clone, Copy)]
pub struct BatchPolicy {
    /// Max total queries folded into one batch.
    pub max_queries: usize,
    /// How long to linger for more compatible jobs once one is pending.
    pub linger: Duration,
    /// Queue capacity (jobs) before submissions are rejected.
    pub max_queue: usize,
}

impl Default for BatchPolicy {
    fn default() -> Self {
        BatchPolicy {
            max_queries: 8192,
            linger: Duration::from_millis(2),
            max_queue: 1024,
        }
    }
}

/// A formed batch: stage-1-compatible jobs to run together.
pub(crate) struct Batch {
    pub jobs: Vec<Job>,
    pub dataset: String,
    /// The first member's resolved options.  Every stage-1-relevant field
    /// (the [`ResolvedOptions::stage1_key`] projection) is identical
    /// across members by admission; the `variant` field is only the first
    /// job's and must not drive stage 2 — use [`Batch::stage2_groups`]
    /// and each job's own resolved options instead.
    pub options: ResolvedOptions,
    /// Total queries across jobs.
    pub total_queries: usize,
    /// When batch formation finished (the linger window closed).  With
    /// each job's `admitted` stamp this bounds the trace's coalesce-wait
    /// span; stamped unconditionally (one `Instant::now()` per batch, not
    /// per job, so the untraced path stays allocation- and lock-free).
    pub formed: Instant,
}

impl Batch {
    /// Partition the jobs by stage-2 key, in first-seen order.  Returns
    /// `(key, job indices)` per group; most batches have exactly one.
    pub fn stage2_groups(&self) -> Vec<(Stage2Key, Vec<usize>)> {
        let mut groups: Vec<(Stage2Key, Vec<usize>)> = Vec::new();
        for (i, job) in self.jobs.iter().enumerate() {
            let key = job.resolved.stage2_key();
            match groups.iter_mut().find(|(k, _)| *k == key) {
                Some((_, idxs)) => idxs.push(i),
                None => groups.push((key, vec![i])),
            }
        }
        groups
    }
}

/// The bounded, condvar-signalled job queue.
pub(crate) struct JobQueue {
    // lock-order: job_queue
    inner: Mutex<QueueState>,
    cond: Condvar,
    policy: BatchPolicy,
}

struct QueueState {
    jobs: VecDeque<Job>,
    closed: bool,
}

impl JobQueue {
    pub fn new(policy: BatchPolicy) -> Self {
        JobQueue {
            inner: Mutex::new(QueueState { jobs: VecDeque::new(), closed: false }),
            cond: Condvar::new(),
            policy,
        }
    }

    /// Enqueue a job; rejects when full or closed (backpressure).
    ///
    /// A full queue is first swept of **cancelled** jobs (tickets/streams
    /// dropped without waiting): their slots belong to nobody, so a
    /// dropped ticket can never leak queue capacity — a full-capacity
    /// submit right after dropping one succeeds.
    pub fn push(&self, job: Job) -> Result<()> {
        let mut st = self.inner.lock().unwrap();
        if st.closed {
            return Err(Error::Unavailable("coordinator shut down".into()));
        }
        if st.jobs.len() >= self.policy.max_queue {
            st.jobs.retain(|j| !j.cancelled());
        }
        if st.jobs.len() >= self.policy.max_queue {
            return Err(Error::Unavailable(format!(
                "queue full ({} jobs); retry later",
                st.jobs.len()
            )));
        }
        st.jobs.push_back(job);
        drop(st);
        self.cond.notify_one();
        Ok(())
    }

    /// Queue depth (diagnostics).
    pub fn depth(&self) -> usize {
        self.inner.lock().unwrap().jobs.len()
    }

    /// Close the queue; wakes the dispatcher so it can drain and exit.
    pub fn close(&self) {
        self.inner.lock().unwrap().closed = true;
        self.cond.notify_all();
    }

    /// Pull the next batch: blocks for work, lingers briefly to coalesce
    /// compatible jobs, respects `max_queries`.  Returns None once closed
    /// and drained.
    pub fn next_batch(&self) -> Option<Batch> {
        let mut st = self.inner.lock().unwrap();
        // wait for a first job (or shutdown)
        loop {
            while let Some(first) = st.jobs.pop_front() {
                if first.cancelled() {
                    continue; // dropped ticket: free the slot, skip the work
                }
                drop(st);
                return Some(self.fill_batch(first));
            }
            if st.closed {
                return None;
            }
            st = self.cond.wait(st).unwrap();
        }
    }

    /// Grow a batch around `first`, lingering for compatible arrivals.
    /// Compatibility = same dataset + equal stage-1 key (stage-2 variants
    /// may differ; they split only at stage 2).
    fn fill_batch(&self, mut first: Job) -> Batch {
        let dataset = first.request.dataset.clone();
        let options = first.resolved;
        let stage1 = options.stage1_key();
        let now = Instant::now();
        first.admitted = Some(now);
        let mut total = first.request.queries.len();
        let mut jobs = vec![first];
        let deadline = now + self.policy.linger;

        loop {
            let mut st = self.inner.lock().unwrap();
            // take every currently-queued compatible job (preserving FIFO
            // order of incompatible ones)
            let mut i = 0;
            while i < st.jobs.len() && total < self.policy.max_queries {
                if st.jobs[i].cancelled() {
                    // dropped ticket: drop the abandoned job on the floor
                    st.jobs.remove(i);
                    continue;
                }
                let compat = {
                    let j = &st.jobs[i];
                    j.request.dataset == dataset
                        && j.resolved.stage1_key() == stage1
                        // tenant partition (v2.8): numerics-neutral, but a
                        // batch is one shard-pool schedule unit — keep its
                        // DRR cost attributable to a single tenant
                        && j.resolved.tenant == options.tenant
                        && total + j.request.queries.len() <= self.policy.max_queries
                };
                if compat {
                    // i < len is loop-invariant, so remove cannot miss;
                    // spelled as let-else to keep this path panic-free
                    let Some(mut j) = st.jobs.remove(i) else { break };
                    j.admitted = Some(Instant::now());
                    total += j.request.queries.len();
                    jobs.push(j);
                } else {
                    i += 1;
                }
            }
            if total >= self.policy.max_queries || st.closed {
                break;
            }
            let now = Instant::now();
            if now >= deadline {
                break;
            }
            // linger: wait for more arrivals up to the deadline
            let (st2, timeout) = self.cond.wait_timeout(st, deadline - now).unwrap();
            drop(st2);
            if timeout.timed_out() {
                break;
            }
        }
        Batch { jobs, dataset, options, total_queries: total, formed: Instant::now() }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::request::{
        FrameTx, InterpolationRequest, StreamFrame, StreamHandle,
    };
    use crate::knn::grid_knn::RingRule;
    use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
    use std::sync::{mpsc, Arc};

    type RespRx = mpsc::Receiver<StreamFrame>;

    fn job_with(dataset: &str, nq: usize, resolved: ResolvedOptions) -> (Job, RespRx) {
        let (tx, rx) = mpsc::channel();
        let queries = vec![(0.0, 0.0); nq];
        (
            Job {
                request: InterpolationRequest::new(dataset, queries),
                resolved,
                respond: StreamHandle {
                    tx: FrameTx::Unbounded(tx),
                    buffered: Arc::new(AtomicUsize::new(0)),
                    bounded: false,
                },
                cancel: Arc::new(AtomicBool::new(false)),
                enqueued: Instant::now(),
                admitted: None,
                admit: None,
            },
            rx,
        )
    }

    fn job(dataset: &str, nq: usize) -> (Job, RespRx) {
        job_with(dataset, nq, ResolvedOptions::default())
    }

    #[test]
    fn coalesces_same_dataset() {
        let q = JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(1),
            ..Default::default()
        });
        let (j1, _r1) = job("a", 10);
        let (j2, _r2) = job("a", 20);
        let (j3, _r3) = job("b", 5);
        q.push(j1).unwrap();
        q.push(j2).unwrap();
        q.push(j3).unwrap();
        let b1 = q.next_batch().unwrap();
        assert_eq!(b1.dataset, "a");
        assert_eq!(b1.jobs.len(), 2);
        assert_eq!(b1.total_queries, 30);
        let b2 = q.next_batch().unwrap();
        assert_eq!(b2.dataset, "b");
        assert_eq!(b2.total_queries, 5);
    }

    #[test]
    fn batch_formation_stamps_admission_instants() {
        // the trace's admission/coalesce spans derive from these stamps:
        // enqueued <= admitted <= formed for every member
        let q = JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(1),
            ..Default::default()
        });
        let (j1, _r1) = job("a", 1);
        assert!(j1.admitted.is_none(), "admission stamps only at batch formation");
        let (j2, _r2) = job("a", 1);
        q.push(j1).unwrap();
        q.push(j2).unwrap();
        let b = q.next_batch().unwrap();
        assert_eq!(b.jobs.len(), 2);
        for j in &b.jobs {
            let admitted = j.admitted.expect("every batched job is stamped");
            assert!(admitted >= j.enqueued);
            assert!(b.formed >= admitted);
        }
    }

    #[test]
    fn mixed_options_never_share_a_batch() {
        let q = JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(1),
            ..Default::default()
        });
        let base = ResolvedOptions::default();
        let other_k = ResolvedOptions { k: 3, ..base };
        let other_ring = ResolvedOptions { ring_rule: RingRule::PaperPlusOne, ..base };
        let other_local = ResolvedOptions { local_neighbors: Some(32), ..base };
        let other_alpha =
            ResolvedOptions { alpha_levels: [1.0, 2.0, 3.0, 4.0, 5.0], ..base };
        let (j1, _r1) = job_with("a", 4, base);
        let (j2, _r2) = job_with("a", 4, other_k);
        let (j3, _r3) = job_with("a", 4, other_ring);
        let (j4, _r4) = job_with("a", 4, other_local);
        let (j5, _r5) = job_with("a", 4, other_alpha);
        let (j6, _r6) = job_with("a", 4, base); // compatible with j1
        for j in [j1, j2, j3, j4, j5, j6] {
            q.push(j).unwrap();
        }
        // first batch: j1 + j6 (same resolved options), nothing else
        let b1 = q.next_batch().unwrap();
        assert_eq!(b1.jobs.len(), 2);
        assert_eq!(b1.options, base);
        // the four incompatible jobs each form their own batch, in order
        for want in [other_k, other_ring, other_local, other_alpha] {
            let b = q.next_batch().unwrap();
            assert_eq!(b.jobs.len(), 1);
            assert_eq!(b.options, want);
        }
    }

    #[test]
    fn variant_only_difference_coalesces_into_one_batch() {
        // the stage-2 kernel variant is not part of the admission key:
        // such jobs share one stage-1 sweep and split only at stage 2
        let q = JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(1),
            ..Default::default()
        });
        let base = ResolvedOptions::default(); // Variant::Tiled
        let naive = ResolvedOptions { variant: crate::runtime::Variant::Naive, ..base };
        let (j1, _r1) = job_with("a", 4, base);
        let (j2, _r2) = job_with("a", 4, naive);
        let (j3, _r3) = job_with("a", 4, base);
        for j in [j1, j2, j3] {
            q.push(j).unwrap();
        }
        let b = q.next_batch().unwrap();
        assert_eq!(b.jobs.len(), 3, "variant-only differences coalesce");
        assert_eq!(b.total_queries, 12);
        let groups = b.stage2_groups();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0].0, base.stage2_key());
        assert_eq!(groups[0].1, vec![0, 2]);
        assert_eq!(groups[1].0, naive.stage2_key());
        assert_eq!(groups[1].1, vec![1]);
    }

    #[test]
    fn tenants_never_share_a_batch() {
        // the tenant is numerics-neutral (not a stage-1 key member) but
        // still partitions batches: one batch = one shard-pool schedule
        // unit, attributed to exactly one tenant
        let q = JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(1),
            ..Default::default()
        });
        let base = ResolvedOptions::default(); // anonymous tenant
        let acme = ResolvedOptions {
            tenant: Some(crate::shard::TenantTag::new("acme").unwrap()),
            ..base
        };
        assert_eq!(base.stage1_key(), acme.stage1_key(), "tenant is numerics-neutral");
        let (j1, _r1) = job_with("a", 4, base);
        let (j2, _r2) = job_with("a", 4, acme);
        let (j3, _r3) = job_with("a", 4, base);
        for j in [j1, j2, j3] {
            q.push(j).unwrap();
        }
        let b1 = q.next_batch().unwrap();
        assert_eq!(b1.jobs.len(), 2, "same-tenant jobs coalesce");
        assert_eq!(b1.options.tenant, None);
        let b2 = q.next_batch().unwrap();
        assert_eq!(b2.jobs.len(), 1);
        assert_eq!(b2.options.tenant.unwrap().as_str(), "acme");
    }

    #[test]
    fn epochs_never_share_a_batch() {
        // the live subsystem stamps the dataset epoch into the resolved
        // options at submit time; a compaction publish between two
        // submissions must split them into separate batches
        let q = JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(1),
            ..Default::default()
        });
        let base = ResolvedOptions { epoch: Some(0), ..Default::default() };
        let next = ResolvedOptions { epoch: Some(1), ..base };
        let (j1, _r1) = job_with("a", 4, base);
        let (j2, _r2) = job_with("a", 4, next);
        let (j3, _r3) = job_with("a", 4, base);
        for j in [j1, j2, j3] {
            q.push(j).unwrap();
        }
        let b1 = q.next_batch().unwrap();
        assert_eq!(b1.jobs.len(), 2, "same-epoch jobs coalesce");
        assert_eq!(b1.options.epoch, Some(0));
        let b2 = q.next_batch().unwrap();
        assert_eq!(b2.jobs.len(), 1);
        assert_eq!(b2.options.epoch, Some(1));
    }

    #[test]
    fn overlay_versions_never_share_a_batch() {
        // submit stamps the snapshot's overlay version; a mutation
        // between two submissions must split them into separate batches
        // (their stage-1 products come from different overlay states)
        let q = JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(1),
            ..Default::default()
        });
        let base =
            ResolvedOptions { epoch: Some(0), overlay: Some(0), ..Default::default() };
        let bumped = ResolvedOptions { overlay: Some(1), ..base };
        let (j1, _r1) = job_with("a", 4, base);
        let (j2, _r2) = job_with("a", 4, bumped);
        let (j3, _r3) = job_with("a", 4, base);
        for j in [j1, j2, j3] {
            q.push(j).unwrap();
        }
        let b1 = q.next_batch().unwrap();
        assert_eq!(b1.jobs.len(), 2, "same-overlay jobs coalesce");
        assert_eq!(b1.options.overlay, Some(0));
        let b2 = q.next_batch().unwrap();
        assert_eq!(b2.jobs.len(), 1);
        assert_eq!(b2.options.overlay, Some(1));
    }

    #[test]
    fn respects_max_queries() {
        let q = JobQueue::new(BatchPolicy {
            max_queries: 25,
            linger: Duration::from_millis(1),
            ..Default::default()
        });
        let (j1, _r1) = job("a", 20);
        let (j2, _r2) = job("a", 10);
        q.push(j1).unwrap();
        q.push(j2).unwrap();
        let b1 = q.next_batch().unwrap();
        assert_eq!(b1.jobs.len(), 1, "20+10 > 25 must not merge");
        let b2 = q.next_batch().unwrap();
        assert_eq!(b2.total_queries, 10);
    }

    #[test]
    fn backpressure_rejects_when_full() {
        let q = JobQueue::new(BatchPolicy { max_queue: 2, ..Default::default() });
        let (j1, _r1) = job("a", 1);
        let (j2, _r2) = job("a", 1);
        let (j3, _r3) = job("a", 1);
        q.push(j1).unwrap();
        q.push(j2).unwrap();
        assert!(matches!(q.push(j3), Err(Error::Unavailable(_))));
        assert_eq!(q.depth(), 2);
    }

    #[test]
    fn dropped_ticket_frees_its_queue_slot() {
        // the Ticket-drop leak fix: a queued job whose consumer dropped
        // its ticket (cancel flag set) is swept when the queue is full,
        // so a full-capacity submit right after the drop succeeds
        let q = JobQueue::new(BatchPolicy { max_queue: 2, ..Default::default() });
        let (j1, _r1) = job("a", 1);
        let cancel1 = j1.cancel.clone();
        let (j2, _r2) = job("a", 1);
        q.push(j1).unwrap();
        q.push(j2).unwrap();
        // simulate `drop(ticket)` for the first job (TileStream::drop
        // sets exactly this flag — pinned in request.rs tests)
        cancel1.store(true, Ordering::Relaxed);
        let (j3, _r3) = job("a", 1);
        q.push(j3).unwrap();
        assert_eq!(q.depth(), 2, "the cancelled job's slot was reclaimed");
        // the cancelled job is also never executed: the surviving two
        // jobs form the only batch
        let b = q.next_batch().unwrap();
        assert_eq!(b.jobs.len(), 2);
        assert!(b.jobs.iter().all(|j| !j.cancelled()));
    }

    #[test]
    fn cancelled_jobs_are_skipped_at_batch_formation() {
        let q = JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(1),
            ..Default::default()
        });
        let (j1, _r1) = job("a", 4);
        let cancel1 = j1.cancel.clone();
        let (j2, _r2) = job("a", 4);
        let (j3, _r3) = job("a", 4);
        let cancel3 = j3.cancel.clone();
        q.push(j1).unwrap();
        q.push(j2).unwrap();
        q.push(j3).unwrap();
        cancel1.store(true, Ordering::Relaxed); // cancelled while queued (head)
        cancel3.store(true, Ordering::Relaxed); // cancelled while queued (tail)
        let b = q.next_batch().unwrap();
        assert_eq!(b.jobs.len(), 1, "only the live job executes");
        assert_eq!(b.total_queries, 4);
        assert_eq!(q.depth(), 0, "cancelled jobs were dropped, not left queued");
    }

    #[test]
    fn close_drains_then_none() {
        let q = JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(0),
            ..Default::default()
        });
        let (j1, _r1) = job("a", 1);
        q.push(j1).unwrap();
        q.close();
        assert!(q.next_batch().is_some());
        assert!(q.next_batch().is_none());
        let (j2, _r2) = job("a", 1);
        assert!(q.push(j2).is_err());
    }

    #[test]
    fn blocking_wakeup_from_other_thread() {
        let q = std::sync::Arc::new(JobQueue::new(BatchPolicy {
            linger: Duration::from_millis(0),
            ..Default::default()
        }));
        let q2 = q.clone();
        let h = std::thread::spawn(move || q2.next_batch().map(|b| b.total_queries));
        std::thread::sleep(Duration::from_millis(20));
        let (j, _r) = job("x", 7);
        q.push(j).unwrap();
        assert_eq!(h.join().unwrap(), Some(7));
    }
}
