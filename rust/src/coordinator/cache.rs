//! The overlay-versioned neighbor cache: a bounded LRU of stage-1
//! products ([`NeighborArtifact`]) so a repeated raster — the dominant
//! serving pattern for DEM/tile workloads — skips the kNN search
//! entirely, on compacted **and** mutated snapshots alike.
//!
//! ## Key & invalidation rules
//!
//! An entry is keyed on `(dataset, served epoch, epoch-base instance,
//! overlay version, Stage1Key, query-set fingerprint, query count)`.
//! Correctness rests on three rules:
//!
//! 1. **Mutation state is part of the key, not a reason to bypass.**
//!    Every append/remove bumps the snapshot's
//!    [`crate::live::DeltaOverlay::version`] (copy-on-write overlays make
//!    `(epoch, version)` name exactly one overlay state), so artifacts
//!    computed over a mutated snapshot — built via [`crate::knn::merged`]
//!    — are cached and served exactly until the next mutation, whose
//!    version bump retires them by key mismatch; stale versions age out
//!    of the LRU.  (The PR-3 rule "only compacted snapshots are cached"
//!    is gone: it degenerated live-feed workloads to re-running the
//!    dominant kNN stage on every raster.)
//! 2. **Compaction bumps the epoch** (and resets the overlay version),
//!    so post-compaction lookups miss the pre-compaction entries by key.
//! 3. **Registering over or dropping a dataset purges its entries**
//!    explicitly (same name + epoch 0 would otherwise collide with a
//!    different point set); the epoch-base `instance` id backstops the
//!    in-flight re-register race.
//!
//! ## Subset reuse
//!
//! A lookup that misses on the exact fingerprint still hits when some
//! cached entry with the same `(dataset, epoch, instance, overlay,
//! Stage1Key)` identity covers **every query row** of the new raster:
//! stage-1 products are per-query functions of the snapshot, so the
//! covering entry's rows are gathered (via
//! [`NeighborArtifact::subset_rows`]) into a fresh artifact — row
//! subsets, permutations, and sub-tiles of a cached raster all skip the
//! kNN search.  Each entry carries a query→row index for the cover test.
//!
//! The store is a small `Mutex<VecDeque>` scanned linearly for exact-key
//! hits: capacities are tens of entries (each potentially megabytes of
//! artifact), so a hash map would buy nothing — and `Stage1Key` holds
//! `f64`s, which have no `Eq`/`Hash`.  Queries are identified by a
//! 128-bit FNV-1a fingerprint of their raw bits plus the exact count;
//! two distinct rasters colliding on both fingerprint halves is
//! beyond-astronomical, and a false hit is the only way this cache could
//! ever change answers (the subset path compares raw coordinate bits,
//! not hashes).
//!
//! Covering-entry probes (subset and tile-granular partial cover — and
//! the per-tile lookups the subscription worker issues on every dirty
//! push) go through a **coordinate-bits index**: `coordinate → entry
//! uids` postings, so a probe inspects only the entries that actually
//! contain its first query coordinate instead of walking the whole LRU.
//! Any entry covering *every* probe row necessarily contains the first
//! one, so the posting list is a complete candidate set.
//!
//! ## Accounting
//!
//! Entry weight = every buffer the entry can retain: `r_obs`, the lazy
//! alpha vector **at its materialized size** (it may materialize while
//! cached, so it is charged up front), the neighbor table, and the
//! query→row subset index.  The eviction loop keeps
//! `bytes <= max_bytes` after every insert, so the budget is exceeded
//! only transiently, by at most the incoming entry's own weight.

use std::collections::{HashMap, VecDeque};
use std::sync::{Arc, Mutex};

use crate::aidw::plan::NeighborArtifact;

use super::options::Stage1Key;

/// Full identity of one cached stage-1 product.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheKey {
    pub dataset: String,
    /// The epoch of the snapshot the artifact was computed from.
    pub epoch: u64,
    /// Identity of the epoch base ([`crate::coordinator::Dataset::uid`],
    /// a process-unique monotonic counter): a backstop against the
    /// register-over race where an in-flight batch of a displaced dataset
    /// could insert under the same `(name, epoch)` as its replacement
    /// after the purge.
    pub instance: u64,
    /// The overlay version of the snapshot the artifact was computed
    /// from (0 = compacted).  Every append/remove bumps it, so mutated
    /// snapshots cache safely — see module docs, rule 1.
    pub overlay: u64,
    pub stage1: Stage1Key,
    /// 128-bit query-set fingerprint (see [`query_fingerprint`]).
    pub queries_fp: (u64, u64),
    pub n_queries: usize,
}

impl CacheKey {
    /// Same snapshot + same stage-1 options — everything but the query
    /// set.  Two keys agreeing here describe artifacts whose rows are
    /// interchangeable per query coordinate (the subset-reuse precondition).
    fn same_identity(&self, other: &CacheKey) -> bool {
        self.dataset == other.dataset
            && self.epoch == other.epoch
            && self.instance == other.instance
            && self.overlay == other.overlay
            && self.stage1 == other.stage1
    }
}

/// Two independent 64-bit FNV-1a passes over the queries' raw f64 bits.
pub fn query_fingerprint(queries: &[(f64, f64)]) -> (u64, u64) {
    fn fnv(queries: &[(f64, f64)], mut h: u64) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01B3;
        for &(x, y) in queries {
            for b in x.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
            for b in y.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        }
        h
    }
    (
        fnv(queries, 0xcbf2_9ce4_8422_2325),
        fnv(queries, 0x9e37_79b9_7f4a_7c15),
    )
}

/// Heap bytes an artifact of `n_rows` query rows holds: r_obs + the lazy
/// alpha vector at its materialized size (it may materialize while the
/// entry is cached, so it is charged up front) + an optional width-`w`
/// row-major neighbor table.  The single formula both [`artifact_bytes`]
/// and the subset-hit charge derive from — keep them from drifting apart.
fn artifact_row_bytes(n_rows: usize, table_width: Option<usize>) -> usize {
    n_rows * 8 // r_obs
        + n_rows * 8 // alphas (lazy; charged at materialized size)
        + table_width.map_or(0, |w| n_rows * w * 4)
}

/// Heap bytes one artifact retains (the artifact half of the eviction
/// weight).
fn artifact_bytes(a: &NeighborArtifact) -> usize {
    artifact_row_bytes(a.r_obs.len(), a.neighbors.as_ref().map(|t| t.width))
}

/// Approximate bytes per indexed query coordinate: the per-entry
/// query→row slot (two u64 key halves + a u32 row) plus the cache-wide
/// coordinate-index posting (key + entry uid), with hash-map overhead.
const ROW_INDEX_BYTES_PER_QUERY: usize = 48;

/// One cached stage-1 product plus its subset-reuse row index.
#[derive(Debug)]
struct Entry {
    key: CacheKey,
    artifact: Arc<NeighborArtifact>,
    /// Stable insert-order id — the coordinate index's handle on this
    /// entry (positions shift on every LRU promotion, uids never do).
    uid: u64,
    /// Eviction weight (artifact buffers + row index), fixed at insert.
    weight: usize,
    /// Query coordinate bits → artifact row.  Duplicate coordinates in
    /// the source raster collapse to one row, which is sound: stage-1
    /// rows are per-query functions of the snapshot, so equal
    /// coordinates hold bit-identical rows.
    rows: HashMap<(u64, u64), u32>,
}

/// What a [`NeighborCache::lookup`] found.
pub enum CacheOutcome {
    /// Exact raster match: the cached artifact itself (its `stage1_s` is
    /// the build time this hit saved).
    Hit(Arc<NeighborArtifact>),
    /// A covering entry matched every query row: a freshly-gathered
    /// subset artifact (the caller may re-insert it under its own key).
    Subset {
        artifact: NeighborArtifact,
        /// Stage-1 seconds the gather substituted for — the covering
        /// entry's recorded build time scaled to the gathered row count
        /// (feeds the `stage1_saved_ms` counter).
        saved_s: f64,
    },
    Miss,
}

/// Point-in-time cache statistics (protocol v2.3 metrics surface).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Entries currently resident (occupancy gauge).
    pub entries: usize,
    /// Approximate resident bytes (occupancy gauge).
    pub bytes: usize,
    /// Entries evicted by the LRU bounds since startup (purges excluded).
    pub evictions: u64,
    /// Artifact bytes served from the cache — `artifact_bytes` of the
    /// served artifact (the cached one on exact hits, the gathered one
    /// on subset hits); row-index overhead is excluded on both paths so
    /// the two are directly comparable.
    pub hit_bytes: u64,
}

#[derive(Debug, Default)]
struct CacheState {
    /// Front = most recently used.
    entries: VecDeque<Entry>,
    /// Coordinate bits → uids of entries whose row index contains that
    /// coordinate.  Covering probes walk one posting list instead of the
    /// whole LRU; maintained on insert, replace, eviction, and purge.
    by_coord: HashMap<(u64, u64), Vec<u64>>,
    next_uid: u64,
    bytes: usize,
    evictions: u64,
    hit_bytes: u64,
}

impl CacheState {
    /// Add one entry's coordinates to the coordinate index.
    fn index_entry(&mut self, e: &Entry) {
        for coord in e.rows.keys() {
            self.by_coord.entry(*coord).or_default().push(e.uid);
        }
    }

    /// Remove one entry's postings (replace / eviction / purge).
    fn deindex_entry(&mut self, e: &Entry) {
        for coord in e.rows.keys() {
            if let Some(uids) = self.by_coord.get_mut(coord) {
                uids.retain(|&u| u != e.uid);
                if uids.is_empty() {
                    self.by_coord.remove(coord);
                }
            }
        }
    }
}

/// Bounded LRU of stage-1 artifacts, capped both by entry count and by
/// approximate resident bytes (large-raster artifacts are megabytes
/// each; an entry-only bound would let memory scale with raster size).
/// `capacity == 0` disables caching; an artifact larger than the whole
/// byte budget is simply not cached.
#[derive(Debug, Default)]
pub struct NeighborCache {
    // lock-order: neighbor_cache
    inner: Mutex<CacheState>,
    capacity: usize,
    max_bytes: usize,
}

impl NeighborCache {
    pub fn new(capacity: usize, max_bytes: usize) -> NeighborCache {
        NeighborCache { inner: Mutex::new(CacheState::default()), capacity, max_bytes }
    }

    /// True when the cache can hold anything at all.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Look up an artifact for `key` / `queries` (the raster behind the
    /// key's fingerprint).  An exact hit returns the cached artifact; a
    /// subset hit gathers the covered rows out of a same-identity entry
    /// (see module docs).  Either hit promotes the serving entry to
    /// most-recently-used.
    pub fn lookup(&self, key: &CacheKey, queries: &[(f64, f64)]) -> CacheOutcome {
        if self.capacity == 0 {
            return CacheOutcome::Miss;
        }
        let mut st = self.inner.lock().unwrap();
        if let Some(pos) = st.entries.iter().position(|e| e.key == *key) {
            let entry = st.entries.remove(pos).unwrap();
            let art = entry.artifact.clone();
            st.hit_bytes += artifact_bytes(&art) as u64;
            st.entries.push_front(entry);
            return CacheOutcome::Hit(art);
        }
        if queries.is_empty() {
            return CacheOutcome::Miss; // exact-key-only callers pass no raster
        }
        match Self::find_covering(&mut st, key, queries) {
            Some((art, rows, saved_s)) => {
                // the row gather can be megabytes — run it off the lock
                drop(st);
                CacheOutcome::Subset { artifact: art.subset_rows(&rows), saved_s }
            }
            None => CacheOutcome::Miss,
        }
    }

    /// Row-gather `queries` out of the first same-identity entry covering
    /// every one of them; `None` when no entry covers the whole slice.
    /// The caller picks the granularity: [`NeighborCache::lookup`] passes
    /// the full raster (the classic subset hit), the dispatcher's
    /// partial-cover pass calls this per tile so that only uncovered
    /// tiles pay a kNN sweep (ROADMAP PR-4(a)).  A hit promotes the
    /// serving entry and charges `hit_bytes`.
    pub fn subset_for(
        &self,
        key: &CacheKey,
        queries: &[(f64, f64)],
    ) -> Option<(NeighborArtifact, f64)> {
        if self.capacity == 0 || queries.is_empty() {
            return None;
        }
        let mut st = self.inner.lock().unwrap();
        let (art, rows, saved_s) = Self::find_covering(&mut st, key, queries)?;
        drop(st); // the row gather can be megabytes — run it off the lock
        Some((art.subset_rows(&rows), saved_s))
    }

    /// The shared subset scan: find a same-identity entry covering every
    /// query row, promote it, and charge hit bytes.  Returns the covering
    /// artifact, the row indices to gather, and the stage-1 seconds the
    /// gather substitutes for (the entry's recorded build time scaled by
    /// row fraction); the caller performs the gather off the lock.
    fn find_covering(
        st: &mut std::sync::MutexGuard<'_, CacheState>,
        key: &CacheKey,
        queries: &[(f64, f64)],
    ) -> Option<(Arc<NeighborArtifact>, Vec<u32>, f64)> {
        // a covering entry must contain the first query coordinate, so
        // its posting list is a complete candidate set — the probe walks
        // candidates that share that coordinate, not the whole LRU
        let (x0, y0) = queries[0];
        let candidates = st.by_coord.get(&(x0.to_bits(), y0.to_bits()))?.clone();
        let mut found: Option<(usize, Vec<u32>)> = None;
        'candidate: for uid in candidates {
            let Some(pos) = st.entries.iter().position(|e| e.uid == uid) else {
                debug_assert!(false, "coordinate index points at a missing entry");
                continue;
            };
            let entry = &st.entries[pos];
            if !entry.key.same_identity(key) {
                continue;
            }
            let mut rows = Vec::with_capacity(queries.len());
            for &(x, y) in queries {
                match entry.rows.get(&(x.to_bits(), y.to_bits())) {
                    Some(&r) => rows.push(r),
                    None => continue 'candidate,
                }
            }
            found = Some((pos, rows));
            break;
        }
        let (pos, rows) = found?;
        let entry = st.entries.remove(pos).unwrap();
        let art = entry.artifact.clone();
        // charge the gathered artifact's bytes (known without building
        // it — same formula as `artifact_bytes`)
        let width = art.neighbors.as_ref().map(|t| t.width);
        st.hit_bytes += artifact_row_bytes(rows.len(), width) as u64;
        let entry_rows = art.r_obs.len().max(1);
        let saved_s = art.stage1_s * rows.len() as f64 / entry_rows as f64;
        st.entries.push_front(entry);
        Some((art, rows, saved_s))
    }

    /// Exact-key lookup (tests and simple callers); a hit is promoted.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<NeighborArtifact>> {
        match self.lookup(key, &[]) {
            CacheOutcome::Hit(art) => Some(art),
            _ => None,
        }
    }

    /// Insert (or refresh) an artifact, evicting least-recently-used
    /// entries beyond the entry or byte bound.  `queries` must be the
    /// raster the key's fingerprint was computed from; it seeds the
    /// subset-reuse row index.  Returns how many entries the insert
    /// evicted (the coordinator journals evictions; 0 when the insert was
    /// skipped or nothing had to go).
    pub fn put(
        &self,
        key: CacheKey,
        queries: &[(f64, f64)],
        artifact: Arc<NeighborArtifact>,
    ) -> usize {
        if self.capacity == 0 {
            return 0;
        }
        debug_assert_eq!(key.n_queries, queries.len(), "key/queries mismatch");
        let art_bytes = artifact_bytes(&artifact);
        if self.max_bytes > 0 && art_bytes > self.max_bytes {
            return 0; // would evict everything and still bust the budget —
                      // bail before building the O(n) row index
        }
        let rows: HashMap<(u64, u64), u32> = queries
            .iter()
            .enumerate()
            .map(|(i, &(x, y))| ((x.to_bits(), y.to_bits()), i as u32))
            .collect();
        let weight = art_bytes + rows.len() * ROW_INDEX_BYTES_PER_QUERY;
        if self.max_bytes > 0 && weight > self.max_bytes {
            return 0; // row-index overhead alone busts the budget
        }
        let mut st = self.inner.lock().unwrap();
        if let Some(pos) = st.entries.iter().position(|e| e.key == key) {
            let old = st.entries.remove(pos).unwrap();
            st.bytes -= old.weight;
            st.deindex_entry(&old);
        }
        let uid = st.next_uid;
        st.next_uid += 1;
        let entry = Entry { key, artifact, uid, weight, rows };
        st.index_entry(&entry);
        st.entries.push_front(entry);
        st.bytes += weight;
        let mut evicted = 0usize;
        while st.entries.len() > self.capacity
            || (self.max_bytes > 0 && st.bytes > self.max_bytes)
        {
            match st.entries.pop_back() {
                Some(victim) => {
                    st.bytes -= victim.weight;
                    st.deindex_entry(&victim);
                    st.evictions += 1;
                    evicted += 1;
                }
                None => break,
            }
        }
        evicted
    }

    /// Drop every entry of one dataset (register-over / drop paths).
    /// Returns how many entries were purged (journaled by the caller).
    pub fn purge_dataset(&self, dataset: &str) -> usize {
        let mut st = self.inner.lock().unwrap();
        let before = st.entries.len();
        let mut kept = VecDeque::with_capacity(st.entries.len());
        while let Some(e) = st.entries.pop_front() {
            if e.key.dataset == dataset {
                st.deindex_entry(&e);
            } else {
                kept.push_back(e);
            }
        }
        st.entries = kept;
        st.bytes = st.entries.iter().map(|e| e.weight).sum();
        before - st.entries.len()
    }

    /// Entries currently held (diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes (diagnostics).
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }

    /// Occupancy gauges + eviction/hit-byte counters (protocol v2.3).
    pub fn stats(&self) -> CacheStats {
        let st = self.inner.lock().unwrap();
        CacheStats {
            entries: st.entries.len(),
            bytes: st.bytes,
            evictions: st.evictions,
            hit_bytes: st.hit_bytes,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::aidw::params::AidwParams;
    use crate::aidw::plan::NeighborTable;
    use crate::coordinator::options::ResolvedOptions;

    fn key_for(dataset: &str, epoch: u64, overlay: u64, queries: &[(f64, f64)]) -> CacheKey {
        CacheKey {
            dataset: dataset.to_string(),
            epoch,
            instance: 7,
            overlay,
            stage1: ResolvedOptions::default().stage1_key(),
            queries_fp: query_fingerprint(queries),
            n_queries: queries.len(),
        }
    }

    fn raster(tag: u64, n: usize) -> Vec<(f64, f64)> {
        (0..n).map(|i| (tag as f64 + i as f64, tag as f64 - i as f64)).collect()
    }

    fn artifact(tag: f64, n: usize) -> Arc<NeighborArtifact> {
        Arc::new(NeighborArtifact::new(
            vec![tag; n],
            1.0,
            AidwParams::default(),
            None,
            0.0,
        ))
    }

    const NO_BYTE_CAP: usize = usize::MAX;

    #[test]
    fn lru_evicts_oldest_and_promotes_hits() {
        let c = NeighborCache::new(2, NO_BYTE_CAP);
        assert!(c.enabled());
        let (q1, q2, q3) = (raster(1, 3), raster(2, 3), raster(3, 3));
        c.put(key_for("d", 0, 0, &q1), &q1, artifact(1.0, 3));
        c.put(key_for("d", 0, 0, &q2), &q2, artifact(2.0, 3));
        // touch entry 1 so entry 2 becomes the LRU victim
        assert!(c.get(&key_for("d", 0, 0, &q1)).is_some());
        c.put(key_for("d", 0, 0, &q3), &q3, artifact(3.0, 3));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key_for("d", 0, 0, &q2)).is_none(), "LRU evicted");
        assert!(c.get(&key_for("d", 0, 0, &q1)).is_some());
        assert!(c.get(&key_for("d", 0, 0, &q3)).is_some());
        let stats = c.stats();
        assert_eq!(stats.entries, 2);
        assert_eq!(stats.evictions, 1, "the LRU victim counts as an eviction");
        assert!(stats.hit_bytes > 0);
    }

    #[test]
    fn epoch_overlay_and_dataset_separate_entries() {
        let c = NeighborCache::new(8, NO_BYTE_CAP);
        let q = raster(1, 2);
        c.put(key_for("d", 0, 0, &q), &q, artifact(1.0, 2));
        assert!(c.get(&key_for("d", 1, 0, &q)).is_none(), "epoch mismatch misses");
        assert!(c.get(&key_for("d", 0, 1, &q)).is_none(), "overlay mismatch misses");
        assert!(c.get(&key_for("e", 0, 0, &q)).is_none(), "dataset mismatch misses");
        let hit = c.get(&key_for("d", 0, 0, &q)).unwrap();
        assert_eq!(hit.r_obs, vec![1.0, 1.0]);
        // mutated-snapshot (overlay > 0) entries cache and serve too
        c.put(key_for("d", 0, 3, &q), &q, artifact(3.0, 2));
        assert_eq!(c.get(&key_for("d", 0, 3, &q)).unwrap().r_obs, vec![3.0, 3.0]);
        assert!(c.get(&key_for("d", 0, 4, &q)).is_none(), "next mutation retires it");
    }

    #[test]
    fn subset_lookup_gathers_covered_rows() {
        let c = NeighborCache::new(8, NO_BYTE_CAP);
        let full = raster(5, 6);
        let art = Arc::new(NeighborArtifact::new(
            (0..6).map(|i| i as f64).collect(),
            1.0,
            AidwParams::default(),
            Some(NeighborTable { idx: (0..12u32).collect(), width: 2 }),
            0.6, // recorded build time: 0.1 s per row
        ));
        c.put(key_for("d", 2, 4, &full), &full, art);
        // a row subset in scrambled order hits via the covering entry
        let sub = vec![full[4], full[1], full[4]];
        match c.lookup(&key_for("d", 2, 4, &sub), &sub) {
            CacheOutcome::Subset { artifact: got, saved_s } => {
                assert_eq!(got.r_obs, vec![4.0, 1.0, 4.0]);
                let t = got.neighbors.unwrap();
                assert_eq!(t.idx, vec![8, 9, 2, 3, 8, 9]);
                // saved time = entry build time scaled to 3 of 6 rows
                assert!((saved_s - 0.3).abs() < 1e-12, "{saved_s}");
            }
            _ => panic!("expected a subset hit"),
        }
        // tile-granular cover: subset_for serves an arbitrary slice
        let tile = vec![full[2], full[0]];
        let (tart, tsaved) = c
            .subset_for(&key_for("d", 2, 4, &tile), &tile)
            .expect("covered tile gathers");
        assert_eq!(tart.r_obs, vec![2.0, 0.0]);
        assert!((tsaved - 0.2).abs() < 1e-12, "{tsaved}");
        // an uncovered tile is None — the caller sweeps it instead
        assert!(c
            .subset_for(&key_for("d", 2, 4, &[(77.0, 77.0)]), &[(77.0, 77.0)])
            .is_none());
        // identity must match: same rows at another overlay version miss
        assert!(matches!(
            c.lookup(&key_for("d", 2, 5, &sub), &sub),
            CacheOutcome::Miss
        ));
        // a raster with any uncovered row misses
        let stranger = vec![full[0], (999.0, 999.0)];
        assert!(matches!(
            c.lookup(&key_for("d", 2, 4, &stranger), &stranger),
            CacheOutcome::Miss
        ));
    }

    #[test]
    fn purge_and_disable() {
        let c = NeighborCache::new(4, NO_BYTE_CAP);
        let q = raster(1, 1);
        c.put(key_for("d", 0, 0, &q), &q, artifact(1.0, 1));
        c.put(key_for("e", 0, 0, &q), &q, artifact(2.0, 1));
        assert!(c.bytes() > 0);
        c.purge_dataset("d");
        assert!(c.get(&key_for("d", 0, 0, &q)).is_none());
        assert!(c.get(&key_for("e", 0, 0, &q)).is_some());
        assert_eq!(c.len(), 1);
        // one 1-query artifact: r_obs (8) + lazy alphas (8) + row index
        assert_eq!(c.bytes(), 16 + ROW_INDEX_BYTES_PER_QUERY);
        assert_eq!(c.stats().evictions, 0, "purges are not evictions");

        let off = NeighborCache::new(0, NO_BYTE_CAP);
        assert!(!off.enabled());
        off.put(key_for("d", 0, 0, &q), &q, artifact(1.0, 1));
        assert!(off.get(&key_for("d", 0, 0, &q)).is_none());
        assert!(matches!(off.lookup(&key_for("d", 0, 0, &q), &q), CacheOutcome::Miss));
        assert!(off.is_empty());
    }

    #[test]
    fn byte_budget_bounds_memory() {
        // one 8-query artifact with a width-4 table, weighed truthfully:
        // r_obs 64 + lazy alphas 64 + table 8*4*4=128 + row index 8*24=192
        fn big(tag: f64) -> Arc<NeighborArtifact> {
            Arc::new(NeighborArtifact::new(
                vec![tag; 8],
                1.0,
                AidwParams::default(),
                Some(NeighborTable { idx: vec![0; 32], width: 4 }),
                0.0,
            ))
        }
        const W: usize = 64 + 64 + 128 + 8 * ROW_INDEX_BYTES_PER_QUERY;
        let budget = 2 * W;
        let c = NeighborCache::new(64, budget);
        let (q1, q2, q3) = (raster(1, 8), raster(2, 8), raster(3, 8));
        c.put(key_for("d", 0, 0, &q1), &q1, big(1.0));
        assert_eq!(c.bytes(), W, "entry weight covers every retained buffer");
        c.put(key_for("d", 0, 0, &q2), &q2, big(2.0));
        assert_eq!((c.len(), c.bytes()), (2, budget));
        c.put(key_for("d", 0, 0, &q3), &q3, big(3.0));
        assert_eq!((c.len(), c.bytes()), (2, budget), "byte budget evicts the LRU");
        assert_eq!(c.stats().evictions, 1);
        assert!(c.get(&key_for("d", 0, 0, &q1)).is_none());
        assert!(c.get(&key_for("d", 0, 0, &q3)).is_some());
        // a full cache never exceeds max_bytes once an insert completes —
        // even when the lazy alphas materialize only *after* insertion
        // (their bytes were charged up front)
        let hit = c.get(&key_for("d", 0, 0, &q2)).unwrap();
        let _ = hit.alphas();
        assert!(hit.alphas_materialized());
        assert!(c.bytes() <= budget, "materializing alphas must not bust the budget");
        assert_eq!(c.bytes(), budget, "alpha bytes were already accounted");
        // an artifact bigger than the whole budget is not cached at all
        let huge = raster(4, 1000);
        c.put(key_for("d", 0, 0, &huge), &huge, artifact(4.0, 1000));
        assert!(c.get(&key_for("d", 0, 0, &huge)).is_none());
        assert_eq!(c.len(), 2, "oversized artifact left the cache untouched");
    }

    #[test]
    fn coord_index_survives_replace_evict_and_purge() {
        let c = NeighborCache::new(2, NO_BYTE_CAP);
        let (q1, q2) = (raster(1, 4), raster(2, 4));
        c.put(key_for("d", 0, 0, &q1), &q1, artifact(1.0, 4));
        c.put(key_for("e", 0, 0, &q2), &q2, artifact(2.0, 4));
        // covering probe resolves through the coordinate index
        let sub = vec![q1[2], q1[0]];
        assert!(matches!(
            c.lookup(&key_for("d", 0, 0, &sub), &sub),
            CacheOutcome::Subset { .. }
        ));
        // same-key replace: the fresh artifact serves (no stale posting)
        c.put(key_for("d", 0, 0, &q1), &q1, artifact(9.0, 4));
        match c.lookup(&key_for("d", 0, 0, &sub), &sub) {
            CacheOutcome::Subset { artifact: got, .. } => {
                assert_eq!(got.r_obs, vec![9.0, 9.0]);
            }
            _ => panic!("replaced entry must still cover"),
        }
        // evict both original entries (capacity 2) with two new rasters
        let (q3, q4) = (raster(3, 4), raster(4, 4));
        c.put(key_for("f", 0, 0, &q3), &q3, artifact(3.0, 4));
        c.put(key_for("g", 0, 0, &q4), &q4, artifact(4.0, 4));
        assert!(
            matches!(c.lookup(&key_for("d", 0, 0, &sub), &sub), CacheOutcome::Miss),
            "an evicted entry must not serve via a stale index posting"
        );
        // purge one dataset: its postings vanish, the survivor's keep serving
        c.purge_dataset("g");
        let sub4 = vec![q4[0]];
        assert!(matches!(
            c.lookup(&key_for("g", 0, 0, &sub4), &sub4),
            CacheOutcome::Miss
        ));
        let sub3 = vec![q3[3], q3[1]];
        match c.lookup(&key_for("f", 0, 0, &sub3), &sub3) {
            CacheOutcome::Subset { artifact: got, .. } => {
                assert_eq!(got.r_obs, vec![3.0, 3.0]);
            }
            _ => panic!("survivor must still cover after a purge"),
        }
    }

    #[test]
    fn fingerprint_sensitivity() {
        let a = query_fingerprint(&[(1.0, 2.0), (3.0, 4.0)]);
        let b = query_fingerprint(&[(1.0, 2.0), (3.0, 4.0)]);
        assert_eq!(a, b);
        assert_ne!(a, query_fingerprint(&[(1.0, 2.0), (3.0, 4.000001)]));
        assert_ne!(a, query_fingerprint(&[(3.0, 4.0), (1.0, 2.0)]), "order matters");
        // -0.0 and 0.0 are different rasters bit-wise; the fingerprint
        // distinguishes them (conservative: a miss merely recomputes)
        assert_ne!(query_fingerprint(&[(0.0, 0.0)]), query_fingerprint(&[(-0.0, 0.0)]));
    }
}
