//! The epoch-keyed neighbor cache: a bounded LRU of stage-1 products
//! ([`NeighborArtifact`]) so a repeated raster — the dominant serving
//! pattern for DEM/tile workloads — skips the kNN search entirely.
//!
//! ## Key & invalidation rules
//!
//! An entry is keyed on `(dataset, served epoch, Stage1Key, query-set
//! fingerprint, query count)`.  Correctness rests on three rules:
//!
//! 1. **Only compacted snapshots are cached or served from the cache.**
//!    A mutated snapshot (non-empty delta overlay) changes with every
//!    append/remove while keeping its epoch, so its stage-1 products are
//!    never inserted and never looked up — any mutation therefore
//!    invalidates the cache for that dataset *implicitly* (lookups bypass
//!    it until the overlay is folded).
//! 2. **Compaction bumps the epoch**, so post-compaction lookups miss the
//!    pre-compaction entries by key; stale epochs age out of the LRU.
//! 3. **Registering over or dropping a dataset purges its entries**
//!    explicitly (same name + epoch 0 would otherwise collide with a
//!    different point set).
//!
//! The store is a small `Mutex<VecDeque>` scanned linearly: capacities
//! are tens of entries (each potentially megabytes of artifact), so a
//! hash map would buy nothing — and `Stage1Key` holds `f64`s, which have
//! no `Eq`/`Hash`.  Queries are identified by a 128-bit FNV-1a
//! fingerprint of their raw bits plus the exact count; two distinct
//! rasters colliding on both fingerprint halves is beyond-astronomical,
//! and a false hit is the only way this cache could ever change answers.

use std::collections::VecDeque;
use std::sync::{Arc, Mutex};

use crate::aidw::plan::NeighborArtifact;

use super::options::Stage1Key;

/// Full identity of one cached stage-1 product.
#[derive(Debug, Clone, PartialEq)]
pub struct CacheKey {
    pub dataset: String,
    /// The epoch of the (compacted) snapshot the artifact was computed
    /// from.
    pub epoch: u64,
    /// Identity of the epoch base ([`crate::coordinator::Dataset::uid`],
    /// a process-unique monotonic counter): a backstop against the
    /// register-over race where an in-flight batch of a displaced dataset
    /// could insert under the same `(name, epoch)` as its replacement
    /// after the purge.
    pub instance: u64,
    pub stage1: Stage1Key,
    /// 128-bit query-set fingerprint (see [`query_fingerprint`]).
    pub queries_fp: (u64, u64),
    pub n_queries: usize,
}

/// Two independent 64-bit FNV-1a passes over the queries' raw f64 bits.
pub fn query_fingerprint(queries: &[(f64, f64)]) -> (u64, u64) {
    fn fnv(queries: &[(f64, f64)], mut h: u64) -> u64 {
        const PRIME: u64 = 0x0000_0100_0000_01B3;
        for &(x, y) in queries {
            for b in x.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
            for b in y.to_bits().to_le_bytes() {
                h ^= b as u64;
                h = h.wrapping_mul(PRIME);
            }
        }
        h
    }
    (
        fnv(queries, 0xcbf2_9ce4_8422_2325),
        fnv(queries, 0x9e37_79b9_7f4a_7c15),
    )
}

/// Approximate heap bytes one artifact retains (the eviction weight).
fn artifact_bytes(a: &NeighborArtifact) -> usize {
    a.r_obs.len() * 8
        + a.alphas.len() * 8
        + a.neighbors.as_ref().map_or(0, |t| t.idx.len() * 4)
}

#[derive(Debug, Default)]
struct CacheState {
    /// Front = most recently used.  Each entry carries its byte weight.
    entries: VecDeque<(CacheKey, Arc<NeighborArtifact>, usize)>,
    bytes: usize,
}

/// Bounded LRU of stage-1 artifacts, capped both by entry count and by
/// approximate resident bytes (large-raster artifacts are megabytes
/// each; an entry-only bound would let memory scale with raster size).
/// `capacity == 0` disables caching; an artifact larger than the whole
/// byte budget is simply not cached.
#[derive(Debug, Default)]
pub struct NeighborCache {
    inner: Mutex<CacheState>,
    capacity: usize,
    max_bytes: usize,
}

impl NeighborCache {
    pub fn new(capacity: usize, max_bytes: usize) -> NeighborCache {
        NeighborCache { inner: Mutex::new(CacheState::default()), capacity, max_bytes }
    }

    /// True when the cache can hold anything at all.
    pub fn enabled(&self) -> bool {
        self.capacity > 0
    }

    /// Look up an artifact; a hit is promoted to most-recently-used.
    pub fn get(&self, key: &CacheKey) -> Option<Arc<NeighborArtifact>> {
        if self.capacity == 0 {
            return None;
        }
        let mut st = self.inner.lock().unwrap();
        let pos = st.entries.iter().position(|(k, _, _)| k == key)?;
        let entry = st.entries.remove(pos).unwrap();
        let art = entry.1.clone();
        st.entries.push_front(entry);
        Some(art)
    }

    /// Insert (or refresh) an artifact, evicting least-recently-used
    /// entries beyond the entry or byte bound.
    pub fn put(&self, key: CacheKey, artifact: Arc<NeighborArtifact>) {
        if self.capacity == 0 {
            return;
        }
        let weight = artifact_bytes(&artifact);
        if self.max_bytes > 0 && weight > self.max_bytes {
            return; // would evict everything and still bust the budget
        }
        let mut st = self.inner.lock().unwrap();
        if let Some(pos) = st.entries.iter().position(|(k, _, _)| *k == key) {
            let (_, _, w) = st.entries.remove(pos).unwrap();
            st.bytes -= w;
        }
        st.entries.push_front((key, artifact, weight));
        st.bytes += weight;
        while st.entries.len() > self.capacity
            || (self.max_bytes > 0 && st.bytes > self.max_bytes)
        {
            match st.entries.pop_back() {
                Some((_, _, w)) => st.bytes -= w,
                None => break,
            }
        }
    }

    /// Drop every entry of one dataset (register-over / drop paths).
    pub fn purge_dataset(&self, dataset: &str) {
        let mut st = self.inner.lock().unwrap();
        st.entries.retain(|(k, _, _)| k.dataset != dataset);
        st.bytes = st.entries.iter().map(|(_, _, w)| *w).sum();
    }

    /// Entries currently held (diagnostics).
    pub fn len(&self) -> usize {
        self.inner.lock().unwrap().entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Approximate resident bytes (diagnostics).
    pub fn bytes(&self) -> usize {
        self.inner.lock().unwrap().bytes
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::options::ResolvedOptions;

    fn key(dataset: &str, epoch: u64, fp: u64) -> CacheKey {
        CacheKey {
            dataset: dataset.to_string(),
            epoch,
            instance: 7,
            stage1: ResolvedOptions::default().stage1_key(),
            queries_fp: (fp, fp ^ 0xABCD),
            n_queries: 3,
        }
    }

    fn artifact(tag: f64) -> Arc<NeighborArtifact> {
        Arc::new(NeighborArtifact {
            r_obs: vec![tag],
            alphas: vec![tag],
            neighbors: None,
            stage1_s: 0.0,
        })
    }

    const NO_BYTE_CAP: usize = usize::MAX;

    #[test]
    fn lru_evicts_oldest_and_promotes_hits() {
        let c = NeighborCache::new(2, NO_BYTE_CAP);
        assert!(c.enabled());
        c.put(key("d", 0, 1), artifact(1.0));
        c.put(key("d", 0, 2), artifact(2.0));
        // touch entry 1 so entry 2 becomes the LRU victim
        assert!(c.get(&key("d", 0, 1)).is_some());
        c.put(key("d", 0, 3), artifact(3.0));
        assert_eq!(c.len(), 2);
        assert!(c.get(&key("d", 0, 2)).is_none(), "LRU evicted");
        assert!(c.get(&key("d", 0, 1)).is_some());
        assert!(c.get(&key("d", 0, 3)).is_some());
    }

    #[test]
    fn epoch_and_dataset_separate_entries() {
        let c = NeighborCache::new(8, NO_BYTE_CAP);
        c.put(key("d", 0, 1), artifact(1.0));
        assert!(c.get(&key("d", 1, 1)).is_none(), "epoch mismatch misses");
        assert!(c.get(&key("e", 0, 1)).is_none(), "dataset mismatch misses");
        let hit = c.get(&key("d", 0, 1)).unwrap();
        assert_eq!(hit.r_obs, vec![1.0]);
    }

    #[test]
    fn purge_and_disable() {
        let c = NeighborCache::new(4, NO_BYTE_CAP);
        c.put(key("d", 0, 1), artifact(1.0));
        c.put(key("e", 0, 1), artifact(2.0));
        assert!(c.bytes() > 0);
        c.purge_dataset("d");
        assert!(c.get(&key("d", 0, 1)).is_none());
        assert!(c.get(&key("e", 0, 1)).is_some());
        assert_eq!(c.len(), 1);
        assert_eq!(c.bytes(), 16, "one 1-query artifact (r_obs + alphas)");

        let off = NeighborCache::new(0, NO_BYTE_CAP);
        assert!(!off.enabled());
        off.put(key("d", 0, 1), artifact(1.0));
        assert!(off.get(&key("d", 0, 1)).is_none());
        assert!(off.is_empty());
    }

    #[test]
    fn byte_budget_bounds_memory() {
        fn big(tag: f64, n: usize) -> Arc<NeighborArtifact> {
            Arc::new(NeighborArtifact {
                r_obs: vec![tag; n],
                alphas: vec![tag; n],
                neighbors: None,
                stage1_s: 0.0,
            })
        }
        // each 8-query artifact weighs 8 * 16 = 128 bytes; budget = 2
        let c = NeighborCache::new(64, 256);
        c.put(key("d", 0, 1), big(1.0, 8));
        c.put(key("d", 0, 2), big(2.0, 8));
        assert_eq!((c.len(), c.bytes()), (2, 256));
        c.put(key("d", 0, 3), big(3.0, 8));
        assert_eq!((c.len(), c.bytes()), (2, 256), "byte budget evicts the LRU");
        assert!(c.get(&key("d", 0, 1)).is_none());
        assert!(c.get(&key("d", 0, 3)).is_some());
        // an artifact bigger than the whole budget is not cached at all
        c.put(key("d", 0, 4), big(4.0, 1000));
        assert!(c.get(&key("d", 0, 4)).is_none());
        assert_eq!(c.len(), 2, "oversized artifact left the cache untouched");
    }

    #[test]
    fn fingerprint_sensitivity() {
        let a = query_fingerprint(&[(1.0, 2.0), (3.0, 4.0)]);
        let b = query_fingerprint(&[(1.0, 2.0), (3.0, 4.0)]);
        assert_eq!(a, b);
        assert_ne!(a, query_fingerprint(&[(1.0, 2.0), (3.0, 4.000001)]));
        assert_ne!(a, query_fingerprint(&[(3.0, 4.0), (1.0, 2.0)]), "order matters");
        // -0.0 and 0.0 are different rasters bit-wise; the fingerprint
        // distinguishes them (conservative: a miss merely recomputes)
        assert_ne!(query_fingerprint(&[(0.0, 0.0)]), query_fingerprint(&[(-0.0, 0.0)]));
    }
}
