//! Dataset registry: named point sets with their prebuilt grid index.
//!
//! Building the even grid is a per-dataset cost, not a per-request cost —
//! the registry builds it once at registration (the serving analog of the
//! paper's one-time grid construction) and every request reuses it.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use crate::aidw::alpha;
use crate::error::{Error, Result};
use crate::geom::PointSet;
use crate::grid::{EvenGrid, GridConfig};
use crate::pool::Pool;

/// Process-wide monotonic id source for [`Dataset::uid`].
static NEXT_DATASET_UID: AtomicU64 = AtomicU64::new(1);

/// A registered dataset: points + spatial index + cached Eq.-2 constant.
#[derive(Debug)]
pub struct Dataset {
    pub name: String,
    pub points: PointSet,
    pub grid: EvenGrid,
    /// Expected NN distance (Eq. 2) over the dataset's own bounds.
    pub r_exp: f64,
    /// Study-region area used for r_exp.
    pub area: f64,
    /// Process-unique build id: every `Dataset::build` (registration or
    /// compaction epoch) gets a fresh value, never reused.  The neighbor
    /// cache keys on it so a stale entry of a displaced same-name dataset
    /// can never be mistaken for its replacement (an allocation address
    /// would be ABA-prone; a counter cannot repeat).
    pub uid: u64,
}

impl Dataset {
    /// Build a dataset: constructs the grid index immediately.
    pub fn build(
        pool: &Pool,
        name: &str,
        points: PointSet,
        grid_cfg: &GridConfig,
        area_override: Option<f64>,
    ) -> Result<Dataset> {
        if points.is_empty() {
            return Err(Error::InvalidArgument(format!("dataset '{name}' has no points")));
        }
        let grid = EvenGrid::build_on(pool, &points, None, grid_cfg)?;
        let area = area_override.unwrap_or_else(|| points.bounds().area().max(f64::MIN_POSITIVE));
        let r_exp = alpha::expected_nn_distance(points.len() as f64, area);
        Ok(Dataset {
            name: name.to_string(),
            points,
            grid,
            r_exp,
            area,
            uid: NEXT_DATASET_UID.fetch_add(1, Ordering::Relaxed),
        })
    }
}

/// Thread-safe name -> dataset map.
#[derive(Debug, Default)]
pub struct DatasetRegistry {
    // lock-order: dataset_registry
    map: RwLock<HashMap<String, Arc<Dataset>>>,
}

impl DatasetRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a dataset.  Returns the displaced entry when
    /// the name was already registered, so callers (notably the live
    /// compactor publishing a rebuilt epoch) can log/verify the retirement
    /// of the old index instead of silently dropping it.
    pub fn insert(&self, ds: Dataset) -> Option<Arc<Dataset>> {
        self.map.write().unwrap().insert(ds.name.clone(), Arc::new(ds))
    }

    /// Fetch by name.
    pub fn get(&self, name: &str) -> Result<Arc<Dataset>> {
        self.map
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::UnknownDataset(name.to_string()))
    }

    /// Remove a dataset; true if it existed.
    pub fn remove(&self, name: &str) -> bool {
        self.map.write().unwrap().remove(name).is_some()
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    #[test]
    fn build_and_lookup() {
        let reg = DatasetRegistry::new();
        let pool = Pool::new(2);
        let pts = workload::uniform_square(500, 50.0, 61);
        let ds = Dataset::build(&pool, "d1", pts, &GridConfig::default(), None).unwrap();
        assert!(ds.r_exp > 0.0);
        assert!(reg.insert(ds).is_none(), "fresh insert displaces nothing");
        assert_eq!(reg.len(), 1);
        let got = reg.get("d1").unwrap();
        assert_eq!(got.points.len(), 500);
        assert_eq!(got.grid.n_points(), 500);
        assert!(reg.get("nope").is_err());
        assert_eq!(reg.names(), vec!["d1".to_string()]);
        assert!(reg.remove("d1"));
        assert!(!reg.remove("d1"));
        assert!(reg.is_empty());
    }

    #[test]
    fn empty_dataset_rejected() {
        let pool = Pool::new(1);
        let r = Dataset::build(&pool, "e", PointSet::default(), &GridConfig::default(), None);
        assert!(r.is_err());
    }

    #[test]
    fn replace_updates_and_returns_displaced() {
        let reg = DatasetRegistry::new();
        let pool = Pool::new(1);
        let mut displaced = Vec::new();
        for n in [100usize, 200] {
            let pts = workload::uniform_square(n, 10.0, 62);
            displaced.push(
                reg.insert(Dataset::build(&pool, "d", pts, &GridConfig::default(), None).unwrap()),
            );
        }
        assert_eq!(reg.get("d").unwrap().points.len(), 200);
        assert_eq!(reg.len(), 1);
        // the replace path hands back the retired epoch for verification
        assert!(displaced[0].is_none());
        let old = displaced[1].as_ref().expect("replace returns the old dataset");
        assert_eq!(old.points.len(), 100);
    }

    #[test]
    fn area_override_changes_r_exp() {
        let pool = Pool::new(1);
        let pts = workload::uniform_square(100, 10.0, 63);
        let a = Dataset::build(&pool, "a", pts.clone(), &GridConfig::default(), None).unwrap();
        let b = Dataset::build(&pool, "b", pts, &GridConfig::default(), Some(1e6)).unwrap();
        assert!(b.r_exp > a.r_exp);
        assert_eq!(b.area, 1e6);
    }
}
