//! Coordinator metrics: lock-free counters + latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Exponential-bucket latency histogram (microseconds, 1us..~17min).
#[derive(Debug, Default)]
pub struct LatencyHisto {
    /// bucket i counts latencies in [2^i, 2^(i+1)) microseconds
    buckets: [AtomicU64; 30],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl LatencyHisto {
    /// Record one latency.
    pub fn record(&self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(29);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in seconds (0 when empty).
    pub fn mean_s(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Approximate quantile (bucket upper bound), seconds.
    pub fn quantile_s(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen >= target {
                return (1u64 << (i + 1)) as f64 / 1e6;
            }
        }
        (1u64 << 30) as f64 / 1e6
    }
}

/// Coordinator-wide counters.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub queries: AtomicU64,
    pub batches: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    /// Stage-1 (kNN + alpha) executions actually run by the planner —
    /// one per batch that missed the neighbor cache.  Two jobs coalesced
    /// on an equal stage-1 key bump this once, not twice.
    pub stage1_execs: AtomicU64,
    /// Batches served straight from the [`super::cache::NeighborCache`]
    /// (stage 1 skipped entirely) on an exact raster match.
    pub stage1_cache_hits: AtomicU64,
    /// Batches served by gathering a row subset out of a covering cached
    /// artifact (stage 1 equally skipped; per-query-row reuse).
    pub stage1_subset_hits: AtomicU64,
    /// Stage-2 executions (one per distinct stage-2 key per batch).
    pub stage2_execs: AtomicU64,
    /// Batches whose jobs spanned more than one stage-2 variant — the
    /// coalescing the stage-key split makes possible (such jobs would
    /// each have paid their own kNN sweep under full-options admission).
    pub coalesced_batches: AtomicU64,
    /// Tiles gathered out of covering cached artifacts during partial-
    /// cover stage-1 reuse (protocol v2.4; the whole-raster subset hit
    /// counts under `stage1_subset_hits` instead).
    pub stage1_tile_gathers: AtomicU64,
    /// Result tiles emitted by the stage-2 streaming executor (v2.4).
    pub stream_tiles: AtomicU64,
    /// Live raster subscriptions currently registered (gauge, v2.5).
    pub subs_active: AtomicU64,
    /// Post-mutation update pushes delivered to subscriptions (v2.5);
    /// a burst of mutations coalesces into one update.
    pub sub_updates: AtomicU64,
    /// Tiles pushed over subscription streams, initial + updates (v2.5).
    pub tiles_pushed: AtomicU64,
    /// Update tiles recomputed because the dirty-footprint bound flagged
    /// at least one of their rows (v2.5; excludes initial-raster tiles).
    pub tiles_dirty: AtomicU64,
    /// Update tiles *proven clean* and skipped — the subscriber kept its
    /// materialized values and no stage ran for them (v2.5).
    pub tiles_skipped_clean: AtomicU64,
    /// Peak values buffered between the stage-2 executor and any bounded
    /// stream consumer (gauge, v2.4): bounded by construction at
    /// `stream_buffer_tiles x tile_rows` — this gauge is the receipt.
    stream_peak_buffered: AtomicU64,
    /// Stage-1 wall time *not spent* thanks to cache/subset hits,
    /// accumulated from each served entry's recorded build time
    /// (microsecond fixed point; protocol v2.4 `stage1_saved_ms`).
    stage1_saved_us: AtomicU64,
    /// Cumulative stage seconds (microsecond fixed point).
    knn_us: AtomicU64,
    interp_us: AtomicU64,
    pub latency: LatencyHisto,
}

impl Metrics {
    pub fn add_stage_times(&self, knn_s: f64, interp_s: f64) {
        self.knn_us.fetch_add((knn_s * 1e6) as u64, Ordering::Relaxed);
        self.interp_us.fetch_add((interp_s * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn knn_seconds(&self) -> f64 {
        self.knn_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn interp_seconds(&self) -> f64 {
        self.interp_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Credit stage-1 wall seconds a cache/subset hit did not spend.
    pub fn add_stage1_saved(&self, seconds: f64) {
        self.stage1_saved_us
            .fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
    }

    /// Stage-1 milliseconds saved by the cache so far.
    pub fn stage1_saved_ms(&self) -> f64 {
        self.stage1_saved_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Raise the buffered-values peak gauge to at least `buffered`.
    pub fn note_stream_buffered(&self, buffered: usize) {
        self.stream_peak_buffered
            .fetch_max(buffered as u64, Ordering::Relaxed);
    }

    /// Plain-data snapshot for reporting (cache gauges zeroed; the
    /// coordinator composes them in via [`Metrics::snapshot_with`]).
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.snapshot_with(super::cache::CacheStats::default())
    }

    /// Snapshot with the neighbor-cache occupancy/eviction/hit-byte
    /// gauges folded in (protocol v2.3 metrics surface).
    pub fn snapshot_with(&self, cache: super::cache::CacheStats) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            stage1_execs: self.stage1_execs.load(Ordering::Relaxed),
            stage1_cache_hits: self.stage1_cache_hits.load(Ordering::Relaxed),
            stage1_subset_hits: self.stage1_subset_hits.load(Ordering::Relaxed),
            stage2_execs: self.stage2_execs.load(Ordering::Relaxed),
            coalesced_batches: self.coalesced_batches.load(Ordering::Relaxed),
            stage1_tile_gathers: self.stage1_tile_gathers.load(Ordering::Relaxed),
            stream_tiles: self.stream_tiles.load(Ordering::Relaxed),
            subs_active: self.subs_active.load(Ordering::Relaxed),
            sub_updates: self.sub_updates.load(Ordering::Relaxed),
            tiles_pushed: self.tiles_pushed.load(Ordering::Relaxed),
            tiles_dirty: self.tiles_dirty.load(Ordering::Relaxed),
            tiles_skipped_clean: self.tiles_skipped_clean.load(Ordering::Relaxed),
            stream_peak_buffered: self.stream_peak_buffered.load(Ordering::Relaxed),
            stage1_saved_ms: self.stage1_saved_ms(),
            cache_entries: cache.entries as u64,
            cache_bytes: cache.bytes as u64,
            cache_evictions: cache.evictions,
            cache_hit_bytes: cache.hit_bytes,
            knn_s: self.knn_seconds(),
            interp_s: self.interp_seconds(),
            mean_latency_s: self.latency.mean_s(),
            p99_latency_s: self.latency.quantile_s(0.99),
        }
    }
}

/// Point-in-time copy of the metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub queries: u64,
    pub batches: u64,
    pub rejected: u64,
    pub errors: u64,
    /// Planner stage-1 executions (cache misses).
    pub stage1_execs: u64,
    /// Batches served from the neighbor cache (exact raster match).
    pub stage1_cache_hits: u64,
    /// Batches served by subset row-gather out of a cached artifact.
    pub stage1_subset_hits: u64,
    /// Planner stage-2 executions (>= batches when variants coalesce).
    pub stage2_execs: u64,
    /// Batches that coalesced more than one stage-2 variant.
    pub coalesced_batches: u64,
    /// Tiles row-gathered out of covering cached artifacts during
    /// partial-cover stage-1 reuse (v2.4).
    pub stage1_tile_gathers: u64,
    /// Result tiles emitted by the streaming stage-2 executor (v2.4).
    pub stream_tiles: u64,
    /// Live raster subscriptions currently registered (gauge, v2.5).
    pub subs_active: u64,
    /// Post-mutation update pushes delivered to subscriptions (v2.5).
    pub sub_updates: u64,
    /// Tiles pushed over subscription streams, initial + updates (v2.5).
    pub tiles_pushed: u64,
    /// Update tiles recomputed as dirty (v2.5).
    pub tiles_dirty: u64,
    /// Update tiles proven clean and skipped (v2.5): the receipt that
    /// incremental maintenance did less work than a full recompute.
    pub tiles_skipped_clean: u64,
    /// Peak values buffered toward any bounded stream consumer (v2.4).
    pub stream_peak_buffered: u64,
    /// Stage-1 wall milliseconds the neighbor cache saved (v2.4): each
    /// hit credits the served entry's recorded build time, making the
    /// cache's win directly visible in dashboards.
    pub stage1_saved_ms: f64,
    /// Neighbor-cache occupancy: resident entries (gauge, v2.3).
    pub cache_entries: u64,
    /// Neighbor-cache occupancy: approximate resident bytes (gauge, v2.3).
    pub cache_bytes: u64,
    /// Entries evicted by the LRU bounds since startup (v2.3).
    pub cache_evictions: u64,
    /// Artifact bytes served from the cache since startup (v2.3).
    pub cache_hit_bytes: u64,
    pub knn_s: f64,
    pub interp_s: f64,
    pub mean_latency_s: f64,
    pub p99_latency_s: f64,
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histo_mean_and_quantile() {
        let h = LatencyHisto::default();
        for _ in 0..90 {
            h.record(0.001); // 1000us -> bucket 9
        }
        for _ in 0..10 {
            h.record(0.1); // 100000us
        }
        assert_eq!(h.count(), 100);
        let mean = h.mean_s();
        assert!((mean - 0.0109).abs() < 1e-3, "{mean}");
        assert!(h.quantile_s(0.5) < 0.01);
        assert!(h.quantile_s(0.99) > 0.05);
    }

    #[test]
    fn empty_histo() {
        let h = LatencyHisto::default();
        assert_eq!(h.mean_s(), 0.0);
        assert_eq!(h.quantile_s(0.99), 0.0);
    }

    #[test]
    fn stage1_saved_and_stream_gauges() {
        let m = Metrics::default();
        m.add_stage1_saved(0.002);
        m.add_stage1_saved(0.0005);
        assert!((m.stage1_saved_ms() - 2.5).abs() < 1e-6);
        // the peak gauge only ever rises
        m.note_stream_buffered(80);
        m.note_stream_buffered(40);
        let s = m.snapshot();
        assert!((s.stage1_saved_ms - 2.5).abs() < 1e-6);
        assert_eq!(s.stream_peak_buffered, 80);
        assert_eq!(s.stream_tiles, 0);
        assert_eq!(s.stage1_tile_gathers, 0);
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.add_stage_times(1.5, 2.5);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert!((s.knn_s - 1.5).abs() < 1e-5);
        assert!((s.interp_s - 2.5).abs() < 1e-5);
    }

    #[test]
    fn subscription_counters_snapshot() {
        let m = Metrics::default();
        m.subs_active.fetch_add(2, Ordering::Relaxed);
        m.sub_updates.fetch_add(5, Ordering::Relaxed);
        m.tiles_pushed.fetch_add(9, Ordering::Relaxed);
        m.tiles_dirty.fetch_add(4, Ordering::Relaxed);
        m.tiles_skipped_clean.fetch_add(11, Ordering::Relaxed);
        m.subs_active.fetch_sub(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.subs_active, 1, "gauge settles on unregister");
        assert_eq!(s.sub_updates, 5);
        assert_eq!(s.tiles_pushed, 9);
        assert_eq!(s.tiles_dirty, 4);
        assert_eq!(s.tiles_skipped_clean, 11);
    }
}
