//! Coordinator metrics: lock-free counters + latency histogram.

use std::sync::atomic::{AtomicU64, Ordering};

/// Exponential-bucket latency histogram (microseconds, 1us..~17min).
#[derive(Debug, Default)]
pub struct LatencyHisto {
    /// bucket i counts latencies in [2^i, 2^(i+1)) microseconds
    buckets: [AtomicU64; 30],
    sum_us: AtomicU64,
    count: AtomicU64,
}

impl LatencyHisto {
    /// Record one latency.
    pub fn record(&self, seconds: f64) {
        let us = (seconds * 1e6).max(0.0) as u64;
        let idx = (64 - us.max(1).leading_zeros() as usize - 1).min(29);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    /// Number of recorded samples.
    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean latency in seconds (0 when empty).
    pub fn mean_s(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_us.load(Ordering::Relaxed) as f64 / c as f64 / 1e6
    }

    /// Approximate quantile, seconds, interpolated within the matched
    /// bucket.  (The pre-v2.6 version returned the bucket *upper* bound,
    /// which overstated p99 by up to 2x on power-of-two buckets — a
    /// sample at 1100us reported as 2048us.)
    pub fn quantile_s(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil().max(1.0) as u64;
        let mut seen = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if seen + c >= target {
                // bucket i spans [2^i, 2^(i+1)) us: place the quantile at
                // the rank's fraction through the bucket instead of its
                // upper edge (bucket 29 is the clamped catch-all; its
                // nominal width keeps the estimate finite)
                let lo = (1u64 << i) as f64;
                let frac = (target - seen) as f64 / c as f64;
                return (lo + frac * lo) / 1e6;
            }
            seen += c;
        }
        (1u64 << 30) as f64 / 1e6
    }

    /// Plain copy of the per-bucket counts (bucket i counts samples in
    /// [2^i, 2^(i+1)) us) — the exposition surface protocol v2.6 opens.
    pub fn bucket_counts(&self) -> [u64; 30] {
        let mut out = [0u64; 30];
        for (o, b) in out.iter_mut().zip(self.buckets.iter()) {
            *o = b.load(Ordering::Relaxed);
        }
        out
    }

    /// Upper bound of bucket `i` in seconds (the Prometheus `le` label).
    pub fn bucket_le_s(i: usize) -> f64 {
        (1u64 << (i + 1)) as f64 / 1e6
    }
}

/// Coordinator-wide counters.
#[derive(Debug, Default)]
pub struct Metrics {
    pub requests: AtomicU64,
    pub queries: AtomicU64,
    pub batches: AtomicU64,
    pub rejected: AtomicU64,
    pub errors: AtomicU64,
    /// Stage-1 (kNN + alpha) executions actually run by the planner —
    /// one per batch that missed the neighbor cache.  Two jobs coalesced
    /// on an equal stage-1 key bump this once, not twice.
    pub stage1_execs: AtomicU64,
    /// Batches served straight from the [`super::cache::NeighborCache`]
    /// (stage 1 skipped entirely) on an exact raster match.
    pub stage1_cache_hits: AtomicU64,
    /// Batches served by gathering a row subset out of a covering cached
    /// artifact (stage 1 equally skipped; per-query-row reuse).
    pub stage1_subset_hits: AtomicU64,
    /// Stage-2 executions (one per distinct stage-2 key per batch).
    pub stage2_execs: AtomicU64,
    /// Batches whose jobs spanned more than one stage-2 variant — the
    /// coalescing the stage-key split makes possible (such jobs would
    /// each have paid their own kNN sweep under full-options admission).
    pub coalesced_batches: AtomicU64,
    /// Tiles gathered out of covering cached artifacts during partial-
    /// cover stage-1 reuse (protocol v2.4; the whole-raster subset hit
    /// counts under `stage1_subset_hits` instead).
    pub stage1_tile_gathers: AtomicU64,
    /// Result tiles emitted by the stage-2 streaming executor (v2.4).
    pub stream_tiles: AtomicU64,
    /// Live raster subscriptions currently registered (gauge, v2.5).
    pub subs_active: AtomicU64,
    /// Post-mutation update pushes delivered to subscriptions (v2.5);
    /// a burst of mutations coalesces into one update.
    pub sub_updates: AtomicU64,
    /// Tiles pushed over subscription streams, initial + updates (v2.5).
    pub tiles_pushed: AtomicU64,
    /// Update tiles recomputed because the dirty-footprint bound flagged
    /// at least one of their rows (v2.5; excludes initial-raster tiles).
    pub tiles_dirty: AtomicU64,
    /// Update tiles *proven clean* and skipped — the subscriber kept its
    /// materialized values and no stage ran for them (v2.5).
    pub tiles_skipped_clean: AtomicU64,
    /// Peak values buffered between the stage-2 executor and any bounded
    /// stream consumer (gauge, v2.4): bounded by construction at
    /// `stream_buffer_tiles x tile_rows` — this gauge is the receipt.
    stream_peak_buffered: AtomicU64,
    /// Stage-1 wall time *not spent* thanks to cache/subset hits,
    /// accumulated from each served entry's recorded build time
    /// (microsecond fixed point; protocol v2.4 `stage1_saved_ms`).
    stage1_saved_us: AtomicU64,
    /// Cumulative stage seconds (microsecond fixed point).
    knn_us: AtomicU64,
    interp_us: AtomicU64,
    pub latency: LatencyHisto,
    /// Subscription push lag: mutation capture instant → update frames
    /// delivered (v2.6).  Answers "how stale is this feed?" — the gap the
    /// ROADMAP's scale-out work needs visible before sharding.
    pub sub_lag: LatencyHisto,
    /// Requests/subscriptions rejected by tenant admission — token
    /// bucket exhausted or in-flight quota reached (v2.8, fail-closed).
    pub over_quota: AtomicU64,
    /// Per-shard sweep tasks executed by the shard worker pool (v2.8;
    /// one chunked scatter task per counted unit, not one per batch).
    pub shard_stage1_tasks: AtomicU64,
    /// Query rows whose exact termination ball escaped their shard's
    /// clip region and re-ran against the whole grid (v2.8) — the
    /// correctness escape hatch that keeps sharding bit-identical.
    pub shard_escalated_rows: AtomicU64,
    /// Subscription dirty-tile recomputes executed on the shard worker
    /// pool instead of the subscription worker thread (v2.8).
    pub shard_sub_recomputes: AtomicU64,
}

impl Metrics {
    pub fn add_stage_times(&self, knn_s: f64, interp_s: f64) {
        self.knn_us.fetch_add((knn_s * 1e6) as u64, Ordering::Relaxed);
        self.interp_us.fetch_add((interp_s * 1e6) as u64, Ordering::Relaxed);
    }

    pub fn knn_seconds(&self) -> f64 {
        self.knn_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    pub fn interp_seconds(&self) -> f64 {
        self.interp_us.load(Ordering::Relaxed) as f64 / 1e6
    }

    /// Credit stage-1 wall seconds a cache/subset hit did not spend.
    pub fn add_stage1_saved(&self, seconds: f64) {
        self.stage1_saved_us
            .fetch_add((seconds * 1e6) as u64, Ordering::Relaxed);
    }

    /// Stage-1 milliseconds saved by the cache so far.
    pub fn stage1_saved_ms(&self) -> f64 {
        self.stage1_saved_us.load(Ordering::Relaxed) as f64 / 1e3
    }

    /// Raise the buffered-values peak gauge to at least `buffered`.
    pub fn note_stream_buffered(&self, buffered: usize) {
        self.stream_peak_buffered
            .fetch_max(buffered as u64, Ordering::Relaxed);
    }

    /// Fold one sharded stage-1 execution's facts into the counters
    /// (no-op for unsharded passthroughs, which submit no pool tasks).
    pub fn record_shard_sweep(&self, sweep: &crate::shard::SweepStats) {
        if !sweep.sharded {
            return;
        }
        self.shard_stage1_tasks.fetch_add(sweep.tasks, Ordering::Relaxed);
        self.shard_escalated_rows
            .fetch_add(sweep.escalated, Ordering::Relaxed);
    }

    /// Plain-data snapshot for reporting (cache gauges zeroed; the
    /// coordinator composes them in via [`Metrics::snapshot_with`]).
    pub fn snapshot(&self) -> MetricsSnapshot {
        self.snapshot_with(super::cache::CacheStats::default())
    }

    /// Snapshot with the neighbor-cache occupancy/eviction/hit-byte
    /// gauges folded in (protocol v2.3 metrics surface).
    pub fn snapshot_with(&self, cache: super::cache::CacheStats) -> MetricsSnapshot {
        MetricsSnapshot {
            requests: self.requests.load(Ordering::Relaxed),
            queries: self.queries.load(Ordering::Relaxed),
            batches: self.batches.load(Ordering::Relaxed),
            rejected: self.rejected.load(Ordering::Relaxed),
            errors: self.errors.load(Ordering::Relaxed),
            stage1_execs: self.stage1_execs.load(Ordering::Relaxed),
            stage1_cache_hits: self.stage1_cache_hits.load(Ordering::Relaxed),
            stage1_subset_hits: self.stage1_subset_hits.load(Ordering::Relaxed),
            stage2_execs: self.stage2_execs.load(Ordering::Relaxed),
            coalesced_batches: self.coalesced_batches.load(Ordering::Relaxed),
            stage1_tile_gathers: self.stage1_tile_gathers.load(Ordering::Relaxed),
            stream_tiles: self.stream_tiles.load(Ordering::Relaxed),
            subs_active: self.subs_active.load(Ordering::Relaxed),
            sub_updates: self.sub_updates.load(Ordering::Relaxed),
            tiles_pushed: self.tiles_pushed.load(Ordering::Relaxed),
            tiles_dirty: self.tiles_dirty.load(Ordering::Relaxed),
            tiles_skipped_clean: self.tiles_skipped_clean.load(Ordering::Relaxed),
            stream_peak_buffered: self.stream_peak_buffered.load(Ordering::Relaxed),
            stage1_saved_ms: self.stage1_saved_ms(),
            cache_entries: cache.entries as u64,
            cache_bytes: cache.bytes as u64,
            cache_evictions: cache.evictions,
            cache_hit_bytes: cache.hit_bytes,
            knn_s: self.knn_seconds(),
            interp_s: self.interp_seconds(),
            mean_latency_s: self.latency.mean_s(),
            p50_latency_s: self.latency.quantile_s(0.50),
            p90_latency_s: self.latency.quantile_s(0.90),
            p99_latency_s: self.latency.quantile_s(0.99),
            sub_lag_mean_s: self.sub_lag.mean_s(),
            sub_lag_p99_s: self.sub_lag.quantile_s(0.99),
            sub_lag_count: self.sub_lag.count(),
            over_quota: self.over_quota.load(Ordering::Relaxed),
            shard_stage1_tasks: self.shard_stage1_tasks.load(Ordering::Relaxed),
            shard_escalated_rows: self.shard_escalated_rows.load(Ordering::Relaxed),
            shard_sub_recomputes: self.shard_sub_recomputes.load(Ordering::Relaxed),
            latency_buckets: self.latency.bucket_counts(),
            sub_lag_buckets: self.sub_lag.bucket_counts(),
        }
    }
}

/// Point-in-time copy of the metrics.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct MetricsSnapshot {
    pub requests: u64,
    pub queries: u64,
    pub batches: u64,
    pub rejected: u64,
    pub errors: u64,
    /// Planner stage-1 executions (cache misses).
    pub stage1_execs: u64,
    /// Batches served from the neighbor cache (exact raster match).
    pub stage1_cache_hits: u64,
    /// Batches served by subset row-gather out of a cached artifact.
    pub stage1_subset_hits: u64,
    /// Planner stage-2 executions (>= batches when variants coalesce).
    pub stage2_execs: u64,
    /// Batches that coalesced more than one stage-2 variant.
    pub coalesced_batches: u64,
    /// Tiles row-gathered out of covering cached artifacts during
    /// partial-cover stage-1 reuse (v2.4).
    pub stage1_tile_gathers: u64,
    /// Result tiles emitted by the streaming stage-2 executor (v2.4).
    pub stream_tiles: u64,
    /// Live raster subscriptions currently registered (gauge, v2.5).
    pub subs_active: u64,
    /// Post-mutation update pushes delivered to subscriptions (v2.5).
    pub sub_updates: u64,
    /// Tiles pushed over subscription streams, initial + updates (v2.5).
    pub tiles_pushed: u64,
    /// Update tiles recomputed as dirty (v2.5).
    pub tiles_dirty: u64,
    /// Update tiles proven clean and skipped (v2.5): the receipt that
    /// incremental maintenance did less work than a full recompute.
    pub tiles_skipped_clean: u64,
    /// Peak values buffered toward any bounded stream consumer (v2.4).
    pub stream_peak_buffered: u64,
    /// Stage-1 wall milliseconds the neighbor cache saved (v2.4): each
    /// hit credits the served entry's recorded build time, making the
    /// cache's win directly visible in dashboards.
    pub stage1_saved_ms: f64,
    /// Neighbor-cache occupancy: resident entries (gauge, v2.3).
    pub cache_entries: u64,
    /// Neighbor-cache occupancy: approximate resident bytes (gauge, v2.3).
    pub cache_bytes: u64,
    /// Entries evicted by the LRU bounds since startup (v2.3).
    pub cache_evictions: u64,
    /// Artifact bytes served from the cache since startup (v2.3).
    pub cache_hit_bytes: u64,
    pub knn_s: f64,
    pub interp_s: f64,
    pub mean_latency_s: f64,
    /// Median request latency, interpolated within its bucket (v2.6).
    pub p50_latency_s: f64,
    /// 90th-percentile request latency (v2.6).
    pub p90_latency_s: f64,
    pub p99_latency_s: f64,
    /// Mean subscription push lag, mutation capture → update delivered
    /// (v2.6; 0 until a mutate→push cycle has completed).
    pub sub_lag_mean_s: f64,
    /// 99th-percentile subscription push lag (v2.6).
    pub sub_lag_p99_s: f64,
    /// Subscription push-lag samples recorded (v2.6).
    pub sub_lag_count: u64,
    /// Tenant-admission rejections, fail-closed (v2.8).
    pub over_quota: u64,
    /// Per-shard sweep tasks run by the shard worker pool (v2.8).
    pub shard_stage1_tasks: u64,
    /// Rows escalated from a shard clip to the whole grid (v2.8) — the
    /// audit trail of the bit-identity escape hatch.
    pub shard_escalated_rows: u64,
    /// Subscription dirty-tile recomputes served by the shard pool (v2.8).
    pub shard_sub_recomputes: u64,
    /// Request-latency histogram buckets, bucket i = [2^i, 2^(i+1)) us
    /// (v2.6; previously private to [`LatencyHisto`]).
    pub latency_buckets: [u64; 30],
    /// Subscription push-lag histogram buckets (v2.6).
    pub sub_lag_buckets: [u64; 30],
}

/// Prometheus-style text exposition of a snapshot (protocol v2.6
/// `metrics_text` op and `aidw serve --metrics-text`).
///
/// Every scalar [`MetricsSnapshot`] field becomes one `aidw_<field>`
/// sample; the two histograms become cumulative `aidw_<field>{le="..."}`
/// series (plus `+Inf`) the way Prometheus histograms expect, so
/// `histogram_quantile()` works unmodified.  The metrics-parity CI gate
/// checks every snapshot field surfaces here *and* in the JSON `metrics`
/// op — adding a field without exporting it fails the build.
pub fn prometheus_text(s: &MetricsSnapshot) -> String {
    let mut out = String::with_capacity(2048);
    let mut scalar = |name: &str, v: f64| {
        out.push_str("aidw_");
        out.push_str(name);
        out.push(' ');
        out.push_str(&format_sample(v));
        out.push('\n');
    };
    scalar("requests", s.requests as f64);
    scalar("queries", s.queries as f64);
    scalar("batches", s.batches as f64);
    scalar("rejected", s.rejected as f64);
    scalar("errors", s.errors as f64);
    scalar("stage1_execs", s.stage1_execs as f64);
    scalar("stage1_cache_hits", s.stage1_cache_hits as f64);
    scalar("stage1_subset_hits", s.stage1_subset_hits as f64);
    scalar("stage2_execs", s.stage2_execs as f64);
    scalar("coalesced_batches", s.coalesced_batches as f64);
    scalar("stage1_tile_gathers", s.stage1_tile_gathers as f64);
    scalar("stream_tiles", s.stream_tiles as f64);
    scalar("subs_active", s.subs_active as f64);
    scalar("sub_updates", s.sub_updates as f64);
    scalar("tiles_pushed", s.tiles_pushed as f64);
    scalar("tiles_dirty", s.tiles_dirty as f64);
    scalar("tiles_skipped_clean", s.tiles_skipped_clean as f64);
    scalar("stream_peak_buffered", s.stream_peak_buffered as f64);
    scalar("stage1_saved_ms", s.stage1_saved_ms);
    scalar("cache_entries", s.cache_entries as f64);
    scalar("cache_bytes", s.cache_bytes as f64);
    scalar("cache_evictions", s.cache_evictions as f64);
    scalar("cache_hit_bytes", s.cache_hit_bytes as f64);
    scalar("knn_s", s.knn_s);
    scalar("interp_s", s.interp_s);
    scalar("mean_latency_s", s.mean_latency_s);
    scalar("p50_latency_s", s.p50_latency_s);
    scalar("p90_latency_s", s.p90_latency_s);
    scalar("p99_latency_s", s.p99_latency_s);
    scalar("sub_lag_mean_s", s.sub_lag_mean_s);
    scalar("sub_lag_p99_s", s.sub_lag_p99_s);
    scalar("sub_lag_count", s.sub_lag_count as f64);
    scalar("over_quota", s.over_quota as f64);
    scalar("shard_stage1_tasks", s.shard_stage1_tasks as f64);
    scalar("shard_escalated_rows", s.shard_escalated_rows as f64);
    scalar("shard_sub_recomputes", s.shard_sub_recomputes as f64);
    histogram(&mut out, "latency_buckets", &s.latency_buckets);
    histogram(&mut out, "sub_lag_buckets", &s.sub_lag_buckets);
    out
}

fn histogram(out: &mut String, name: &str, buckets: &[u64; 30]) {
    let mut cumulative = 0u64;
    for (i, &c) in buckets.iter().enumerate() {
        cumulative += c;
        out.push_str(&format!(
            "aidw_{name}{{le=\"{}\"}} {cumulative}\n",
            format_sample(LatencyHisto::bucket_le_s(i))
        ));
    }
    out.push_str(&format!("aidw_{name}{{le=\"+Inf\"}} {cumulative}\n"));
}

/// Render a sample value: integers without a decimal point, everything
/// else via shortest-roundtrip float formatting.
fn format_sample(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 9.007_199_254_740_992e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histo_mean_and_quantile() {
        let h = LatencyHisto::default();
        for _ in 0..90 {
            h.record(0.001); // 1000us -> bucket 9
        }
        for _ in 0..10 {
            h.record(0.1); // 100000us
        }
        assert_eq!(h.count(), 100);
        let mean = h.mean_s();
        assert!((mean - 0.0109).abs() < 1e-3, "{mean}");
        assert!(h.quantile_s(0.5) < 0.01);
        assert!(h.quantile_s(0.99) > 0.05);
    }

    #[test]
    fn empty_histo() {
        let h = LatencyHisto::default();
        assert_eq!(h.mean_s(), 0.0);
        assert_eq!(h.quantile_s(0.99), 0.0);
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        // pre-v2.6 this returned the bucket upper bound: 100 identical
        // 1000us samples reported p99 = 2048us, a 2x overstatement
        let h = LatencyHisto::default();
        for _ in 0..100 {
            h.record(0.001); // 1000us -> bucket 9 = [512, 1024)us
        }
        let p99 = h.quantile_s(0.99);
        assert!(p99 < 1024.0 / 1e6, "p99 {p99} must stay inside the bucket");
        assert!(p99 >= 512.0 / 1e6, "p99 {p99} below bucket lower bound");
        // a single sample lands mid-estimate, not at the upper edge
        let one = LatencyHisto::default();
        one.record(0.001);
        assert!(one.quantile_s(0.5) < 1024.0 / 1e6);
        // quantile ordering holds under interpolation
        let mixed = LatencyHisto::default();
        for _ in 0..90 {
            mixed.record(0.001);
        }
        for _ in 0..10 {
            mixed.record(0.1);
        }
        assert!(mixed.quantile_s(0.5) <= mixed.quantile_s(0.9));
        assert!(mixed.quantile_s(0.9) <= mixed.quantile_s(0.99));
    }

    #[test]
    fn bucket_counts_surface_in_snapshot() {
        let m = Metrics::default();
        m.latency.record(0.001); // bucket 9
        m.sub_lag.record(0.004); // 4000us -> bucket 11
        let s = m.snapshot();
        assert_eq!(s.latency_buckets[9], 1);
        assert_eq!(s.latency_buckets.iter().sum::<u64>(), 1);
        assert_eq!(s.sub_lag_buckets[11], 1);
        assert_eq!(s.sub_lag_count, 1);
        assert!(s.sub_lag_mean_s > 0.0);
        assert!(s.sub_lag_p99_s > 0.0);
        assert!(s.p50_latency_s > 0.0 && s.p50_latency_s <= s.p90_latency_s);
        assert!(s.p90_latency_s <= s.p99_latency_s);
    }

    #[test]
    fn prometheus_text_shapes() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.latency.record(0.001);
        let text = prometheus_text(&m.snapshot());
        assert!(text.contains("aidw_requests 3\n"));
        // cumulative histogram with +Inf terminator
        assert!(text.contains("aidw_latency_buckets{le=\"+Inf\"} 1\n"));
        // bucket 9's upper bound (1024us = 0.001024s) carries the sample
        assert!(text.contains("aidw_latency_buckets{le=\"0.001024\"} 1\n"), "{text}");
        // every line is `name[{labels}] value`
        for line in text.lines() {
            let mut parts = line.rsplitn(2, ' ');
            let value = parts.next().unwrap();
            assert!(value.parse::<f64>().is_ok(), "unparseable sample: {line}");
            assert!(parts.next().unwrap().starts_with("aidw_"), "{line}");
        }
    }

    #[test]
    fn metrics_parity_every_snapshot_field_in_both_encoders() {
        // the CI metrics-parity gate: introspect MetricsSnapshot's field
        // names out of its Debug rendering and require each to surface in
        // BOTH the JSON `metrics` op response and the Prometheus text
        // exposition — a field added to the snapshot but forgotten by an
        // encoder fails here, not in a dashboard weeks later
        let m = Metrics::default();
        m.latency.record(0.001);
        m.sub_lag.record(0.002);
        let s = m.snapshot();
        let debug = format!("{s:?}");
        let mut fields: Vec<String> = Vec::new();
        for tok in debug.split_whitespace() {
            if let Some(name) = tok.strip_suffix(':') {
                if name.chars().next().is_some_and(|c| c.is_ascii_alphabetic()) {
                    fields.push(name.to_string());
                }
            }
        }
        assert!(fields.len() >= 30, "Debug introspection broke: {fields:?}");
        assert!(fields.iter().any(|f| f == "sub_lag_p99_s"));
        let json = crate::service::protocol::ok_metrics(&s, &[]);
        let text = prometheus_text(&s);
        for f in &fields {
            assert!(json.contains(&format!("\"{f}\"")), "metrics op response missing field {f}");
            assert!(text.contains(&format!("aidw_{f}")), "metrics_text exposition missing {f}");
        }
    }

    #[test]
    fn stage1_saved_and_stream_gauges() {
        let m = Metrics::default();
        m.add_stage1_saved(0.002);
        m.add_stage1_saved(0.0005);
        assert!((m.stage1_saved_ms() - 2.5).abs() < 1e-6);
        // the peak gauge only ever rises
        m.note_stream_buffered(80);
        m.note_stream_buffered(40);
        let s = m.snapshot();
        assert!((s.stage1_saved_ms - 2.5).abs() < 1e-6);
        assert_eq!(s.stream_peak_buffered, 80);
        assert_eq!(s.stream_tiles, 0);
        assert_eq!(s.stage1_tile_gathers, 0);
    }

    #[test]
    fn shard_counters_snapshot() {
        let m = Metrics::default();
        // unsharded passthrough: nothing recorded
        m.record_shard_sweep(&crate::shard::SweepStats::default());
        let s = m.snapshot();
        assert_eq!(s.shard_stage1_tasks, 0);
        assert_eq!(s.shard_escalated_rows, 0);
        // sharded sweep: tasks + escalations fold in
        m.record_shard_sweep(&crate::shard::SweepStats {
            sharded: true,
            shards: 4,
            tasks: 7,
            escalated: 2,
            scatter_s: 0.001,
            gather_s: 0.002,
        });
        m.over_quota.fetch_add(3, Ordering::Relaxed);
        m.shard_sub_recomputes.fetch_add(5, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.shard_stage1_tasks, 7);
        assert_eq!(s.shard_escalated_rows, 2);
        assert_eq!(s.over_quota, 3);
        assert_eq!(s.shard_sub_recomputes, 5);
    }

    #[test]
    fn snapshot_roundtrip() {
        let m = Metrics::default();
        m.requests.fetch_add(3, Ordering::Relaxed);
        m.add_stage_times(1.5, 2.5);
        let s = m.snapshot();
        assert_eq!(s.requests, 3);
        assert!((s.knn_s - 1.5).abs() < 1e-5);
        assert!((s.interp_s - 2.5).abs() < 1e-5);
    }

    #[test]
    fn subscription_counters_snapshot() {
        let m = Metrics::default();
        m.subs_active.fetch_add(2, Ordering::Relaxed);
        m.sub_updates.fetch_add(5, Ordering::Relaxed);
        m.tiles_pushed.fetch_add(9, Ordering::Relaxed);
        m.tiles_dirty.fetch_add(4, Ordering::Relaxed);
        m.tiles_skipped_clean.fetch_add(11, Ordering::Relaxed);
        m.subs_active.fetch_sub(1, Ordering::Relaxed);
        let s = m.snapshot();
        assert_eq!(s.subs_active, 1, "gauge settles on unregister");
        assert_eq!(s.sub_updates, 5);
        assert_eq!(s.tiles_pushed, 9);
        assert_eq!(s.tiles_dirty, 4);
        assert_eq!(s.tiles_skipped_clean, 11);
    }
}
