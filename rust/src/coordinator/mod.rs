//! The L3 coordinator — the serving system around the paper's algorithm.
//!
//! ```text
//!  clients ──► bounded JobQueue ──► dispatcher thread      stage-2 thread
//!                 (backpressure)     │ batch formation       │ owns Engine
//!                                    │ STAGE 1: grid kNN     │ STAGE 2: alpha +
//!                                    │ (CPU pool, rust)      │ streamed interp
//!                                    └── sync_channel(depth) ┘ (PJRT artifacts)
//! ```
//!
//! The two stages run in separate threads connected by a bounded channel,
//! so stage 1 of batch *i+1* overlaps stage 2 of batch *i* — the paper's
//! two-stage decomposition (Fig. 1) turned into a serving pipeline.
//! Python is never involved: stage 2 executes AOT artifacts through PJRT,
//! or falls back to the pure-rust kernel when artifacts are absent.
//!
//! Every request carries its own [`QueryOptions`] — k, kernel variant,
//! ring rule, local mode, alpha levels, fuzzy bounds, area — resolved
//! against [`CoordinatorConfig`] defaults at submit time.
//!
//! ## The Stage1/Stage2 seam
//!
//! Execution is planned along the paper's own decomposition
//! ([`crate::aidw::plan`]): the dispatcher builds a
//! [`crate::aidw::plan::Stage1Plan`] per batch (grid kNN over a compacted
//! snapshot, merged base ∪ delta over a mutated one; local mode gathers
//! neighbor ids in the same pass) whose product — the
//! [`crate::aidw::plan::NeighborArtifact`] of per-query r_obs, alphas,
//! and neighbor indices — is handed to the stage-2 thread.
//!
//! * **Admission** keys on [`ResolvedOptions::stage1_key`], *not* full
//!   option equality: jobs that differ only in stage-2 kernel variant
//!   share one batch, the kNN sweep (the dominant cost in the paper) runs
//!   once, and stage 2 executes once per distinct variant group over that
//!   group's query rows.
//! * **Reuse**: the [`cache::NeighborCache`] holds recent artifacts keyed
//!   on `(dataset, epoch, overlay version, stage1_key, query
//!   fingerprint)`, so a repeated raster skips stage 1 entirely — on
//!   mutated (uncompacted) snapshots too: every append/remove bumps the
//!   overlay version, which retires stale artifacts by key instead of
//!   bypassing the cache.  A raster whose rows are covered by a cached
//!   artifact of the same snapshot is served by row-gather (subset
//!   reuse).  Invalidation rules live in [`cache`]: mutation bumps the
//!   overlay version, compaction bumps the epoch, and register/drop
//!   purge by name.
//!
//! Responses echo each job's *own* resolved options (the batch may mix
//! variants) plus the planner's coalescing/cache facts
//! ([`InterpolationResponse::stage1_cache_hit`] /
//! [`InterpolationResponse::stage2_groups`]).
//!
//! ## Tiled, streamed delivery
//!
//! Stage 2 executes **per tile** ([`crate::aidw::plan::TilePlan`], sized
//! by the resolved `tile_rows`) over borrowed row slices of the shared
//! artifact and delivers every tile as a frame the moment it is
//! computed.  [`Coordinator::submit_stream`] exposes the frames as a
//! bounded [`TileStream`] (backpressure at
//! [`CoordinatorConfig::stream_buffer_tiles`] outstanding tiles);
//! [`Coordinator::submit`]'s [`Ticket`] concatenates the frames of an
//! unbounded channel — the monolithic API is a view over the tiled one,
//! so there is exactly one execution path and the two are bit-identical
//! by construction.  Tiling is also the grain of partial-cover cache
//! reuse: a raster that misses as a whole row-gathers the tiles a cached
//! artifact covers and sweeps only the rest.
//!
//! Datasets are **live** ([`crate::live`]): appends and removals layer a
//! small delta overlay over the immutable epoch grid, queries merge grid
//! kNN over the epoch with brute force over the delta, and a background
//! compactor folds the overlay into a new epoch.  Submit stamps the
//! dataset's current epoch into the resolved options, so epoch changes
//! partition batch admission (a batch never mixes epochs) and every
//! response echoes the epoch it was served from.  Each batch is served
//! from one snapshot taken at batch formation; in-flight batches keep
//! their snapshot across a compaction publish.

pub mod batcher;
pub mod cache;
pub mod dataset;
pub mod metrics;
pub mod options;
pub mod request;
pub mod snapshot;

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use crate::aidw::params::AidwParams;
use crate::aidw::pipeline::weighted_stage_layout_on;
use crate::aidw::plan::{self, NeighborArtifact, NeighborTable, SearchKind, Stage1Plan, TilePlan};
use crate::error::{Error, Result};
use crate::geom::PointSet;
use crate::grid::GridConfig;
use crate::knn::grid_knn::RingRule;
use crate::live::{
    AppendOutcome, CompactionReport, LiveConfig, LiveDataset, LiveRegistry, LiveSnapshot,
    LiveStatus, RemoveOutcome,
};
use crate::pool::Pool;
use crate::runtime::{AidwExecutor, Engine};

pub use crate::runtime::Variant;
pub use batcher::BatchPolicy;
pub use cache::NeighborCache;
pub use dataset::{Dataset, DatasetRegistry};
pub use metrics::{Metrics, MetricsSnapshot};
pub use options::{Layout, LocalMode, QueryOptions, ResolvedOptions, Stage1Key, Stage2Key};
pub use request::{
    Backend, InterpolationRequest, InterpolationResponse, StreamSummary, Ticket, TileResult,
    TileStream,
};

use batcher::{Batch, JobQueue};
use cache::CacheKey;
use request::{FrameTx, Job, StreamFrame, StreamHandle};

/// Stage-2 engine selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineMode {
    /// Use PJRT artifacts if present, else pure-rust fallback.
    #[default]
    Auto,
    /// Require PJRT artifacts (error at startup when missing).
    PjrtRequired,
    /// Force the pure-rust stage 2 (benchmark baseline / no artifacts).
    CpuOnly,
}

/// Coordinator configuration — the *defaults* requests inherit; every
/// algorithmic knob here can be overridden per request via
/// [`QueryOptions`].
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifact directory (None = default dir / $AIDW_ARTIFACTS).
    pub artifact_dir: Option<std::path::PathBuf>,
    pub engine_mode: EngineMode,
    /// Use the small q256/m1024 artifacts (fast XLA compiles — tests).
    pub test_shapes: bool,
    /// Default kernel variant for requests that don't specify one.
    pub default_variant: Variant,
    /// Default AIDW parameters (k, alpha levels, fuzzy bounds, area).
    pub params: AidwParams,
    pub grid: GridConfig,
    pub batch: BatchPolicy,
    /// Default kNN ring rule (Exact by default).
    pub ring_rule: RingRule,
    /// Worker width for stage 1 (None = machine-sized).
    pub stage1_threads: Option<usize>,
    /// Bounded depth of the stage-1 -> stage-2 channel.
    pub pipeline_depth: usize,
    /// Default local-AIDW mode (extension A5): when set, stage 2 weights
    /// each query over its N nearest neighbors instead of all data points.
    /// Stage 1 gathers the neighbor ids in the same grid pass that feeds
    /// alpha.  None = the paper's dense weighting.
    pub local_neighbors: Option<usize>,
    /// Live-mutation durability directory: when set, registrations write
    /// a snapshot, every append/remove appends to a per-dataset WAL, and
    /// startup restores snapshot + WAL automatically.  None = in-memory
    /// datasets (mutable, but lost on restart).
    pub live_dir: Option<std::path::PathBuf>,
    /// Live-mutation tunables (compaction threshold, WAL sync).
    pub live: LiveConfig,
    /// Capacity (entries) of the stage-1 [`NeighborCache`]; 0 disables
    /// neighbor reuse.  See [`cache`] for the key and invalidation rules.
    pub neighbor_cache: usize,
    /// Approximate byte budget of the [`NeighborCache`] (large-raster
    /// artifacts are megabytes each, so an entry bound alone would let
    /// memory scale with raster size).  0 = entry bound only.
    pub neighbor_cache_bytes: usize,
    /// Default stage-2 tile size in query rows (requests may override via
    /// [`QueryOptions::tile_rows`]).  `None` = one whole-raster tile —
    /// the pre-streaming behaviour.  Tiling is numerics-neutral; it sets
    /// execution/delivery granularity and the grain of partial-cover
    /// cache reuse.
    pub tile_rows: Option<usize>,
    /// Bound on tiles in flight toward one stream consumer: the stage-2
    /// executor blocks once this many tiles are unconsumed, so
    /// service-side buffering stays at most
    /// `stream_buffer_tiles x tile_rows` values per stream
    /// (whole-raster tickets are exempt — they buffer freely so an
    /// unconsumed ticket can never stall the pipeline).  Min 1.
    pub stream_buffer_tiles: usize,
    /// Capacity (events) of the structured [`crate::obs::Journal`] ring —
    /// the `events` op's backing store (protocol v2.6).  Older events are
    /// dropped (and counted) once the ring is full; 0 keeps sequencing
    /// but retains nothing.
    pub journal_capacity: usize,
    /// Default CPU stage-2 data-access schedule (requests may override
    /// via [`QueryOptions::layout`], protocol v2.7).  `None` = the
    /// planner picks per job by stage-2 work size at planning time.
    /// Numerics-neutral: every layout is bit-identical.
    pub layout: Option<crate::aidw::plan::Layout>,
    /// Spatial shard count for grid-search stage 1 (protocol v2.8):
    /// partition each dataset's grid into this many contiguous cell-row
    /// bands and sweep them on the shard worker pool.  `None` = auto per
    /// dataset by point count ([`crate::shard::ShardPlan::auto_count`]);
    /// `Some(1)` forces the unsharded passthrough.  Bit-identical either
    /// way (see [`crate::shard`] for the halo/escalation proof).
    pub shards: Option<usize>,
    /// Worker threads of the shard pool — per-shard stage-1 sweeps and
    /// subscription dirty-tile recomputes.  `None` = machine-sized.
    pub shard_threads: Option<usize>,
    /// Per-tenant admission policy (protocol v2.8): token-bucket rate
    /// limit and in-flight quota, fail-closed with the structured
    /// `over_quota` error.  The default is fully open — pre-v2.8
    /// behavior.
    pub tenant_policy: crate::shard::TenantPolicy,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: None,
            engine_mode: EngineMode::Auto,
            test_shapes: false,
            default_variant: Variant::Tiled,
            params: AidwParams::default(),
            grid: GridConfig::default(),
            batch: BatchPolicy::default(),
            ring_rule: RingRule::Exact,
            stage1_threads: None,
            pipeline_depth: 2,
            local_neighbors: None,
            live_dir: None,
            live: LiveConfig::default(),
            neighbor_cache: 64,
            neighbor_cache_bytes: 256 << 20, // 256 MiB
            tile_rows: None,
            stream_buffer_tiles: 2,
            journal_capacity: 1024,
            layout: None,
            shards: None,
            shard_threads: None,
            tenant_policy: crate::shard::TenantPolicy::default(),
        }
    }
}

/// How a batch's stage-1 artifact was obtained (drives the trace's
/// stage-1 span; `saved_s` is the sweep time the cache substituted for).
#[derive(Debug, Clone, Copy)]
enum Stage1Info {
    /// The kNN + alpha sweep actually ran.
    Swept,
    /// Exact neighbor-cache hit.
    CacheHit { saved_s: f64 },
    /// Subset row-gather out of one or more covering cached artifacts.
    SubsetHit { saved_s: f64 },
}

/// A batch after stage 1, waiting for stage 2.
struct Stage2Job {
    batch: Batch,
    queries: Arc<Vec<(f64, f64)>>,
    /// The stage-1 product (r_obs + alphas + neighbor table), shared with
    /// the neighbor cache.
    artifact: Arc<NeighborArtifact>,
    /// The consistent live snapshot this whole batch is served from.
    snap: Arc<LiveSnapshot>,
    /// True when the artifact came from the cache (stage 1 skipped).
    cache_hit: bool,
    /// How stage 1 was satisfied (trace detail behind `cache_hit`).
    stage1: Stage1Info,
    /// Shard scatter/gather facts when the sweep took the sharded path
    /// (all-default on cache hits and unsharded passthroughs).
    shard: crate::shard::SweepStats,
}

pub(crate) struct Shared {
    pub(crate) registry: LiveRegistry,
    pub(crate) queue: JobQueue,
    pub(crate) metrics: Metrics,
    pub(crate) cache: NeighborCache,
    pub(crate) config: CoordinatorConfig,
    pub(crate) pool: Pool,
    pub(crate) running: AtomicBool,
    /// Live raster subscriptions (incremental dirty-tile push) — see
    /// [`crate::subscribe`].
    pub(crate) subs: crate::subscribe::SubscriptionRegistry,
    /// Structured event journal (protocol v2.6 `events` op): mutations,
    /// compactions, cache churn, subscription lifecycle, WAL rotation —
    /// everything that used to be an `eprintln!` or invisible.
    pub(crate) journal: Arc<crate::obs::Journal>,
    /// Sharded stage-1 engine + tenant admission gate (protocol v2.8):
    /// the dispatcher scatters grid sweeps through it and the
    /// subscription worker submits dirty-tile recomputes to its pool.
    pub(crate) shard: crate::shard::ShardEngine,
}

/// The interpolation service coordinator.  See module docs.
pub struct Coordinator {
    shared: Arc<Shared>,
    dispatcher: Option<JoinHandle<()>>,
    stage2: Option<JoinHandle<()>>,
    /// The subscription worker (dirty-tile classification + push).
    subs_worker: Option<JoinHandle<()>>,
    /// Which backend stage 2 is using (resolved at startup).
    backend: Backend,
}

impl Coordinator {
    /// Start the coordinator (spawns the pipeline threads).
    pub fn new(config: CoordinatorConfig) -> Result<Coordinator> {
        config.params.validate().map_err(Error::InvalidArgument)?;
        // Resolve the stage-2 backend up front so startup fails fast.
        let artifact_dir = config
            .artifact_dir
            .clone()
            .unwrap_or_else(crate::runtime::default_artifact_dir);
        let backend = match config.engine_mode {
            EngineMode::CpuOnly => Backend::CpuFallback,
            EngineMode::PjrtRequired => {
                if !artifact_dir.join("manifest.json").exists() {
                    return Err(Error::Artifact(format!(
                        "PJRT required but no manifest at {}",
                        artifact_dir.display()
                    )));
                }
                Backend::Pjrt
            }
            EngineMode::Auto => {
                if artifact_dir.join("manifest.json").exists() {
                    Backend::Pjrt
                } else {
                    Backend::CpuFallback
                }
            }
        };

        let pool = match config.stage1_threads {
            Some(n) => Pool::new(n),
            None => Pool::machine_sized(),
        };
        let journal = Arc::new(crate::obs::Journal::new(config.journal_capacity));
        let shard_threads = config.shard_threads.unwrap_or_else(|| {
            std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
        });
        let shard = crate::shard::ShardEngine::new(
            config.shards,
            shard_threads,
            crate::shard::DEFAULT_QUANTUM,
            config.tenant_policy,
        );
        let shared = Arc::new(Shared {
            registry: LiveRegistry::new(),
            queue: JobQueue::new(config.batch),
            metrics: Metrics::default(),
            cache: NeighborCache::new(config.neighbor_cache, config.neighbor_cache_bytes),
            config,
            pool,
            running: AtomicBool::new(true),
            subs: crate::subscribe::SubscriptionRegistry::default(),
            journal,
            shard,
        });

        // restore persisted live datasets (snapshot + WAL replay) before
        // any request can arrive
        if let Some(dir) = shared.config.live_dir.clone() {
            for name in crate::live::wal::list_live(&dir)? {
                let ds = LiveDataset::load(
                    &shared.pool,
                    &name,
                    &dir,
                    &shared.config.grid,
                    shared.config.params.area,
                    shared.config.live,
                )?;
                attach_observer(&shared, &ds);
                shared.journal.info(
                    "dataset_load",
                    Some(&name),
                    format!("restored from {} (snapshot + WAL replay)", dir.display()),
                );
                shared.registry.insert(ds);
            }
        }

        // stage-1 -> stage-2 bounded channel
        let (tx, rx) = mpsc::sync_channel::<Stage2Job>(shared.config.pipeline_depth);

        let dispatcher = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("aidw-dispatch".into())
                .spawn(move || dispatcher_loop(shared, tx))
                .map_err(Error::Io)?
        };
        let stage2 = {
            let shared = shared.clone();
            let dir = artifact_dir.clone();
            std::thread::Builder::new()
                .name("aidw-stage2".into())
                .spawn(move || stage2_loop(shared, rx, backend, dir))
                .map_err(Error::Io)?
        };

        // subscription worker: initial-raster pushes + dirty-tile
        // recompute after mutations (see crate::subscribe)
        let (sub_tx, sub_rx) = mpsc::channel::<crate::subscribe::SubEvent>();
        shared.subs.attach(sub_tx);
        let subs_worker = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("aidw-subs".into())
                .spawn(move || crate::subscribe::worker_loop(shared, sub_rx))
                .map_err(Error::Io)?
        };

        Ok(Coordinator {
            shared,
            dispatcher: Some(dispatcher),
            stage2: Some(stage2),
            subs_worker: Some(subs_worker),
            backend,
        })
    }

    /// Coordinator with default config.
    pub fn with_defaults() -> Result<Coordinator> {
        Coordinator::new(CoordinatorConfig::default())
    }

    /// The stage-2 backend in use.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The configuration requests resolve their options against.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.shared.config
    }

    /// Register a dataset (builds its epoch-0 grid index now; with a
    /// live directory configured, also writes the durable snapshot and a
    /// fresh WAL).
    pub fn register_dataset(&self, name: &str, points: PointSet) -> Result<()> {
        let cfg = &self.shared.config;
        // retire any existing entry *before* writing the replacement's
        // durable files, so the old dataset's compactor can never clobber
        // them afterwards
        let displaced = self.shared.registry.get(name).is_ok();
        if let Ok(old) = self.shared.registry.get(name) {
            old.retire();
        }
        let ds = match &cfg.live_dir {
            Some(dir) => LiveDataset::build_persistent(
                &self.shared.pool,
                name,
                points,
                &cfg.grid,
                cfg.params.area,
                cfg.live,
                dir,
            )?,
            None => LiveDataset::build(
                &self.shared.pool,
                name,
                points,
                &cfg.grid,
                cfg.params.area,
                cfg.live,
            )?,
        };
        attach_observer(&self.shared, &ds);
        let n_points = ds.snapshot().live_len;
        if let Some(old) = self.shared.registry.insert(ds) {
            // deliberate epoch retirement (already detached from the
            // durable files above; a concurrent register of the same name
            // may hand us a not-yet-retired instance, so retire again)
            old.retire();
        }
        self.shared.journal.info(
            "dataset_register",
            Some(name),
            format!("{n_points} points{}", if displaced { " (replaced existing)" } else { "" }),
        );
        // stage-1 artifacts of the displaced dataset must not survive a
        // same-name re-register (epoch numbering restarts at 0); purge
        // *after* the insert so no pre-insert batch can re-populate
        // between purge and publish (the epoch-base instance id in the
        // cache key is the backstop for the remaining race)
        let purged = self.shared.cache.purge_dataset(name);
        if purged > 0 {
            self.shared.journal.info(
                "cache_purge",
                Some(name),
                format!("{purged} stage-1 entries dropped on re-register"),
            );
        }
        // displaced-epoch retirement: subscriptions on the old instance
        // must terminate with a structured error, not serve the new one
        if displaced && self.shared.subs.active_on(name) {
            self.shared.subs.notify(crate::subscribe::SubEvent::Retired {
                dataset: name.to_string(),
                replaced: true,
            });
        }
        Ok(())
    }

    /// Remove a dataset (joins its compactor and deletes its durable
    /// state so a restart does not resurrect it).
    pub fn drop_dataset(&self, name: &str) -> bool {
        let purged = self.shared.cache.purge_dataset(name);
        if purged > 0 {
            self.shared.journal.info(
                "cache_purge",
                Some(name),
                format!("{purged} stage-1 entries dropped with dataset"),
            );
        }
        match self.shared.registry.remove(name) {
            Some(ds) => {
                // after retire() no compaction — background or an
                // in-flight synchronous one — can re-create the files we
                // are about to delete
                ds.retire();
                if let Some(dir) = &self.shared.config.live_dir {
                    std::fs::remove_file(crate::live::wal::live_path(dir, name)).ok();
                    let base = crate::live::wal::wal_path(dir, name);
                    crate::live::wal::remove_rotated_segments(&base);
                    std::fs::remove_file(base).ok();
                }
                if self.shared.subs.active_on(name) {
                    self.shared.subs.notify(crate::subscribe::SubEvent::Retired {
                        dataset: name.to_string(),
                        replaced: false,
                    });
                }
                self.shared.journal.info("dataset_drop", Some(name), String::new());
                true
            }
            None => false,
        }
    }

    /// Append points to a live dataset; may trigger background
    /// compaction once the overlay crosses the configured threshold.
    pub fn append_points(&self, name: &str, points: PointSet) -> Result<AppendOutcome> {
        let ds = self.shared.registry.get(name)?;
        // dirty-footprint event for live subscriptions (datasets without
        // subscribers pay only the active_on check)
        let watched = self.shared.subs.active_on(name);
        let out = ds.append(&points)?;
        self.shared.journal.record(
            crate::obs::Severity::Info,
            "mutation_append",
            Some(name),
            format!("{} points (ids {}..)", out.count, out.first_id),
            Some(out.mut_seq),
        );
        if watched {
            let coords = points.xs.iter().zip(&points.ys).map(|(&x, &y)| (x, y)).collect();
            self.shared.subs.notify(crate::subscribe::SubEvent::Mutated {
                dataset: name.to_string(),
                coords,
                seq: out.mut_seq,
                at: std::time::Instant::now(),
            });
        }
        LiveDataset::maybe_spawn_compaction(&ds);
        Ok(out)
    }

    /// Tombstone live points by id (strict: all ids must be live).
    pub fn remove_points(&self, name: &str, ids: &[u64]) -> Result<RemoveOutcome> {
        let ds = self.shared.registry.get(name)?;
        let out = if self.shared.subs.active_on(name) {
            // the victims' coordinates are the dirty footprint; the live
            // layer resolves them per id under its write lock — exact and
            // O(ids · log n), never a full live scan
            let (out, coords) = ds.remove_traced(ids)?;
            self.shared.subs.notify(crate::subscribe::SubEvent::Mutated {
                dataset: name.to_string(),
                coords,
                seq: out.mut_seq,
                at: std::time::Instant::now(),
            });
            out
        } else {
            ds.remove(ids)?
        };
        self.shared.journal.record(
            crate::obs::Severity::Info,
            "mutation_remove",
            Some(name),
            format!("{} points tombstoned", out.removed),
            Some(out.mut_seq),
        );
        LiveDataset::maybe_spawn_compaction(&ds);
        Ok(out)
    }

    /// Synchronously compact a live dataset (fold overlay, bump epoch,
    /// truncate WAL).  The subscription identity refresh and journal
    /// events ride the dataset's compaction observer ([`attach_observer`])
    /// — the same path background compactions take, so sync and
    /// background compactions are indistinguishable downstream.
    pub fn compact_dataset(&self, name: &str) -> Result<CompactionReport> {
        self.shared.registry.get(name)?.compact_now()
    }

    /// Live mutation/compaction statistics for one dataset.
    pub fn live_status(&self, name: &str) -> Result<LiveStatus> {
        Ok(self.shared.registry.get(name)?.status())
    }

    /// Direct access to a live dataset (tests, advanced callers).
    pub fn live_dataset(&self, name: &str) -> Result<Arc<LiveDataset>> {
        self.shared.registry.get(name)
    }

    /// Registered dataset names.
    pub fn datasets(&self) -> Vec<String> {
        self.shared.registry.names()
    }

    /// Submit asynchronously; returns a ticket to await.
    ///
    /// Fails fast — before the job reaches any pipeline thread — on empty
    /// queries, unknown datasets, and invalid option overrides (`k == 0`,
    /// `r_max <= r_min`, non-positive alpha levels, ...).
    ///
    /// Internally this **is** a stream: execution is tiled and delivered
    /// frame by frame, and the [`Ticket`] concatenates the tiles back —
    /// one execution path for both APIs.  The ticket's channel is
    /// unbounded, so an unconsumed ticket never blocks the pipeline, and
    /// dropping the ticket without waiting cancels the job (a queued slot
    /// is reclaimed; an executing job stops delivering).
    pub fn submit(&self, request: InterpolationRequest) -> Result<Ticket> {
        Ok(Ticket::new(self.enqueue(request, false)?))
    }

    /// Submit for **incremental delivery**: the returned [`TileStream`]
    /// yields in-order [`TileResult`]s as stage 2 computes them, then a
    /// terminal [`StreamSummary`].  The channel is bounded at
    /// [`CoordinatorConfig::stream_buffer_tiles`] tiles, so a slow
    /// consumer backpressures the stage-2 executor instead of buffering
    /// the raster — constant memory on both sides.  Consume promptly (or
    /// drop to cancel): while one stream's frames are unconsumed, the
    /// executor blocks and later batches wait behind it.
    pub fn submit_stream(&self, request: InterpolationRequest) -> Result<TileStream> {
        self.enqueue(request, true)
    }

    /// Register a **standing raster**: the returned
    /// [`crate::subscribe::SubscriptionStream`] first delivers the full
    /// initial raster (update 0) as tile frames, then, after every
    /// mutation of the dataset, an update containing only the **dirty
    /// tiles** recomputed against the new `(epoch, overlay)` snapshot —
    /// clean tiles are never recomputed (protocol v2.5 `subscribe`).
    /// Updates coalesce rapid mutation bursts into one push.  Dropping
    /// the stream unsubscribes; if the dataset is dropped or
    /// registered-over, the stream terminates with a structured error
    /// frame.  See [`crate::subscribe`] for the dirty-footprint bound.
    ///
    /// The echoed options stamp the `(epoch, overlay)` observed at
    /// **admission**; under concurrent mutation the worker may compute
    /// update 0 from a later snapshot.  Each update's header — update 0
    /// included — is the authoritative serving-snapshot identity; the
    /// echo is an audit of what admission saw, like the serving path's.
    pub fn subscribe(
        &self,
        request: InterpolationRequest,
    ) -> Result<crate::subscribe::SubscriptionStream> {
        use crate::subscribe::{NewSub, SubEvent, SubscriptionStream};
        if request.queries.is_empty() {
            return Err(Error::InvalidArgument("empty query list".into()));
        }
        let live = self.shared.registry.get(&request.dataset)?;
        let mut resolved = request.options.resolve(&self.shared.config);
        resolved.validate()?;
        // v2.8 admission: subscriptions are long-lived, so they hold no
        // in-flight slot — only the tenant's token bucket is charged
        // (one token per subscribe; dirty-tile pushes ride free).
        let tenant = resolved.tenant.unwrap_or_default();
        if let Err(e) = self.shared.shard.governor().admit_transient(tenant) {
            self.shared.metrics.over_quota.fetch_add(1, Ordering::Relaxed);
            self.shared.journal.info(
                "over_quota",
                Some(&request.dataset),
                format!("subscribe rejected for tenant {tenant}"),
            );
            return Err(e);
        }
        let snap = live.snapshot();
        resolved.epoch = Some(snap.epoch);
        resolved.overlay = Some(snap.overlay_version());
        let rows = request.queries.len();
        let plan = TilePlan::new(rows, resolved.tile_rows);
        let events = self
            .shared
            .subs
            .sender()
            .ok_or_else(|| Error::Unavailable("subscription worker not running".into()))?;
        // bounded frame queue: a slow subscriber backpressures its own
        // pushes (the worker waits in a cancellable poll loop)
        let (tx, rx) = mpsc::sync_channel(self.shared.config.stream_buffer_tiles.max(2));
        let id = self.shared.subs.next_id();
        let cancel = Arc::new(AtomicBool::new(false));
        self.shared.subs.register(id, &request.dataset, cancel.clone());
        self.shared.metrics.subs_active.fetch_add(1, Ordering::Relaxed);
        self.shared.journal.info(
            "sub_register",
            Some(&request.dataset),
            format!("feed {id}: {rows} rows, {} tiles", plan.n_tiles()),
        );
        let sub = NewSub {
            id,
            dataset: request.dataset.clone(),
            queries: request.queries,
            resolved,
            tx,
            cancel: cancel.clone(),
        };
        if events.send(SubEvent::Subscribe(Box::new(sub))).is_err() {
            if self.shared.subs.unregister(id) {
                self.shared.metrics.subs_active.fetch_sub(1, Ordering::Relaxed);
            }
            return Err(Error::Unavailable("subscription worker stopped".into()));
        }
        Ok(SubscriptionStream::new(
            rx,
            rows,
            plan.n_tiles(),
            plan.tile_rows(),
            echo_options(&resolved, &snap),
            id,
            cancel,
            events,
        ))
    }

    /// Registered-but-unswept subscription count (diagnostics/tests).
    pub fn subscriptions(&self) -> usize {
        self.shared.subs.len()
    }

    /// Shared submission prologue: validate, resolve, stamp the snapshot
    /// identity, and enqueue with the requested delivery flavor.
    fn enqueue(&self, request: InterpolationRequest, bounded: bool) -> Result<TileStream> {
        if request.queries.is_empty() {
            return Err(Error::InvalidArgument("empty query list".into()));
        }
        // fail fast on unknown datasets (cheap read-lock check)
        let live = self.shared.registry.get(&request.dataset)?;
        // resolve per-request options against config defaults and validate
        let mut resolved = request.options.resolve(&self.shared.config);
        resolved.validate()?;
        // stamp the dataset's current (epoch, overlay version) pair into
        // the admission key — read from one snapshot, so the pair is
        // consistent: jobs admitted against different epochs *or* across
        // a mutation never share a batch, and the response echo reports
        // the pair a batch was served from.  (Local weighting on a
        // mutated dataset is served by the merged per-id gather — the
        // PR-2 rejection is gone.)
        let snap = live.snapshot();
        resolved.epoch = Some(snap.epoch);
        resolved.overlay = Some(snap.overlay_version());
        // v2.8 admission: charge the tenant's token bucket and claim an
        // in-flight slot.  Fail-closed: over-quota is a structured error
        // before the job touches the queue.  The guard rides the job and
        // frees the slot wherever the job ends (served, failed, swept).
        let tenant = resolved.tenant.unwrap_or_default();
        let admit = match self.shared.shard.governor().admit(tenant) {
            Ok(guard) => Some(guard),
            Err(e) => {
                self.shared.metrics.over_quota.fetch_add(1, Ordering::Relaxed);
                self.shared.journal.info(
                    "over_quota",
                    Some(&request.dataset),
                    format!("request rejected for tenant {tenant}"),
                );
                return Err(e);
            }
        };
        let n_queries = request.queries.len() as u64;
        let buffered = Arc::new(AtomicUsize::new(0));
        let cancel = Arc::new(std::sync::atomic::AtomicBool::new(false));
        let (tx, rx) = if bounded {
            // capacity counts *queued* tiles; the executor's one in-flight
            // tile makes the total outstanding exactly stream_buffer_tiles
            let cap = self.shared.config.stream_buffer_tiles.max(1) - 1;
            let (tx, rx) = mpsc::sync_channel(cap);
            (FrameTx::Bounded(tx), rx)
        } else {
            let (tx, rx) = mpsc::channel();
            (FrameTx::Unbounded(tx), rx)
        };
        let job = Job {
            request,
            resolved,
            respond: StreamHandle { tx, buffered: buffered.clone(), bounded },
            cancel: cancel.clone(),
            enqueued: std::time::Instant::now(),
            admitted: None,
            admit,
        };
        match self.shared.queue.push(job) {
            Ok(()) => {
                // count only accepted jobs (rejected submissions used to
                // inflate both counters)
                self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.shared
                    .metrics
                    .queries
                    .fetch_add(n_queries, Ordering::Relaxed);
                Ok(TileStream::new(rx, buffered, cancel))
            }
            Err(e) => {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit and block for the response.
    pub fn interpolate(&self, request: InterpolationRequest) -> Result<InterpolationResponse> {
        self.submit(request)?.wait()
    }

    /// Convenience: values only.
    pub fn interpolate_values(&self, dataset: &str, queries: Vec<(f64, f64)>) -> Result<Vec<f64>> {
        Ok(self.interpolate(InterpolationRequest::new(dataset, queries))?.values)
    }

    /// Persist every registered dataset to `<dir>/<name>.aidw` (the v1
    /// portable export: the *live merged* point set, without ids — WAL
    /// durability is the `live_dir` mechanism, this is for interchange).
    pub fn save_datasets(&self, dir: &std::path::Path) -> Result<usize> {
        let all = self.shared.registry.all();
        for ds in &all {
            let (pts, _ids) = ds.snapshot().live_points();
            snapshot::save_dataset(dir, ds.name(), &pts)?;
        }
        Ok(all.len())
    }

    /// Register every snapshot found in `dir` (grid indexes are rebuilt).
    pub fn load_datasets(&self, dir: &std::path::Path) -> Result<usize> {
        let loaded = snapshot::load_dir(dir)?;
        let count = loaded.len();
        for (name, pts) in loaded {
            self.register_dataset(&name, pts)?;
        }
        Ok(count)
    }

    /// Metrics snapshot (planner counters + neighbor-cache occupancy).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot_with(self.shared.cache.stats())
    }

    /// Prometheus-style text exposition of the metrics snapshot —
    /// protocol v2.6 `metrics_text` op and `aidw serve --metrics-text`.
    pub fn metrics_text(&self) -> String {
        metrics::prometheus_text(&self.metrics())
    }

    /// Per-tenant admission counters (protocol v2.8): one entry per
    /// tenant lane the governor has seen — admitted / rejected /
    /// currently in-flight — for diagnostics and the fairness tests.
    pub fn tenant_stats(&self) -> Vec<crate::shard::TenantStat> {
        self.shared.shard.governor().stats()
    }

    /// The structured event journal (advanced callers / tests; the
    /// `events` op is the usual consumer).
    pub fn journal(&self) -> Arc<crate::obs::Journal> {
        self.shared.journal.clone()
    }

    /// Journal page: events with `seq >= since`, oldest first, at most
    /// `max` (0 = no cap) — the protocol v2.6 `events` op.
    pub fn events(&self, since: u64, max: usize) -> crate::obs::EventsPage {
        self.shared.journal.events_since(since, max)
    }

    /// Current queue depth (diagnostics / backpressure observers).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Graceful shutdown: drains queued work, stops the pipeline threads,
    /// and joins any background compactions.
    pub fn shutdown(&mut self) {
        if self.shared.running.swap(false, Ordering::SeqCst) {
            self.shared.queue.close();
            if let Some(h) = self.dispatcher.take() {
                let _ = h.join();
            }
            if let Some(h) = self.stage2.take() {
                let _ = h.join();
            }
            // terminate every subscription with a structured error and
            // stop the worker; running=false already unwedged any push
            // blocked on a full frame queue
            self.shared.subs.shutdown();
            if let Some(h) = self.subs_worker.take() {
                let _ = h.join();
            }
            // drain + join the shard workers after every producer of
            // shard tasks (dispatcher, stage 2, subscription worker) has
            // stopped, and before the datasets they read go away
            self.shared.shard.shutdown();
            self.shared.registry.shutdown_all();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Wire a live dataset's compaction lifecycle into the coordinator's
/// observability plane.  The dataset's own threads — the background
/// compactor included — journal compaction start/finish/fail through the
/// attached journal, and every *published* compaction invokes the hook,
/// which notifies the subscription worker so standing feeds refresh
/// their serving `(epoch, overlay)` identity without waiting for the
/// next mutation (ROADMAP PR-6 follow-up (b)).  Synchronous
/// `compact_dataset` calls ride the same path, so sync and background
/// compactions are indistinguishable downstream.
fn attach_observer(shared: &Arc<Shared>, ds: &LiveDataset) {
    // Weak: the hook lives inside the dataset, which the Shared registry
    // owns — a strong Arc here would cycle and leak the coordinator.
    let weak = Arc::downgrade(shared);
    ds.attach_observer(shared.journal.clone(), move |name, _report| {
        if let Some(sh) = weak.upgrade() {
            if sh.subs.active_on(name) {
                sh.subs.notify(crate::subscribe::SubEvent::Compacted {
                    dataset: name.to_string(),
                });
            }
        }
    });
}

/// Insert a freshly built stage-1 artifact into the neighbor cache and
/// journal the insert — plus any evictions the insert forced — so cache
/// churn is reconstructable from the event log.  Runs once per batch
/// miss, never on the per-query hot path.
fn journal_cache_insert(
    shared: &Shared,
    dataset: &str,
    key: CacheKey,
    queries: &[(f64, f64)],
    art: Arc<NeighborArtifact>,
) {
    let detail = format!("rows={} stage1_s={:.6}", queries.len(), art.stage1_s);
    let evicted = shared.cache.put(key, queries, art);
    shared.journal.info("cache_insert", Some(dataset), detail);
    if evicted > 0 {
        shared
            .journal
            .info("cache_evict", Some(dataset), format!("evicted={evicted}"));
    }
}

/// Dispatcher: batch formation + the planned stage 1 on the CPU pool.
/// Builds a [`Stage1Plan`] from the batch's stage-1 key (grid over a
/// compacted snapshot, merged over a mutated one; local mode gathers
/// neighbor ids in the same pass), consults the [`NeighborCache`], and
/// hands the resulting [`NeighborArtifact`] to stage 2.
fn dispatcher_loop(shared: Arc<Shared>, tx: mpsc::SyncSender<Stage2Job>) {
    while let Some(batch) = shared.queue.next_batch() {
        shared.metrics.batches.fetch_add(1, Ordering::Relaxed);

        let live = match shared.registry.get(&batch.dataset) {
            Ok(ds) => ds,
            Err(e) => {
                fail_batch(&shared, batch, &e);
                continue;
            }
        };
        // one snapshot per batch: every member is served from the same
        // epoch/overlay state, and keeps it across a compaction publish
        let snap = live.snapshot();

        // concatenate all queries of the batch (Arc: the raster is shared
        // with the shard engine's scatter tasks and the stage-2 job)
        let mut queries = Vec::with_capacity(batch.total_queries);
        for job in &batch.jobs {
            queries.extend_from_slice(&job.request.queries);
        }
        let queries = Arc::new(queries);

        // STAGE 1 (planned): the paper's fast kNN search + adaptive
        // alpha, one execution per batch regardless of how many stage-2
        // variants the members carry.
        let opts = batch.options;
        let search = if snap.is_compacted() { SearchKind::Grid } else { SearchKind::Merged };
        let area = opts.area.unwrap_or_else(|| snap.area());
        let params = opts.params();
        let stage1 = Stage1Plan::new(
            opts.k,
            opts.ring_rule,
            opts.local_neighbors,
            &params,
            snap.live_len,
            area,
            search,
        );

        // Neighbor reuse on every snapshot, mutated or compacted (see
        // cache.rs for the key and invalidation rules): the key's stage-1
        // (epoch, overlay) pair is normalized to the snapshot actually
        // served, so a compaction or mutation publishing between
        // admission and formation cannot split cache identity.
        let cache_key = if shared.cache.enabled() {
            let mut s1 = opts.stage1_key();
            s1.epoch = Some(snap.epoch);
            s1.overlay = Some(snap.overlay_version());
            Some(CacheKey {
                dataset: batch.dataset.clone(),
                epoch: snap.epoch,
                instance: snap.base.uid,
                overlay: snap.overlay_version(),
                stage1: s1,
                queries_fp: cache::query_fingerprint(&queries),
                n_queries: queries.len(),
            })
        } else {
            None
        };
        let outcome = match cache_key.as_ref() {
            Some(k) => shared.cache.lookup(k, &queries),
            None => cache::CacheOutcome::Miss,
        };
        // the batcher partitions on tenant, so the whole batch shares one
        // admission identity (anonymous when the field is absent)
        let tenant = opts.tenant.unwrap_or_default();
        let (artifact, cache_hit, stage1_info, sweep) = match outcome {
            cache::CacheOutcome::Hit(art) => {
                shared.metrics.stage1_cache_hits.fetch_add(1, Ordering::Relaxed);
                // the saved-seconds counter: this hit skipped a sweep that
                // cost the entry's recorded build time (ROADMAP PR-4(b))
                shared.metrics.add_stage1_saved(art.stage1_s);
                let saved_s = art.stage1_s;
                (art, true, Stage1Info::CacheHit { saved_s }, crate::shard::SweepStats::default())
            }
            cache::CacheOutcome::Subset { artifact: mut sub, saved_s } => {
                // a covering artifact served this raster's rows: no kNN
                // sweep ran; re-insert under the exact key so repeats of
                // this raster hit directly
                shared.metrics.stage1_subset_hits.fetch_add(1, Ordering::Relaxed);
                shared.metrics.add_stage1_saved(saved_s);
                // record the stage-1 cost this artifact substitutes for,
                // so later exact hits on the re-inserted entry credit a
                // realistic saving instead of the gather's ~0
                sub.stage1_s = saved_s;
                let art = Arc::new(sub);
                if let Some(key) = cache_key {
                    journal_cache_insert(&shared, &batch.dataset, key, &queries, art.clone());
                }
                (art, true, Stage1Info::SubsetHit { saved_s }, crate::shard::SweepStats::default())
            }
            cache::CacheOutcome::Miss => {
                // tile-granular partial cover (ROADMAP PR-4(a)): when the
                // batch has a tile plan, tiles whose rows live inside a
                // same-identity cached artifact row-gather; only the
                // uncovered tiles pay a kNN sweep
                let partial = cache_key.as_ref().and_then(|key| {
                    stage1_partial_cover(
                        &shared, key, &stage1, search, &snap, &queries, opts.tile_rows, tenant,
                    )
                });
                match partial {
                    Some((art, all_covered, gathered_saved_s, sweep)) => {
                        let art = Arc::new(art);
                        if let Some(key) = cache_key {
                            journal_cache_insert(&shared, &batch.dataset, key, &queries, art.clone());
                        }
                        // `cache_hit` reports whether the request paid for
                        // stage 1: true only when *every* tile gathered
                        // (rows spanning several cached rasters) — a
                        // partially-swept batch did pay (reduced) time
                        let info = if all_covered {
                            Stage1Info::SubsetHit { saved_s: gathered_saved_s }
                        } else {
                            Stage1Info::Swept
                        };
                        (art, all_covered, info, sweep)
                    }
                    None => {
                        // grid search scatters across the shard engine
                        // (bit-identical to the direct sweep — see
                        // crate::shard); merged search stays on the
                        // unsharded path (overlay rows have no band
                        // locality)
                        let (art, sweep) = match search {
                            SearchKind::Grid => shared
                                .shard
                                .execute_grid(&stage1, &snap, &queries, &shared.pool, tenant),
                            SearchKind::Merged => (
                                stage1.execute_merged(&shared.pool, &snap.merged_view(), &queries),
                                crate::shard::SweepStats::default(),
                            ),
                        };
                        let art = Arc::new(art);
                        shared.metrics.stage1_execs.fetch_add(1, Ordering::Relaxed);
                        shared.metrics.record_shard_sweep(&sweep);
                        if let Some(key) = cache_key {
                            journal_cache_insert(&shared, &batch.dataset, key, &queries, art.clone());
                        }
                        (art, false, Stage1Info::Swept, sweep)
                    }
                }
            }
        };

        let job = Stage2Job {
            batch,
            queries,
            artifact,
            snap,
            cache_hit,
            stage1: stage1_info,
            shard: sweep,
        };
        if tx.send(job).is_err() {
            break; // stage 2 is gone
        }
    }
    // dropping tx closes the stage-2 loop
}

/// Tile-granular partial-cover stage 1 (ROADMAP PR-4(a)): when a raster
/// misses the cache as a whole, check per tile whether a same-identity
/// cached artifact covers the tile's rows — covered tiles row-gather via
/// `subset_rows`, only the uncovered tiles run a kNN sweep, and the
/// per-tile artifacts are stitched back in row order.  Bit-identity holds
/// because stage-1 rows are per-query functions of the snapshot (the same
/// property behind whole-raster subset reuse).
///
/// Returns `None` when tiling is off, there is only one tile (the
/// whole-raster subset pass already ran), or no tile is covered — the
/// caller then sweeps the whole raster as before.  On `Some`, the bool
/// is true when **every** tile was gathered (no sweep ran at all — the
/// caller reports it as a cache hit); the returned artifact's `stage1_s`
/// is the wall time actually spent sweeping, and the final `f64` is the
/// stage-1 cost credited from gathered tiles (for trace saved-s).
fn stage1_partial_cover(
    shared: &Shared,
    key: &CacheKey,
    stage1: &Stage1Plan,
    search: SearchKind,
    snap: &Arc<LiveSnapshot>,
    queries: &[(f64, f64)],
    tile_rows: Option<usize>,
    tenant: crate::shard::TenantTag,
) -> Option<(NeighborArtifact, bool, f64, crate::shard::SweepStats)> {
    let tr = tile_rows?;
    let plan = TilePlan::new(queries.len(), Some(tr));
    if plan.n_tiles() <= 1 {
        return None;
    }
    // pass 1: gather every covered tile out of the cache
    let mut parts: Vec<Option<NeighborArtifact>> = Vec::with_capacity(plan.n_tiles());
    let mut covered_tiles = 0usize;
    let mut saved_s = 0.0f64;
    for range in plan.iter() {
        match shared.cache.subset_for(key, &queries[range]) {
            Some((art, s)) => {
                covered_tiles += 1;
                saved_s += s;
                parts.push(Some(art));
            }
            None => parts.push(None),
        }
    }
    if covered_tiles == 0 {
        return None;
    }
    // pass 2: sweep only the uncovered tiles (grid tiles scatter across
    // the shard engine just like whole-raster sweeps; the per-tile copy
    // is bounded by tile_rows)
    let mut sweep_s = 0.0f64;
    let mut swept_tiles = 0usize;
    let mut sweep = crate::shard::SweepStats::default();
    for (tile, part) in parts.iter_mut().enumerate() {
        if part.is_some() {
            continue;
        }
        let range = plan.range(tile);
        let art = match search {
            SearchKind::Grid => {
                let tile_queries = Arc::new(queries[range].to_vec());
                let (art, s) =
                    shared.shard.execute_grid(stage1, snap, &tile_queries, &shared.pool, tenant);
                sweep.merge(&s);
                art
            }
            SearchKind::Merged => {
                stage1.execute_merged(&shared.pool, &snap.merged_view(), &queries[range])
            }
        };
        sweep_s += art.stage1_s;
        swept_tiles += 1;
        *part = Some(art);
    }
    // stitch in row order; alphas stay lazy — recomputed from the same
    // (r_exp, params), bit-identical whether a row was gathered or swept
    let width = stage1.gather;
    let mut r_obs = Vec::with_capacity(queries.len());
    let mut idx: Option<Vec<u32>> = width.map(|w| Vec::with_capacity(queries.len() * w));
    for part in parts {
        let part = part.expect("every tile gathered or swept");
        r_obs.extend_from_slice(&part.r_obs);
        if let (Some(idx), Some(table)) = (idx.as_mut(), part.neighbors.as_ref()) {
            idx.extend_from_slice(&table.idx);
        }
    }
    let neighbors = match (idx, width) {
        (Some(idx), Some(w)) => {
            debug_assert_eq!(idx.len(), queries.len() * w);
            Some(NeighborTable { idx, width: w })
        }
        _ => None,
    };
    shared
        .metrics
        .stage1_tile_gathers
        .fetch_add(covered_tiles as u64, Ordering::Relaxed);
    shared.metrics.add_stage1_saved(saved_s);
    if swept_tiles > 0 {
        shared.metrics.stage1_execs.fetch_add(1, Ordering::Relaxed);
    } else {
        // every tile was gathered (rows spanning several cached rasters):
        // no sweep ran at all — a subset-reuse event
        shared.metrics.stage1_subset_hits.fetch_add(1, Ordering::Relaxed);
    }
    shared.metrics.record_shard_sweep(&sweep);
    Some((
        NeighborArtifact::new(r_obs, stage1.r_exp, stage1.params.clone(), neighbors, sweep_s),
        swept_tiles == 0,
        saved_s,
        sweep,
    ))
}

/// Stage 2: adaptive alpha + tiled, incrementally-delivered weighted
/// interpolation.  Every batch is executed tile by tile per member job
/// and delivered as frames; the whole-raster `submit` path consumes the
/// same frames through its [`Ticket`], so there is exactly one execution
/// path.
fn stage2_loop(
    shared: Arc<Shared>,
    rx: mpsc::Receiver<Stage2Job>,
    backend: Backend,
    artifact_dir: std::path::PathBuf,
) {
    // The Engine lives entirely in this thread (PJRT handles are not
    // shared across threads).
    let engine = match backend {
        Backend::Pjrt => match Engine::new(&artifact_dir) {
            Ok(e) => Some(e),
            Err(err) => {
                shared.journal.error(
                    "engine_fallback",
                    None,
                    format!("engine init failed ({err}); using CPU fallback"),
                );
                None
            }
        },
        Backend::CpuFallback => None,
    };

    while let Ok(sj) = rx.recv() {
        run_stage2_streamed(&shared, &engine, &sj);
    }
}

/// The effective AIDW parameter block for a batch: resolved options with
/// the snapshot's live area substituted when no explicit override was
/// given and k clamped to the live point count (what stage 1 actually
/// searched with).
fn effective_params(opts: &ResolvedOptions, snap: &LiveSnapshot) -> AidwParams {
    let mut p = opts.params();
    p.k = opts.k.min(snap.live_len).max(1);
    p.area = Some(opts.area.unwrap_or_else(|| snap.area()));
    p
}

/// The audit echo for one job: its *own* resolved options (the batch may
/// mix variants) with the live area, clamped k, and the served
/// (epoch, overlay) pair substituted.  The pair may be newer than the
/// admission pair if a compaction or mutation published in between —
/// still one single snapshot for the batch.
fn echo_options(resolved: &ResolvedOptions, snap: &LiveSnapshot) -> ResolvedOptions {
    let mut echoed = *resolved;
    echoed.area = Some(echoed.area.unwrap_or_else(|| snap.area()));
    echoed.k = echoed.k.min(snap.live_len).max(1);
    echoed.epoch = Some(snap.epoch);
    echoed.overlay = Some(snap.overlay_version());
    echoed
}

/// Execute one batch's stage 2 **per member job, per tile**, delivering
/// each tile as a frame the moment it is computed, then a terminal
/// summary per job.
///
/// Tiling facts:
/// * each job's [`TilePlan`] comes from its own resolved `tile_rows`
///   (jobs in one batch may differ — tiling is not an admission key);
/// * a tile executes over **borrowed row slices** of the shared
///   [`NeighborArtifact`] — queries, alphas, r_obs, and the neighbor
///   table rows are contiguous per job, so no gather/scatter copies;
/// * peak stage-2 memory is one tile's values: nothing whole-raster is
///   materialized here (the whole-raster `submit` concatenates
///   client-side in its [`Ticket`]);
/// * a bounded (explicit-stream) consumer backpressures the send once
///   `stream_buffer_tiles` tiles are outstanding; an error mid-job emits
///   a structured error frame for that job and moves on to the next job.
fn run_stage2_streamed(shared: &Shared, engine: &Option<Engine>, sj: &Stage2Job) {
    let opts = &sj.batch.options;
    let art: &NeighborArtifact = &sj.artifact;
    let params = effective_params(opts, &sj.snap);
    let stage2_groups = sj.batch.stage2_groups().len();

    // Lazy alphas: the PJRT stage 2 recomputes alpha on-device from
    // r_obs, so only the CPU consumers — merged (mutated-snapshot)
    // batches and the pure-rust fallback — materialize the vector.  The
    // materialization is alpha work, i.e. stage-1-attributed time; a
    // cache-hit artifact returns its already-materialized vector for
    // free.
    let needs_alphas = !sj.snap.is_compacted() || engine.is_none();
    let t_alpha = std::time::Instant::now();
    let alphas: &[f64] = if needs_alphas { art.alphas() } else { &[] };
    let alpha_init_s = if needs_alphas { t_alpha.elapsed().as_secs_f64() } else { 0.0 };
    let mut alpha_extra_s = alpha_init_s;

    // a cache-hit batch spent no stage-1 time of its own
    let stage1_s = if sj.cache_hit { 0.0 } else { art.stage1_s };

    // merged (mutated-snapshot) batches run the CPU path even when
    // artifacts are loaded; report what actually ran
    let backend = if engine.is_some() && sj.snap.is_compacted() {
        Backend::Pjrt
    } else {
        Backend::CpuFallback
    };

    // per-job row offsets into the concatenated query block
    let mut offsets = Vec::with_capacity(sj.batch.jobs.len());
    let mut off = 0usize;
    for job in &sj.batch.jobs {
        offsets.push(off);
        off += job.request.queries.len();
    }

    let total = sj.queries.len();
    let mut interp_s = 0.0f64;

    for (ji, job) in sj.batch.jobs.iter().enumerate() {
        let start = offsets[ji];
        let len = job.request.queries.len();
        let key = job.resolved.stage2_key();
        let plan = TilePlan::new(len, job.resolved.tile_rows);
        let echoed = echo_options(&job.resolved, &sj.snap);
        // Stage-2 planning: pick this job's CPU data-access schedule —
        // the request/config override, or by job size (rows × points
        // each row sums: gathered width in local mode, the live count
        // dense).  Bit-identical by contract, so per-job choice inside
        // one coalesced batch is sound.
        let points_per_row =
            art.neighbors.as_ref().map(|t| t.width).unwrap_or(sj.snap.live_len);
        let layout = plan::Layout::choose(job.resolved.layout, len, points_per_row);
        // Per-request trace (protocol v2.6): opt-in per job.  With
        // tracing off this is `None` and the loop below touches only the
        // pre-existing atomics — no allocation, no locks, no extra
        // timestamps on the hot path.
        let mut trace = if job.resolved.trace {
            let fp = crate::obs::fnv1a_64(format!("{:?}", job.resolved.stage1_key()).as_bytes());
            let mut t =
                crate::obs::Trace::new(&sj.batch.dataset, echoed.epoch, echoed.overlay, fp);
            // the schedule the stage-2 planner actually chose — auditable
            // even when the request didn't pin one (the options echo only
            // carries explicit overrides, for v2.6 byte-compat)
            t.layout = Some(layout.tag());
            // admission wait: enqueue -> taken into a forming batch;
            // coalesce wait: taken -> batch sealed.  A job missing its
            // admission stamp (shouldn't happen) charges the whole wait
            // to admission.
            let admitted = job.admitted.unwrap_or(sj.batch.formed);
            t.push(
                crate::obs::SpanKind::AdmissionWait,
                admitted.duration_since(job.enqueued).as_secs_f64(),
            );
            t.push(
                crate::obs::SpanKind::CoalesceWait,
                sj.batch.formed.duration_since(admitted).as_secs_f64(),
            );
            match sj.stage1 {
                Stage1Info::Swept => {
                    t.push(crate::obs::SpanKind::Stage1Knn, stage1_s + alpha_init_s)
                }
                Stage1Info::CacheHit { saved_s } => {
                    t.push_saved(crate::obs::SpanKind::Stage1CacheHit, saved_s)
                }
                Stage1Info::SubsetHit { saved_s } => {
                    t.push_saved(crate::obs::SpanKind::Stage1SubsetHit, saved_s)
                }
            }
            // v2.8: when the sweep took the sharded path, break its wall
            // time into the scatter and gather legs
            if sj.shard.sharded {
                t.push(crate::obs::SpanKind::ShardScatter, sj.shard.scatter_s);
                t.push(crate::obs::SpanKind::ShardGather, sj.shard.gather_s);
            }
            Some(t)
        } else {
            None
        };
        let mut buffer_wait_s = 0.0f64;
        let mut delivered = true;
        for (tile_index, range) in plan.iter().enumerate() {
            if job.cancelled() {
                delivered = false;
                break; // consumer dropped its handle mid-stream
            }
            let gs = start + range.start;
            let ge = start + range.end;
            let q = &sj.queries[gs..ge];
            let a: &[f64] = if needs_alphas { &alphas[gs..ge] } else { &[] };
            let r = &art.r_obs[gs..ge];
            let tbl = art
                .neighbors
                .as_ref()
                .map(|t| (&t.idx[gs * t.width..ge * t.width], t.width));
            match run_stage2_tile(shared, engine, sj, &params, key, layout, q, a, r, tbl) {
                Ok((values, a_s, i_s)) => {
                    alpha_extra_s += a_s;
                    interp_s += i_s;
                    if let Some(t) = trace.as_mut() {
                        t.push_tile(tile_index, a_s + i_s);
                    }
                    let n_vals = values.len();
                    // gauge before send: "buffered" includes the frame the
                    // (possibly blocked) send is carrying, so the recorded
                    // peak is the true outstanding maximum
                    job.respond.buffered.fetch_add(n_vals, Ordering::Relaxed);
                    if job.respond.bounded {
                        shared
                            .metrics
                            .note_stream_buffered(job.respond.buffered.load(Ordering::Relaxed));
                    }
                    let frame = StreamFrame::Tile(TileResult {
                        tile_index,
                        n_tiles: plan.n_tiles(),
                        row_range: (range.start, range.end),
                        values,
                        options: echoed,
                    });
                    let alive =
                        || !job.cancelled() && shared.running.load(Ordering::Relaxed);
                    // stream-buffer wait is only timed when traced: the
                    // extra Instant pair stays off the untraced path
                    let sent = if trace.is_some() {
                        let t_send = std::time::Instant::now();
                        let ok = job.respond.tx.send_while(frame, alive);
                        buffer_wait_s += t_send.elapsed().as_secs_f64();
                        ok
                    } else {
                        job.respond.tx.send_while(frame, alive)
                    };
                    if sent {
                        shared.metrics.stream_tiles.fetch_add(1, Ordering::Relaxed);
                    } else {
                        // consumer gone (dropped ticket/stream): undo the
                        // gauge and skip this job's remaining tiles
                        job.respond.buffered.fetch_sub(n_vals, Ordering::Relaxed);
                        delivered = false;
                        break;
                    }
                }
                Err(e) => {
                    // structured mid-stream error: this job fails (after
                    // any tiles it already received); the batch's other
                    // jobs still get their own tiles
                    shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                    let _ = job.respond.tx.send_while(
                        StreamFrame::Err(Error::Service(e.to_string())),
                        || !job.cancelled() && shared.running.load(Ordering::Relaxed),
                    );
                    delivered = false;
                    break;
                }
            }
        }
        if delivered {
            shared
                .metrics
                .latency
                .record(job.enqueued.elapsed().as_secs_f64());
            if let Some(t) = trace.as_mut() {
                t.push(crate::obs::SpanKind::StreamBufferWait, buffer_wait_s);
            }
            let _ = job.respond.tx.send_while(
                StreamFrame::Done(StreamSummary {
                    rows: len,
                    n_tiles: plan.n_tiles(),
                    knn_s: stage1_s + alpha_extra_s,
                    interp_s,
                    batch_queries: total,
                    backend,
                    options: echoed,
                    stage1_cache_hit: sj.cache_hit,
                    stage2_groups,
                    trace: trace.take(),
                }),
                || !job.cancelled() && shared.running.load(Ordering::Relaxed),
            );
        }
    }

    shared.metrics.add_stage_times(stage1_s + alpha_extra_s, interp_s);
    shared
        .metrics
        .stage2_execs
        .fetch_add(stage2_groups as u64, Ordering::Relaxed);
    if stage2_groups > 1 {
        shared.metrics.coalesced_batches.fetch_add(1, Ordering::Relaxed);
    }
}

/// One stage-2 tile execution over borrowed row slices of the neighbor
/// artifact; returns (values, alpha_extra_s, interp_s).  `table` is the
/// tile's neighbor-index rows plus the row width.
#[allow(clippy::too_many_arguments)]
fn run_stage2_tile(
    shared: &Shared,
    engine: &Option<Engine>,
    sj: &Stage2Job,
    params: &AidwParams,
    key: options::Stage2Key,
    layout: plan::Layout,
    queries: &[(f64, f64)],
    alphas: &[f64],
    r_obs: &[f64],
    table: Option<(&[u32], usize)>,
) -> Result<(Vec<f64>, f64, f64)> {
    let t0 = std::time::Instant::now();
    if !sj.snap.is_compacted() {
        // merged stage 2 on the CPU: the fixed-shape PJRT artifacts
        // cannot see overlay deltas; the compactor restores the artifact
        // path at the next epoch
        let v = match table {
            Some((idx, width)) => crate::live::merged_local_weighted_layout_on(
                &shared.pool,
                &sj.snap,
                queries,
                alphas,
                idx,
                width,
                layout,
            ),
            None => crate::live::merged_weighted_stage_layout_on(
                &shared.pool,
                &sj.snap,
                queries,
                alphas,
                layout,
            ),
        };
        return Ok((v, 0.0, t0.elapsed().as_secs_f64()));
    }
    let dataset: &Dataset = &sj.snap.base;
    match engine {
        Some(engine) => {
            // the device path has its own fixed layout; the CPU schedule
            // knob does not apply here
            let exec = if shared.config.test_shapes {
                AidwExecutor::new_test_shapes(engine)
            } else {
                AidwExecutor::new(engine)
            };
            let (v, times) = match table {
                Some((idx, width)) => {
                    exec.local_aidw(&dataset.points, queries, r_obs, idx, width, params)?
                }
                None => exec.improved_aidw(&dataset.points, queries, r_obs, params, key.variant)?,
            };
            Ok((v, times.knn_s, times.interp_s))
        }
        None => {
            // pure-rust stage 2 over the artifact's alphas (the one
            // shared A5 kernel for local mode, layout-dispatched)
            let v = match table {
                Some((idx, width)) => plan::local_weighted_with_layout(
                    &shared.pool,
                    queries,
                    alphas,
                    idx,
                    width,
                    layout,
                    |pid| {
                        let i = pid as usize;
                        (dataset.points.xs[i], dataset.points.ys[i], dataset.points.zs[i])
                    },
                ),
                None => {
                    weighted_stage_layout_on(&shared.pool, &dataset.points, queries, alphas, layout)
                }
            };
            Ok((v, 0.0, t0.elapsed().as_secs_f64()))
        }
    }
}

fn fail_batch(shared: &Shared, batch: Batch, err: &Error) {
    shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
    let msg = err.to_string();
    for job in batch.jobs {
        let _ = job.respond.tx.send_while(
            StreamFrame::Err(Error::Service(msg.clone())),
            || !job.cancelled() && shared.running.load(Ordering::Relaxed),
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    fn cpu_coordinator() -> Coordinator {
        let cfg = CoordinatorConfig {
            engine_mode: EngineMode::CpuOnly,
            ..Default::default()
        };
        Coordinator::new(cfg).unwrap()
    }

    #[test]
    fn register_and_interpolate_cpu() {
        let c = cpu_coordinator();
        assert_eq!(c.backend(), Backend::CpuFallback);
        let pts = workload::uniform_square(400, 50.0, 71);
        c.register_dataset("d", pts.clone()).unwrap();
        assert_eq!(c.datasets(), vec!["d".to_string()]);
        let queries = workload::uniform_square(50, 50.0, 72).xy();
        let resp = c
            .interpolate(InterpolationRequest::new("d", queries.clone()))
            .unwrap();
        assert_eq!(resp.values.len(), 50);
        assert_eq!(resp.backend, Backend::CpuFallback);
        // the response echoes the fully-resolved options
        assert_eq!(resp.options.k, 10);
        assert_eq!(resp.options.ring_rule, RingRule::Exact);
        assert_eq!(resp.options.local_neighbors, None);
        assert!(resp.options.area.is_some(), "area must be filled in");
        // matches the serial reference
        let want = crate::aidw::serial::aidw_serial(&pts, &queries, &AidwParams::default());
        for (g, w) in resp.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.queries, 50);
        assert!(m.batches >= 1);
    }

    #[test]
    fn unknown_dataset_fails_fast() {
        let c = cpu_coordinator();
        let err = c
            .interpolate(InterpolationRequest::new("missing", vec![(0.0, 0.0)]))
            .unwrap_err();
        assert!(matches!(err, Error::UnknownDataset(_)), "{err}");
    }

    #[test]
    fn empty_queries_rejected() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 73);
        c.register_dataset("d", pts).unwrap();
        assert!(c.interpolate(InterpolationRequest::new("d", vec![])).is_err());
    }

    #[test]
    fn invalid_options_rejected_at_submit() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 73);
        c.register_dataset("d", pts).unwrap();
        let q = vec![(1.0, 1.0)];
        for bad in [
            QueryOptions::new().k(0),
            QueryOptions::new().r_bounds(2.0, 1.0),
            QueryOptions::new().alpha_levels([0.0, 1.0, 2.0, 3.0, 4.0]),
            QueryOptions::new().area(-1.0),
            QueryOptions::new().local_neighbors(0),
        ] {
            let err = c
                .submit(InterpolationRequest::new("d", q.clone()).with_options(bad.clone()))
                .unwrap_err();
            assert!(matches!(err, Error::InvalidArgument(_)), "{bad:?}: {err}");
        }
        // invalid submissions must not inflate the accepted counters
        let m = c.metrics();
        assert_eq!(m.requests, 0);
        assert_eq!(m.queries, 0);
    }

    #[test]
    fn concurrent_submissions_batch_together() {
        let c = std::sync::Arc::new(cpu_coordinator());
        let pts = workload::uniform_square(600, 50.0, 74);
        c.register_dataset("d", pts).unwrap();
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let queries = workload::uniform_square(25, 50.0, 100 + t).xy();
                c.interpolate(InterpolationRequest::new("d", queries)).unwrap()
            }));
        }
        let resps: Vec<InterpolationResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(resps.iter().all(|r| r.values.len() == 25));
        // at least some requests shared a batch (probabilistic but the
        // linger window makes it overwhelmingly likely under contention)
        let m = c.metrics();
        assert_eq!(m.requests, 8);
        assert!(m.batches <= 8);
    }

    #[test]
    fn shutdown_is_idempotent_and_rejects_after() {
        let mut c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 75);
        c.register_dataset("d", pts).unwrap();
        c.shutdown();
        c.shutdown();
        assert!(c
            .interpolate(InterpolationRequest::new("d", vec![(1.0, 1.0)]))
            .is_err());
    }

    #[test]
    fn rejected_submissions_do_not_count_as_requests() {
        let mut c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 85);
        c.register_dataset("d", pts).unwrap();
        c.shutdown(); // queue closed -> push fails
        let err = c
            .submit(InterpolationRequest::new("d", vec![(1.0, 1.0)]))
            .unwrap_err();
        assert!(matches!(err, Error::Unavailable(_)), "{err}");
        let m = c.metrics();
        assert_eq!(m.requests, 0, "rejected submit must not count");
        assert_eq!(m.queries, 0);
        assert_eq!(m.rejected, 1);
    }

    #[test]
    fn local_mode_cpu_matches_local_pipeline() {
        let cfg = CoordinatorConfig {
            engine_mode: EngineMode::CpuOnly,
            local_neighbors: Some(48),
            ..Default::default()
        };
        let c = Coordinator::new(cfg).unwrap();
        let pts = workload::uniform_square(1000, 80.0, 78);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(60, 80.0, 79).xy();
        let got = c.interpolate_values("d", queries.clone()).unwrap();
        let want = crate::aidw::local::interpolate_local(
            &pts,
            &queries,
            &AidwParams::default(),
            &crate::aidw::local::LocalConfig { n_neighbors: 48, ..Default::default() },
        )
        .unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn per_request_k_override() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(300, 50.0, 76);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(20, 50.0, 77).xy();
        let got = c
            .interpolate(InterpolationRequest::new("d", queries.clone()).with_k(3))
            .unwrap();
        assert_eq!(got.options.k, 3, "resolved echo must report the override");
        let mut p = AidwParams::default();
        p.k = 3;
        let want = crate::aidw::serial::aidw_serial(&pts, &queries, &p);
        for (g, w) in got.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
        // oversized k clamps to the dataset size, and the echo reports
        // the clamped value (what stage 1 actually searched with)
        let resp = c
            .interpolate(InterpolationRequest::new("d", queries.clone()).with_k(10_000))
            .unwrap();
        assert_eq!(resp.options.k, 300);
        let mut p = AidwParams::default();
        p.k = 10_000; // serial reference clamps internally the same way
        let want = crate::aidw::serial::aidw_serial(&pts, &queries, &p);
        for (g, w) in resp.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn per_request_local_override_on_dense_coordinator() {
        // coordinator defaults to dense; one request opts into local mode
        let c = cpu_coordinator();
        let pts = workload::uniform_square(800, 80.0, 81);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(40, 80.0, 82).xy();
        let resp = c
            .interpolate(
                InterpolationRequest::new("d", queries.clone())
                    .with_options(QueryOptions::new().local_neighbors(64)),
            )
            .unwrap();
        assert_eq!(resp.options.local_neighbors, Some(64));
        let want = crate::aidw::local::interpolate_local(
            &pts,
            &queries,
            &AidwParams::default(),
            &crate::aidw::local::LocalConfig { n_neighbors: 64, ..Default::default() },
        )
        .unwrap();
        for (g, w) in resp.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn mutated_dataset_serves_merged_and_echoes_epoch() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(400, 50.0, 91);
        c.register_dataset("d", pts).unwrap();
        let extra = workload::uniform_square(40, 50.0, 92);
        let appended = c.append_points("d", extra).unwrap();
        assert_eq!(appended.first_id, 400);
        assert_eq!(appended.count, 40);
        let removed = c.remove_points("d", &[0, 401]).unwrap();
        assert_eq!(removed.removed, 2);
        assert_eq!(c.live_status("d").unwrap().live_points, 438);

        let queries = workload::uniform_square(30, 50.0, 93).xy();
        let resp = c
            .interpolate(InterpolationRequest::new("d", queries.clone()))
            .unwrap();
        assert_eq!(resp.options.epoch, Some(0), "epoch echoed for audit");
        assert_eq!(resp.values.len(), 30);

        // bit-identical to a fresh registration of the merged live set
        let (merged, _) = c.live_dataset("d").unwrap().snapshot().live_points();
        let c2 = cpu_coordinator();
        c2.register_dataset("m", merged).unwrap();
        let want = c2
            .interpolate(InterpolationRequest::new("m", queries.clone()))
            .unwrap();
        assert_eq!(resp.values, want.values, "merged path must be exact");

        // compaction bumps the epoch; answers stay bit-identical
        let rep = c.compact_dataset("d").unwrap();
        assert_eq!((rep.old_epoch, rep.new_epoch), (0, 1));
        let resp2 = c
            .interpolate(InterpolationRequest::new("d", queries))
            .unwrap();
        assert_eq!(resp2.options.epoch, Some(1));
        assert_eq!(resp2.values, want.values);
    }

    #[test]
    fn local_mode_works_on_mutated_dataset() {
        // the PR-2 rejection is gone: the merged per-id gather serves A5
        // on a mutated dataset, bit-identical to a fresh registration of
        // the merged live set
        let c = cpu_coordinator();
        let base = workload::uniform_square(300, 50.0, 94);
        c.register_dataset("d", base).unwrap();
        let q = workload::uniform_square(25, 50.0, 97).xy();
        let local = QueryOptions::new().local_neighbors(16);
        // local mode works while compacted
        c.interpolate(
            InterpolationRequest::new("d", q.clone()).with_options(local.clone()),
        )
        .unwrap();
        c.append_points("d", workload::uniform_square(5, 50.0, 95)).unwrap();
        c.remove_points("d", &[7]).unwrap();
        let got = c
            .interpolate(InterpolationRequest::new("d", q.clone()).with_options(local.clone()))
            .unwrap();
        assert_eq!(got.options.local_neighbors, Some(16));
        // oracle: fresh registration of the materialized live set
        let (merged, _) = c.live_dataset("d").unwrap().snapshot().live_points();
        let c2 = cpu_coordinator();
        c2.register_dataset("m", merged).unwrap();
        let want = c2
            .interpolate(InterpolationRequest::new("m", q.clone()).with_options(local.clone()))
            .unwrap();
        assert_eq!(got.values, want.values, "merged local must be exact");
        // compaction changes nothing about the answers
        c.compact_dataset("d").unwrap();
        let after = c
            .interpolate(InterpolationRequest::new("d", q).with_options(local))
            .unwrap();
        assert_eq!(after.values, want.values);
    }

    #[test]
    fn mutations_on_unknown_dataset_fail_fast() {
        let c = cpu_coordinator();
        assert!(c.append_points("ghost", workload::uniform_square(3, 1.0, 96)).is_err());
        assert!(c.remove_points("ghost", &[0]).is_err());
        assert!(c.compact_dataset("ghost").is_err());
        assert!(c.live_status("ghost").is_err());
    }

    #[test]
    fn per_request_area_override_changes_alpha_regime() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(400, 10.0, 83);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(30, 10.0, 84).xy();
        let lo = c
            .interpolate(
                InterpolationRequest::new("d", queries.clone())
                    .with_options(QueryOptions::new().area(1e9)),
            )
            .unwrap();
        let hi = c
            .interpolate(
                InterpolationRequest::new("d", queries.clone())
                    .with_options(QueryOptions::new().area(1e-9)),
            )
            .unwrap();
        assert_eq!(lo.options.area, Some(1e9));
        assert_eq!(hi.options.area, Some(1e-9));
        let diff: f64 = lo
            .values
            .iter()
            .zip(&hi.values)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-9, "area override had no effect");
        // each matches its serial reference
        for (resp, area) in [(&lo, 1e9), (&hi, 1e-9)] {
            let mut p = AidwParams::default();
            p.area = Some(area);
            let want = crate::aidw::serial::aidw_serial(&pts, &queries, &p);
            for (g, w) in resp.values.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "{g} vs {w}");
            }
        }
    }
}
