//! The L3 coordinator — the serving system around the paper's algorithm.
//!
//! ```text
//!  clients ──► bounded JobQueue ──► dispatcher thread      stage-2 thread
//!                 (backpressure)     │ batch formation       │ owns Engine
//!                                    │ STAGE 1: grid kNN     │ STAGE 2: alpha +
//!                                    │ (CPU pool, rust)      │ streamed interp
//!                                    └── sync_channel(depth) ┘ (PJRT artifacts)
//! ```
//!
//! The two stages run in separate threads connected by a bounded channel,
//! so stage 1 of batch *i+1* overlaps stage 2 of batch *i* — the paper's
//! two-stage decomposition (Fig. 1) turned into a serving pipeline.
//! Python is never involved: stage 2 executes AOT artifacts through PJRT,
//! or falls back to the pure-rust kernel when artifacts are absent.
//!
//! Every request carries its own [`QueryOptions`] — k, kernel variant,
//! ring rule, local mode, alpha levels, fuzzy bounds, area — resolved
//! against [`CoordinatorConfig`] defaults at submit time.  Batches form
//! only among option-identical jobs, and both stages read the batch's
//! [`ResolvedOptions`] instead of the shared config, so one coordinator
//! concurrently serves arbitrarily mixed tunings.
//!
//! Datasets are **live** ([`crate::live`]): appends and removals layer a
//! small delta overlay over the immutable epoch grid, queries merge grid
//! kNN over the epoch with brute force over the delta, and a background
//! compactor folds the overlay into a new epoch.  Submit stamps the
//! dataset's current epoch into the resolved options, so epoch changes
//! partition batch admission (a batch never mixes epochs) and every
//! response echoes the epoch it was served from.  Each batch is served
//! from one snapshot taken at batch formation; in-flight batches keep
//! their snapshot across a compaction publish.

pub mod batcher;
pub mod dataset;
pub mod metrics;
pub mod options;
pub mod request;
pub mod snapshot;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use crate::aidw::alpha;
use crate::aidw::params::AidwParams;
use crate::aidw::pipeline::weighted_stage_on;
use crate::error::{Error, Result};
use crate::geom::PointSet;
use crate::grid::GridConfig;
use crate::knn::grid_knn::{grid_knn_avg_distances_on, GridKnnConfig, RingRule};
use crate::knn::merged::merged_knn_avg_distances_on;
use crate::live::{
    AppendOutcome, CompactionReport, LiveConfig, LiveDataset, LiveRegistry, LiveSnapshot,
    LiveStatus, RemoveOutcome,
};
use crate::pool::Pool;
use crate::runtime::{AidwExecutor, Engine};

pub use crate::runtime::Variant;
pub use batcher::BatchPolicy;
pub use dataset::{Dataset, DatasetRegistry};
pub use metrics::{Metrics, MetricsSnapshot};
pub use options::{LocalMode, QueryOptions, ResolvedOptions};
pub use request::{Backend, InterpolationRequest, InterpolationResponse, Ticket};

use batcher::{Batch, JobQueue};
use request::Job;

/// Stage-2 engine selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineMode {
    /// Use PJRT artifacts if present, else pure-rust fallback.
    #[default]
    Auto,
    /// Require PJRT artifacts (error at startup when missing).
    PjrtRequired,
    /// Force the pure-rust stage 2 (benchmark baseline / no artifacts).
    CpuOnly,
}

/// Coordinator configuration — the *defaults* requests inherit; every
/// algorithmic knob here can be overridden per request via
/// [`QueryOptions`].
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifact directory (None = default dir / $AIDW_ARTIFACTS).
    pub artifact_dir: Option<std::path::PathBuf>,
    pub engine_mode: EngineMode,
    /// Use the small q256/m1024 artifacts (fast XLA compiles — tests).
    pub test_shapes: bool,
    /// Default kernel variant for requests that don't specify one.
    pub default_variant: Variant,
    /// Default AIDW parameters (k, alpha levels, fuzzy bounds, area).
    pub params: AidwParams,
    pub grid: GridConfig,
    pub batch: BatchPolicy,
    /// Default kNN ring rule (Exact by default).
    pub ring_rule: RingRule,
    /// Worker width for stage 1 (None = machine-sized).
    pub stage1_threads: Option<usize>,
    /// Bounded depth of the stage-1 -> stage-2 channel.
    pub pipeline_depth: usize,
    /// Default local-AIDW mode (extension A5): when set, stage 2 weights
    /// each query over its N nearest neighbors instead of all data points.
    /// Stage 1 gathers the neighbor ids in the same grid pass that feeds
    /// alpha.  None = the paper's dense weighting.
    pub local_neighbors: Option<usize>,
    /// Live-mutation durability directory: when set, registrations write
    /// a snapshot, every append/remove appends to a per-dataset WAL, and
    /// startup restores snapshot + WAL automatically.  None = in-memory
    /// datasets (mutable, but lost on restart).
    pub live_dir: Option<std::path::PathBuf>,
    /// Live-mutation tunables (compaction threshold, WAL sync).
    pub live: LiveConfig,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: None,
            engine_mode: EngineMode::Auto,
            test_shapes: false,
            default_variant: Variant::Tiled,
            params: AidwParams::default(),
            grid: GridConfig::default(),
            batch: BatchPolicy::default(),
            ring_rule: RingRule::Exact,
            stage1_threads: None,
            pipeline_depth: 2,
            local_neighbors: None,
            live_dir: None,
            live: LiveConfig::default(),
        }
    }
}

/// A batch after stage 1, waiting for stage 2.
struct Stage2Job {
    batch: Batch,
    queries: Vec<(f64, f64)>,
    r_obs: Vec<f64>,
    /// Local mode only: row-major (queries x n) neighbor indices.
    neighbors: Option<(Vec<u32>, usize)>,
    /// The consistent live snapshot this whole batch is served from.
    snap: Arc<LiveSnapshot>,
    knn_s: f64,
}

struct Shared {
    registry: LiveRegistry,
    queue: JobQueue,
    metrics: Metrics,
    config: CoordinatorConfig,
    pool: Pool,
    running: AtomicBool,
}

/// The interpolation service coordinator.  See module docs.
pub struct Coordinator {
    shared: Arc<Shared>,
    dispatcher: Option<JoinHandle<()>>,
    stage2: Option<JoinHandle<()>>,
    /// Which backend stage 2 is using (resolved at startup).
    backend: Backend,
}

impl Coordinator {
    /// Start the coordinator (spawns the pipeline threads).
    pub fn new(config: CoordinatorConfig) -> Result<Coordinator> {
        config.params.validate().map_err(Error::InvalidArgument)?;
        // Resolve the stage-2 backend up front so startup fails fast.
        let artifact_dir = config
            .artifact_dir
            .clone()
            .unwrap_or_else(crate::runtime::default_artifact_dir);
        let backend = match config.engine_mode {
            EngineMode::CpuOnly => Backend::CpuFallback,
            EngineMode::PjrtRequired => {
                if !artifact_dir.join("manifest.json").exists() {
                    return Err(Error::Artifact(format!(
                        "PJRT required but no manifest at {}",
                        artifact_dir.display()
                    )));
                }
                Backend::Pjrt
            }
            EngineMode::Auto => {
                if artifact_dir.join("manifest.json").exists() {
                    Backend::Pjrt
                } else {
                    Backend::CpuFallback
                }
            }
        };

        let pool = match config.stage1_threads {
            Some(n) => Pool::new(n),
            None => Pool::machine_sized(),
        };
        let shared = Arc::new(Shared {
            registry: LiveRegistry::new(),
            queue: JobQueue::new(config.batch),
            metrics: Metrics::default(),
            config,
            pool,
            running: AtomicBool::new(true),
        });

        // restore persisted live datasets (snapshot + WAL replay) before
        // any request can arrive
        if let Some(dir) = shared.config.live_dir.clone() {
            for name in crate::live::wal::list_live(&dir)? {
                let ds = LiveDataset::load(
                    &shared.pool,
                    &name,
                    &dir,
                    &shared.config.grid,
                    shared.config.params.area,
                    shared.config.live,
                )?;
                shared.registry.insert(ds);
            }
        }

        // stage-1 -> stage-2 bounded channel
        let (tx, rx) = mpsc::sync_channel::<Stage2Job>(shared.config.pipeline_depth);

        let dispatcher = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("aidw-dispatch".into())
                .spawn(move || dispatcher_loop(shared, tx))
                .map_err(Error::Io)?
        };
        let stage2 = {
            let shared = shared.clone();
            let dir = artifact_dir.clone();
            std::thread::Builder::new()
                .name("aidw-stage2".into())
                .spawn(move || stage2_loop(shared, rx, backend, dir))
                .map_err(Error::Io)?
        };

        Ok(Coordinator { shared, dispatcher: Some(dispatcher), stage2: Some(stage2), backend })
    }

    /// Coordinator with default config.
    pub fn with_defaults() -> Result<Coordinator> {
        Coordinator::new(CoordinatorConfig::default())
    }

    /// The stage-2 backend in use.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The configuration requests resolve their options against.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.shared.config
    }

    /// Register a dataset (builds its epoch-0 grid index now; with a
    /// live directory configured, also writes the durable snapshot and a
    /// fresh WAL).
    pub fn register_dataset(&self, name: &str, points: PointSet) -> Result<()> {
        let cfg = &self.shared.config;
        // retire any existing entry *before* writing the replacement's
        // durable files, so the old dataset's compactor can never clobber
        // them afterwards
        if let Ok(old) = self.shared.registry.get(name) {
            old.retire();
        }
        let ds = match &cfg.live_dir {
            Some(dir) => LiveDataset::build_persistent(
                &self.shared.pool,
                name,
                points,
                &cfg.grid,
                cfg.params.area,
                cfg.live,
                dir,
            )?,
            None => LiveDataset::build(
                &self.shared.pool,
                name,
                points,
                &cfg.grid,
                cfg.params.area,
                cfg.live,
            )?,
        };
        if let Some(old) = self.shared.registry.insert(ds) {
            // deliberate epoch retirement (already detached from the
            // durable files above; a concurrent register of the same name
            // may hand us a not-yet-retired instance, so retire again)
            old.retire();
        }
        Ok(())
    }

    /// Remove a dataset (joins its compactor and deletes its durable
    /// state so a restart does not resurrect it).
    pub fn drop_dataset(&self, name: &str) -> bool {
        match self.shared.registry.remove(name) {
            Some(ds) => {
                // after retire() no compaction — background or an
                // in-flight synchronous one — can re-create the files we
                // are about to delete
                ds.retire();
                if let Some(dir) = &self.shared.config.live_dir {
                    std::fs::remove_file(crate::live::wal::live_path(dir, name)).ok();
                    std::fs::remove_file(crate::live::wal::wal_path(dir, name)).ok();
                }
                true
            }
            None => false,
        }
    }

    /// Append points to a live dataset; may trigger background
    /// compaction once the overlay crosses the configured threshold.
    pub fn append_points(&self, name: &str, points: PointSet) -> Result<AppendOutcome> {
        let ds = self.shared.registry.get(name)?;
        let out = ds.append(&points)?;
        LiveDataset::maybe_spawn_compaction(&ds);
        Ok(out)
    }

    /// Tombstone live points by id (strict: all ids must be live).
    pub fn remove_points(&self, name: &str, ids: &[u64]) -> Result<RemoveOutcome> {
        let ds = self.shared.registry.get(name)?;
        let out = ds.remove(ids)?;
        LiveDataset::maybe_spawn_compaction(&ds);
        Ok(out)
    }

    /// Synchronously compact a live dataset (fold overlay, bump epoch,
    /// truncate WAL).
    pub fn compact_dataset(&self, name: &str) -> Result<CompactionReport> {
        self.shared.registry.get(name)?.compact_now()
    }

    /// Live mutation/compaction statistics for one dataset.
    pub fn live_status(&self, name: &str) -> Result<LiveStatus> {
        Ok(self.shared.registry.get(name)?.status())
    }

    /// Direct access to a live dataset (tests, advanced callers).
    pub fn live_dataset(&self, name: &str) -> Result<Arc<LiveDataset>> {
        self.shared.registry.get(name)
    }

    /// Registered dataset names.
    pub fn datasets(&self) -> Vec<String> {
        self.shared.registry.names()
    }

    /// Submit asynchronously; returns a ticket to await.
    ///
    /// Fails fast — before the job reaches any pipeline thread — on empty
    /// queries, unknown datasets, and invalid option overrides (`k == 0`,
    /// `r_max <= r_min`, non-positive alpha levels, ...).
    pub fn submit(&self, request: InterpolationRequest) -> Result<Ticket> {
        if request.queries.is_empty() {
            return Err(Error::InvalidArgument("empty query list".into()));
        }
        // fail fast on unknown datasets (cheap read-lock check)
        let live = self.shared.registry.get(&request.dataset)?;
        // resolve per-request options against config defaults and validate
        let mut resolved = request.options.resolve(&self.shared.config);
        resolved.validate()?;
        // stamp the dataset's current epoch into the admission key: jobs
        // admitted against different epochs never share a batch, and the
        // response echo reports the epoch a batch was served from
        resolved.epoch = Some(live.epoch());
        // local weighting needs per-id neighbor gathers the merged path
        // does not provide yet; reject while the overlay is non-empty
        if resolved.local_neighbors.is_some() && live.is_mutated() {
            return Err(Error::InvalidArgument(format!(
                "local weighting is unavailable while dataset '{}' has \
                 uncompacted mutations; request dense weighting or compact first",
                request.dataset
            )));
        }
        let n_queries = request.queries.len() as u64;
        let (tx, rx) = mpsc::channel();
        let job = Job {
            request,
            resolved,
            respond: tx,
            enqueued: std::time::Instant::now(),
        };
        match self.shared.queue.push(job) {
            Ok(()) => {
                // count only accepted jobs (rejected submissions used to
                // inflate both counters)
                self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.shared
                    .metrics
                    .queries
                    .fetch_add(n_queries, Ordering::Relaxed);
                Ok(Ticket { rx })
            }
            Err(e) => {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit and block for the response.
    pub fn interpolate(&self, request: InterpolationRequest) -> Result<InterpolationResponse> {
        self.submit(request)?.wait()
    }

    /// Convenience: values only.
    pub fn interpolate_values(&self, dataset: &str, queries: Vec<(f64, f64)>) -> Result<Vec<f64>> {
        Ok(self.interpolate(InterpolationRequest::new(dataset, queries))?.values)
    }

    /// Persist every registered dataset to `<dir>/<name>.aidw` (the v1
    /// portable export: the *live merged* point set, without ids — WAL
    /// durability is the `live_dir` mechanism, this is for interchange).
    pub fn save_datasets(&self, dir: &std::path::Path) -> Result<usize> {
        let all = self.shared.registry.all();
        for ds in &all {
            let (pts, _ids) = ds.snapshot().live_points();
            snapshot::save_dataset(dir, ds.name(), &pts)?;
        }
        Ok(all.len())
    }

    /// Register every snapshot found in `dir` (grid indexes are rebuilt).
    pub fn load_datasets(&self, dir: &std::path::Path) -> Result<usize> {
        let loaded = snapshot::load_dir(dir)?;
        let count = loaded.len();
        for (name, pts) in loaded {
            self.register_dataset(&name, pts)?;
        }
        Ok(count)
    }

    /// Metrics snapshot.
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot()
    }

    /// Current queue depth (diagnostics / backpressure observers).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Graceful shutdown: drains queued work, stops the pipeline threads,
    /// and joins any background compactions.
    pub fn shutdown(&mut self) {
        if self.shared.running.swap(false, Ordering::SeqCst) {
            self.shared.queue.close();
            if let Some(h) = self.dispatcher.take() {
                let _ = h.join();
            }
            if let Some(h) = self.stage2.take() {
                let _ = h.join();
            }
            self.shared.registry.shutdown_all();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Dispatcher: batch formation + stage 1 (grid kNN) on the CPU pool, per
/// the batch's resolved options.
fn dispatcher_loop(shared: Arc<Shared>, tx: mpsc::SyncSender<Stage2Job>) {
    while let Some(batch) = shared.queue.next_batch() {
        shared.metrics.batches.fetch_add(1, Ordering::Relaxed);

        let live = match shared.registry.get(&batch.dataset) {
            Ok(ds) => ds,
            Err(e) => {
                fail_batch(&shared, batch, &e);
                continue;
            }
        };
        // one snapshot per batch: every member is served from the same
        // epoch/overlay state, and keeps it across a compaction publish
        let snap = live.snapshot();

        // concatenate all queries of the batch
        let mut queries = Vec::with_capacity(batch.total_queries);
        for job in &batch.jobs {
            queries.extend_from_slice(&job.request.queries);
        }

        // STAGE 1: grid kNN (the paper's fast kNN search), driven by the
        // batch's options.  A compacted snapshot takes the plain grid
        // path (honoring the request's ring rule; in local mode the same
        // grid pass also gathers neighbor ids).  A mutated snapshot takes
        // the merged path: grid over the epoch base ∪ brute force over
        // the delta, tombstones filtered, always the exact bound.
        let t0 = std::time::Instant::now();
        let opts = batch.options;
        let k = opts.k.min(snap.live_len).max(1);
        let (r_obs, neighbors) = if snap.is_compacted() {
            match opts.local_neighbors {
                Some(n) => {
                    let n = n.max(k);
                    let (idx, r_obs) = crate::knn::grid_knn::grid_knn_neighbors(
                        &shared.pool,
                        &snap.base.grid,
                        &queries,
                        n,
                        k,
                        opts.ring_rule,
                    );
                    (r_obs, Some((idx, n)))
                }
                None => {
                    let knn_cfg = GridKnnConfig { k, rule: opts.ring_rule };
                    let (r_obs, _) =
                        grid_knn_avg_distances_on(&shared.pool, &snap.base.grid, &queries, &knn_cfg);
                    (r_obs, None)
                }
            }
        } else {
            if opts.local_neighbors.is_some() {
                // submit guards this; a mutation can still race in between
                fail_batch(
                    &shared,
                    batch,
                    &Error::InvalidArgument(format!(
                        "local weighting is unavailable while dataset '{}' has \
                         uncompacted mutations",
                        snap.base.name
                    )),
                );
                continue;
            }
            let view = snap.merged_view();
            let r_obs = merged_knn_avg_distances_on(&shared.pool, &view, &queries, k);
            (r_obs, None)
        };
        let knn_s = t0.elapsed().as_secs_f64();

        let job = Stage2Job { batch, queries, r_obs, neighbors, snap, knn_s };
        if tx.send(job).is_err() {
            break; // stage 2 is gone
        }
    }
    // dropping tx closes the stage-2 loop
}

/// Stage 2: adaptive alpha + streamed weighted interpolation.
fn stage2_loop(
    shared: Arc<Shared>,
    rx: mpsc::Receiver<Stage2Job>,
    backend: Backend,
    artifact_dir: std::path::PathBuf,
) {
    // The Engine lives entirely in this thread (PJRT handles are not
    // shared across threads).
    let engine = match backend {
        Backend::Pjrt => match Engine::new(&artifact_dir) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("aidw: engine init failed ({err}); using CPU fallback");
                None
            }
        },
        Backend::CpuFallback => None,
    };

    while let Ok(sj) = rx.recv() {
        let result = run_stage2(&shared, &engine, &sj);
        match result {
            Ok((values, knn_extra_s, interp_s)) => {
                let knn_s = sj.knn_s + knn_extra_s;
                shared.metrics.add_stage_times(knn_s, interp_s);
                // merged (mutated-snapshot) batches run the CPU path even
                // when artifacts are loaded; report what actually ran
                let backend = if engine.is_some() && sj.snap.is_compacted() {
                    Backend::Pjrt
                } else {
                    Backend::CpuFallback
                };
                respond_batch(&shared, sj, values, knn_s, interp_s, backend);
            }
            Err(e) => {
                shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                let msg = e.to_string();
                for job in sj.batch.jobs {
                    let _ = job.respond.send(Err(Error::Service(msg.clone())));
                }
            }
        }
    }
}

/// The effective AIDW parameter block for a batch: resolved options with
/// the snapshot's live area substituted when no explicit override was
/// given and k clamped to the live point count (what stage 1 actually
/// searched with).
fn effective_params(opts: &ResolvedOptions, snap: &LiveSnapshot) -> AidwParams {
    let mut p = opts.params();
    p.k = opts.k.min(snap.live_len).max(1);
    p.area = Some(opts.area.unwrap_or_else(|| snap.area()));
    p
}

/// Execute stage 2 for one batch; returns (values, extra_knn_s, interp_s).
fn run_stage2(
    shared: &Shared,
    engine: &Option<Engine>,
    sj: &Stage2Job,
) -> Result<(Vec<f64>, f64, f64)> {
    let opts = &sj.batch.options;
    let params = effective_params(opts, &sj.snap);
    if !sj.snap.is_compacted() {
        // merged stage 2 on the CPU: Eq.-1 sums over base-live + delta
        // points with r_exp recomputed from the live count/bounds.  The
        // fixed-shape PJRT artifacts cannot see overlay deltas; the
        // compactor restores the artifact path at the next epoch.
        let r_exp = match opts.area {
            Some(a) => alpha::expected_nn_distance(sj.snap.live_len as f64, a),
            None => sj.snap.r_exp(),
        };
        let t0 = std::time::Instant::now();
        let alphas: Vec<f64> = sj
            .r_obs
            .iter()
            .map(|&ro| alpha::adaptive_alpha(ro, r_exp, &params))
            .collect();
        let alpha_s = t0.elapsed().as_secs_f64();
        let t1 = std::time::Instant::now();
        let values =
            crate::live::merged_weighted_stage_on(&shared.pool, &sj.snap, &sj.queries, &alphas);
        return Ok((values, alpha_s, t1.elapsed().as_secs_f64()));
    }
    let dataset: &Dataset = &sj.snap.base;
    match engine {
        Some(engine) => {
            let exec = if shared.config.test_shapes {
                AidwExecutor::new_test_shapes(engine)
            } else {
                AidwExecutor::new(engine)
            };
            let (values, times) = match &sj.neighbors {
                Some((idx, n)) => exec.local_aidw(
                    &dataset.points,
                    &sj.queries,
                    &sj.r_obs,
                    idx,
                    *n,
                    &params,
                )?,
                None => exec.improved_aidw(
                    &dataset.points,
                    &sj.queries,
                    &sj.r_obs,
                    &params,
                    opts.variant,
                )?,
            };
            Ok((values, times.knn_s, times.interp_s))
        }
        None => {
            // pure-rust stage 2; recompute r_exp only when the request
            // overrode the area (else the dataset's cached Eq.-2 constant
            // is exact)
            let r_exp = match opts.area {
                Some(a) => alpha::expected_nn_distance(dataset.points.len() as f64, a),
                None => dataset.r_exp,
            };
            let t0 = std::time::Instant::now();
            let alphas: Vec<f64> = sj
                .r_obs
                .iter()
                .map(|&ro| alpha::adaptive_alpha(ro, r_exp, &params))
                .collect();
            let alpha_s = t0.elapsed().as_secs_f64();
            let t1 = std::time::Instant::now();
            let values = match &sj.neighbors {
                Some((idx, n)) => local_weighted_cpu(
                    &shared.pool, &dataset.points, &sj.queries, &alphas, idx, *n),
                None => weighted_stage_on(
                    &shared.pool, &dataset.points, &sj.queries, &alphas),
            };
            Ok((values, alpha_s, t1.elapsed().as_secs_f64()))
        }
    }
}

/// CPU local weighting with precomputed alphas (stage-2 fallback of the
/// local mode; mirrors `aidw::local` but reuses this batch's stage-1
/// neighbor gather instead of searching again).
fn local_weighted_cpu(
    pool: &Pool,
    data: &crate::geom::PointSet,
    queries: &[(f64, f64)],
    alphas: &[f64],
    nbr_idx: &[u32],
    n: usize,
) -> Vec<f64> {
    use crate::geom::{dist2, EPS_D2};
    let mut out = vec![0f64; queries.len()];
    pool.for_each_slice_mut(&mut out, 64, |offset, chunk| {
        for (j, slot) in chunk.iter_mut().enumerate() {
            let qi = offset + j;
            let (qx, qy) = queries[qi];
            let a = alphas[qi];
            let mut sw = 0.0f64;
            let mut swz = 0.0f64;
            for &pid in &nbr_idx[qi * n..(qi + 1) * n] {
                if pid == u32::MAX {
                    continue;
                }
                let i = pid as usize;
                let d2 = dist2(qx, qy, data.xs[i], data.ys[i]).max(EPS_D2);
                let w = (-0.5 * a * d2.ln()).exp();
                sw += w;
                swz += w * data.zs[i];
            }
            *slot = swz / sw;
        }
    });
    out
}

/// Split batch results back per job and respond, echoing the resolved
/// options (with the live area, clamped k, and served epoch substituted)
/// for client-side audit.
fn respond_batch(
    shared: &Shared,
    sj: Stage2Job,
    values: Vec<f64>,
    knn_s: f64,
    interp_s: f64,
    backend: Backend,
) {
    let mut echoed = sj.batch.options;
    echoed.area = Some(echoed.area.unwrap_or_else(|| sj.snap.area()));
    // the audit record reports what ran: k is clamped to the live count,
    // and the epoch is the snapshot the batch was served from (it may be
    // newer than the admission epoch if a compaction published in between
    // — still one single epoch for the whole batch)
    echoed.k = echoed.k.min(sj.snap.live_len).max(1);
    echoed.epoch = Some(sj.snap.epoch);
    let total = sj.queries.len();
    let mut offset = 0usize;
    for job in sj.batch.jobs {
        let n = job.request.queries.len();
        let slice = values[offset..offset + n].to_vec();
        offset += n;
        shared
            .metrics
            .latency
            .record(job.enqueued.elapsed().as_secs_f64());
        let _ = job.respond.send(Ok(InterpolationResponse {
            values: slice,
            knn_s,
            interp_s,
            batch_queries: total,
            backend,
            options: echoed,
        }));
    }
}

fn fail_batch(shared: &Shared, batch: Batch, err: &Error) {
    shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
    let msg = err.to_string();
    for job in batch.jobs {
        let _ = job.respond.send(Err(Error::Service(msg.clone())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    fn cpu_coordinator() -> Coordinator {
        let cfg = CoordinatorConfig {
            engine_mode: EngineMode::CpuOnly,
            ..Default::default()
        };
        Coordinator::new(cfg).unwrap()
    }

    #[test]
    fn register_and_interpolate_cpu() {
        let c = cpu_coordinator();
        assert_eq!(c.backend(), Backend::CpuFallback);
        let pts = workload::uniform_square(400, 50.0, 71);
        c.register_dataset("d", pts.clone()).unwrap();
        assert_eq!(c.datasets(), vec!["d".to_string()]);
        let queries = workload::uniform_square(50, 50.0, 72).xy();
        let resp = c
            .interpolate(InterpolationRequest::new("d", queries.clone()))
            .unwrap();
        assert_eq!(resp.values.len(), 50);
        assert_eq!(resp.backend, Backend::CpuFallback);
        // the response echoes the fully-resolved options
        assert_eq!(resp.options.k, 10);
        assert_eq!(resp.options.ring_rule, RingRule::Exact);
        assert_eq!(resp.options.local_neighbors, None);
        assert!(resp.options.area.is_some(), "area must be filled in");
        // matches the serial reference
        let want = crate::aidw::serial::aidw_serial(&pts, &queries, &AidwParams::default());
        for (g, w) in resp.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.queries, 50);
        assert!(m.batches >= 1);
    }

    #[test]
    fn unknown_dataset_fails_fast() {
        let c = cpu_coordinator();
        let err = c
            .interpolate(InterpolationRequest::new("missing", vec![(0.0, 0.0)]))
            .unwrap_err();
        assert!(matches!(err, Error::UnknownDataset(_)), "{err}");
    }

    #[test]
    fn empty_queries_rejected() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 73);
        c.register_dataset("d", pts).unwrap();
        assert!(c.interpolate(InterpolationRequest::new("d", vec![])).is_err());
    }

    #[test]
    fn invalid_options_rejected_at_submit() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 73);
        c.register_dataset("d", pts).unwrap();
        let q = vec![(1.0, 1.0)];
        for bad in [
            QueryOptions::new().k(0),
            QueryOptions::new().r_bounds(2.0, 1.0),
            QueryOptions::new().alpha_levels([0.0, 1.0, 2.0, 3.0, 4.0]),
            QueryOptions::new().area(-1.0),
            QueryOptions::new().local_neighbors(0),
        ] {
            let err = c
                .submit(InterpolationRequest::new("d", q.clone()).with_options(bad.clone()))
                .unwrap_err();
            assert!(matches!(err, Error::InvalidArgument(_)), "{bad:?}: {err}");
        }
        // invalid submissions must not inflate the accepted counters
        let m = c.metrics();
        assert_eq!(m.requests, 0);
        assert_eq!(m.queries, 0);
    }

    #[test]
    fn concurrent_submissions_batch_together() {
        let c = std::sync::Arc::new(cpu_coordinator());
        let pts = workload::uniform_square(600, 50.0, 74);
        c.register_dataset("d", pts).unwrap();
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let queries = workload::uniform_square(25, 50.0, 100 + t).xy();
                c.interpolate(InterpolationRequest::new("d", queries)).unwrap()
            }));
        }
        let resps: Vec<InterpolationResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(resps.iter().all(|r| r.values.len() == 25));
        // at least some requests shared a batch (probabilistic but the
        // linger window makes it overwhelmingly likely under contention)
        let m = c.metrics();
        assert_eq!(m.requests, 8);
        assert!(m.batches <= 8);
    }

    #[test]
    fn shutdown_is_idempotent_and_rejects_after() {
        let mut c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 75);
        c.register_dataset("d", pts).unwrap();
        c.shutdown();
        c.shutdown();
        assert!(c
            .interpolate(InterpolationRequest::new("d", vec![(1.0, 1.0)]))
            .is_err());
    }

    #[test]
    fn rejected_submissions_do_not_count_as_requests() {
        let mut c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 85);
        c.register_dataset("d", pts).unwrap();
        c.shutdown(); // queue closed -> push fails
        let err = c
            .submit(InterpolationRequest::new("d", vec![(1.0, 1.0)]))
            .unwrap_err();
        assert!(matches!(err, Error::Unavailable(_)), "{err}");
        let m = c.metrics();
        assert_eq!(m.requests, 0, "rejected submit must not count");
        assert_eq!(m.queries, 0);
        assert_eq!(m.rejected, 1);
    }

    #[test]
    fn local_mode_cpu_matches_local_pipeline() {
        let cfg = CoordinatorConfig {
            engine_mode: EngineMode::CpuOnly,
            local_neighbors: Some(48),
            ..Default::default()
        };
        let c = Coordinator::new(cfg).unwrap();
        let pts = workload::uniform_square(1000, 80.0, 78);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(60, 80.0, 79).xy();
        let got = c.interpolate_values("d", queries.clone()).unwrap();
        let want = crate::aidw::local::interpolate_local(
            &pts,
            &queries,
            &AidwParams::default(),
            &crate::aidw::local::LocalConfig { n_neighbors: 48, ..Default::default() },
        )
        .unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn per_request_k_override() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(300, 50.0, 76);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(20, 50.0, 77).xy();
        let got = c
            .interpolate(InterpolationRequest::new("d", queries.clone()).with_k(3))
            .unwrap();
        assert_eq!(got.options.k, 3, "resolved echo must report the override");
        let mut p = AidwParams::default();
        p.k = 3;
        let want = crate::aidw::serial::aidw_serial(&pts, &queries, &p);
        for (g, w) in got.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
        // oversized k clamps to the dataset size, and the echo reports
        // the clamped value (what stage 1 actually searched with)
        let resp = c
            .interpolate(InterpolationRequest::new("d", queries.clone()).with_k(10_000))
            .unwrap();
        assert_eq!(resp.options.k, 300);
        let mut p = AidwParams::default();
        p.k = 10_000; // serial reference clamps internally the same way
        let want = crate::aidw::serial::aidw_serial(&pts, &queries, &p);
        for (g, w) in resp.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn per_request_local_override_on_dense_coordinator() {
        // coordinator defaults to dense; one request opts into local mode
        let c = cpu_coordinator();
        let pts = workload::uniform_square(800, 80.0, 81);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(40, 80.0, 82).xy();
        let resp = c
            .interpolate(
                InterpolationRequest::new("d", queries.clone())
                    .with_options(QueryOptions::new().local_neighbors(64)),
            )
            .unwrap();
        assert_eq!(resp.options.local_neighbors, Some(64));
        let want = crate::aidw::local::interpolate_local(
            &pts,
            &queries,
            &AidwParams::default(),
            &crate::aidw::local::LocalConfig { n_neighbors: 64, ..Default::default() },
        )
        .unwrap();
        for (g, w) in resp.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn mutated_dataset_serves_merged_and_echoes_epoch() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(400, 50.0, 91);
        c.register_dataset("d", pts).unwrap();
        let extra = workload::uniform_square(40, 50.0, 92);
        let appended = c.append_points("d", extra).unwrap();
        assert_eq!(appended.first_id, 400);
        assert_eq!(appended.count, 40);
        let removed = c.remove_points("d", &[0, 401]).unwrap();
        assert_eq!(removed.removed, 2);
        assert_eq!(c.live_status("d").unwrap().live_points, 438);

        let queries = workload::uniform_square(30, 50.0, 93).xy();
        let resp = c
            .interpolate(InterpolationRequest::new("d", queries.clone()))
            .unwrap();
        assert_eq!(resp.options.epoch, Some(0), "epoch echoed for audit");
        assert_eq!(resp.values.len(), 30);

        // bit-identical to a fresh registration of the merged live set
        let (merged, _) = c.live_dataset("d").unwrap().snapshot().live_points();
        let c2 = cpu_coordinator();
        c2.register_dataset("m", merged).unwrap();
        let want = c2
            .interpolate(InterpolationRequest::new("m", queries.clone()))
            .unwrap();
        assert_eq!(resp.values, want.values, "merged path must be exact");

        // compaction bumps the epoch; answers stay bit-identical
        let rep = c.compact_dataset("d").unwrap();
        assert_eq!((rep.old_epoch, rep.new_epoch), (0, 1));
        let resp2 = c
            .interpolate(InterpolationRequest::new("d", queries))
            .unwrap();
        assert_eq!(resp2.options.epoch, Some(1));
        assert_eq!(resp2.values, want.values);
    }

    #[test]
    fn local_mode_rejected_on_mutated_dataset_until_compaction() {
        let c = cpu_coordinator();
        c.register_dataset("d", workload::uniform_square(300, 50.0, 94)).unwrap();
        let q = vec![(1.0, 1.0)];
        // local mode works while compacted
        c.interpolate(
            InterpolationRequest::new("d", q.clone())
                .with_options(QueryOptions::new().local_neighbors(16)),
        )
        .unwrap();
        c.append_points("d", workload::uniform_square(5, 50.0, 95)).unwrap();
        let err = c
            .submit(
                InterpolationRequest::new("d", q.clone())
                    .with_options(QueryOptions::new().local_neighbors(16)),
            )
            .unwrap_err();
        assert!(matches!(err, Error::InvalidArgument(_)), "{err}");
        // dense requests still fine, and compaction restores local mode
        c.interpolate(InterpolationRequest::new("d", q.clone())).unwrap();
        c.compact_dataset("d").unwrap();
        c.interpolate(
            InterpolationRequest::new("d", q)
                .with_options(QueryOptions::new().local_neighbors(16)),
        )
        .unwrap();
    }

    #[test]
    fn mutations_on_unknown_dataset_fail_fast() {
        let c = cpu_coordinator();
        assert!(c.append_points("ghost", workload::uniform_square(3, 1.0, 96)).is_err());
        assert!(c.remove_points("ghost", &[0]).is_err());
        assert!(c.compact_dataset("ghost").is_err());
        assert!(c.live_status("ghost").is_err());
    }

    #[test]
    fn per_request_area_override_changes_alpha_regime() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(400, 10.0, 83);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(30, 10.0, 84).xy();
        let lo = c
            .interpolate(
                InterpolationRequest::new("d", queries.clone())
                    .with_options(QueryOptions::new().area(1e9)),
            )
            .unwrap();
        let hi = c
            .interpolate(
                InterpolationRequest::new("d", queries.clone())
                    .with_options(QueryOptions::new().area(1e-9)),
            )
            .unwrap();
        assert_eq!(lo.options.area, Some(1e9));
        assert_eq!(hi.options.area, Some(1e-9));
        let diff: f64 = lo
            .values
            .iter()
            .zip(&hi.values)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-9, "area override had no effect");
        // each matches its serial reference
        for (resp, area) in [(&lo, 1e9), (&hi, 1e-9)] {
            let mut p = AidwParams::default();
            p.area = Some(area);
            let want = crate::aidw::serial::aidw_serial(&pts, &queries, &p);
            for (g, w) in resp.values.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "{g} vs {w}");
            }
        }
    }
}
