//! The L3 coordinator — the serving system around the paper's algorithm.
//!
//! ```text
//!  clients ──► bounded JobQueue ──► dispatcher thread      stage-2 thread
//!                 (backpressure)     │ batch formation       │ owns Engine
//!                                    │ STAGE 1: grid kNN     │ STAGE 2: alpha +
//!                                    │ (CPU pool, rust)      │ streamed interp
//!                                    └── sync_channel(depth) ┘ (PJRT artifacts)
//! ```
//!
//! The two stages run in separate threads connected by a bounded channel,
//! so stage 1 of batch *i+1* overlaps stage 2 of batch *i* — the paper's
//! two-stage decomposition (Fig. 1) turned into a serving pipeline.
//! Python is never involved: stage 2 executes AOT artifacts through PJRT,
//! or falls back to the pure-rust kernel when artifacts are absent.
//!
//! Every request carries its own [`QueryOptions`] — k, kernel variant,
//! ring rule, local mode, alpha levels, fuzzy bounds, area — resolved
//! against [`CoordinatorConfig`] defaults at submit time.
//!
//! ## The Stage1/Stage2 seam
//!
//! Execution is planned along the paper's own decomposition
//! ([`crate::aidw::plan`]): the dispatcher builds a
//! [`crate::aidw::plan::Stage1Plan`] per batch (grid kNN over a compacted
//! snapshot, merged base ∪ delta over a mutated one; local mode gathers
//! neighbor ids in the same pass) whose product — the
//! [`crate::aidw::plan::NeighborArtifact`] of per-query r_obs, alphas,
//! and neighbor indices — is handed to the stage-2 thread.
//!
//! * **Admission** keys on [`ResolvedOptions::stage1_key`], *not* full
//!   option equality: jobs that differ only in stage-2 kernel variant
//!   share one batch, the kNN sweep (the dominant cost in the paper) runs
//!   once, and stage 2 executes once per distinct variant group over that
//!   group's query rows.
//! * **Reuse**: the [`cache::NeighborCache`] holds recent artifacts keyed
//!   on `(dataset, epoch, overlay version, stage1_key, query
//!   fingerprint)`, so a repeated raster skips stage 1 entirely — on
//!   mutated (uncompacted) snapshots too: every append/remove bumps the
//!   overlay version, which retires stale artifacts by key instead of
//!   bypassing the cache.  A raster whose rows are covered by a cached
//!   artifact of the same snapshot is served by row-gather (subset
//!   reuse).  Invalidation rules live in [`cache`]: mutation bumps the
//!   overlay version, compaction bumps the epoch, and register/drop
//!   purge by name.
//!
//! Responses echo each job's *own* resolved options (the batch may mix
//! variants) plus the planner's coalescing/cache facts
//! ([`InterpolationResponse::stage1_cache_hit`] /
//! [`InterpolationResponse::stage2_groups`]).
//!
//! Datasets are **live** ([`crate::live`]): appends and removals layer a
//! small delta overlay over the immutable epoch grid, queries merge grid
//! kNN over the epoch with brute force over the delta, and a background
//! compactor folds the overlay into a new epoch.  Submit stamps the
//! dataset's current epoch into the resolved options, so epoch changes
//! partition batch admission (a batch never mixes epochs) and every
//! response echoes the epoch it was served from.  Each batch is served
//! from one snapshot taken at batch formation; in-flight batches keep
//! their snapshot across a compaction publish.

pub mod batcher;
pub mod cache;
pub mod dataset;
pub mod metrics;
pub mod options;
pub mod request;
pub mod snapshot;

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc};
use std::thread::JoinHandle;

use crate::aidw::params::AidwParams;
use crate::aidw::pipeline::weighted_stage_on;
use crate::aidw::plan::{self, NeighborArtifact, NeighborTable, SearchKind, Stage1Plan};
use crate::error::{Error, Result};
use crate::geom::PointSet;
use crate::grid::GridConfig;
use crate::knn::grid_knn::RingRule;
use crate::live::{
    AppendOutcome, CompactionReport, LiveConfig, LiveDataset, LiveRegistry, LiveSnapshot,
    LiveStatus, RemoveOutcome,
};
use crate::pool::Pool;
use crate::runtime::{AidwExecutor, Engine};

pub use crate::runtime::Variant;
pub use batcher::BatchPolicy;
pub use cache::NeighborCache;
pub use dataset::{Dataset, DatasetRegistry};
pub use metrics::{Metrics, MetricsSnapshot};
pub use options::{LocalMode, QueryOptions, ResolvedOptions, Stage1Key, Stage2Key};
pub use request::{Backend, InterpolationRequest, InterpolationResponse, Ticket};

use batcher::{Batch, JobQueue};
use cache::CacheKey;
use request::Job;

/// Stage-2 engine selection.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum EngineMode {
    /// Use PJRT artifacts if present, else pure-rust fallback.
    #[default]
    Auto,
    /// Require PJRT artifacts (error at startup when missing).
    PjrtRequired,
    /// Force the pure-rust stage 2 (benchmark baseline / no artifacts).
    CpuOnly,
}

/// Coordinator configuration — the *defaults* requests inherit; every
/// algorithmic knob here can be overridden per request via
/// [`QueryOptions`].
#[derive(Debug, Clone)]
pub struct CoordinatorConfig {
    /// Artifact directory (None = default dir / $AIDW_ARTIFACTS).
    pub artifact_dir: Option<std::path::PathBuf>,
    pub engine_mode: EngineMode,
    /// Use the small q256/m1024 artifacts (fast XLA compiles — tests).
    pub test_shapes: bool,
    /// Default kernel variant for requests that don't specify one.
    pub default_variant: Variant,
    /// Default AIDW parameters (k, alpha levels, fuzzy bounds, area).
    pub params: AidwParams,
    pub grid: GridConfig,
    pub batch: BatchPolicy,
    /// Default kNN ring rule (Exact by default).
    pub ring_rule: RingRule,
    /// Worker width for stage 1 (None = machine-sized).
    pub stage1_threads: Option<usize>,
    /// Bounded depth of the stage-1 -> stage-2 channel.
    pub pipeline_depth: usize,
    /// Default local-AIDW mode (extension A5): when set, stage 2 weights
    /// each query over its N nearest neighbors instead of all data points.
    /// Stage 1 gathers the neighbor ids in the same grid pass that feeds
    /// alpha.  None = the paper's dense weighting.
    pub local_neighbors: Option<usize>,
    /// Live-mutation durability directory: when set, registrations write
    /// a snapshot, every append/remove appends to a per-dataset WAL, and
    /// startup restores snapshot + WAL automatically.  None = in-memory
    /// datasets (mutable, but lost on restart).
    pub live_dir: Option<std::path::PathBuf>,
    /// Live-mutation tunables (compaction threshold, WAL sync).
    pub live: LiveConfig,
    /// Capacity (entries) of the stage-1 [`NeighborCache`]; 0 disables
    /// neighbor reuse.  See [`cache`] for the key and invalidation rules.
    pub neighbor_cache: usize,
    /// Approximate byte budget of the [`NeighborCache`] (large-raster
    /// artifacts are megabytes each, so an entry bound alone would let
    /// memory scale with raster size).  0 = entry bound only.
    pub neighbor_cache_bytes: usize,
}

impl Default for CoordinatorConfig {
    fn default() -> Self {
        CoordinatorConfig {
            artifact_dir: None,
            engine_mode: EngineMode::Auto,
            test_shapes: false,
            default_variant: Variant::Tiled,
            params: AidwParams::default(),
            grid: GridConfig::default(),
            batch: BatchPolicy::default(),
            ring_rule: RingRule::Exact,
            stage1_threads: None,
            pipeline_depth: 2,
            local_neighbors: None,
            live_dir: None,
            live: LiveConfig::default(),
            neighbor_cache: 64,
            neighbor_cache_bytes: 256 << 20, // 256 MiB
        }
    }
}

/// A batch after stage 1, waiting for stage 2.
struct Stage2Job {
    batch: Batch,
    queries: Vec<(f64, f64)>,
    /// The stage-1 product (r_obs + alphas + neighbor table), shared with
    /// the neighbor cache.
    artifact: Arc<NeighborArtifact>,
    /// The consistent live snapshot this whole batch is served from.
    snap: Arc<LiveSnapshot>,
    /// True when the artifact came from the cache (stage 1 skipped).
    cache_hit: bool,
}

struct Shared {
    registry: LiveRegistry,
    queue: JobQueue,
    metrics: Metrics,
    cache: NeighborCache,
    config: CoordinatorConfig,
    pool: Pool,
    running: AtomicBool,
}

/// The interpolation service coordinator.  See module docs.
pub struct Coordinator {
    shared: Arc<Shared>,
    dispatcher: Option<JoinHandle<()>>,
    stage2: Option<JoinHandle<()>>,
    /// Which backend stage 2 is using (resolved at startup).
    backend: Backend,
}

impl Coordinator {
    /// Start the coordinator (spawns the pipeline threads).
    pub fn new(config: CoordinatorConfig) -> Result<Coordinator> {
        config.params.validate().map_err(Error::InvalidArgument)?;
        // Resolve the stage-2 backend up front so startup fails fast.
        let artifact_dir = config
            .artifact_dir
            .clone()
            .unwrap_or_else(crate::runtime::default_artifact_dir);
        let backend = match config.engine_mode {
            EngineMode::CpuOnly => Backend::CpuFallback,
            EngineMode::PjrtRequired => {
                if !artifact_dir.join("manifest.json").exists() {
                    return Err(Error::Artifact(format!(
                        "PJRT required but no manifest at {}",
                        artifact_dir.display()
                    )));
                }
                Backend::Pjrt
            }
            EngineMode::Auto => {
                if artifact_dir.join("manifest.json").exists() {
                    Backend::Pjrt
                } else {
                    Backend::CpuFallback
                }
            }
        };

        let pool = match config.stage1_threads {
            Some(n) => Pool::new(n),
            None => Pool::machine_sized(),
        };
        let shared = Arc::new(Shared {
            registry: LiveRegistry::new(),
            queue: JobQueue::new(config.batch),
            metrics: Metrics::default(),
            cache: NeighborCache::new(config.neighbor_cache, config.neighbor_cache_bytes),
            config,
            pool,
            running: AtomicBool::new(true),
        });

        // restore persisted live datasets (snapshot + WAL replay) before
        // any request can arrive
        if let Some(dir) = shared.config.live_dir.clone() {
            for name in crate::live::wal::list_live(&dir)? {
                let ds = LiveDataset::load(
                    &shared.pool,
                    &name,
                    &dir,
                    &shared.config.grid,
                    shared.config.params.area,
                    shared.config.live,
                )?;
                shared.registry.insert(ds);
            }
        }

        // stage-1 -> stage-2 bounded channel
        let (tx, rx) = mpsc::sync_channel::<Stage2Job>(shared.config.pipeline_depth);

        let dispatcher = {
            let shared = shared.clone();
            std::thread::Builder::new()
                .name("aidw-dispatch".into())
                .spawn(move || dispatcher_loop(shared, tx))
                .map_err(Error::Io)?
        };
        let stage2 = {
            let shared = shared.clone();
            let dir = artifact_dir.clone();
            std::thread::Builder::new()
                .name("aidw-stage2".into())
                .spawn(move || stage2_loop(shared, rx, backend, dir))
                .map_err(Error::Io)?
        };

        Ok(Coordinator { shared, dispatcher: Some(dispatcher), stage2: Some(stage2), backend })
    }

    /// Coordinator with default config.
    pub fn with_defaults() -> Result<Coordinator> {
        Coordinator::new(CoordinatorConfig::default())
    }

    /// The stage-2 backend in use.
    pub fn backend(&self) -> Backend {
        self.backend
    }

    /// The configuration requests resolve their options against.
    pub fn config(&self) -> &CoordinatorConfig {
        &self.shared.config
    }

    /// Register a dataset (builds its epoch-0 grid index now; with a
    /// live directory configured, also writes the durable snapshot and a
    /// fresh WAL).
    pub fn register_dataset(&self, name: &str, points: PointSet) -> Result<()> {
        let cfg = &self.shared.config;
        // retire any existing entry *before* writing the replacement's
        // durable files, so the old dataset's compactor can never clobber
        // them afterwards
        if let Ok(old) = self.shared.registry.get(name) {
            old.retire();
        }
        let ds = match &cfg.live_dir {
            Some(dir) => LiveDataset::build_persistent(
                &self.shared.pool,
                name,
                points,
                &cfg.grid,
                cfg.params.area,
                cfg.live,
                dir,
            )?,
            None => LiveDataset::build(
                &self.shared.pool,
                name,
                points,
                &cfg.grid,
                cfg.params.area,
                cfg.live,
            )?,
        };
        if let Some(old) = self.shared.registry.insert(ds) {
            // deliberate epoch retirement (already detached from the
            // durable files above; a concurrent register of the same name
            // may hand us a not-yet-retired instance, so retire again)
            old.retire();
        }
        // stage-1 artifacts of the displaced dataset must not survive a
        // same-name re-register (epoch numbering restarts at 0); purge
        // *after* the insert so no pre-insert batch can re-populate
        // between purge and publish (the epoch-base instance id in the
        // cache key is the backstop for the remaining race)
        self.shared.cache.purge_dataset(name);
        Ok(())
    }

    /// Remove a dataset (joins its compactor and deletes its durable
    /// state so a restart does not resurrect it).
    pub fn drop_dataset(&self, name: &str) -> bool {
        self.shared.cache.purge_dataset(name);
        match self.shared.registry.remove(name) {
            Some(ds) => {
                // after retire() no compaction — background or an
                // in-flight synchronous one — can re-create the files we
                // are about to delete
                ds.retire();
                if let Some(dir) = &self.shared.config.live_dir {
                    std::fs::remove_file(crate::live::wal::live_path(dir, name)).ok();
                    std::fs::remove_file(crate::live::wal::wal_path(dir, name)).ok();
                }
                true
            }
            None => false,
        }
    }

    /// Append points to a live dataset; may trigger background
    /// compaction once the overlay crosses the configured threshold.
    pub fn append_points(&self, name: &str, points: PointSet) -> Result<AppendOutcome> {
        let ds = self.shared.registry.get(name)?;
        let out = ds.append(&points)?;
        LiveDataset::maybe_spawn_compaction(&ds);
        Ok(out)
    }

    /// Tombstone live points by id (strict: all ids must be live).
    pub fn remove_points(&self, name: &str, ids: &[u64]) -> Result<RemoveOutcome> {
        let ds = self.shared.registry.get(name)?;
        let out = ds.remove(ids)?;
        LiveDataset::maybe_spawn_compaction(&ds);
        Ok(out)
    }

    /// Synchronously compact a live dataset (fold overlay, bump epoch,
    /// truncate WAL).
    pub fn compact_dataset(&self, name: &str) -> Result<CompactionReport> {
        self.shared.registry.get(name)?.compact_now()
    }

    /// Live mutation/compaction statistics for one dataset.
    pub fn live_status(&self, name: &str) -> Result<LiveStatus> {
        Ok(self.shared.registry.get(name)?.status())
    }

    /// Direct access to a live dataset (tests, advanced callers).
    pub fn live_dataset(&self, name: &str) -> Result<Arc<LiveDataset>> {
        self.shared.registry.get(name)
    }

    /// Registered dataset names.
    pub fn datasets(&self) -> Vec<String> {
        self.shared.registry.names()
    }

    /// Submit asynchronously; returns a ticket to await.
    ///
    /// Fails fast — before the job reaches any pipeline thread — on empty
    /// queries, unknown datasets, and invalid option overrides (`k == 0`,
    /// `r_max <= r_min`, non-positive alpha levels, ...).
    pub fn submit(&self, request: InterpolationRequest) -> Result<Ticket> {
        if request.queries.is_empty() {
            return Err(Error::InvalidArgument("empty query list".into()));
        }
        // fail fast on unknown datasets (cheap read-lock check)
        let live = self.shared.registry.get(&request.dataset)?;
        // resolve per-request options against config defaults and validate
        let mut resolved = request.options.resolve(&self.shared.config);
        resolved.validate()?;
        // stamp the dataset's current (epoch, overlay version) pair into
        // the admission key — read from one snapshot, so the pair is
        // consistent: jobs admitted against different epochs *or* across
        // a mutation never share a batch, and the response echo reports
        // the pair a batch was served from.  (Local weighting on a
        // mutated dataset is served by the merged per-id gather — the
        // PR-2 rejection is gone.)
        let snap = live.snapshot();
        resolved.epoch = Some(snap.epoch);
        resolved.overlay = Some(snap.overlay_version());
        let n_queries = request.queries.len() as u64;
        let (tx, rx) = mpsc::channel();
        let job = Job {
            request,
            resolved,
            respond: tx,
            enqueued: std::time::Instant::now(),
        };
        match self.shared.queue.push(job) {
            Ok(()) => {
                // count only accepted jobs (rejected submissions used to
                // inflate both counters)
                self.shared.metrics.requests.fetch_add(1, Ordering::Relaxed);
                self.shared
                    .metrics
                    .queries
                    .fetch_add(n_queries, Ordering::Relaxed);
                Ok(Ticket { rx })
            }
            Err(e) => {
                self.shared.metrics.rejected.fetch_add(1, Ordering::Relaxed);
                Err(e)
            }
        }
    }

    /// Submit and block for the response.
    pub fn interpolate(&self, request: InterpolationRequest) -> Result<InterpolationResponse> {
        self.submit(request)?.wait()
    }

    /// Convenience: values only.
    pub fn interpolate_values(&self, dataset: &str, queries: Vec<(f64, f64)>) -> Result<Vec<f64>> {
        Ok(self.interpolate(InterpolationRequest::new(dataset, queries))?.values)
    }

    /// Persist every registered dataset to `<dir>/<name>.aidw` (the v1
    /// portable export: the *live merged* point set, without ids — WAL
    /// durability is the `live_dir` mechanism, this is for interchange).
    pub fn save_datasets(&self, dir: &std::path::Path) -> Result<usize> {
        let all = self.shared.registry.all();
        for ds in &all {
            let (pts, _ids) = ds.snapshot().live_points();
            snapshot::save_dataset(dir, ds.name(), &pts)?;
        }
        Ok(all.len())
    }

    /// Register every snapshot found in `dir` (grid indexes are rebuilt).
    pub fn load_datasets(&self, dir: &std::path::Path) -> Result<usize> {
        let loaded = snapshot::load_dir(dir)?;
        let count = loaded.len();
        for (name, pts) in loaded {
            self.register_dataset(&name, pts)?;
        }
        Ok(count)
    }

    /// Metrics snapshot (planner counters + neighbor-cache occupancy).
    pub fn metrics(&self) -> MetricsSnapshot {
        self.shared.metrics.snapshot_with(self.shared.cache.stats())
    }

    /// Current queue depth (diagnostics / backpressure observers).
    pub fn queue_depth(&self) -> usize {
        self.shared.queue.depth()
    }

    /// Graceful shutdown: drains queued work, stops the pipeline threads,
    /// and joins any background compactions.
    pub fn shutdown(&mut self) {
        if self.shared.running.swap(false, Ordering::SeqCst) {
            self.shared.queue.close();
            if let Some(h) = self.dispatcher.take() {
                let _ = h.join();
            }
            if let Some(h) = self.stage2.take() {
                let _ = h.join();
            }
            self.shared.registry.shutdown_all();
        }
    }
}

impl Drop for Coordinator {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Dispatcher: batch formation + the planned stage 1 on the CPU pool.
/// Builds a [`Stage1Plan`] from the batch's stage-1 key (grid over a
/// compacted snapshot, merged over a mutated one; local mode gathers
/// neighbor ids in the same pass), consults the [`NeighborCache`], and
/// hands the resulting [`NeighborArtifact`] to stage 2.
fn dispatcher_loop(shared: Arc<Shared>, tx: mpsc::SyncSender<Stage2Job>) {
    while let Some(batch) = shared.queue.next_batch() {
        shared.metrics.batches.fetch_add(1, Ordering::Relaxed);

        let live = match shared.registry.get(&batch.dataset) {
            Ok(ds) => ds,
            Err(e) => {
                fail_batch(&shared, batch, &e);
                continue;
            }
        };
        // one snapshot per batch: every member is served from the same
        // epoch/overlay state, and keeps it across a compaction publish
        let snap = live.snapshot();

        // concatenate all queries of the batch
        let mut queries = Vec::with_capacity(batch.total_queries);
        for job in &batch.jobs {
            queries.extend_from_slice(&job.request.queries);
        }

        // STAGE 1 (planned): the paper's fast kNN search + adaptive
        // alpha, one execution per batch regardless of how many stage-2
        // variants the members carry.
        let opts = batch.options;
        let search = if snap.is_compacted() { SearchKind::Grid } else { SearchKind::Merged };
        let area = opts.area.unwrap_or_else(|| snap.area());
        let params = opts.params();
        let stage1 = Stage1Plan::new(
            opts.k,
            opts.ring_rule,
            opts.local_neighbors,
            &params,
            snap.live_len,
            area,
            search,
        );

        // Neighbor reuse on every snapshot, mutated or compacted (see
        // cache.rs for the key and invalidation rules): the key's stage-1
        // (epoch, overlay) pair is normalized to the snapshot actually
        // served, so a compaction or mutation publishing between
        // admission and formation cannot split cache identity.
        let cache_key = if shared.cache.enabled() {
            let mut s1 = opts.stage1_key();
            s1.epoch = Some(snap.epoch);
            s1.overlay = Some(snap.overlay_version());
            Some(CacheKey {
                dataset: batch.dataset.clone(),
                epoch: snap.epoch,
                instance: snap.base.uid,
                overlay: snap.overlay_version(),
                stage1: s1,
                queries_fp: cache::query_fingerprint(&queries),
                n_queries: queries.len(),
            })
        } else {
            None
        };
        let outcome = match cache_key.as_ref() {
            Some(k) => shared.cache.lookup(k, &queries),
            None => cache::CacheOutcome::Miss,
        };
        let (artifact, cache_hit) = match outcome {
            cache::CacheOutcome::Hit(art) => {
                shared.metrics.stage1_cache_hits.fetch_add(1, Ordering::Relaxed);
                (art, true)
            }
            cache::CacheOutcome::Subset(sub) => {
                // a covering artifact served this raster's rows: no kNN
                // sweep ran; re-insert under the exact key so repeats of
                // this raster hit directly
                shared.metrics.stage1_subset_hits.fetch_add(1, Ordering::Relaxed);
                let art = Arc::new(sub);
                if let Some(key) = cache_key {
                    shared.cache.put(key, &queries, art.clone());
                }
                (art, true)
            }
            cache::CacheOutcome::Miss => {
                let art = Arc::new(match search {
                    SearchKind::Grid => {
                        stage1.execute_grid(&shared.pool, &snap.base.grid, &queries)
                    }
                    SearchKind::Merged => {
                        stage1.execute_merged(&shared.pool, &snap.merged_view(), &queries)
                    }
                });
                shared.metrics.stage1_execs.fetch_add(1, Ordering::Relaxed);
                if let Some(key) = cache_key {
                    shared.cache.put(key, &queries, art.clone());
                }
                (art, false)
            }
        };

        let job = Stage2Job { batch, queries, artifact, snap, cache_hit };
        if tx.send(job).is_err() {
            break; // stage 2 is gone
        }
    }
    // dropping tx closes the stage-2 loop
}

/// Stage 2: adaptive alpha + streamed weighted interpolation.
fn stage2_loop(
    shared: Arc<Shared>,
    rx: mpsc::Receiver<Stage2Job>,
    backend: Backend,
    artifact_dir: std::path::PathBuf,
) {
    // The Engine lives entirely in this thread (PJRT handles are not
    // shared across threads).
    let engine = match backend {
        Backend::Pjrt => match Engine::new(&artifact_dir) {
            Ok(e) => Some(e),
            Err(err) => {
                eprintln!("aidw: engine init failed ({err}); using CPU fallback");
                None
            }
        },
        Backend::CpuFallback => None,
    };

    while let Ok(sj) = rx.recv() {
        let result = run_stage2(&shared, &engine, &sj);
        match result {
            Ok(out) => {
                // a cache-hit batch spent no stage-1 time of its own
                let stage1_s = if sj.cache_hit { 0.0 } else { sj.artifact.stage1_s };
                let knn_s = stage1_s + out.alpha_extra_s;
                shared.metrics.add_stage_times(knn_s, out.interp_s);
                shared
                    .metrics
                    .stage2_execs
                    .fetch_add(out.groups as u64, Ordering::Relaxed);
                if out.groups > 1 {
                    shared.metrics.coalesced_batches.fetch_add(1, Ordering::Relaxed);
                }
                // merged (mutated-snapshot) batches run the CPU path even
                // when artifacts are loaded; report what actually ran
                let backend = if engine.is_some() && sj.snap.is_compacted() {
                    Backend::Pjrt
                } else {
                    Backend::CpuFallback
                };
                respond_batch(&shared, sj, out, knn_s, backend);
            }
            Err(e) => {
                shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
                let msg = e.to_string();
                for job in sj.batch.jobs {
                    let _ = job.respond.send(Err(Error::Service(msg.clone())));
                }
            }
        }
    }
}

/// The effective AIDW parameter block for a batch: resolved options with
/// the snapshot's live area substituted when no explicit override was
/// given and k clamped to the live point count (what stage 1 actually
/// searched with).
fn effective_params(opts: &ResolvedOptions, snap: &LiveSnapshot) -> AidwParams {
    let mut p = opts.params();
    p.k = opts.k.min(snap.live_len).max(1);
    p.area = Some(opts.area.unwrap_or_else(|| snap.area()));
    p
}

/// What one batch's stage 2 produced.
struct Stage2Outcome {
    values: Vec<f64>,
    /// Stage-1-attributed extra seconds (the PJRT path recomputes alpha
    /// on-device from r_obs).
    alpha_extra_s: f64,
    interp_s: f64,
    /// Distinct stage-2 executions this batch split into.
    groups: usize,
}

/// Execute stage 2 for one batch: once per distinct stage-2 key, each
/// group consuming its own rows of the shared [`NeighborArtifact`].
fn run_stage2(shared: &Shared, engine: &Option<Engine>, sj: &Stage2Job) -> Result<Stage2Outcome> {
    let opts = &sj.batch.options;
    let art: &NeighborArtifact = &sj.artifact;
    let params = effective_params(opts, &sj.snap);
    let groups = sj.batch.stage2_groups();

    // Lazy alphas: the PJRT stage 2 recomputes alpha on-device from
    // r_obs, so only the CPU consumers — merged (mutated-snapshot)
    // batches and the pure-rust fallback — materialize the vector.  The
    // materialization is alpha work, i.e. stage-1-attributed time; a
    // cache-hit artifact returns its already-materialized vector for
    // free.
    let needs_alphas = !sj.snap.is_compacted() || engine.is_none();
    let t_alpha = std::time::Instant::now();
    let alphas: &[f64] = if needs_alphas { art.alphas() } else { &[] };
    let lazy_alpha_s = if needs_alphas { t_alpha.elapsed().as_secs_f64() } else { 0.0 };

    // fast path (the overwhelmingly common single-variant batch): the
    // one group *is* the whole contiguous block — execute over borrowed
    // slices of the artifact, no gather/scatter copies
    if groups.len() == 1 {
        let (values, alpha_extra_s, interp_s) = run_stage2_group(
            shared,
            engine,
            sj,
            &params,
            groups[0].0,
            &sj.queries,
            alphas,
            &art.r_obs,
            art.neighbors.as_ref(),
        )?;
        return Ok(Stage2Outcome {
            values,
            alpha_extra_s: alpha_extra_s + lazy_alpha_s,
            interp_s,
            groups: 1,
        });
    }

    // per-job row offsets into the concatenated query block
    let mut offsets = Vec::with_capacity(sj.batch.jobs.len());
    let mut off = 0usize;
    for job in &sj.batch.jobs {
        offsets.push(off);
        off += job.request.queries.len();
    }

    let mut values = vec![0f64; sj.queries.len()];
    let mut alpha_extra_s = lazy_alpha_s;
    let mut interp_s = 0.0f64;

    for (key, members) in &groups {
        // gather the group's rows (each job is contiguous; a group of
        // several jobs may not be)
        let rows: usize = members
            .iter()
            .map(|&m| sj.batch.jobs[m].request.queries.len())
            .sum();
        let mut g_queries = Vec::with_capacity(rows);
        let mut g_alphas = Vec::with_capacity(if needs_alphas { rows } else { 0 });
        let mut g_robs = Vec::with_capacity(rows);
        for &m in members {
            let start = offsets[m];
            let len = sj.batch.jobs[m].request.queries.len();
            g_queries.extend_from_slice(&sj.queries[start..start + len]);
            if needs_alphas {
                g_alphas.extend_from_slice(&alphas[start..start + len]);
            }
            g_robs.extend_from_slice(&art.r_obs[start..start + len]);
        }
        let g_table = art.neighbors.as_ref().map(|t| {
            let mut idx = Vec::with_capacity(rows * t.width);
            for &m in members {
                let start = offsets[m];
                let len = sj.batch.jobs[m].request.queries.len();
                idx.extend_from_slice(&t.idx[start * t.width..(start + len) * t.width]);
            }
            NeighborTable { idx, width: t.width }
        });

        let (out, a_s, i_s) = run_stage2_group(
            shared,
            engine,
            sj,
            &params,
            *key,
            &g_queries,
            &g_alphas,
            &g_robs,
            g_table.as_ref(),
        )?;
        alpha_extra_s += a_s;
        interp_s += i_s;

        // scatter the group's rows back into batch order
        let mut gi = 0usize;
        for &m in members {
            let start = offsets[m];
            let len = sj.batch.jobs[m].request.queries.len();
            values[start..start + len].copy_from_slice(&out[gi..gi + len]);
            gi += len;
        }
    }

    Ok(Stage2Outcome { values, alpha_extra_s, interp_s, groups: groups.len() })
}

/// One stage-2 group execution over (a slice of) the neighbor artifact;
/// returns (values, alpha_extra_s, interp_s).
#[allow(clippy::too_many_arguments)]
fn run_stage2_group(
    shared: &Shared,
    engine: &Option<Engine>,
    sj: &Stage2Job,
    params: &AidwParams,
    key: options::Stage2Key,
    queries: &[(f64, f64)],
    alphas: &[f64],
    r_obs: &[f64],
    table: Option<&NeighborTable>,
) -> Result<(Vec<f64>, f64, f64)> {
    let t0 = std::time::Instant::now();
    if !sj.snap.is_compacted() {
        // merged stage 2 on the CPU: the fixed-shape PJRT artifacts
        // cannot see overlay deltas; the compactor restores the artifact
        // path at the next epoch
        let v = match table {
            Some(t) => crate::live::merged_local_weighted_on(
                &shared.pool,
                &sj.snap,
                queries,
                alphas,
                &t.idx,
                t.width,
            ),
            None => {
                crate::live::merged_weighted_stage_on(&shared.pool, &sj.snap, queries, alphas)
            }
        };
        return Ok((v, 0.0, t0.elapsed().as_secs_f64()));
    }
    let dataset: &Dataset = &sj.snap.base;
    match engine {
        Some(engine) => {
            let exec = if shared.config.test_shapes {
                AidwExecutor::new_test_shapes(engine)
            } else {
                AidwExecutor::new(engine)
            };
            let (v, times) = match table {
                Some(t) => {
                    exec.local_aidw(&dataset.points, queries, r_obs, &t.idx, t.width, params)?
                }
                None => exec.improved_aidw(&dataset.points, queries, r_obs, params, key.variant)?,
            };
            Ok((v, times.knn_s, times.interp_s))
        }
        None => {
            // pure-rust stage 2 over the artifact's alphas
            let v = match table {
                Some(t) => {
                    plan::local_weighted_on(&shared.pool, &dataset.points, queries, alphas, t)
                }
                None => weighted_stage_on(&shared.pool, &dataset.points, queries, alphas),
            };
            Ok((v, 0.0, t0.elapsed().as_secs_f64()))
        }
    }
}

/// Split batch results back per job and respond.  Each job's echo is its
/// *own* resolved options (a batch may mix stage-2 variants) with the
/// live area, clamped k, and served epoch substituted for client-side
/// audit, plus the planner facts (cache hit, stage-2 group count).
fn respond_batch(shared: &Shared, sj: Stage2Job, out: Stage2Outcome, knn_s: f64, backend: Backend) {
    let total = sj.queries.len();
    let stage2_groups = out.groups;
    let mut offset = 0usize;
    for job in sj.batch.jobs {
        let n = job.request.queries.len();
        let slice = out.values[offset..offset + n].to_vec();
        offset += n;
        let mut echoed = job.resolved;
        echoed.area = Some(echoed.area.unwrap_or_else(|| sj.snap.area()));
        // the audit record reports what ran: k is clamped to the live
        // count, and the (epoch, overlay) pair is the snapshot the batch
        // was served from (it may be newer than the admission pair if a
        // compaction or mutation published in between — still one single
        // snapshot for the batch)
        echoed.k = echoed.k.min(sj.snap.live_len).max(1);
        echoed.epoch = Some(sj.snap.epoch);
        echoed.overlay = Some(sj.snap.overlay_version());
        shared
            .metrics
            .latency
            .record(job.enqueued.elapsed().as_secs_f64());
        let _ = job.respond.send(Ok(InterpolationResponse {
            values: slice,
            knn_s,
            interp_s: out.interp_s,
            batch_queries: total,
            backend,
            options: echoed,
            stage1_cache_hit: sj.cache_hit,
            stage2_groups,
        }));
    }
}

fn fail_batch(shared: &Shared, batch: Batch, err: &Error) {
    shared.metrics.errors.fetch_add(1, Ordering::Relaxed);
    let msg = err.to_string();
    for job in batch.jobs {
        let _ = job.respond.send(Err(Error::Service(msg.clone())));
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    fn cpu_coordinator() -> Coordinator {
        let cfg = CoordinatorConfig {
            engine_mode: EngineMode::CpuOnly,
            ..Default::default()
        };
        Coordinator::new(cfg).unwrap()
    }

    #[test]
    fn register_and_interpolate_cpu() {
        let c = cpu_coordinator();
        assert_eq!(c.backend(), Backend::CpuFallback);
        let pts = workload::uniform_square(400, 50.0, 71);
        c.register_dataset("d", pts.clone()).unwrap();
        assert_eq!(c.datasets(), vec!["d".to_string()]);
        let queries = workload::uniform_square(50, 50.0, 72).xy();
        let resp = c
            .interpolate(InterpolationRequest::new("d", queries.clone()))
            .unwrap();
        assert_eq!(resp.values.len(), 50);
        assert_eq!(resp.backend, Backend::CpuFallback);
        // the response echoes the fully-resolved options
        assert_eq!(resp.options.k, 10);
        assert_eq!(resp.options.ring_rule, RingRule::Exact);
        assert_eq!(resp.options.local_neighbors, None);
        assert!(resp.options.area.is_some(), "area must be filled in");
        // matches the serial reference
        let want = crate::aidw::serial::aidw_serial(&pts, &queries, &AidwParams::default());
        for (g, w) in resp.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
        let m = c.metrics();
        assert_eq!(m.requests, 1);
        assert_eq!(m.queries, 50);
        assert!(m.batches >= 1);
    }

    #[test]
    fn unknown_dataset_fails_fast() {
        let c = cpu_coordinator();
        let err = c
            .interpolate(InterpolationRequest::new("missing", vec![(0.0, 0.0)]))
            .unwrap_err();
        assert!(matches!(err, Error::UnknownDataset(_)), "{err}");
    }

    #[test]
    fn empty_queries_rejected() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 73);
        c.register_dataset("d", pts).unwrap();
        assert!(c.interpolate(InterpolationRequest::new("d", vec![])).is_err());
    }

    #[test]
    fn invalid_options_rejected_at_submit() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 73);
        c.register_dataset("d", pts).unwrap();
        let q = vec![(1.0, 1.0)];
        for bad in [
            QueryOptions::new().k(0),
            QueryOptions::new().r_bounds(2.0, 1.0),
            QueryOptions::new().alpha_levels([0.0, 1.0, 2.0, 3.0, 4.0]),
            QueryOptions::new().area(-1.0),
            QueryOptions::new().local_neighbors(0),
        ] {
            let err = c
                .submit(InterpolationRequest::new("d", q.clone()).with_options(bad.clone()))
                .unwrap_err();
            assert!(matches!(err, Error::InvalidArgument(_)), "{bad:?}: {err}");
        }
        // invalid submissions must not inflate the accepted counters
        let m = c.metrics();
        assert_eq!(m.requests, 0);
        assert_eq!(m.queries, 0);
    }

    #[test]
    fn concurrent_submissions_batch_together() {
        let c = std::sync::Arc::new(cpu_coordinator());
        let pts = workload::uniform_square(600, 50.0, 74);
        c.register_dataset("d", pts).unwrap();
        let mut handles = Vec::new();
        for t in 0..8u64 {
            let c = c.clone();
            handles.push(std::thread::spawn(move || {
                let queries = workload::uniform_square(25, 50.0, 100 + t).xy();
                c.interpolate(InterpolationRequest::new("d", queries)).unwrap()
            }));
        }
        let resps: Vec<InterpolationResponse> =
            handles.into_iter().map(|h| h.join().unwrap()).collect();
        assert!(resps.iter().all(|r| r.values.len() == 25));
        // at least some requests shared a batch (probabilistic but the
        // linger window makes it overwhelmingly likely under contention)
        let m = c.metrics();
        assert_eq!(m.requests, 8);
        assert!(m.batches <= 8);
    }

    #[test]
    fn shutdown_is_idempotent_and_rejects_after() {
        let mut c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 75);
        c.register_dataset("d", pts).unwrap();
        c.shutdown();
        c.shutdown();
        assert!(c
            .interpolate(InterpolationRequest::new("d", vec![(1.0, 1.0)]))
            .is_err());
    }

    #[test]
    fn rejected_submissions_do_not_count_as_requests() {
        let mut c = cpu_coordinator();
        let pts = workload::uniform_square(50, 10.0, 85);
        c.register_dataset("d", pts).unwrap();
        c.shutdown(); // queue closed -> push fails
        let err = c
            .submit(InterpolationRequest::new("d", vec![(1.0, 1.0)]))
            .unwrap_err();
        assert!(matches!(err, Error::Unavailable(_)), "{err}");
        let m = c.metrics();
        assert_eq!(m.requests, 0, "rejected submit must not count");
        assert_eq!(m.queries, 0);
        assert_eq!(m.rejected, 1);
    }

    #[test]
    fn local_mode_cpu_matches_local_pipeline() {
        let cfg = CoordinatorConfig {
            engine_mode: EngineMode::CpuOnly,
            local_neighbors: Some(48),
            ..Default::default()
        };
        let c = Coordinator::new(cfg).unwrap();
        let pts = workload::uniform_square(1000, 80.0, 78);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(60, 80.0, 79).xy();
        let got = c.interpolate_values("d", queries.clone()).unwrap();
        let want = crate::aidw::local::interpolate_local(
            &pts,
            &queries,
            &AidwParams::default(),
            &crate::aidw::local::LocalConfig { n_neighbors: 48, ..Default::default() },
        )
        .unwrap();
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn per_request_k_override() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(300, 50.0, 76);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(20, 50.0, 77).xy();
        let got = c
            .interpolate(InterpolationRequest::new("d", queries.clone()).with_k(3))
            .unwrap();
        assert_eq!(got.options.k, 3, "resolved echo must report the override");
        let mut p = AidwParams::default();
        p.k = 3;
        let want = crate::aidw::serial::aidw_serial(&pts, &queries, &p);
        for (g, w) in got.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
        // oversized k clamps to the dataset size, and the echo reports
        // the clamped value (what stage 1 actually searched with)
        let resp = c
            .interpolate(InterpolationRequest::new("d", queries.clone()).with_k(10_000))
            .unwrap();
        assert_eq!(resp.options.k, 300);
        let mut p = AidwParams::default();
        p.k = 10_000; // serial reference clamps internally the same way
        let want = crate::aidw::serial::aidw_serial(&pts, &queries, &p);
        for (g, w) in resp.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn per_request_local_override_on_dense_coordinator() {
        // coordinator defaults to dense; one request opts into local mode
        let c = cpu_coordinator();
        let pts = workload::uniform_square(800, 80.0, 81);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(40, 80.0, 82).xy();
        let resp = c
            .interpolate(
                InterpolationRequest::new("d", queries.clone())
                    .with_options(QueryOptions::new().local_neighbors(64)),
            )
            .unwrap();
        assert_eq!(resp.options.local_neighbors, Some(64));
        let want = crate::aidw::local::interpolate_local(
            &pts,
            &queries,
            &AidwParams::default(),
            &crate::aidw::local::LocalConfig { n_neighbors: 64, ..Default::default() },
        )
        .unwrap();
        for (g, w) in resp.values.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9, "{g} vs {w}");
        }
    }

    #[test]
    fn mutated_dataset_serves_merged_and_echoes_epoch() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(400, 50.0, 91);
        c.register_dataset("d", pts).unwrap();
        let extra = workload::uniform_square(40, 50.0, 92);
        let appended = c.append_points("d", extra).unwrap();
        assert_eq!(appended.first_id, 400);
        assert_eq!(appended.count, 40);
        let removed = c.remove_points("d", &[0, 401]).unwrap();
        assert_eq!(removed.removed, 2);
        assert_eq!(c.live_status("d").unwrap().live_points, 438);

        let queries = workload::uniform_square(30, 50.0, 93).xy();
        let resp = c
            .interpolate(InterpolationRequest::new("d", queries.clone()))
            .unwrap();
        assert_eq!(resp.options.epoch, Some(0), "epoch echoed for audit");
        assert_eq!(resp.values.len(), 30);

        // bit-identical to a fresh registration of the merged live set
        let (merged, _) = c.live_dataset("d").unwrap().snapshot().live_points();
        let c2 = cpu_coordinator();
        c2.register_dataset("m", merged).unwrap();
        let want = c2
            .interpolate(InterpolationRequest::new("m", queries.clone()))
            .unwrap();
        assert_eq!(resp.values, want.values, "merged path must be exact");

        // compaction bumps the epoch; answers stay bit-identical
        let rep = c.compact_dataset("d").unwrap();
        assert_eq!((rep.old_epoch, rep.new_epoch), (0, 1));
        let resp2 = c
            .interpolate(InterpolationRequest::new("d", queries))
            .unwrap();
        assert_eq!(resp2.options.epoch, Some(1));
        assert_eq!(resp2.values, want.values);
    }

    #[test]
    fn local_mode_works_on_mutated_dataset() {
        // the PR-2 rejection is gone: the merged per-id gather serves A5
        // on a mutated dataset, bit-identical to a fresh registration of
        // the merged live set
        let c = cpu_coordinator();
        let base = workload::uniform_square(300, 50.0, 94);
        c.register_dataset("d", base).unwrap();
        let q = workload::uniform_square(25, 50.0, 97).xy();
        let local = QueryOptions::new().local_neighbors(16);
        // local mode works while compacted
        c.interpolate(
            InterpolationRequest::new("d", q.clone()).with_options(local.clone()),
        )
        .unwrap();
        c.append_points("d", workload::uniform_square(5, 50.0, 95)).unwrap();
        c.remove_points("d", &[7]).unwrap();
        let got = c
            .interpolate(InterpolationRequest::new("d", q.clone()).with_options(local.clone()))
            .unwrap();
        assert_eq!(got.options.local_neighbors, Some(16));
        // oracle: fresh registration of the materialized live set
        let (merged, _) = c.live_dataset("d").unwrap().snapshot().live_points();
        let c2 = cpu_coordinator();
        c2.register_dataset("m", merged).unwrap();
        let want = c2
            .interpolate(InterpolationRequest::new("m", q.clone()).with_options(local.clone()))
            .unwrap();
        assert_eq!(got.values, want.values, "merged local must be exact");
        // compaction changes nothing about the answers
        c.compact_dataset("d").unwrap();
        let after = c
            .interpolate(InterpolationRequest::new("d", q).with_options(local))
            .unwrap();
        assert_eq!(after.values, want.values);
    }

    #[test]
    fn mutations_on_unknown_dataset_fail_fast() {
        let c = cpu_coordinator();
        assert!(c.append_points("ghost", workload::uniform_square(3, 1.0, 96)).is_err());
        assert!(c.remove_points("ghost", &[0]).is_err());
        assert!(c.compact_dataset("ghost").is_err());
        assert!(c.live_status("ghost").is_err());
    }

    #[test]
    fn per_request_area_override_changes_alpha_regime() {
        let c = cpu_coordinator();
        let pts = workload::uniform_square(400, 10.0, 83);
        c.register_dataset("d", pts.clone()).unwrap();
        let queries = workload::uniform_square(30, 10.0, 84).xy();
        let lo = c
            .interpolate(
                InterpolationRequest::new("d", queries.clone())
                    .with_options(QueryOptions::new().area(1e9)),
            )
            .unwrap();
        let hi = c
            .interpolate(
                InterpolationRequest::new("d", queries.clone())
                    .with_options(QueryOptions::new().area(1e-9)),
            )
            .unwrap();
        assert_eq!(lo.options.area, Some(1e9));
        assert_eq!(hi.options.area, Some(1e-9));
        let diff: f64 = lo
            .values
            .iter()
            .zip(&hi.values)
            .map(|(a, b)| (a - b).abs())
            .sum();
        assert!(diff > 1e-9, "area override had no effect");
        // each matches its serial reference
        for (resp, area) in [(&lo, 1e9), (&hi, 1e-9)] {
            let mut p = AidwParams::default();
            p.area = Some(area);
            let want = crate::aidw::serial::aidw_serial(&pts, &queries, &p);
            for (g, w) in resp.values.iter().zip(&want) {
                assert!((g - w).abs() < 1e-9, "{g} vs {w}");
            }
        }
    }
}
