//! Per-request query options — the knobs of the paper's two-stage
//! pipeline, settable on every request instead of frozen at coordinator
//! construction.
//!
//! [`QueryOptions`] is the *partial* form clients build: every field is
//! optional and defaults to the coordinator's [`super::CoordinatorConfig`].
//! At submit time the coordinator resolves it against its config into a
//! [`ResolvedOptions`] — the fully-concrete form that (a) keys batch
//! admission (only option-identical jobs may share a grid-kNN sweep and a
//! stage-2 tensor), (b) drives both pipeline stages, and (c) is echoed on
//! the [`super::InterpolationResponse`] so clients can audit what actually
//! ran.
//!
//! ```
//! use aidw::coordinator::QueryOptions;
//! use aidw::knn::grid_knn::RingRule;
//!
//! let opts = QueryOptions::new()
//!     .k(16)
//!     .ring_rule(RingRule::PaperPlusOne)
//!     .local_neighbors(64)
//!     .alpha_levels([0.5, 1.0, 2.0, 3.0, 4.0]);
//! assert_eq!(opts.k, Some(16));
//! ```

use crate::aidw::params::AidwParams;
use crate::error::{Error, Result};
use crate::knn::grid_knn::RingRule;
use crate::runtime::Variant;
use crate::shard::TenantTag;

pub use crate::aidw::plan::Layout;

use super::CoordinatorConfig;

/// Stage-2 weighting scope override.
///
/// Three states matter per request: inherit the coordinator's mode
/// (`None` in [`QueryOptions::local`]), force the paper's dense weighting
/// over all data points (`Dense`), or restrict to the N nearest neighbors
/// (`Nearest(n)`, extension A5).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LocalMode {
    /// Weight every data point (the paper's Eq. 1).
    Dense,
    /// Weight only the N nearest neighbors gathered in stage 1.
    Nearest(usize),
}

/// Per-request overrides; unset fields fall back to the coordinator
/// config.  Build fluently: `QueryOptions::new().k(16).area(1e4)`.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct QueryOptions {
    /// Neighbors for the spatial-pattern statistic (Eq. 3).
    pub k: Option<usize>,
    /// Stage-2 kernel variant (naive / tiled).
    pub variant: Option<Variant>,
    /// Ring-expansion termination rule for the grid kNN.
    pub ring_rule: Option<RingRule>,
    /// Stage-2 weighting scope (dense vs N nearest).
    pub local: Option<LocalMode>,
    /// The five distance-decay levels of Eq. 6.
    pub alpha_levels: Option<[f64; 5]>,
    /// Fuzzy-membership lower bound of Eq. 5.
    pub r_min: Option<f64>,
    /// Fuzzy-membership upper bound of Eq. 5.
    pub r_max: Option<f64>,
    /// Explicit study-region area `A` of Eq. 2 (default: dataset bounds).
    pub area: Option<f64>,
    /// Stage-2 tile size in query rows (protocol v2.4): results are
    /// executed and delivered per tile of at most this many rows.  `None`
    /// inherits the coordinator default (itself `None` = one whole-raster
    /// tile).  Tiling never changes the numbers — tiles concatenated in
    /// order are bit-identical to the monolithic pass — so it is part of
    /// neither stage key.
    pub tile_rows: Option<usize>,
    /// Request a per-stage span timeline on the response (protocol v2.6
    /// `"trace":true`).  Pure observability: like `tile_rows` it changes
    /// no numerics, so it is part of neither stage key — a traced and an
    /// untraced request still coalesce and share cached artifacts.
    pub trace: Option<bool>,
    /// Pin the CPU stage-2 data-access schedule (protocol v2.7 `layout`
    /// field).  `None` inherits the coordinator default (itself `None` =
    /// the planner picks by stage-2 work size at planning time).  The
    /// blocked layouts are bit-identical to the scalar reference, so
    /// like `tile_rows`/`trace` this is part of neither stage key.
    pub layout: Option<Layout>,
    /// Admission identity (protocol v2.8 `tenant` field): the tenant
    /// whose rate limit, in-flight quota, and fair-scheduling lane this
    /// request consumes.  `None` = the anonymous tenant.  Numerics-
    /// neutral, so part of neither stage key.
    pub tenant: Option<TenantTag>,
}

impl QueryOptions {
    /// All-defaults options (inherit everything from the coordinator).
    pub fn new() -> QueryOptions {
        QueryOptions::default()
    }

    /// Override k for the Eq.-3 statistic.
    pub fn k(mut self, k: usize) -> Self {
        self.k = Some(k);
        self
    }

    /// Override the stage-2 kernel variant.
    pub fn variant(mut self, v: Variant) -> Self {
        self.variant = Some(v);
        self
    }

    /// Override the kNN ring-expansion rule.
    pub fn ring_rule(mut self, rule: RingRule) -> Self {
        self.ring_rule = Some(rule);
        self
    }

    /// Restrict stage 2 to the `n` nearest neighbors (extension A5).
    pub fn local_neighbors(mut self, n: usize) -> Self {
        self.local = Some(LocalMode::Nearest(n));
        self
    }

    /// Force the paper's dense weighting even when the coordinator
    /// defaults to local mode.
    pub fn dense(mut self) -> Self {
        self.local = Some(LocalMode::Dense);
        self
    }

    /// Override the five alpha decay levels of Eq. 6.
    pub fn alpha_levels(mut self, levels: [f64; 5]) -> Self {
        self.alpha_levels = Some(levels);
        self
    }

    /// Override the fuzzy-membership bounds of Eq. 5.
    pub fn r_bounds(mut self, r_min: f64, r_max: f64) -> Self {
        self.r_min = Some(r_min);
        self.r_max = Some(r_max);
        self
    }

    /// Override the study-region area of Eq. 2.
    pub fn area(mut self, area: f64) -> Self {
        self.area = Some(area);
        self
    }

    /// Execute and deliver stage 2 per tile of at most `rows` query rows
    /// (streaming granularity; numerics-neutral).
    pub fn tile_rows(mut self, rows: usize) -> Self {
        self.tile_rows = Some(rows);
        self
    }

    /// Request a per-stage span timeline on the response (protocol v2.6).
    pub fn trace(mut self, on: bool) -> Self {
        self.trace = Some(on);
        self
    }

    /// Pin the CPU stage-2 data-access schedule (protocol v2.7;
    /// numerics-neutral — every layout is bit-identical).
    pub fn layout(mut self, layout: Layout) -> Self {
        self.layout = Some(layout);
        self
    }

    /// Attribute this request to a tenant for admission control and fair
    /// scheduling (protocol v2.8; numerics-neutral).
    pub fn tenant(mut self, tenant: TenantTag) -> Self {
        self.tenant = Some(tenant);
        self
    }

    /// True when no field overrides the coordinator defaults.
    pub fn is_default(&self) -> bool {
        *self == QueryOptions::default()
    }

    /// Resolve against coordinator defaults into the concrete form.
    pub fn resolve(&self, config: &CoordinatorConfig) -> ResolvedOptions {
        ResolvedOptions {
            k: self.k.unwrap_or(config.params.k),
            variant: self.variant.unwrap_or(config.default_variant),
            ring_rule: self.ring_rule.unwrap_or(config.ring_rule),
            local_neighbors: match self.local {
                None => config.local_neighbors,
                Some(LocalMode::Dense) => None,
                Some(LocalMode::Nearest(n)) => Some(n),
            },
            alpha_levels: self.alpha_levels.unwrap_or(config.params.alpha_levels),
            r_min: self.r_min.unwrap_or(config.params.r_min),
            r_max: self.r_max.unwrap_or(config.params.r_max),
            area: self.area.or(config.params.area),
            tile_rows: self.tile_rows.or(config.tile_rows),
            epoch: None,
            overlay: None,
            trace: self.trace.unwrap_or(false),
            layout: self.layout.or(config.layout),
            tenant: self.tenant,
        }
    }
}

/// Fully-resolved per-request options: every knob concrete.  The audit
/// record echoed on responses.  Batch admission keys on the
/// [`ResolvedOptions::stage1_key`] projection — **not** full equality:
/// jobs that differ only in the stage-2 `variant` deliberately share a
/// batch (one kNN sweep, per-variant stage-2 groups).  When adding a new
/// option field, decide explicitly whether it belongs in [`Stage1Key`]
/// (affects the search/alpha product — must separate batches) or is
/// stage-2-only like `variant`; a field in neither place would silently
/// coalesce jobs whose numerics differ.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ResolvedOptions {
    /// Clamped to the dataset size at execution time; the response echo
    /// reports the clamped value.
    pub k: usize,
    pub variant: Variant,
    pub ring_rule: RingRule,
    /// `Some(n)` = stage 2 over the n nearest neighbors; `None` = dense.
    pub local_neighbors: Option<usize>,
    pub alpha_levels: [f64; 5],
    pub r_min: f64,
    pub r_max: f64,
    /// `None` = the dataset's own bounding-box area (substituted in the
    /// response echo once the dataset is known).
    pub area: Option<f64>,
    /// Stage-2 tile size in query rows; `None` = one whole-raster tile.
    /// Execution/delivery granularity only — tiles concatenated in order
    /// are bit-identical to the monolithic pass, so this is deliberately
    /// part of **neither** [`Stage1Key`] nor [`Stage2Key`] (requests
    /// differing only here still coalesce and share cached artifacts).
    pub tile_rows: Option<usize>,
    /// The dataset epoch this request was admitted against — **server
    /// assigned** at submit time (never client settable; the wire decoder
    /// ignores an incoming `epoch` field).  The epoch is part of
    /// [`Stage1Key`], so batch admission never mixes jobs admitted
    /// against different epochs of a live dataset; the response echo
    /// reports the epoch the batch was actually served from.  `None` for
    /// execution paths without epoch semantics (in-process sessions).
    pub epoch: Option<u64>,
    /// The dataset's overlay version at admission — **server assigned**
    /// like `epoch` (never client settable).  Bumped by every
    /// append/remove and reset by compaction, it completes the mutation
    /// half of stage-1 identity: jobs admitted across a mutation never
    /// share a batch, and cached artifacts are keyed on it, so a mutated
    /// (uncompacted) snapshot serves from the `NeighborCache` exactly
    /// until the next mutation.  The response echo reports the overlay
    /// version the batch was actually served from.  `None` for paths
    /// without live-mutation semantics (in-process sessions).
    pub overlay: Option<u64>,
    /// Emit a per-stage span timeline on the response (protocol v2.6).
    /// Observability only — no numerics — so like `tile_rows` it belongs
    /// to **neither** stage key: traced and untraced requests coalesce
    /// into one batch and share cached stage-1 artifacts.  The disabled
    /// path tests this single bool and does nothing else.
    pub trace: bool,
    /// The pinned CPU stage-2 data-access schedule, if the request (or
    /// the coordinator config) pinned one; `None` = the planner chooses
    /// per job at stage-2 planning time ([`Layout::choose`]) and records
    /// the choice on the request trace, not here — which is what keeps
    /// the no-override options echo byte-identical to v2.6.  Every
    /// layout is bit-identical to the scalar reference, so this belongs
    /// to **neither** stage key: jobs differing only in layout coalesce
    /// and share cached artifacts.
    pub layout: Option<Layout>,
    /// The tenant this request was admitted under (protocol v2.8
    /// `tenant` field); `None` = the anonymous tenant.  Pure
    /// admission/scheduling identity — rate limits, in-flight quotas, and
    /// deficit-round-robin fairness on the shard worker pool — with no
    /// effect on any numeric result, so it belongs to **neither** stage
    /// key.  The batcher still partitions batches on it *separately*
    /// (batch membership must be single-tenant so DRR costs are
    /// attributable), but two tenants' identical rasters share cached
    /// stage-1 artifacts.
    pub tenant: Option<TenantTag>,
}

impl Default for ResolvedOptions {
    fn default() -> Self {
        let p = AidwParams::default();
        ResolvedOptions {
            k: p.k,
            variant: Variant::default(),
            ring_rule: RingRule::default(),
            local_neighbors: None,
            alpha_levels: p.alpha_levels,
            r_min: p.r_min,
            r_max: p.r_max,
            area: None,
            tile_rows: None,
            epoch: None,
            overlay: None,
            trace: false,
            layout: None,
            tenant: None,
        }
    }
}

/// The **stage-1 admission key**: every knob that determines the kNN
/// search and the adaptive-alpha product (the paper's first stage).  Jobs
/// whose options agree on this key can share one stage-1 execution — one
/// grid/merged kNN sweep producing one reusable
/// [`crate::aidw::plan::NeighborArtifact`] — even when their stage-2
/// variants differ.  The batcher admits on this key; the coordinator's
/// `NeighborCache` keys cached artifacts on it (plus the dataset, the
/// served epoch, and a query-set fingerprint).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Stage1Key {
    pub k: usize,
    pub ring_rule: RingRule,
    /// `Some(n)` = stage 1 must also gather the n nearest neighbor
    /// indices (local stage 2 consumes them); part of the key because a
    /// dense artifact cannot serve a local consumer.
    pub local_neighbors: Option<usize>,
    pub alpha_levels: [f64; 5],
    pub r_min: f64,
    pub r_max: f64,
    pub area: Option<f64>,
    /// The admission epoch: stage-1 products from different epochs of a
    /// live dataset never mix.
    pub epoch: Option<u64>,
    /// The admission overlay version: stage-1 products from different
    /// overlay states of one epoch never mix either — this is what lets
    /// mutated-snapshot artifacts be cached at all.
    pub overlay: Option<u64>,
}

/// The **stage-2 execution key**: what remains once the neighbor artifact
/// exists — the weighted-interpolation kernel variant.  Jobs in one batch
/// may carry different stage-2 keys; the stage-2 executor runs once per
/// distinct key over that group's query rows.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Stage2Key {
    pub variant: Variant,
}

/// [`ResolvedOptions`] fields that are deliberately part of **neither**
/// stage key: changing them never changes the numbers, so jobs differing
/// only here still coalesce into one batch and share cached stage-1
/// artifacts.  This table is the third bucket of the classification
/// contract enforced by `aidw tidy` (rule `stage_key`): every resolved
/// field must appear in `stage1_key()`, `stage2_key()`, or here —
/// adding a knob without classifying it fails the build.
pub const NEITHER_STAGE_KEY: &[&str] = &[
    // execution/delivery granularity (protocol v2.4): tiles concatenated
    // in order are bit-identical to the monolithic pass
    "tile_rows",
    // observability only (protocol v2.6): a traced and an untraced
    // request produce byte-identical numeric results
    "trace",
    // data-access schedule (protocol v2.7): every layout replays the
    // scalar reference's summation order bit-identically
    "layout",
    // admission identity (protocol v2.8): rate limits, quotas, and fair
    // scheduling never change a number — two tenants' identical rasters
    // share one sweep and one cached artifact.  The batcher partitions
    // batches on tenant *separately* (single-tenant batches keep DRR
    // costs attributable), which is stricter than a stage-key split and
    // still numerics-neutral.
    "tenant",
];

/// [`QueryOptions`] fields whose [`ResolvedOptions`] counterpart has a
/// different name, as `(query_field, resolved_field)` pairs.  Consumed by
/// `aidw tidy` (rule `stage_key`) when mapping the request surface onto
/// the resolved classification.
pub const QUERY_FIELD_ALIASES: &[(&str, &str)] = &[("local", "local_neighbors")];

impl ResolvedOptions {
    /// Project out the stage-1 admission key (everything but the stage-2
    /// variant).  See [`Stage1Key`].
    pub fn stage1_key(&self) -> Stage1Key {
        Stage1Key {
            k: self.k,
            ring_rule: self.ring_rule,
            local_neighbors: self.local_neighbors,
            alpha_levels: self.alpha_levels,
            r_min: self.r_min,
            r_max: self.r_max,
            area: self.area,
            epoch: self.epoch,
            overlay: self.overlay,
        }
    }

    /// Project out the stage-2 execution key.  See [`Stage2Key`].
    pub fn stage2_key(&self) -> Stage2Key {
        Stage2Key { variant: self.variant }
    }

    /// The AIDW parameter block these options describe.
    pub fn params(&self) -> AidwParams {
        AidwParams {
            k: self.k,
            alpha_levels: self.alpha_levels,
            r_min: self.r_min,
            r_max: self.r_max,
            area: self.area,
        }
    }

    /// Fail fast on nonsense before any pipeline thread sees the job
    /// (`AidwParams::validate` semantics plus the local-mode knob).
    pub fn validate(&self) -> Result<()> {
        self.params().validate().map_err(Error::InvalidArgument)?;
        if self.local_neighbors == Some(0) {
            return Err(Error::InvalidArgument(
                "local_neighbors must be >= 1 (or unset for dense weighting)".into(),
            ));
        }
        if self.tile_rows == Some(0) {
            return Err(Error::InvalidArgument(
                "tile_rows must be >= 1 (or unset for one whole-raster tile)".into(),
            ));
        }
        if let Some(l) = self.layout {
            if !l.is_valid() {
                return Err(Error::InvalidArgument(format!(
                    "layout {} has an out-of-range tile width (1..={})",
                    l.tag(),
                    crate::aidw::plan::MAX_BLOCK
                )));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn config() -> CoordinatorConfig {
        CoordinatorConfig::default()
    }

    #[test]
    fn empty_options_resolve_to_config() {
        let cfg = config();
        let r = QueryOptions::new().resolve(&cfg);
        assert_eq!(r, ResolvedOptions::default());
        assert!(QueryOptions::new().is_default());
        assert!(r.validate().is_ok());
    }

    #[test]
    fn builder_overrides_stick() {
        let cfg = config();
        let r = QueryOptions::new()
            .k(17)
            .variant(Variant::Naive)
            .ring_rule(RingRule::PaperPlusOne)
            .local_neighbors(64)
            .alpha_levels([1.0, 2.0, 3.0, 4.0, 5.0])
            .r_bounds(0.5, 1.5)
            .area(123.0)
            .resolve(&cfg);
        assert_eq!(r.k, 17);
        assert_eq!(r.variant, Variant::Naive);
        assert_eq!(r.ring_rule, RingRule::PaperPlusOne);
        assert_eq!(r.local_neighbors, Some(64));
        assert_eq!(r.alpha_levels, [1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!((r.r_min, r.r_max), (0.5, 1.5));
        assert_eq!(r.area, Some(123.0));
        assert!(r.validate().is_ok());
    }

    #[test]
    fn dense_override_beats_config_local_mode() {
        let mut cfg = config();
        cfg.local_neighbors = Some(48);
        let inherit = QueryOptions::new().resolve(&cfg);
        assert_eq!(inherit.local_neighbors, Some(48));
        let dense = QueryOptions::new().dense().resolve(&cfg);
        assert_eq!(dense.local_neighbors, None);
        let narrower = QueryOptions::new().local_neighbors(16).resolve(&cfg);
        assert_eq!(narrower.local_neighbors, Some(16));
    }

    #[test]
    fn validation_rejects_bad_overrides() {
        let cfg = config();
        assert!(QueryOptions::new().k(0).resolve(&cfg).validate().is_err());
        assert!(QueryOptions::new()
            .r_bounds(2.0, 1.0)
            .resolve(&cfg)
            .validate()
            .is_err());
        assert!(QueryOptions::new()
            .alpha_levels([0.5, 1.0, -2.0, 3.0, 4.0])
            .resolve(&cfg)
            .validate()
            .is_err());
        assert!(QueryOptions::new().area(0.0).resolve(&cfg).validate().is_err());
        let mut zero_local = QueryOptions::new();
        zero_local.local = Some(LocalMode::Nearest(0));
        assert!(zero_local.resolve(&cfg).validate().is_err());
        assert!(QueryOptions::new().tile_rows(0).resolve(&cfg).validate().is_err());
        assert!(QueryOptions::new().tile_rows(1).resolve(&cfg).validate().is_ok());
    }

    #[test]
    fn neither_stage_key_table_matches_behavior() {
        // the declared third bucket (enforced structurally by `aidw
        // tidy`) pinned behaviorally: perturbing each listed field moves
        // neither stage key
        assert_eq!(NEITHER_STAGE_KEY, &["tile_rows", "trace", "layout", "tenant"]);
        let cfg = config();
        let base = QueryOptions::new().resolve(&cfg);
        let mut perturbed = base;
        perturbed.tile_rows = Some(7);
        perturbed.trace = true;
        perturbed.layout = Some(Layout::Soa);
        perturbed.tenant = Some(TenantTag::new("acme").unwrap());
        assert_ne!(base, perturbed);
        assert_eq!(base.stage1_key(), perturbed.stage1_key());
        assert_eq!(base.stage2_key(), perturbed.stage2_key());
        // alias table: the one renamed field, no duplicates
        assert_eq!(QUERY_FIELD_ALIASES, &[("local", "local_neighbors")]);
    }

    #[test]
    fn tile_rows_is_in_neither_stage_key() {
        // tiling is execution/delivery granularity, not numerics: jobs
        // differing only in tile_rows must coalesce and share artifacts
        let cfg = config();
        let base = QueryOptions::new().resolve(&cfg);
        let tiled = QueryOptions::new().tile_rows(64).resolve(&cfg);
        assert_eq!(tiled.tile_rows, Some(64));
        assert_ne!(base, tiled, "resolved sets differ");
        assert_eq!(base.stage1_key(), tiled.stage1_key());
        assert_eq!(base.stage2_key(), tiled.stage2_key());
        // config default flows through when the request is silent
        let mut cfg2 = config();
        cfg2.tile_rows = Some(128);
        assert_eq!(QueryOptions::new().resolve(&cfg2).tile_rows, Some(128));
        assert_eq!(QueryOptions::new().tile_rows(8).resolve(&cfg2).tile_rows, Some(8));
    }

    #[test]
    fn trace_is_in_neither_stage_key() {
        // tracing is observability, not numerics: a traced and an
        // untraced request must coalesce and share cached artifacts
        let cfg = config();
        let base = QueryOptions::new().resolve(&cfg);
        assert!(!base.trace, "tracing is opt-in");
        let traced = QueryOptions::new().trace(true).resolve(&cfg);
        assert!(traced.trace);
        assert_ne!(base, traced, "resolved sets differ");
        assert_eq!(base.stage1_key(), traced.stage1_key());
        assert_eq!(base.stage2_key(), traced.stage2_key());
        assert!(traced.validate().is_ok());
        // explicit false == absent
        assert_eq!(QueryOptions::new().trace(false).resolve(&cfg), base);
    }

    #[test]
    fn layout_is_in_neither_stage_key() {
        // layout is a data-access schedule, bit-identical by contract:
        // jobs differing only in layout must coalesce and share artifacts
        let cfg = config();
        let base = QueryOptions::new().resolve(&cfg);
        assert_eq!(base.layout, None, "layout is planner-auto by default");
        let soa = QueryOptions::new().layout(Layout::Soa).resolve(&cfg);
        assert_eq!(soa.layout, Some(Layout::Soa));
        assert_ne!(base, soa, "resolved sets differ");
        assert_eq!(base.stage1_key(), soa.stage1_key());
        assert_eq!(base.stage2_key(), soa.stage2_key());
        assert!(soa.validate().is_ok());
        // config default flows through when the request is silent
        let mut cfg2 = config();
        cfg2.layout = Some(Layout::AosoaTiles { width: 8 });
        assert_eq!(
            QueryOptions::new().resolve(&cfg2).layout,
            Some(Layout::AosoaTiles { width: 8 })
        );
        assert_eq!(
            QueryOptions::new().layout(Layout::Aos).resolve(&cfg2).layout,
            Some(Layout::Aos)
        );
        // programmatic out-of-range AosoaTiles width fails validation
        let bad = QueryOptions::new().layout(Layout::AosoaTiles { width: 0 }).resolve(&cfg);
        assert!(bad.validate().is_err());
    }

    #[test]
    fn tenant_is_in_neither_stage_key() {
        // tenancy is admission identity, not numerics: two tenants'
        // identical rasters share one stage-1 sweep and cached artifact
        // (the batcher's single-tenant partition is pinned in batcher.rs)
        let cfg = config();
        let base = QueryOptions::new().resolve(&cfg);
        assert_eq!(base.tenant, None, "anonymous by default");
        let acme = QueryOptions::new()
            .tenant(TenantTag::new("acme").unwrap())
            .resolve(&cfg);
        assert_eq!(acme.tenant, Some(TenantTag::new("acme").unwrap()));
        assert_ne!(base, acme, "resolved sets differ");
        assert_eq!(base.stage1_key(), acme.stage1_key());
        assert_eq!(base.stage2_key(), acme.stage2_key());
        assert!(acme.validate().is_ok());
    }

    #[test]
    fn partial_r_bound_override_validates_against_config_default() {
        // r_min alone, above the config's r_max = 2.0 -> invalid
        let cfg = config();
        let mut o = QueryOptions::new();
        o.r_min = Some(3.0);
        assert!(o.resolve(&cfg).validate().is_err());
        o.r_min = Some(1.0);
        assert!(o.resolve(&cfg).validate().is_ok());
    }

    #[test]
    fn resolution_is_deterministic_and_stamps_no_epoch() {
        let cfg = config();
        // explicit default == inherited default (identical stage keys)
        let explicit = QueryOptions::new().k(cfg.params.k).resolve(&cfg);
        let inherited = QueryOptions::new().resolve(&cfg);
        assert_eq!(explicit, inherited);
        // differing knobs resolve to different option sets (admission
        // itself keys on stage1_key(); see stage_keys_split_variant_…)
        assert_ne!(QueryOptions::new().k(11).resolve(&cfg), inherited);
        assert_ne!(
            QueryOptions::new().ring_rule(RingRule::PaperPlusOne).resolve(&cfg),
            inherited
        );
        // the dataset epoch is part of the stage-1 key: jobs admitted
        // before and after a compaction publish never share a batch
        let e0 = ResolvedOptions { epoch: Some(0), ..inherited };
        let e1 = ResolvedOptions { epoch: Some(1), ..inherited };
        assert_ne!(e0.stage1_key(), e1.stage1_key());
        // same for the overlay version: jobs admitted before and after a
        // mutation never share a batch (or a cached artifact)
        let v0 = ResolvedOptions { overlay: Some(0), ..inherited };
        let v1 = ResolvedOptions { overlay: Some(1), ..inherited };
        assert_ne!(v0.stage1_key(), v1.stage1_key());
        // client-side resolution never assigns epoch or overlay; the
        // coordinator stamps both at submit time
        assert_eq!(inherited.epoch, None);
        assert_eq!(inherited.overlay, None);
    }

    #[test]
    fn stage_keys_split_variant_from_search() {
        let cfg = config();
        let base = QueryOptions::new().resolve(&cfg);
        // variant-only difference: same stage-1 key, different stage-2 key
        let naive = ResolvedOptions { variant: Variant::Naive, ..base };
        let tiled = ResolvedOptions { variant: Variant::Tiled, ..base };
        assert_eq!(naive.stage1_key(), tiled.stage1_key());
        assert_ne!(naive.stage2_key(), tiled.stage2_key());
        // every search-affecting knob separates stage-1 keys
        for other in [
            ResolvedOptions { k: 3, ..base },
            ResolvedOptions { ring_rule: RingRule::PaperPlusOne, ..base },
            ResolvedOptions { local_neighbors: Some(32), ..base },
            ResolvedOptions { alpha_levels: [1.0, 2.0, 3.0, 4.0, 5.0], ..base },
            ResolvedOptions { r_min: 0.5, ..base },
            ResolvedOptions { r_max: 3.0, ..base },
            ResolvedOptions { area: Some(7.0), ..base },
            ResolvedOptions { epoch: Some(1), ..base },
            ResolvedOptions { overlay: Some(1), ..base },
        ] {
            assert_ne!(other.stage1_key(), base.stage1_key(), "{other:?}");
            assert_eq!(other.stage2_key(), base.stage2_key());
        }
    }
}
