//! Request/response types flowing through the coordinator.

use std::sync::mpsc;

use crate::error::{Error, Result};
use crate::runtime::Variant;

use super::options::{QueryOptions, ResolvedOptions};

/// One interpolation request: queries against a registered dataset, plus
/// per-request [`QueryOptions`] (builder style — the public fields of the
/// old API are gone).
///
/// ```
/// use aidw::coordinator::InterpolationRequest;
/// use aidw::coordinator::QueryOptions;
///
/// let req = InterpolationRequest::new("survey", vec![(1.0, 2.0)])
///     .with_options(QueryOptions::new().k(16).local_neighbors(64));
/// assert_eq!(req.options.k, Some(16));
/// ```
#[derive(Debug, Clone)]
pub struct InterpolationRequest {
    pub dataset: String,
    pub queries: Vec<(f64, f64)>,
    /// Per-request overrides; unset fields inherit the coordinator config.
    pub options: QueryOptions,
}

impl InterpolationRequest {
    pub fn new(dataset: &str, queries: Vec<(f64, f64)>) -> Self {
        InterpolationRequest {
            dataset: dataset.to_string(),
            queries,
            options: QueryOptions::default(),
        }
    }

    /// Replace the whole options block.
    pub fn with_options(mut self, options: QueryOptions) -> Self {
        self.options = options;
        self
    }

    /// Fluent shorthand for [`QueryOptions::k`].
    pub fn with_k(mut self, k: usize) -> Self {
        self.options.k = Some(k);
        self
    }

    /// Fluent shorthand for [`QueryOptions::variant`].
    pub fn with_variant(mut self, v: Variant) -> Self {
        self.options.variant = Some(v);
        self
    }
}

/// The prediction values plus execution metadata.
#[derive(Debug, Clone)]
pub struct InterpolationResponse {
    pub values: Vec<f64>,
    /// Stage-1 (kNN + alpha) seconds for the batch this request rode in.
    pub knn_s: f64,
    /// Stage-2 (weighted interpolating) seconds for the batch.
    pub interp_s: f64,
    /// Queries in the batch (how much sharing this request got).
    pub batch_queries: usize,
    /// Which engine ran stage 2.
    pub backend: Backend,
    /// The fully-resolved options this request actually ran with (the
    /// audit record: config defaults substituted, dataset area filled in).
    pub options: ResolvedOptions,
    /// True when the batch was served from the coordinator's
    /// `NeighborCache` (stage 1 skipped entirely; protocol v2.2) —
    /// either an exact raster match or a subset row-gather out of a
    /// covering cached artifact (v2.3; the metrics counters distinguish
    /// the two).  Mutated (uncompacted) snapshots hit too: the cache is
    /// keyed on the overlay version.
    pub stage1_cache_hit: bool,
    /// How many stage-2 executions the batch split into — more than 1
    /// means this request's kNN sweep was coalesced with jobs carrying a
    /// different stage-2 variant (protocol v2.2).
    pub stage2_groups: usize,
}

/// Stage-2 execution backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifact on PJRT (the paper's GPU analog).
    Pjrt,
    /// Pure-rust fallback (no artifacts present).
    CpuFallback,
}

/// In-flight job: request + resolved options + response channel.
pub(crate) struct Job {
    pub request: InterpolationRequest,
    /// Options resolved against the coordinator config at submit time —
    /// the batch-admission key.
    pub resolved: ResolvedOptions,
    pub respond: mpsc::Sender<Result<InterpolationResponse>>,
    pub enqueued: std::time::Instant,
}

/// Handle for awaiting an async submission.
pub struct Ticket {
    pub(crate) rx: mpsc::Receiver<Result<InterpolationResponse>>,
}

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<InterpolationResponse> {
        self.rx.recv().map_err(|_| {
            crate::error::Error::Unavailable("coordinator dropped the job".into())
        })?
    }

    /// Poll without blocking.
    ///
    /// `None` means *not ready yet — poll again*.  A dropped job (the
    /// coordinator shut down or panicked before responding) surfaces as
    /// `Some(Err(Unavailable))` instead of hanging the poller forever.
    pub fn try_wait(&self) -> Option<Result<InterpolationResponse>> {
        match self.rx.try_recv() {
            Ok(r) => Some(r),
            Err(mpsc::TryRecvError::Empty) => None,
            Err(mpsc::TryRecvError::Disconnected) => Some(Err(Error::Unavailable(
                "coordinator dropped the job".into(),
            ))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builder_sets_options() {
        let req = InterpolationRequest::new("d", vec![(0.0, 0.0)])
            .with_k(5)
            .with_variant(Variant::Naive);
        assert_eq!(req.options.k, Some(5));
        assert_eq!(req.options.variant, Some(Variant::Naive));
        assert_eq!(req.dataset, "d");
    }

    #[test]
    fn try_wait_distinguishes_pending_from_dropped() {
        // pending: sender alive, nothing sent
        let (tx, rx) = mpsc::channel::<Result<InterpolationResponse>>();
        let t = Ticket { rx };
        assert!(t.try_wait().is_none());
        // dropped: sender gone without a response
        drop(tx);
        match t.try_wait() {
            Some(Err(Error::Unavailable(_))) => {}
            other => panic!("expected Unavailable, got {:?}", other.map(|r| r.is_ok())),
        }
    }
}
