//! Request/response types flowing through the coordinator.

use std::sync::mpsc;

use crate::error::Result;
use crate::runtime::Variant;

/// One interpolation request: queries against a registered dataset.
#[derive(Debug, Clone)]
pub struct InterpolationRequest {
    pub dataset: String,
    pub queries: Vec<(f64, f64)>,
    /// Override the coordinator's default kernel variant.
    pub variant: Option<Variant>,
    /// Override k for this request (must be <= compiled k-buffer).
    pub k: Option<usize>,
}

impl InterpolationRequest {
    pub fn new(dataset: &str, queries: Vec<(f64, f64)>) -> Self {
        InterpolationRequest { dataset: dataset.to_string(), queries, variant: None, k: None }
    }
}

/// The prediction values plus execution metadata.
#[derive(Debug, Clone)]
pub struct InterpolationResponse {
    pub values: Vec<f64>,
    /// Stage-1 (kNN + alpha) seconds for the batch this request rode in.
    pub knn_s: f64,
    /// Stage-2 (weighted interpolating) seconds for the batch.
    pub interp_s: f64,
    /// Queries in the batch (how much sharing this request got).
    pub batch_queries: usize,
    /// Which engine ran stage 2.
    pub backend: Backend,
}

/// Stage-2 execution backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifact on PJRT (the paper's GPU analog).
    Pjrt,
    /// Pure-rust fallback (no artifacts present).
    CpuFallback,
}

/// In-flight job: request + response channel.
pub(crate) struct Job {
    pub request: InterpolationRequest,
    pub respond: mpsc::Sender<Result<InterpolationResponse>>,
    pub enqueued: std::time::Instant,
}

/// Handle for awaiting an async submission.
pub struct Ticket {
    pub(crate) rx: mpsc::Receiver<Result<InterpolationResponse>>,
}

impl Ticket {
    /// Block until the response arrives.
    pub fn wait(self) -> Result<InterpolationResponse> {
        self.rx.recv().map_err(|_| {
            crate::error::Error::Unavailable("coordinator dropped the job".into())
        })?
    }

    /// Poll without blocking.
    pub fn try_wait(&self) -> Option<Result<InterpolationResponse>> {
        self.rx.try_recv().ok()
    }
}
