//! Request/response types flowing through the coordinator, including the
//! tiled streaming surface.
//!
//! Every submission — streaming or not — is answered as a sequence of
//! frames: zero or more in-order [`TileResult`]s, then one terminal
//! [`StreamSummary`] (or an error).  [`Coordinator::submit_stream`]
//! exposes the frames directly as a [`TileStream`]; the whole-raster
//! [`Ticket`] is a thin wrapper that concatenates the tiles back into one
//! [`InterpolationResponse`], so there is exactly **one** execution path
//! (tiled) and the monolithic API is a view over it.
//!
//! [`Coordinator::submit_stream`]: super::Coordinator::submit_stream

use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{mpsc, Arc, Mutex};

use crate::error::{Error, Result};
use crate::runtime::Variant;

use super::options::{QueryOptions, ResolvedOptions};

/// One interpolation request: queries against a registered dataset, plus
/// per-request [`QueryOptions`] (builder style — the public fields of the
/// old API are gone).
///
/// ```
/// use aidw::coordinator::InterpolationRequest;
/// use aidw::coordinator::QueryOptions;
///
/// let req = InterpolationRequest::new("survey", vec![(1.0, 2.0)])
///     .with_options(QueryOptions::new().k(16).local_neighbors(64));
/// assert_eq!(req.options.k, Some(16));
/// ```
#[derive(Debug, Clone)]
pub struct InterpolationRequest {
    pub dataset: String,
    pub queries: Vec<(f64, f64)>,
    /// Per-request overrides; unset fields inherit the coordinator config.
    pub options: QueryOptions,
}

impl InterpolationRequest {
    pub fn new(dataset: &str, queries: Vec<(f64, f64)>) -> Self {
        InterpolationRequest {
            dataset: dataset.to_string(),
            queries,
            options: QueryOptions::default(),
        }
    }

    /// Replace the whole options block.
    pub fn with_options(mut self, options: QueryOptions) -> Self {
        self.options = options;
        self
    }

    /// Fluent shorthand for [`QueryOptions::k`].
    pub fn with_k(mut self, k: usize) -> Self {
        self.options.k = Some(k);
        self
    }

    /// Fluent shorthand for [`QueryOptions::variant`].
    pub fn with_variant(mut self, v: Variant) -> Self {
        self.options.variant = Some(v);
        self
    }

    /// Fluent shorthand for [`QueryOptions::tile_rows`].
    pub fn with_tile_rows(mut self, rows: usize) -> Self {
        self.options.tile_rows = Some(rows);
        self
    }
}

/// The prediction values plus execution metadata.
#[derive(Debug, Clone)]
pub struct InterpolationResponse {
    pub values: Vec<f64>,
    /// Stage-1 (kNN + alpha) seconds for the batch this request rode in,
    /// measured up to this request's completion: delivery is per job —
    /// each member's terminal frame is sent as soon as its own tiles are
    /// done — so a later batch peer's on-device alpha seconds are not yet
    /// included in an earlier peer's number (single-job batches, the
    /// common case, are exact batch totals).
    pub knn_s: f64,
    /// Stage-2 (weighted interpolating) seconds accumulated up to this
    /// request's completion (see [`InterpolationResponse::knn_s`] for
    /// the per-job delivery caveat; the `metrics` op reports exact
    /// batch-level totals).
    pub interp_s: f64,
    /// Queries in the batch (how much sharing this request got).
    pub batch_queries: usize,
    /// Which engine ran stage 2.
    pub backend: Backend,
    /// The fully-resolved options this request actually ran with (the
    /// audit record: config defaults substituted, dataset area filled in).
    pub options: ResolvedOptions,
    /// True when the batch was served from the coordinator's
    /// `NeighborCache` (stage 1 skipped entirely; protocol v2.2) —
    /// either an exact raster match or a subset row-gather out of a
    /// covering cached artifact (v2.3; the metrics counters distinguish
    /// the two).  Mutated (uncompacted) snapshots hit too: the cache is
    /// keyed on the overlay version.
    pub stage1_cache_hit: bool,
    /// How many stage-2 executions the batch split into — more than 1
    /// means this request's kNN sweep was coalesced with jobs carrying a
    /// different stage-2 variant (protocol v2.2).
    pub stage2_groups: usize,
    /// Per-stage span timeline (protocol v2.6), present exactly when the
    /// request opted in via `QueryOptions::trace`.
    pub trace: Option<crate::obs::Trace>,
}

/// Stage-2 execution backend.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifact on PJRT (the paper's GPU analog).
    Pjrt,
    /// Pure-rust fallback (no artifacts present).
    CpuFallback,
}

/// One in-order tile of a (possibly streamed) interpolation: the values
/// of query rows `row_range.0 .. row_range.1` in the *request's own* row
/// space, plus the resolved-options audit echo (protocol v2.4).
#[derive(Debug, Clone)]
pub struct TileResult {
    /// 0-based tile index; tiles arrive strictly in order.
    pub tile_index: usize,
    /// Total tiles this request splits into.
    pub n_tiles: usize,
    /// `[start, end)` query-row range this tile covers.
    pub row_range: (usize, usize),
    /// Predicted values for the covered rows.
    pub values: Vec<f64>,
    /// The fully-resolved options the request ran with (same audit echo
    /// the whole-raster response carries: area filled, k clamped, served
    /// epoch/overlay stamped).
    pub options: ResolvedOptions,
}

/// The terminal frame of a stream: everything the whole-raster response
/// reports except the values themselves.
#[derive(Debug, Clone)]
pub struct StreamSummary {
    /// Query rows the stream delivered.
    pub rows: usize,
    /// Tiles the stream delivered.
    pub n_tiles: usize,
    /// Stage-1 (kNN + alpha) seconds for the batch this request rode in.
    pub knn_s: f64,
    /// Stage-2 seconds accumulated up to this request's completion.
    pub interp_s: f64,
    /// Queries in the batch (how much sharing this request got).
    pub batch_queries: usize,
    pub backend: Backend,
    /// The resolved-options audit echo.
    pub options: ResolvedOptions,
    pub stage1_cache_hit: bool,
    pub stage2_groups: usize,
    /// Per-stage span timeline (protocol v2.6), present exactly when the
    /// request opted in via `QueryOptions::trace`.
    pub trace: Option<crate::obs::Trace>,
}

/// A frame on the executor -> consumer channel.
pub(crate) enum StreamFrame {
    Tile(TileResult),
    Done(StreamSummary),
    Err(Error),
}

/// Sender half of a frame channel: bounded (explicit streams — the
/// executor blocks once `stream_buffer_tiles` tiles are outstanding, the
/// backpressure that keeps service-side buffering constant) or unbounded
/// (whole-raster tickets — fire-and-forget like the pre-stream API, so an
/// unconsumed ticket can never stall the pipeline).
pub(crate) enum FrameTx {
    Bounded(mpsc::SyncSender<StreamFrame>),
    Unbounded(mpsc::Sender<StreamFrame>),
}

impl FrameTx {
    /// Send one frame; `false` means the consumer is gone (dropped
    /// ticket/stream) and the producer should stop delivering.  A
    /// bounded send may block indefinitely on a live-but-idle consumer —
    /// producers that must stay responsive (the coordinator's stage-2
    /// thread, which `shutdown` joins) use [`FrameTx::send_while`].
    pub fn send(&self, frame: StreamFrame) -> bool {
        match self {
            FrameTx::Bounded(tx) => tx.send(frame).is_ok(),
            FrameTx::Unbounded(tx) => tx.send(frame).is_ok(),
        }
    }

    /// Send one frame, but on a **full** bounded channel keep waiting
    /// only while `keep_waiting()` holds (polled every few hundred
    /// microseconds).  Returns `false` when the consumer is gone or the
    /// wait was abandoned — either way the producer should stop
    /// delivering to this consumer.  This is what keeps a held-but-idle
    /// stream from wedging `Coordinator::shutdown`: the stage-2 thread
    /// passes a predicate that clears on shutdown and on job
    /// cancellation.
    pub fn send_while(&self, frame: StreamFrame, keep_waiting: impl Fn() -> bool) -> bool {
        match self {
            FrameTx::Unbounded(tx) => tx.send(frame).is_ok(),
            FrameTx::Bounded(tx) => {
                let mut frame = frame;
                loop {
                    match tx.try_send(frame) {
                        Ok(()) => return true,
                        Err(mpsc::TrySendError::Full(f)) => {
                            if !keep_waiting() {
                                return false;
                            }
                            frame = f;
                            std::thread::sleep(std::time::Duration::from_micros(200));
                        }
                        Err(mpsc::TrySendError::Disconnected(_)) => return false,
                    }
                }
            }
        }
    }
}

/// The producer-side handle a [`Job`] carries: the frame sender plus the
/// buffered-values gauge shared with the consuming [`TileStream`].
pub(crate) struct StreamHandle {
    pub tx: FrameTx,
    /// Values sent but not yet received (shared with the receiver, which
    /// decrements as it drains).
    pub buffered: Arc<AtomicUsize>,
    /// True for bounded (explicit-stream) channels — only those feed the
    /// `stream_peak_buffered` gauge, because unbounded tickets buffer
    /// arbitrarily by design.
    pub bounded: bool,
}

/// In-flight job: request + resolved options + frame channel.
pub(crate) struct Job {
    pub request: InterpolationRequest,
    /// Options resolved against the coordinator config at submit time —
    /// the batch-admission key.
    pub resolved: ResolvedOptions,
    pub respond: StreamHandle,
    /// Set when the consumer dropped its ticket/stream without waiting:
    /// the batcher sweeps cancelled jobs out of the queue (freeing their
    /// backpressure slots) and the dispatcher skips them at batch
    /// formation, so abandoned work is never executed.
    pub cancel: Arc<AtomicBool>,
    pub enqueued: std::time::Instant,
    /// When the dispatcher admitted this job into a batch (popped or
    /// linger-taken) — the end of the admission-wait span and the start
    /// of the coalesce-wait span.  `None` until batch formation; only
    /// consulted when `resolved.trace` is set.
    pub admitted: Option<std::time::Instant>,
    /// The tenant's in-flight slot (protocol v2.8): an RAII guard claimed
    /// at submission and released by Drop wherever the job ends — served,
    /// failed, cancelled, or swept.  `None` only in tests that bypass
    /// `enqueue`.
    pub admit: Option<crate::shard::AdmitGuard>,
}

impl Job {
    pub fn cancelled(&self) -> bool {
        self.cancel.load(Ordering::Relaxed)
    }
}

/// Consumer of a frame sequence: yields [`TileResult`]s strictly in
/// order, then terminates with a [`StreamSummary`] (or an error).
/// Dropping it before the terminal frame cancels the job — a queued job
/// is swept (its backpressure slot freed), an executing one stops
/// delivering at the next tile.
pub struct TileStream {
    rx: mpsc::Receiver<StreamFrame>,
    buffered: Arc<AtomicUsize>,
    cancel: Arc<AtomicBool>,
    summary: Option<StreamSummary>,
    finished: bool,
    /// Tiles drained by non-blocking polls before the terminal frame.
    collected: Vec<TileResult>,
}

impl TileStream {
    pub(crate) fn new(
        rx: mpsc::Receiver<StreamFrame>,
        buffered: Arc<AtomicUsize>,
        cancel: Arc<AtomicBool>,
    ) -> TileStream {
        TileStream {
            rx,
            buffered,
            cancel,
            summary: None,
            finished: false,
            collected: Vec::new(),
        }
    }

    /// Block for the next tile.  `None` means the stream completed —
    /// [`TileStream::summary`] then holds the terminal facts.  An error
    /// (mid-stream or fail-stop) is yielded once, after which the stream
    /// is finished.
    pub fn next(&mut self) -> Option<Result<TileResult>> {
        if self.finished {
            return None;
        }
        match self.rx.recv() {
            Ok(StreamFrame::Tile(t)) => {
                self.buffered.fetch_sub(t.values.len(), Ordering::Relaxed);
                Some(Ok(t))
            }
            Ok(StreamFrame::Done(s)) => {
                self.summary = Some(s);
                self.finished = true;
                None
            }
            Ok(StreamFrame::Err(e)) => {
                self.finished = true;
                Some(Err(e))
            }
            Err(_) => {
                self.finished = true;
                Some(Err(Error::Unavailable(
                    "coordinator dropped the job".into(),
                )))
            }
        }
    }

    /// The terminal summary, once [`TileStream::next`] has returned
    /// `None`.
    pub fn summary(&self) -> Option<&StreamSummary> {
        self.summary.as_ref()
    }

    /// Drain the whole stream and concatenate the tiles into the classic
    /// whole-raster response (the monolithic API as a view over the tiled
    /// one).
    pub fn wait(mut self) -> Result<InterpolationResponse> {
        let mut tiles = std::mem::take(&mut self.collected);
        while let Some(next) = self.next() {
            tiles.push(next?);
        }
        self.assemble(tiles)
    }

    /// Non-blocking poll toward the whole-raster response: drains every
    /// available frame, returns `Some` once the terminal frame arrived.
    /// `None` strictly means *not finished yet — poll again*; a dropped
    /// job surfaces as `Some(Err(Unavailable))` instead of hanging the
    /// poller forever.
    pub fn try_collect(&mut self) -> Option<Result<InterpolationResponse>> {
        loop {
            if self.finished {
                // terminal frame already consumed by an earlier poll
                return Some(Err(Error::Unavailable(
                    "response already taken from this ticket".into(),
                )));
            }
            match self.rx.try_recv() {
                Ok(StreamFrame::Tile(t)) => {
                    self.buffered.fetch_sub(t.values.len(), Ordering::Relaxed);
                    self.collected.push(t);
                }
                Ok(StreamFrame::Done(s)) => {
                    self.summary = Some(s);
                    self.finished = true;
                    let tiles = std::mem::take(&mut self.collected);
                    return Some(self.assemble(tiles));
                }
                Ok(StreamFrame::Err(e)) => {
                    self.finished = true;
                    return Some(Err(e));
                }
                Err(mpsc::TryRecvError::Empty) => return None,
                Err(mpsc::TryRecvError::Disconnected) => {
                    self.finished = true;
                    return Some(Err(Error::Unavailable(
                        "coordinator dropped the job".into(),
                    )));
                }
            }
        }
    }

    fn assemble(&mut self, tiles: Vec<TileResult>) -> Result<InterpolationResponse> {
        let summary = self
            .summary
            .take()
            .ok_or_else(|| Error::Unavailable("stream ended without a summary".into()))?;
        let mut values = Vec::with_capacity(summary.rows);
        for t in &tiles {
            debug_assert_eq!(t.row_range.0, values.len(), "tiles must be contiguous");
            values.extend_from_slice(&t.values);
        }
        debug_assert_eq!(values.len(), summary.rows);
        Ok(InterpolationResponse {
            values,
            knn_s: summary.knn_s,
            interp_s: summary.interp_s,
            batch_queries: summary.batch_queries,
            backend: summary.backend,
            options: summary.options,
            stage1_cache_hit: summary.stage1_cache_hit,
            stage2_groups: summary.stage2_groups,
            trace: summary.trace,
        })
    }
}

impl Drop for TileStream {
    fn drop(&mut self) {
        if !self.finished {
            // dropped without draining: cancel the job so a queued slot is
            // reclaimable and an executing producer stops delivering
            self.cancel.store(true, Ordering::Relaxed);
        }
    }
}

/// Handle for awaiting an async whole-raster submission: drains its
/// underlying [`TileStream`] and concatenates the tiles.  Dropping it
/// without waiting cancels the job (the batcher frees the queue slot).
pub struct Ticket {
    // lock-order: tile_stream
    pub(crate) stream: Mutex<TileStream>,
}

impl Ticket {
    pub(crate) fn new(stream: TileStream) -> Ticket {
        Ticket { stream: Mutex::new(stream) }
    }

    /// The underlying frame stream (session-facade plumbing).
    pub(crate) fn into_stream(self) -> TileStream {
        self.stream.into_inner().unwrap()
    }

    /// Block until the response arrives.
    pub fn wait(self) -> Result<InterpolationResponse> {
        self.into_stream().wait()
    }

    /// Poll without blocking.
    ///
    /// `None` means *not ready yet — poll again*.  A dropped job (the
    /// coordinator shut down or panicked before responding) surfaces as
    /// `Some(Err(Unavailable))` instead of hanging the poller forever.
    pub fn try_wait(&self) -> Option<Result<InterpolationResponse>> {
        self.stream.lock().unwrap().try_collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parts() -> (mpsc::Sender<StreamFrame>, TileStream, Arc<AtomicBool>) {
        let (tx, rx) = mpsc::channel();
        let cancel = Arc::new(AtomicBool::new(false));
        let stream = TileStream::new(rx, Arc::new(AtomicUsize::new(0)), cancel.clone());
        (tx, stream, cancel)
    }

    fn tile(i: usize, n: usize, start: usize, values: Vec<f64>) -> TileResult {
        let end = start + values.len();
        TileResult {
            tile_index: i,
            n_tiles: n,
            row_range: (start, end),
            values,
            options: ResolvedOptions::default(),
        }
    }

    fn done(rows: usize, n_tiles: usize) -> StreamSummary {
        StreamSummary {
            rows,
            n_tiles,
            knn_s: 0.1,
            interp_s: 0.2,
            batch_queries: rows,
            backend: Backend::CpuFallback,
            options: ResolvedOptions::default(),
            stage1_cache_hit: false,
            stage2_groups: 1,
            trace: None,
        }
    }

    #[test]
    fn builder_sets_options() {
        let req = InterpolationRequest::new("d", vec![(0.0, 0.0)])
            .with_k(5)
            .with_variant(Variant::Naive)
            .with_tile_rows(16);
        assert_eq!(req.options.k, Some(5));
        assert_eq!(req.options.variant, Some(Variant::Naive));
        assert_eq!(req.options.tile_rows, Some(16));
        assert_eq!(req.dataset, "d");
    }

    #[test]
    fn ticket_concatenates_tiles_in_order() {
        let (tx, stream, _cancel) = parts();
        tx.send(StreamFrame::Tile(tile(0, 2, 0, vec![1.0, 2.0]))).unwrap();
        tx.send(StreamFrame::Tile(tile(1, 2, 2, vec![3.0]))).unwrap();
        tx.send(StreamFrame::Done(done(3, 2))).unwrap();
        let resp = Ticket::new(stream).wait().unwrap();
        assert_eq!(resp.values, vec![1.0, 2.0, 3.0]);
        assert_eq!(resp.batch_queries, 3);
        assert!((resp.knn_s - 0.1).abs() < 1e-12);
    }

    #[test]
    fn try_wait_distinguishes_pending_from_dropped() {
        // pending: sender alive, nothing sent
        let (tx, stream, _cancel) = parts();
        let t = Ticket::new(stream);
        assert!(t.try_wait().is_none());
        // a tile alone is still pending (terminal frame not yet in)
        tx.send(StreamFrame::Tile(tile(0, 2, 0, vec![1.0]))).unwrap();
        assert!(t.try_wait().is_none());
        // dropped: sender gone without a terminal frame
        drop(tx);
        match t.try_wait() {
            Some(Err(Error::Unavailable(_))) => {}
            other => panic!("expected Unavailable, got {:?}", other.map(|r| r.is_ok())),
        }
    }

    #[test]
    fn try_wait_assembles_once_done_arrives() {
        let (tx, stream, _cancel) = parts();
        let t = Ticket::new(stream);
        tx.send(StreamFrame::Tile(tile(0, 2, 0, vec![1.0, 2.0]))).unwrap();
        tx.send(StreamFrame::Tile(tile(1, 2, 2, vec![3.0]))).unwrap();
        tx.send(StreamFrame::Done(done(3, 2))).unwrap();
        let resp = t.try_wait().expect("terminal frame arrived").unwrap();
        assert_eq!(resp.values, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn stream_yields_tiles_then_summary() {
        let (tx, mut stream, _cancel) = parts();
        tx.send(StreamFrame::Tile(tile(0, 2, 0, vec![1.0]))).unwrap();
        tx.send(StreamFrame::Tile(tile(1, 2, 1, vec![2.0]))).unwrap();
        tx.send(StreamFrame::Done(done(2, 2))).unwrap();
        let t0 = stream.next().unwrap().unwrap();
        assert_eq!((t0.tile_index, t0.row_range), (0, (0, 1)));
        let t1 = stream.next().unwrap().unwrap();
        assert_eq!((t1.tile_index, t1.row_range), (1, (1, 2)));
        assert!(stream.summary().is_none(), "summary only after exhaustion");
        assert!(stream.next().is_none());
        assert_eq!(stream.summary().unwrap().n_tiles, 2);
        assert!(stream.next().is_none(), "finished streams stay finished");
    }

    #[test]
    fn mid_stream_error_is_yielded_once() {
        let (tx, mut stream, _cancel) = parts();
        tx.send(StreamFrame::Tile(tile(0, 3, 0, vec![1.0]))).unwrap();
        tx.send(StreamFrame::Err(Error::Service("boom".into()))).unwrap();
        assert!(stream.next().unwrap().is_ok());
        assert!(matches!(stream.next(), Some(Err(Error::Service(_)))));
        assert!(stream.next().is_none());
        assert!(stream.summary().is_none());
    }

    #[test]
    fn drop_without_wait_cancels_the_job() {
        let (_tx, stream, cancel) = parts();
        assert!(!cancel.load(Ordering::Relaxed));
        drop(stream);
        assert!(cancel.load(Ordering::Relaxed), "drop must flag cancellation");
        // a consumed ticket must NOT cancel (the job already completed)
        let (tx, stream, cancel) = parts();
        tx.send(StreamFrame::Done(done(0, 0))).unwrap();
        let resp = Ticket::new(stream).wait().unwrap();
        assert!(resp.values.is_empty());
        assert!(!cancel.load(Ordering::Relaxed), "completed wait is not a cancel");
    }

    #[test]
    fn buffered_gauge_decrements_as_tiles_drain() {
        let (tx, rx) = mpsc::channel();
        let buffered = Arc::new(AtomicUsize::new(0));
        let cancel = Arc::new(AtomicBool::new(false));
        let mut stream = TileStream::new(rx, buffered.clone(), cancel);
        // producer side: count values in, send
        buffered.fetch_add(2, Ordering::Relaxed);
        tx.send(StreamFrame::Tile(tile(0, 1, 0, vec![1.0, 2.0]))).unwrap();
        tx.send(StreamFrame::Done(done(2, 1))).unwrap();
        assert_eq!(buffered.load(Ordering::Relaxed), 2);
        stream.next().unwrap().unwrap();
        assert_eq!(buffered.load(Ordering::Relaxed), 0, "receiver drains the gauge");
        assert!(stream.next().is_none());
    }
}
