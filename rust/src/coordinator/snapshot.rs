//! Dataset persistence: binary snapshots of registered datasets so a
//! service restart does not need clients to re-upload their point sets.
//!
//! Format (little-endian):
//! ```text
//! magic "AIDWSNP1" | u64 n | n×f64 xs | n×f64 ys | n×f64 zs
//! ```
//! The grid index is *not* serialized — rebuilding it is an O(n) sort
//! (faster than deserializing on modern cores) and keeps the format
//! independent of index-layout changes.

use std::io::{Read, Write};
use std::path::Path;

use crate::error::{Error, Result};
use crate::geom::PointSet;

const MAGIC: &[u8; 8] = b"AIDWSNP1";

/// Serialize a point set to the writer.
pub fn write_points<W: Write>(w: &mut W, pts: &PointSet) -> Result<()> {
    w.write_all(MAGIC)?;
    w.write_all(&(pts.len() as u64).to_le_bytes())?;
    for channel in [&pts.xs, &pts.ys, &pts.zs] {
        for &v in channel.iter() {
            w.write_all(&v.to_le_bytes())?;
        }
    }
    Ok(())
}

/// Deserialize a point set from the reader.
pub fn read_points<R: Read>(r: &mut R) -> Result<PointSet> {
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != MAGIC {
        return Err(Error::InvalidArgument(format!(
            "bad snapshot magic {:?} (expected {MAGIC:?})",
            &magic
        )));
    }
    let mut nb = [0u8; 8];
    r.read_exact(&mut nb)?;
    let n = u64::from_le_bytes(nb) as usize;
    // sanity cap: 2^33 points = 192 GiB — reject obviously corrupt headers
    if n > (1 << 33) {
        return Err(Error::InvalidArgument(format!("implausible point count {n}")));
    }
    let mut read_channel = |n: usize| -> Result<Vec<f64>> {
        let mut buf = vec![0u8; n * 8];
        r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    };
    let xs = read_channel(n)?;
    let ys = read_channel(n)?;
    let zs = read_channel(n)?;
    for v in xs.iter().chain(&ys).chain(&zs) {
        if !v.is_finite() {
            return Err(Error::InvalidArgument("non-finite value in snapshot".into()));
        }
    }
    Ok(PointSet::from_soa(xs, ys, zs))
}

/// Validate a dataset name for on-disk persistence.  Path separators and
/// NULs are unsafe; a leading `.` would publish a dot-file that collides
/// with the `.<name>.aidw.tmp` / `.<name>.live.tmp` staging convention
/// (and would be invisible to a plain `ls`).  Shared by the v1 snapshot
/// writer and the live WAL/snapshot layer.
pub fn validate_dataset_name(name: &str) -> Result<()> {
    if name.is_empty() || name.contains(['/', '\\', '\0']) || name.starts_with('.') {
        return Err(Error::InvalidArgument(format!("unsafe dataset name '{name}'")));
    }
    Ok(())
}

/// Save one dataset to `<dir>/<name>.aidw`.
pub fn save_dataset(dir: &Path, name: &str, pts: &PointSet) -> Result<()> {
    validate_dataset_name(name)?;
    std::fs::create_dir_all(dir)?;
    let tmp = dir.join(format!(".{name}.aidw.tmp"));
    {
        let mut f = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        write_points(&mut f, pts)?;
        f.flush()?;
    }
    // atomic publish
    std::fs::rename(&tmp, dir.join(format!("{name}.aidw")))?;
    Ok(())
}

/// Load every `*.aidw` snapshot in `dir`: returns (name, points) pairs,
/// sorted by name.  Unreadable files produce errors, not silent skips.
pub fn load_dir(dir: &Path) -> Result<Vec<(String, PointSet)>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) != Some("aidw") {
            continue;
        }
        let name = path
            .file_stem()
            .and_then(|s| s.to_str())
            .ok_or_else(|| Error::InvalidArgument(format!("bad snapshot path {path:?}")))?
            .to_string();
        let mut f = std::io::BufReader::new(std::fs::File::open(&path)?);
        let pts = read_points(&mut f)
            .map_err(|e| Error::InvalidArgument(format!("{}: {e}", path.display())))?;
        out.push((name, pts));
    }
    out.sort_by(|a, b| a.0.cmp(&b.0));
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    fn tmpdir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("aidw_snap_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn roundtrip_in_memory() {
        let pts = workload::uniform_square(500, 100.0, 401);
        let mut buf = Vec::new();
        write_points(&mut buf, &pts).unwrap();
        assert_eq!(buf.len(), 8 + 8 + 3 * 500 * 8);
        let back = read_points(&mut &buf[..]).unwrap();
        assert_eq!(back.xs, pts.xs);
        assert_eq!(back.ys, pts.ys);
        assert_eq!(back.zs, pts.zs);
    }

    #[test]
    fn empty_set_roundtrips() {
        let pts = crate::geom::PointSet::default();
        let mut buf = Vec::new();
        write_points(&mut buf, &pts).unwrap();
        assert_eq!(read_points(&mut &buf[..]).unwrap().len(), 0);
    }

    #[test]
    fn corrupt_inputs_rejected() {
        // bad magic
        assert!(read_points(&mut &b"NOTMAGIC\0\0\0\0\0\0\0\0"[..]).is_err());
        // truncated body
        let pts = workload::uniform_square(10, 1.0, 402);
        let mut buf = Vec::new();
        write_points(&mut buf, &pts).unwrap();
        assert!(read_points(&mut &buf[..buf.len() - 5]).is_err());
        // implausible count
        let mut huge = MAGIC.to_vec();
        huge.extend_from_slice(&u64::MAX.to_le_bytes());
        assert!(read_points(&mut &huge[..]).is_err());
        // non-finite payload
        let mut nan = MAGIC.to_vec();
        nan.extend_from_slice(&1u64.to_le_bytes());
        nan.extend_from_slice(&f64::NAN.to_le_bytes());
        nan.extend_from_slice(&1f64.to_le_bytes());
        nan.extend_from_slice(&1f64.to_le_bytes());
        assert!(read_points(&mut &nan[..]).is_err());
    }

    #[test]
    fn save_and_load_dir() {
        let dir = tmpdir("dir");
        let a = workload::uniform_square(100, 10.0, 403);
        let b = workload::terrain_samples(50, 10.0, 0.0, 404);
        save_dataset(&dir, "alpha", &a).unwrap();
        save_dataset(&dir, "beta", &b).unwrap();
        std::fs::write(dir.join("ignore.txt"), b"noise").unwrap();
        let loaded = load_dir(&dir).unwrap();
        assert_eq!(loaded.len(), 2);
        assert_eq!(loaded[0].0, "alpha");
        assert_eq!(loaded[0].1.len(), 100);
        assert_eq!(loaded[1].0, "beta");
        assert_eq!(loaded[1].1.zs, b.zs);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn unsafe_names_rejected() {
        let dir = tmpdir("names");
        let pts = workload::uniform_square(5, 1.0, 405);
        assert!(save_dataset(&dir, "../evil", &pts).is_err());
        assert!(save_dataset(&dir, "", &pts).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn dot_names_rejected() {
        // a name like ".foo" would publish ".foo.aidw", colliding with the
        // ".<name>.aidw.tmp" staging convention and silently showing up in
        // load_dir
        let dir = tmpdir("dotnames");
        let pts = workload::uniform_square(5, 1.0, 406);
        assert!(save_dataset(&dir, ".foo", &pts).is_err());
        assert!(save_dataset(&dir, ".", &pts).is_err());
        assert!(validate_dataset_name(".hidden").is_err());
        assert!(validate_dataset_name("ok.name").is_ok());
        // nothing was published
        assert!(load_dir(&dir).unwrap().is_empty());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_dir_is_empty() {
        let got = load_dir(Path::new("/nonexistent/aidw_snapshots")).unwrap();
        assert!(got.is_empty());
    }
}
