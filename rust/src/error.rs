//! Crate-wide error type (hand-rolled Display — no derive crates in the
//! offline vendor set).

use std::fmt;

/// Errors produced by the aidw library.
#[derive(Debug)]
pub enum Error {
    /// Artifact directory missing or malformed (run `make artifacts`).
    Artifact(String),

    /// The PJRT layer (xla crate) failed.
    Xla(String),

    /// A request referenced an unknown dataset.
    UnknownDataset(String),

    /// Invalid request or configuration parameters.
    InvalidArgument(String),

    /// kNN search cannot satisfy k (fewer than k data points).
    InsufficientData { k: usize, available: usize },

    /// JSON parse error (service protocol / manifest).
    Json { offset: usize, message: String },

    /// Service-level failure (bind, connect, protocol).
    Service(String),

    /// The coordinator is shutting down / queue closed / job dropped.
    Unavailable(String),

    /// A tenant exceeded its admission quota (token-bucket rate or
    /// in-flight cap) — the structured fail-closed rejection of the
    /// multi-tenant admission layer (protocol v2.8 code `over_quota`).
    OverQuota(String),

    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Artifact(m) => write!(f, "artifact error: {m}"),
            Error::Xla(m) => write!(f, "xla/pjrt error: {m}"),
            Error::UnknownDataset(m) => write!(f, "unknown dataset: {m}"),
            Error::InvalidArgument(m) => write!(f, "invalid argument: {m}"),
            Error::InsufficientData { k, available } => {
                write!(f, "k={k} exceeds data points available ({available})")
            }
            Error::Json { offset, message } => {
                write!(f, "json error at byte {offset}: {message}")
            }
            Error::Service(m) => write!(f, "service error: {m}"),
            Error::Unavailable(m) => write!(f, "coordinator unavailable: {m}"),
            Error::OverQuota(m) => write!(f, "over quota: {m}"),
            Error::Io(e) => write!(f, "io error: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
