//! Crate-wide error type.

use thiserror::Error;

/// Errors produced by the aidw library.
#[derive(Error, Debug)]
pub enum Error {
    /// Artifact directory missing or malformed (run `make artifacts`).
    #[error("artifact error: {0}")]
    Artifact(String),

    /// The PJRT layer (xla crate) failed.
    #[error("xla/pjrt error: {0}")]
    Xla(String),

    /// A request referenced an unknown dataset.
    #[error("unknown dataset: {0}")]
    UnknownDataset(String),

    /// Invalid request or configuration parameters.
    #[error("invalid argument: {0}")]
    InvalidArgument(String),

    /// kNN search cannot satisfy k (fewer than k data points).
    #[error("k={k} exceeds data points available ({available})")]
    InsufficientData { k: usize, available: usize },

    /// JSON parse error (service protocol / manifest).
    #[error("json error at byte {offset}: {message}")]
    Json { offset: usize, message: String },

    /// Service-level failure (bind, connect, protocol).
    #[error("service error: {0}")]
    Service(String),

    /// The coordinator is shutting down / queue closed.
    #[error("coordinator unavailable: {0}")]
    Unavailable(String),

    /// Underlying I/O failure.
    #[error("io error: {0}")]
    Io(#[from] std::io::Error),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
