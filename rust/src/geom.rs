//! Planar geometry primitives: points, bounding boxes, distances.
//!
//! AIDW operates on scattered 2.5D samples: planar (x, y) position plus a
//! scalar value z (elevation, concentration, ...).  Point storage is
//! Structure-of-Arrays throughout — the paper's §4.2.1 data layout — which
//! is also what the PJRT artifacts consume directly.

/// Squared-distance floor used by the weighting kernels (identical to
/// `EPS_D2` in `python/compile/kernels/ref.py` so fp paths agree).
pub const EPS_D2: f64 = 1e-12;

/// Squared Euclidean distance between two planar points.
#[inline(always)]
pub fn dist2(ax: f64, ay: f64, bx: f64, by: f64) -> f64 {
    let dx = ax - bx;
    let dy = ay - by;
    dx * dx + dy * dy
}

/// Single-precision squared distance (GPU-analog paths are f32).
#[inline(always)]
pub fn dist2_f32(ax: f32, ay: f32, bx: f32, by: f32) -> f32 {
    let dx = ax - bx;
    let dy = ay - by;
    dx * dx + dy * dy
}

/// Axis-aligned bounding box of a planar region.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Aabb {
    pub min_x: f64,
    pub min_y: f64,
    pub max_x: f64,
    pub max_y: f64,
}

impl Aabb {
    /// The empty box (inverted bounds; `extend` fixes it up).
    pub const EMPTY: Aabb = Aabb {
        min_x: f64::INFINITY,
        min_y: f64::INFINITY,
        max_x: f64::NEG_INFINITY,
        max_y: f64::NEG_INFINITY,
    };

    /// Box from explicit bounds.
    pub fn new(min_x: f64, min_y: f64, max_x: f64, max_y: f64) -> Self {
        Aabb { min_x, min_y, max_x, max_y }
    }

    /// Bounding box of a set of coordinates (serial fold).
    pub fn from_points(xs: &[f64], ys: &[f64]) -> Self {
        let mut b = Aabb::EMPTY;
        for (&x, &y) in xs.iter().zip(ys) {
            b.extend(x, y);
        }
        b
    }

    /// Grow to include a point.
    #[inline]
    pub fn extend(&mut self, x: f64, y: f64) {
        self.min_x = self.min_x.min(x);
        self.min_y = self.min_y.min(y);
        self.max_x = self.max_x.max(x);
        self.max_y = self.max_y.max(y);
    }

    /// Union of two boxes.
    pub fn union(&self, other: &Aabb) -> Aabb {
        Aabb {
            min_x: self.min_x.min(other.min_x),
            min_y: self.min_y.min(other.min_y),
            max_x: self.max_x.max(other.max_x),
            max_y: self.max_y.max(other.max_y),
        }
    }

    /// Width (x extent); zero for the empty box.
    pub fn width(&self) -> f64 {
        (self.max_x - self.min_x).max(0.0)
    }

    /// Height (y extent); zero for the empty box.
    pub fn height(&self) -> f64 {
        (self.max_y - self.min_y).max(0.0)
    }

    /// Area of the region — the `A` of Eq. 2.
    pub fn area(&self) -> f64 {
        self.width() * self.height()
    }

    /// True if the box contains the point (inclusive bounds).
    pub fn contains(&self, x: f64, y: f64) -> bool {
        x >= self.min_x && x <= self.max_x && y >= self.min_y && y <= self.max_y
    }

    /// True if no point was ever added.
    pub fn is_empty(&self) -> bool {
        self.min_x > self.max_x || self.min_y > self.max_y
    }
}

/// A scattered set of 2.5D samples in SoA layout.
#[derive(Debug, Clone, Default)]
pub struct PointSet {
    pub xs: Vec<f64>,
    pub ys: Vec<f64>,
    pub zs: Vec<f64>,
}

impl PointSet {
    /// Empty set with reserved capacity.
    pub fn with_capacity(n: usize) -> Self {
        PointSet {
            xs: Vec::with_capacity(n),
            ys: Vec::with_capacity(n),
            zs: Vec::with_capacity(n),
        }
    }

    /// Build from parallel SoA vectors (must be equal length).
    pub fn from_soa(xs: Vec<f64>, ys: Vec<f64>, zs: Vec<f64>) -> Self {
        assert_eq!(xs.len(), ys.len());
        assert_eq!(xs.len(), zs.len());
        PointSet { xs, ys, zs }
    }

    /// Number of points.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True when the set holds no points.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Append one sample.
    pub fn push(&mut self, x: f64, y: f64, z: f64) {
        self.xs.push(x);
        self.ys.push(y);
        self.zs.push(z);
    }

    /// Planar positions only (query sets carry no z).
    pub fn xy(&self) -> Vec<(f64, f64)> {
        self.xs.iter().zip(&self.ys).map(|(&x, &y)| (x, y)).collect()
    }

    /// Bounding box of the positions.
    pub fn bounds(&self) -> Aabb {
        Aabb::from_points(&self.xs, &self.ys)
    }

    /// Borrowed columnar view of the whole set.  Because `PointSet` is
    /// already SoA, this is a zero-cost reborrow — the "columnar view
    /// built once per epoch" that the layout-parameterized stage-2
    /// kernels consume, carried through compaction for free (compaction
    /// rebuilds the `PointSet` itself, and the view borrows from it).
    pub fn columns(&self) -> Columns<'_> {
        Columns { xs: &self.xs, ys: &self.ys, zs: &self.zs }
    }

    /// Min/max of the value channel, or None if empty.
    pub fn z_range(&self) -> Option<(f64, f64)> {
        if self.is_empty() {
            return None;
        }
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for &z in &self.zs {
            lo = lo.min(z);
            hi = hi.max(z);
        }
        Some((lo, hi))
    }
}

/// Borrowed columnar (SoA) view over a contiguous range of samples.
///
/// The layout-parameterized stage-2 kernels walk these parallel slices in
/// fixed-width blocks; slicing a view (`sub`) is how cache-blocked loops
/// carve L1/L2-resident panels out of a full epoch without copying.
#[derive(Debug, Clone, Copy)]
pub struct Columns<'a> {
    pub xs: &'a [f64],
    pub ys: &'a [f64],
    pub zs: &'a [f64],
}

impl<'a> Columns<'a> {
    /// View over parallel slices (must be equal length).
    pub fn new(xs: &'a [f64], ys: &'a [f64], zs: &'a [f64]) -> Columns<'a> {
        assert_eq!(xs.len(), ys.len());
        assert_eq!(xs.len(), zs.len());
        Columns { xs, ys, zs }
    }

    /// Number of samples in view.
    pub fn len(&self) -> usize {
        self.xs.len()
    }

    /// True when the view covers no samples.
    pub fn is_empty(&self) -> bool {
        self.xs.is_empty()
    }

    /// Sub-view over `[start, end)` (a cache panel).
    pub fn sub(&self, start: usize, end: usize) -> Columns<'a> {
        Columns {
            xs: &self.xs[start..end],
            ys: &self.ys[start..end],
            zs: &self.zs[start..end],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dist2_basics() {
        assert_eq!(dist2(0.0, 0.0, 3.0, 4.0), 25.0);
        assert_eq!(dist2(1.0, 1.0, 1.0, 1.0), 0.0);
        assert_eq!(dist2_f32(0.0, 0.0, 3.0, 4.0), 25.0);
    }

    #[test]
    fn aabb_from_points() {
        let b = Aabb::from_points(&[1.0, -2.0, 3.0], &[0.5, 4.0, -1.0]);
        assert_eq!(b, Aabb::new(-2.0, -1.0, 3.0, 4.0));
        assert_eq!(b.width(), 5.0);
        assert_eq!(b.height(), 5.0);
        assert_eq!(b.area(), 25.0);
    }

    #[test]
    fn aabb_empty() {
        let b = Aabb::EMPTY;
        assert!(b.is_empty());
        assert_eq!(b.area(), 0.0);
        let b2 = Aabb::from_points(&[], &[]);
        assert!(b2.is_empty());
    }

    #[test]
    fn aabb_contains_and_union() {
        let a = Aabb::new(0.0, 0.0, 1.0, 1.0);
        let b = Aabb::new(2.0, 2.0, 3.0, 3.0);
        assert!(a.contains(0.5, 0.5));
        assert!(a.contains(1.0, 1.0)); // inclusive
        assert!(!a.contains(1.1, 0.5));
        let u = a.union(&b);
        assert!(u.contains(1.5, 1.5));
        assert_eq!(u.area(), 9.0);
    }

    #[test]
    fn pointset_roundtrip() {
        let mut p = PointSet::with_capacity(2);
        p.push(1.0, 2.0, 3.0);
        p.push(-1.0, 0.0, 5.0);
        assert_eq!(p.len(), 2);
        assert_eq!(p.xy(), vec![(1.0, 2.0), (-1.0, 0.0)]);
        assert_eq!(p.z_range(), Some((3.0, 5.0)));
        let b = p.bounds();
        assert_eq!(b, Aabb::new(-1.0, 0.0, 1.0, 2.0));
    }

    #[test]
    #[should_panic]
    fn pointset_soa_length_mismatch_panics() {
        let _ = PointSet::from_soa(vec![1.0], vec![1.0, 2.0], vec![1.0]);
    }

    #[test]
    fn columns_view_and_sub() {
        let mut p = PointSet::with_capacity(3);
        p.push(1.0, 2.0, 3.0);
        p.push(4.0, 5.0, 6.0);
        p.push(7.0, 8.0, 9.0);
        let c = p.columns();
        assert_eq!(c.len(), 3);
        assert!(!c.is_empty());
        let s = c.sub(1, 3);
        assert_eq!(s.len(), 2);
        assert_eq!(s.xs, &[4.0, 7.0]);
        assert_eq!(s.ys, &[5.0, 8.0]);
        assert_eq!(s.zs, &[6.0, 9.0]);
    }

    #[test]
    #[should_panic]
    fn columns_length_mismatch_panics() {
        let xs = [1.0];
        let ys = [1.0, 2.0];
        let zs = [1.0];
        let _ = Columns::new(&xs, &ys, &zs);
    }
}
