//! The even planar grid — the paper's space-partitioning structure
//! (§3.2.1-3.2.3, Figs. 2-3) in CSR form.
//!
//! Construction mirrors the paper's GPU pipeline step by step:
//!
//! 1. bounding box via parallel minmax (`thrust::minmax_element` analog);
//! 2. square cell width from Eq. 2 — the expected nearest-neighbor distance
//!    of a random pattern — times a tunable factor (ablation A1);
//! 3. `nCol = (maxX - minX + w) / w`, `nRow = (maxY - minY + w) / w`
//!    (the paper's exact formulas);
//! 4. per-point cell ids `gid = row * nCol + col` in parallel;
//! 5. stable radix `sort_by_key(gid, point_index)`;
//! 6. segmented reduction/scan (counts + segment heads) folded into a dense
//!    `cell_start` CSR offset array;
//! 7. gather of the coordinate arrays into cell order, so a cell's points
//!    are one contiguous cache-friendly slice.

use crate::error::{Error, Result};
use crate::geom::{Aabb, PointSet};
use crate::pool::{self, Pool};
use crate::primitives::{reduce, scan, sort};

/// Grid construction parameters.
#[derive(Debug, Clone, Copy)]
pub struct GridConfig {
    /// Multiplier on the Eq.-2 cell width (1.0 = the paper's choice).
    /// Larger cells mean fewer, fuller cells; ablation A1 sweeps this.
    pub cell_width_factor: f64,
    /// Optional explicit cell width (overrides Eq. 2 entirely).
    pub explicit_cell_width: Option<f64>,
    /// Hard cap on cell count (guards against degenerate tiny widths).
    pub max_cells: usize,
}

impl Default for GridConfig {
    fn default() -> Self {
        GridConfig {
            cell_width_factor: 1.0,
            explicit_cell_width: None,
            max_cells: 1 << 26, // 64M cells ~ 256 MB of offsets
        }
    }
}

/// The even grid over a point set, with points stored cell-contiguously.
#[derive(Debug, Clone)]
pub struct EvenGrid {
    bounds: Aabb,
    cell_width: f64,
    n_rows: usize,
    n_cols: usize,
    /// CSR offsets: points of cell `c` sit at `sorted index start[c]..start[c+1]`.
    cell_start: Vec<u32>,
    /// Original index of each point, in cell-sorted order.
    point_index: Vec<u32>,
    /// Coordinates/values gathered into cell-sorted order.
    xs: Vec<f64>,
    ys: Vec<f64>,
    zs: Vec<f64>,
}

impl EvenGrid {
    /// Build the grid over `points`, optionally extending the partitioned
    /// region to also cover `extra_bounds` (the paper partitions the region
    /// enclosing *both* data and interpolated points; a serving deployment
    /// passes the expected query region here).
    pub fn build(points: &PointSet, extra_bounds: Option<Aabb>, cfg: &GridConfig) -> Result<Self> {
        Self::build_on(pool::global(), points, extra_bounds, cfg)
    }

    /// [`EvenGrid::build`] on an explicit pool (tests/benches).
    pub fn build_on(
        pool: &Pool,
        points: &PointSet,
        extra_bounds: Option<Aabb>,
        cfg: &GridConfig,
    ) -> Result<Self> {
        let n = points.len();
        if n == 0 {
            return Err(Error::InvalidArgument("cannot build grid over empty point set".into()));
        }

        // 1. bounding box (parallel minmax, Thrust analog)
        let (min_x, max_x) = reduce::parallel_minmax(pool, &points.xs).unwrap();
        let (min_y, max_y) = reduce::parallel_minmax(pool, &points.ys).unwrap();
        let mut bounds = Aabb::new(min_x, min_y, max_x, max_y);
        if let Some(extra) = extra_bounds {
            if !extra.is_empty() {
                bounds = bounds.union(&extra);
            }
        }

        // 2. cell width: Eq. 2 (expected NN distance) * factor
        let area = bounds.area().max(f64::MIN_POSITIVE);
        let r_exp = 1.0 / (2.0 * ((n as f64) / area).sqrt());
        let mut cell_width = match cfg.explicit_cell_width {
            Some(w) => w,
            None => r_exp * cfg.cell_width_factor,
        };
        if !cell_width.is_finite() || cell_width <= 0.0 {
            // degenerate geometry (all points coincident): one cell
            cell_width = 1.0;
        }

        // 3. rows/cols per the paper's integer formulas, capped
        let mut n_cols = ((bounds.width() + cell_width) / cell_width) as usize;
        let mut n_rows = ((bounds.height() + cell_width) / cell_width) as usize;
        n_cols = n_cols.max(1);
        n_rows = n_rows.max(1);
        while n_cols * n_rows > cfg.max_cells {
            cell_width *= 2.0;
            n_cols = (((bounds.width() + cell_width) / cell_width) as usize).max(1);
            n_rows = (((bounds.height() + cell_width) / cell_width) as usize).max(1);
        }
        let n_cells = n_rows * n_cols;

        // 4. per-point cell ids (parallel; one "GPU thread" per point)
        let mut keys = vec![0u32; n];
        {
            let xs = &points.xs;
            let ys = &points.ys;
            let keys_ptr = SendPtr(keys.as_mut_ptr());
            pool.parallel_for(n, 1 << 14, |r| {
                let kp = keys_ptr;
                for i in r {
                    let (row, col) =
                        locate(xs[i], ys[i], &bounds, cell_width, n_rows, n_cols);
                    // SAFETY: keys has n slots and parallel_for hands
                    // each worker a disjoint range of i, so every write
                    // is in-bounds and race-free
                    unsafe { *kp.0.add(i) = (row * n_cols + col) as u32 };
                }
            });
        }

        // 5. stable sort of point indices by cell id
        let mut sorted_keys = keys;
        let mut point_index: Vec<u32> = (0..n as u32).collect();
        sort::radix_sort_by_key(pool, &mut sorted_keys, &mut point_index);

        // 6. CSR offsets from the segmented counts: scatter counts into a
        //    dense per-cell array, then exclusive scan (Fig. 3)
        let (unique_cells, counts) = reduce::counts_by_key(&sorted_keys);
        let mut dense_counts = vec![0u32; n_cells];
        for (&cell, &count) in unique_cells.iter().zip(&counts) {
            dense_counts[cell as usize] = count;
        }
        let mut cell_start = vec![0u32; n_cells + 1];
        let total = scan::exclusive_scan(pool, &dense_counts, &mut cell_start[..n_cells]);
        cell_start[n_cells] = total;
        debug_assert_eq!(total as usize, n);

        // 7. gather coordinates into cell order
        let mut xs = vec![0f64; n];
        let mut ys = vec![0f64; n];
        let mut zs = vec![0f64; n];
        {
            let (gx, gy, gz) =
                (SendPtr(xs.as_mut_ptr()), SendPtr(ys.as_mut_ptr()), SendPtr(zs.as_mut_ptr()));
            let idx = &point_index;
            let sx = &points.xs;
            let sy = &points.ys;
            let sz = &points.zs;
            pool.parallel_for(n, 1 << 14, |r| {
                let (gx, gy, gz) = (gx, gy, gz);
                for i in r {
                    let src = idx[i] as usize;
                    // SAFETY: the gathered vectors have n slots and the
                    // ranges partition 0..n, so each i is written once
                    // by one worker; src is a permutation index < n
                    unsafe {
                        *gx.0.add(i) = sx[src];
                        *gy.0.add(i) = sy[src];
                        *gz.0.add(i) = sz[src];
                    }
                }
            });
        }

        Ok(EvenGrid {
            bounds,
            cell_width,
            n_rows,
            n_cols,
            cell_start,
            point_index,
            xs,
            ys,
            zs,
        })
    }

    /// Region the grid partitions.
    pub fn bounds(&self) -> Aabb {
        self.bounds
    }

    /// Square cell width.
    pub fn cell_width(&self) -> f64 {
        self.cell_width
    }

    /// Grid dimensions (rows, cols).
    pub fn dims(&self) -> (usize, usize) {
        (self.n_rows, self.n_cols)
    }

    /// Total number of cells.
    pub fn n_cells(&self) -> usize {
        self.n_rows * self.n_cols
    }

    /// Number of indexed points.
    pub fn n_points(&self) -> usize {
        self.point_index.len()
    }

    /// (row, col) of the cell containing (x, y), clamped to the grid.
    pub fn locate(&self, x: f64, y: f64) -> (usize, usize) {
        locate(x, y, &self.bounds, self.cell_width, self.n_rows, self.n_cols)
    }

    /// Cell-sorted coordinate arrays (for bulk export to the runtime).
    pub fn sorted_coords(&self) -> (&[f64], &[f64], &[f64]) {
        (&self.xs, &self.ys, &self.zs)
    }

    /// Original point index of each cell-sorted slot.
    pub fn sorted_index(&self) -> &[u32] {
        &self.point_index
    }

    /// The points of one cell as (xs, ys, zs, original_indices) slices.
    pub fn cell_points(&self, row: usize, col: usize) -> (&[f64], &[f64], &[f64], &[u32]) {
        let c = row * self.n_cols + col;
        let a = self.cell_start[c] as usize;
        let b = self.cell_start[c + 1] as usize;
        (&self.xs[a..b], &self.ys[a..b], &self.zs[a..b], &self.point_index[a..b])
    }

    /// Number of points in one cell.
    pub fn cell_count(&self, row: usize, col: usize) -> usize {
        let c = row * self.n_cols + col;
        (self.cell_start[c + 1] - self.cell_start[c]) as usize
    }

    /// Visit every cell of the square *ring* at Chebyshev distance `level`
    /// from (row, col): `level == 0` is the center cell itself.  Cells
    /// outside the grid are skipped.  Returns the number of points seen.
    pub fn for_ring<F>(&self, row: usize, col: usize, level: usize, mut f: F) -> usize
    where
        F: FnMut(&[f64], &[f64], &[f64], &[u32]),
    {
        let (r0, c0) = (row as isize, col as isize);
        let lv = level as isize;
        let mut seen = 0usize;
        let visit = |r: isize, c: isize, f: &mut F, seen: &mut usize| {
            if r < 0 || c < 0 || r >= self.n_rows as isize || c >= self.n_cols as isize {
                return;
            }
            let (xs, ys, zs, idx) = self.cell_points(r as usize, c as usize);
            *seen += xs.len();
            if !xs.is_empty() {
                f(xs, ys, zs, idx);
            }
        };
        if level == 0 {
            visit(r0, c0, &mut f, &mut seen);
            return seen;
        }
        // top and bottom rows of the ring
        for c in (c0 - lv)..=(c0 + lv) {
            visit(r0 - lv, c, &mut f, &mut seen);
            visit(r0 + lv, c, &mut f, &mut seen);
        }
        // left and right columns, excluding corners already visited
        for r in (r0 - lv + 1)..=(r0 + lv - 1) {
            visit(r, c0 - lv, &mut f, &mut seen);
            visit(r, c0 + lv, &mut f, &mut seen);
        }
        seen
    }

    /// Row-clipped [`EvenGrid::for_ring`]: visit the same ring cells in the
    /// same order, skipping any cell whose row lies outside
    /// `[row_lo, row_hi)`.  The visit sequence is exactly the `for_ring`
    /// sequence restricted to the clip band — the property the sharded
    /// stage-1 bit-identity proof rests on (`crate::shard`): tied
    /// candidates inside the band keep their relative offer order.
    pub fn for_ring_rows<F>(
        &self,
        row: usize,
        col: usize,
        level: usize,
        row_lo: usize,
        row_hi: usize,
        mut f: F,
    ) -> usize
    where
        F: FnMut(&[f64], &[f64], &[f64], &[u32]),
    {
        if row_lo == 0 && row_hi >= self.n_rows {
            return self.for_ring(row, col, level, f);
        }
        let (r0, c0) = (row as isize, col as isize);
        let lv = level as isize;
        let mut seen = 0usize;
        let visit = |r: isize, c: isize, f: &mut F, seen: &mut usize| {
            if r < 0
                || c < 0
                || r >= self.n_rows as isize
                || c >= self.n_cols as isize
                || r < row_lo as isize
                || r >= row_hi as isize
            {
                return;
            }
            let (xs, ys, zs, idx) = self.cell_points(r as usize, c as usize);
            *seen += xs.len();
            if !xs.is_empty() {
                f(xs, ys, zs, idx);
            }
        };
        if level == 0 {
            visit(r0, c0, &mut f, &mut seen);
            return seen;
        }
        for c in (c0 - lv)..=(c0 + lv) {
            visit(r0 - lv, c, &mut f, &mut seen);
            visit(r0 + lv, c, &mut f, &mut seen);
        }
        for r in (r0 - lv + 1)..=(r0 + lv - 1) {
            visit(r, c0 - lv, &mut f, &mut seen);
            visit(r, c0 + lv, &mut f, &mut seen);
        }
        seen
    }

    /// True when the square of Chebyshev radius `level` around (row, col)
    /// covers the whole grid — no point lies outside it.
    pub fn ring_exhausted(&self, row: usize, col: usize, level: usize) -> bool {
        let lv = level as isize;
        let (r, c) = (row as isize, col as isize);
        r - lv < 0
            && c - lv < 0
            && r + lv >= self.n_rows as isize - 1
            && c + lv >= self.n_cols as isize - 1
    }

    /// Lower bound on the distance from (x, y) to any cell *outside* the
    /// square of Chebyshev radius `level` around its own cell.  `None` when
    /// the square already covers the whole grid.  This powers the exact
    /// kNN termination criterion.
    pub fn min_dist_beyond(&self, x: f64, y: f64, row: usize, col: usize, level: usize) -> Option<f64> {
        if self.ring_exhausted(row, col, level) {
            return None;
        }
        let w = self.cell_width;
        let lv = level as f64;
        let mut d = f64::INFINITY;
        // distance to the 4 edges of the visited square, ignoring edges
        // beyond the grid boundary (nothing lives there)
        let left_edge = self.bounds.min_x + (col as f64 - lv) * w;
        let right_edge = self.bounds.min_x + (col as f64 + lv + 1.0) * w;
        let bottom_edge = self.bounds.min_y + (row as f64 - lv) * w;
        let top_edge = self.bounds.min_y + (row as f64 + lv + 1.0) * w;
        if col as isize - level as isize >= 0 {
            d = d.min(x - left_edge);
        }
        if col + level + 1 < self.n_cols {
            d = d.min(right_edge - x);
        }
        if row as isize - level as isize >= 0 {
            d = d.min(y - bottom_edge);
        }
        if row + level + 1 < self.n_rows {
            d = d.min(top_edge - y);
        }
        Some(d.max(0.0))
    }

    /// Histogram statistics over cell occupancy (diagnostics / DESIGN.md).
    pub fn occupancy_stats(&self) -> GridStats {
        let n_cells = self.n_cells();
        let mut empty = 0usize;
        let mut max = 0usize;
        for c in 0..n_cells {
            let cnt = (self.cell_start[c + 1] - self.cell_start[c]) as usize;
            if cnt == 0 {
                empty += 1;
            }
            max = max.max(cnt);
        }
        GridStats {
            n_cells,
            n_points: self.n_points(),
            empty_cells: empty,
            max_per_cell: max,
            mean_per_cell: self.n_points() as f64 / n_cells as f64,
        }
    }
}

/// Occupancy summary of a built grid.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GridStats {
    pub n_cells: usize,
    pub n_points: usize,
    pub empty_cells: usize,
    pub max_per_cell: usize,
    pub mean_per_cell: f64,
}

/// Cell coordinates of (x, y) — the paper's `(p - min) / w` with clamping so
/// out-of-region queries fall into the nearest border cell.
#[inline]
fn locate(x: f64, y: f64, b: &Aabb, w: f64, n_rows: usize, n_cols: usize) -> (usize, usize) {
    let col = ((x - b.min_x) / w).floor() as isize;
    let row = ((y - b.min_y) / w).floor() as isize;
    let col = col.clamp(0, n_cols as isize - 1) as usize;
    let row = row.clamp(0, n_rows as isize - 1) as usize;
    (row, col)
}

#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: the wrapped pointer is only dereferenced inside scoped-thread
// loops that partition the output into disjoint index ranges per worker
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: as above — shared across workers, written at disjoint indices
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;
    use crate::workload;

    fn grid_for(n: usize, seed: u64) -> (PointSet, EvenGrid) {
        let pts = workload::uniform_square(n, 100.0, seed);
        let grid = EvenGrid::build(&pts, None, &GridConfig::default()).unwrap();
        (pts, grid)
    }

    #[test]
    fn empty_set_rejected() {
        let pts = PointSet::default();
        assert!(EvenGrid::build(&pts, None, &GridConfig::default()).is_err());
    }

    #[test]
    fn csr_partitions_all_points() {
        let (pts, grid) = grid_for(5000, 1);
        assert_eq!(grid.n_points(), 5000);
        // cell_start is monotone and ends at n
        let cs = &grid.cell_start;
        assert_eq!(cs[0], 0);
        assert_eq!(*cs.last().unwrap() as usize, pts.len());
        for w in cs.windows(2) {
            assert!(w[0] <= w[1]);
        }
        // point_index is a permutation
        let mut seen = grid.point_index.clone();
        seen.sort_unstable();
        assert_eq!(seen, (0..5000u32).collect::<Vec<_>>());
    }

    #[test]
    fn every_point_is_in_its_cell() {
        let (pts, grid) = grid_for(2000, 2);
        let (n_rows, n_cols) = grid.dims();
        for row in 0..n_rows {
            for col in 0..n_cols {
                let (xs, ys, _, idx) = grid.cell_points(row, col);
                for j in 0..xs.len() {
                    let (r2, c2) = grid.locate(xs[j], ys[j]);
                    assert_eq!((r2, c2), (row, col));
                    // gathered coords match the original arrays
                    let orig = idx[j] as usize;
                    assert_eq!(xs[j], pts.xs[orig]);
                    assert_eq!(ys[j], pts.ys[orig]);
                }
            }
        }
    }

    #[test]
    fn eq2_cell_width() {
        let (pts, grid) = grid_for(10_000, 3);
        let b = pts.bounds();
        let expect = 1.0 / (2.0 * (10_000.0 / b.area()).sqrt());
        assert!((grid.cell_width() - expect).abs() < 1e-12);
        // Eq.-2 width -> mean occupancy ~ 0.25 points/cell
        let stats = grid.occupancy_stats();
        assert!(stats.mean_per_cell > 0.15 && stats.mean_per_cell < 0.35,
                "{stats:?}");
    }

    #[test]
    fn explicit_cell_width_respected() {
        let pts = workload::uniform_square(500, 100.0, 4);
        let cfg = GridConfig { explicit_cell_width: Some(10.0), ..Default::default() };
        let grid = EvenGrid::build(&pts, None, &cfg).unwrap();
        assert_eq!(grid.cell_width(), 10.0);
        let (rows, cols) = grid.dims();
        assert!(rows >= 10 && rows <= 11, "{rows}");
        assert!(cols >= 10 && cols <= 11, "{cols}");
    }

    #[test]
    fn max_cells_cap_enforced() {
        let pts = workload::uniform_square(1000, 100.0, 5);
        let cfg = GridConfig {
            explicit_cell_width: Some(1e-4), // would be ~1e12 cells
            max_cells: 4096,
            ..Default::default()
        };
        let grid = EvenGrid::build(&pts, None, &cfg).unwrap();
        assert!(grid.n_cells() <= 4096);
        assert_eq!(grid.n_points(), 1000);
    }

    #[test]
    fn locate_clamps_outside_queries() {
        let (_, grid) = grid_for(100, 6);
        let (n_rows, n_cols) = grid.dims();
        assert_eq!(grid.locate(-1e9, -1e9), (0, 0));
        assert_eq!(grid.locate(1e9, 1e9), (n_rows - 1, n_cols - 1));
    }

    #[test]
    fn ring_visits_each_cell_once() {
        let (_, grid) = grid_for(3000, 7);
        let (n_rows, n_cols) = grid.dims();
        let (r0, c0) = (n_rows / 2, n_cols / 2);
        // union of rings 0..=L == square of radius L, counted exactly once
        let mut total = 0usize;
        for level in 0..=3usize {
            total += grid.for_ring(r0, c0, level, |_, _, _, _| {});
        }
        let mut expect = 0usize;
        for r in r0.saturating_sub(3)..=(r0 + 3).min(n_rows - 1) {
            for c in c0.saturating_sub(3)..=(c0 + 3).min(n_cols - 1) {
                expect += grid.cell_count(r, c);
            }
        }
        assert_eq!(total, expect);
    }

    #[test]
    fn ring_exhaustion() {
        let (_, grid) = grid_for(200, 8);
        let (n_rows, n_cols) = grid.dims();
        let max_dim = n_rows.max(n_cols);
        assert!(!grid.ring_exhausted(0, 0, 0));
        assert!(grid.ring_exhausted(0, 0, max_dim));
        assert!(grid.ring_exhausted(n_rows / 2, n_cols / 2, max_dim));
    }

    #[test]
    fn min_dist_beyond_is_lower_bound() {
        let (pts, grid) = grid_for(4000, 9);
        let mut rng = Pcg32::seeded(99);
        for _ in 0..200 {
            let qx = rng.uniform(0.0, 100.0);
            let qy = rng.uniform(0.0, 100.0);
            let (row, col) = grid.locate(qx, qy);
            for level in 0..4usize {
                let Some(bound) = grid.min_dist_beyond(qx, qy, row, col, level) else {
                    continue;
                };
                // every point OUTSIDE the level-square must be at least
                // `bound` away
                for i in 0..pts.len() {
                    let (r, c) = grid.locate(pts.xs[i], pts.ys[i]);
                    let cheby =
                        (r as isize - row as isize).abs().max((c as isize - col as isize).abs());
                    if cheby as usize > level {
                        let d = crate::geom::dist2(qx, qy, pts.xs[i], pts.ys[i]).sqrt();
                        assert!(
                            d >= bound - 1e-9,
                            "point {i} at cheby {cheby} dist {d} < bound {bound} (level {level})"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn coincident_points_degenerate_geometry() {
        let mut pts = PointSet::default();
        for _ in 0..32 {
            pts.push(5.0, 5.0, 1.0);
        }
        let grid = EvenGrid::build(&pts, None, &GridConfig::default()).unwrap();
        assert_eq!(grid.n_points(), 32);
        assert_eq!(grid.dims(), (1, 1));
    }

    #[test]
    fn extra_bounds_extend_region() {
        let pts = workload::uniform_square(500, 10.0, 11);
        let extra = Aabb::new(-10.0, -10.0, 30.0, 30.0);
        let grid = EvenGrid::build(&pts, Some(extra), &GridConfig::default()).unwrap();
        assert!(grid.bounds().contains(-10.0, -10.0));
        assert!(grid.bounds().contains(30.0, 30.0));
    }
}
