//! Minimal JSON reader/writer (serde is unavailable in the offline vendor
//! set).  Supports the full JSON grammar minus exotic number forms; used by
//! the artifact manifest ([`crate::runtime`]) and the TCP service protocol
//! ([`crate::service`]).

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value.  Objects use a BTreeMap so serialization is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    /// Parse a complete JSON document (trailing whitespace allowed).
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    /// Serialize compactly.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    x.write(out);
                }
                out.push(']');
            }
            Json::Obj(map) => {
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 => Some(*n as usize),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// `obj[key]`, or Json::Null when missing / not an object.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        self.as_obj().and_then(|m| m.get(key)).unwrap_or(&NULL)
    }

    /// Builder: object from pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    /// Builder: array of f64.
    pub fn num_array(xs: &[f64]) -> Json {
        Json::Arr(xs.iter().map(|&x| Json::Num(x)).collect())
    }

    /// Extract an array of f64 (error if any element is non-numeric).
    pub fn to_f64_vec(&self) -> Result<Vec<f64>> {
        let arr = self
            .as_arr()
            .ok_or_else(|| Error::Json { offset: 0, message: "expected array".into() })?;
        arr.iter()
            .map(|v| {
                v.as_f64()
                    .ok_or_else(|| Error::Json { offset: 0, message: "expected number".into() })
            })
            .collect()
    }
}

/// Append a JSON number exactly as [`Json::Num`] serializes it: integral
/// values inside the exactly-representable i64 window print without a
/// fraction, everything else via Rust's shortest-roundtrip `{n}` format.
/// The zero-copy protocol writers ([`crate::service::protocol`]) call this
/// directly so their hand-built frames stay byte-identical to Json-built
/// ones.
pub fn write_num(out: &mut String, n: f64) {
    if n.fract() == 0.0 && n.abs() < 9e15 {
        let _ = write!(out, "{}", n as i64);
    } else {
        let _ = write!(out, "{n}");
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> Error {
        Error::Json { offset: self.pos, message: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<()> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("bad utf8 in number"))?;
        text.parse::<f64>().map(Json::Num).map_err(|_| self.err("invalid number"))
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.pos + 4 >= self.bytes.len() {
                                return Err(self.err("truncated \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.bytes[self.pos + 1..self.pos + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            // (surrogate pairs outside BMP are not needed by
                            // our protocol; map unpaired surrogates to U+FFFD)
                            s.push(char::from_u32(code).unwrap_or('\u{FFFD}'));
                            self.pos += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let rest = std::str::from_utf8(&self.bytes[self.pos..])
                        .map_err(|_| self.err("invalid utf8"))?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.pos += 1;
                }
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        let arr = v.get("a").as_arr().unwrap();
        assert_eq!(arr.len(), 3);
        assert_eq!(arr[2].get("b"), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        for bad in ["", "{", "[1,", "{\"a\" 1}", "tru", "1 2", "{\"a\":}"] {
            assert!(Json::parse(bad).is_err(), "{bad:?} parsed");
        }
    }

    #[test]
    fn roundtrip() {
        let cases = [
            r#"{"a":[1,2,3],"b":{"c":"d"},"e":null,"f":true,"g":-1.25}"#,
            r#"[[],{},"",0]"#,
        ];
        for c in cases {
            let v = Json::parse(c).unwrap();
            let s = v.to_string();
            assert_eq!(Json::parse(&s).unwrap(), v, "{c}");
        }
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("quote\" slash\\ newline\n tab\t".into());
        let s = v.to_string();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""Aé""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé"));
    }

    #[test]
    fn integers_serialize_without_fraction() {
        assert_eq!(Json::Num(5.0).to_string(), "5");
        assert_eq!(Json::Num(5.5).to_string(), "5.5");
    }

    #[test]
    fn typed_accessors() {
        let v = Json::parse(r#"{"n": 3, "s": "x", "b": false, "a": [1.5]}"#).unwrap();
        assert_eq!(v.get("n").as_usize(), Some(3));
        assert_eq!(v.get("missing").as_usize(), None);
        assert_eq!(v.get("b").as_bool(), Some(false));
        assert_eq!(v.get("a").to_f64_vec().unwrap(), vec![1.5]);
        assert!(v.get("s").to_f64_vec().is_err());
    }

    #[test]
    fn num_array_builder() {
        let v = Json::num_array(&[1.0, 2.5]);
        assert_eq!(v.to_string(), "[1,2.5]");
    }

    #[test]
    fn write_num_matches_json_num() {
        let cases = [
            0.0,
            -0.0,
            5.0,
            -5.0,
            5.5,
            -1.25,
            1e-12,
            8.9e15,
            9.1e15, // above the i64-safe window: keeps float form
            f64::MAX,
            1234567890.0,
            0.1 + 0.2, // shortest-roundtrip form
        ];
        for n in cases {
            let mut s = String::new();
            write_num(&mut s, n);
            assert_eq!(s, Json::Num(n).to_string(), "n={n}");
        }
    }
}
