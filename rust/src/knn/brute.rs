//! Brute-force kNN — the *original* algorithm's search (paper §3.1,
//! Mei et al. 2015): for every query, stream all m data points through a
//! k-buffer.  O(n·m) total; trivially parallel across queries.

use crate::geom::dist2;
use crate::knn::kbuffer::KBuffer;
use crate::pool::{self, Pool};

/// Average distance to the k nearest data points for every query (Eq. 3),
/// by exhaustive scan.  Parallel across queries.
pub fn brute_knn_avg_distances(
    dx: &[f64],
    dy: &[f64],
    queries: &[(f64, f64)],
    k: usize,
) -> Vec<f64> {
    brute_knn_avg_distances_on(pool::global(), dx, dy, queries, k)
}

/// [`brute_knn_avg_distances`] on an explicit pool.
pub fn brute_knn_avg_distances_on(
    pool: &Pool,
    dx: &[f64],
    dy: &[f64],
    queries: &[(f64, f64)],
    k: usize,
) -> Vec<f64> {
    assert_eq!(dx.len(), dy.len());
    let mut out = vec![0f64; queries.len()];
    pool.for_each_slice_mut(&mut out, 64, |offset, chunk| {
        let mut buf = KBuffer::new(k);
        for (j, slot) in chunk.iter_mut().enumerate() {
            let (qx, qy) = queries[offset + j];
            buf.clear();
            for i in 0..dx.len() {
                buf.insert(dist2(qx, qy, dx[i], dy[i]));
            }
            *slot = buf.avg_distance();
        }
    });
    out
}

/// The k smallest squared distances per query (ascending) — the raw
/// k-buffer contents, used by property tests as the exactness oracle.
pub fn brute_knn_topk(
    pool: &Pool,
    dx: &[f64],
    dy: &[f64],
    queries: &[(f64, f64)],
    k: usize,
) -> Vec<Vec<f64>> {
    assert_eq!(dx.len(), dy.len());
    let results = pool.map_ranges(queries.len(), 64, |r| {
        let mut local = Vec::with_capacity(r.end - r.start);
        let mut buf = KBuffer::new(k);
        for &(qx, qy) in &queries[r] {
            buf.clear();
            for i in 0..dx.len() {
                buf.insert(dist2(qx, qy, dx[i], dy[i]));
            }
            local.push(buf.as_slice().to_vec());
        }
        local
    });
    results.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::pool::Pool;
    use crate::workload;

    #[test]
    fn tiny_handmade_case() {
        // data on a line, query at origin: nearest are 1, 2 -> avg 1.5
        let dx = [1.0, -2.0, 5.0, 10.0];
        let dy = [0.0, 0.0, 0.0, 0.0];
        let got = brute_knn_avg_distances(&dx, &dy, &[(0.0, 0.0)], 2);
        assert!((got[0] - 1.5).abs() < 1e-12);
    }

    #[test]
    fn k_equals_m_uses_all_points() {
        let dx = [3.0, 0.0];
        let dy = [4.0, 1.0];
        let got = brute_knn_avg_distances(&dx, &dy, &[(0.0, 0.0)], 2);
        assert!((got[0] - 3.0).abs() < 1e-12); // (5 + 1)/2
    }

    #[test]
    fn k_larger_than_m_averages_available() {
        // paper's kernels assume m >= k; we degrade gracefully
        let dx = [3.0];
        let dy = [4.0];
        let got = brute_knn_avg_distances(&dx, &dy, &[(0.0, 0.0)], 8);
        assert!((got[0] - 5.0).abs() < 1e-12);
    }

    #[test]
    fn matches_full_sort_reference() {
        let pts = workload::uniform_square(400, 50.0, 21);
        let queries: Vec<(f64, f64)> =
            workload::uniform_square(37, 50.0, 22).xy();
        let k = 10;
        let got = brute_knn_avg_distances(&pts.xs, &pts.ys, &queries, k);
        for (qi, &(qx, qy)) in queries.iter().enumerate() {
            let mut ds: Vec<f64> = (0..pts.len())
                .map(|i| dist2(qx, qy, pts.xs[i], pts.ys[i]).sqrt())
                .collect();
            ds.sort_by(|a, b| a.partial_cmp(b).unwrap());
            let want = ds[..k].iter().sum::<f64>() / k as f64;
            assert!((got[qi] - want).abs() < 1e-9, "query {qi}");
        }
    }

    #[test]
    fn pool_width_invariant() {
        let pts = workload::uniform_square(300, 10.0, 23);
        let queries: Vec<(f64, f64)> = workload::uniform_square(100, 10.0, 24).xy();
        let a = brute_knn_avg_distances_on(&Pool::new(1), &pts.xs, &pts.ys, &queries, 5);
        let b = brute_knn_avg_distances_on(&Pool::new(4), &pts.xs, &pts.ys, &queries, 5);
        assert_eq!(a, b);
    }

    #[test]
    fn topk_is_sorted_prefix() {
        let pts = workload::uniform_square(200, 10.0, 25);
        let queries: Vec<(f64, f64)> = workload::uniform_square(20, 10.0, 26).xy();
        let top = brute_knn_topk(&Pool::new(2), &pts.xs, &pts.ys, &queries, 6);
        assert_eq!(top.len(), queries.len());
        for row in &top {
            assert_eq!(row.len(), 6);
            for w in row.windows(2) {
                assert!(w[0] <= w[1]);
            }
        }
    }
}
