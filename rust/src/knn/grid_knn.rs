//! Grid-accelerated kNN — the paper's *fast kNN search* (§3.2.4, Fig. 5),
//! the core contribution of the improved algorithm.
//!
//! Per query:
//!
//! 1. locate the query's cell (row/col arithmetic, clamped);
//! 2. iteratively expand square rings of cells until at least k candidate
//!    points have been seen;
//! 3. apply a termination rule:
//!    * [`RingRule::PaperPlusOne`] — the paper's Remark: after the level L
//!      at which ≥ k candidates exist, expand exactly one more ring so
//!      near-boundary neighbors in ring L+1 are not missed (Fig. 4);
//!    * [`RingRule::Exact`] (default) — keep expanding until no cell
//!      outside the visited square can hold a point closer than the
//!      current k-th distance (lower bound from
//!      [`crate::grid::EvenGrid::min_dist_beyond`]).  This is provably
//!      exact for any query position and point distribution; on the
//!      paper's uniform workloads it visits the same rings as the paper's
//!      rule almost always (ablation A4 quantifies the difference).
//! 4. insert candidate squared distances into a [`KBuffer`]; sqrt only in
//!    the Eq.-3 epilogue.
//!
//! Parallel across queries; zero allocation inside the per-query loop.

use crate::geom::dist2;
use crate::grid::EvenGrid;
use crate::knn::kbuffer::KBuffer;
use crate::pool::{self, Pool};

/// Ring-expansion termination rule (ablation A4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum RingRule {
    /// Provably exact: expand while an unvisited cell could beat the k-th
    /// distance.
    #[default]
    Exact,
    /// The paper's heuristic: first level with ≥ k candidates, plus one.
    PaperPlusOne,
}

impl RingRule {
    /// Wire/CLI tag (protocol v2 `ring` field).
    pub fn tag(&self) -> &'static str {
        match self {
            RingRule::Exact => "exact",
            RingRule::PaperPlusOne => "paper+1",
        }
    }
}

impl std::str::FromStr for RingRule {
    type Err = crate::error::Error;
    fn from_str(s: &str) -> std::result::Result<Self, Self::Err> {
        match s {
            "exact" => Ok(RingRule::Exact),
            "paper+1" | "paper_plus_one" => Ok(RingRule::PaperPlusOne),
            other => Err(crate::error::Error::InvalidArgument(format!(
                "unknown ring rule '{other}' (expected 'exact' or 'paper+1')"
            ))),
        }
    }
}

/// Grid kNN configuration.
#[derive(Debug, Clone, Copy)]
pub struct GridKnnConfig {
    /// Number of nearest neighbors (the paper uses k = 10).
    pub k: usize,
    /// Termination rule.
    pub rule: RingRule,
}

impl Default for GridKnnConfig {
    fn default() -> Self {
        GridKnnConfig { k: 10, rule: RingRule::Exact }
    }
}

/// Search statistics (perf diagnostics; aggregated by benches).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct KnnStats {
    /// Total candidate points whose distance was computed.
    pub candidates: u64,
    /// Total rings visited across queries.
    pub rings: u64,
    /// Max ring level reached by any query.
    pub max_level: usize,
}

/// Average distance to the k nearest data points for each query (Eq. 3),
/// via grid local search.  Parallel across queries.
pub fn grid_knn_avg_distances(
    grid: &EvenGrid,
    queries: &[(f64, f64)],
    cfg: &GridKnnConfig,
) -> Vec<f64> {
    grid_knn_avg_distances_on(pool::global(), grid, queries, cfg).0
}

/// [`grid_knn_avg_distances`] on an explicit pool, returning search stats.
pub fn grid_knn_avg_distances_on(
    pool: &Pool,
    grid: &EvenGrid,
    queries: &[(f64, f64)],
    cfg: &GridKnnConfig,
) -> (Vec<f64>, KnnStats) {
    let mut out = vec![0f64; queries.len()];
    let stats_parts: Vec<KnnStats> = {
        let out_ptr = SendPtr(out.as_mut_ptr());
        pool.map_ranges(queries.len(), 64, |r| {
            let op = out_ptr;
            let mut buf = KBuffer::new(cfg.k);
            let mut stats = KnnStats::default();
            for qi in r {
                let (qx, qy) = queries[qi];
                let avg = single_query(grid, qx, qy, cfg, &mut buf, &mut stats);
                // SAFETY: out has queries.len() slots and map_ranges
                // hands each worker a disjoint qi range, so every write
                // is in-bounds and race-free
                unsafe { *op.0.add(qi) = avg };
            }
            stats
        })
    };
    let mut stats = KnnStats::default();
    for s in stats_parts {
        stats.candidates += s.candidates;
        stats.rings += s.rings;
        stats.max_level = stats.max_level.max(s.max_level);
    }
    (out, stats)
}

/// The k smallest squared distances per query — exactness oracle interface
/// mirroring [`crate::knn::brute::brute_knn_topk`].
pub fn grid_knn_topk(
    pool: &Pool,
    grid: &EvenGrid,
    queries: &[(f64, f64)],
    cfg: &GridKnnConfig,
) -> Vec<Vec<f64>> {
    let results = pool.map_ranges(queries.len(), 64, |r| {
        let mut buf = KBuffer::new(cfg.k);
        let mut stats = KnnStats::default();
        let mut local = Vec::with_capacity(r.end - r.start);
        for qi in r {
            let (qx, qy) = queries[qi];
            single_query(grid, qx, qy, cfg, &mut buf, &mut stats);
            local.push(buf.as_slice().to_vec());
        }
        local
    });
    results.into_iter().flatten().collect()
}

/// Neighbor lists for the local-weighting extension: for each query, the
/// `n_neighbors` nearest data points' **original indices** (row-major
/// `(queries.len(), n_neighbors)`, `u32::MAX`-padded when fewer points
/// exist) plus the Eq.-3 average distance over the first `k_alpha` of them.
///
/// One grid pass serves both stage-1 products: the alpha statistic needs
/// k distances, the local stage 2 needs N >= k neighbor ids — the buffer
/// is sized to `max(k_alpha, n_neighbors)` and searched once.
pub fn grid_knn_neighbors(
    pool: &Pool,
    grid: &EvenGrid,
    queries: &[(f64, f64)],
    n_neighbors: usize,
    k_alpha: usize,
    rule: RingRule,
) -> (Vec<u32>, Vec<f64>) {
    assert!(n_neighbors >= 1 && k_alpha >= 1);
    let width = n_neighbors.max(k_alpha);
    let mut idx_out = vec![u32::MAX; queries.len() * n_neighbors];
    let mut r_obs = vec![0f64; queries.len()];
    {
        let idx_ptr = SendPtr(idx_out.as_mut_ptr());
        let r_ptr = SendPtr(r_obs.as_mut_ptr());
        pool.parallel_for(queries.len(), 64, |range| {
            let ip = idx_ptr;
            let rp = r_ptr;
            let mut buf = crate::knn::kbuffer::KBufferIdx::new(width);
            let cfg = GridKnnConfig { k: width, rule };
            let mut stats = KnnStats::default();
            for qi in range {
                let (qx, qy) = queries[qi];
                single_query_idx(grid, qx, qy, &cfg, &mut buf, &mut stats);
                // SAFETY: r_obs has queries.len() slots and idx_out has
                // queries.len()*n_neighbors; ranges are disjoint per
                // worker and buf holds >= n_neighbors indices, so every
                // write is in-bounds and race-free
                unsafe {
                    *rp.0.add(qi) = buf.avg_distance(k_alpha);
                    let dst = ip.0.add(qi * n_neighbors);
                    for (j, &id) in buf.idx_slice()[..n_neighbors].iter().enumerate() {
                        *dst.add(j) = id;
                    }
                }
            }
        });
    }
    (idx_out, r_obs)
}

/// One query's ring-expansion search with index tracking (the
/// [`grid_knn_neighbors`] worker; mirrors [`single_query`]).
fn single_query_idx(
    grid: &EvenGrid,
    qx: f64,
    qy: f64,
    cfg: &GridKnnConfig,
    buf: &mut crate::knn::kbuffer::KBufferIdx,
    stats: &mut KnnStats,
) {
    single_query_idx_rows(grid, qx, qy, cfg, buf, stats, 0, usize::MAX);
}

/// Row-clipped [`single_query_idx`]: identical ring expansion, candidate
/// order, and termination logic, but only cells with row in
/// `[row_lo, row_hi)` contribute candidates — the per-shard sweep of
/// [`crate::shard`].  With the full row range this *is* the unsharded
/// search (the clipped ring visitor delegates).  The termination bound
/// ([`EvenGrid::min_dist_beyond`]) and exhaustion test stay whole-grid:
/// both remain valid (conservative) lower bounds for the clipped point
/// set, so the search is exact over clip points for [`RingRule::Exact`].
#[allow(clippy::too_many_arguments)]
pub fn single_query_idx_rows(
    grid: &EvenGrid,
    qx: f64,
    qy: f64,
    cfg: &GridKnnConfig,
    buf: &mut crate::knn::kbuffer::KBufferIdx,
    stats: &mut KnnStats,
    row_lo: usize,
    row_hi: usize,
) {
    buf.clear();
    let (row, col) = grid.locate(qx, qy);
    let mut level = 0usize;
    let mut k_level: Option<usize> = None;
    let mut seen = 0usize;
    loop {
        seen += grid.for_ring_rows(row, col, level, row_lo, row_hi, |xs, ys, _zs, idx| {
            for j in 0..xs.len() {
                buf.insert(dist2(qx, qy, xs[j], ys[j]), idx[j]);
            }
        });
        stats.rings += 1;
        if k_level.is_none() && seen >= cfg.k {
            k_level = Some(level);
        }
        if grid.ring_exhausted(row, col, level) {
            break;
        }
        match cfg.rule {
            RingRule::PaperPlusOne => {
                if let Some(kl) = k_level {
                    if level >= kl + 1 {
                        break;
                    }
                }
            }
            RingRule::Exact => {
                if buf.full() {
                    match grid.min_dist_beyond(qx, qy, row, col, level) {
                        None => break,
                        Some(bound) => {
                            if bound * bound >= buf.kth_d2() {
                                break;
                            }
                        }
                    }
                }
            }
        }
        level += 1;
    }
    stats.candidates += seen as u64;
}

/// One query's ring-expansion search.  Leaves the k-buffer filled; returns
/// the Eq.-3 average distance.
fn single_query(
    grid: &EvenGrid,
    qx: f64,
    qy: f64,
    cfg: &GridKnnConfig,
    buf: &mut KBuffer,
    stats: &mut KnnStats,
) -> f64 {
    single_query_rows(grid, qx, qy, cfg, buf, stats, 0, usize::MAX)
}

/// Row-clipped [`single_query`] (no index tracking) — see
/// [`single_query_idx_rows`] for the clipping contract.
#[allow(clippy::too_many_arguments)]
pub fn single_query_rows(
    grid: &EvenGrid,
    qx: f64,
    qy: f64,
    cfg: &GridKnnConfig,
    buf: &mut KBuffer,
    stats: &mut KnnStats,
    row_lo: usize,
    row_hi: usize,
) -> f64 {
    buf.clear();
    let (row, col) = grid.locate(qx, qy);
    let mut level = 0usize;
    // level (if any) at which cumulative candidates first reached k —
    // drives the PaperPlusOne rule
    let mut k_level: Option<usize> = None;
    let mut seen = 0usize;

    loop {
        seen += grid.for_ring_rows(row, col, level, row_lo, row_hi, |xs, ys, _zs, _idx| {
            for j in 0..xs.len() {
                buf.insert(dist2(qx, qy, xs[j], ys[j]));
            }
        });
        stats.rings += 1;
        stats.max_level = stats.max_level.max(level);

        if k_level.is_none() && seen >= cfg.k {
            k_level = Some(level);
        }

        if grid.ring_exhausted(row, col, level) {
            break; // whole grid visited — nothing more to find
        }

        match cfg.rule {
            RingRule::PaperPlusOne => {
                // stop one ring after the level that reached k candidates
                if let Some(kl) = k_level {
                    if level >= kl + 1 {
                        break;
                    }
                }
            }
            RingRule::Exact => {
                if buf.full() {
                    match grid.min_dist_beyond(qx, qy, row, col, level) {
                        None => break,
                        Some(bound) => {
                            if bound * bound >= buf.kth_d2() {
                                break;
                            }
                        }
                    }
                }
            }
        }
        level += 1;
    }
    stats.candidates += seen as u64;
    buf.avg_distance()
}

#[derive(Clone, Copy)]
struct SendPtr<T>(*mut T);
// SAFETY: the wrapped pointer is only dereferenced inside scoped-thread
// loops that partition the output into disjoint index ranges per worker
unsafe impl<T> Send for SendPtr<T> {}
// SAFETY: as above — shared across workers, written at disjoint indices
unsafe impl<T> Sync for SendPtr<T> {}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::{EvenGrid, GridConfig};
    use crate::knn::brute;
    use crate::pool::Pool;
    use crate::workload;

    fn setup(n: usize, nq: usize, seed: u64) -> (EvenGrid, Vec<(f64, f64)>) {
        let pts = workload::uniform_square(n, 100.0, seed);
        let grid = EvenGrid::build(&pts, None, &GridConfig::default()).unwrap();
        let queries = workload::uniform_square(nq, 100.0, seed + 1000).xy();
        (grid, queries)
    }

    #[test]
    fn exact_rule_matches_brute_force() {
        let pts = workload::uniform_square(2000, 100.0, 31);
        let grid = EvenGrid::build(&pts, None, &GridConfig::default()).unwrap();
        let queries = workload::uniform_square(300, 100.0, 32).xy();
        let pool = Pool::new(2);
        let cfg = GridKnnConfig { k: 10, rule: RingRule::Exact };
        let got = grid_knn_topk(&pool, &grid, &queries, &cfg);
        let want = brute::brute_knn_topk(&pool, &pts.xs, &pts.ys, &queries, 10);
        for (qi, (g, w)) in got.iter().zip(&want).enumerate() {
            for (a, b) in g.iter().zip(w) {
                assert!((a - b).abs() < 1e-9, "query {qi}: {g:?} vs {w:?}");
            }
        }
    }

    #[test]
    fn avg_distances_match_brute() {
        let (grid, queries) = setup(1500, 200, 33);
        let pts_coords = grid.sorted_coords();
        let pool = Pool::new(2);
        let cfg = GridKnnConfig::default();
        let (got, stats) = grid_knn_avg_distances_on(&pool, &grid, &queries, &cfg);
        let want = brute::brute_knn_avg_distances_on(
            &pool, pts_coords.0, pts_coords.1, &queries, cfg.k);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
        // the local search must touch far fewer candidates than brute force
        assert!(
            (stats.candidates as usize) < 1500 * queries.len() / 4,
            "{stats:?}"
        );
    }

    #[test]
    fn paper_rule_close_to_exact_on_uniform_data() {
        // on the paper's uniform workloads the +1 heuristic should agree
        // with the exact rule nearly always
        let (grid, queries) = setup(3000, 400, 34);
        let pool = Pool::new(2);
        let exact = grid_knn_topk(&pool, &grid, &queries,
                                  &GridKnnConfig { k: 10, rule: RingRule::Exact });
        let paper = grid_knn_topk(&pool, &grid, &queries,
                                  &GridKnnConfig { k: 10, rule: RingRule::PaperPlusOne });
        let mismatches = exact
            .iter()
            .zip(&paper)
            .filter(|(a, b)| {
                a.iter().zip(b.iter()).any(|(x, y)| (x - y).abs() > 1e-9)
            })
            .count();
        assert!(
            mismatches * 100 <= queries.len(), // <= 1%
            "paper rule diverged on {mismatches}/{} queries",
            queries.len()
        );
    }

    #[test]
    fn queries_outside_region_clamp_and_succeed() {
        let (grid, _) = setup(800, 0, 35);
        let far = vec![(-50.0, -50.0), (500.0, 500.0), (50.0, -100.0)];
        let pool = Pool::new(1);
        let cfg = GridKnnConfig::default();
        let (got, _) = grid_knn_avg_distances_on(&pool, &grid, &far, &cfg);
        let coords = grid.sorted_coords();
        let want = brute::brute_knn_avg_distances_on(&pool, coords.0, coords.1, &far, cfg.k);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-9);
        }
    }

    #[test]
    fn k_exceeding_points_uses_all() {
        let pts = workload::uniform_square(6, 10.0, 36);
        let grid = EvenGrid::build(&pts, None, &GridConfig::default()).unwrap();
        let pool = Pool::new(1);
        let cfg = GridKnnConfig { k: 50, rule: RingRule::Exact };
        let queries = vec![(5.0, 5.0)];
        let (got, _) = grid_knn_avg_distances_on(&pool, &grid, &queries, &cfg);
        let want = brute::brute_knn_avg_distances_on(&pool, &pts.xs, &pts.ys, &queries, 50);
        assert!((got[0] - want[0]).abs() < 1e-9);
    }

    #[test]
    fn clustered_distribution_still_exact() {
        // clusters break the uniform-density assumption behind the paper's
        // +1 rule; the Exact rule must still match brute force
        let pts = workload::clustered(2000, 100.0, 8, 2.0, 37);
        let grid = EvenGrid::build(&pts, None, &GridConfig::default()).unwrap();
        let queries = workload::uniform_square(200, 100.0, 38).xy();
        let pool = Pool::new(2);
        let cfg = GridKnnConfig { k: 10, rule: RingRule::Exact };
        let got = grid_knn_topk(&pool, &grid, &queries, &cfg);
        let want = brute::brute_knn_topk(&pool, &pts.xs, &pts.ys, &queries, 10);
        for (g, w) in got.iter().zip(&want) {
            for (a, b) in g.iter().zip(w) {
                assert!((a - b).abs() < 1e-9);
            }
        }
    }

    #[test]
    fn neighbors_match_brute_force_ids() {
        let pts = workload::uniform_square(1200, 100.0, 301);
        let grid = EvenGrid::build(&pts, None, &GridConfig::default()).unwrap();
        let queries = workload::uniform_square(150, 100.0, 302).xy();
        let pool = Pool::new(2);
        let n = 8;
        let (idx, r_obs) =
            grid_knn_neighbors(&pool, &grid, &queries, n, 5, RingRule::Exact);
        assert_eq!(idx.len(), queries.len() * n);
        for (qi, &(qx, qy)) in queries.iter().enumerate() {
            // brute-force reference ordering
            let mut ds: Vec<(f64, u32)> = (0..pts.len())
                .map(|i| (crate::geom::dist2(qx, qy, pts.xs[i], pts.ys[i]), i as u32))
                .collect();
            ds.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
            let got = &idx[qi * n..(qi + 1) * n];
            for (j, &g) in got.iter().enumerate() {
                // allow tie permutations: distance must match exactly
                let gd = crate::geom::dist2(qx, qy, pts.xs[g as usize], pts.ys[g as usize]);
                assert!((gd - ds[j].0).abs() < 1e-12, "q{qi} slot {j}");
            }
            // r_obs over the first 5
            let want: f64 = ds[..5].iter().map(|p| p.0.sqrt()).sum::<f64>() / 5.0;
            assert!((r_obs[qi] - want).abs() < 1e-9);
        }
    }

    #[test]
    fn neighbors_pad_when_data_is_small() {
        let pts = workload::uniform_square(3, 10.0, 303);
        let grid = EvenGrid::build(&pts, None, &GridConfig::default()).unwrap();
        let pool = Pool::new(1);
        let (idx, r_obs) = grid_knn_neighbors(
            &pool, &grid, &[(5.0, 5.0)], 8, 10, RingRule::Exact);
        assert_eq!(idx.len(), 8);
        assert_eq!(idx.iter().filter(|&&i| i != u32::MAX).count(), 3);
        assert!(r_obs[0] > 0.0);
    }

    #[test]
    fn query_on_data_point_sees_zero_distance() {
        let pts = workload::uniform_square(500, 50.0, 39);
        let grid = EvenGrid::build(&pts, None, &GridConfig::default()).unwrap();
        let pool = Pool::new(1);
        let q = vec![(pts.xs[17], pts.ys[17])];
        let top = grid_knn_topk(&pool, &grid, &q, &GridKnnConfig::default());
        assert!(top[0][0] < 1e-18);
    }
}
