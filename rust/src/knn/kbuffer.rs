//! The fixed-k insertion buffer — the paper's in-thread nearest-neighbor
//! accumulator (§3.1 steps 1-3): a sorted array of the k smallest squared
//! distances maintained by compare-replace-bubble, no heap, no allocation
//! in the search loop.

/// Sorted ascending buffer of the k smallest squared distances seen so far.
///
/// Semantics match the paper's in-kernel loop exactly:
/// * while fewer than k distances have been seen, every insert is accepted;
/// * afterwards an insert is accepted iff it beats the current k-th
///   distance, which it replaces before bubbling down into sorted place.
#[derive(Debug, Clone)]
pub struct KBuffer {
    d2: Vec<f64>,
    len: usize,
}

impl KBuffer {
    /// Buffer for the k smallest squared distances (k >= 1).
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "k must be positive");
        KBuffer { d2: vec![f64::INFINITY; k], len: 0 }
    }

    /// Capacity k.
    #[inline]
    pub fn k(&self) -> usize {
        self.d2.len()
    }

    /// Number of real distances inserted (saturates at k).
    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no distance has been inserted.
    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// True once k distances have been accepted.
    #[inline]
    pub fn full(&self) -> bool {
        self.len == self.d2.len()
    }

    /// Current k-th (largest retained) squared distance; +inf until full.
    #[inline]
    pub fn kth_d2(&self) -> f64 {
        self.d2[self.d2.len() - 1]
    }

    /// Offer a squared distance; keeps the buffer sorted ascending.
    #[inline]
    pub fn insert(&mut self, d2: f64) {
        let k = self.d2.len();
        if d2 >= self.d2[k - 1] {
            return; // not better than the k-th (also handles the filling
                    // phase: slots are +inf)
        }
        // replace the k-th, bubble toward the front (paper's swap loop)
        let mut i = k - 1;
        self.d2[i] = d2;
        while i > 0 && self.d2[i - 1] > self.d2[i] {
            self.d2.swap(i - 1, i);
            i -= 1;
        }
        if self.len < k {
            self.len += 1;
        }
    }

    /// Reset for reuse (no reallocation).
    #[inline]
    pub fn clear(&mut self) {
        self.d2.fill(f64::INFINITY);
        self.len = 0;
    }

    /// The retained squared distances, ascending (`+inf` in unfilled slots).
    pub fn as_slice(&self) -> &[f64] {
        &self.d2
    }

    /// Average *distance* (not squared) over the filled slots — Eq. 3's
    /// r_obs, with the single deferred sqrt per neighbor happening here.
    pub fn avg_distance(&self) -> f64 {
        if self.len == 0 {
            return 0.0;
        }
        let s: f64 = self.d2[..self.len].iter().map(|&d| d.sqrt()).sum();
        s / self.len as f64
    }
}

/// A k-buffer that also tracks *which* point produced each distance —
/// the index-carrying variant used by the local-weighting extension
/// (EXPERIMENTS.md ablation A5), where stage 2 needs the neighbor ids,
/// not just their distances.
#[derive(Debug, Clone)]
pub struct KBufferIdx {
    d2: Vec<f64>,
    idx: Vec<u32>,
    len: usize,
}

impl KBufferIdx {
    /// Buffer for the k nearest (distance, index) pairs.
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "k must be positive");
        KBufferIdx { d2: vec![f64::INFINITY; k], idx: vec![u32::MAX; k], len: 0 }
    }

    #[inline]
    pub fn k(&self) -> usize {
        self.d2.len()
    }

    #[inline]
    pub fn len(&self) -> usize {
        self.len
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn full(&self) -> bool {
        self.len == self.d2.len()
    }

    #[inline]
    pub fn kth_d2(&self) -> f64 {
        self.d2[self.d2.len() - 1]
    }

    /// Offer a (squared distance, point index) pair.
    #[inline]
    pub fn insert(&mut self, d2: f64, idx: u32) {
        let k = self.d2.len();
        if d2 >= self.d2[k - 1] {
            return;
        }
        let mut i = k - 1;
        self.d2[i] = d2;
        self.idx[i] = idx;
        while i > 0 && self.d2[i - 1] > self.d2[i] {
            self.d2.swap(i - 1, i);
            self.idx.swap(i - 1, i);
            i -= 1;
        }
        if self.len < k {
            self.len += 1;
        }
    }

    #[inline]
    pub fn clear(&mut self) {
        self.d2.fill(f64::INFINITY);
        self.idx.fill(u32::MAX);
        self.len = 0;
    }

    /// Sorted squared distances (ascending; +inf padding).
    pub fn d2_slice(&self) -> &[f64] {
        &self.d2
    }

    /// Point indices aligned with [`KBufferIdx::d2_slice`] (u32::MAX padding).
    pub fn idx_slice(&self) -> &[u32] {
        &self.idx
    }

    /// Eq.-3 average distance over the first `k_used` filled slots.
    pub fn avg_distance(&self, k_used: usize) -> f64 {
        let n = k_used.min(self.len);
        if n == 0 {
            return 0.0;
        }
        let s: f64 = self.d2[..n].iter().map(|&d| d.sqrt()).sum();
        s / n as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rng::Pcg32;

    #[test]
    fn keeps_k_smallest_sorted() {
        let mut b = KBuffer::new(3);
        for d in [9.0, 1.0, 5.0, 3.0, 7.0, 0.5] {
            b.insert(d);
        }
        assert_eq!(b.as_slice(), &[0.5, 1.0, 3.0]);
        assert!(b.full());
        assert_eq!(b.kth_d2(), 3.0);
    }

    #[test]
    fn filling_phase() {
        let mut b = KBuffer::new(4);
        assert!(b.is_empty());
        b.insert(2.0);
        assert_eq!(b.len(), 1);
        assert!(!b.full());
        assert_eq!(b.kth_d2(), f64::INFINITY);
        b.insert(1.0);
        b.insert(3.0);
        b.insert(0.1);
        assert!(b.full());
        assert_eq!(b.as_slice(), &[0.1, 1.0, 2.0, 3.0]);
    }

    #[test]
    fn rejects_larger_than_kth() {
        let mut b = KBuffer::new(2);
        b.insert(1.0);
        b.insert(2.0);
        b.insert(10.0);
        assert_eq!(b.as_slice(), &[1.0, 2.0]);
    }

    #[test]
    fn duplicates_allowed() {
        let mut b = KBuffer::new(3);
        for _ in 0..5 {
            b.insert(1.0);
        }
        assert_eq!(b.as_slice(), &[1.0, 1.0, 1.0]);
    }

    #[test]
    fn matches_sort_reference() {
        let mut rng = Pcg32::seeded(17);
        for k in [1usize, 2, 5, 10, 32] {
            let ds: Vec<f64> = (0..500).map(|_| rng.uniform(0.0, 100.0)).collect();
            let mut b = KBuffer::new(k);
            for &d in &ds {
                b.insert(d);
            }
            let mut want = ds.clone();
            want.sort_by(|a, b| a.partial_cmp(b).unwrap());
            want.truncate(k);
            assert_eq!(b.as_slice(), &want[..], "k={k}");
        }
    }

    #[test]
    fn avg_distance_is_eq3() {
        let mut b = KBuffer::new(2);
        b.insert(9.0); // d = 3
        b.insert(16.0); // d = 4
        assert!((b.avg_distance() - 3.5).abs() < 1e-12);
        // partial fill averages over what exists
        let mut p = KBuffer::new(8);
        p.insert(4.0);
        assert!((p.avg_distance() - 2.0).abs() < 1e-12);
        assert_eq!(KBuffer::new(3).avg_distance(), 0.0);
    }

    #[test]
    fn clear_reuses() {
        let mut b = KBuffer::new(2);
        b.insert(1.0);
        b.clear();
        assert!(b.is_empty());
        assert_eq!(b.kth_d2(), f64::INFINITY);
    }

    #[test]
    fn idx_buffer_tracks_indices() {
        let mut b = KBufferIdx::new(3);
        for (i, d) in [9.0, 1.0, 5.0, 3.0, 7.0, 0.5].iter().enumerate() {
            b.insert(*d, i as u32);
        }
        assert_eq!(b.d2_slice(), &[0.5, 1.0, 3.0]);
        assert_eq!(b.idx_slice(), &[5, 1, 3]);
        assert!(b.full());
    }

    #[test]
    fn idx_buffer_matches_plain_buffer() {
        let mut rng = Pcg32::seeded(77);
        for k in [1usize, 4, 10] {
            let ds: Vec<f64> = (0..300).map(|_| rng.uniform(0.0, 50.0)).collect();
            let mut plain = KBuffer::new(k);
            let mut withidx = KBufferIdx::new(k);
            for (i, &d) in ds.iter().enumerate() {
                plain.insert(d);
                withidx.insert(d, i as u32);
            }
            assert_eq!(plain.as_slice(), withidx.d2_slice());
            // the recorded indices really point at those distances
            for (slot, &i) in withidx.idx_slice().iter().enumerate() {
                assert_eq!(ds[i as usize], withidx.d2_slice()[slot]);
            }
            assert!((plain.avg_distance() - withidx.avg_distance(k)).abs() < 1e-12);
        }
    }

    #[test]
    fn idx_buffer_partial_avg() {
        let mut b = KBufferIdx::new(4);
        b.insert(4.0, 0); // d=2
        b.insert(16.0, 1); // d=4
        assert!((b.avg_distance(1) - 2.0).abs() < 1e-12);
        assert!((b.avg_distance(2) - 3.0).abs() < 1e-12);
        assert!((b.avg_distance(10) - 3.0).abs() < 1e-12); // clamps to len
        b.clear();
        assert_eq!(b.avg_distance(4), 0.0);
        assert_eq!(b.idx_slice(), &[u32::MAX; 4]);
    }
}
