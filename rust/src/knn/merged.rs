//! kNN over a live dataset: grid search over the immutable epoch base
//! **union** brute force over the small delta overlay, with tombstoned
//! points filtered out of both.
//!
//! This is the hybrid the live subsystem is built on (cf. Gowanlock's
//! hybrid kNN-join, arXiv:1810.04758, and Garcia's observation that brute
//! force wins at small n, arXiv:0804.1448): the bulk of the points stay
//! indexed by the epoch's [`EvenGrid`], while the recently-appended tail
//! is scanned exhaustively — the delta is bounded by the compaction
//! threshold, so the brute pass stays O(k·|delta|)-ish per query.
//!
//! **Termination is always the provably-exact bound**, regardless of the
//! request's ring rule: the paper's "+1 ring" heuristic counts grid
//! candidates to decide when to stop, and delta points are not in the
//! grid, so the count that justifies the heuristic is ill-defined here.
//! Upgrading to the exact rule makes merged results *identical* to a
//! from-scratch grid over the merged point set queried with
//! [`RingRule::Exact`](crate::knn::grid_knn::RingRule) — the equivalence
//! the `it_live` property test pins down bit-for-bit.
//!
//! Delta candidates are inserted **before** ring expansion so the k-th
//! distance bound is tight from the first ring.
//!
//! Stage-1 products built from a merged search are **cacheable**: the
//! coordinator's `NeighborCache` keys them on the snapshot's overlay
//! version (every append/remove bumps it), so a repeated raster on a
//! mutated dataset reuses the merged sweep instead of re-running it —
//! the exact pathology fast kNN search exists to avoid.

use std::collections::HashSet;

use crate::geom::dist2;
use crate::grid::EvenGrid;
use crate::knn::kbuffer::KBufferIdx;
use crate::pool::Pool;

/// Borrowed view of one consistent live snapshot, as the search needs it.
///
/// Merged candidate indices are `u32`: a value `< n_base` is an original
/// index into the base point set; `n_base + j` is position `j` in the
/// delta append log.
#[derive(Clone, Copy)]
pub struct MergedView<'a> {
    pub grid: &'a EvenGrid,
    /// Original base indices that are tombstoned.
    pub base_dead: &'a HashSet<u32>,
    pub delta_xs: &'a [f64],
    pub delta_ys: &'a [f64],
    /// Delta append-log positions that are tombstoned.
    pub delta_dead: &'a HashSet<u32>,
}

impl<'a> MergedView<'a> {
    fn n_base(&self) -> usize {
        self.grid.n_points()
    }
}

/// One query's merged exact search; leaves the (d2, merged-index) buffer
/// filled ascending.
fn single_query_merged(view: &MergedView<'_>, qx: f64, qy: f64, buf: &mut KBufferIdx) {
    buf.clear();
    let n_base = view.n_base() as u32;
    // brute pass over the live delta first: tightens kth_d2 before any
    // ring is visited
    for j in 0..view.delta_xs.len() {
        let jj = j as u32;
        if view.delta_dead.contains(&jj) {
            continue;
        }
        buf.insert(dist2(qx, qy, view.delta_xs[j], view.delta_ys[j]), n_base + jj);
    }
    // grid pass over the epoch base, skipping tombstones, exact bound
    let (row, col) = view.grid.locate(qx, qy);
    let mut level = 0usize;
    loop {
        view.grid.for_ring(row, col, level, |xs, ys, _zs, idx| {
            for j in 0..xs.len() {
                if view.base_dead.contains(&idx[j]) {
                    continue;
                }
                buf.insert(dist2(qx, qy, xs[j], ys[j]), idx[j]);
            }
        });
        if view.grid.ring_exhausted(row, col, level) {
            break;
        }
        if buf.full() {
            match view.grid.min_dist_beyond(qx, qy, row, col, level) {
                None => break,
                Some(bound) => {
                    if bound * bound >= buf.kth_d2() {
                        break;
                    }
                }
            }
        }
        level += 1;
    }
}

/// Eq.-3 average distance to the k nearest **live** points for each query
/// (the merged analog of
/// [`grid_knn_avg_distances_on`](crate::knn::grid_knn::grid_knn_avg_distances_on)).
/// Parallel across queries.
pub fn merged_knn_avg_distances_on(
    pool: &Pool,
    view: &MergedView<'_>,
    queries: &[(f64, f64)],
    k: usize,
) -> Vec<f64> {
    let k = k.max(1);
    let mut out = vec![0f64; queries.len()];
    pool.for_each_slice_mut(&mut out, 64, |offset, chunk| {
        let mut buf = KBufferIdx::new(k);
        for (j, slot) in chunk.iter_mut().enumerate() {
            let (qx, qy) = queries[offset + j];
            single_query_merged(view, qx, qy, &mut buf);
            *slot = buf.avg_distance(k);
        }
    });
    out
}

/// Neighbor lists over the live merged set — the per-id gather that lets
/// local (A5) weighting run on a *mutated* dataset without waiting for
/// compaction: for each query, the `n_neighbors` nearest live points'
/// **merged candidate indices** (row-major `(queries.len(), n_neighbors)`,
/// ascending distance, `u32::MAX`-padded when fewer live points exist;
/// `< n_base` = original base index, else `n_base + delta position`,
/// tombstones filtered on both sides), plus the Eq.-3 average distance
/// over the first `k_alpha` of them.
///
/// The merged analog of
/// [`grid_knn_neighbors`](crate::knn::grid_knn::grid_knn_neighbors): one
/// search serves both stage-1 products, and the ascending-distance row
/// order is the summation order the local stage 2 consumes — which makes
/// merged local answers bit-identical to a post-compaction run over the
/// same live set.
///
/// **Tie caveat:** when two live points are *exactly* equidistant from a
/// query and the tie straddles the last retained slot, which of the tied
/// points is kept depends on visitation order (delta-first here; cell
/// order in a compacted grid).  Distances — and hence the dense path and
/// r_obs — are unaffected, but a gathered neighbor *set* can differ at
/// such a tie, so the bit-identity guarantee for local answers assumes
/// no two points share exact coordinates at the cut boundary (duplicate
/// sensor positions with different readings are the one realistic way to
/// manufacture this).
pub fn merged_knn_neighbors_on(
    pool: &Pool,
    view: &MergedView<'_>,
    queries: &[(f64, f64)],
    n_neighbors: usize,
    k_alpha: usize,
) -> (Vec<u32>, Vec<f64>) {
    assert!(n_neighbors >= 1 && k_alpha >= 1);
    let width = n_neighbors.max(k_alpha);
    let parts = pool.map_ranges(queries.len(), 64, |r| {
        let mut buf = KBufferIdx::new(width);
        let mut idx = Vec::with_capacity((r.end - r.start) * n_neighbors);
        let mut r_obs = Vec::with_capacity(r.end - r.start);
        for qi in r {
            let (qx, qy) = queries[qi];
            single_query_merged(view, qx, qy, &mut buf);
            r_obs.push(buf.avg_distance(k_alpha));
            idx.extend_from_slice(&buf.idx_slice()[..n_neighbors]);
        }
        (idx, r_obs)
    });
    let mut idx_out = Vec::with_capacity(queries.len() * n_neighbors);
    let mut r_out = Vec::with_capacity(queries.len());
    for (idx, r_obs) in parts {
        idx_out.extend(idx);
        r_out.extend(r_obs);
    }
    (idx_out, r_out)
}

/// The k nearest live points per query as ascending `(d2, merged_index)`
/// pairs (fewer when fewer live points exist) — the oracle interface the
/// incremental-vs-rebuild property test compares against a from-scratch
/// grid.
pub fn merged_knn_topk_on(
    pool: &Pool,
    view: &MergedView<'_>,
    queries: &[(f64, f64)],
    k: usize,
) -> Vec<Vec<(f64, u32)>> {
    let k = k.max(1);
    let results = pool.map_ranges(queries.len(), 64, |r| {
        let mut buf = KBufferIdx::new(k);
        let mut local = Vec::with_capacity(r.end - r.start);
        for qi in r {
            let (qx, qy) = queries[qi];
            single_query_merged(view, qx, qy, &mut buf);
            let n = buf.len();
            local.push(
                buf.d2_slice()[..n]
                    .iter()
                    .copied()
                    .zip(buf.idx_slice()[..n].iter().copied())
                    .collect::<Vec<(f64, u32)>>(),
            );
        }
        local
    });
    results.into_iter().flatten().collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::geom::PointSet;
    use crate::grid::{EvenGrid, GridConfig};
    use crate::knn::brute;
    use crate::workload;

    /// Brute-force reference over the merged live point multiset.
    fn merged_live_points(
        base: &PointSet,
        base_dead: &HashSet<u32>,
        delta: &PointSet,
        delta_dead: &HashSet<u32>,
    ) -> PointSet {
        let mut out = PointSet::default();
        for i in 0..base.len() {
            if !base_dead.contains(&(i as u32)) {
                out.push(base.xs[i], base.ys[i], base.zs[i]);
            }
        }
        for j in 0..delta.len() {
            if !delta_dead.contains(&(j as u32)) {
                out.push(delta.xs[j], delta.ys[j], delta.zs[j]);
            }
        }
        out
    }

    #[test]
    fn merged_matches_brute_force_with_tombstones() {
        let base = workload::uniform_square(1500, 100.0, 701);
        let delta = workload::uniform_square(90, 100.0, 702);
        let base_dead: HashSet<u32> = (0..40u32).map(|i| i * 31 % 1500).collect();
        let delta_dead: HashSet<u32> = [3u32, 17, 55].into_iter().collect();
        let grid = EvenGrid::build(&base, None, &GridConfig::default()).unwrap();
        let view = MergedView {
            grid: &grid,
            base_dead: &base_dead,
            delta_xs: &delta.xs,
            delta_ys: &delta.ys,
            delta_dead: &delta_dead,
        };
        let queries = workload::uniform_square(200, 100.0, 703).xy();
        let pool = Pool::new(2);
        let merged = merged_live_points(&base, &base_dead, &delta, &delta_dead);

        let got = merged_knn_avg_distances_on(&pool, &view, &queries, 10);
        let want =
            brute::brute_knn_avg_distances_on(&pool, &merged.xs, &merged.ys, &queries, 10);
        for (g, w) in got.iter().zip(&want) {
            assert!((g - w).abs() < 1e-12, "{g} vs {w}");
        }

        let top = merged_knn_topk_on(&pool, &view, &queries, 10);
        let want_top = brute::brute_knn_topk(&pool, &merged.xs, &merged.ys, &queries, 10);
        for (qi, (g, w)) in top.iter().zip(&want_top).enumerate() {
            assert_eq!(g.len(), 10);
            for (slot, ((d2, _idx), wref)) in g.iter().zip(w).enumerate() {
                assert!((d2 - wref).abs() < 1e-12, "q{qi} slot {slot}");
            }
        }
    }

    #[test]
    fn empty_delta_equals_plain_grid_search() {
        let base = workload::uniform_square(800, 50.0, 704);
        let grid = EvenGrid::build(&base, None, &GridConfig::default()).unwrap();
        let none_u32: HashSet<u32> = HashSet::new();
        let view = MergedView {
            grid: &grid,
            base_dead: &none_u32,
            delta_xs: &[],
            delta_ys: &[],
            delta_dead: &none_u32,
        };
        let queries = workload::uniform_square(60, 50.0, 705).xy();
        let pool = Pool::new(2);
        let got = merged_knn_avg_distances_on(&pool, &view, &queries, 10);
        let cfg = crate::knn::grid_knn::GridKnnConfig::default();
        let (want, _) =
            crate::knn::grid_knn::grid_knn_avg_distances_on(&pool, &grid, &queries, &cfg);
        assert_eq!(got, want, "merged search with no delta must be bit-identical");
    }

    #[test]
    fn neighbor_gather_matches_topk_and_filters_tombstones() {
        let base = workload::uniform_square(600, 60.0, 708);
        let delta = workload::uniform_square(50, 60.0, 709);
        let base_dead: HashSet<u32> = (0..20u32).map(|i| i * 17 % 600).collect();
        let delta_dead: HashSet<u32> = [2u32, 30].into_iter().collect();
        let grid = EvenGrid::build(&base, None, &GridConfig::default()).unwrap();
        let view = MergedView {
            grid: &grid,
            base_dead: &base_dead,
            delta_xs: &delta.xs,
            delta_ys: &delta.ys,
            delta_dead: &delta_dead,
        };
        let queries = workload::uniform_square(80, 60.0, 710).xy();
        let pool = Pool::new(2);
        let n = 12;
        let k_alpha = 5;
        let (idx, r_obs) = merged_knn_neighbors_on(&pool, &view, &queries, n, k_alpha);
        assert_eq!(idx.len(), queries.len() * n);
        let top = merged_knn_topk_on(&pool, &view, &queries, n);
        let avg = merged_knn_avg_distances_on(&pool, &view, &queries, k_alpha);
        for qi in 0..queries.len() {
            let row = &idx[qi * n..(qi + 1) * n];
            for (slot, &(_, want_idx)) in top[qi].iter().enumerate() {
                assert_eq!(row[slot], want_idx, "q{qi} slot {slot}");
                // tombstoned candidates never surface
                let got = row[slot];
                if (got as usize) < base.len() {
                    assert!(!base_dead.contains(&got));
                } else {
                    assert!(!delta_dead.contains(&(got - base.len() as u32)));
                }
            }
            assert_eq!(r_obs[qi], avg[qi], "q{qi}: r_obs must match the k_alpha average");
        }
    }

    #[test]
    fn neighbor_gather_pads_when_live_set_is_small() {
        let base = workload::uniform_square(4, 10.0, 711);
        let grid = EvenGrid::build(&base, None, &GridConfig::default()).unwrap();
        let none: HashSet<u32> = HashSet::new();
        let dead: HashSet<u32> = [1u32].into_iter().collect();
        let view = MergedView {
            grid: &grid,
            base_dead: &dead,
            delta_xs: &[],
            delta_ys: &[],
            delta_dead: &none,
        };
        let pool = Pool::new(1);
        let (idx, r_obs) = merged_knn_neighbors_on(&pool, &view, &[(5.0, 5.0)], 8, 10);
        assert_eq!(idx.len(), 8);
        assert_eq!(idx.iter().filter(|&&i| i != u32::MAX).count(), 3);
        assert!(r_obs[0] > 0.0);
    }

    #[test]
    fn fully_tombstoned_base_serves_from_delta() {
        let base = workload::uniform_square(50, 10.0, 706);
        let delta = workload::uniform_square(8, 10.0, 707);
        let base_dead: HashSet<u32> = (0..50u32).collect();
        let delta_dead = HashSet::new();
        let grid = EvenGrid::build(&base, None, &GridConfig::default()).unwrap();
        let view = MergedView {
            grid: &grid,
            base_dead: &base_dead,
            delta_xs: &delta.xs,
            delta_ys: &delta.ys,
            delta_dead: &delta_dead,
        };
        let pool = Pool::new(1);
        let top = merged_knn_topk_on(&pool, &view, &[(5.0, 5.0)], 10);
        assert_eq!(top[0].len(), 8, "only the 8 delta points are live");
        for &(_, idx) in &top[0] {
            assert!(idx >= 50, "all survivors come from the delta");
        }
    }
}
