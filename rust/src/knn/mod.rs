//! k-nearest-neighbor search engines.
//!
//! Three implementations:
//!
//! * [`brute`] — the original global scan: every data point streamed
//!   through a per-query k-buffer (paper §2.3 / Mei et al. 2015);
//! * [`grid_knn`] — the improved local search over the [`crate::grid`]
//!   even grid with iterative ring expansion (paper §3.2.4);
//! * [`merged`] — the live-dataset hybrid: grid search over an immutable
//!   epoch base unioned with a brute pass over the mutable delta overlay,
//!   filtering tombstones (the serving form of Gowanlock's hybrid
//!   kNN-join, arXiv:1810.04758).
//!
//! All defer `sqrt` to the epilogue (squared distances throughout) and
//! share the [`kbuffer::KBuffer`] insertion structure — the paper's
//! "compare with the k-th distance, replace, bubble into place" loop.

pub mod brute;
pub mod grid_knn;
pub mod kbuffer;
pub mod merged;

pub use brute::brute_knn_avg_distances;
pub use grid_knn::{grid_knn_avg_distances, GridKnnConfig, RingRule};
pub use kbuffer::KBuffer;
pub use merged::MergedView;
