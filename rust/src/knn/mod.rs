//! k-nearest-neighbor search engines.
//!
//! Two implementations, mirroring the paper's "original" vs "improved"
//! algorithms:
//!
//! * [`brute`] — the original global scan: every data point streamed
//!   through a per-query k-buffer (paper §2.3 / Mei et al. 2015);
//! * [`grid_knn`] — the improved local search over the [`crate::grid`]
//!   even grid with iterative ring expansion (paper §3.2.4).
//!
//! Both defer `sqrt` to the epilogue (squared distances throughout) and
//! share the [`kbuffer::KBuffer`] insertion structure — the paper's
//! "compare with the k-th distance, replace, bubble into place" loop.

pub mod brute;
pub mod grid_knn;
pub mod kbuffer;

pub use brute::brute_knn_avg_distances;
pub use grid_knn::{grid_knn_avg_distances, GridKnnConfig, RingRule};
pub use kbuffer::KBuffer;
