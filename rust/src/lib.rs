//! # aidw — Adaptive IDW interpolation with fast grid kNN search
//!
//! A production-grade reproduction of *Improving GPU-accelerated Adaptive
//! IDW Interpolation Algorithm Using Fast kNN Search* (Mei, Xu & Xu 2016,
//! doi:10.1186/s40064-016-3035-2) as a three-layer rust + JAX/Pallas stack:
//!
//! * **L3 (this crate)** — the coordinator: even-grid spatial index, grid
//!   kNN with ring expansion, parallel primitives (radix sort-by-key,
//!   segmented reduce/scan), dataset registry, dynamic batcher, two-stage
//!   pipeline scheduler, and a TCP JSON interpolation service.
//! * **L2 (python/compile/model.py)** — the AIDW compute graphs (Eq. 1-6),
//!   AOT-lowered to HLO text at build time (`make artifacts`).
//! * **L1 (python/compile/kernels/)** — Pallas block-tiled kernels for the
//!   weighted-interpolation and brute-force-kNN hot loops.
//!
//! Python never runs on the request path: the [`runtime`] module loads the
//! AOT artifacts through the PJRT CPU client (`xla` crate) and the
//! [`coordinator`] streams arbitrary problem sizes through their fixed
//! shapes.
//!
//! ## Quick start
//!
//! One facade — [`session::AidwSession`] — covers every execution path
//! (serial reference, pure-rust pipeline, serving coordinator), and one
//! options type — [`coordinator::QueryOptions`] — tunes every request:
//!
//! ```no_run
//! use aidw::prelude::*;
//!
//! // 1000 scattered data points in a 100x100 region
//! let pts = workload::uniform_square(1000, 100.0, 42);
//! let queries = workload::uniform_square(500, 100.0, 7).xy();
//!
//! let session = AidwSession::in_process(); // pure-rust improved pipeline
//! session.register("survey", pts).unwrap();
//!
//! // per-request tuning: k, ring rule, local mode, alpha levels, ...
//! let z = session
//!     .interpolate_values("survey", &queries, &QueryOptions::new().k(16))
//!     .unwrap();
//! assert_eq!(z.len(), 500);
//! ```
//!
//! The serving path (dynamic batching, PJRT artifacts when present, the
//! TCP protocol) is `AidwSession::serving(CoordinatorConfig::default())`
//! or the [`coordinator::Coordinator`] directly; every option above is
//! also settable per request over the wire (protocol v2, see
//! [`service::protocol`]).  See `examples/quickstart.rs`.

pub mod aidw;
pub mod analysis;
pub mod benchlib;
pub mod benchsuite;
pub mod cli;
pub mod coordinator;
pub mod error;
pub mod geom;
pub mod grid;
pub mod jsonio;
pub mod knn;
pub mod live;
pub mod obs;
pub mod pool;
pub mod primitives;
pub mod proptest;
pub mod raster;
pub mod rng;
pub mod runtime;
pub mod service;
pub mod session;
pub mod shard;
pub mod subscribe;
pub mod workload;

pub use error::{Error, Result};

/// Convenience re-exports for examples and downstream users.
pub mod prelude {
    pub use crate::aidw::plan::{NeighborArtifact, Stage1Plan, Stage2Plan, TilePlan};
    pub use crate::aidw::{params::AidwParams, pipeline, serial};
    pub use crate::coordinator::{
        Coordinator, CoordinatorConfig, InterpolationRequest, LocalMode, QueryOptions,
        ResolvedOptions, Stage1Key, Stage2Key, StreamSummary, TileResult, TileStream, Variant,
    };
    pub use crate::error::{Error, Result};
    pub use crate::geom::{Aabb, PointSet};
    pub use crate::grid::EvenGrid;
    pub use crate::knn::{brute, grid_knn};
    pub use crate::live::{LiveConfig, LiveDataset, LiveStatus};
    pub use crate::runtime::Engine;
    pub use crate::session::{AidwSession, SessionReply, SessionStream, SessionTicket};
    pub use crate::shard::{SweepStats, TenantPolicy, TenantTag};
    pub use crate::subscribe::{SubTile, SubUpdate, SubUpdateStart, SubscriptionFrame, SubscriptionStream};
    pub use crate::workload;
}
