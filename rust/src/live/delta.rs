//! The delta overlay — the small mutable tail of a [`super::LiveDataset`].
//!
//! An overlay is immutable once published: every mutation builds a new
//! overlay (copy-on-write) and swaps it in, so in-flight queries keep a
//! consistent view.  Cloning is O(delta), and the delta is bounded by the
//! compaction threshold, so mutation cost stays small and independent of
//! the base size.
//!
//! Within one epoch the append log is strictly append-only: removing an
//! appended point never shrinks `points`, it only tombstones the point's
//! id.  That invariant is what lets the compactor diff "overlay now"
//! against "overlay at capture" as a plain suffix + tombstone difference
//! (see [`super::LiveDataset`] compaction).

use std::collections::HashSet;

use crate::geom::PointSet;

/// Where a live id currently resolves.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum LiveLocation {
    /// Original index into the epoch base point set.
    Base(u32),
    /// Position in the overlay append log.
    Delta(u32),
}

/// Appended points + tombstones layered over an immutable epoch base.
#[derive(Debug, Clone, Default)]
pub struct DeltaOverlay {
    /// Monotonic overlay version within the epoch: bumped by every
    /// append/remove (each builds a new overlay, so equal `(epoch,
    /// version)` implies the identical overlay state), reset by
    /// compaction (to the carried-mutation count of the fresh overlay).
    /// This is the mutation half of the stage-1 cache identity: artifacts
    /// computed over a mutated snapshot stay valid exactly until the next
    /// mutation, and the version bump is what retires them.
    pub version: u64,
    /// Appended points, in append order (append-only within an epoch).
    pub points: PointSet,
    /// Stable id of each appended point (strictly ascending).
    pub ids: Vec<u64>,
    /// Ids of removed live points (base or delta).
    pub tombstones: HashSet<u64>,
    /// Original base indices of tombstoned base points (query-time filter).
    pub base_dead: HashSet<u32>,
    /// Append-log positions of tombstoned delta points (query-time filter).
    pub delta_dead: HashSet<u32>,
}

impl DeltaOverlay {
    /// True when the overlay changes nothing about the base.
    pub fn is_empty(&self) -> bool {
        self.points.is_empty() && self.tombstones.is_empty()
    }

    /// Appended points that are still live.
    pub fn live_appends(&self) -> usize {
        self.points.len() - self.delta_dead.len()
    }

    /// Compaction pressure: total overlay entries (appends + tombstones).
    pub fn pressure(&self) -> usize {
        self.points.len() + self.tombstones.len()
    }

    /// True when append-log position `pos` is still live.
    #[inline]
    pub fn delta_live(&self, pos: usize) -> bool {
        !self.delta_dead.contains(&(pos as u32))
    }

    /// New overlay with `pts` appended under the given ids (parallel to
    /// the points; must be ascending and above every existing id —
    /// callers assign fresh ids or replay logged ones).
    pub fn with_appends(&self, pts: &PointSet, ids: &[u64]) -> DeltaOverlay {
        assert_eq!(pts.len(), ids.len(), "points/ids length mismatch");
        let mut next = self.clone();
        next.version += 1;
        for i in 0..pts.len() {
            next.points.push(pts.xs[i], pts.ys[i], pts.zs[i]);
            next.ids.push(ids[i]);
        }
        next
    }

    /// New overlay with the given (id, location) pairs tombstoned.  The
    /// caller has already resolved and validated every id against the
    /// current snapshot.
    pub fn with_removals(&self, removals: &[(u64, LiveLocation)]) -> DeltaOverlay {
        let mut next = self.clone();
        next.version += 1;
        for &(id, loc) in removals {
            next.tombstones.insert(id);
            match loc {
                LiveLocation::Base(idx) => {
                    next.base_dead.insert(idx);
                }
                LiveLocation::Delta(pos) => {
                    next.delta_dead.insert(pos);
                }
            }
        }
        next
    }

    /// Locate a live id inside the append log (ids are ascending, so this
    /// is a binary search).  Returns the log position even if tombstoned;
    /// callers check `delta_dead` themselves.
    pub fn find_id(&self, id: u64) -> Option<u32> {
        self.ids.binary_search(&id).ok().map(|p| p as u32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    #[test]
    fn append_and_remove_are_copy_on_write() {
        let base = DeltaOverlay::default();
        assert!(base.is_empty());
        let pts = workload::uniform_square(4, 10.0, 1);
        let a = base.with_appends(&pts, &[100, 101, 102, 103]);
        assert!(base.is_empty(), "original untouched");
        assert_eq!(a.points.len(), 4);
        assert_eq!(a.ids, vec![100, 101, 102, 103]);
        assert_eq!(a.live_appends(), 4);
        assert_eq!(a.pressure(), 4);

        let b = a.with_removals(&[(101, LiveLocation::Delta(1)), (7, LiveLocation::Base(7))]);
        assert_eq!(a.tombstones.len(), 0, "original untouched");
        assert_eq!(b.points.len(), 4, "append log never shrinks in-epoch");
        assert_eq!(b.live_appends(), 3);
        assert!(b.tombstones.contains(&101));
        assert!(b.base_dead.contains(&7));
        assert!(b.delta_dead.contains(&1));
        assert!(!b.delta_live(1));
        assert!(b.delta_live(0));
        assert_eq!(b.pressure(), 6);
    }

    #[test]
    fn every_mutation_bumps_the_version() {
        let base = DeltaOverlay::default();
        assert_eq!(base.version, 0);
        let pts = workload::uniform_square(2, 10.0, 3);
        let a = base.with_appends(&pts, &[10, 11]);
        assert_eq!(a.version, 1);
        let b = a.with_removals(&[(10, LiveLocation::Delta(0))]);
        assert_eq!(b.version, 2);
        let c = b.with_appends(&pts, &[12, 13]);
        assert_eq!(c.version, 3);
        assert_eq!(base.version, 0, "copy-on-write: originals keep their version");
    }

    #[test]
    fn find_id_binary_search() {
        let pts = workload::uniform_square(5, 10.0, 2);
        let d = DeltaOverlay::default().with_appends(&pts, &[50, 51, 52, 53, 54]);
        assert_eq!(d.find_id(50), Some(0));
        assert_eq!(d.find_id(54), Some(4));
        assert_eq!(d.find_id(49), None);
        assert_eq!(d.find_id(55), None);
    }
}
