//! Live dataset mutation: epoch-versioned grid index with a delta
//! overlay, background compaction, and WAL-backed durability.
//!
//! The paper treats the even grid as a one-time construction cost
//! (§3.2.1–3.2.3); [`crate::coordinator::Dataset`] accordingly freezes
//! the index at registration.  A serving system under live sensor traffic
//! cannot afford a full O(n log n) rebuild per update, so a
//! [`LiveDataset`] splits the world in two:
//!
//! * an **immutable epoch** — `Arc<Dataset>` (points + `EvenGrid`), never
//!   modified after publication, so in-flight queries keep a consistent
//!   snapshot for as long as they hold the `Arc`;
//! * a small **delta overlay** ([`delta::DeltaOverlay`]) — appended
//!   points plus a tombstone set for removals, rebuilt copy-on-write per
//!   mutation (O(delta), never O(n)).
//!
//! Queries merge grid-kNN results over the epoch with brute force over
//! the delta ([`crate::knn::merged`]), filter tombstones from both sides,
//! and recompute `r_exp` from the live count and bounds.  Once the
//! overlay crosses `compact_threshold`, a background compactor rebuilds
//! the grid over the merged point set off-thread and publishes the new
//! epoch with an atomic pointer swap (`RwLock<Arc<_>>` held only for the
//! swap itself — the ArcSwap idiom without the dependency).
//!
//! ## Choosing `compact_threshold`
//!
//! The threshold trades *query* cost against *compaction* cost.  Every
//! query pays O(|delta|) for the brute pass and a hash-probe per grid
//! candidate once tombstones exist, so a large threshold taxes every
//! query a little; every compaction pays O(n log n) for the rebuild plus
//! an O(n) durable snapshot write, so a small threshold taxes the write
//! path a lot (and churns epochs, splitting batches keyed on the epoch).
//! The default (4096) keeps the brute pass around the cost of visiting
//! one-to-two extra grid rings at the paper's densities; latency-critical
//! read-heavy deployments should lower it, ingest-heavy ones raise it.
//! `pressure` = appends + tombstones is the trigger metric, so removal
//! storms compact too (tombstones slow the grid pass even though they
//! shrink the live set).
//!
//! ## Durability
//!
//! With a live directory attached, every mutation appends one record to a
//! per-dataset WAL *before* it is applied in memory, and compaction
//! truncates the WAL only after the rebuilt snapshot has been published
//! by atomic rename ([`wal`] documents the formats and the idempotent
//! replay that makes the publish sequence crash-safe).  Multi-record WAL
//! writes (the compactor re-logging a carried overlay) are
//! **group-committed** — all records of one logical commit in a single
//! `write_all`, then at most one `sync_data` — so `wal_sync` costs one
//! fsync per commit, not one per record.  Restart = snapshot load + WAL
//! replay; the kill-and-restart integration test pins the result down
//! bit-for-bit against a fresh build of the merged set.

pub mod delta;
pub mod registry;
pub mod wal;

use std::collections::HashSet;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, RwLock};
use std::thread::JoinHandle;

use crate::aidw::alpha;
use crate::coordinator::dataset::Dataset;
use crate::coordinator::snapshot::validate_dataset_name;
use crate::error::{Error, Result};
use crate::geom::{dist2, Aabb, Columns, PointSet, EPS_D2};
use crate::grid::GridConfig;
use crate::knn::merged::MergedView;
use crate::pool::Pool;

pub use delta::{DeltaOverlay, LiveLocation};
pub use registry::LiveRegistry;
pub use wal::{Wal, WalRecord};

/// Tunables of the live mutation layer.
#[derive(Debug, Clone, Copy)]
pub struct LiveConfig {
    /// Overlay pressure (appends + tombstones) that triggers background
    /// compaction.  See the module docs for the trade-off.
    pub compact_threshold: usize,
    /// Spawn the background compactor automatically when the threshold is
    /// crossed (`false` = only explicit `compact` requests compact).
    pub auto_compact: bool,
    /// `sync_data` every WAL record and snapshot (survives OS/power
    /// failure, not just process death).  Off by default: one fsync per
    /// mutation is the difference between ~10^5 and ~10^2 mutations/s on
    /// commodity disks.
    pub wal_sync: bool,
    /// Rotate the WAL to a fresh segment file once the active one grows
    /// past this many bytes (ROADMAP PR-4(c)): unbounded ingest-heavy
    /// feeds then produce a chain of bounded segments instead of one
    /// giant file.  Replay walks segments in order; compaction re-seeds
    /// segment 0 and deletes the obsolete siblings.  0 = never rotate.
    pub wal_segment_bytes: usize,
}

impl Default for LiveConfig {
    fn default() -> Self {
        LiveConfig {
            compact_threshold: 4096,
            auto_compact: true,
            wal_sync: false,
            wal_segment_bytes: 64 << 20, // 64 MiB
        }
    }
}

/// One immutable, consistent view of a live dataset.  Cheap to clone;
/// in-flight requests hold it across a compaction publish unharmed.
#[derive(Debug, Clone)]
pub struct LiveSnapshot {
    /// Epoch counter: bumped by every compaction publish, persisted.
    pub epoch: u64,
    /// The immutable epoch base (points + grid + cached r_exp).
    pub base: Arc<Dataset>,
    /// Stable id of each base point, aligned with the base point order
    /// and strictly ascending (compaction preserves both invariants).
    pub base_ids: Arc<Vec<u64>>,
    /// The mutable tail: appends + tombstones.
    pub delta: Arc<DeltaOverlay>,
    /// Exact bounding box of the *live* point set (appends extend it;
    /// boundary removals trigger a recompute).
    pub live_bounds: Aabb,
    /// Number of live points (base - tombstoned + live appends).
    pub live_len: usize,
    /// Explicit Eq.-2 area override, when configured.
    pub area_override: Option<f64>,
    /// Count of mutations (appends + removes) ever applied to this
    /// dataset instance — assigned under the write lock, **carried
    /// across compactions** (unlike the per-epoch overlay version,
    /// whose renumbering at a fold makes cross-epoch gap detection
    /// ambiguous).  Consumers that must account for *every* mutation —
    /// the subscription worker's dirty-footprint ledger — key on this:
    /// two snapshots with equal `mut_seq` are value-identical up to
    /// compaction.
    pub mut_seq: u64,
}

impl LiveSnapshot {
    /// True when the overlay is empty — queries may take the plain
    /// grid-only fast path (including PJRT stage 2 and the request's own
    /// ring rule).
    pub fn is_compacted(&self) -> bool {
        self.delta.is_empty()
    }

    /// The overlay version this snapshot was published at — the mutation
    /// half of stage-1 cache identity: `(epoch, overlay_version)` names
    /// exactly one overlay state, so artifacts keyed on the pair stay
    /// servable until the next append/remove bumps the version (or a
    /// compaction bumps the epoch).
    pub fn overlay_version(&self) -> u64 {
        self.delta.version
    }

    /// The effective Eq.-2 study-region area of the live set (mirrors
    /// [`Dataset::build`]'s default).
    pub fn area(&self) -> f64 {
        self.area_override
            .unwrap_or_else(|| self.live_bounds.area().max(f64::MIN_POSITIVE))
    }

    /// Expected NN distance (Eq. 2) recomputed from the live count and
    /// bounds — what the frozen `Dataset::r_exp` cannot track.
    pub fn r_exp(&self) -> f64 {
        alpha::expected_nn_distance(self.live_len as f64, self.area())
    }

    /// Borrowed view for the merged kNN search.
    pub fn merged_view(&self) -> MergedView<'_> {
        MergedView {
            grid: &self.base.grid,
            base_dead: &self.delta.base_dead,
            delta_xs: &self.delta.points.xs,
            delta_ys: &self.delta.points.ys,
            delta_dead: &self.delta.delta_dead,
        }
    }

    /// Materialize the live point set (base-live in base order, then live
    /// appends in append order) with the matching ids.  This ordering is
    /// the contract the bit-identity guarantee rests on: a fresh
    /// registration of exactly this point set serves identical values.
    pub fn live_points(&self) -> (PointSet, Vec<u64>) {
        let base = &self.base.points;
        let mut pts = PointSet::with_capacity(self.live_len);
        let mut ids = Vec::with_capacity(self.live_len);
        for i in 0..base.len() {
            if self.delta.base_dead.contains(&(i as u32)) {
                continue;
            }
            pts.push(base.xs[i], base.ys[i], base.zs[i]);
            ids.push(self.base_ids[i]);
        }
        for p in 0..self.delta.points.len() {
            if !self.delta.delta_live(p) {
                continue;
            }
            pts.push(self.delta.points.xs[p], self.delta.points.ys[p], self.delta.points.zs[p]);
            ids.push(self.delta.ids[p]);
        }
        (pts, ids)
    }

    /// Translate a merged candidate index (from
    /// [`crate::knn::merged::merged_knn_topk_on`]) to the point's stable id.
    pub fn merged_index_to_id(&self, idx: u32) -> u64 {
        let n_base = self.base.points.len() as u32;
        if idx < n_base {
            self.base_ids[idx as usize]
        } else {
            self.delta.ids[(idx - n_base) as usize]
        }
    }
}

/// What an append did.
#[derive(Debug, Clone, Copy)]
pub struct AppendOutcome {
    /// First assigned id; the batch occupies `first_id..first_id+count`.
    pub first_id: u64,
    pub count: usize,
    pub epoch: u64,
    pub live_points: usize,
    pub delta_points: usize,
    /// Overlay pressure after the append (compaction trigger metric).
    pub pressure: usize,
    /// The dataset's mutation count *after* this append (see
    /// [`LiveSnapshot::mut_seq`]) — read under the same write lock, so
    /// it names exactly the snapshot this append published.
    pub mut_seq: u64,
}

/// What a remove did.
#[derive(Debug, Clone, Copy)]
pub struct RemoveOutcome {
    pub removed: usize,
    pub epoch: u64,
    pub live_points: usize,
    pub tombstones: usize,
    pub pressure: usize,
    /// The dataset's mutation count *after* this removal (see
    /// [`LiveSnapshot::mut_seq`]).
    pub mut_seq: u64,
}

/// Point-in-time mutation/compaction statistics.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LiveStatus {
    pub epoch: u64,
    pub base_points: usize,
    pub delta_points: usize,
    pub live_appends: usize,
    pub tombstones: usize,
    pub live_points: usize,
    pub next_id: u64,
    pub wal_records: u64,
    pub compactions: u64,
    pub persistent: bool,
    pub compacting: bool,
}

/// What one compaction folded and carried.
#[derive(Debug, Clone, Copy)]
pub struct CompactionReport {
    pub old_epoch: u64,
    pub new_epoch: u64,
    /// Overlay entries folded into the new base.
    pub folded_appends: usize,
    pub folded_tombstones: usize,
    /// Mutations that raced the compaction and survive in the new overlay.
    pub carried_appends: usize,
    pub carried_tombstones: usize,
    /// `Arc` strong references still holding the retired epoch base at
    /// publish time — epoch-retirement verification (1 = nothing but the
    /// report holds it; more = in-flight batches still draining).
    pub retired_refs: usize,
    /// True when there was nothing to fold.
    pub noop: bool,
}

/// Observability sinks a coordinator attaches after construction (and
/// before registry insert): the structured event journal plus a hook
/// fired after every non-noop compaction publish — background *and*
/// synchronous runs, so downstream consumers (subscription feeds) see
/// one notification per epoch change regardless of who triggered it.
struct LiveObserver {
    journal: Arc<crate::obs::Journal>,
    on_compacted: Box<dyn Fn(&str, &CompactionReport) + Send + Sync>,
}

impl std::fmt::Debug for LiveObserver {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("LiveObserver").finish_non_exhaustive()
    }
}

/// A registered dataset that accepts appends/removals without blocking
/// readers.  See the module docs.
#[derive(Debug)]
pub struct LiveDataset {
    name: String,
    grid_cfg: GridConfig,
    area_override: Option<f64>,
    config: LiveConfig,
    /// The published snapshot; writers briefly take the write lock to
    /// swap in a new `Arc`, readers clone it out.
    // lock-order: live_state
    state: RwLock<Arc<LiveSnapshot>>,
    /// Append-ordered durable log (None = in-memory dataset).
    // lock-order: live_wal
    wal: Mutex<Option<Wal>>,
    dir: Option<PathBuf>,
    next_id: AtomicU64,
    compacting: AtomicBool,
    /// Set by [`LiveDataset::retire`]: no further compaction may touch
    /// the durable files (the registry dropped or replaced this entry).
    retired: AtomicBool,
    /// Serializes actual compaction work (sync `compact` vs background).
    /// Acquired before any other lock on this type: the observed order
    /// is compact_gate < live_state < live_wal, compact_gate <
    /// live_observer.
    // lock-order: compact_gate
    compact_gate: Mutex<()>,
    // lock-order: compact_handle
    compact_handle: Mutex<Option<JoinHandle<()>>>,
    compactions: AtomicU64,
    /// Event journal + compaction hook (None until a coordinator calls
    /// [`LiveDataset::attach_observer`]; standalone datasets run silent).
    // lock-order: live_observer
    observer: RwLock<Option<LiveObserver>>,
}

impl LiveDataset {
    /// In-memory live dataset over a freshly built epoch-0 grid.
    pub fn build(
        pool: &Pool,
        name: &str,
        points: PointSet,
        grid_cfg: &GridConfig,
        area_override: Option<f64>,
        config: LiveConfig,
    ) -> Result<LiveDataset> {
        let n = points.len() as u64;
        let ids: Vec<u64> = (0..n).collect();
        Self::from_epoch(pool, name, points, ids, 0, n, grid_cfg, area_override, config, None, None)
    }

    /// Durable live dataset: writes the epoch-0 snapshot and a fresh WAL
    /// under `dir` before returning.
    pub fn build_persistent(
        pool: &Pool,
        name: &str,
        points: PointSet,
        grid_cfg: &GridConfig,
        area_override: Option<f64>,
        config: LiveConfig,
        dir: &Path,
    ) -> Result<LiveDataset> {
        validate_dataset_name(name)?;
        std::fs::create_dir_all(dir)?;
        let n = points.len() as u64;
        let ids: Vec<u64> = (0..n).collect();
        wal::save_live_snapshot(dir, name, 0, n, &points, &ids, config.wal_sync)?;
        let w = Wal::create_rotating(
            &wal::wal_path(dir, name),
            config.wal_sync,
            config.wal_segment_bytes as u64,
        )?;
        Self::from_epoch(
            pool,
            name,
            points,
            ids,
            0,
            n,
            grid_cfg,
            area_override,
            config,
            Some(dir.to_path_buf()),
            Some(w),
        )
    }

    /// Restore from `dir`: load the last compacted snapshot, replay the
    /// WAL over it (idempotently, trimming any torn tail), and reattach
    /// the WAL for further appends.
    pub fn load(
        pool: &Pool,
        name: &str,
        dir: &Path,
        grid_cfg: &GridConfig,
        area_override: Option<f64>,
        config: LiveConfig,
    ) -> Result<LiveDataset> {
        validate_dataset_name(name)?;
        let snap_file = wal::load_live_snapshot(dir, name)?;
        let path = wal::wal_path(dir, name);
        let readout = wal::read_wal_segments(&path)?;
        let ds = Self::from_epoch(
            pool,
            name,
            snap_file.points,
            snap_file.ids,
            snap_file.epoch,
            snap_file.next_id,
            grid_cfg,
            area_override,
            config,
            Some(dir.to_path_buf()),
            None, // attached below, after replay
        )?;
        for rec in &readout.records {
            ds.replay(rec)?;
        }
        let wal = if readout.existed {
            Wal::open_after_replay_rotating(
                &path,
                config.wal_sync,
                readout.records.len() as u64,
                readout.last_segment,
                readout.clean_len,
                config.wal_segment_bytes as u64,
            )?
        } else {
            Wal::create_rotating(&path, config.wal_sync, config.wal_segment_bytes as u64)?
        };
        *ds.wal.lock().unwrap() = Some(wal);
        Ok(ds)
    }

    #[allow(clippy::too_many_arguments)]
    fn from_epoch(
        pool: &Pool,
        name: &str,
        points: PointSet,
        ids: Vec<u64>,
        epoch: u64,
        next_id: u64,
        grid_cfg: &GridConfig,
        area_override: Option<f64>,
        config: LiveConfig,
        dir: Option<PathBuf>,
        wal: Option<Wal>,
    ) -> Result<LiveDataset> {
        if points.len() != ids.len() {
            return Err(Error::InvalidArgument(format!(
                "dataset '{name}': {} points but {} ids",
                points.len(),
                ids.len()
            )));
        }
        let base = Arc::new(Dataset::build(pool, name, points, grid_cfg, area_override)?);
        let live_bounds = base.points.bounds();
        let live_len = base.points.len();
        let snap = LiveSnapshot {
            epoch,
            base,
            base_ids: Arc::new(ids),
            delta: Arc::new(DeltaOverlay::default()),
            live_bounds,
            live_len,
            area_override,
            mut_seq: 0,
        };
        Ok(LiveDataset {
            name: name.to_string(),
            grid_cfg: *grid_cfg,
            area_override,
            config,
            state: RwLock::new(Arc::new(snap)),
            wal: Mutex::new(wal),
            dir,
            next_id: AtomicU64::new(next_id),
            compacting: AtomicBool::new(false),
            retired: AtomicBool::new(false),
            compact_gate: Mutex::new(()),
            compact_handle: Mutex::new(None),
            compactions: AtomicU64::new(0),
            observer: RwLock::new(None),
        })
    }

    /// Attach the structured event journal and a compaction-completion
    /// hook.  Called once by the owning coordinator before the dataset
    /// becomes reachable; later mutations/compactions journal through it.
    pub fn attach_observer(
        &self,
        journal: Arc<crate::obs::Journal>,
        on_compacted: impl Fn(&str, &CompactionReport) + Send + Sync + 'static,
    ) {
        *self.observer.write().unwrap() =
            Some(LiveObserver { journal, on_compacted: Box::new(on_compacted) });
    }

    /// The attached journal, if any (background threads clone it out so
    /// they never hold the observer lock across IO).
    fn journal(&self) -> Option<Arc<crate::obs::Journal>> {
        self.observer.read().unwrap().as_ref().map(|o| o.journal.clone())
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn config(&self) -> &LiveConfig {
        &self.config
    }

    /// The current published snapshot (the reader entry point).
    pub fn snapshot(&self) -> Arc<LiveSnapshot> {
        self.state.read().unwrap().clone()
    }

    /// Current epoch (what batch admission keys on).
    pub fn epoch(&self) -> u64 {
        self.state.read().unwrap().epoch
    }

    /// True when the overlay is non-empty (queries take the merged path).
    pub fn is_mutated(&self) -> bool {
        !self.state.read().unwrap().delta.is_empty()
    }

    /// Append points; assigns consecutive stable ids and logs to the WAL
    /// before publishing.
    pub fn append(&self, pts: &PointSet) -> Result<AppendOutcome> {
        self.apply_append(None, pts, true)
    }

    /// Tombstone live points by id.  Strict: every id must be live, or
    /// the whole request is rejected and nothing mutates.
    pub fn remove(&self, ids: &[u64]) -> Result<RemoveOutcome> {
        Ok(self.apply_remove(ids, true, true, false)?.0)
    }

    /// [`remove`](Self::remove), additionally reporting each victim's
    /// coordinates.  The trace is resolved from the id indexes under the
    /// same write lock that applies the tombstones — O(ids · log n) and
    /// exact even under concurrent mutation — so it is the
    /// dirty-footprint feed for raster subscriptions.
    pub fn remove_traced(&self, ids: &[u64]) -> Result<(RemoveOutcome, Vec<(f64, f64)>)> {
        let (out, coords) = self.apply_remove(ids, true, true, true)?;
        Ok((out, coords.unwrap_or_default()))
    }

    /// Shared append core.  `explicit_ids` is the replay path (ids from
    /// the log, possibly non-contiguous after per-point dedup); `None`
    /// assigns a fresh consecutive range under the write lock.
    fn apply_append(
        &self,
        explicit_ids: Option<&[u64]>,
        pts: &PointSet,
        log: bool,
    ) -> Result<AppendOutcome> {
        if pts.is_empty() {
            return Err(Error::InvalidArgument("append of zero points".into()));
        }
        for v in pts.xs.iter().chain(&pts.ys).chain(&pts.zs) {
            if !v.is_finite() {
                return Err(Error::InvalidArgument("non-finite coordinate in append".into()));
            }
        }
        let mut state = self.state.write().unwrap();
        let cur = state.clone();
        let ids: Vec<u64> = match explicit_ids {
            Some(ids) => ids.to_vec(),
            None => {
                let first = self.next_id.load(Ordering::SeqCst);
                (first..first + pts.len() as u64).collect()
            }
        };
        let first_id = ids[0];
        // WAL before memory: an IO failure must leave the dataset
        // unchanged (public appends are contiguous by construction, so
        // one record with first_id covers the whole batch)
        if log {
            if let Some(w) = self.wal.lock().unwrap().as_mut() {
                let seg_before = w.segment_index();
                w.append(&WalRecord::Append { first_id, points: pts.clone() })?;
                let seg = w.segment_index();
                if seg != seg_before {
                    if let Some(j) = self.journal() {
                        j.info(
                            "wal_rotate",
                            Some(&self.name),
                            format!("segment {seg_before} -> {seg}"),
                        );
                    }
                }
            }
        }
        self.next_id.fetch_max(ids[ids.len() - 1] + 1, Ordering::SeqCst);
        let delta = Arc::new(cur.delta.with_appends(pts, &ids));
        let mut bounds = cur.live_bounds;
        for i in 0..pts.len() {
            bounds.extend(pts.xs[i], pts.ys[i]);
        }
        let snap = LiveSnapshot {
            epoch: cur.epoch,
            base: cur.base.clone(),
            base_ids: cur.base_ids.clone(),
            live_bounds: bounds,
            live_len: cur.live_len + pts.len(),
            area_override: cur.area_override,
            delta,
            mut_seq: cur.mut_seq + 1,
        };
        let out = AppendOutcome {
            first_id,
            count: pts.len(),
            epoch: snap.epoch,
            live_points: snap.live_len,
            delta_points: snap.delta.points.len(),
            pressure: snap.delta.pressure(),
            mut_seq: snap.mut_seq,
        };
        *state = Arc::new(snap);
        Ok(out)
    }

    fn resolve_live(&self, snap: &LiveSnapshot, id: u64) -> Option<LiveLocation> {
        if let Ok(pos) = snap.base_ids.binary_search(&id) {
            let idx = pos as u32;
            if snap.delta.base_dead.contains(&idx) {
                None
            } else {
                Some(LiveLocation::Base(idx))
            }
        } else if let Some(pos) = snap.delta.find_id(id) {
            if snap.delta.delta_live(pos as usize) {
                Some(LiveLocation::Delta(pos))
            } else {
                None
            }
        } else {
            None
        }
    }

    fn apply_remove(
        &self,
        ids: &[u64],
        log: bool,
        strict: bool,
        trace_coords: bool,
    ) -> Result<(RemoveOutcome, Option<Vec<(f64, f64)>>)> {
        if ids.is_empty() {
            return Err(Error::InvalidArgument("remove of zero ids".into()));
        }
        let mut state = self.state.write().unwrap();
        let cur = state.clone();
        let mut removals = Vec::with_capacity(ids.len());
        let mut seen = HashSet::with_capacity(ids.len());
        for &id in ids {
            let duplicate = !seen.insert(id);
            match self.resolve_live(&cur, id) {
                Some(loc) if !duplicate => removals.push((id, loc)),
                _ if strict => {
                    return Err(Error::InvalidArgument(format!(
                        "id {id} is not a live point of dataset '{}'",
                        self.name
                    )));
                }
                _ => {} // replay: already applied — skip
            }
        }
        if removals.is_empty() {
            // replay no-op
            return Ok((
                RemoveOutcome {
                    removed: 0,
                    epoch: cur.epoch,
                    live_points: cur.live_len,
                    tombstones: cur.delta.tombstones.len(),
                    pressure: cur.delta.pressure(),
                    mut_seq: cur.mut_seq,
                },
                trace_coords.then(Vec::new),
            ));
        }
        if cur.live_len <= removals.len() {
            return Err(Error::InvalidArgument(format!(
                "removing {} point(s) would leave dataset '{}' empty",
                removals.len(),
                self.name
            )));
        }
        if log {
            let logged: Vec<u64> = removals.iter().map(|&(id, _)| id).collect();
            if let Some(w) = self.wal.lock().unwrap().as_mut() {
                let seg_before = w.segment_index();
                w.append(&WalRecord::Remove { ids: logged })?;
                let seg = w.segment_index();
                if seg != seg_before {
                    if let Some(j) = self.journal() {
                        j.info(
                            "wal_rotate",
                            Some(&self.name),
                            format!("segment {seg_before} -> {seg}"),
                        );
                    }
                }
            }
        }
        let delta = Arc::new(cur.delta.with_removals(&removals));
        let coord_of = |loc: LiveLocation| match loc {
            LiveLocation::Base(i) => {
                (cur.base.points.xs[i as usize], cur.base.points.ys[i as usize])
            }
            LiveLocation::Delta(p) => {
                (cur.delta.points.xs[p as usize], cur.delta.points.ys[p as usize])
            }
        };
        let trace =
            trace_coords.then(|| removals.iter().map(|&(_, loc)| coord_of(loc)).collect());
        // the bounds shrink only if a removed point sat on the rectangle;
        // recompute exactly in that case (O(live), rare)
        let mut bounds = cur.live_bounds;
        let on_boundary = removals.iter().any(|&(_, loc)| {
            let (x, y) = coord_of(loc);
            x == bounds.min_x || x == bounds.max_x || y == bounds.min_y || y == bounds.max_y
        });
        if on_boundary {
            bounds = live_bounds_of(&cur.base.points, &delta);
        }
        let snap = LiveSnapshot {
            epoch: cur.epoch,
            base: cur.base.clone(),
            base_ids: cur.base_ids.clone(),
            live_bounds: bounds,
            live_len: cur.live_len - removals.len(),
            area_override: cur.area_override,
            delta,
            mut_seq: cur.mut_seq + 1,
        };
        let out = RemoveOutcome {
            removed: removals.len(),
            epoch: snap.epoch,
            live_points: snap.live_len,
            tombstones: snap.delta.tombstones.len(),
            pressure: snap.delta.pressure(),
            mut_seq: snap.mut_seq,
        };
        *state = Arc::new(snap);
        Ok((out, trace))
    }

    /// Idempotent application of one replayed WAL record.
    fn replay(&self, rec: &WalRecord) -> Result<()> {
        match rec {
            WalRecord::Append { first_id, points } => {
                // keep the id counter ahead even when skipping everything
                self.next_id
                    .fetch_max(first_id + points.len() as u64, Ordering::SeqCst);
                // per-point idempotency: a crash between the compaction
                // snapshot rename and the WAL reset leaves records whose
                // batches are *partially* folded (a folded-then-removed id
                // is in neither the base nor the delta).  Re-add exactly
                // the absent ids; the Remove records that follow in the
                // log re-tombstone any that were dead before the crash.
                let snap = self.snapshot();
                let mut pts = PointSet::default();
                let mut ids = Vec::new();
                for i in 0..points.len() {
                    let id = first_id + i as u64;
                    let present = snap.base_ids.binary_search(&id).is_ok()
                        || snap.delta.find_id(id).is_some();
                    if !present {
                        pts.push(points.xs[i], points.ys[i], points.zs[i]);
                        ids.push(id);
                    }
                }
                if pts.is_empty() {
                    return Ok(()); // fully folded already
                }
                self.apply_append(Some(&ids), &pts, false).map(|_| ())
            }
            WalRecord::Remove { ids } => self.apply_remove(ids, false, false, false).map(|_| ()),
        }
    }

    /// Synchronously fold the overlay into a new epoch base, publish it
    /// (memory + disk), and truncate the WAL to the mutations that raced
    /// this compaction.  The grid rebuild, snapshot write, and fresh-WAL
    /// staging all run off the state lock; the write-lock section is the
    /// overlay diff, the (rare, small) carried-record appends, one
    /// rename, and the pointer swap.
    pub fn compact_now(&self) -> Result<CompactionReport> {
        let _gate = self.compact_gate.lock().unwrap();
        let snap = self.snapshot();
        if self.retired.load(Ordering::SeqCst) || snap.delta.is_empty() {
            return Ok(CompactionReport {
                old_epoch: snap.epoch,
                new_epoch: snap.epoch,
                folded_appends: 0,
                folded_tombstones: 0,
                carried_appends: 0,
                carried_tombstones: 0,
                retired_refs: 0,
                noop: true,
            });
        }
        if let Some(j) = self.journal() {
            j.info(
                "compaction_start",
                Some(&self.name),
                format!(
                    "epoch {} pressure {:.3} ({} appends, {} tombstones)",
                    snap.epoch,
                    snap.delta.pressure(),
                    snap.delta.points.len(),
                    snap.delta.tombstones.len()
                ),
            );
        }
        // 1. rebuild off-lock from the captured snapshot
        let (merged, merged_ids) = snap.live_points();
        let new_epoch = snap.epoch + 1;
        let base = Arc::new(Dataset::build(
            crate::pool::global(),
            &self.name,
            merged,
            &self.grid_cfg,
            self.area_override,
        )?);
        let base_ids = Arc::new(merged_ids);
        // 2. durable publish (atomic rename) before the in-memory swap; a
        //    crash after this point is healed by idempotent WAL replay.
        //    The replacement WAL is *staged* here too (file create +
        //    header + fsync off the hot lock); only the carried-record
        //    appends and the rename happen under the lock below.
        let mut staged_wal = match &self.dir {
            Some(dir) => {
                wal::save_live_snapshot(
                    dir,
                    &self.name,
                    new_epoch,
                    self.next_id.load(Ordering::SeqCst),
                    &base.points,
                    &base_ids,
                    self.config.wal_sync,
                )?;
                Some(wal::StagedWal::stage_rotating(
                    &wal::wal_path(dir, &self.name),
                    self.config.wal_sync,
                    self.config.wal_segment_bytes as u64,
                )?)
            }
            None => None,
        };
        // 3. swap: diff the overlay now against the captured one — the
        //    in-epoch append-only invariant makes this a suffix + a
        //    tombstone set difference
        let mut state = self.state.write().unwrap();
        let cur = state.clone();
        let captured_appends = snap.delta.points.len();
        let mut delta = DeltaOverlay::default();
        for p in captured_appends..cur.delta.points.len() {
            delta.points.push(
                cur.delta.points.xs[p],
                cur.delta.points.ys[p],
                cur.delta.points.zs[p],
            );
            delta.ids.push(cur.delta.ids[p]);
        }
        let mut carried_tombs: Vec<u64> = cur
            .delta
            .tombstones
            .difference(&snap.delta.tombstones)
            .copied()
            .collect();
        carried_tombs.sort_unstable();
        for &t in &carried_tombs {
            delta.tombstones.insert(t);
            if let Ok(pos) = base_ids.binary_search(&t) {
                delta.base_dead.insert(pos as u32);
            } else if let Some(pos) = delta.find_id(t) {
                delta.delta_dead.insert(pos);
            }
        }
        // the carried overlay is a fresh chain head: its version must be
        // non-zero exactly when it carries mutations, so a post-publish
        // snapshot with racing mutations can never collide with the
        // compacted (version 0) identity of the same epoch
        delta.version = (delta.points.len() + carried_tombs.len()) as u64;
        // reset the WAL to exactly the carried overlay: one append record
        // per contiguous id run (runs are whole append batches in
        // practice, but replayed WALs may carry gaps).  The records are
        // group-committed — one write, one fsync — instead of paying a
        // `sync_data` per record under `wal_sync`.
        if let Some(staged) = staged_wal.as_mut() {
            let mut carried_records = Vec::new();
            let mut run_start = 0usize;
            for p in 0..=delta.points.len() {
                let run_ends = p == delta.points.len()
                    || (p > run_start && delta.ids[p] != delta.ids[p - 1] + 1);
                if run_ends {
                    if run_start < p {
                        let mut pts = PointSet::with_capacity(p - run_start);
                        for q in run_start..p {
                            pts.push(delta.points.xs[q], delta.points.ys[q], delta.points.zs[q]);
                        }
                        carried_records.push(WalRecord::Append {
                            first_id: delta.ids[run_start],
                            points: pts,
                        });
                    }
                    run_start = p;
                }
            }
            if !carried_tombs.is_empty() {
                carried_records.push(WalRecord::Remove { ids: carried_tombs.clone() });
            }
            staged.append_batch(&carried_records)?;
        }
        if let Some(staged) = staged_wal.take() {
            let mut guard = self.wal.lock().unwrap();
            *guard = Some(staged.publish()?);
            // The fresh WAL re-seeds segment 0, so every rotated sibling
            // now holds only folded history — delete them while holding
            // the WAL lock (no concurrent append can rotate into a
            // doomed segment).  A crash between the rename and this
            // cleanup leaves stale segments that replay *after* the
            // fresh carried records; that is safe by case analysis on
            // any id in a stale Append record: (a) appended before the
            // compaction capture -> folded into the new base, so the
            // per-point replay sees it present (tombstoned base ids stay
            // in base_ids) and skips it; (b) appended after the capture
            // -> re-logged as a carried record in fresh segment 0, so it
            // is already in the delta (find_id sees tombstoned entries)
            // and skips; (c) its Append record sat in the replaced
            // segment 0 -> the record is gone, nothing replays.  A
            // pre-capture append+remove pair that was folded *away*
            // replays as re-add-then-re-remove because the Remove record
            // always sits at or after the Append in the surviving
            // suffix.  (Pinned by the crash-window regression tests.)
            if let Some(dir) = &self.dir {
                wal::remove_rotated_segments(&wal::wal_path(dir, &self.name));
            }
            drop(guard);
        }
        let report = CompactionReport {
            old_epoch: snap.epoch,
            new_epoch,
            folded_appends: captured_appends,
            folded_tombstones: snap.delta.tombstones.len(),
            carried_appends: delta.points.len(),
            carried_tombstones: carried_tombs.len(),
            // the epoch being retired: the captured snapshot's base
            retired_refs: Arc::strong_count(&cur.base),
            noop: false,
        };
        *state = Arc::new(LiveSnapshot {
            epoch: new_epoch,
            base,
            base_ids,
            delta: Arc::new(delta),
            live_bounds: cur.live_bounds,
            live_len: cur.live_len,
            area_override: cur.area_override,
            // compaction is not a mutation: the ledger carries across the
            // fold (racing mutations already bumped `cur`'s count)
            mut_seq: cur.mut_seq,
        });
        drop(state);
        self.compactions.fetch_add(1, Ordering::SeqCst);
        // journal + completion hook after publish: observers see the new
        // epoch the moment they react.  Fires for sync and background
        // runs alike — this is the single compaction-completion signal.
        if let Some(obs) = self.observer.read().unwrap().as_ref() {
            obs.journal.info(
                "compaction_finish",
                Some(&self.name),
                format!(
                    "epoch {} -> {} (folded {}+{}, carried {}+{})",
                    report.old_epoch,
                    report.new_epoch,
                    report.folded_appends,
                    report.folded_tombstones,
                    report.carried_appends,
                    report.carried_tombstones
                ),
            );
            (obs.on_compacted)(&self.name, &report);
        }
        Ok(report)
    }

    /// Spawn a background compaction when auto-compaction is on, the
    /// pressure threshold is crossed, and none is already running.
    /// Returns whether one was spawned.
    pub fn maybe_spawn_compaction(this: &Arc<LiveDataset>) -> bool {
        if !this.config.auto_compact {
            return false;
        }
        if this.snapshot().delta.pressure() < this.config.compact_threshold {
            return false;
        }
        if this.compacting.swap(true, Ordering::SeqCst) {
            return false; // already running
        }
        let mut slot = this.compact_handle.lock().unwrap();
        if let Some(h) = slot.take() {
            let _ = h.join(); // previous run already finished (flag was clear)
        }
        let me = this.clone();
        match std::thread::Builder::new()
            .name("aidw-compact".into())
            .spawn(move || {
                if let Err(e) = me.compact_now() {
                    // swallowed before PR 7: a failed background fold now
                    // leaves an Error event queryable via the `events` op
                    match me.journal() {
                        Some(j) => {
                            j.error(
                                "compaction_fail",
                                Some(&me.name),
                                format!("background compaction failed: {e}"),
                            );
                        }
                        // tidy:allow(print_hygiene) -- standalone dataset: no journal is attached, stderr is the only sink for a failed background fold
                        None => eprintln!(
                            "aidw: background compaction of '{}' failed: {e}",
                            me.name
                        ),
                    }
                }
                me.compacting.store(false, Ordering::SeqCst);
            }) {
            Ok(h) => {
                *slot = Some(h);
                true
            }
            Err(_) => {
                this.compacting.store(false, Ordering::SeqCst);
                false
            }
        }
    }

    /// Mutation/compaction statistics.
    pub fn status(&self) -> LiveStatus {
        let snap = self.snapshot();
        LiveStatus {
            epoch: snap.epoch,
            base_points: snap.base.points.len(),
            delta_points: snap.delta.points.len(),
            live_appends: snap.delta.live_appends(),
            tombstones: snap.delta.tombstones.len(),
            live_points: snap.live_len,
            next_id: self.next_id.load(Ordering::SeqCst),
            wal_records: self.wal.lock().unwrap().as_ref().map(|w| w.records()).unwrap_or(0),
            compactions: self.compactions.load(Ordering::SeqCst),
            persistent: self.dir.is_some(),
            compacting: self.compacting.load(Ordering::SeqCst),
        }
    }

    /// Permanently detach this dataset from its durable files: after
    /// `retire` returns, no compaction (background or an in-flight
    /// synchronous one on another thread) will write the `.live`/`.wal`
    /// files again, so the caller can safely delete or overwrite them.
    /// Registry drop/replace paths call this before touching the disk.
    pub fn retire(&self) {
        self.retired.store(true, Ordering::SeqCst);
        self.shutdown();
        // wait out any synchronous compact_now already past the retired
        // check — it holds the gate for its whole run, publish included
        drop(self.compact_gate.lock().unwrap());
    }

    /// Join any in-flight background compaction (shutdown hygiene —
    /// temp-dir tests and clean process exit must not race the WAL).
    pub fn shutdown(&self) {
        if let Some(h) = self.compact_handle.lock().unwrap().take() {
            if h.thread().id() == std::thread::current().id() {
                // the compactor itself dropped the last Arc: joining
                // ourselves would deadlock, and there is nothing to wait
                // for — the compaction already finished
                return;
            }
            let _ = h.join();
        }
    }

    /// The k nearest live points per query as ascending `(d2, stable id)`
    /// pairs — the oracle the incremental-vs-rebuild property test uses.
    pub fn knn_topk_ids(
        &self,
        pool: &Pool,
        queries: &[(f64, f64)],
        k: usize,
    ) -> Vec<Vec<(f64, u64)>> {
        let snap = self.snapshot();
        let view = snap.merged_view();
        crate::knn::merged::merged_knn_topk_on(pool, &view, queries, k)
            .into_iter()
            .map(|row| {
                row.into_iter()
                    .map(|(d2, idx)| (d2, snap.merged_index_to_id(idx)))
                    .collect()
            })
            .collect()
    }
}

impl Drop for LiveDataset {
    fn drop(&mut self) {
        self.shutdown();
    }
}

/// Exact bounds of the live point set (base minus tombstones, plus live
/// appends), in the same fold order the fresh-registration path uses.
fn live_bounds_of(base: &PointSet, delta: &DeltaOverlay) -> Aabb {
    let mut b = Aabb::EMPTY;
    for i in 0..base.len() {
        if delta.base_dead.contains(&(i as u32)) {
            continue;
        }
        b.extend(base.xs[i], base.ys[i]);
    }
    for p in 0..delta.points.len() {
        if delta.delta_live(p) {
            b.extend(delta.points.xs[p], delta.points.ys[p]);
        }
    }
    b
}

/// Stage-2 dense weighting over the live set: Eq.-1 sums over base-live
/// points in base order, then live appends in append order — the exact
/// summation sequence `weighted_stage_on` would use over the materialized
/// merged set, so live answers are bit-identical to a fresh registration.
pub fn merged_weighted_stage_on(
    pool: &Pool,
    snap: &LiveSnapshot,
    queries: &[(f64, f64)],
    alphas: &[f64],
) -> Vec<f64> {
    assert_eq!(queries.len(), alphas.len());
    let base = &snap.base.points;
    let delta = &snap.delta;
    let no_base_dead = delta.base_dead.is_empty();
    let mut out = vec![0f64; queries.len()];
    pool.for_each_slice_mut(&mut out, 16, |offset, chunk| {
        for (j, slot) in chunk.iter_mut().enumerate() {
            let (qx, qy) = queries[offset + j];
            let a = alphas[offset + j];
            let mut sw = 0.0f64;
            let mut swz = 0.0f64;
            if no_base_dead {
                for i in 0..base.len() {
                    let d2 = dist2(qx, qy, base.xs[i], base.ys[i]).max(EPS_D2);
                    let w = (-0.5 * a * d2.ln()).exp();
                    sw += w;
                    swz += w * base.zs[i];
                }
            } else {
                for i in 0..base.len() {
                    if delta.base_dead.contains(&(i as u32)) {
                        continue;
                    }
                    let d2 = dist2(qx, qy, base.xs[i], base.ys[i]).max(EPS_D2);
                    let w = (-0.5 * a * d2.ln()).exp();
                    sw += w;
                    swz += w * base.zs[i];
                }
            }
            for p in 0..delta.points.len() {
                if !delta.delta_live(p) {
                    continue;
                }
                let d2 =
                    dist2(qx, qy, delta.points.xs[p], delta.points.ys[p]).max(EPS_D2);
                let w = (-0.5 * a * d2.ln()).exp();
                sw += w;
                swz += w * delta.points.zs[p];
            }
            *slot = swz / sw;
        }
    });
    out
}

/// Local (A5) stage 2 over the live set: Eq.-1 weighting restricted to
/// each query's gathered neighbors, with **merged candidate indices**
/// (from [`crate::knn::merged::merged_knn_neighbors_on`]) resolved into
/// base/delta coordinates.  Rows are consumed in the table's
/// ascending-distance order — the same summation sequence
/// [`crate::aidw::plan::local_weighted_on`] uses over a compacted index,
/// so merged local answers are bit-identical to a post-compaction run
/// over the same live set (pinned by `tests/it_live.rs`).
pub fn merged_local_weighted_on(
    pool: &Pool,
    snap: &LiveSnapshot,
    queries: &[(f64, f64)],
    alphas: &[f64],
    nbr_idx: &[u32],
    width: usize,
) -> Vec<f64> {
    merged_local_weighted_layout_on(
        pool,
        snap,
        queries,
        alphas,
        nbr_idx,
        width,
        crate::aidw::plan::Layout::Aos,
    )
}

/// Layout-parameterized twin of [`merged_local_weighted_on`]: the same
/// merged-index resolution plugged into the layout-dispatching A5 kernel
/// ([`crate::aidw::plan::local_weighted_with_layout`]) — `Aos` is the
/// scalar reference, the blocked layouts gather each row's live
/// neighbors into per-worker columnar scratch first.  Bit-identical for
/// every layout.
pub fn merged_local_weighted_layout_on(
    pool: &Pool,
    snap: &LiveSnapshot,
    queries: &[(f64, f64)],
    alphas: &[f64],
    nbr_idx: &[u32],
    width: usize,
    layout: crate::aidw::plan::Layout,
) -> Vec<f64> {
    let base = &snap.base.points;
    let n_base = base.len() as u32;
    let delta = &snap.delta;
    // the one shared A5 kernel, with merged-index resolution plugged in
    crate::aidw::plan::local_weighted_with_layout(
        pool,
        queries,
        alphas,
        nbr_idx,
        width,
        layout,
        |pid| {
            if pid < n_base {
                let i = pid as usize;
                (base.xs[i], base.ys[i], base.zs[i])
            } else {
                let p = (pid - n_base) as usize;
                (delta.points.xs[p], delta.points.ys[p], delta.points.zs[p])
            }
        },
    )
}

/// Layout-parameterized twin of [`merged_weighted_stage_on`].  For the
/// blocked layouts the live appends are gathered into columnar scratch
/// **once per call** (append order preserved, off the per-row path) and
/// handed to the shared blocked dense core as the tail range, so each
/// row still sums base-live points in base order then live appends in
/// append order — bit-identical to the scalar merged reference.
/// Tombstoned bases (`base_dead` non-empty — a transient state between
/// delete and compaction) fall back to the scalar reference: soundness
/// over cleverness, same as the subscription dirty bound.
pub fn merged_weighted_stage_layout_on(
    pool: &Pool,
    snap: &LiveSnapshot,
    queries: &[(f64, f64)],
    alphas: &[f64],
    layout: crate::aidw::plan::Layout,
) -> Vec<f64> {
    use crate::aidw::plan::Layout;
    let delta = &snap.delta;
    if layout == Layout::Aos || !delta.base_dead.is_empty() {
        return merged_weighted_stage_on(pool, snap, queries, alphas);
    }
    let n_delta_live = (0..delta.points.len()).filter(|&p| delta.delta_live(p)).count();
    let mut dx = Vec::with_capacity(n_delta_live);
    let mut dy = Vec::with_capacity(n_delta_live);
    let mut dz = Vec::with_capacity(n_delta_live);
    for p in 0..delta.points.len() {
        if delta.delta_live(p) {
            dx.push(delta.points.xs[p]);
            dy.push(delta.points.ys[p]);
            dz.push(delta.points.zs[p]);
        }
    }
    crate::aidw::pipeline::blocked_dense_on(
        pool,
        snap.base.points.columns(),
        Columns::new(&dx, &dy, &dz),
        queries,
        alphas,
        layout.micro_width(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("aidw_live_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    fn build_mem(n: usize, seed: u64) -> LiveDataset {
        let pool = Pool::new(2);
        let pts = workload::uniform_square(n, 50.0, seed);
        LiveDataset::build(&pool, "d", pts, &GridConfig::default(), None, LiveConfig::default())
            .unwrap()
    }

    #[test]
    fn append_remove_bookkeeping() {
        let ds = build_mem(100, 801);
        assert_eq!(ds.epoch(), 0);
        assert!(!ds.is_mutated());
        let extra = workload::uniform_square(10, 50.0, 802);
        let a = ds.append(&extra).unwrap();
        assert_eq!(a.first_id, 100);
        assert_eq!(a.count, 10);
        assert_eq!(a.live_points, 110);
        assert!(ds.is_mutated());
        // remove one base point and one appended point
        let r = ds.remove(&[5, 103]).unwrap();
        assert_eq!(r.removed, 2);
        assert_eq!(r.live_points, 108);
        assert_eq!(r.tombstones, 2);
        // strict semantics: unknown, double-remove, and duplicate ids fail
        assert!(ds.remove(&[5]).is_err(), "already removed");
        assert!(ds.remove(&[9999]).is_err(), "unknown id");
        assert!(ds.remove(&[7, 7]).is_err(), "duplicate in one request");
        // the failed request mutated nothing
        assert_eq!(ds.status().live_points, 108);
        let st = ds.status();
        assert_eq!(st.epoch, 0);
        assert_eq!(st.base_points, 100);
        assert_eq!(st.delta_points, 10);
        assert_eq!(st.next_id, 110);
        assert!(!st.persistent);
    }

    #[test]
    fn cannot_remove_every_live_point() {
        let pool = Pool::new(1);
        let pts = workload::uniform_square(3, 10.0, 803);
        let ds = LiveDataset::build(
            &pool,
            "d",
            pts,
            &GridConfig::default(),
            None,
            LiveConfig::default(),
        )
        .unwrap();
        assert!(ds.remove(&[0, 1, 2]).is_err());
        ds.remove(&[0, 1]).unwrap();
        assert!(ds.remove(&[2]).is_err(), "last live point is protected");
    }

    #[test]
    fn snapshot_isolation_across_mutations() {
        let ds = build_mem(50, 804);
        let before = ds.snapshot();
        ds.append(&workload::uniform_square(5, 50.0, 805)).unwrap();
        ds.remove(&[0]).unwrap();
        assert_eq!(before.live_len, 50, "held snapshot is immutable");
        assert!(before.delta.is_empty());
        assert_eq!(ds.snapshot().live_len, 54);
    }

    #[test]
    fn compaction_bumps_epoch_and_preserves_live_set() {
        let ds = build_mem(200, 806);
        let extra = workload::uniform_square(30, 50.0, 807);
        ds.append(&extra).unwrap();
        ds.remove(&[3, 7, 201]).unwrap();
        let pool = Pool::new(2);
        let queries = workload::uniform_square(40, 50.0, 808).xy();
        let before = ds.knn_topk_ids(&pool, &queries, 10);
        let (live_before, ids_before) = ds.snapshot().live_points();

        let rep = ds.compact_now().unwrap();
        assert!(!rep.noop);
        assert_eq!((rep.old_epoch, rep.new_epoch), (0, 1));
        assert_eq!(rep.folded_appends, 30);
        assert_eq!(rep.folded_tombstones, 3);
        assert_eq!(rep.carried_appends, 0);
        assert!(rep.retired_refs >= 1);
        assert_eq!(ds.epoch(), 1);
        assert!(!ds.is_mutated());

        let (live_after, ids_after) = ds.snapshot().live_points();
        assert_eq!(live_before.xs, live_after.xs);
        assert_eq!(live_before.zs, live_after.zs);
        assert_eq!(ids_before, ids_after);
        // kNN ids + distances identical across the epoch swap
        let after = ds.knn_topk_ids(&pool, &queries, 10);
        assert_eq!(before, after);
        // idempotent: nothing left to fold
        assert!(ds.compact_now().unwrap().noop);
        // ids remain stable: removing a pre-compaction id still works
        ds.remove(&[10]).unwrap();
        assert!(ds.remove(&[3]).is_err(), "id folded away stays dead");
    }

    #[test]
    fn overlay_version_tracks_mutations_and_resets_at_compaction() {
        let ds = build_mem(120, 840);
        assert_eq!(ds.snapshot().overlay_version(), 0);
        ds.append(&workload::uniform_square(6, 50.0, 841)).unwrap();
        assert_eq!(ds.snapshot().overlay_version(), 1);
        ds.remove(&[3]).unwrap();
        assert_eq!(ds.snapshot().overlay_version(), 2);
        ds.append(&workload::uniform_square(2, 50.0, 842)).unwrap();
        assert_eq!(ds.snapshot().overlay_version(), 3);
        // full fold: the fresh overlay carries nothing -> version 0
        ds.compact_now().unwrap();
        let snap = ds.snapshot();
        assert_eq!((snap.epoch, snap.overlay_version()), (1, 0));
        assert!(snap.is_compacted());
        // a failed (strict) remove publishes nothing: version unchanged
        assert!(ds.remove(&[3]).is_err());
        assert_eq!(ds.snapshot().overlay_version(), 0);
    }

    #[test]
    fn mut_seq_counts_every_mutation_and_carries_across_compaction() {
        let ds = build_mem(120, 860);
        assert_eq!(ds.snapshot().mut_seq, 0);
        let a = ds.append(&workload::uniform_square(6, 50.0, 861)).unwrap();
        assert_eq!((a.mut_seq, ds.snapshot().mut_seq), (1, 1));
        let r = ds.remove(&[2]).unwrap();
        assert_eq!((r.mut_seq, ds.snapshot().mut_seq), (2, 2));
        // compaction renumbers the overlay version but is not a mutation:
        // the ledger carries across the fold unchanged
        ds.compact_now().unwrap();
        let snap = ds.snapshot();
        assert_eq!((snap.epoch, snap.overlay_version(), snap.mut_seq), (1, 0, 2));
        let a = ds.append(&workload::uniform_square(2, 50.0, 862)).unwrap();
        assert_eq!(a.mut_seq, 3, "the ledger keeps counting in the new epoch");
        // a failed (strict) remove publishes nothing
        assert!(ds.remove(&[2]).is_err());
        assert_eq!(ds.snapshot().mut_seq, 3);
    }

    #[test]
    fn remove_traced_reports_victim_coordinates_from_base_and_delta() {
        let pool = Pool::new(1);
        let mut pts = PointSet::default();
        for i in 0..12 {
            pts.push(i as f64, 2.0 * i as f64, 1.0); // ids 0..12 (base)
        }
        let ds = LiveDataset::build(
            &pool,
            "d",
            pts,
            &GridConfig::default(),
            None,
            LiveConfig::default(),
        )
        .unwrap();
        let mut extra = PointSet::default();
        extra.push(50.0, 60.0, 2.0); // id 12 (delta)
        ds.append(&extra).unwrap();
        let (out, coords) = ds.remove_traced(&[3, 12, 7]).unwrap();
        assert_eq!(out.removed, 3);
        assert_eq!(out.mut_seq, 2);
        // trace order follows the request order, base and delta alike
        assert_eq!(coords, vec![(3.0, 6.0), (50.0, 60.0), (7.0, 14.0)]);
    }

    #[test]
    fn carried_mutations_keep_a_nonzero_overlay_version() {
        // mutations racing a compaction survive in the fresh overlay; its
        // published version must be non-zero so the post-publish mutated
        // state can never alias the compacted (version 0) cache identity
        // of the same epoch.  The writer keeps bumping the version after
        // the publish, so the observable invariant is a lower bound.
        let ds = Arc::new(build_mem(200, 845));
        ds.append(&workload::uniform_square(10, 50.0, 846)).unwrap();
        let writer = {
            let ds = ds.clone();
            std::thread::spawn(move || {
                for i in 0..20 {
                    ds.append(&workload::uniform_square(3, 50.0, 900 + i)).unwrap();
                }
            })
        };
        for _ in 0..10 {
            let rep = ds.compact_now().unwrap();
            let carried = (rep.carried_appends + rep.carried_tombstones) as u64;
            if carried > 0 {
                let snap = ds.snapshot();
                assert!(
                    snap.overlay_version() >= carried,
                    "carried overlay published version 0 ({} carried)",
                    carried
                );
            }
        }
        writer.join().unwrap();
        ds.compact_now().unwrap();
        // regardless of interleavings, a fully-folded overlay is version 0
        let snap = ds.snapshot();
        assert!(snap.is_compacted());
        assert_eq!(snap.overlay_version(), 0);
    }

    #[test]
    fn bounds_shrink_when_boundary_point_removed() {
        let pool = Pool::new(1);
        let mut pts = PointSet::default();
        for i in 0..20 {
            pts.push(i as f64 % 5.0, (i / 5) as f64, 1.0);
        }
        pts.push(100.0, 100.0, 2.0); // the outlier, id 20
        let ds = LiveDataset::build(
            &pool,
            "d",
            pts,
            &GridConfig::default(),
            None,
            LiveConfig::default(),
        )
        .unwrap();
        assert_eq!(ds.snapshot().live_bounds.max_x, 100.0);
        ds.remove(&[20]).unwrap();
        let snap = ds.snapshot();
        assert_eq!(snap.live_bounds.max_x, 4.0);
        assert_eq!(snap.live_bounds.max_y, 3.0);
        // r_exp now reflects the shrunken live region exactly
        let (live, _) = snap.live_points();
        let fresh_area = live.bounds().area().max(f64::MIN_POSITIVE);
        assert_eq!(snap.area(), fresh_area);
    }

    #[test]
    fn persistence_roundtrip_with_wal_replay() {
        let dir = tmpdir("roundtrip");
        let pool = Pool::new(2);
        let pts = workload::uniform_square(120, 50.0, 809);
        let cfg = LiveConfig::default();
        {
            let ds = LiveDataset::build_persistent(
                &pool,
                "d",
                pts.clone(),
                &GridConfig::default(),
                None,
                cfg,
                &dir,
            )
            .unwrap();
            ds.append(&workload::uniform_square(15, 50.0, 810)).unwrap();
            ds.remove(&[2, 11, 130]).unwrap();
            // no graceful save: the WAL is the only record of the mutations
        }
        let back = LiveDataset::load(&pool, "d", &dir, &GridConfig::default(), None, cfg).unwrap();
        let st = back.status();
        assert_eq!(st.epoch, 0);
        assert_eq!(st.live_points, 132);
        assert_eq!(st.tombstones, 3);
        assert_eq!(st.next_id, 135);
        assert_eq!(st.wal_records, 2);
        // a second replay cycle is byte-stable (idempotence)
        drop(back);
        let again = LiveDataset::load(&pool, "d", &dir, &GridConfig::default(), None, cfg).unwrap();
        assert_eq!(again.status().live_points, 132);
        // compaction truncates the WAL and survives restart
        again.compact_now().unwrap();
        assert_eq!(again.status().wal_records, 0);
        drop(again);
        let last = LiveDataset::load(&pool, "d", &dir, &GridConfig::default(), None, cfg).unwrap();
        let st = last.status();
        assert_eq!(st.epoch, 1);
        assert_eq!(st.live_points, 132);
        assert_eq!(st.tombstones, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn wal_rotation_replays_across_segment_boundaries() {
        // ROADMAP PR-4(c): a tiny segment limit forces several rotations
        // mid-feed; restart must replay the whole segment chain in order,
        // and compaction must re-seed segment 0 and delete the siblings
        let dir = tmpdir("rotation");
        let pool = Pool::new(2);
        let cfg = LiveConfig {
            wal_segment_bytes: 256,
            auto_compact: false,
            ..Default::default()
        };
        let base = workload::uniform_square(60, 20.0, 851);
        let wal_base = wal::wal_path(&dir, "d");
        {
            let ds = LiveDataset::build_persistent(
                &pool,
                "d",
                base,
                &GridConfig::default(),
                None,
                cfg,
                &dir,
            )
            .unwrap();
            // each append record is ~ 25 + 24*count bytes; ten 4-point
            // batches (~121 B each) cross the 256 B limit repeatedly
            for b in 0..10 {
                ds.append(&workload::uniform_square(4, 20.0, 860 + b)).unwrap();
            }
            ds.remove(&[0, 61]).unwrap();
            assert!(
                wal::seg_path(&wal_base, 1).exists(),
                "tiny segment limit must have rotated"
            );
            // no graceful save: the segment chain is the only record
        }
        let back =
            LiveDataset::load(&pool, "d", &dir, &GridConfig::default(), None, cfg).unwrap();
        let st = back.status();
        assert_eq!(st.live_points, 98, "60 + 40 appends - 2 removes");
        assert_eq!(st.tombstones, 2);
        assert_eq!(st.wal_records, 11);
        let (live_a, ids_a) = back.snapshot().live_points();
        // a second replay cycle is byte-stable (idempotence across the chain)
        drop(back);
        let again =
            LiveDataset::load(&pool, "d", &dir, &GridConfig::default(), None, cfg).unwrap();
        let (live_b, ids_b) = again.snapshot().live_points();
        assert_eq!(live_a.xs, live_b.xs);
        assert_eq!(live_a.zs, live_b.zs);
        assert_eq!(ids_a, ids_b);
        // appends after restart land on the last segment and keep rotating
        for b in 0..4 {
            again.append(&workload::uniform_square(4, 20.0, 880 + b)).unwrap();
        }
        // compaction folds everything, re-seeds segment 0, and deletes
        // the obsolete rotated segments
        again.compact_now().unwrap();
        assert_eq!(again.status().wal_records, 0);
        assert!(
            !wal::seg_path(&wal_base, 1).exists(),
            "compaction must delete obsolete segments"
        );
        assert!(wal_base.exists());
        drop(again);
        let last =
            LiveDataset::load(&pool, "d", &dir, &GridConfig::default(), None, cfg).unwrap();
        assert_eq!(last.status().live_points, 114);
        assert_eq!(last.status().epoch, 1);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crash_window_with_stale_rotated_segments_replays_clean() {
        // compaction publishes the fresh segment-0 WAL (rename), then
        // deletes the rotated siblings; a crash between the two leaves
        // stale segments that replay AFTER the fresh carried records.
        // Per-point idempotent replay must heal every case — including a
        // folded-away append+remove pair whose Append record sits in a
        // stale segment (re-add, then the stale Remove re-tombstones).
        let dir = tmpdir("crashrot");
        let pool = Pool::new(2);
        let cfg = LiveConfig {
            wal_segment_bytes: 200,
            auto_compact: false,
            ..Default::default()
        };
        let ds = LiveDataset::build_persistent(
            &pool,
            "d",
            workload::uniform_square(40, 20.0, 869),
            &GridConfig::default(),
            None,
            cfg,
            &dir,
        )
        .unwrap();
        for b in 0..6 {
            ds.append(&workload::uniform_square(4, 20.0, 870 + b)).unwrap(); // ids 40..64
        }
        // ids 49 and 53 live in the 3rd/4th append batches, whose Append
        // records end up in *rotated* (stale-after-crash) segments
        ds.remove(&[49, 53]).unwrap();
        let wal_base = wal::wal_path(&dir, "d");
        let mut stale: Vec<(u64, Vec<u8>)> = Vec::new();
        let mut i = 1u64;
        while wal::seg_path(&wal_base, i).exists() {
            stale.push((i, std::fs::read(wal::seg_path(&wal_base, i)).unwrap()));
            i += 1;
        }
        assert!(stale.len() >= 2, "the feed must have rotated");
        let live_before = ds.snapshot().live_points();

        ds.compact_now().unwrap(); // rename + sibling cleanup both ran...
        for (idx, bytes) in &stale {
            // ...un-delete the siblings: the crash window
            std::fs::write(wal::seg_path(&wal_base, *idx), bytes).unwrap();
        }
        drop(ds);

        let back =
            LiveDataset::load(&pool, "d", &dir, &GridConfig::default(), None, cfg).unwrap();
        let st = back.status();
        assert_eq!(st.live_points, 62, "40 + 24 appends - 2 removes");
        let (live_after, ids_after) = back.snapshot().live_points();
        assert_eq!(live_before.0.xs, live_after.xs, "stale-segment replay is exact");
        assert_eq!(live_before.0.zs, live_after.zs);
        assert_eq!(live_before.1, ids_after);
        let mut uniq = ids_after.clone();
        uniq.sort_unstable();
        uniq.dedup();
        assert_eq!(uniq.len(), 62, "no duplicate resurrections");
        assert!(back.remove(&[49]).is_err(), "folded-away id stays dead");
        assert!(back.remove(&[53]).is_err());
        back.remove(&[50]).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn crash_between_snapshot_publish_and_wal_reset_replays_clean() {
        // the compaction publish sequence is: (1) rename new snapshot,
        // (2) reset WAL.  A crash between the two leaves the *old* WAL
        // next to the *new* snapshot; replay must not resurrect folded
        // points — including the partial-fold case where some ids of an
        // append batch were folded in and others were folded *away* by a
        // pre-compaction removal.
        let dir = tmpdir("crashwin");
        let pool = Pool::new(2);
        let cfg = LiveConfig::default();
        let base = workload::uniform_square(50, 20.0, 821);
        let ds = LiveDataset::build_persistent(
            &pool,
            "d",
            base,
            &GridConfig::default(),
            None,
            cfg,
            &dir,
        )
        .unwrap();
        ds.append(&workload::uniform_square(5, 20.0, 822)).unwrap(); // ids 50..55
        ds.remove(&[50, 7]).unwrap(); // one delta id, one base id
        let wal_file = wal::wal_path(&dir, "d");
        let old_wal = std::fs::read(&wal_file).unwrap();
        let live_before = ds.snapshot().live_points().0;

        ds.compact_now().unwrap(); // snapshot renamed AND WAL reset...
        std::fs::write(&wal_file, &old_wal).unwrap(); // ...un-reset: the crash window
        drop(ds);

        let back = LiveDataset::load(&pool, "d", &dir, &GridConfig::default(), None, cfg).unwrap();
        let st = back.status();
        assert_eq!(st.live_points, 53, "no duplicates, no resurrections");
        let (live_after, ids_after) = back.snapshot().live_points();
        assert_eq!(live_before.xs, live_after.xs, "replay over new snapshot is exact");
        assert_eq!(live_before.zs, live_after.zs);
        // ids are unique and the folded-away ones stay dead
        let mut sorted = ids_after.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(sorted.len(), 53, "every live id appears exactly once");
        assert!(back.remove(&[50]).is_err(), "folded-away delta id stays dead");
        assert!(back.remove(&[7]).is_err(), "folded-away base id stays dead");
        back.remove(&[51]).unwrap();
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn retire_blocks_further_durable_writes() {
        let dir = tmpdir("retire");
        let pool = Pool::new(1);
        let ds = LiveDataset::build_persistent(
            &pool,
            "d",
            workload::uniform_square(40, 10.0, 823),
            &GridConfig::default(),
            None,
            LiveConfig::default(),
            &dir,
        )
        .unwrap();
        ds.append(&workload::uniform_square(4, 10.0, 824)).unwrap();
        ds.retire();
        let rep = ds.compact_now().unwrap();
        assert!(rep.noop, "retired datasets never compact");
        // the registry-side deletion cannot be raced into resurrection
        std::fs::remove_file(wal::live_path(&dir, "d")).unwrap();
        std::fs::remove_file(wal::wal_path(&dir, "d")).unwrap();
        assert!(ds.compact_now().unwrap().noop);
        assert!(!wal::live_path(&dir, "d").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn auto_compaction_triggers_on_pressure() {
        let pool = Pool::new(2);
        let pts = workload::uniform_square(64, 50.0, 811);
        let cfg = LiveConfig { compact_threshold: 8, ..Default::default() };
        let ds = Arc::new(
            LiveDataset::build(&pool, "d", pts, &GridConfig::default(), None, cfg).unwrap(),
        );
        ds.append(&workload::uniform_square(4, 50.0, 812)).unwrap();
        assert!(!LiveDataset::maybe_spawn_compaction(&ds), "below threshold");
        ds.append(&workload::uniform_square(4, 50.0, 813)).unwrap();
        assert!(LiveDataset::maybe_spawn_compaction(&ds));
        ds.shutdown(); // join the background run
        assert_eq!(ds.epoch(), 1);
        assert!(!ds.is_mutated());
        assert_eq!(ds.status().compactions, 1);
    }

    #[test]
    fn merged_local_weighting_is_bit_identical_to_fresh_local() {
        // gather + weight over a mutated snapshot must equal the plain
        // local pipeline over the materialized live set, bit for bit
        let ds = build_mem(500, 830);
        ds.append(&workload::uniform_square(60, 50.0, 831)).unwrap();
        ds.remove(&[3, 77, 502]).unwrap();
        let pool = Pool::new(2);
        let snap = ds.snapshot();
        let queries = workload::uniform_square(40, 50.0, 832).xy();
        let params = crate::aidw::AidwParams::default();
        let n = 32;

        let view = snap.merged_view();
        let (idx, r_obs) =
            crate::knn::merged::merged_knn_neighbors_on(&pool, &view, &queries, n, params.k);
        let r_exp = snap.r_exp();
        let alphas: Vec<f64> = r_obs
            .iter()
            .map(|&ro| alpha::adaptive_alpha(ro, r_exp, &params))
            .collect();
        let got = merged_local_weighted_on(&pool, &snap, &queries, &alphas, &idx, n);

        let (live, _) = snap.live_points();
        let want = crate::aidw::local::interpolate_local_on(
            &pool,
            &live,
            &queries,
            &params,
            &crate::aidw::local::LocalConfig {
                n_neighbors: n,
                rule: crate::knn::grid_knn::RingRule::Exact,
            },
        )
        .unwrap();
        assert_eq!(got, want, "merged local weighting must be exact");
    }

    #[test]
    fn mutations_racing_compaction_are_carried_not_lost() {
        // deterministic version of the race: mutate between the capture
        // and the publish by mutating after snapshot() but calling the
        // internals in the same order compact_now does — here we simply
        // mutate from another thread while compacting repeatedly
        let ds = Arc::new(build_mem(300, 814));
        ds.append(&workload::uniform_square(20, 50.0, 815)).unwrap();
        let writer = {
            let ds = ds.clone();
            std::thread::spawn(move || {
                for i in 0..10 {
                    ds.append(&workload::uniform_square(5, 50.0, 900 + i)).unwrap();
                    ds.remove(&[i]).unwrap();
                }
            })
        };
        for _ in 0..5 {
            ds.compact_now().unwrap();
        }
        writer.join().unwrap();
        ds.compact_now().unwrap();
        let st = ds.status();
        // 300 + 20 + 50 appends − 10 removals
        assert_eq!(st.live_points, 360);
        assert_eq!(st.tombstones, 0, "fully folded");
        let (live, _) = ds.snapshot().live_points();
        assert_eq!(live.len(), 360);
    }
}
