//! Thread-safe name → [`LiveDataset`] map — the live counterpart of
//! [`crate::coordinator::DatasetRegistry`], with the same replace-path
//! contract: `insert` hands back the displaced entry so the caller can
//! retire it deliberately (join its compactor, log the epoch) instead of
//! silently dropping a dataset that may have a background thread and a
//! WAL attached.

use std::collections::HashMap;
use std::sync::{Arc, RwLock};

use crate::error::{Error, Result};

use super::LiveDataset;

/// Thread-safe name -> live dataset map.
#[derive(Debug, Default)]
pub struct LiveRegistry {
    // lock-order: live_registry
    map: RwLock<HashMap<String, Arc<LiveDataset>>>,
}

impl LiveRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Register (or replace) a dataset; returns the displaced entry on
    /// replace.
    pub fn insert(&self, ds: LiveDataset) -> Option<Arc<LiveDataset>> {
        let ds = Arc::new(ds);
        self.map.write().unwrap().insert(ds.name().to_string(), ds)
    }

    /// Fetch by name.
    pub fn get(&self, name: &str) -> Result<Arc<LiveDataset>> {
        self.map
            .read()
            .unwrap()
            .get(name)
            .cloned()
            .ok_or_else(|| Error::UnknownDataset(name.to_string()))
    }

    /// Remove a dataset, returning it so the caller can shut it down.
    pub fn remove(&self, name: &str) -> Option<Arc<LiveDataset>> {
        self.map.write().unwrap().remove(name)
    }

    /// Registered names, sorted.
    pub fn names(&self) -> Vec<String> {
        let mut v: Vec<String> = self.map.read().unwrap().keys().cloned().collect();
        v.sort();
        v
    }

    pub fn len(&self) -> usize {
        self.map.read().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Every registered dataset (snapshot of the map).
    pub fn all(&self) -> Vec<Arc<LiveDataset>> {
        let mut v: Vec<(String, Arc<LiveDataset>)> = self
            .map
            .read()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.clone()))
            .collect();
        v.sort_by(|a, b| a.0.cmp(&b.0));
        v.into_iter().map(|(_, ds)| ds).collect()
    }

    /// Join every dataset's background compactor (coordinator shutdown).
    pub fn shutdown_all(&self) {
        for ds in self.all() {
            ds.shutdown();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::grid::GridConfig;
    use crate::live::LiveConfig;
    use crate::pool::Pool;
    use crate::workload;

    fn build(n: usize, seed: u64) -> LiveDataset {
        let pool = Pool::new(1);
        LiveDataset::build(
            &pool,
            "d",
            workload::uniform_square(n, 10.0, seed),
            &GridConfig::default(),
            None,
            LiveConfig::default(),
        )
        .unwrap()
    }

    #[test]
    fn insert_get_replace_remove() {
        let reg = LiveRegistry::new();
        assert!(reg.is_empty());
        assert!(reg.insert(build(50, 821)).is_none());
        assert_eq!(reg.len(), 1);
        assert_eq!(reg.get("d").unwrap().snapshot().live_len, 50);
        assert!(reg.get("nope").is_err());
        // replace returns the displaced dataset for deliberate retirement
        let old = reg.insert(build(80, 822)).expect("displaced");
        assert_eq!(old.snapshot().live_len, 50);
        old.shutdown();
        assert_eq!(reg.get("d").unwrap().snapshot().live_len, 80);
        assert_eq!(reg.names(), vec!["d".to_string()]);
        let removed = reg.remove("d").expect("was registered");
        removed.shutdown();
        assert!(reg.remove("d").is_none());
        assert!(reg.is_empty());
        reg.shutdown_all();
    }
}
