//! Durability for live datasets: a per-dataset write-ahead log plus a
//! compacted binary snapshot, in the `AIDWSNP1` spirit (little-endian,
//! magic-tagged, no serde).
//!
//! On-disk layout per dataset under the live directory:
//!
//! ```text
//! <name>.live   magic "AIDWLSS1" | u64 epoch | u64 next_id | u64 n
//!               | n×f64 xs | n×f64 ys | n×f64 zs | n×u64 ids
//! <name>.wal    magic "AIDWWAL1" | record*
//! record        u8 tag | u64 payload_len | payload
//!   tag 1       append: u64 first_id | u64 count | count×f64 xs|ys|zs
//!   tag 2       remove: u64 count | count×u64 ids
//! ```
//!
//! Restart replays the WAL over the last compacted snapshot.  Replay is
//! **idempotent** (appends whose ids already exist and removes of absent
//! ids are skipped), which makes the compaction publish sequence safe: a
//! crash between the snapshot rename and the WAL reset merely re-applies
//! records the new snapshot already folded in.  A torn tail (crash mid
//! `write`) is detected and truncated on reopen, never propagated.
//!
//! Writers are unbuffered — one `write_all` per commit — and optionally
//! `sync_data` each commit (`wal_sync`); without sync a flushed record
//! still survives any process kill short of an OS/power failure.  A
//! commit is one [`Wal::append`] (single record) or one
//! [`Wal::append_batch`] **group commit** (all records of one logical
//! mutation written together, then one fsync — the ingest-heavy path's
//! answer to per-record `sync_data` cost).

use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use crate::coordinator::snapshot::validate_dataset_name;
use crate::error::{Error, Result};
use crate::geom::PointSet;

const WAL_MAGIC: &[u8; 8] = b"AIDWWAL1";
const SNAP_MAGIC: &[u8; 8] = b"AIDWLSS1";

const TAG_APPEND: u8 = 1;
const TAG_REMOVE: u8 = 2;

/// Sanity cap shared with the v1 snapshot reader: reject obviously
/// corrupt headers before allocating.
const MAX_PLAUSIBLE: u64 = 1 << 33;

/// One durable mutation.
#[derive(Debug, Clone, PartialEq)]
pub enum WalRecord {
    /// Points appended under consecutive ids starting at `first_id`.
    Append { first_id: u64, points: PointSet },
    /// Live ids tombstoned.
    Remove { ids: Vec<u64> },
}

/// `<dir>/<name>.live` — the compacted snapshot.
pub fn live_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.live"))
}

/// `<dir>/<name>.wal` — the write-ahead log (segment 0; rotation appends
/// `.1`, `.2`, ... siblings — see [`seg_path`]).
pub fn wal_path(dir: &Path, name: &str) -> PathBuf {
    dir.join(format!("{name}.wal"))
}

/// Path of WAL segment `i`: segment 0 is the base `<name>.wal`, segment
/// `i >= 1` is `<name>.wal.<i>`.  Segments are contiguous: replay walks
/// 0, 1, 2, ... until the first missing index.
pub fn seg_path(base: &Path, i: u64) -> PathBuf {
    if i == 0 {
        base.to_path_buf()
    } else {
        PathBuf::from(format!("{}.{i}", base.display()))
    }
}

/// Delete every rotated segment (`.1` and up) of `base` — the compaction
/// epilogue (the fresh WAL is re-seeded at segment 0) and the drop path.
/// The base itself is left alone.
pub fn remove_rotated_segments(base: &Path) {
    let mut i = 1u64;
    loop {
        let p = seg_path(base, i);
        if std::fs::remove_file(&p).is_err() {
            break; // first missing index ends the contiguous run
        }
        i += 1;
    }
}

fn read_u64<R: Read>(r: &mut R) -> std::io::Result<u64> {
    let mut b = [0u8; 8];
    r.read_exact(&mut b)?;
    Ok(u64::from_le_bytes(b))
}

/// Dot-prefixed sibling used for atomic tmp-write-then-rename publishes.
fn tmp_path(path: &Path) -> PathBuf {
    let file = path
        .file_name()
        .and_then(|f| f.to_str())
        .unwrap_or("live");
    path.with_file_name(format!(".{file}.tmp"))
}

// ---- live snapshot ------------------------------------------------------

/// A decoded `<name>.live` file.
#[derive(Debug, Clone)]
pub struct LiveSnapshotFile {
    pub epoch: u64,
    pub next_id: u64,
    pub points: PointSet,
    pub ids: Vec<u64>,
}

/// Atomically publish the compacted state of one dataset to
/// `<dir>/<name>.live`.
pub fn save_live_snapshot(
    dir: &Path,
    name: &str,
    epoch: u64,
    next_id: u64,
    pts: &PointSet,
    ids: &[u64],
    sync: bool,
) -> Result<()> {
    validate_dataset_name(name)?;
    assert_eq!(pts.len(), ids.len(), "points/ids length mismatch");
    std::fs::create_dir_all(dir)?;
    let path = live_path(dir, name);
    let tmp = tmp_path(&path);
    {
        let mut w = std::io::BufWriter::new(std::fs::File::create(&tmp)?);
        w.write_all(SNAP_MAGIC)?;
        w.write_all(&epoch.to_le_bytes())?;
        w.write_all(&next_id.to_le_bytes())?;
        w.write_all(&(pts.len() as u64).to_le_bytes())?;
        for channel in [&pts.xs, &pts.ys, &pts.zs] {
            for &v in channel.iter() {
                w.write_all(&v.to_le_bytes())?;
            }
        }
        for &id in ids {
            w.write_all(&id.to_le_bytes())?;
        }
        w.flush()?;
        if sync {
            w.get_ref().sync_data()?;
        }
    }
    std::fs::rename(&tmp, &path)?;
    Ok(())
}

/// Load `<dir>/<name>.live`.
pub fn load_live_snapshot(dir: &Path, name: &str) -> Result<LiveSnapshotFile> {
    let path = live_path(dir, name);
    let mut r = std::io::BufReader::new(std::fs::File::open(&path)?);
    let mut magic = [0u8; 8];
    r.read_exact(&mut magic)?;
    if &magic != SNAP_MAGIC {
        return Err(Error::InvalidArgument(format!(
            "{}: bad live-snapshot magic {:?} (expected {SNAP_MAGIC:?})",
            path.display(),
            &magic
        )));
    }
    let epoch = read_u64(&mut r)?;
    let next_id = read_u64(&mut r)?;
    let n = read_u64(&mut r)?;
    if n > MAX_PLAUSIBLE {
        return Err(Error::InvalidArgument(format!(
            "{}: implausible point count {n}",
            path.display()
        )));
    }
    let n = n as usize;
    let mut read_f64s = |n: usize| -> Result<Vec<f64>> {
        let mut buf = vec![0u8; n * 8];
        r.read_exact(&mut buf)?;
        Ok(buf
            .chunks_exact(8)
            .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
            .collect())
    };
    let xs = read_f64s(n)?;
    let ys = read_f64s(n)?;
    let zs = read_f64s(n)?;
    for v in xs.iter().chain(&ys).chain(&zs) {
        if !v.is_finite() {
            return Err(Error::InvalidArgument(format!(
                "{}: non-finite value in live snapshot",
                path.display()
            )));
        }
    }
    let mut ids = Vec::with_capacity(n);
    {
        let mut buf = vec![0u8; n * 8];
        r.read_exact(&mut buf)?;
        for c in buf.chunks_exact(8) {
            ids.push(u64::from_le_bytes(c.try_into().unwrap()));
        }
    }
    // ids must be strictly ascending (the id→index binary search relies
    // on it) and below next_id
    for w in ids.windows(2) {
        if w[0] >= w[1] {
            return Err(Error::InvalidArgument(format!(
                "{}: live snapshot ids not strictly ascending",
                path.display()
            )));
        }
    }
    if ids.last().is_some_and(|&last| last >= next_id) {
        return Err(Error::InvalidArgument(format!(
            "{}: live snapshot id exceeds next_id",
            path.display()
        )));
    }
    Ok(LiveSnapshotFile { epoch, next_id, points: PointSet::from_soa(xs, ys, zs), ids })
}

/// Names of every `*.live` snapshot in `dir`, sorted.
pub fn list_live(dir: &Path) -> Result<Vec<String>> {
    let mut out = Vec::new();
    if !dir.exists() {
        return Ok(out);
    }
    for entry in std::fs::read_dir(dir)? {
        let path = entry?.path();
        if path.extension().and_then(|e| e.to_str()) != Some("live") {
            continue;
        }
        let Some(name) = path.file_stem().and_then(|s| s.to_str()) else {
            continue;
        };
        if validate_dataset_name(name).is_ok() {
            out.push(name.to_string());
        }
    }
    out.sort();
    Ok(out)
}

// ---- the WAL ------------------------------------------------------------

/// An open, appendable WAL — optionally **segment-rotating**: once the
/// active segment grows past `seg_limit` bytes, the next append opens a
/// fresh `<base>.wal.<i+1>` segment, so a single unbounded ingest feed
/// never grows one giant file (ROADMAP PR-4(c)).  Records never split
/// across segments (rotation happens between appends), replay walks the
/// segments in index order, and compaction re-seeds segment 0 and
/// deletes the obsolete siblings.
#[derive(Debug)]
pub struct Wal {
    file: std::fs::File,
    records: u64,
    sync: bool,
    /// Segment-0 path; `None` = rotation disabled (ad-hoc WALs).
    base: Option<PathBuf>,
    /// Index of the active segment.
    seg_index: u64,
    /// Bytes written to the active segment so far (header included).
    seg_bytes: u64,
    /// Rotate once `seg_bytes` exceeds this; 0 = never rotate.
    seg_limit: u64,
}

/// Everything `read_wal` / `read_wal_segments` learned about a WAL.
#[derive(Debug, Default)]
pub struct WalReadout {
    pub records: Vec<WalRecord>,
    /// Byte length of the structurally-complete prefix (of the **last**
    /// segment when reading a segmented WAL).
    pub clean_len: u64,
    /// True when a torn tail (crash mid-write) was detected and skipped.
    pub torn: bool,
    /// False when the file did not exist.
    pub existed: bool,
    /// Index of the last (active) segment; 0 for unrotated WALs.
    pub last_segment: u64,
}

impl Wal {
    /// Create (or truncate to) a fresh WAL holding only the magic header
    /// (rotation disabled — tests and ad-hoc logs).
    pub fn create(path: &Path, sync: bool) -> Result<Wal> {
        Wal::create_rotating(path, sync, 0)
    }

    /// Create (or truncate to) a fresh segment-0 WAL that rotates past
    /// `seg_limit` bytes (0 = never).  A fresh WAL is a fresh **chain**:
    /// any rotated `.N` siblings left by a previous incarnation of the
    /// same path (e.g. a same-name re-register) are deleted first, or
    /// the next load would replay the old incarnation's records after
    /// the new ones and resurrect foreign points.
    pub fn create_rotating(path: &Path, sync: bool, seg_limit: u64) -> Result<Wal> {
        remove_rotated_segments(path);
        let mut file = std::fs::OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(path)?;
        file.write_all(WAL_MAGIC)?;
        if sync {
            file.sync_data()?;
        }
        Ok(Wal {
            file,
            records: 0,
            sync,
            base: Some(path.to_path_buf()),
            seg_index: 0,
            seg_bytes: WAL_MAGIC.len() as u64,
            seg_limit,
        })
    }

    /// Index of the active segment (diagnostics / compaction cleanup).
    pub fn segment_index(&self) -> u64 {
        self.seg_index
    }

    /// Atomically replace the WAL at `path` with a fresh one pre-seeded
    /// with `records` (the compactor re-logs the surviving overlay here),
    /// returning the open handle.  The seed records are group-committed.
    pub fn write_fresh(path: &Path, records: &[WalRecord], sync: bool) -> Result<Wal> {
        let mut staged = StagedWal::stage(path, sync)?;
        staged.append_batch(records)?;
        staged.publish()
    }

    /// Reopen an existing single-segment WAL for appending after replay.
    /// `clean_len` (from [`read_wal`]) trims any torn tail before the
    /// first append.
    pub fn open_after_replay(path: &Path, sync: bool, records: u64, clean_len: u64) -> Result<Wal> {
        Wal::open_after_replay_rotating(path, sync, records, 0, clean_len, 0)
    }

    /// Reopen a (possibly rotated) WAL for appending after replay: the
    /// active segment is `last_segment` (from [`read_wal_segments`]),
    /// trimmed to `clean_len`; subsequent appends rotate past
    /// `seg_limit` bytes.
    pub fn open_after_replay_rotating(
        base: &Path,
        sync: bool,
        records: u64,
        last_segment: u64,
        clean_len: u64,
        seg_limit: u64,
    ) -> Result<Wal> {
        let path = seg_path(base, last_segment);
        let file = std::fs::OpenOptions::new().write(true).open(&path)?;
        file.set_len(clean_len)?;
        // append semantics: all writes land at the (now trimmed) end
        let file = {
            drop(file);
            std::fs::OpenOptions::new().append(true).open(&path)?
        };
        Ok(Wal {
            file,
            records,
            sync,
            base: Some(base.to_path_buf()),
            seg_index: last_segment,
            seg_bytes: clean_len,
            seg_limit,
        })
    }

    /// Records appended so far (including pre-seeded/replayed ones).
    pub fn records(&self) -> u64 {
        self.records
    }

    /// Durably append one record: a single `write_all`, plus `sync_data`
    /// when the WAL runs in sync mode.
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        self.append_batch(std::slice::from_ref(rec))
    }

    /// **Group commit**: durably append every record of one logical
    /// commit with a single `write_all` and at most one `sync_data` —
    /// under `wal_sync`, an N-record commit costs one fsync instead of N.
    /// The on-disk bytes are identical to N sequential [`Wal::append`]
    /// calls (each record keeps its own frame, so a torn tail still
    /// truncates at a record boundary on replay).  An empty batch is a
    /// no-op (no write, no fsync).
    pub fn append_batch(&mut self, recs: &[WalRecord]) -> Result<()> {
        if recs.is_empty() {
            return Ok(());
        }
        let mut buf = Vec::new();
        for rec in recs {
            let (tag, payload) = encode(rec);
            buf.reserve(9 + payload.len());
            buf.push(tag);
            buf.extend_from_slice(&(payload.len() as u64).to_le_bytes());
            buf.extend_from_slice(&payload);
        }
        self.file.write_all(&buf)?;
        if self.sync {
            self.file.sync_data()?;
        }
        self.records += recs.len() as u64;
        self.seg_bytes += buf.len() as u64;
        self.maybe_rotate()?;
        Ok(())
    }

    /// Open the next segment once the active one grew past the limit.
    /// Rotation happens *between* commits, so a record never spans two
    /// segments and a torn tail stays confined to the last segment.  The
    /// new segment is staged at a dot-tmp sibling and **renamed into
    /// place only after its magic header is written** (and fsynced under
    /// `wal_sync`): a crash or write failure mid-rotation leaves at most
    /// an invisible tmp file, never a magic-less `.wal.N` that would
    /// make `read_wal_segments` reject the whole chain.  A rotation
    /// failure (e.g. disk full) is non-fatal to the durable record
    /// already written: the error propagates, but the WAL keeps
    /// appending to the oversized segment on the next commit.
    fn maybe_rotate(&mut self) -> Result<()> {
        if self.seg_limit == 0 || self.seg_bytes <= self.seg_limit {
            return Ok(());
        }
        let Some(base) = self.base.clone() else {
            return Ok(());
        };
        let next = seg_path(&base, self.seg_index + 1);
        let tmp = tmp_path(&next);
        let mut file = std::fs::OpenOptions::new()
            .write(true)
            .create(true)
            .truncate(true)
            .open(&tmp)?;
        file.write_all(WAL_MAGIC)?;
        if self.sync {
            file.sync_data()?;
        }
        std::fs::rename(&tmp, &next)?;
        // the handle follows the rename (same inode)
        self.file = file;
        self.seg_index += 1;
        self.seg_bytes = WAL_MAGIC.len() as u64;
        Ok(())
    }
}

/// A fresh WAL staged at the dot-tmp sibling, not yet published.  The
/// compactor creates it (file open + header write + optional fsync)
/// *before* taking the snapshot-swap write lock, so the only file work
/// under the lock is appending the rare carried records and one rename.
#[derive(Debug)]
pub struct StagedWal {
    wal: Wal,
    tmp: PathBuf,
    dest: PathBuf,
    /// Applied at publish time — the staged file itself never rotates
    /// (it holds at most the compactor's carried overlay).
    seg_limit: u64,
}

impl StagedWal {
    /// Create the staged file holding only the magic header.
    pub fn stage(dest: &Path, sync: bool) -> Result<StagedWal> {
        StagedWal::stage_rotating(dest, sync, 0)
    }

    /// Stage a fresh segment-0 WAL that, once published, rotates past
    /// `seg_limit` bytes.
    pub fn stage_rotating(dest: &Path, sync: bool, seg_limit: u64) -> Result<StagedWal> {
        let tmp = tmp_path(dest);
        let wal = Wal::create(&tmp, sync)?;
        Ok(StagedWal { wal, tmp, dest: dest.to_path_buf(), seg_limit })
    }

    /// Append a record to the staged (unpublished) file.
    pub fn append(&mut self, rec: &WalRecord) -> Result<()> {
        self.wal.append(rec)
    }

    /// Group-commit a batch of records to the staged file (one write, at
    /// most one fsync — see [`Wal::append_batch`]).
    pub fn append_batch(&mut self, recs: &[WalRecord]) -> Result<()> {
        self.wal.append_batch(recs)
    }

    /// Atomically publish over the destination, returning the open,
    /// appendable handle (same inode — rename does not invalidate it).
    /// The handle is rebased to the destination and armed with the
    /// staged rotation limit.
    pub fn publish(self) -> Result<Wal> {
        if self.wal.sync {
            self.wal.file.sync_data()?;
        }
        std::fs::rename(&self.tmp, &self.dest)?;
        let mut wal = self.wal;
        wal.base = Some(self.dest);
        wal.seg_limit = self.seg_limit;
        Ok(wal)
    }
}

fn encode(rec: &WalRecord) -> (u8, Vec<u8>) {
    match rec {
        WalRecord::Append { first_id, points } => {
            let mut p = Vec::with_capacity(16 + 24 * points.len());
            p.extend_from_slice(&first_id.to_le_bytes());
            p.extend_from_slice(&(points.len() as u64).to_le_bytes());
            for channel in [&points.xs, &points.ys, &points.zs] {
                for &v in channel.iter() {
                    p.extend_from_slice(&v.to_le_bytes());
                }
            }
            (TAG_APPEND, p)
        }
        WalRecord::Remove { ids } => {
            let mut p = Vec::with_capacity(8 + 8 * ids.len());
            p.extend_from_slice(&(ids.len() as u64).to_le_bytes());
            for &id in ids {
                p.extend_from_slice(&id.to_le_bytes());
            }
            (TAG_REMOVE, p)
        }
    }
}

/// Read every complete record of a WAL.  A missing file is an empty
/// readout; a torn tail stops the scan (and is reported so the reopen can
/// truncate it); a structurally-complete but invalid record is a hard
/// error — that is corruption, not a crash artifact.
pub fn read_wal(path: &Path) -> Result<WalReadout> {
    let bytes = match std::fs::read(path) {
        Ok(b) => b,
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => {
            return Ok(WalReadout::default());
        }
        Err(e) => return Err(e.into()),
    };
    if bytes.len() < 8 || &bytes[..8] != WAL_MAGIC {
        return Err(Error::InvalidArgument(format!(
            "{}: bad WAL magic",
            path.display()
        )));
    }
    let mut out = WalReadout {
        clean_len: 8,
        existed: true,
        ..Default::default()
    };
    let mut pos = 8usize;
    loop {
        if pos == bytes.len() {
            break; // clean end
        }
        if pos + 9 > bytes.len() {
            out.torn = true;
            break;
        }
        let tag = bytes[pos];
        let len = u64::from_le_bytes(bytes[pos + 1..pos + 9].try_into().unwrap());
        if len > MAX_PLAUSIBLE * 24 {
            return Err(Error::InvalidArgument(format!(
                "{}: implausible WAL record length {len}",
                path.display()
            )));
        }
        let len = len as usize;
        if pos + 9 + len > bytes.len() {
            out.torn = true;
            break;
        }
        let payload = &bytes[pos + 9..pos + 9 + len];
        out.records.push(decode(path, tag, payload)?);
        pos += 9 + len;
        out.clean_len = pos as u64;
    }
    Ok(out)
}

/// Read a (possibly rotated) WAL: walk segments `base`, `base.1`,
/// `base.2`, ... in index order, concatenating their records.  Only the
/// **last** segment may carry a torn tail (a crash tears only the active
/// segment); a torn non-final segment is corruption and a hard error.
/// `clean_len` and `last_segment` describe the active segment for
/// [`Wal::open_after_replay_rotating`].
pub fn read_wal_segments(base: &Path) -> Result<WalReadout> {
    let mut out = read_wal(base)?;
    if !out.existed {
        return Ok(out);
    }
    let mut i = 1u64;
    loop {
        let p = seg_path(base, i);
        if !p.exists() {
            break;
        }
        if out.torn {
            return Err(Error::InvalidArgument(format!(
                "{}: torn WAL segment {} followed by segment {i}",
                base.display(),
                i - 1
            )));
        }
        let seg = read_wal(&p)?;
        out.records.extend(seg.records);
        out.torn = seg.torn;
        out.clean_len = seg.clean_len;
        out.last_segment = i;
        i += 1;
    }
    Ok(out)
}

fn decode(path: &Path, tag: u8, payload: &[u8]) -> Result<WalRecord> {
    let bad = |m: &str| Error::InvalidArgument(format!("{}: {m}", path.display()));
    let u64_at = |at: usize| u64::from_le_bytes(payload[at..at + 8].try_into().unwrap());
    match tag {
        TAG_APPEND => {
            if payload.len() < 16 {
                return Err(bad("short append record"));
            }
            let first_id = u64_at(0);
            let count = u64_at(8);
            if count > MAX_PLAUSIBLE || payload.len() != 16 + 24 * count as usize {
                return Err(bad("append record length mismatch"));
            }
            let count = count as usize;
            let f64s = |from: usize| -> Vec<f64> {
                payload[from..from + 8 * count]
                    .chunks_exact(8)
                    .map(|c| f64::from_le_bytes(c.try_into().unwrap()))
                    .collect()
            };
            let xs = f64s(16);
            let ys = f64s(16 + 8 * count);
            let zs = f64s(16 + 16 * count);
            if xs.iter().chain(&ys).chain(&zs).any(|v| !v.is_finite()) {
                return Err(bad("non-finite value in append record"));
            }
            Ok(WalRecord::Append { first_id, points: PointSet::from_soa(xs, ys, zs) })
        }
        TAG_REMOVE => {
            if payload.len() < 8 {
                return Err(bad("short remove record"));
            }
            let count = u64_at(0);
            if count > MAX_PLAUSIBLE || payload.len() != 8 + 8 * count as usize {
                return Err(bad("remove record length mismatch"));
            }
            let ids = (0..count as usize).map(|i| u64_at(8 + 8 * i)).collect();
            Ok(WalRecord::Remove { ids })
        }
        other => Err(bad(&format!("unknown WAL record tag {other}"))),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload;

    fn tmpdir(tag: &str) -> PathBuf {
        let d = std::env::temp_dir().join(format!("aidw_wal_{tag}_{}", std::process::id()));
        std::fs::create_dir_all(&d).unwrap();
        d
    }

    #[test]
    fn wal_roundtrip() {
        let dir = tmpdir("roundtrip");
        let path = wal_path(&dir, "d");
        let pts = workload::uniform_square(7, 10.0, 601);
        {
            let mut wal = Wal::create(&path, false).unwrap();
            wal.append(&WalRecord::Append { first_id: 100, points: pts.clone() }).unwrap();
            wal.append(&WalRecord::Remove { ids: vec![3, 101] }).unwrap();
            assert_eq!(wal.records(), 2);
        }
        let back = read_wal(&path).unwrap();
        assert!(back.existed);
        assert!(!back.torn);
        assert_eq!(back.records.len(), 2);
        assert_eq!(
            back.records[0],
            WalRecord::Append { first_id: 100, points: pts }
        );
        assert_eq!(back.records[1], WalRecord::Remove { ids: vec![3, 101] });
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_tail_is_trimmed_not_fatal() {
        let dir = tmpdir("torn");
        let path = wal_path(&dir, "d");
        let pts = workload::uniform_square(5, 10.0, 602);
        {
            let mut wal = Wal::create(&path, false).unwrap();
            wal.append(&WalRecord::Remove { ids: vec![1] }).unwrap();
            wal.append(&WalRecord::Append { first_id: 9, points: pts }).unwrap();
        }
        let full = std::fs::metadata(&path).unwrap().len();
        // crash mid-write of the second record
        let clean = read_wal(&path).unwrap();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&path)
            .unwrap()
            .set_len(full - 11)
            .unwrap();
        let torn = read_wal(&path).unwrap();
        assert!(torn.torn);
        assert_eq!(torn.records.len(), 1);
        assert!(torn.clean_len < full);
        // reopening truncates the tail; subsequent appends read back clean
        let mut wal =
            Wal::open_after_replay(&path, false, torn.records.len() as u64, torn.clean_len)
                .unwrap();
        wal.append(&WalRecord::Remove { ids: vec![7] }).unwrap();
        let again = read_wal(&path).unwrap();
        assert!(!again.torn);
        assert_eq!(again.records.len(), 2);
        assert_eq!(again.records[1], WalRecord::Remove { ids: vec![7] });
        assert_eq!(clean.records.len(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn group_commit_is_byte_identical_to_per_record_appends() {
        // the group-commit regression: a batched commit must leave the
        // exact bytes N sequential appends leave, so replay after the
        // batched commit is identical to replay after per-record commits
        let dir = tmpdir("group");
        let pts_a = workload::uniform_square(6, 10.0, 604);
        let pts_b = workload::uniform_square(3, 10.0, 605);
        let records = vec![
            WalRecord::Append { first_id: 10, points: pts_a },
            WalRecord::Remove { ids: vec![2, 11] },
            WalRecord::Append { first_id: 16, points: pts_b },
            WalRecord::Remove { ids: vec![16] },
        ];
        let one_by_one = wal_path(&dir, "single");
        {
            let mut wal = Wal::create(&one_by_one, true).unwrap();
            for rec in &records {
                wal.append(rec).unwrap();
            }
            assert_eq!(wal.records(), 4);
        }
        let batched = wal_path(&dir, "batched");
        {
            let mut wal = Wal::create(&batched, true).unwrap();
            wal.append_batch(&records).unwrap();
            assert_eq!(wal.records(), 4);
            wal.append_batch(&[]).unwrap(); // empty commit is a no-op
            assert_eq!(wal.records(), 4);
        }
        assert_eq!(
            std::fs::read(&one_by_one).unwrap(),
            std::fs::read(&batched).unwrap(),
            "group commit must not change the on-disk format"
        );
        let back = read_wal(&batched).unwrap();
        assert!(!back.torn);
        assert_eq!(back.records, records, "replay after the batched commit is identical");
        // a tear inside the batch still truncates at a record boundary
        let full = std::fs::metadata(&batched).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&batched)
            .unwrap()
            .set_len(full - 5)
            .unwrap();
        let torn = read_wal(&batched).unwrap();
        assert!(torn.torn);
        assert_eq!(torn.records, records[..3], "only the torn last record is dropped");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn rotation_splits_segments_and_replay_walks_them_in_order() {
        let dir = tmpdir("rotate");
        let base = wal_path(&dir, "d");
        // tiny limit: every remove record (~9 + 16 bytes) crosses it
        let mut wal = Wal::create_rotating(&base, false, 48).unwrap();
        let records: Vec<WalRecord> =
            (0..6).map(|i| WalRecord::Remove { ids: vec![i] }).collect();
        for rec in &records {
            wal.append(rec).unwrap();
        }
        assert!(wal.segment_index() >= 2, "tiny limit must have rotated");
        assert!(seg_path(&base, 1).exists());
        assert!(seg_path(&base, wal.segment_index()).exists());
        // every record survives, in order, across the boundaries
        let back = read_wal_segments(&base).unwrap();
        assert!(back.existed);
        assert!(!back.torn);
        assert_eq!(back.records, records);
        assert_eq!(back.last_segment, wal.segment_index());
        // reopen-after-replay appends to the *last* segment and keeps
        // rotating
        drop(wal);
        let mut wal = Wal::open_after_replay_rotating(
            &base,
            false,
            back.records.len() as u64,
            back.last_segment,
            back.clean_len,
            48,
        )
        .unwrap();
        wal.append(&WalRecord::Remove { ids: vec![99] }).unwrap();
        let again = read_wal_segments(&base).unwrap();
        assert_eq!(again.records.len(), 7);
        assert_eq!(again.records[6], WalRecord::Remove { ids: vec![99] });
        // a torn tail in the *last* segment trims, as for unrotated WALs
        let last = seg_path(&base, again.last_segment);
        let full = std::fs::metadata(&last).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&last)
            .unwrap()
            .set_len(full - 3)
            .unwrap();
        let torn = read_wal_segments(&base).unwrap();
        assert!(torn.torn);
        assert!(torn.records.len() < again.records.len());
        // rotated-segment cleanup removes every sibling but the base
        remove_rotated_segments(&base);
        assert!(base.exists());
        assert!(!seg_path(&base, 1).exists());
        let only_base = read_wal_segments(&base).unwrap();
        assert_eq!(only_base.last_segment, 0);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fresh_wal_deletes_stale_rotated_siblings() {
        // a same-name re-register creates a fresh WAL at the same path;
        // rotated segments of the previous incarnation must die with it,
        // or the next load replays foreign records after the new ones
        let dir = tmpdir("stale_sib");
        let base = wal_path(&dir, "d");
        {
            let mut wal = Wal::create_rotating(&base, false, 32).unwrap();
            for i in 0..3 {
                wal.append(&WalRecord::Remove { ids: vec![i] }).unwrap();
            }
            assert!(seg_path(&base, 1).exists(), "old incarnation rotated");
        }
        // the "re-register": a fresh WAL at the same path
        let mut wal = Wal::create_rotating(&base, false, 32).unwrap();
        assert!(!seg_path(&base, 1).exists(), "stale siblings must be deleted");
        wal.append(&WalRecord::Remove { ids: vec![42] }).unwrap();
        let back = read_wal_segments(&base).unwrap();
        assert_eq!(
            back.records,
            vec![WalRecord::Remove { ids: vec![42] }],
            "only the new incarnation's records replay"
        );
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn torn_middle_segment_is_corruption() {
        let dir = tmpdir("torn_mid");
        let base = wal_path(&dir, "d");
        let mut wal = Wal::create_rotating(&base, false, 32).unwrap();
        for i in 0..4 {
            wal.append(&WalRecord::Remove { ids: vec![i] }).unwrap();
        }
        assert!(wal.segment_index() >= 1);
        // tear segment 0 while later segments exist: not a crash
        // artifact (crashes only tear the active tail) — hard error
        let full = std::fs::metadata(&base).unwrap().len();
        std::fs::OpenOptions::new()
            .write(true)
            .open(&base)
            .unwrap()
            .set_len(full - 3)
            .unwrap();
        assert!(read_wal_segments(&base).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn missing_wal_is_empty_and_bad_magic_is_fatal() {
        let dir = tmpdir("magic");
        let missing = read_wal(&wal_path(&dir, "none")).unwrap();
        assert!(!missing.existed);
        assert!(missing.records.is_empty());
        let path = wal_path(&dir, "bad");
        std::fs::write(&path, b"NOTAWAL!").unwrap();
        assert!(read_wal(&path).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn write_fresh_reseeds_atomically() {
        let dir = tmpdir("fresh");
        let path = wal_path(&dir, "d");
        {
            let mut wal = Wal::create(&path, false).unwrap();
            for i in 0..5 {
                wal.append(&WalRecord::Remove { ids: vec![i] }).unwrap();
            }
        }
        let surviving = vec![WalRecord::Remove { ids: vec![42] }];
        let wal = Wal::write_fresh(&path, &surviving, false).unwrap();
        assert_eq!(wal.records(), 1);
        let back = read_wal(&path).unwrap();
        assert_eq!(back.records, surviving);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn live_snapshot_roundtrip_and_validation() {
        let dir = tmpdir("snap");
        let pts = workload::uniform_square(20, 10.0, 603);
        let ids: Vec<u64> = (5..25).collect();
        save_live_snapshot(&dir, "d", 3, 25, &pts, &ids, false).unwrap();
        let back = load_live_snapshot(&dir, "d").unwrap();
        assert_eq!(back.epoch, 3);
        assert_eq!(back.next_id, 25);
        assert_eq!(back.ids, ids);
        assert_eq!(back.points.xs, pts.xs);
        assert_eq!(back.points.zs, pts.zs);
        assert_eq!(list_live(&dir).unwrap(), vec!["d".to_string()]);
        // dot names rejected (shared with the v1 snapshot convention)
        assert!(save_live_snapshot(&dir, ".d", 0, 0, &pts, &ids, false).is_err());
        // non-ascending ids rejected
        let mut bad_ids = ids.clone();
        bad_ids.swap(0, 1);
        save_live_snapshot(&dir, "bad", 0, 25, &pts, &bad_ids, false).unwrap();
        assert!(load_live_snapshot(&dir, "bad").is_err());
        std::fs::remove_dir_all(&dir).ok();
    }
}
