//! `aidw` — CLI for the AIDW interpolation service.
//!
//! Subcommands:
//!   serve        start the TCP JSON service (protocol v2.8)
//!   interpolate  one-shot interpolation over a generated/loaded workload
//!   query        interpolate against a running service over TCP
//!                (--stream consumes the v2.4 tiled streaming response;
//!                --trace prints the server's v2.6 span timeline)
//!   subscribe    hold a standing raster against a running service and
//!                print incremental dirty-tile updates (protocol v2.5)
//!   mutate       append/remove/compact/stat against a running service
//!   events       page a running service's structured event journal
//!                (protocol v2.6)
//!   bench        run the perf suite, emit BENCH_aidw.json
//!   info         artifact + engine diagnostics
//!   generate     write a synthetic workload to CSV
//!   tidy         repo-invariant static analysis over this crate's
//!                own sources (tier-1 gate; see src/analysis/)
//!
//! Run `aidw help` for flags.  Every per-request tuning knob of
//! `QueryOptions` (k, variant, ring rule, local mode, alpha levels, fuzzy
//! bounds, area) has a flag on `interpolate`; `serve` flags set the
//! coordinator *defaults* that protocol-v2 clients may override per
//! request.  `serve --live-dir DIR` turns on WAL-backed durability for
//! live dataset mutation.

use std::sync::Arc;

use aidw::aidw::params::AidwParams;
use aidw::cli::Args;
use aidw::coordinator::{CoordinatorConfig, EngineMode, QueryOptions};
use aidw::error::{Error, Result};
use aidw::geom::PointSet;
use aidw::knn::grid_knn::RingRule;
use aidw::runtime::Variant;
use aidw::service::Server;
use aidw::session::AidwSession;
use aidw::workload;

const HELP: &str = "\
aidw — Adaptive IDW interpolation with fast grid kNN search
       (Mei, Xu & Xu 2016; rust + JAX/Pallas AOT via PJRT)

USAGE:
  aidw serve       [--addr 127.0.0.1:7878] [--cpu-only] [--k 10]
                   [--ring exact|paper+1] [--local N] [--snapshots DIR]
                   [--live-dir DIR] [--compact-threshold N] [--wal-sync]
                   [--neighbor-cache N] [--tile-rows N] [--stream-buffer N]
                   [--journal N] [--metrics-text] [--layout aos|soa|aosoa:N]
                   [--shards N] [--shard-threads N] [--tenant-rate R]
                   [--tenant-burst B] [--tenant-inflight N]
  aidw interpolate [--engine serving|pipeline|serial] [--cpu-only]
                   [--data N] [--queries N] [--side 100] [--seed 42]
                   [--variant naive|tiled] [--k 10] [--ring exact|paper+1]
                   [--local N] [--alpha-levels 0.5,1,2,3,4]
                   [--rmin 0] [--rmax 2] [--area A]
                   [--dist uniform|clustered|terrain] [--file pts.csv]
                   [--out out.csv] [--tile-rows N] [--layout aos|soa|aosoa:N]
  aidw query       --addr HOST:PORT --dataset NAME [--queries N] [--side 100]
                   [--seed 42] [--stream] [--trace] [--tile-rows N]
                   [--out out.csv] [--tenant NAME]
                   [--variant naive|tiled] [--k 10] [--ring exact|paper+1]
                   [--local N] [--alpha-levels 0.5,1,2,3,4]
                   [--rmin 0] [--rmax 2] [--area A] [--layout aos|soa|aosoa:N]
  aidw subscribe   --addr HOST:PORT --dataset NAME [--queries N] [--side 100]
                   [--seed 42] [--updates N] [--out out.csv] [--tenant NAME]
                   [--variant naive|tiled] [--k 10] [--ring exact|paper+1]
                   [--local N] [--tile-rows N] [--area A]
  aidw mutate      --addr HOST:PORT --dataset NAME --action append|remove|compact|stat
                   [--file pts.csv | --n N --side 100 --seed 42 --dist uniform]
                   [--ids 3,17,9000]
  aidw events      --addr HOST:PORT [--since N] [--max 100]
  aidw bench       [--sizes 1024,4096,16384 | --sizes small] [--seed 42]
                   [--threads N] [--serial-cap 2048] [--no-serial]
                   [--reps 3] [--warmup 1] [--out BENCH_aidw.json]
  aidw generate    [--n N] [--side 100] [--seed 42]
                   [--dist uniform|clustered|terrain|sensors] --out file.csv
  aidw tidy        [--json] [--root DIR]
  aidw info
  aidw help

`serve` flags set coordinator defaults; `interpolate` flags are
per-request QueryOptions (protocol v2 exposes the same fields on the
wire).  `--local 0` forces dense weighting.  `serve --live-dir DIR`
enables WAL-backed durable mutation (protocol v2.1 `mutate` op); `aidw
mutate` is the matching client.  `aidw query --stream` consumes the
protocol-v2.4 tiled streaming response — tiles are printed/written as
they arrive, so a raster larger than client memory streams through in
constant space.  `aidw subscribe` registers a protocol-v2.5 standing
raster: after the initial materialization, every server-side mutation
pushes only the dirty tiles (exact-kNN termination-bound footprint),
applied to a client-side raster kept bit-identical to a from-scratch
query; `--updates N` unsubscribes after N incremental updates.  `aidw
bench` writes the sizes x variants x stage-times JSON the repo tracks
as its perf trajectory.

Observability (protocol v2.6): `aidw query --trace` asks the server for
a per-request span timeline (admission wait, coalesce wait, stage-1 kNN
or cache credit, per-tile stage 2, stream-buffer wait, serialization)
stamped with the serving snapshot, and prints it after the reply.
`aidw events` pages the server's bounded event journal (mutations,
compactions, cache and subscription activity); poll with `--since
NEXT_SEQ` to tail it.  `serve --journal N` sizes the journal ring
buffer; `serve --metrics-text` prints a Prometheus-style metrics
rendering every 60s (the same text the v2.6 `metrics_text` op returns).

Stage-2 layout (protocol v2.7): `--layout aos|soa|aosoa:N` pins the
weighting kernel's memory schedule (bit-identical output either way);
absent, the planner picks per request by raster size and records its
choice on the `--trace` timeline.  `aidw bench` times every layout in
the `layout` section of BENCH_aidw.json; `--sizes small` is shorthand
for a quick 256,512 run, and `--reps/--warmup` set the median-of-N
timing hygiene every bench section uses.

Sharding & multi-tenancy (protocol v2.8): `serve --shards N` partitions
each dataset's grid into N row bands and runs stage-1 kNN per shard on
a dedicated worker pool (absent = auto by point count, 1 = the
unsharded sweep); results are bit-identical either way — a row whose
exact termination ball escapes its shard's halo is transparently
re-run cross-shard.  `--shard-threads N` sizes the pool (default:
machine cores); the same pool recomputes subscription dirty tiles.
Requests may carry `--tenant NAME` (lowercase [a-z0-9_.-], <= 24
chars); the server schedules tenants' work deficit-round-robin and
enforces `--tenant-rate R` (requests/s refill), `--tenant-burst B`
(token-bucket depth), and `--tenant-inflight N` (concurrent requests
per tenant) fail-closed: over-quota requests get a structured
`over_quota` error and never enter the queue.  Absent flags leave that
limit off; anonymous requests share one default tenant lane.

`aidw tidy` runs the repo-invariant static analyzer over this crate's
own sources (stage-key classification, lock-order graph, protocol doc
drift, panic/print hygiene, SAFETY comments — see src/analysis/) and
exits nonzero on any unallowlisted finding; `--json` emits the
machine-readable findings report, `--root DIR` points at a checkout
other than the working directory.  ci.sh runs it as a fatal gate.
";

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    match run(&argv) {
        Ok(()) => {}
        Err(e) => {
            eprintln!("error: {e}");
            std::process::exit(1);
        }
    }
}

fn run(argv: &[String]) -> Result<()> {
    let args = Args::parse(
        argv,
        &["cpu-only", "verbose", "wal-sync", "no-serial", "stream", "trace", "metrics-text", "json"],
    )?;
    match args.subcommand.as_str() {
        "serve" => serve(&args),
        "interpolate" => interpolate(&args),
        "query" => query(&args),
        "subscribe" => subscribe(&args),
        "mutate" => mutate(&args),
        "events" => events(&args),
        "bench" => bench(&args),
        "generate" => generate(&args),
        "tidy" => tidy(&args),
        "info" => info(),
        "" | "help" => {
            print!("{HELP}");
            Ok(())
        }
        other => Err(Error::InvalidArgument(format!(
            "unknown subcommand '{other}' (try `aidw help`)"
        ))),
    }
}

/// Coordinator defaults from `serve`-style flags.
fn config_from(args: &Args) -> Result<CoordinatorConfig> {
    let mut cfg = CoordinatorConfig::default();
    if args.has("cpu-only") {
        cfg.engine_mode = EngineMode::CpuOnly;
    }
    cfg.params = AidwParams { k: args.get_usize("k", 10)?, ..Default::default() };
    if let Some(r) = args.get("ring") {
        cfg.ring_rule = r.parse::<RingRule>()?;
    }
    // --local N: A5 extension — stage 2 over N nearest neighbors only
    if let Some(n) = args.get("local") {
        let n: usize = n
            .parse()
            .map_err(|_| Error::InvalidArgument("--local expects an integer".into()))?;
        if n > 0 {
            cfg.local_neighbors = Some(n);
        }
    }
    // planner: stage-1 neighbor-cache capacity (0 disables reuse)
    cfg.neighbor_cache = args.get_usize("neighbor-cache", cfg.neighbor_cache)?;
    // streaming: default stage-2 tile size (0/absent = whole raster) and
    // the per-stream buffered-tile bound
    if let Some(t) = tile_rows_flag(args)? {
        cfg.tile_rows = Some(t);
    }
    cfg.stream_buffer_tiles = args.get_usize("stream-buffer", cfg.stream_buffer_tiles)?;
    // live mutation: durability directory + compaction tunables
    if let Some(dir) = args.get("live-dir") {
        cfg.live_dir = Some(std::path::PathBuf::from(dir));
    }
    cfg.live.compact_threshold =
        args.get_usize("compact-threshold", cfg.live.compact_threshold)?;
    if args.has("wal-sync") {
        cfg.live.wal_sync = true;
    }
    // observability: event-journal ring-buffer capacity
    cfg.journal_capacity = args.get_usize("journal", cfg.journal_capacity)?;
    // v2.7: default stage-2 layout (absent = per-request planner choice)
    if let Some(l) = args.get("layout") {
        cfg.layout = Some(l.parse::<aidw::coordinator::Layout>()?);
    }
    // v2.8: spatial shard count (absent = auto by point count, 1 = off),
    // shard worker-pool width, and the per-tenant admission policy
    if args.get("shards").is_some() {
        cfg.shards = Some(args.get_usize("shards", 0)?.max(1));
    }
    if args.get("shard-threads").is_some() {
        cfg.shard_threads = Some(args.get_usize("shard-threads", 0)?.max(1));
    }
    if args.get("tenant-rate").is_some() {
        let r = args.get_f64("tenant-rate", 0.0)?;
        if r <= 0.0 {
            return Err(Error::InvalidArgument("--tenant-rate expects a positive rate".into()));
        }
        cfg.tenant_policy.rate_per_s = Some(r);
    }
    cfg.tenant_policy.burst = args.get_f64("tenant-burst", cfg.tenant_policy.burst)?;
    if args.get("tenant-inflight").is_some() {
        let n = args.get_usize("tenant-inflight", 0)?;
        if n == 0 {
            return Err(Error::InvalidArgument(
                "--tenant-inflight expects a positive count".into(),
            ));
        }
        cfg.tenant_policy.max_in_flight = Some(n);
    }
    Ok(cfg)
}

/// Per-request QueryOptions from `interpolate`-style flags.
fn options_from(args: &Args) -> Result<QueryOptions> {
    let mut o = QueryOptions::new();
    if let Some(v) = args.get("variant") {
        o = o.variant(v.parse::<Variant>()?);
    }
    if args.get("k").is_some() {
        o = o.k(args.get_usize("k", 10)?);
    }
    if let Some(r) = args.get("ring") {
        o = o.ring_rule(r.parse::<RingRule>()?);
    }
    if let Some(n) = args.get("local") {
        let n: usize = n
            .parse()
            .map_err(|_| Error::InvalidArgument("--local expects an integer".into()))?;
        o = if n == 0 { o.dense() } else { o.local_neighbors(n) };
    }
    if let Some(levels) = args.get_f64_list("alpha-levels")? {
        if levels.len() != 5 {
            return Err(Error::InvalidArgument(format!(
                "--alpha-levels expects 5 values, got {}",
                levels.len()
            )));
        }
        o = o.alpha_levels([levels[0], levels[1], levels[2], levels[3], levels[4]]);
    }
    // set each bound only when its flag is present, so a lone --rmin
    // doesn't turn the library's r_max default into an explicit override
    if args.get("rmin").is_some() {
        o.r_min = Some(args.get_f64("rmin", 0.0)?);
    }
    if args.get("rmax").is_some() {
        o.r_max = Some(args.get_f64("rmax", 0.0)?);
    }
    if args.get("area").is_some() {
        o = o.area(args.get_f64("area", 0.0)?);
    }
    if let Some(t) = tile_rows_flag(args)? {
        o = o.tile_rows(t);
    }
    if args.has("trace") {
        o = o.trace(true);
    }
    // v2.7: pin the stage-2 layout (absent = planner's choice)
    if let Some(l) = args.get("layout") {
        o = o.layout(l.parse::<aidw::coordinator::Layout>()?);
    }
    // v2.8: bill this request to a tenant lane (absent = anonymous)
    if let Some(t) = args.get("tenant") {
        o = o.tenant(aidw::shard::TenantTag::new(t)?);
    }
    Ok(o)
}

/// The one `--tile-rows` parse shared by `serve`, `interpolate`, and
/// `query`, with one zero policy everywhere: `0` (like an absent flag)
/// means one whole-raster tile rather than an invalid-argument error.
fn tile_rows_flag(args: &Args) -> Result<Option<usize>> {
    match args.get("tile-rows") {
        None => Ok(None),
        Some(t) => {
            let t: usize = t.parse().map_err(|_| {
                Error::InvalidArgument("--tile-rows expects an integer".into())
            })?;
            Ok(if t > 0 { Some(t) } else { None })
        }
    }
}

fn serve(args: &Args) -> Result<()> {
    let addr = args.get_or("addr", "127.0.0.1:7878");
    let cfg = config_from(args)?;
    let live_dir = cfg.live_dir.clone();
    let session = AidwSession::serving(cfg)?;
    println!("aidw service: backend={}", session.backend_label());
    if let Some(dir) = &live_dir {
        // Coordinator::new already replayed snapshot + WAL for every
        // dataset found under the live directory
        let names = session.datasets();
        println!(
            "live dir {}: restored {} dataset(s){}",
            dir.display(),
            names.len(),
            if names.is_empty() { String::new() } else { format!(" ({})", names.join(", ")) }
        );
    }
    // --snapshots DIR: restore v1 portable snapshots at startup
    if let Some(dir) = args.get("snapshots") {
        let n = session
            .coordinator()
            .expect("serving session")
            .load_datasets(std::path::Path::new(dir))?;
        println!("restored {n} dataset(s) from {dir}");
    }
    // hand the coordinator over to the TCP server
    let coord = match session.into_coordinator() {
        Some(c) => Arc::new(c),
        None => unreachable!("serving session always has a coordinator"),
    };
    let server = Server::start(coord.clone(), &addr)?;
    println!("listening on {}", server.addr());
    println!(
        "protocol v{}: newline-delimited JSON; see rust/src/service/protocol.rs",
        aidw::service::protocol::PROTOCOL_VERSION
    );
    // serve until killed; --metrics-text prints the Prometheus-style
    // exposition (the same text the `metrics_text` op returns) every 60s
    let metrics_text = args.has("metrics-text");
    loop {
        if metrics_text {
            std::thread::sleep(std::time::Duration::from_secs(60));
            print!("{}", coord.metrics_text());
        } else {
            std::thread::sleep(std::time::Duration::from_secs(3600));
        }
    }
}

/// Thin TCP client for the v2.1 mutate ops.
fn mutate(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| Error::InvalidArgument("--addr is required".into()))?;
    let dataset = args
        .get("dataset")
        .ok_or_else(|| Error::InvalidArgument("--dataset is required".into()))?;
    let action = args
        .get("action")
        .ok_or_else(|| Error::InvalidArgument("--action is required".into()))?;
    let mut client = aidw::service::Client::connect(addr)?;
    match action {
        "append" => {
            let n = args.get_usize("n", 1024)?;
            let side = args.get_f64("side", 100.0)?;
            let seed = args.get_usize("seed", 42)? as u64;
            let pts = load_or_make(args, n, side, seed)?;
            let r = client.append(dataset, &pts)?;
            println!(
                "appended {} point(s) as ids {}..{} (epoch {}, {} live, {} in delta)",
                r.count,
                r.first_id,
                r.first_id + r.count as u64,
                r.epoch,
                r.live_points,
                r.delta_points
            );
        }
        "remove" => {
            let ids = args
                .get_u64_list("ids")?
                .ok_or_else(|| Error::InvalidArgument("--ids is required for remove".into()))?;
            let r = client.remove(dataset, &ids)?;
            println!(
                "removed {} point(s) (epoch {}, {} live, {} tombstones)",
                r.removed, r.epoch, r.live_points, r.tombstones
            );
        }
        "compact" => {
            let r = client.compact(dataset)?;
            if r.noop {
                println!("nothing to compact (epoch {})", r.epoch);
            } else {
                println!("compacted into epoch {}", r.epoch);
            }
        }
        "stat" => {
            let s = client.live_stat(dataset)?;
            println!(
                "epoch {}  live {}  base {}  delta {}  tombstones {}",
                s.epoch, s.live_points, s.base_points, s.delta_points, s.tombstones
            );
            println!(
                "wal_records {}  compactions {}  persistent {}  compacting {}",
                s.wal_records, s.compactions, s.persistent, s.compacting
            );
        }
        other => {
            return Err(Error::InvalidArgument(format!(
                "unknown action '{other}' (append|remove|compact|stat)"
            )))
        }
    }
    Ok(())
}

/// Run the perf suite and emit `BENCH_aidw.json` — the repo's perf
/// trajectory artifact (sizes x variants x stage times).
fn bench(args: &Args) -> Result<()> {
    let sizes: Vec<usize> = match args.get("sizes") {
        // `small` = the CI bench-smoke sizes: fast enough to gate on
        Some("small") => vec![256, 512],
        Some(s) => s
            .split(',')
            .map(|x| {
                x.trim().parse::<usize>().map_err(|_| {
                    Error::InvalidArgument(format!("--sizes expects integers, got '{x}'"))
                })
            })
            .collect::<Result<_>>()?,
        None => vec![1024, 4096, 16384],
    };
    let seed = args.get_usize("seed", 42)? as u64;
    let opts = aidw::benchsuite::MeasureOpts {
        serial: !args.has("no-serial"),
        serial_sub_cap: args.get_usize("serial-cap", 2048)?,
        seed,
        side: args.get_f64("side", 100.0)?,
        reps: args.get_usize("reps", 3)?.max(1),
        warmup: args.get_usize("warmup", 1)?,
    };
    let pool = match args.get_usize("threads", 0)? {
        0 => aidw::pool::Pool::machine_sized(),
        n => aidw::pool::Pool::new(n),
    };
    let out_path = args.get_or("out", "BENCH_aidw.json");
    let threads = match args.get_usize("threads", 0)? {
        0 => None,
        n => Some(n),
    };

    // planner suite (stage1/stage2/coalesce/cache-hit through the
    // two-stage execution planner) runs on every backend
    let mut planner = Vec::with_capacity(sizes.len());
    for &n in &sizes {
        println!("  planner n = {} ...", aidw::benchsuite::size_label(n));
        planner.push(aidw::benchsuite::measure_planner_reps(n, &opts, threads)?);
    }

    // mutated-dataset cache suite: repeated rasters on an uncompacted
    // snapshot must ride the overlay-versioned neighbor cache
    let mut live_cache = Vec::with_capacity(sizes.len());
    for &n in &sizes {
        println!("  live-cache n = {} ...", aidw::benchsuite::size_label(n));
        live_cache.push(aidw::benchsuite::measure_live_cache_reps(n, &opts, threads)?);
    }

    // subscription suite: dirty-tile incremental update vs a from-scratch
    // raster at the same snapshot (both bit-identical by construction)
    let mut subscribe = Vec::with_capacity(sizes.len());
    for &n in &sizes {
        println!("  subscribe n = {} ...", aidw::benchsuite::size_label(n));
        subscribe.push(aidw::benchsuite::measure_subscribe_reps(n, &opts, threads)?);
    }

    // layout ablation (PR 8): dense + local stage-2 under every stage-2
    // layout, bit-identity asserted inside the measurement
    let mut layouts = Vec::with_capacity(sizes.len());
    for &n in &sizes {
        println!("  layout n = {} ...", aidw::benchsuite::size_label(n));
        layouts.push(aidw::benchsuite::measure_layouts(&pool, n, &opts)?);
    }

    // sharded stage-1 sweep (PR 10): per-shard-count times with the
    // bit-identity contract asserted inside the measurement
    let mut shards = Vec::with_capacity(sizes.len());
    for &n in &sizes {
        println!("  shard n = {} ...", aidw::benchsuite::size_label(n));
        shards.push(aidw::benchsuite::measure_shards(&pool, n, &opts)?);
    }

    let artifact_dir = aidw::runtime::default_artifact_dir();
    let doc = if artifact_dir.join("manifest.json").exists() {
        println!("bench: PJRT artifacts found — full five-version suite");
        let engine = aidw::runtime::Engine::new(&artifact_dir)?;
        let mut results = Vec::with_capacity(sizes.len());
        for &n in &sizes {
            println!("  measuring n = {} ...", aidw::benchsuite::size_label(n));
            results.push(aidw::benchsuite::measure_size_reps(&engine, &pool, n, &opts)?);
        }
        aidw::benchsuite::pjrt_bench_json(
            &results,
            &planner,
            &live_cache,
            &subscribe,
            &layouts,
            &shards,
            pool.threads(),
            seed,
        )
    } else {
        println!("bench: no artifacts — CPU suite (serial + improved pipeline)");
        let mut results = Vec::with_capacity(sizes.len());
        for &n in &sizes {
            println!("  measuring n = {} ...", aidw::benchsuite::size_label(n));
            results.push(aidw::benchsuite::measure_size_cpu_reps(&pool, n, &opts));
        }
        aidw::benchsuite::cpu_bench_json(
            &results,
            &planner,
            &live_cache,
            &subscribe,
            &layouts,
            &shards,
            pool.threads(),
            seed,
        )
    };
    std::fs::write(&out_path, doc.to_string() + "\n")?;
    println!("wrote {out_path}");
    Ok(())
}

fn make_points(dist: &str, n: usize, side: f64, seed: u64) -> Result<PointSet> {
    Ok(match dist {
        "uniform" => workload::uniform_square(n, side, seed),
        "clustered" => workload::clustered(n, side, 8, side / 50.0, seed),
        "terrain" => workload::terrain_samples(n, side, 0.5, seed),
        "sensors" => workload::sensor_stations(n, side, seed),
        other => {
            return Err(Error::InvalidArgument(format!("unknown distribution '{other}'")))
        }
    })
}

/// Data source: `--file pts.csv` wins over the generated `--dist`.
fn load_or_make(args: &Args, n: usize, side: f64, seed: u64) -> Result<PointSet> {
    match args.get("file") {
        Some(path) => workload::csvio::load_points(std::path::Path::new(path)),
        None => make_points(&args.get_or("dist", "uniform"), n, side, seed),
    }
}

fn interpolate(args: &Args) -> Result<()> {
    let n_data = args.get_usize("data", 4096)?;
    let n_queries = args.get_usize("queries", 4096)?;
    let side = args.get_f64("side", 100.0)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let dist = args.get_or("dist", "uniform");

    let data = load_or_make(args, n_data, side, seed)?;
    let n_data = data.len();
    let queries = workload::uniform_square(n_queries, side, seed + 1).xy();

    // one facade, three engines: per-request options are identical across
    // them, so --engine switches the execution path without rewiring
    let session = match args.get_or("engine", "serving").as_str() {
        "serving" => AidwSession::serving(config_from(args)?)?,
        "pipeline" => AidwSession::in_process(),
        "serial" => AidwSession::serial(),
        other => {
            return Err(Error::InvalidArgument(format!(
                "unknown engine '{other}' (serving|pipeline|serial)"
            )))
        }
    };
    let options = options_from(args)?;
    println!(
        "backend={}  data={}  queries={}  dist={}",
        session.backend_label(),
        n_data,
        n_queries,
        dist
    );
    session.register("cli", data)?;
    let t0 = std::time::Instant::now();
    let reply = session.interpolate("cli", &queries, &options)?;
    let total = t0.elapsed().as_secs_f64();
    let o = &reply.options;
    println!(
        "ran with: k={} variant={} ring={} local={} alpha_levels={:?}",
        o.k,
        o.variant.tag(),
        o.ring_rule.tag(),
        match o.local_neighbors {
            Some(n) => format!("nearest-{n}"),
            None => "dense".into(),
        },
        o.alpha_levels,
    );
    println!(
        "done in {:.3}s  (stage1 kNN {:.3}s, stage2 interp {:.3}s)",
        total, reply.knn_s, reply.interp_s
    );
    println!(
        "throughput: {:.0} queries/s",
        n_queries as f64 / total
    );

    if let Some(out) = args.get("out") {
        let mut csv = String::from("x,y,z\n");
        for (q, z) in queries.iter().zip(&reply.values) {
            csv.push_str(&format!("{},{},{}\n", q.0, q.1, z));
        }
        std::fs::write(out, csv)?;
        println!("wrote {out}");
    } else {
        let show = reply.values.len().min(5);
        println!("first {show} predictions: {:?}", &reply.values[..show]);
    }
    Ok(())
}

/// Interpolate against a running service over TCP — the protocol-v2.4
/// client path.  With `--stream`, tiles are consumed (and optionally
/// written to `--out`) as they arrive off the socket: the client holds
/// one tile at a time, so rasters far larger than memory stream through
/// in constant space.
fn query(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| Error::InvalidArgument("--addr is required".into()))?;
    let dataset = args
        .get("dataset")
        .ok_or_else(|| Error::InvalidArgument("--dataset is required".into()))?;
    let n_queries = args.get_usize("queries", 4096)?;
    let side = args.get_f64("side", 100.0)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let queries = workload::uniform_square(n_queries, side, seed + 1).xy();
    let options = options_from(args)?;
    let mut client = aidw::service::Client::connect(addr)?;

    if !args.has("stream") {
        let t0 = std::time::Instant::now();
        let reply = client.interpolate_with(dataset, &queries, options)?;
        println!(
            "{} values in {:.3}s (stage1 {:.3}s, stage2 {:.3}s, cache_hit {})",
            reply.values.len(),
            t0.elapsed().as_secs_f64(),
            reply.knn_s,
            reply.interp_s,
            reply.cache_hit
        );
        if let Some(t) = &reply.trace {
            print_trace(t);
        }
        if let Some(out) = args.get("out") {
            write_csv(out, &queries, &reply.values)?;
            println!("wrote {out}");
        }
        return Ok(());
    }

    let t0 = std::time::Instant::now();
    let mut stream = client.interpolate_stream(dataset, &queries, options)?;
    println!(
        "streaming {} rows as {} tile(s) of <= {} rows",
        stream.rows, stream.n_tiles, stream.tile_rows
    );
    let n_tiles = stream.n_tiles;
    let mut sink: Option<std::io::BufWriter<std::fs::File>> = match args.get("out") {
        Some(out) => {
            use std::io::Write;
            let mut w = std::io::BufWriter::new(std::fs::File::create(out)?);
            writeln!(w, "x,y,z").map_err(Error::Io)?;
            Some(w)
        }
        None => None,
    };
    let mut rows = 0usize;
    while let Some(tile) = stream.next_tile() {
        let tile = tile?;
        // constant memory: each tile is consumed (printed/written) and
        // dropped before the next arrives
        if let Some(w) = sink.as_mut() {
            use std::io::Write;
            for (q, z) in queries[tile.row0..tile.row0 + tile.values.len()]
                .iter()
                .zip(&tile.values)
            {
                writeln!(w, "{},{},{}", q.0, q.1, z).map_err(Error::Io)?;
            }
        }
        rows += tile.values.len();
        println!(
            "  tile {}/{}: rows {}..{} ({:.1}%)",
            tile.tile_index + 1,
            n_tiles,
            tile.row0,
            tile.row0 + tile.values.len(),
            100.0 * rows as f64 / queries.len() as f64
        );
        drop(tile);
    }
    let done = stream
        .done()
        .cloned()
        .ok_or_else(|| Error::Service("stream ended without a done frame".into()))?;
    println!(
        "done in {:.3}s: {} rows (stage1 {:.3}s, stage2 {:.3}s, cache_hit {})",
        t0.elapsed().as_secs_f64(),
        rows,
        done.knn_s,
        done.interp_s,
        done.cache_hit
    );
    if let Some(t) = &done.trace {
        print_trace(t);
    }
    if let Some(out) = args.get("out") {
        println!("wrote {out} (incrementally, one tile at a time)");
    }
    Ok(())
}

/// Print a v2.6 span timeline (the `--trace` output).
fn print_trace(t: &aidw::obs::Trace) {
    println!(
        "trace: dataset={} epoch={} overlay={} stage1_fp={:016x}",
        t.dataset,
        t.epoch.map_or_else(|| "-".to_string(), |e| e.to_string()),
        t.overlay.map_or_else(|| "-".to_string(), |v| v.to_string()),
        t.stage1_fp
    );
    for s in &t.spans {
        let note = match (s.tile, s.saved_s) {
            (Some(tile), _) => format!("  (tile {tile})"),
            (None, Some(saved)) => format!("  (saved {saved:.6}s)"),
            (None, None) => String::new(),
        };
        println!("  {:<18} {:>12.6}s{note}", s.kind.tag(), s.seconds);
    }
    println!("  {:<18} {:>12.6}s", "total", t.total_s());
}

/// Page a running service's structured event journal (protocol v2.6).
fn events(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| Error::InvalidArgument("--addr is required".into()))?;
    let since = args.get_usize("since", 0)? as u64;
    let max = args.get_usize("max", 100)?;
    let mut client = aidw::service::Client::connect(addr)?;
    let page = client.events(since, max)?;
    if page.dropped > 0 {
        println!(
            "(journal ring buffer has overwritten {} event(s) since startup)",
            page.dropped
        );
    }
    for e in &page.events {
        println!(
            "{:>6}  {:>13}  {:<5}  {:<18}  {:<12}  {}{}",
            e.seq,
            e.unix_ms,
            e.severity,
            e.kind,
            e.dataset.as_deref().unwrap_or("-"),
            e.detail,
            e.mut_seq.map_or_else(String::new, |s| format!("  [mut_seq {s}]")),
        );
    }
    println!(
        "{} event(s); poll again with --since {} to tail",
        page.events.len(),
        page.next_seq
    );
    Ok(())
}

/// Hold a standing raster against a running service (protocol v2.5):
/// subscribe, materialize the initial raster, then print each pushed
/// update — only the dirty tiles travel, and the client-side raster
/// stays bit-identical to a from-scratch query at the served snapshot.
fn subscribe(args: &Args) -> Result<()> {
    let addr = args
        .get("addr")
        .ok_or_else(|| Error::InvalidArgument("--addr is required".into()))?;
    let dataset = args
        .get("dataset")
        .ok_or_else(|| Error::InvalidArgument("--dataset is required".into()))?;
    let n_queries = args.get_usize("queries", 1024)?;
    let side = args.get_f64("side", 100.0)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let queries = workload::uniform_square(n_queries, side, seed + 1).xy();
    let options = options_from(args)?;
    // 0 = stay subscribed until the server terminates the feed
    let max_updates = args.get_usize("updates", 0)?;

    let mut client = aidw::service::Client::connect(addr)?;
    let mut sub = client.subscribe(dataset, &queries, options)?;
    println!(
        "subscription {}: {} rows as {} tile(s) of <= {} rows",
        sub.sub, sub.rows, sub.n_tiles, sub.tile_rows
    );
    let mut raster = vec![f64::NAN; sub.rows];
    let mut incremental = 0usize;
    loop {
        let u = match sub.next_update() {
            Ok(u) => u,
            Err(e) => {
                println!("subscription terminated: {e}");
                break;
            }
        };
        u.apply(&mut raster);
        if u.update == 0 {
            println!("initial raster materialized ({} tiles)", u.tiles.len());
        } else {
            incremental += 1;
            println!(
                "update {}: epoch {} overlay {} — {} dirty tile(s) pushed, {} clean skipped",
                u.update,
                u.epoch,
                u.overlay,
                u.tiles.len(),
                u.skipped_clean
            );
        }
        if max_updates > 0 && incremental >= max_updates {
            sub.unsubscribe()?;
            println!("unsubscribed after {incremental} incremental update(s)");
            break;
        }
    }
    if let Some(out) = args.get("out") {
        write_csv(out, &queries, &raster)?;
        println!("wrote {out} (the last materialized raster)");
    }
    Ok(())
}

/// Shared CSV writer for the non-streaming paths.
fn write_csv(path: &str, queries: &[(f64, f64)], values: &[f64]) -> Result<()> {
    let mut csv = String::from("x,y,z\n");
    for (q, z) in queries.iter().zip(values) {
        csv.push_str(&format!("{},{},{}\n", q.0, q.1, z));
    }
    std::fs::write(path, csv)?;
    Ok(())
}

fn generate(args: &Args) -> Result<()> {
    let n = args.get_usize("n", 10240)?;
    let side = args.get_f64("side", 100.0)?;
    let seed = args.get_usize("seed", 42)? as u64;
    let dist = args.get_or("dist", "uniform");
    let out = args
        .get("out")
        .ok_or_else(|| Error::InvalidArgument("--out is required".into()))?;
    let pts = make_points(&dist, n, side, seed)?;
    let mut csv = String::from("x,y,z\n");
    for i in 0..pts.len() {
        csv.push_str(&format!("{},{},{}\n", pts.xs[i], pts.ys[i], pts.zs[i]));
    }
    std::fs::write(out, csv)?;
    println!("wrote {n} {dist} points to {out}");
    Ok(())
}

/// `aidw tidy` — run the repo-invariant static analyzer (src/analysis/)
/// over this crate's own sources and exit nonzero on any finding.
fn tidy(args: &Args) -> Result<()> {
    let src = aidw::analysis::locate_src_dir(args.get("root")).ok_or_else(|| {
        Error::InvalidArgument(
            "tidy: cannot find the crate sources (expected rust/src or src \
             with lib.rs; point --root at a checkout)"
                .into(),
        )
    })?;
    let report = aidw::analysis::run(&src)
        .map_err(|e| Error::Service(format!("tidy: walking {}: {e}", src.display())))?;
    if args.has("json") {
        println!("{}", report.to_json().to_string());
    } else {
        print!("{}", report.render_text());
    }
    if report.clean() {
        Ok(())
    } else {
        Err(Error::Service(format!(
            "tidy: {} finding(s) in {}",
            report.findings.len(),
            src.display()
        )))
    }
}

fn info() -> Result<()> {
    let dir = aidw::runtime::default_artifact_dir();
    println!("artifact dir: {}", dir.display());
    if !dir.join("manifest.json").exists() {
        println!("no manifest found — run `make artifacts`");
        return Ok(());
    }
    let engine = aidw::runtime::Engine::new(&dir)?;
    let man = engine.manifest();
    println!("platform: {}", engine.platform());
    println!(
        "shapes: prod q{} m{}, test q{} m{}, k_buf {}",
        man.q_prod, man.m_prod, man.q_test, man.m_test, man.k_buf
    );
    println!("artifacts ({}):", man.artifacts.len());
    for a in &man.artifacts {
        println!(
            "  {:<44} {} in / {} out",
            a.name,
            a.inputs.len(),
            a.outputs.len()
        );
    }
    Ok(())
}
